//! `bench trace` — the two tracing contracts CI blocks on:
//!
//! 1. **Overhead** — serving throughput with `--trace on` must not be
//!    *significantly* worse than 95% of the untraced throughput (a 5%
//!    overhead allowance). The comparison is a one-sided Welch test
//!    over adaptively many repetitions, never a point comparison of two
//!    single runs: real wall clock is noisy and tracing overhead on
//!    this workload is far below the noise floor.
//! 2. **Schema** — a traced run that exercises every span source at
//!    once (sharded fan-out, straggler speculation, gentle chaos, the
//!    batcher) must produce a span set that passes
//!    [`check_well_formed`] and exports as Chrome trace-event JSON.
//!    The JSON itself is written for the CI python validator, which
//!    re-checks event structure and parent/child ordering with a real
//!    JSON parser.
//!
//! The traced contract run is chaos-seeded, so a CI schema failure
//! replays locally with the same injection schedule.

use crate::coordinator::barrier::SpeculateConfig;
use crate::coordinator::chaos::ChaosConfig;
use crate::coordinator::serve::{Serve, ServeConfig, ServeResult};
use crate::gen::uniform::Uniform;
use crate::obs::{check_well_formed, chrome_trace_json};
use crate::sparse::Csr;
use crate::util::rng::Rng;
use crate::util::stats::{not_worse_gate, AdaptiveConfig, GateResult, Samples};
use anyhow::{ensure, Result};
use std::time::Instant;

/// Chaos seed for the traced contract run (deterministic schedule).
pub const TRACE_CHAOS_SEED: u64 = 0x0B5E;

/// Tracing overhead allowed by the gate: `on` throughput is compared
/// against `off × (1 − this)`.
pub const OVERHEAD_ALLOWANCE: f64 = 0.05;

/// The full `bench trace` report (`BENCH_trace.json` plus the emitted
/// Chrome trace for the python schema validator).
#[derive(Clone, Debug)]
pub struct TraceBenchReport {
    pub jobs: usize,
    /// Repetition-0 display figures; the gate verdict pools all reps.
    pub off_throughput_jobs_per_s: f64,
    pub on_throughput_jobs_per_s: f64,
    /// Contract-run figures: spans retained, instant events, chaos
    /// instants among them, per-shard sub-job spans, slow exemplars
    /// kept, ring evictions.
    pub spans: usize,
    pub instants: usize,
    pub chaos_instants: usize,
    pub shard_spans: usize,
    pub slow_exemplars: usize,
    pub dropped_spans: u64,
    /// [`check_well_formed`] verdict over the contract run's spans.
    pub well_formed: bool,
    pub well_formed_err: Option<String>,
    /// Requests of the contract run that resolved `Done`.
    pub completed: usize,
    /// The contract run's Chrome trace-event JSON (written next to the
    /// report for the CI validator).
    pub chrome_json: String,
    pub gates: Vec<GateResult>,
}

/// One untraced-vs-traced throughput measurement: the same distinct-job
/// stream through an otherwise default front door.
fn throughput_once(trace_on: bool, mats: &[Csr]) -> Result<f64> {
    let mut cfg = ServeConfig::default();
    cfg.workers = 2;
    cfg.ns_per_prod = Some(1.0);
    cfg.trace.enabled = trace_on;
    let serve = Serve::start(cfg)?;
    let t0 = Instant::now();
    let tickets: Vec<_> =
        mats.iter().map(|m| serve.submit("bench", m.clone(), m.clone())).collect();
    for t in tickets {
        ensure!(
            matches!(t.wait(), ServeResult::Done { .. }),
            "trace bench throughput job failed"
        );
    }
    let wall_ns = t0.elapsed().as_nanos() as u64;
    serve.shutdown();
    Ok(mats.len() as f64 / (wall_ns.max(1) as f64 / 1e9))
}

/// The schema contract run: sharded + speculative + chaos-gentle +
/// batched traffic with tracing on, returning the span-set figures and
/// the exported Chrome JSON.
fn contract_run(jobs: usize, report: &mut TraceBenchReport) -> Result<()> {
    let mut cfg = ServeConfig::default();
    cfg.workers = 3;
    cfg.ns_per_prod = Some(1.0);
    // coalescing off so every submit executes: the contract wants many
    // real shard fan-outs, not one leader and N attaches
    cfg.coalesce = false;
    cfg.batch.enabled = true;
    cfg.batch.max_jobs = 4;
    cfg.speculate = SpeculateConfig::on();
    cfg.chaos = ChaosConfig::gentle().with_seed(TRACE_CHAOS_SEED);
    // a 4 KiB device budget forces the big pattern onto the sharded
    // route (same idiom as the serve bench's persistence phase)
    cfg.device_memory_bytes = 4096;
    cfg.max_devices = 4;
    cfg.interconnect = None;
    cfg.trace.enabled = true;
    cfg.trace.slow_k = 4;
    let serve = Serve::start(cfg)?;
    let tracer = serve.tracer().cloned().expect("tracing on must construct a tracer");
    let big = Uniform { n: 300, per_row: 6, jitter: 2 }.generate(&mut Rng::new(41));
    let small = Uniform { n: 120, per_row: 5, jitter: 2 }.generate(&mut Rng::new(42));
    let tickets: Vec<_> = (0..jobs)
        .map(|i| {
            let m = if i % 2 == 0 { &big } else { &small };
            serve.submit(if i % 2 == 0 { "shard" } else { "hash" }, m.clone(), m.clone())
        })
        .collect();
    let mut completed = 0usize;
    for t in tickets {
        if matches!(t.wait(), ServeResult::Done { .. }) {
            completed += 1;
        }
    }
    serve.shutdown();
    let spans = tracer.snapshot_spans();
    report.completed = completed;
    report.spans = spans.len();
    report.instants = spans.iter().filter(|s| s.instant).count();
    report.chaos_instants =
        spans.iter().filter(|s| s.instant && s.name.starts_with("chaos_")).count();
    report.shard_spans = spans.iter().filter(|s| s.name == "shard").count();
    report.slow_exemplars = tracer.slow_exemplars().len();
    report.dropped_spans = tracer.dropped();
    match check_well_formed(&spans) {
        Ok(()) => report.well_formed = true,
        Err(e) => {
            report.well_formed = false;
            report.well_formed_err = Some(e);
        }
    }
    report.chrome_json = chrome_trace_json(&spans);
    Ok(())
}

/// The `bench trace` entry: overhead gate + schema contract, printed as
/// a table and returned for JSON recording. The hard contracts
/// (well-formedness, every request resolved) are asserted by the bench
/// binary and the CI check, not here — this function only measures.
pub fn trace_overhead(jobs: usize) -> Result<TraceBenchReport> {
    let jobs = jobs.max(4);
    let mut rng = Rng::new(2028);
    // distinct value fingerprints per job, so coalescing never collapses
    // the stream and both modes execute every multiply
    let mats: Vec<Csr> =
        (0..jobs).map(|_| Uniform { n: 150, per_row: 6, jitter: 3 }.generate(&mut rng)).collect();
    println!("trace bench: {jobs} distinct jobs, overhead allowance {OVERHEAD_ALLOWANCE}");
    let mut report = TraceBenchReport {
        jobs,
        off_throughput_jobs_per_s: 0.0,
        on_throughput_jobs_per_s: 0.0,
        spans: 0,
        instants: 0,
        chaos_instants: 0,
        shard_spans: 0,
        slow_exemplars: 0,
        dropped_spans: 0,
        well_formed: false,
        well_formed_err: None,
        completed: 0,
        chrome_json: String::new(),
        gates: Vec::new(),
    };
    let stat = AdaptiveConfig::from_env();
    let mut off = Samples::from_values(vec![throughput_once(false, &mats)?]);
    let mut on = Samples::from_values(vec![throughput_once(true, &mats)?]);
    report.off_throughput_jobs_per_s = off.values[0];
    report.on_throughput_jobs_per_s = on.values[0];
    while on.n() < stat.max_reps.max(stat.min_reps).max(2)
        && !(stat.converged(&on) && stat.converged(&off))
    {
        off.push(throughput_once(false, &mats)?);
        on.push(throughput_once(true, &mats)?);
    }
    // the reference is the untraced throughput scaled down by the
    // allowance: "on is not significantly worse than 95% of off"
    let off_scaled = Samples::from_values(
        off.values.iter().map(|v| v * (1.0 - OVERHEAD_ALLOWANCE)).collect(),
    );
    let gate = not_worse_gate("trace_overhead_within_5pct", &on, &off_scaled, true, stat.alpha);
    println!(
        "  overhead gate: {} (p={:.4}, alpha={}, traced {:.1} vs 95%-of-untraced {:.1} jobs/s \
         over {} reps)",
        if gate.pass { "pass" } else { "FAIL" },
        gate.p,
        gate.alpha,
        gate.candidate_mean,
        gate.reference_mean,
        gate.reps_candidate
    );
    report.gates.push(gate);
    contract_run(jobs, &mut report)?;
    println!(
        "  contract run: {}/{} completed, {} spans ({} instants, {} chaos, {} shard), \
         {} exemplars, {} dropped, well_formed {}",
        report.completed,
        jobs,
        report.spans,
        report.instants,
        report.chaos_instants,
        report.shard_spans,
        report.slow_exemplars,
        report.dropped_spans,
        report.well_formed
    );
    if let Some(e) = &report.well_formed_err {
        eprintln!("  well-formedness violation: {e}");
    }
    Ok(report)
}
