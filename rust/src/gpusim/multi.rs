//! Multi-device view: aggregate per-device timelines into makespan and
//! scaling figures, charging inter-device transfers against an
//! [`Interconnect`] model.
//!
//! A sharded SpGEMM run produces one [`Trace`] per simulated device (see
//! [`crate::spgemm::sharded`]). The devices execute concurrently — each
//! has its own host thread, streams, and SMs — so the compute figure is
//! the **makespan**: the critical path, i.e. the slowest device's wall
//! time. Row sharding additionally replicates `B` on every device (a
//! one-to-all broadcast before compute) and gathers the `C` row blocks
//! back to the root device afterwards; both ride the interconnect, not
//! HBM, and on small jobs they dominate — this is exactly where
//! bhSPARSE-style heterogeneous frameworks report communication-bound
//! scaling. [`MultiDevice::simulate_with_interconnect`] charges both
//! phases, so efficiency figures stop over-reporting for small jobs;
//! [`MultiDevice::simulate`] keeps the transfer-free view (both costs 0).

use super::device::DeviceParams;
use super::scheduler::{simulate, simulate_with_arrivals};
use super::timeline::{LaneSpan, OverlapLanes, Timeline};
use super::trace::Trace;
use anyhow::{ensure, Result};

/// Upper bound on broadcast chunks per transfer: real pipelines bound
/// their staging-buffer count, and past this the overlap granularity
/// gains nothing while the event graph keeps growing.
pub const MAX_CHUNKS: usize = 64;

/// Knobs of the overlapped (pipelined broadcast/compute/gather)
/// multi-device execution model. `chunk_bytes` sets the row-panel
/// granularity the `B` broadcast is streamed at: coarse chunks delay the
/// first symbolic kernels (less overlap), fine chunks pipeline tighter
/// but add per-chunk forwarding steps on a ring (see
/// [`Interconnect::chunk_arrivals`]). `enabled: false` keeps the serial
/// three-phase model everywhere — the honest ablation baseline.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OverlapConfig {
    pub enabled: bool,
    /// Target broadcast chunk size in bytes (clamped to [`MAX_CHUNKS`]
    /// chunks per transfer). Default 1 MiB.
    pub chunk_bytes: usize,
}

impl Default for OverlapConfig {
    fn default() -> Self {
        OverlapConfig { enabled: true, chunk_bytes: 1 << 20 }
    }
}

impl OverlapConfig {
    /// The serial baseline: no chunking, no overlap.
    pub fn off() -> OverlapConfig {
        OverlapConfig { enabled: false, ..OverlapConfig::default() }
    }

    /// Defaults overridden by the environment: `OPSPARSE_OVERLAP=off|0`
    /// disables overlap (case-insensitive; `on|1|true` enables, anything
    /// else keeps the default rather than silently enabling),
    /// `OPSPARSE_OVERLAP_CHUNK_KB=<n>` sets the chunk size (benches and
    /// the CLI read both; an unparseable, zero, or overflowing value
    /// keeps the default).
    pub fn from_env() -> OverlapConfig {
        let mut cfg = OverlapConfig::default();
        if let Ok(v) = std::env::var("OPSPARSE_OVERLAP") {
            match v.to_ascii_lowercase().as_str() {
                "on" | "1" | "true" => cfg.enabled = true,
                "off" | "0" | "false" => cfg.enabled = false,
                _ => {}
            }
        }
        if let Some(bytes) = std::env::var("OPSPARSE_OVERLAP_CHUNK_KB")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&kb| kb > 0)
            .and_then(|kb| kb.checked_mul(1024))
        {
            cfg.chunk_bytes = bytes;
        }
        cfg
    }

    /// Chunks a `bytes`-sized broadcast splits into under this config
    /// (1 when disabled — a single chunk is the unpipelined transfer).
    pub fn chunks_for(&self, bytes: usize) -> usize {
        if !self.enabled || bytes == 0 {
            return 1;
        }
        bytes.div_ceil(self.chunk_bytes.max(1)).clamp(1, MAX_CHUNKS)
    }
}

/// Fan-out pattern of the inter-device links.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    /// The root device pushes a full copy to every peer through its own
    /// link, one peer at a time (PCIe devices under one host bridge):
    /// broadcast cost grows linearly with the device count.
    OneToAll,
    /// Devices form a ring and broadcasts pipeline chunks around it
    /// (NVLink-style): the bandwidth term flattens out as the fleet
    /// grows, so a ring beats one-to-all at high device counts.
    Ring,
}

/// Inter-device interconnect: per-link bandwidth, per-message latency,
/// and topology. `bandwidth_gbps` is in GB/s, which conveniently equals
/// bytes/ns.
///
/// # Example
///
/// ```
/// use opsparse::gpusim::{Interconnect, Topology};
///
/// let pcie = Interconnect::pcie3();
/// let one_to_all = pcie.broadcast_ns(1 << 20, 8).unwrap();
/// let ring =
///     Interconnect { topology: Topology::Ring, ..pcie }.broadcast_ns(1 << 20, 8).unwrap();
/// assert!(ring < one_to_all, "pipelined ring wins at high device counts");
/// ```
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Interconnect {
    /// Per-link bandwidth in GB/s (== bytes/ns). Must be positive.
    pub bandwidth_gbps: f64,
    /// Per-message latency in microseconds.
    pub latency_us: f64,
    pub topology: Topology,
}

impl Interconnect {
    /// PCIe 3.0 x16 under one host bridge: ~12 GB/s effective, one
    /// transfer at a time through the root's link.
    pub const fn pcie3() -> Interconnect {
        Interconnect { bandwidth_gbps: 12.0, latency_us: 5.0, topology: Topology::OneToAll }
    }

    /// NVLink ring (V100 DGX-style): ~150 GB/s per direction, pipelined
    /// ring collectives.
    pub const fn nvlink() -> Interconnect {
        Interconnect { bandwidth_gbps: 150.0, latency_us: 1.5, topology: Topology::Ring }
    }

    /// Parse a preset name (`pcie` | `nvlink`), for CLI/env flags.
    pub fn parse(s: &str) -> Option<Interconnect> {
        match s {
            "pcie" | "pcie3" => Some(Interconnect::pcie3()),
            "nvlink" => Some(Interconnect::nvlink()),
            _ => None,
        }
    }

    /// [`Interconnect::parse`] plus the `none` sentinel (no interconnect
    /// charged): `Some(None)` for `"none"`, `Some(Some(_))` for a known
    /// preset, `None` for anything else. The one parser shared by the
    /// `bench shards` CLI flag and the `OPSPARSE_INTERCONNECT` env var,
    /// so both accept exactly the same names.
    pub fn parse_opt(s: &str) -> Option<Option<Interconnect>> {
        if s == "none" {
            Some(None)
        } else {
            Interconnect::parse(s).map(Some)
        }
    }

    fn check(&self) -> Result<()> {
        ensure!(
            self.bandwidth_gbps.is_finite() && self.bandwidth_gbps > 0.0,
            "interconnect bandwidth must be positive and finite, got {} GB/s",
            self.bandwidth_gbps
        );
        ensure!(
            self.latency_us.is_finite() && self.latency_us >= 0.0,
            "interconnect latency must be non-negative, got {} us",
            self.latency_us
        );
        Ok(())
    }

    fn latency_ns(&self) -> f64 {
        self.latency_us * 1e3
    }

    /// Wire time of one chunk when `bytes` stream in `chunks` panels —
    /// the per-chunk rate every arrival schedule here is built from.
    /// Exposed so feedback consumers (chunk-size tuning) read the same
    /// figure the simulator charges instead of re-deriving it.
    pub fn chunk_xfer_ns(&self, bytes: usize, chunks: usize) -> f64 {
        bytes as f64 / chunks.max(1) as f64 / self.bandwidth_gbps
    }

    /// Per-message (hop) latency in ns — the other half of the chunk
    /// trade-off the tuner weighs.
    pub fn hop_latency_ns(&self) -> f64 {
        self.latency_ns()
    }

    /// Time to replicate `bytes` from the root onto the other
    /// `n_devices - 1` devices. Zero for a single device. Errors on a
    /// non-positive bandwidth instead of dividing by zero.
    pub fn broadcast_ns(&self, bytes: usize, n_devices: usize) -> Result<f64> {
        self.check()?;
        if n_devices <= 1 {
            return Ok(0.0);
        }
        let hops = (n_devices - 1) as f64;
        let xfer = bytes as f64 / self.bandwidth_gbps;
        Ok(match self.topology {
            Topology::OneToAll => hops * (self.latency_ns() + xfer),
            // pipelined ring (scatter + forward): the bandwidth term
            // approaches 2x one link's transfer time as the ring grows
            Topology::Ring => hops * self.latency_ns() + xfer * 2.0 * hops / n_devices as f64,
        })
    }

    /// Time to gather per-device result blocks onto the root device
    /// (`block_bytes[0]` is the root's own block and moves nothing).
    /// Zero for a single device; errors on a non-positive bandwidth.
    pub fn gather_ns(&self, block_bytes: &[usize]) -> Result<f64> {
        self.check()?;
        if block_bytes.len() <= 1 {
            return Ok(0.0);
        }
        let hops = (block_bytes.len() - 1) as f64;
        let nonroot: f64 = block_bytes[1..].iter().map(|&b| b as f64).sum();
        // same cost on both topologies: whether blocks serialize through
        // the root's link directly (one-to-all) or forward around the
        // ring, the link into the root carries every non-root byte
        Ok(hops * self.latency_ns() + nonroot / self.bandwidth_gbps)
    }

    /// Arrival time of each broadcast chunk on each device when `bytes`
    /// stream from the root in `chunks` row panels: `result[d][k]` is the
    /// instant chunk `k` is resident on device `d` (the root, device 0,
    /// owns the data — all zeros). The last chunk's arrival on the last
    /// device never exceeds [`Interconnect::broadcast_ns`]: chunking
    /// re-times *when* data lands, it does not invent bandwidth.
    ///
    /// * `OneToAll`: the root's link sends chunk-major (chunk 0 to every
    ///   peer, then chunk 1, …) over an open DMA stream per peer — the
    ///   per-message latency is a stream-head cost, paid once per peer,
    ///   and the final arrival lands exactly at the serial broadcast
    ///   time.
    /// * `Ring`: chunks forward hop by hop, pipelined (hop `h` forwards
    ///   chunk `k` while receiving `k+1`). Each chunk pays the hop
    ///   latency at every hop — the latency-per-chunk side of the
    ///   trade-off — so with fewer chunks than devices the pipeline
    ///   cannot fill and the model falls back to the serial
    ///   scatter-allgather schedule (delivering chunks at its steady
    ///   rate), whichever finishes first.
    pub fn chunk_arrivals(
        &self,
        bytes: usize,
        n_devices: usize,
        chunks: usize,
    ) -> Result<Vec<Vec<f64>>> {
        self.check()?;
        let k = chunks.max(1);
        if n_devices <= 1 {
            return Ok(vec![vec![0.0; k]; n_devices.max(1)]);
        }
        let peers = n_devices - 1;
        let cx = self.chunk_xfer_ns(bytes, k);
        let lat = self.latency_ns();
        let mut arr = vec![vec![0.0f64; k]; n_devices];
        match self.topology {
            Topology::OneToAll => {
                // link event e = c*peers + (p-1): one chunk to one peer;
                // per-peer stream-head latency charged on the link at the
                // peer's first chunk, so the total equals broadcast_ns
                for (c, p) in (0..k).flat_map(|c| (1..n_devices).map(move |p| (c, p))) {
                    let e = c * peers + (p - 1);
                    arr[p][c] = (e + 1) as f64 * cx + (e + 1).min(peers) as f64 * lat;
                }
            }
            Topology::Ring => {
                let serial = self.broadcast_ns(bytes, n_devices)?;
                // pipelined store-and-forward: chunk c reaches hop h at
                // h hops of latency plus (h + c) chunk transfers
                let sf_last = peers as f64 * lat + (peers + k - 1) as f64 * cx;
                if sf_last <= serial + 1e-9 {
                    for (p, row) in arr.iter_mut().enumerate().skip(1) {
                        for (c, slot) in row.iter_mut().enumerate() {
                            *slot = p as f64 * lat + (p + c) as f64 * cx;
                        }
                    }
                } else {
                    // too few chunks to fill the ring pipeline: the bulk
                    // scatter-allgather (the serial algorithm) is faster;
                    // it streams at a steady rate after the latency fill
                    let fill = peers as f64 * lat;
                    let steady = (serial - fill).max(0.0) / k as f64;
                    for (p, row) in arr.iter_mut().enumerate().skip(1) {
                        for (c, slot) in row.iter_mut().enumerate() {
                            *slot = fill * p as f64 / peers as f64 + (c + 1) as f64 * steady;
                        }
                    }
                }
            }
        }
        Ok(arr)
    }

    /// Closed-form overlapped-makespan estimate on `n_devices` uniform
    /// devices, for planning (no traces): the broadcast streams in
    /// [`OverlapConfig::chunks_for`] chunks, each device runs
    /// `sym_fraction` of `per_device_compute_ns` as chunk-gated symbolic
    /// segments and the rest after the last chunk, and finished devices
    /// stream-gather their `c_block_bytes` entry. This is the same event
    /// model [`MultiDevice::simulate_overlapped`] replays on real traces,
    /// collapsed onto the router's scalar compute proxy — so the router's
    /// shard-count decision and the simulator agree on *shape*. Never
    /// exceeds `broadcast + compute + gather` (the serial schedule).
    pub fn overlapped_estimate_ns(
        &self,
        b_bytes: usize,
        per_device_compute_ns: f64,
        sym_fraction: f64,
        c_block_bytes: &[usize],
        overlap: &OverlapConfig,
    ) -> Result<f64> {
        let n = c_block_bytes.len();
        if n <= 1 {
            return Ok(per_device_compute_ns);
        }
        let chunks = overlap.chunks_for(b_bytes);
        let arrivals = self.chunk_arrivals(b_bytes, n, chunks)?;
        let serial_bcast = self.broadcast_ns(b_bytes, n)?;
        let frac = sym_fraction.clamp(0.0, 1.0);
        let seg = per_device_compute_ns * frac / chunks as f64;
        let rest = per_device_compute_ns * (1.0 - frac);
        let finish: Vec<f64> = (0..n)
            .map(|d| {
                let mut t = 0.0f64;
                for &a in &arrivals[d] {
                    t = t.max(a) + seg;
                }
                (t + rest).min(serial_bcast + per_device_compute_ns)
            })
            .collect();
        let (done, _) = self.stream_gather(&finish, c_block_bytes)?;
        let serial =
            serial_bcast + per_device_compute_ns + self.gather_ns(c_block_bytes)?;
        Ok(done.max(finish.iter().cloned().fold(0.0, f64::max)).min(serial))
    }

    /// Streaming `C` gather: device `d`'s row block departs the moment
    /// the device finishes computing (`finish_ns[d]`) instead of waiting
    /// for the whole fleet — early finishers gather under the
    /// stragglers' compute. Blocks serialize on the link into the root in
    /// finish order; `OneToAll` pays the per-block latency on that link
    /// (summing to the serial gather's latency term), a `Ring` pipelines
    /// the forwarding hops so the latency rides outside the link
    /// occupancy. Returns the gather completion time and one transfer
    /// lane span per moved block. Never later than waiting for the
    /// slowest device and then paying [`Interconnect::gather_ns`].
    pub fn stream_gather(
        &self,
        finish_ns: &[f64],
        block_bytes: &[usize],
    ) -> Result<(f64, Vec<LaneSpan>)> {
        self.check()?;
        ensure!(
            finish_ns.len() == block_bytes.len(),
            "{} finish times for {} blocks",
            finish_ns.len(),
            block_bytes.len()
        );
        let max_finish = finish_ns.iter().cloned().fold(0.0, f64::max);
        if finish_ns.len() <= 1 {
            return Ok((max_finish, Vec::new()));
        }
        let lat = self.latency_ns();
        let mut order: Vec<usize> = (1..finish_ns.len()).collect();
        order.sort_by(|&a, &b| finish_ns[a].partial_cmp(&finish_ns[b]).unwrap().then(a.cmp(&b)));
        let mut busy = 0.0f64;
        let mut spans = Vec::with_capacity(order.len());
        for d in order {
            let xfer = block_bytes[d] as f64 / self.bandwidth_gbps;
            let (start, end) = match self.topology {
                // the root's link carries the block and its message
                // latency back to back
                Topology::OneToAll => {
                    let s = busy.max(finish_ns[d]);
                    (s, s + lat + xfer)
                }
                // forwarding latency overlaps with other blocks in
                // flight; only the transfer occupies the root's link
                Topology::Ring => {
                    let s = busy.max(finish_ns[d] + lat);
                    (s, s + xfer)
                }
            };
            busy = end;
            spans.push(LaneSpan::new(format!("gather d{d}"), start, end));
        }
        Ok((busy.max(finish_ns[0]), spans))
    }
}

/// Result of one overlapped (pipelined) multi-device simulation,
/// attached to a [`MultiDevice`] by
/// [`MultiDevice::simulate_overlapped`]. The serial figures on the
/// parent stay what they were — this report carries the pipelined view.
#[derive(Clone, Debug)]
pub struct OverlapReport {
    /// End-to-end pipelined critical path: chunked broadcast feeding
    /// per-device compute, early finishers gathering under stragglers.
    /// Never exceeds the serial [`MultiDevice::makespan_ns`].
    pub makespan_ns: f64,
    /// Broadcast chunks the `B` transfer streamed as.
    pub chunks: usize,
    /// Per-device compute completion under chunk-arrival dependencies.
    pub device_finish_ns: Vec<f64>,
    /// Transfer/compute lane occupancy (diagram + overlap metrics).
    pub lanes: OverlapLanes,
}

/// Per-device simulation results of one multi-device run, plus the
/// modeled interconnect transfers that bracket the compute phase.
#[derive(Clone, Debug, Default)]
pub struct MultiDevice {
    /// One timeline per device, in device order.
    pub timelines: Vec<Timeline>,
    /// Modeled `B` replication cost before compute (0 when simulated
    /// without an interconnect, or with a single device).
    pub broadcast_ns: f64,
    /// Modeled `C` row-block gather cost after compute (0 when simulated
    /// without an interconnect, or with a single device).
    pub gather_ns: f64,
    /// The pipelined view, when simulated via
    /// [`MultiDevice::simulate_overlapped`].
    pub overlap: Option<OverlapReport>,
}

impl MultiDevice {
    /// Simulate one trace per device against the same device model, with
    /// free inter-device transfers (the PR 2 view; see
    /// [`MultiDevice::simulate_with_interconnect`] for the honest one).
    pub fn simulate<'a, I>(traces: I, dev: &DeviceParams) -> MultiDevice
    where
        I: IntoIterator<Item = &'a Trace>,
    {
        MultiDevice {
            timelines: traces.into_iter().map(|t| simulate(t, dev)).collect(),
            broadcast_ns: 0.0,
            gather_ns: 0.0,
            overlap: None,
        }
    }

    /// [`MultiDevice::simulate`], charging the one-to-all/ring `B`
    /// broadcast (`b_bytes` replicated onto every non-root device) and
    /// the `C` row-block gather (`c_block_bytes`, one entry per device)
    /// against `ic`. `c_block_bytes` must have one entry per trace.
    pub fn simulate_with_interconnect<'a, I>(
        traces: I,
        dev: &DeviceParams,
        ic: &Interconnect,
        b_bytes: usize,
        c_block_bytes: &[usize],
    ) -> Result<MultiDevice>
    where
        I: IntoIterator<Item = &'a Trace>,
    {
        let mut md = MultiDevice::simulate(traces, dev);
        ensure!(
            c_block_bytes.len() == md.n_devices(),
            "{} C blocks for {} devices",
            c_block_bytes.len(),
            md.n_devices()
        );
        md.broadcast_ns = ic.broadcast_ns(b_bytes, md.n_devices())?;
        md.gather_ns = ic.gather_ns(c_block_bytes)?;
        Ok(md)
    }

    /// The overlapped (event/dependency) counterpart of
    /// [`MultiDevice::simulate_with_interconnect`]: the `B` broadcast
    /// streams as row-panel chunks whose arrivals gate each device's
    /// trace at its [`crate::gpusim::TraceOp::AwaitChunk`] markers
    /// (already-received panels feed the first symbolic kernels), and
    /// each device's `C` row block starts gathering the moment that
    /// device finishes, while stragglers are still computing. The chunk
    /// count is read off the traces' annotations (see
    /// `spgemm::sharded::multiply_sharded_with` and [`OverlapConfig`]);
    /// an unannotated trace conservatively waits for its device's full
    /// copy of `B`.
    ///
    /// The serial fields (`broadcast_ns`, `gather_ns`, the timelines, and
    /// therefore [`MultiDevice::makespan_ns`]) still describe the serial
    /// three-phase schedule of the *same* traces, so one call yields the
    /// honest before/after pair; the pipelined figure is
    /// [`MultiDevice::overlapped_makespan_ns`]. It can never exceed the
    /// serial makespan: a device that would somehow lose by pipelining
    /// falls back to deferring compute until the bulk broadcast lands —
    /// the serial schedule is always available — and the model charges
    /// whichever finishes first.
    pub fn simulate_overlapped<'a, I>(
        traces: I,
        dev: &DeviceParams,
        ic: &Interconnect,
        b_bytes: usize,
        c_block_bytes: &[usize],
    ) -> Result<MultiDevice>
    where
        I: IntoIterator<Item = &'a Trace>,
    {
        let traces: Vec<&Trace> = traces.into_iter().collect();
        let mut md = MultiDevice::simulate_with_interconnect(
            traces.iter().copied(),
            dev,
            ic,
            b_bytes,
            c_block_bytes,
        )?;
        let n = md.n_devices();
        let chunks = traces.iter().map(|t| t.chunk_deps()).max().unwrap_or(0).max(1);
        let arrivals = ic.chunk_arrivals(b_bytes, n, chunks)?;
        let chunk_xfer = ic.chunk_xfer_ns(b_bytes, chunks);

        let mut finish = Vec::with_capacity(n);
        let mut lanes = OverlapLanes::default();
        for (d, trace) in traces.iter().enumerate() {
            let serial_ns = md.timelines[d].total_ns;
            let full_arrival = arrivals[d].last().copied().unwrap_or(0.0);
            let f = if trace.chunk_deps() > 0 {
                let piped = simulate_with_arrivals(trace, dev, &arrivals[d]).total_ns;
                // the serial fallback (wait for the bulk transfer, then
                // run undisturbed) bounds the pipelined schedule
                piped.min(md.broadcast_ns + serial_ns)
            } else {
                full_arrival + serial_ns
            };
            // the compute lane must match the finish model: an
            // unannotated device idles until its full copy lands
            let start = if d == 0 {
                0.0
            } else if trace.chunk_deps() > 0 {
                arrivals[d].first().copied().unwrap_or(0.0)
            } else {
                full_arrival
            };
            lanes.compute.push(LaneSpan::new(format!("dev{d}"), start, f));
            if d > 0 {
                for (c, &a) in arrivals[d].iter().enumerate() {
                    lanes.transfer.push(LaneSpan::new(
                        format!("bcast d{d} c{c}"),
                        (a - chunk_xfer).max(0.0),
                        a,
                    ));
                }
            }
            finish.push(f);
        }
        let (gather_done, gather_spans) = ic.stream_gather(&finish, c_block_bytes)?;
        lanes.transfer.extend(gather_spans);
        let makespan =
            gather_done.max(finish.iter().cloned().fold(0.0, f64::max)).min(md.makespan_ns());
        lanes.end_ns = makespan;
        md.overlap =
            Some(OverlapReport { makespan_ns: makespan, chunks, device_finish_ns: finish, lanes });
        Ok(md)
    }

    pub fn n_devices(&self) -> usize {
        self.timelines.len()
    }

    /// Per-phase attribution for the tracing layer ([`crate::obs`]): the
    /// `B` broadcast, each distinct compute step aggregated across
    /// devices (max over devices — they run in parallel, so a step's
    /// contribution to the makespan is its slowest device), then the `C`
    /// gather, in execution order. Zero-cost phases are dropped, exactly
    /// as in [`Timeline::phase_spans`].
    pub fn phase_spans(&self) -> Vec<(String, f64)> {
        let mut out = Vec::new();
        if self.broadcast_ns > 0.0 {
            out.push(("broadcast".to_string(), self.broadcast_ns));
        }
        let mut steps: Vec<(String, f64)> = Vec::new();
        for tl in &self.timelines {
            for (name, ns) in tl.phase_spans() {
                match steps.iter_mut().find(|(n, _)| *n == name) {
                    Some((_, acc)) => *acc = acc.max(ns),
                    None => steps.push((name, ns)),
                }
            }
        }
        out.extend(steps);
        if self.gather_ns > 0.0 {
            out.push(("gather".to_string(), self.gather_ns));
        }
        out
    }

    /// Pipelined end-to-end critical path, when this run was simulated
    /// via [`MultiDevice::simulate_overlapped`] (≤ the serial
    /// [`MultiDevice::makespan_ns`] by construction).
    pub fn overlapped_makespan_ns(&self) -> Option<f64> {
        self.overlap.as_ref().map(|o| o.makespan_ns)
    }

    /// Serial-minus-overlapped makespan: the transfer time the pipelined
    /// schedule hid behind compute (0 when simulated serially).
    pub fn overlap_saved_ns(&self) -> f64 {
        self.overlapped_makespan_ns().map_or(0.0, |o| self.makespan_ns() - o)
    }

    /// Per-device chunk-arrival **stall** under the overlapped schedule:
    /// how much later each device finished than its undisturbed compute
    /// time — the broadcast slack the pipeline failed to hide (the
    /// feedback signal chunk-size tuning reads; see
    /// [`crate::coordinator::feedback::tune_chunk_bytes`]). All zeros
    /// when the run was simulated serially; the root (device 0) owns `B`
    /// and never stalls.
    pub fn overlap_stall_ns(&self) -> Vec<f64> {
        match &self.overlap {
            Some(o) => o
                .device_finish_ns
                .iter()
                .zip(&self.timelines)
                .map(|(f, t)| (f - t.total_ns).max(0.0))
                .collect(),
            None => vec![0.0; self.timelines.len()],
        }
    }

    /// Compute critical path: the slowest device's wall time (devices
    /// run concurrently), excluding interconnect transfers.
    pub fn compute_makespan_ns(&self) -> f64 {
        self.timelines.iter().map(|t| t.total_ns).fold(0.0, f64::max)
    }

    /// Modeled interconnect time bracketing the compute phase.
    pub fn comm_ns(&self) -> f64 {
        self.broadcast_ns + self.gather_ns
    }

    /// End-to-end critical path: `B` broadcast, then the slowest device's
    /// compute, then the `C` gather. Equals the compute makespan when no
    /// interconnect was charged.
    pub fn makespan_ns(&self) -> f64 {
        self.comm_ns() + self.compute_makespan_ns()
    }

    /// Per-device wall times in device order.
    pub fn device_total_ns(&self) -> Vec<f64> {
        self.timelines.iter().map(|t| t.total_ns).collect()
    }

    /// Measured compute load imbalance: max device wall time / mean
    /// device wall time (1.0 = perfect; idle devices count toward the
    /// mean). Interconnect time is excluded — it is not imbalance.
    pub fn time_imbalance(&self) -> f64 {
        if self.timelines.is_empty() {
            return 1.0;
        }
        let mean: f64 =
            self.timelines.iter().map(|t| t.total_ns).sum::<f64>() / self.timelines.len() as f64;
        if mean == 0.0 {
            1.0
        } else {
            self.compute_makespan_ns() / mean
        }
    }

    /// Speedup over a single-device wall time (interconnect included).
    pub fn speedup_vs(&self, single_device_ns: f64) -> f64 {
        let m = self.makespan_ns();
        if m <= 0.0 {
            0.0
        } else {
            single_device_ns / m
        }
    }

    /// Scaling efficiency: speedup divided by device count (1.0 = linear).
    pub fn efficiency_vs(&self, single_device_ns: f64) -> f64 {
        if self.timelines.is_empty() {
            return 0.0;
        }
        self.speedup_vs(single_device_ns) / self.timelines.len() as f64
    }

    /// GFLOPS under the makespan (the paper's metric over the fleet).
    pub fn gflops(&self, flops: f64) -> f64 {
        let m = self.makespan_ns();
        if m <= 0.0 {
            0.0
        } else {
            flops / m
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::device::V100;
    use crate::gpusim::trace::{BlockWork, Kernel};

    fn trace_with_blocks(nblocks: usize) -> Trace {
        let mut t = Trace::new();
        t.launch(Kernel {
            name: "k".into(),
            step: "numeric",
            stream: 0,
            tb_size: 256,
            shared_bytes: 0,
            blocks: vec![BlockWork { global_bytes: 100_000, ..Default::default() }; nblocks],
        });
        t
    }

    #[test]
    fn makespan_is_slowest_device() {
        let fast = trace_with_blocks(10);
        let slow = trace_with_blocks(4000);
        let md = MultiDevice::simulate([&fast, &slow], &V100);
        assert_eq!(md.n_devices(), 2);
        let per = md.device_total_ns();
        assert!((md.makespan_ns() - per[1]).abs() < 1e-6);
        assert!(per[1] > per[0]);
        assert!(md.time_imbalance() > 1.0);
        assert_eq!(md.comm_ns(), 0.0, "no interconnect charged by default");
    }

    #[test]
    fn phase_spans_bracket_compute_with_transfers() {
        let fast = trace_with_blocks(10);
        let slow = trace_with_blocks(4000);
        let ic = Interconnect::parse("pcie4").unwrap();
        let md = MultiDevice::simulate_with_interconnect(
            [&fast, &slow],
            &V100,
            &ic,
            1_000_000,
            &[500_000, 500_000],
        )
        .unwrap();
        let phases = md.phase_spans();
        assert_eq!(phases.first().map(|(n, _)| n.as_str()), Some("broadcast"));
        assert_eq!(phases.last().map(|(n, _)| n.as_str()), Some("gather"));
        let numeric = phases.iter().find(|(n, _)| n == "numeric").expect("compute step present");
        assert!(
            (numeric.1 - md.timelines[1].step_ns("numeric")).abs() < 1e-6,
            "compute step aggregates as max over devices"
        );
        // serial simulation (no interconnect): transfers drop out
        let md0 = MultiDevice::simulate([&fast, &slow], &V100);
        assert!(md0.phase_spans().iter().all(|(n, _)| n != "broadcast" && n != "gather"));
    }

    #[test]
    fn balanced_devices_have_low_imbalance_and_good_efficiency() {
        let traces: Vec<Trace> = (0..4).map(|_| trace_with_blocks(1000)).collect();
        let md = MultiDevice::simulate(traces.iter(), &V100);
        assert!((md.time_imbalance() - 1.0).abs() < 1e-9);
        let single = simulate(&trace_with_blocks(4000), &V100).total_ns;
        let eff = md.efficiency_vs(single);
        assert!(eff > 0.5, "4-way split of a 4x trace should scale: eff={eff}");
    }

    #[test]
    fn empty_fleet_is_degenerate_but_defined() {
        let md = MultiDevice::default();
        assert_eq!(md.makespan_ns(), 0.0);
        assert_eq!(md.time_imbalance(), 1.0);
        assert_eq!(md.efficiency_vs(1.0), 0.0);
    }

    #[test]
    fn one_to_all_broadcast_scales_linearly_in_bytes_and_devices() {
        // zero latency isolates the bandwidth term
        let ic = Interconnect { bandwidth_gbps: 10.0, latency_us: 0.0, topology: Topology::OneToAll };
        let base = ic.broadcast_ns(1 << 20, 2).unwrap();
        assert!(base > 0.0);
        let double_bytes = ic.broadcast_ns(2 << 20, 2).unwrap();
        assert!((double_bytes - 2.0 * base).abs() < 1e-6, "linear in bytes");
        let five_devices = ic.broadcast_ns(1 << 20, 5).unwrap();
        assert!((five_devices - 4.0 * base).abs() < 1e-6, "linear in peer count");
        // latency is charged per hop
        let with_lat =
            Interconnect { latency_us: 5.0, ..ic }.broadcast_ns(1 << 20, 5).unwrap();
        assert!((with_lat - (five_devices + 4.0 * 5_000.0)).abs() < 1e-6);
    }

    #[test]
    fn ring_beats_one_to_all_at_high_device_counts() {
        let one = Interconnect { bandwidth_gbps: 12.0, latency_us: 2.0, topology: Topology::OneToAll };
        let ring = Interconnect { topology: Topology::Ring, ..one };
        let bytes = 64 << 20;
        // a two-device "ring" is the same single link
        let o2 = one.broadcast_ns(bytes, 2).unwrap();
        let r2 = ring.broadcast_ns(bytes, 2).unwrap();
        assert!((o2 - r2).abs() < 1e-6);
        // at 8 devices the pipelined ring amortizes the replication
        let o8 = one.broadcast_ns(bytes, 8).unwrap();
        let r8 = ring.broadcast_ns(bytes, 8).unwrap();
        assert!(r8 < o8 / 2.0, "ring {r8} should clearly beat one-to-all {o8}");
        // and the ring's bandwidth term stays bounded as the fleet grows
        let r64 = ring.broadcast_ns(bytes, 64).unwrap();
        let xfer = bytes as f64 / 12.0;
        assert!(r64 - 63.0 * 2_000.0 < 2.0 * xfer + 1e-6);
    }

    #[test]
    fn zero_bandwidth_is_an_error_not_a_division() {
        let dead = Interconnect { bandwidth_gbps: 0.0, latency_us: 1.0, topology: Topology::OneToAll };
        assert!(dead.broadcast_ns(1024, 4).is_err());
        assert!(dead.gather_ns(&[10, 10]).is_err());
        let neg = Interconnect { bandwidth_gbps: -3.0, ..dead };
        assert!(neg.broadcast_ns(1024, 4).is_err());
    }

    #[test]
    fn single_device_pays_no_interconnect() {
        let ic = Interconnect::pcie3();
        assert_eq!(ic.broadcast_ns(1 << 30, 1).unwrap(), 0.0);
        assert_eq!(ic.gather_ns(&[1 << 30]).unwrap(), 0.0);
    }

    #[test]
    fn gather_counts_only_non_root_blocks() {
        let ic = Interconnect { bandwidth_gbps: 1.0, latency_us: 0.0, topology: Topology::OneToAll };
        // root block (index 0) never moves
        let g = ic.gather_ns(&[1_000_000, 100, 200]).unwrap();
        assert!((g - 300.0).abs() < 1e-9, "got {g}");
    }

    #[test]
    fn chunk_arrivals_monotone_and_bounded_by_serial_broadcast() {
        let bytes = 16 << 20;
        for topo in [Topology::OneToAll, Topology::Ring] {
            let ic = Interconnect { bandwidth_gbps: 12.0, latency_us: 3.0, topology: topo };
            for n in [2usize, 4, 8] {
                for chunks in [1usize, 2, 7, 16, 64] {
                    let serial = ic.broadcast_ns(bytes, n).unwrap();
                    let arr = ic.chunk_arrivals(bytes, n, chunks).unwrap();
                    assert_eq!(arr.len(), n);
                    assert!(arr[0].iter().all(|&a| a == 0.0), "root owns B");
                    for (d, row) in arr.iter().enumerate().skip(1) {
                        assert_eq!(row.len(), chunks);
                        for w in row.windows(2) {
                            assert!(w[0] <= w[1] + 1e-9, "{topo:?} d{d}: arrivals not monotone");
                        }
                        assert!(
                            *row.last().unwrap() <= serial + 1e-6,
                            "{topo:?} n={n} chunks={chunks} d{d}: last arrival {} > serial {serial}",
                            row.last().unwrap()
                        );
                        assert!(row[0] > 0.0, "non-root chunk 0 must cost something");
                    }
                }
            }
        }
    }

    #[test]
    fn chunked_arrivals_land_earlier_than_the_bulk_transfer() {
        let ic = Interconnect::pcie3();
        let bulk = ic.chunk_arrivals(32 << 20, 4, 1).unwrap();
        let fine = ic.chunk_arrivals(32 << 20, 4, 32).unwrap();
        for d in 1..4 {
            assert!(
                fine[d][0] < bulk[d][0] / 4.0,
                "first panel should land long before the bulk copy: {} vs {}",
                fine[d][0],
                bulk[d][0]
            );
        }
    }

    #[test]
    fn stream_gather_never_beats_physics_nor_loses_to_serial() {
        let ic = Interconnect { bandwidth_gbps: 10.0, latency_us: 2.0, topology: Topology::OneToAll };
        let finish = [5_000.0, 9_000.0, 1_000.0, 14_000.0];
        let blocks = [4096usize, 50_000, 50_000, 50_000];
        let (done, spans) = ic.stream_gather(&finish, &blocks).unwrap();
        // early finisher (device 2) goes first, under device 3's compute
        assert_eq!(spans[0].what, "gather d2");
        assert!(spans[0].start >= 1_000.0);
        // serial bound: wait for the slowest device, then the full gather
        let serial_done = 14_000.0 + ic.gather_ns(&blocks).unwrap();
        assert!(done <= serial_done + 1e-6, "{done} vs serial {serial_done}");
        // physics bound: the last device's block still has to move
        assert!(done >= 14_000.0 + 50_000.0 / 10.0);
        // mismatched lengths error
        assert!(ic.stream_gather(&finish[..2], &blocks).is_err());
    }

    #[test]
    fn overlapped_simulation_beats_serial_and_is_bounded_by_it() {
        use crate::gpusim::trace::TraceOp;
        let mk = |nblocks: usize, chunks: usize| {
            let mut t = trace_with_blocks(nblocks);
            // annotate: all chunk waits ahead of the launch
            let mut ops = Vec::new();
            for c in 0..chunks {
                ops.push(TraceOp::AwaitChunk { chunk: c, step: "symbolic" });
            }
            ops.append(&mut t.ops);
            t.ops = ops;
            t
        };
        let ic = Interconnect::pcie3();
        let b_bytes = 64 << 20; // make the broadcast matter
        let c_blocks = [1 << 20; 4];
        for chunks in [1usize, 4, 16] {
            let traces: Vec<Trace> = (0..4).map(|_| mk(1000, chunks)).collect();
            let md =
                MultiDevice::simulate_overlapped(traces.iter(), &V100, &ic, b_bytes, &c_blocks)
                    .unwrap();
            let serial = md.makespan_ns();
            let over = md.overlapped_makespan_ns().unwrap();
            assert!(over <= serial + 1e-6, "chunks={chunks}: {over} > serial {serial}");
            assert!(md.overlap_saved_ns() >= -1e-6);
            // the root never waits, so some overlap always materializes
            assert!(over < serial, "chunks={chunks}: pipelining must save something here");
            let report = md.overlap.as_ref().unwrap();
            assert_eq!(report.chunks, chunks);
            assert_eq!(report.device_finish_ns.len(), 4);
            assert!(report.lanes.overlapped_busy_ns() > 0.0, "lanes must overlap");
            assert!(report.lanes.end_ns <= serial + 1e-6);
        }
    }

    #[test]
    fn overlap_stall_is_the_unhidden_broadcast_slack() {
        use crate::gpusim::trace::TraceOp;
        // every device waits for all chunks before computing: the stall
        // is positive on non-root devices and bounded by the serial
        // broadcast; the root owns B and never stalls
        let mk = |chunks: usize| {
            let mut t = trace_with_blocks(500);
            let mut ops = Vec::new();
            for c in 0..chunks {
                ops.push(TraceOp::AwaitChunk { chunk: c, step: "symbolic" });
            }
            ops.append(&mut t.ops);
            t.ops = ops;
            t
        };
        let ic = Interconnect::pcie3();
        let traces: Vec<Trace> = (0..3).map(|_| mk(4)).collect();
        let md = MultiDevice::simulate_overlapped(
            traces.iter(),
            &V100,
            &ic,
            32 << 20,
            &[1 << 20; 3],
        )
        .unwrap();
        let stall = md.overlap_stall_ns();
        assert_eq!(stall.len(), 3);
        assert_eq!(stall[0], 0.0, "the root owns B");
        for (d, &s) in stall.iter().enumerate().skip(1) {
            assert!(s > 0.0, "device {d} must stall waiting on panels");
            assert!(s <= md.broadcast_ns + 1e-6, "stall cannot exceed the serial broadcast");
        }
        // a serial simulation reports no stall at all
        let serial = MultiDevice::simulate(traces.iter(), &V100);
        assert!(serial.overlap_stall_ns().iter().all(|&s| s == 0.0));
    }

    #[test]
    fn overlapped_estimate_bounded_by_serial_schedule() {
        for topo in [Topology::OneToAll, Topology::Ring] {
            let ic = Interconnect { bandwidth_gbps: 12.0, latency_us: 4.0, topology: topo };
            for n in [2usize, 4, 8] {
                let blocks = vec![256 << 10; n];
                for chunk_kb in [64usize, 512, 4096] {
                    let overlap =
                        OverlapConfig { enabled: true, chunk_bytes: chunk_kb << 10 };
                    let compute = 2_000_000.0;
                    let est = ic
                        .overlapped_estimate_ns(8 << 20, compute, 0.35, &blocks, &overlap)
                        .unwrap();
                    let serial = ic.broadcast_ns(8 << 20, n).unwrap()
                        + compute
                        + ic.gather_ns(&blocks).unwrap();
                    assert!(
                        est <= serial + 1e-6,
                        "{topo:?} n={n} chunk={chunk_kb}KB: {est} > {serial}"
                    );
                    assert!(est >= compute, "cannot finish before the compute itself");
                }
            }
        }
    }

    #[test]
    fn interconnect_charges_show_up_in_makespan() {
        let traces: Vec<Trace> = (0..4).map(|_| trace_with_blocks(100)).collect();
        let free = MultiDevice::simulate(traces.iter(), &V100);
        let ic = Interconnect::pcie3();
        let charged = MultiDevice::simulate_with_interconnect(
            traces.iter(),
            &V100,
            &ic,
            8 << 20,
            &[1 << 20; 4],
        )
        .unwrap();
        assert!(charged.broadcast_ns > 0.0);
        assert!(charged.gather_ns > 0.0);
        assert!(
            charged.makespan_ns() > free.makespan_ns(),
            "transfers must lengthen the critical path"
        );
        assert_eq!(charged.compute_makespan_ns(), free.compute_makespan_ns());
        // block-count mismatch is an error
        assert!(MultiDevice::simulate_with_interconnect(
            traces.iter(),
            &V100,
            &ic,
            8 << 20,
            &[1 << 20; 3],
        )
        .is_err());
    }
}
