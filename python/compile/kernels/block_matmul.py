"""L1 Pallas kernels: the TPU adaptation of OpSparse's numeric-phase
accumulator (DESIGN.md §Hardware-Adaptation).

The paper's CUDA hot kernel scatters intermediate products into a
per-thread-block *shared-memory hash table* with atomicCAS. A TPU has no
per-core scatter memory with atomics; the analog of "keep the accumulator
in the fastest on-chip memory" is a **dense accumulator tile in VMEM**, fed
to the MXU as block matmuls. Two kernels express the two routing targets of
the Rust coordinator:

* ``block_pair_matmul`` — BSR numeric phase: for P block pairs,
  ``C[p] = A[p] @ B[p]`` over ``T x T`` dense blocks. The symbolic phase
  (which pairs meet) stays in Rust using the paper's binning + hashing on
  block column indices; this kernel is the per-pair MXU product. One grid
  step per pair; the pair's three tiles live in VMEM (BlockSpec moves them
  HBM -> VMEM exactly where CUDA used shared memory staging).

* ``row_window_accumulate`` — dense-accumulator analog of the hash table
  for a *row window*: for R rows, given the row's K nonzero values
  ``a_vals[r, :]`` and the K gathered B-rows restricted to a W-wide column
  window ``b_rows[r, :, :]``, compute ``c[r, :] = a_vals[r] @ b_rows[r]``.
  The W-wide accumulator tile is the VMEM stand-in for the t_size-slot
  shared hash table; the Rust router picks W from the same binning ranges
  that picked t_size on the GPU.

Both kernels are lowered with ``interpret=True`` — the CPU PJRT plugin
cannot execute Mosaic custom-calls (see /opt/xla-example/README.md). On a
real TPU the same code lowers to Mosaic with T=128 tiles feeding the
128x128 MXU.

VMEM budgeting (for the DESIGN.md §Perf estimate, T=128 f32 on TPU):
3 tiles x 128*128*4B = 192 KiB per grid step, double-buffered by the
Pallas pipeline = 384 KiB of ~16 MiB VMEM; MXU does T^3 MACs per 128-cycle
tile pass -> structurally MXU-bound, not HBM-bound, for T >= 128.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)


# ---------------------------------------------------------------------------
# block_pair_matmul
# ---------------------------------------------------------------------------

def _block_pair_kernel(a_ref, b_ref, o_ref):
    """One grid step: multiply one T x T block pair in VMEM."""
    # a_ref/o_ref carry a leading singleton batch axis from the BlockSpec.
    a = a_ref[0]
    b = b_ref[0]
    # accumulate in f32/f64 (preferred_element_type pins the MXU accumulator)
    o_ref[0] = jnp.dot(a, b, preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_pair_matmul(a: jax.Array, b: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Batched block matmul ``C[p] = A[p] @ B[p]``.

    Args:
      a: ``(P, T, T)`` array.
      b: ``(P, T, T)`` array, same dtype.
      interpret: must stay True on CPU PJRT (Mosaic is TPU-only).

    Returns:
      ``(P, T, T)`` array of products.
    """
    p, t, t2 = a.shape
    assert t == t2 and b.shape == a.shape, (a.shape, b.shape)
    spec = pl.BlockSpec((1, t, t), lambda i: (i, 0, 0))
    return pl.pallas_call(
        _block_pair_kernel,
        grid=(p,),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((p, t, t), a.dtype),
        interpret=interpret,
    )(a, b)


# ---------------------------------------------------------------------------
# row_window_accumulate
# ---------------------------------------------------------------------------

def _row_window_kernel(a_ref, b_ref, o_ref):
    """One grid step: one row's dense-window accumulation in VMEM.

    ``a_ref``: (1, K) row values; ``b_ref``: (1, K, W) gathered B rows;
    ``o_ref``: (1, W) accumulator tile — the VMEM analog of the GPU
    shared-memory hash table (already zero-initialized by pallas_call).
    """
    a = a_ref[0]          # (K,)
    b = b_ref[0]          # (K, W)
    o_ref[0] = jnp.dot(a, b, preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def row_window_accumulate(
    a_vals: jax.Array, b_rows: jax.Array, *, interpret: bool = True
) -> jax.Array:
    """Dense-accumulator numeric phase for a padded row window.

    Args:
      a_vals: ``(R, K)`` — each row's (zero-padded) nonzero values.
      b_rows: ``(R, K, W)`` — for each row, the K gathered rows of B
        restricted to the row's W-wide column window (zero-padded).

    Returns:
      ``(R, W)`` dense output rows; the Rust side compacts them to CSR.
    """
    r, k = a_vals.shape
    r2, k2, w = b_rows.shape
    assert r == r2 and k == k2, (a_vals.shape, b_rows.shape)
    return pl.pallas_call(
        _row_window_kernel,
        grid=(r,),
        in_specs=[
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k, w), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, w), a_vals.dtype),
        interpret=interpret,
    )(a_vals, b_rows)
