//! The serving front door: request coalescing, batched execution,
//! admission control, and warm-start persistence over the
//! [`Coordinator`].
//!
//! The coordinator executes one queue-pop at a time and its planner
//! forgets everything on restart; a production front end needs the four
//! behaviors this module layers on top — without touching the execution
//! paths underneath, so every knob's `off` position reproduces the raw
//! coordinator (PR 5) behavior exactly:
//!
//! * **Coalescing** — concurrent identical requests (same operand
//!   pattern *and* value fingerprints) attach to the one in-flight
//!   leader and share its result: N identical multiplies pay one
//!   symbolic phase and every waiter receives the **same** `Arc`'d
//!   matrix — bit-identical by construction, not by comparison. The
//!   issue keys coalescing on the pattern-fingerprint pair (that is
//!   what the shared symbolic phase depends on); the value
//!   fingerprints are the numeric-identity guard, because two
//!   pattern-equal but value-different requests may share symbolic
//!   work in the worker cache yet must never share a numeric result.
//! * **Batching** — small hash-routed requests accumulate in a
//!   size/age-watermarked [`Batcher`] and flush as one worker visit
//!   ([`Coordinator::submit_batch`]).
//! * **Admission control** — at most `queue_cap` leaders outstanding;
//!   beyond that a request is answered [`ServeResult::Rejected`]
//!   immediately instead of growing the queue without bound. Admission
//!   to the coordinator drains per-tenant queues round-robin, so one
//!   chatty tenant cannot starve the rest, and `inflight_cap` bounds
//!   how many leaders the coordinator holds at once.
//! * **Warm-start persistence** — on shutdown the execution history and
//!   the `ns_per_prod` fit are saved ([`persist`]); on start they are
//!   reloaded, so the first post-restart submit of a warm pattern is
//!   planned from measured timings exactly like the last pre-restart
//!   one (bit-stable: see [`persist::save_state`]).
//!
//! Request lifecycle: **admit** (reject if the bound is hit) →
//! **coalesce** (attach to an identical in-flight leader) → **batch**
//! (hash-routed leaders ride a watermarked batch) → **route** (the
//! coordinator's router, as ever) → **fan-out** (one result, every
//! waiter). Clients hold a [`ServeTicket`] and block on
//! [`ServeTicket::wait`].
//!
//! The [`Coordinator`] owns an `mpsc` receiver and is deliberately not
//! `Sync`, so the front door moves it into a single dispatcher thread;
//! clients only touch a small mutex-guarded front state. The dispatcher
//! alternates between admitting pending requests and polling the
//! coordinator for results (short timeout), which also gives the age
//! watermark its clock.

use super::barrier::SpeculateConfig;
use super::batch::{BatchConfig, Batcher};
use super::chaos::ChaosConfig;
use super::feedback::{
    parse_on_off, persist, ExecHistory, NsPerProdFit, PersistedState, ReplanConfig,
};
use super::metrics::{Metrics, MetricsSnapshot};
use super::router::{EngineMode, Route, Router, RouterConfig};
use super::service::{Coordinator, EngineFactory, Job, JobResult};
use crate::gpusim::{Interconnect, OverlapConfig};
use crate::obs::{chrome_trace_json, Span, TraceConfig, Tracer, LANE_FRONT};
use crate::runtime::BlockEngine;
use crate::sparse::Csr;
use anyhow::{bail, Result};
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How long the dispatcher blocks on the coordinator's result channel
/// per loop iteration. Short enough that admission and the batch age
/// watermark stay responsive under a result drought; long enough that
/// an idle front door costs ~no CPU.
const DISPATCHER_TICK: Duration = Duration::from_micros(500);

/// Where `--persist on` keeps the state file when no path is given.
pub const DEFAULT_PERSIST_PATH: &str = "opsparse-serve.state";

/// Identity of a request for coalescing: both operands' pattern
/// fingerprints (the pair the shared symbolic phase depends on) plus
/// both value fingerprints (the numeric-identity guard — see the module
/// docs).
pub type CoalesceKey = (u64, u64, u64, u64);

/// Every serving knob in one place, replacing scattered `OPSPARSE_*`
/// env reads. Precedence is **CLI > env > default**:
/// [`ServeConfig::default`] is the base, [`ServeConfig::from_env`] lays
/// the environment over it, and [`ServeConfig::from_args`] lays parsed
/// CLI flags over *that*. Env values that fail to parse keep the prior
/// layer's value (the established env convention); CLI values that
/// fail to parse are an error (a typo on the command line should not
/// run with a silently different config).
///
/// The defaults reproduce the PR 5 baseline wherever a knob gates new
/// behavior: batching and persistence are off, the queue bound is high,
/// and `inflight_cap` is unlimited. Coalescing defaults on — it is the
/// front door's reason to exist — and `--coalesce off` restores
/// pass-through admission.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    /// Hash workers in the coordinator pool (`OPSPARSE_WORKERS`,
    /// `--workers`).
    pub workers: usize,
    /// Attach identical in-flight requests to one leader
    /// (`OPSPARSE_COALESCE`, `--coalesce`).
    pub coalesce: bool,
    /// Batch small hash-routed requests into one worker visit
    /// (`OPSPARSE_BATCH`/`--batch`, with `OPSPARSE_BATCH_MAX`/
    /// `--batch-max` and `OPSPARSE_BATCH_AGE_MS`/`--batch-age-ms`).
    pub batch: BatchConfig,
    /// Most leaders outstanding before requests are rejected
    /// (`OPSPARSE_QUEUE_CAP`, `--queue-cap`).
    pub queue_cap: usize,
    /// Most leaders handed to the coordinator at once; pending requests
    /// wait in per-tenant queues drained round-robin
    /// (`OPSPARSE_INFLIGHT`, `--inflight`).
    pub inflight_cap: usize,
    /// State-file path for warm-start persistence; `None` disables
    /// (`OPSPARSE_PERSIST`, `--persist off|on|<path>`; `on` means
    /// [`DEFAULT_PERSIST_PATH`]).
    pub persist: Option<String>,
    /// Adaptive re-planning knobs (`OPSPARSE_REPLAN`/`--replan`,
    /// `OPSPARSE_HISTORY_CAP`/`--history-cap`).
    pub replan: ReplanConfig,
    /// Overlap model (`OPSPARSE_OVERLAP`/`--overlap`,
    /// `OPSPARSE_OVERLAP_CHUNK_KB`/`--chunk-kb`).
    pub overlap: OverlapConfig,
    /// Interconnect charged by the router's sharded-route comparison
    /// (`OPSPARSE_INTERCONNECT`/`--interconnect pcie|nvlink|none`).
    pub interconnect: Option<Interconnect>,
    /// Single-device memory budget for the router.
    pub device_memory_bytes: usize,
    /// Most devices a sharded job may span.
    pub max_devices: usize,
    /// Seed for the live `ns_per_prod` fit when no persisted state is
    /// loaded: `Some(k)` seeds cheaply (tests), `None` uses the
    /// process-wide suite calibration
    /// ([`super::feedback::default_fit`]).
    pub ns_per_prod: Option<f64>,
    /// Straggler speculation for sharded jobs (`OPSPARSE_SPECULATE`/
    /// `--speculate on|off`, `OPSPARSE_SPECULATE_LAG`/`--speculate-lag`).
    /// Off by default: `--speculate off` is exactly the pre-speculation
    /// coordinator.
    pub speculate: SpeculateConfig,
    /// Chaos fault injection at worker sub-job boundaries
    /// (`OPSPARSE_CHAOS`/`--chaos off|gentle|aggressive`,
    /// `OPSPARSE_CHAOS_SEED`/`--chaos-seed`). Off by default; never
    /// enable in production — this knob exists so CI and the chaos bench
    /// can prove the failure-domain machinery.
    pub chaos: ChaosConfig,
    /// Engine commitment (`OPSPARSE_ENGINE`/`--engine
    /// fill|auto|hash|block`). The default ([`EngineMode::Fill`]) is
    /// the pre-dispatch structural routing, bit for bit; `auto` turns
    /// on measured multi-engine dispatch (the front door then shares
    /// one engine-tagged history between the router, the workers, and
    /// persistence, and loads a native block engine so block routes
    /// execute); `hash`/`block` force one engine fleet-wide.
    pub engine: EngineMode,
    /// Request-scoped tracing (`OPSPARSE_TRACE`/`--trace on|off`,
    /// `OPSPARSE_TRACE_DIR`/`--trace-dir <dir>`,
    /// `OPSPARSE_TRACE_SLOW`/`--trace-slow <K>`). Off by default: with
    /// tracing off no span is allocated and no clock is read, so the
    /// hot path is bit-identical to the untraced front door. Giving a
    /// trace dir or a slow-exemplar count implies `--trace on`; an
    /// explicit `--trace off` wins over both.
    pub trace: TraceConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        let router = RouterConfig::default();
        ServeConfig {
            workers: 4,
            coalesce: true,
            batch: BatchConfig::default(),
            queue_cap: 1024,
            inflight_cap: usize::MAX,
            persist: None,
            replan: ReplanConfig::default(),
            overlap: OverlapConfig::default(),
            interconnect: router.interconnect,
            device_memory_bytes: router.device_memory_bytes,
            max_devices: router.max_devices,
            ns_per_prod: None,
            speculate: SpeculateConfig::default(),
            chaos: ChaosConfig::off(),
            engine: EngineMode::default(),
            trace: TraceConfig::default(),
        }
    }
}

impl ServeConfig {
    /// Defaults overlaid by environment variables read through `get`
    /// (tests pass a closure over a plain map; production passes
    /// [`std::env::var`] via [`ServeConfig::from_env`]). Unparseable
    /// values keep the default, matching [`ReplanConfig::from_env`] and
    /// [`OverlapConfig::from_env`].
    pub fn from_env_map(get: impl Fn(&str) -> Option<String>) -> ServeConfig {
        let mut cfg = ServeConfig::default();
        let on_off = |key: &str| get(key).and_then(|v| parse_on_off(&v));
        let num = |key: &str| get(key).and_then(|v| v.parse::<usize>().ok());
        if let Some(n) = num("OPSPARSE_WORKERS").filter(|&n| n > 0) {
            cfg.workers = n;
        }
        if let Some(on) = on_off("OPSPARSE_COALESCE") {
            cfg.coalesce = on;
        }
        if let Some(on) = on_off("OPSPARSE_BATCH") {
            cfg.batch.enabled = on;
        }
        if let Some(n) = num("OPSPARSE_BATCH_MAX").filter(|&n| n > 0) {
            cfg.batch.max_jobs = n;
        }
        if let Some(ms) = num("OPSPARSE_BATCH_AGE_MS") {
            cfg.batch.max_age = Duration::from_millis(ms as u64);
        }
        if let Some(n) = num("OPSPARSE_QUEUE_CAP").filter(|&n| n > 0) {
            cfg.queue_cap = n;
        }
        if let Some(n) = num("OPSPARSE_INFLIGHT").filter(|&n| n > 0) {
            cfg.inflight_cap = n;
        }
        if let Some(v) = get("OPSPARSE_PERSIST") {
            cfg.persist = match parse_on_off(&v) {
                Some(true) => Some(DEFAULT_PERSIST_PATH.to_string()),
                Some(false) => None,
                None if !v.is_empty() => Some(v),
                None => None,
            };
        }
        if let Some(on) = on_off("OPSPARSE_REPLAN") {
            cfg.replan.enabled = on;
        }
        if let Some(cap) = num("OPSPARSE_HISTORY_CAP").filter(|&n| n > 0) {
            cfg.replan.history_cap = cap;
        }
        if let Some(on) = on_off("OPSPARSE_OVERLAP") {
            cfg.overlap.enabled = on;
        }
        if let Some(bytes) = num("OPSPARSE_OVERLAP_CHUNK_KB")
            .filter(|&kb| kb > 0)
            .and_then(|kb| kb.checked_mul(1024))
        {
            cfg.overlap.chunk_bytes = bytes;
        }
        if let Some(ic) = get("OPSPARSE_INTERCONNECT").and_then(|v| Interconnect::parse_opt(&v))
        {
            cfg.interconnect = ic;
        }
        if let Some(on) = on_off("OPSPARSE_SPECULATE") {
            cfg.speculate.enabled = on;
        }
        if let Some(lag) = get("OPSPARSE_SPECULATE_LAG")
            .and_then(|v| v.parse::<f64>().ok())
            .filter(|&l| l > 0.0 && l.is_finite())
        {
            cfg.speculate.lag_factor = lag;
        }
        if let Some(chaos) = get("OPSPARSE_CHAOS").and_then(|v| ChaosConfig::preset(&v)) {
            cfg.chaos = chaos.with_seed(cfg.chaos.seed);
        }
        if let Some(seed) = get("OPSPARSE_CHAOS_SEED").and_then(|v| v.parse::<u64>().ok()) {
            cfg.chaos.seed = seed;
        }
        if let Some(mode) = get("OPSPARSE_ENGINE").and_then(|v| EngineMode::parse(&v)) {
            cfg.engine = mode;
        }
        // dir and slow-K imply tracing on; the explicit on/off switch is
        // read last so `OPSPARSE_TRACE=off` wins over both
        if let Some(dir) = get("OPSPARSE_TRACE_DIR").filter(|d| !d.is_empty()) {
            cfg.trace.dir = Some(dir);
            cfg.trace.enabled = true;
        }
        if let Some(k) = num("OPSPARSE_TRACE_SLOW").filter(|&k| k > 0) {
            cfg.trace.slow_k = k;
            cfg.trace.enabled = true;
        }
        if let Some(on) = on_off("OPSPARSE_TRACE") {
            cfg.trace.enabled = on;
        }
        cfg
    }

    /// [`ServeConfig::from_env_map`] over the process environment.
    pub fn from_env() -> ServeConfig {
        ServeConfig::from_env_map(|k| std::env::var(k).ok())
    }

    /// Environment-derived config overlaid by parsed CLI flags
    /// (`--name value` pairs from the CLI's flag parser): the top of
    /// the CLI > env > default precedence. Unknown flag *names* are
    /// ignored (commands carry their own extra flags); a known flag
    /// with an unparseable *value* is an error.
    pub fn from_args(flags: &HashMap<String, String>) -> Result<ServeConfig> {
        ServeConfig::from_args_over(ServeConfig::from_env(), flags)
    }

    /// [`ServeConfig::from_args`] over an explicit base config — the
    /// testable core (no process-global env reads).
    pub fn from_args_over(
        mut cfg: ServeConfig,
        flags: &HashMap<String, String>,
    ) -> Result<ServeConfig> {
        fn on_off_flag(flags: &HashMap<String, String>, name: &str) -> Result<Option<bool>> {
            match flags.get(name) {
                None => Ok(None),
                Some(v) => match parse_on_off(v) {
                    Some(on) => Ok(Some(on)),
                    None => bail!("--{name} wants on|off, got {v:?}"),
                },
            }
        }
        fn num_flag(flags: &HashMap<String, String>, name: &str) -> Result<Option<usize>> {
            match flags.get(name) {
                None => Ok(None),
                Some(v) => match v.parse::<usize>() {
                    Ok(n) => Ok(Some(n)),
                    Err(_) => bail!("--{name} wants a number, got {v:?}"),
                },
            }
        }
        if let Some(n) = num_flag(flags, "workers")?.filter(|&n| n > 0) {
            cfg.workers = n;
        }
        if let Some(on) = on_off_flag(flags, "coalesce")? {
            cfg.coalesce = on;
        }
        if let Some(on) = on_off_flag(flags, "batch")? {
            cfg.batch.enabled = on;
        }
        if let Some(n) = num_flag(flags, "batch-max")?.filter(|&n| n > 0) {
            cfg.batch.max_jobs = n;
        }
        if let Some(ms) = num_flag(flags, "batch-age-ms")? {
            cfg.batch.max_age = Duration::from_millis(ms as u64);
        }
        if let Some(n) = num_flag(flags, "queue-cap")?.filter(|&n| n > 0) {
            cfg.queue_cap = n;
        }
        if let Some(n) = num_flag(flags, "inflight")?.filter(|&n| n > 0) {
            cfg.inflight_cap = n;
        }
        if let Some(v) = flags.get("persist") {
            cfg.persist = match parse_on_off(v) {
                Some(true) => Some(DEFAULT_PERSIST_PATH.to_string()),
                Some(false) => None,
                None if !v.is_empty() => Some(v.clone()),
                None => bail!("--persist wants on|off|<path>, got an empty value"),
            };
        }
        if let Some(on) = on_off_flag(flags, "replan")? {
            cfg.replan.enabled = on;
        }
        if let Some(cap) = num_flag(flags, "history-cap")?.filter(|&n| n > 0) {
            cfg.replan.history_cap = cap;
        }
        if let Some(on) = on_off_flag(flags, "overlap")? {
            cfg.overlap.enabled = on;
        }
        if let Some(kb) = num_flag(flags, "chunk-kb")?.filter(|&kb| kb > 0) {
            match kb.checked_mul(1024) {
                Some(bytes) => cfg.overlap.chunk_bytes = bytes,
                None => bail!("--chunk-kb {kb} overflows"),
            }
        }
        if let Some(v) = flags.get("interconnect") {
            match Interconnect::parse_opt(v) {
                Some(ic) => cfg.interconnect = ic,
                None => bail!("--interconnect wants pcie|nvlink|none, got {v:?}"),
            }
        }
        if let Some(on) = on_off_flag(flags, "speculate")? {
            cfg.speculate.enabled = on;
        }
        if let Some(v) = flags.get("speculate-lag") {
            match v.parse::<f64>() {
                Ok(l) if l > 0.0 && l.is_finite() => cfg.speculate.lag_factor = l,
                _ => bail!("--speculate-lag wants a positive factor, got {v:?}"),
            }
        }
        if let Some(v) = flags.get("chaos") {
            match ChaosConfig::preset(v) {
                // keep a seed the env layer (or an earlier flag pass)
                // already chose: the preset picks rates, not the schedule
                Some(preset) => cfg.chaos = preset.with_seed(cfg.chaos.seed),
                None => bail!("--chaos wants off|gentle|aggressive, got {v:?}"),
            }
        }
        if let Some(v) = flags.get("chaos-seed") {
            match v.parse::<u64>() {
                Ok(seed) => cfg.chaos.seed = seed,
                Err(_) => bail!("--chaos-seed wants a number, got {v:?}"),
            }
        }
        if let Some(v) = flags.get("engine") {
            match EngineMode::parse(v) {
                Some(mode) => cfg.engine = mode,
                None => bail!("--engine wants fill|auto|hash|block, got {v:?}"),
            }
        }
        if let Some(v) = flags.get("trace-dir") {
            if v.is_empty() {
                bail!("--trace-dir wants a directory path, got an empty value");
            }
            cfg.trace.dir = Some(v.clone());
            cfg.trace.enabled = true;
        }
        if let Some(v) = flags.get("trace-slow") {
            match v.parse::<usize>() {
                Ok(k) if k > 0 => {
                    cfg.trace.slow_k = k;
                    cfg.trace.enabled = true;
                }
                _ => bail!("--trace-slow wants a positive count, got {v:?}"),
            }
        }
        // last, so `--trace off` beats the implied-on of the flags above
        if let Some(on) = on_off_flag(flags, "trace")? {
            cfg.trace.enabled = on;
        }
        Ok(cfg)
    }

    /// The [`RouterConfig`] this serving config implies, carrying the
    /// given live fit.
    pub fn router_config(&self, fit: Arc<NsPerProdFit>) -> RouterConfig {
        RouterConfig {
            device_memory_bytes: self.device_memory_bytes,
            max_devices: self.max_devices,
            interconnect: self.interconnect,
            overlap: self.overlap,
            ns_per_prod: fit.current(),
            fit: Some(fit),
            engine_mode: self.engine,
            ..RouterConfig::default()
        }
    }
}

/// What a [`ServeTicket`] resolves to. Cloneable: coalesced waiters all
/// hold the **same** `Arc`'d matrix or error, which is what makes the
/// fan-out bit-identical by construction.
#[derive(Clone, Debug)]
pub enum ServeResult {
    /// The multiply succeeded.
    Done {
        c: Arc<Csr>,
        /// Route the coordinator executed (the leader's route, for
        /// every coalesced waiter).
        route: Route,
        /// Admission → fan-out latency observed by *this* waiter, ns.
        wall_ns: u64,
        /// This waiter attached to another request's execution.
        coalesced: bool,
    },
    /// The multiply failed; the one error fans out to every waiter.
    Failed { error: Arc<String>, wall_ns: u64, coalesced: bool },
    /// Refused at admission; nothing was queued or executed.
    Rejected {
        /// The outstanding-leader bound (`queue_cap`) was hit.
        queue_full: bool,
    },
}

impl ServeResult {
    /// The result matrix, when the request succeeded.
    pub fn csr(&self) -> Option<&Arc<Csr>> {
        match self {
            ServeResult::Done { c, .. } => Some(c),
            _ => None,
        }
    }

    /// The executed route, when the request ran at all.
    pub fn route(&self) -> Option<Route> {
        match self {
            ServeResult::Done { route, .. } => Some(*route),
            _ => None,
        }
    }

    pub fn is_rejected(&self) -> bool {
        matches!(self, ServeResult::Rejected { .. })
    }
}

/// A claim on one submitted request's result.
pub struct ServeTicket {
    rx: mpsc::Receiver<ServeResult>,
}

impl ServeTicket {
    /// Block until the request resolves. A front door that shut down
    /// before resolving (it drains by design, so this means the
    /// dispatcher died) reports a failure rather than hanging.
    pub fn wait(self) -> ServeResult {
        self.rx.recv().unwrap_or_else(|_| ServeResult::Failed {
            error: Arc::new("serving front door shut down before the result".to_string()),
            wall_ns: 0,
            coalesced: false,
        })
    }

    /// The result, if it has already resolved (non-blocking).
    pub fn try_wait(&self) -> Option<ServeResult> {
        self.rx.try_recv().ok()
    }
}

struct Waiter {
    tx: mpsc::Sender<ServeResult>,
    t0: Instant,
    coalesced: bool,
}

/// One admitted leader: its waiters (itself plus everyone coalesced
/// onto it) and its coalesce-map key.
struct OutstandingReq {
    waiters: Vec<Waiter>,
    key: Option<CoalesceKey>,
}

struct PendingJob {
    id: u64,
    a: Csr,
    b: Csr,
    /// Tracer clock at enqueue (0 with tracing off) — the `queue_wait`
    /// span's start when the dispatcher admits this leader.
    t_ns: u64,
}

/// The mutex-guarded state clients and the dispatcher share. Everything
/// the `!Sync` coordinator owns stays on the dispatcher's side.
#[derive(Default)]
struct FrontState {
    next_id: u64,
    /// Admitted leaders by job id, until their result fans out.
    outstanding: HashMap<u64, OutstandingReq>,
    /// In-flight coalesce identities → leader job id.
    coalesce: HashMap<CoalesceKey, u64>,
    /// Per-tenant FIFO of leaders awaiting coordinator admission.
    queues: HashMap<String, VecDeque<PendingJob>>,
    /// Round-robin rotation over tenants with non-empty queues.
    rr: VecDeque<String>,
    /// Leaders handed to the coordinator (or an open batch) and not yet
    /// finished — bounded by `inflight_cap`.
    admitted: usize,
}

/// The serving front door. Construct with [`Serve::start`], submit with
/// [`Serve::submit`], stop with [`Serve::shutdown`] (which drains
/// in-flight requests and persists warm state when configured).
pub struct Serve {
    cfg: ServeConfig,
    state: Arc<Mutex<FrontState>>,
    metrics: Arc<Metrics>,
    fit: Arc<NsPerProdFit>,
    stop: Arc<AtomicBool>,
    dispatcher: Option<JoinHandle<()>>,
    tracer: Option<Arc<Tracer>>,
}

impl Serve {
    /// Start the front door: load persisted warm state if configured
    /// and present, seed the live fit, spin up the coordinator, and
    /// move it into the dispatcher thread.
    pub fn start(cfg: ServeConfig) -> Result<Serve> {
        Serve::start_with_engine(cfg, None)
    }

    /// [`Serve::start`] with an optional block-engine factory for the
    /// coordinator's PJRT path.
    pub fn start_with_engine(cfg: ServeConfig, engine: Option<EngineFactory>) -> Result<Serve> {
        // a truncated or garbage state file (a crash mid-save, a stale
        // format, disk corruption) must cost only the warmth: log it and
        // start cold — `replan_cold_misses` behaves exactly as with no
        // file — rather than refusing to serve (tests/serve.rs pins both
        // corruption shapes)
        let loaded: Option<PersistedState> = match &cfg.persist {
            Some(path) if std::path::Path::new(path).exists() => {
                match persist::load_state(path) {
                    Ok(s) => Some(s),
                    Err(e) => {
                        eprintln!(
                            "serve: ignoring unreadable warm-start state {path:?} \
                             (cold start): {e:#}"
                        );
                        None
                    }
                }
            }
            _ => None,
        };
        let fit: Arc<NsPerProdFit> = match (&loaded, cfg.ns_per_prod) {
            (Some(s), _) => Arc::new(s.restore_fit()),
            (None, Some(k)) => Arc::new(NsPerProdFit::new(k)),
            (None, None) => super::feedback::default_fit(),
        };
        let mut router_cfg = cfg.router_config(Arc::clone(&fit));
        if router_cfg.engine_mode == EngineMode::Auto {
            // create the engine-tagged history *before* the router and
            // coordinator are built, so the dispatcher thread's router
            // clone, the coordinator's workers, and persistence all
            // share one store — otherwise the front door's batching
            // check could route a pattern differently than the
            // coordinator executes it
            router_cfg.dispatch_history =
                Some(Arc::new(Mutex::new(ExecHistory::new(cfg.replan.history_cap))));
        }
        let router = Router::new(router_cfg);
        // dispatched and forced-block fleets need a block engine loaded
        // or every block route would downgrade (counted in
        // `block_fallbacks`) before it ever measured anything; the
        // native backend is bit-exact, so loading it by default is safe
        let engine = engine.or_else(|| {
            matches!(cfg.engine, EngineMode::Auto | EngineMode::Block).then(|| {
                let t = router.cfg.t.max(1);
                Box::new(move || BlockEngine::native(16, t)) as EngineFactory
            })
        });
        // one tracer shared by the front door and the whole coordinator
        // stack (workers, barrier, monitor); `None` when tracing is off
        // so every hook below compiles down to a branch on a None
        let tracer: Option<Arc<Tracer>> =
            cfg.trace.enabled.then(|| Arc::new(Tracer::new(&cfg.trace)));
        let coord = Coordinator::start_traced(
            cfg.workers,
            router.clone(),
            engine,
            cfg.replan,
            cfg.speculate,
            cfg.chaos,
            tracer.clone(),
        );
        if let Some(s) = &loaded {
            let (held, evicted) = {
                let mut h = coord.history().lock().unwrap_or_else(|e| e.into_inner());
                s.restore_history(&mut h);
                (h.len() as u64, h.evictions())
            };
            coord.metrics.history_patterns.store(held, Ordering::Relaxed);
            coord.metrics.history_evictions.store(evicted, Ordering::Relaxed);
        }
        let metrics = Arc::clone(&coord.metrics);
        let state: Arc<Mutex<FrontState>> = Arc::default();
        let stop = Arc::new(AtomicBool::new(false));
        let dispatcher = {
            let cfg = cfg.clone();
            let state = Arc::clone(&state);
            let metrics = Arc::clone(&metrics);
            let stop = Arc::clone(&stop);
            let fit = Arc::clone(&fit);
            let tracer = tracer.clone();
            std::thread::spawn(move || {
                dispatcher_loop(coord, router, cfg, state, metrics, stop, fit, tracer)
            })
        };
        Ok(Serve { cfg, state, metrics, fit, stop, dispatcher: Some(dispatcher), tracer })
    }

    /// Submit one multiply on behalf of `tenant`. Never blocks on
    /// execution: the ticket resolves later — possibly to
    /// [`ServeResult::Rejected`], decided synchronously here.
    pub fn submit(&self, tenant: &str, a: Csr, b: Csr) -> ServeTicket {
        let (tx, rx) = mpsc::channel();
        let t0 = Instant::now();
        // fingerprint outside the lock: O(nnz) hashing must not stall
        // other submitters or the dispatcher
        let key: Option<CoalesceKey> = self.cfg.coalesce.then(|| {
            (
                a.pattern_fingerprint(),
                b.pattern_fingerprint(),
                a.value_fingerprint(),
                b.value_fingerprint(),
            )
        });
        let mut guard = self.state.lock().unwrap_or_else(|e| e.into_inner());
        let st = &mut *guard;
        if let Some(k) = &key {
            if let Some(&leader) = st.coalesce.get(k) {
                if let Some(req) = st.outstanding.get_mut(&leader) {
                    req.waiters.push(Waiter { tx, t0, coalesced: true });
                    self.metrics.coalesce_hits.fetch_add(1, Ordering::Relaxed);
                    if let Some(tr) = self.tracer.as_ref() {
                        // the attach rides the *leader's* trace: the
                        // waiter has no execution of its own to record
                        tr.instant(
                            leader,
                            tr.parent_for(leader),
                            LANE_FRONT,
                            "coalesce_attach",
                            vec![("waiters".to_string(), req.waiters.len().to_string())],
                        );
                    }
                    return ServeTicket { rx };
                }
            }
        }
        if st.outstanding.len() >= self.cfg.queue_cap {
            self.metrics.rejected_jobs.fetch_add(1, Ordering::Relaxed);
            let _ = tx.send(ServeResult::Rejected { queue_full: true });
            return ServeTicket { rx };
        }
        let id = st.next_id;
        st.next_id += 1;
        // the root opens as soon as the leader has an identity; every
        // span of this request (front door, workers, barrier) nests
        // under it, and fan_out closes it
        let admit_t0 = self.tracer.as_ref().map(|tr| (tr.open_root(id), tr.now_ns()));
        st.outstanding
            .insert(id, OutstandingReq { waiters: vec![Waiter { tx, t0, coalesced: false }], key });
        if let Some(k) = key {
            st.coalesce.insert(k, id);
        }
        self.metrics.observe_queue_depth(st.outstanding.len() as u64);
        let mut t_ns = 0;
        if let (Some(tr), Some((root, s0))) = (self.tracer.as_ref(), admit_t0) {
            let s1 = tr.now_ns();
            tr.record(Span {
                trace: id,
                id: tr.next_span_id(),
                parent: root,
                name: "admit".to_string(),
                lane: LANE_FRONT,
                t0_ns: s0,
                t1_ns: s1,
                args: vec![("tenant".to_string(), tenant.to_string())],
                error: false,
                instant: false,
            });
            self.metrics.phases.admit.observe(s1.saturating_sub(s0));
            t_ns = s1;
        }
        let q = st.queues.entry(tenant.to_string()).or_default();
        q.push_back(PendingJob { id, a, b, t_ns });
        if q.len() == 1 && !st.rr.iter().any(|t| t == tenant) {
            st.rr.push_back(tenant.to_string());
        }
        ServeTicket { rx }
    }

    /// Live metrics handle (shared with the coordinator underneath).
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// The request tracer, when `trace.enabled`; `None` otherwise —
    /// callers export or inspect spans through this handle while the
    /// front door runs (the dispatcher also writes trace files on
    /// shutdown when `trace.dir` is set).
    pub fn tracer(&self) -> Option<&Arc<Tracer>> {
        self.tracer.as_ref()
    }

    /// Point-in-time copy of the counters.
    pub fn metrics_snapshot(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// The live `ns_per_prod` fit the router reads per decision.
    pub fn fit(&self) -> &Arc<NsPerProdFit> {
        &self.fit
    }

    /// The config this front door runs under.
    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }

    /// Drain in-flight requests, persist warm state when configured,
    /// stop the coordinator, and join every thread.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }
}

impl Drop for Serve {
    fn drop(&mut self) {
        // dropping without shutdown() must not leak the dispatcher (or
        // skip persistence); stop_and_join is idempotent
        self.stop_and_join();
    }
}

/// Resolve one coordinator result: look up the leader, drop its
/// coalesce-map entry, and send every waiter its shared view of the one
/// result.
fn fan_out(st: &mut FrontState, metrics: &Metrics, tracer: Option<&Arc<Tracer>>, r: JobResult) {
    let Some(req) = st.outstanding.remove(&r.id) else {
        return; // unknown id: not ours to resolve
    };
    if let Some(k) = &req.key {
        st.coalesce.remove(k);
    }
    st.admitted = st.admitted.saturating_sub(1);
    metrics.observe_queue_depth(st.outstanding.len() as u64);
    let shared: std::result::Result<Arc<Csr>, Arc<String>> = match r.c {
        Ok(c) => Ok(Arc::new(c)),
        Err(e) => Err(Arc::new(format!("{e:#}"))),
    };
    let n_waiters = req.waiters.len();
    let mut max_wall = 0u64;
    for w in req.waiters {
        let wall_ns = w.t0.elapsed().as_nanos() as u64;
        max_wall = max_wall.max(wall_ns);
        metrics.observe_serve_latency(wall_ns);
        let msg = match &shared {
            Ok(c) => ServeResult::Done {
                c: Arc::clone(c),
                route: r.route,
                wall_ns,
                coalesced: w.coalesced,
            },
            Err(e) => ServeResult::Failed {
                error: Arc::clone(e),
                wall_ns,
                coalesced: w.coalesced,
            },
        };
        let _ = w.tx.send(msg);
    }
    if let Some(tr) = tracer {
        // every child span of this request was recorded before its
        // JobResult was sent, so closing the root here caps the tree
        tr.close_root(
            r.id,
            shared.is_err(),
            vec![
                ("route".to_string(), format!("{:?}", r.route)),
                ("wall_ns".to_string(), max_wall.to_string()),
                ("waiters".to_string(), n_waiters.to_string()),
            ],
        );
        tr.note_slow(r.id, max_wall);
    }
}

/// Record `batch_residency` spans for a flushing batch: one per member,
/// from the moment it entered the open batch to the flush, and forget
/// the marks. No-op with tracing off (the marks map stays empty).
fn record_batch_residency(
    tracer: Option<&Arc<Tracer>>,
    metrics: &Metrics,
    marks: &mut HashMap<u64, u64>,
    batch: &[Job],
) {
    let Some(tr) = tracer else { return };
    let s1 = tr.now_ns();
    for job in batch {
        let Some(s0) = marks.remove(&job.id) else { continue };
        tr.record(Span {
            trace: job.id,
            id: tr.next_span_id(),
            parent: tr.parent_for(job.id),
            name: "batch_residency".to_string(),
            lane: LANE_FRONT,
            t0_ns: s0,
            t1_ns: s1.max(s0),
            args: vec![("members".to_string(), batch.len().to_string())],
            error: false,
            instant: false,
        });
        metrics.phases.batch_residency.observe(s1.saturating_sub(s0));
    }
}

/// Move pending leaders into the coordinator (or the open batch) until
/// the inflight bound is hit, draining tenant queues round-robin.
#[allow(clippy::too_many_arguments)]
fn admit_ready(
    st: &mut FrontState,
    cfg: &ServeConfig,
    coord: &Coordinator,
    router: &Router,
    metrics: &Metrics,
    batcher: &mut Batcher,
    tracer: Option<&Arc<Tracer>>,
    batch_marks: &mut HashMap<u64, u64>,
) {
    while st.admitted < cfg.inflight_cap {
        let Some(tenant) = st.rr.pop_front() else { break };
        let Some(q) = st.queues.get_mut(&tenant) else { continue };
        let Some(pj) = q.pop_front() else { continue };
        if let Some(tr) = tracer {
            // time spent in the per-tenant queue waiting for an inflight
            // slot, admission instant back to the enqueue stamp
            let s1 = tr.now_ns();
            let s0 = if pj.t_ns > 0 { pj.t_ns.min(s1) } else { s1 };
            tr.record(Span {
                trace: pj.id,
                id: tr.next_span_id(),
                parent: tr.parent_for(pj.id),
                name: "queue_wait".to_string(),
                lane: LANE_FRONT,
                t0_ns: s0,
                t1_ns: s1,
                args: vec![("tenant".to_string(), tenant.clone())],
                error: false,
                instant: false,
            });
            metrics.phases.queue_wait.observe(s1.saturating_sub(s0));
        }
        if !q.is_empty() {
            st.rr.push_back(tenant);
        }
        st.admitted += 1;
        let id = pj.id;
        let job = Job { id, a: pj.a, b: pj.b, force_route: None };
        // routing and shard planning walk malformed operands (the
        // failure-injection surface); on the raw coordinator that
        // panic costs the *submitting* thread, but here the submitting
        // thread is the dispatcher every tenant depends on — convert
        // the panic into one failed request instead
        let submitted = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            if cfg.batch.enabled && matches!(router.route(&job.a, &job.b), Route::Hash) {
                if let Some(tr) = tracer {
                    batch_marks.insert(id, tr.now_ns());
                }
                if let Some(batch) = batcher.push(job) {
                    record_batch_residency(tracer, metrics, batch_marks, &batch);
                    coord.submit_batch(batch);
                }
            } else {
                coord.submit(job);
            }
        }));
        if submitted.is_err() {
            batch_marks.remove(&id);
            fan_out(
                st,
                metrics,
                tracer,
                JobResult {
                    id,
                    route: Route::Hash,
                    c: Err(anyhow::anyhow!(
                        "admission panicked while routing (malformed operands?)"
                    )),
                    wall_ns: 0,
                    nprod: 0,
                },
            );
        }
    }
}

/// The dispatcher: owns the coordinator, alternates admission with
/// result polling, flushes aged batches, and on stop drains everything
/// outstanding before persisting and shutting the coordinator down.
#[allow(clippy::too_many_arguments)]
fn dispatcher_loop(
    coord: Coordinator,
    router: Router,
    cfg: ServeConfig,
    state: Arc<Mutex<FrontState>>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    fit: Arc<NsPerProdFit>,
    tracer: Option<Arc<Tracer>>,
) {
    let mut batcher = Batcher::new(cfg.batch);
    // enqueue stamps of jobs riding the open batch (`batch_residency`
    // spans); always empty with tracing off
    let mut batch_marks: HashMap<u64, u64> = HashMap::new();
    loop {
        let stopping = stop.load(Ordering::SeqCst);
        {
            let mut guard = state.lock().unwrap_or_else(|e| e.into_inner());
            admit_ready(
                &mut guard,
                &cfg,
                &coord,
                &router,
                &metrics,
                &mut batcher,
                tracer.as_ref(),
                &mut batch_marks,
            );
        }
        // the age watermark (or a stop) flushes a partial batch so its
        // members never wait on traffic that may not come
        let flush = if stopping { batcher.take() } else { batcher.take_aged() };
        if let Some(batch) = flush {
            record_batch_residency(tracer.as_ref(), &metrics, &mut batch_marks, &batch);
            coord.submit_batch(batch);
        }
        if let Some(r) = coord.recv_timeout(DISPATCHER_TICK) {
            let mut guard = state.lock().unwrap_or_else(|e| e.into_inner());
            // fan out before admitting: a freed inflight slot goes to
            // the next tenant in the rotation on the same tick
            fan_out(&mut guard, &metrics, tracer.as_ref(), r);
            admit_ready(
                &mut guard,
                &cfg,
                &coord,
                &router,
                &metrics,
                &mut batcher,
                tracer.as_ref(),
                &mut batch_marks,
            );
        }
        if stopping {
            let drained = {
                let guard = state.lock().unwrap_or_else(|e| e.into_inner());
                guard.outstanding.is_empty()
            };
            if drained && batcher.is_empty() {
                break;
            }
        }
    }
    if let Some(path) = &cfg.persist {
        let snapshot = {
            let h = coord.history().lock().unwrap_or_else(|e| e.into_inner());
            PersistedState::capture(&h, &fit)
        };
        if let Err(e) = persist::save_state(path, &snapshot) {
            eprintln!("serve: failed to persist warm state: {e:#}");
        }
    }
    if let (Some(tr), Some(dir)) = (tracer.as_ref(), cfg.trace.dir.as_ref()) {
        write_trace_files(tr, dir);
    }
    coord.shutdown();
}

/// Write the full Chrome trace and the slow-request exemplar trace into
/// `dir` (created if missing). Both load in Perfetto / `chrome://tracing`.
fn write_trace_files(tr: &Tracer, dir: &str) {
    if let Err(e) = std::fs::create_dir_all(dir) {
        eprintln!("serve: failed to create trace dir {dir:?}: {e}");
        return;
    }
    let full = std::path::Path::new(dir).join("serve-trace.json");
    if let Err(e) = std::fs::write(&full, tr.export_chrome()) {
        eprintln!("serve: failed to write {full:?}: {e}");
    }
    let exemplars = tr.slow_exemplars();
    if !exemplars.is_empty() {
        let mut spans: Vec<Span> = exemplars.into_iter().flat_map(|s| s.spans).collect();
        spans.sort_by_key(|s| (s.t0_ns, s.id));
        let slow = std::path::Path::new(dir).join("serve-trace-slow.json");
        if let Err(e) = std::fs::write(&slow, chrome_trace_json(&spans)) {
            eprintln!("serve: failed to write {slow:?}: {e}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Serving behavior (coalescing, rejection, batching, persistence,
    // baseline parity) is integration-tested in tests/serve.rs; these
    // unit tests pin the config layering contract: CLI > env > default.

    #[test]
    fn default_layer_reproduces_the_baseline_posture() {
        let d = ServeConfig::default();
        assert!(d.coalesce, "coalescing is the front door's default-on feature");
        assert!(!d.batch.enabled, "batching defaults off (PR 5 baseline)");
        assert!(d.persist.is_none(), "persistence defaults off");
        assert_eq!(d.inflight_cap, usize::MAX, "admission is pass-through by default");
        assert_eq!(d.workers, 4);
        assert!(d.queue_cap >= 1024);
        let r = RouterConfig::default();
        assert_eq!(d.device_memory_bytes, r.device_memory_bytes);
        assert_eq!(d.max_devices, r.max_devices);
        assert_eq!(d.interconnect, r.interconnect);
        assert_eq!(d.replan, ReplanConfig::default());
        assert_eq!(d.overlap, OverlapConfig::default());
        assert!(!d.speculate.enabled, "speculation defaults off (PR 6 baseline)");
        assert!(d.chaos.is_off(), "chaos defaults off");
        assert_eq!(d.engine, EngineMode::Fill, "dispatch is opt-in (PR 8 baseline)");
    }

    #[test]
    fn env_layer_overrides_defaults_and_junk_keeps_them() {
        let env: HashMap<&str, &str> = [
            ("OPSPARSE_WORKERS", "7"),
            ("OPSPARSE_COALESCE", "off"),
            ("OPSPARSE_BATCH", "on"),
            ("OPSPARSE_BATCH_MAX", "12"),
            ("OPSPARSE_BATCH_AGE_MS", "9"),
            ("OPSPARSE_QUEUE_CAP", "3"),
            ("OPSPARSE_INFLIGHT", "2"),
            ("OPSPARSE_PERSIST", "warm.state"),
            ("OPSPARSE_REPLAN", "off"),
            ("OPSPARSE_HISTORY_CAP", "5"),
            ("OPSPARSE_OVERLAP", "off"),
            ("OPSPARSE_OVERLAP_CHUNK_KB", "64"),
            ("OPSPARSE_INTERCONNECT", "none"),
            ("OPSPARSE_SPECULATE", "on"),
            ("OPSPARSE_SPECULATE_LAG", "2.5"),
            ("OPSPARSE_CHAOS", "gentle"),
            ("OPSPARSE_CHAOS_SEED", "42"),
            ("OPSPARSE_ENGINE", "auto"),
        ]
        .into_iter()
        .collect();
        let cfg = ServeConfig::from_env_map(|k| env.get(k).map(|v| v.to_string()));
        assert_eq!(cfg.workers, 7);
        assert!(!cfg.coalesce);
        assert!(cfg.batch.enabled);
        assert_eq!(cfg.batch.max_jobs, 12);
        assert_eq!(cfg.batch.max_age, Duration::from_millis(9));
        assert_eq!(cfg.queue_cap, 3);
        assert_eq!(cfg.inflight_cap, 2);
        assert_eq!(cfg.persist.as_deref(), Some("warm.state"));
        assert!(!cfg.replan.enabled);
        assert_eq!(cfg.replan.history_cap, 5);
        assert!(!cfg.overlap.enabled);
        assert_eq!(cfg.overlap.chunk_bytes, 64 * 1024);
        assert_eq!(cfg.interconnect, None);
        assert!(cfg.speculate.enabled);
        assert_eq!(cfg.speculate.lag_factor, 2.5);
        assert_eq!(cfg.chaos, ChaosConfig::gentle().with_seed(42));
        assert_eq!(cfg.engine, EngineMode::Auto);
        // `on` maps to the default path; junk values keep the defaults
        let env2: HashMap<&str, &str> = [
            ("OPSPARSE_PERSIST", "on"),
            ("OPSPARSE_WORKERS", "zero"),
            ("OPSPARSE_COALESCE", "maybe"),
            ("OPSPARSE_INTERCONNECT", "carrier-pigeon"),
            ("OPSPARSE_ENGINE", "cuda"),
        ]
        .into_iter()
        .collect();
        let cfg2 = ServeConfig::from_env_map(|k| env2.get(k).map(|v| v.to_string()));
        assert_eq!(cfg2.persist.as_deref(), Some(DEFAULT_PERSIST_PATH));
        assert_eq!(cfg2.workers, ServeConfig::default().workers, "junk keeps default");
        assert!(cfg2.coalesce, "junk keeps default");
        assert_eq!(cfg2.interconnect, ServeConfig::default().interconnect);
        assert_eq!(cfg2.engine, EngineMode::Fill, "junk keeps default");
        // an empty env reproduces the defaults exactly
        assert_eq!(ServeConfig::from_env_map(|_| None), ServeConfig::default());
    }

    #[test]
    fn cli_layer_beats_env_and_rejects_junk() {
        // env says one thing...
        let env: HashMap<&str, &str> = [
            ("OPSPARSE_COALESCE", "off"),
            ("OPSPARSE_QUEUE_CAP", "3"),
            ("OPSPARSE_BATCH", "on"),
            ("OPSPARSE_ENGINE", "hash"),
        ]
        .into_iter()
        .collect();
        let base = ServeConfig::from_env_map(|k| env.get(k).map(|v| v.to_string()));
        // ...the CLI says another: CLI wins, untouched knobs keep env
        let flags: HashMap<String, String> = [
            ("coalesce".to_string(), "on".to_string()),
            ("queue-cap".to_string(), "77".to_string()),
            ("persist".to_string(), "cli.state".to_string()),
            ("engine".to_string(), "auto".to_string()),
        ]
        .into_iter()
        .collect();
        let cfg = ServeConfig::from_args_over(base.clone(), &flags).unwrap();
        assert!(cfg.coalesce, "CLI overrides env");
        assert_eq!(cfg.queue_cap, 77, "CLI overrides env");
        assert!(cfg.batch.enabled, "knobs the CLI left alone keep the env layer");
        assert_eq!(cfg.persist.as_deref(), Some("cli.state"));
        assert_eq!(cfg.engine, EngineMode::Auto, "CLI overrides env");
        // unknown flag names are ignored (commands carry extra flags)
        let extra: HashMap<String, String> =
            [("jobs".to_string(), "32".to_string())].into_iter().collect();
        assert_eq!(ServeConfig::from_args_over(base.clone(), &extra).unwrap(), base);
        // ...but a junk value on a known flag is an error, not a default
        for (k, v) in [
            ("coalesce", "maybe"),
            ("queue-cap", "many"),
            ("interconnect", "string-and-cans"),
            ("speculate", "perhaps"),
            ("speculate-lag", "-3"),
            ("chaos", "cruel"),
            ("chaos-seed", "lucky"),
            ("engine", "cuda"),
            ("trace", "maybe"),
            ("trace-slow", "lots"),
            ("trace-slow", "0"),
            ("trace-dir", ""),
        ] {
            let bad: HashMap<String, String> =
                [(k.to_string(), v.to_string())].into_iter().collect();
            assert!(
                ServeConfig::from_args_over(base.clone(), &bad).is_err(),
                "--{k} {v} must be rejected"
            );
        }
    }

    #[test]
    fn speculate_and_chaos_flags_layer_over_env() {
        // env turns chaos on with a seed; the CLI swaps the preset but
        // keeps the seed (the preset picks rates, not the schedule),
        // and flips speculation on with a custom lag factor
        let env: HashMap<&str, &str> =
            [("OPSPARSE_CHAOS", "gentle"), ("OPSPARSE_CHAOS_SEED", "7")].into_iter().collect();
        let base = ServeConfig::from_env_map(|k| env.get(k).map(|v| v.to_string()));
        assert_eq!(base.chaos, ChaosConfig::gentle().with_seed(7));
        let flags: HashMap<String, String> = [
            ("chaos".to_string(), "aggressive".to_string()),
            ("speculate".to_string(), "on".to_string()),
            ("speculate-lag".to_string(), "1.5".to_string()),
        ]
        .into_iter()
        .collect();
        let cfg = ServeConfig::from_args_over(base, &flags).unwrap();
        assert_eq!(cfg.chaos, ChaosConfig::aggressive().with_seed(7));
        assert!(cfg.speculate.enabled);
        assert_eq!(cfg.speculate.lag_factor, 1.5);
        // --chaos off really is off, whatever the seed says
        let off: HashMap<String, String> =
            [("chaos".to_string(), "off".to_string())].into_iter().collect();
        assert!(ServeConfig::from_args_over(cfg, &off).unwrap().chaos.is_off());
    }

    #[test]
    fn trace_knobs_layer_and_imply_enabled() {
        // defaults: off, no dir, 8 exemplars
        let d = ServeConfig::default();
        assert_eq!(d.trace, TraceConfig::default());
        assert!(!d.trace.enabled, "tracing defaults off (PR 9 baseline)");
        // env: a dir or a slow-K implies on; explicit off wins over both
        let env: HashMap<&str, &str> =
            [("OPSPARSE_TRACE_DIR", "/tmp/tr"), ("OPSPARSE_TRACE_SLOW", "3")]
                .into_iter()
                .collect();
        let cfg = ServeConfig::from_env_map(|k| env.get(k).map(|v| v.to_string()));
        assert!(cfg.trace.enabled);
        assert_eq!(cfg.trace.dir.as_deref(), Some("/tmp/tr"));
        assert_eq!(cfg.trace.slow_k, 3);
        let env_off: HashMap<&str, &str> =
            [("OPSPARSE_TRACE_DIR", "/tmp/tr"), ("OPSPARSE_TRACE", "off")].into_iter().collect();
        let cfg_off = ServeConfig::from_env_map(|k| env_off.get(k).map(|v| v.to_string()));
        assert!(!cfg_off.trace.enabled, "explicit off beats the implied on");
        assert_eq!(cfg_off.trace.dir.as_deref(), Some("/tmp/tr"), "the dir survives for later");
        // CLI: same implication, layered over env
        let flags: HashMap<String, String> =
            [("trace-slow".to_string(), "5".to_string())].into_iter().collect();
        let cfg2 = ServeConfig::from_args_over(cfg_off, &flags).unwrap();
        assert!(cfg2.trace.enabled, "--trace-slow implies --trace on");
        assert_eq!(cfg2.trace.slow_k, 5);
        let off: HashMap<String, String> = [
            ("trace".to_string(), "off".to_string()),
            ("trace-dir".to_string(), "/tmp/t2".to_string()),
        ]
        .into_iter()
        .collect();
        let cfg3 = ServeConfig::from_args_over(cfg2, &off).unwrap();
        assert!(!cfg3.trace.enabled, "--trace off beats --trace-dir on the same line");
        assert_eq!(cfg3.trace.dir.as_deref(), Some("/tmp/t2"));
    }

    #[test]
    fn persist_flag_spellings() {
        let base = ServeConfig::default();
        let mk = |v: &str| {
            let flags: HashMap<String, String> =
                [("persist".to_string(), v.to_string())].into_iter().collect();
            ServeConfig::from_args_over(base.clone(), &flags).unwrap().persist
        };
        assert_eq!(mk("on").as_deref(), Some(DEFAULT_PERSIST_PATH));
        assert_eq!(mk("off"), None);
        assert_eq!(mk("/tmp/custom.state").as_deref(), Some("/tmp/custom.state"));
    }

    #[test]
    fn router_config_carries_the_serve_knobs_and_fit() {
        let mut cfg = ServeConfig::default();
        cfg.device_memory_bytes = 4096;
        cfg.max_devices = 4;
        cfg.interconnect = None;
        cfg.overlap = OverlapConfig::off();
        cfg.engine = EngineMode::Auto;
        let fit = Arc::new(NsPerProdFit::new(2.0));
        let rc = cfg.router_config(Arc::clone(&fit));
        assert_eq!(rc.device_memory_bytes, 4096);
        assert_eq!(rc.max_devices, 4);
        assert_eq!(rc.interconnect, None);
        assert!(!rc.overlap.enabled);
        assert_eq!(rc.ns_per_prod, 2.0);
        assert!(rc.fit.is_some());
        assert_eq!(rc.ns_per_prod_now(), 2.0);
        assert_eq!(rc.engine_mode, EngineMode::Auto, "the engine knob reaches the router");
    }
}
