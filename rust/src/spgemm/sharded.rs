//! Row-sharded multi-device SpGEMM: partition `A` into contiguous row
//! shards, run the full six-step OpSparse pipeline per shard on its own
//! simulated device, and stitch the per-shard `C` row blocks into one CSR.
//!
//! Sharding is the standard path past a single device's memory and SM
//! count: row-block decomposition keeps every shard a complete, ordinary
//! SpGEMM (`C[lo..hi, :] = A[lo..hi, :] * B`), so the per-shard work
//! reuses [`multiply_reuse`] unchanged and the stitched result is
//! **bit-identical** to the unsharded pipeline — each output row is
//! computed by exactly the same code on exactly the same data, only on a
//! different device.
//!
//! Shards are balanced by per-row *intermediate products*
//! ([`crate::sparse::stats::nprod_per_row`]), not raw row count: on
//! power-law matrices a few
//! hub-coupled rows carry most of the multiply, and an equal-rows split
//! would overload one shard (see [`ShardPlan::balanced`]).
//!
//! `B` is replicated on every device — a one-to-all broadcast — and the
//! `C` row blocks are gathered back to the root; both transfers are
//! charged by [`crate::gpusim::Interconnect`] when the traces are fed to
//! [`crate::gpusim::MultiDevice::simulate_with_interconnect`]. Each
//! shard gets its own [`DevicePool`] and its own trace; use
//! [`crate::gpusim::MultiDevice`] for the makespan / scaling-efficiency
//! view, or [`ShardedOutput::into_output`] for a single-device
//! serialized view.
//!
//! # Example
//!
//! ```
//! use opsparse::gen::uniform::Uniform;
//! use opsparse::gpusim::{MultiDevice, V100};
//! use opsparse::spgemm::{multiply, multiply_sharded, OpSparseConfig};
//! use opsparse::util::rng::Rng;
//!
//! let a = Uniform { n: 256, per_row: 6, jitter: 3 }.generate(&mut Rng::new(7));
//! let cfg = OpSparseConfig::default();
//!
//! let sharded = multiply_sharded(&a, &a, &cfg, 4).unwrap();
//! // stitched result is bit-identical to the unsharded pipeline
//! assert_eq!(sharded.c, multiply(&a, &a, &cfg).unwrap().c);
//!
//! // aggregate the four device timelines into the critical-path view
//! let md = MultiDevice::simulate(sharded.traces(), &V100);
//! assert_eq!(md.n_devices(), 4);
//! assert!(md.makespan_ns() > 0.0);
//! ```

use super::hash_table::ProbeStats;
use super::pipeline::{multiply_reuse, OpSparseConfig, SpgemmOutput, SymbolicReuse};
use crate::gpusim::multi::OverlapConfig;
use crate::gpusim::pool::DevicePool;
use crate::gpusim::trace::{Trace, TraceOp};
use crate::sparse::ops::row_slice;
use crate::sparse::Csr;
use anyhow::{anyhow, ensure, Result};
use std::sync::Arc;

/// A partition of `A`'s rows into contiguous shards.
///
/// Invariants: `bounds.len() == n_shards + 1`, `bounds[0] == 0`, the
/// bounds are non-decreasing, and `bounds[n_shards] == rows`. Empty
/// shards (equal neighbouring bounds) are legal — they arise when the
/// shard count exceeds the row count — and execute as zero-row pipelines.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    bounds: Vec<usize>,
    /// Per-shard work (sum of `nprod + 1` over the shard's rows).
    costs: Vec<u64>,
}

impl ShardPlan {
    /// Balance shards by per-row intermediate products: a greedy prefix
    /// walk that closes the current shard when taking the next row would
    /// overshoot its fair share of the *remaining* work more than
    /// stopping short undershoots it. Each row costs `nprod[i] + 1` (the
    /// `+ 1` accounts for per-row metadata traffic and keeps all-zero
    /// matrices splittable).
    ///
    /// With `n_shards >= rows` every non-empty shard holds exactly one
    /// row; trailing shards are empty.
    pub fn balanced(nprod: &[usize], n_shards: usize) -> ShardPlan {
        let shards = n_shards.max(1);
        // one greedy-cut implementation serves both the proxy and the
        // measured-cost path ([`ShardPlan::from_history`]): integer-
        // valued f64 costs keep the arithmetic exact below 2^53, so
        // this is the same cut the all-integer loop produced
        let cost: Vec<f64> = nprod.iter().map(|&p| p as f64 + 1.0).collect();
        let bounds = cut_rows_f64(&cost, shards);
        let costs: Vec<u64> = bounds
            .windows(2)
            .map(|w| (w[0]..w[1]).map(|i| nprod[i] as u64 + 1).sum())
            .collect();
        ShardPlan { bounds, costs }
    }

    /// [`ShardPlan::balanced`] with every interior cut snapped to a
    /// multiple of the block size `t` — the plan the block-sharded route
    /// ([`crate::coordinator::Route::ShardedBlock`]) runs on. A cut
    /// inside a `T`-row block would split that block across devices: the
    /// per-shard BSR conversions would then pad *different* block
    /// contents than the unsharded conversion, and bit-identity with the
    /// unsharded block result would be lost. Each interior bound rounds
    /// to the nearest multiple of `t` (monotonicity preserved, bounds
    /// clamped to `[0, rows]`); the outer bounds stay `0` and `rows`, so
    /// a ragged final block remains intact on the last shard. `t <= 1`
    /// degenerates to the unaligned proxy plan.
    pub fn balanced_aligned(nprod: &[usize], n_shards: usize, t: usize) -> ShardPlan {
        let plan = ShardPlan::balanced(nprod, n_shards);
        if t <= 1 {
            return plan;
        }
        let n = nprod.len();
        let mut bounds = plan.bounds;
        let last = bounds.len() - 1;
        for i in 1..last {
            let b = bounds[i];
            let down = b / t * t;
            let up = (down + t).min(n);
            let snapped = if b - down <= up - b { down } else { up };
            bounds[i] = snapped.max(bounds[i - 1]).min(n);
        }
        let costs: Vec<u64> = bounds
            .windows(2)
            .map(|w| (w[0]..w[1]).map(|i| nprod[i] as u64 + 1).sum())
            .collect();
        ShardPlan { bounds, costs }
    }

    pub fn n_shards(&self) -> usize {
        self.costs.len()
    }

    /// Total row count the plan partitions.
    pub fn rows(&self) -> usize {
        *self.bounds.last().unwrap()
    }

    /// Row range `[lo, hi)` of shard `s`.
    pub fn range(&self, s: usize) -> (usize, usize) {
        (self.bounds[s], self.bounds[s + 1])
    }

    /// The shard boundaries (`n_shards + 1` entries).
    pub fn bounds(&self) -> &[usize] {
        &self.bounds
    }

    /// Planned work per shard (in `nprod + 1` units).
    pub fn costs(&self) -> &[u64] {
        &self.costs
    }

    /// Planned load imbalance: max shard work / mean shard work
    /// (1.0 = perfect). Empty shards count toward the mean.
    pub fn load_imbalance(&self) -> f64 {
        let total: u64 = self.costs.iter().sum();
        if total == 0 {
            return 1.0;
        }
        let mean = total as f64 / self.costs.len() as f64;
        let max = *self.costs.iter().max().unwrap() as f64;
        max / mean
    }

    /// Re-cut shard bounds from a *measured* previous run of the same
    /// pattern: `measured` carries one `(lo, hi, ns)` entry per shard of
    /// that run (simulated `device_total_ns`, or worker wall times in the
    /// service). Each shard's measured time is distributed over its rows
    /// proportionally to the `nprod + 1` proxy — the proxy is still the
    /// best *within-shard* shape estimate; the measurement corrects the
    /// *between-shard* scale the proxy misses (per-bin kernel-config
    /// effects, §5.3) — and the greedy prefix cut then equalizes measured
    /// ns instead of products.
    ///
    /// Falls back to [`ShardPlan::balanced`] when the measurement is
    /// unusable (empty, not a contiguous partition of `nprod.len()`,
    /// non-finite or all-zero timings) — the cold-pattern path. When the
    /// measurement is usable, three candidate cuts compete on modeled
    /// makespan (max shard cost under the measured row costs): the
    /// greedy re-cut, the *previous run's own bounds* (when the shard
    /// count matches), and the proxy. The previous bounds win unless the
    /// re-cut beats them by [`REPLAN_SWITCH_GAIN`] — switching plans
    /// invalidates the per-shard symbolic cache keys, so a challenger
    /// must improve meaningfully, which also damps plan oscillation:
    /// once a cut is good, repeats keep it (and keep their warm cache
    /// entries). Whatever wins, the chosen plan never degrades the
    /// modeled makespan vs the proxy plan, and any valid partition
    /// stitches bit-identically — the re-cut can only move time, never
    /// change the result.
    pub fn from_history(
        nprod: &[usize],
        n_shards: usize,
        measured: &[MeasuredShard],
    ) -> ShardPlan {
        let n = nprod.len();
        let proxy = ShardPlan::balanced(nprod, n_shards);
        let mut expect = 0usize;
        let mut usable = !measured.is_empty();
        for m in measured {
            if m.lo != expect || m.hi < m.lo || m.hi > n || !m.ns.is_finite() || m.ns < 0.0 {
                usable = false;
                break;
            }
            expect = m.hi;
        }
        if !usable || expect != n {
            return proxy;
        }
        let mut cost = vec![0.0f64; n];
        let mut total = 0.0f64;
        for m in measured {
            if m.hi == m.lo {
                continue;
            }
            let w: f64 = (m.lo..m.hi).map(|i| nprod[i] as f64 + 1.0).sum();
            for i in m.lo..m.hi {
                cost[i] = m.ns * (nprod[i] as f64 + 1.0) / w;
            }
            total += m.ns;
        }
        if total <= 0.0 {
            return proxy;
        }
        let recut = cut_rows_f64(&cost, n_shards.max(1));
        let max_shard = |b: &[usize]| -> f64 {
            b.windows(2).map(|w| cost[w[0]..w[1]].iter().sum::<f64>()).fold(0.0, f64::max)
        };
        let m_recut = max_shard(&recut);
        let m_proxy = max_shard(proxy.bounds());
        // the measured partition IS the previous run's plan — a
        // stability candidate when the shard count still matches
        let prev: Option<Vec<usize>> = (measured.len() == n_shards.max(1)).then(|| {
            let mut b = Vec::with_capacity(measured.len() + 1);
            b.push(0);
            b.extend(measured.iter().map(|m| m.hi));
            b
        });
        // adopting the re-cut always demands the GAIN margin — over the
        // incumbent *and* over the proxy — so every plan switch is
        // backed by a clearly-predicted win. (A persistently mispredicted
        // re-cut can still alternate with the proxy across runs: the
        // margin bounds how wrong the within-shard proportionality
        // assumption must be for that to happen; rejection memory in the
        // history would eliminate it and is a noted follow-on.)
        let chosen = match prev {
            Some(p) => {
                let m_prev = max_shard(&p);
                if m_recut < m_prev * REPLAN_SWITCH_GAIN
                    && m_recut < m_proxy * REPLAN_SWITCH_GAIN
                {
                    recut // meaningfully better than incumbent and proxy
                } else if m_prev <= m_proxy + 1e-9 {
                    p // keep the incumbent (and its warm cache keys)
                } else {
                    // incumbent degraded and the re-cut did not clearly
                    // win (a re-cut beating proxy*GAIN would also beat
                    // the worse incumbent*GAIN and land in branch 1)
                    proxy.bounds().to_vec()
                }
            }
            None => {
                if m_recut < m_proxy * REPLAN_SWITCH_GAIN {
                    recut
                } else {
                    proxy.bounds().to_vec()
                }
            }
        };
        let costs: Vec<u64> = chosen
            .windows(2)
            .map(|w| cost[w[0]..w[1]].iter().sum::<f64>().round() as u64)
            .collect();
        ShardPlan { bounds: chosen, costs }
    }
}

/// Hysteresis of the warm re-cut: a challenger plan must beat the
/// incumbent's modeled makespan by this factor before
/// [`ShardPlan::from_history`] switches to it. Re-cutting has a real
/// switching cost — per-shard symbolic cache entries are keyed on the
/// shard bounds, so a new cut recomputes every changed shard's symbolic
/// phase once — and sub-percent modeled differences are noise.
pub const REPLAN_SWITCH_GAIN: f64 = 0.995;

/// One shard's measured execution of a previous run: the row range it
/// covered and the time it took (simulated device ns, or a worker's wall
/// clock). The feedback layer ([`crate::coordinator::feedback`]) stores
/// these per pattern and [`ShardPlan::from_history`] re-cuts from them.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MeasuredShard {
    /// First row of the shard (inclusive).
    pub lo: usize,
    /// One past the last row of the shard.
    pub hi: usize,
    /// Measured time the shard took, in ns.
    pub ns: f64,
}

/// The greedy prefix cut of [`ShardPlan::balanced`], on measured `f64`
/// row costs: close the current shard when taking the next row would
/// overshoot its fair share of the remaining work more than stopping
/// short undershoots it. Returns `shards + 1` monotone bounds covering
/// `0..cost.len()` (trailing empty shards when rows run out).
fn cut_rows_f64(cost: &[f64], shards: usize) -> Vec<usize> {
    let n = cost.len();
    let total: f64 = cost.iter().sum();
    let mut bounds = Vec::with_capacity(shards + 1);
    bounds.push(0);
    let mut closed = 0usize;
    let mut acc = 0.0f64;
    let mut spent = 0.0f64;
    for (i, &c) in cost.iter().enumerate() {
        let open = shards - closed; // shards left, incl. the current one
        if open > 1 && acc > 0.0 {
            let target = (total - spent) / open as f64;
            if (acc + c) - target > target - acc {
                bounds.push(i);
                closed += 1;
                spent += acc;
                acc = 0.0;
            }
        }
        acc += c;
    }
    bounds.push(n);
    while bounds.len() < shards + 1 {
        bounds.push(n);
    }
    bounds
}

/// Cached per-shard symbolic results for one `(A pattern, B pattern,
/// plan)` triple: entry `s` replays shard `s`'s symbolic phase (see
/// [`SymbolicReuse`]). Callers key entries on
/// `(Csr::pattern_fingerprint_rows(lo, hi), fingerprint(B))` — the
/// shard-aware cache keys — so repeated sharded traffic (AMG re-setup at
/// scale) skips every per-shard symbolic phase, not just whole-operand
/// repeats. Missing (`None`) entries compute normally.
#[derive(Clone, Debug, Default)]
pub struct ShardReuse {
    pub entries: Vec<Option<Arc<SymbolicReuse>>>,
}

/// Result of a sharded multiply: the stitched matrix plus every shard's
/// full pipeline output (one simulated device each).
#[derive(Clone, Debug)]
pub struct ShardedOutput {
    /// The stitched result, bit-identical to the unsharded pipeline's `C`.
    pub c: Csr,
    /// The row partition the run used.
    pub plan: ShardPlan,
    /// Per-shard pipeline outputs, in shard order. `shards[s].trace` is
    /// device `s`'s trace; `shards[s].c` is the row block `C[lo..hi, :]`.
    pub shards: Vec<SpgemmOutput>,
    /// Total intermediate products across all shards.
    pub nprod: usize,
    /// Overlap model the traces were annotated for (chunked-broadcast
    /// dependencies; see [`annotate_chunk_deps`]).
    pub overlap: OverlapConfig,
    /// Device footprint of the replicated `B` operand — the broadcast
    /// payload, kept so callers can feed
    /// [`crate::gpusim::MultiDevice::simulate_overlapped`] without
    /// holding on to `B`.
    pub b_bytes: usize,
}

impl ShardedOutput {
    /// Per-device traces in shard order (feed to
    /// [`crate::gpusim::MultiDevice::simulate`]).
    pub fn traces(&self) -> impl Iterator<Item = &Trace> {
        self.shards.iter().map(|s| &s.trace)
    }

    /// Per-device `C` row-block sizes in bytes, in shard order — the
    /// payload a result gather moves (feed to
    /// [`crate::gpusim::MultiDevice::simulate_with_interconnect`]).
    pub fn c_block_bytes(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.c.device_bytes()).collect()
    }

    pub fn flops(&self) -> f64 {
        2.0 * self.nprod as f64
    }

    /// Collapse into a single [`SpgemmOutput`] whose trace concatenates
    /// the shard traces. The merged trace *serializes* the devices, so
    /// simulating it gives the one-device-at-a-time upper bound, not the
    /// concurrent makespan — use [`crate::gpusim::MultiDevice`] for that.
    pub fn into_output(self) -> SpgemmOutput {
        let ShardedOutput { c, shards, nprod, .. } = self;
        let symbolic_skipped = !shards.is_empty() && shards.iter().all(|s| s.symbolic_skipped);
        let mut trace = Trace::new();
        let mut sym_stats = ProbeStats::default();
        let mut num_stats = ProbeStats::default();
        let mut fallback = 0usize;
        for s in shards {
            sym_stats.add(&s.sym_stats);
            num_stats.add(&s.num_stats);
            fallback += s.sym_fallback_rows;
            trace.ops.extend(s.trace.ops);
        }
        SpgemmOutput {
            c,
            trace,
            nprod,
            sym_stats,
            num_stats,
            sym_fallback_rows: fallback,
            symbolic_skipped,
        }
    }
}

/// Row-sharded `C = A * B` over `n_shards` simulated devices, each shard
/// balanced by intermediate products and run through the full OpSparse
/// pipeline with per-call allocation (no cross-call pools).
///
/// Prefer [`crate::spgemm::request::SpgemmRequest`] in new code — this
/// wrapper is `SpgemmRequest::new(a, b).config(cfg).shards(n)`, kept
/// for existing callers:
///
/// ```
/// use opsparse::sparse::Csr;
/// use opsparse::spgemm::{multiply_sharded, OpSparseConfig, SpgemmRequest};
///
/// let a = Csr::identity(64);
/// let cfg = OpSparseConfig::default();
/// let old = multiply_sharded(&a, &a, &cfg, 3).unwrap();
/// let new = SpgemmRequest::new(&a, &a).config(&cfg).shards(3).run_sharded().unwrap();
/// assert_eq!(old.c, new.c); // bit-identical
/// assert_eq!(old.plan.bounds(), new.plan.bounds()); // same cut
/// ```
pub fn multiply_sharded(
    a: &Csr,
    b: &Csr,
    cfg: &OpSparseConfig,
    n_shards: usize,
) -> Result<ShardedOutput> {
    crate::spgemm::request::SpgemmRequest::new(a, b).config(cfg).shards(n_shards).run_sharded()
}

/// [`multiply_sharded`] for a warm owner: balances a fresh plan and runs
/// it over `pools`, growing the vector to `n_shards` first (one
/// [`DevicePool`] per device, recycled across calls). A convenience
/// wrapper with the default overlap model and no per-shard symbolic
/// reuse — callers that need the plan up front (shard-aware cache keys,
/// as [`crate::apps::SpgemmContext`] does) or custom overlap/reuse call
/// [`multiply_sharded_with`] directly.
/// Prefer [`crate::spgemm::request::SpgemmRequest`] in new code — this
/// wrapper only adds the pool-vector growth before delegating to
/// `SpgemmRequest::new(a, b).config(cfg).shards(n).pools(..)`:
///
/// ```
/// use opsparse::gpusim::DevicePool;
/// use opsparse::sparse::Csr;
/// use opsparse::spgemm::{multiply_sharded_pooled, OpSparseConfig, SpgemmRequest};
///
/// let a = Csr::identity(64);
/// let cfg = OpSparseConfig::default();
/// let mut pools = Vec::new();
/// let old = multiply_sharded_pooled(&a, &a, &cfg, 2, &mut pools).unwrap();
/// let mut pools2 = vec![DevicePool::new(), DevicePool::new()];
/// let new = SpgemmRequest::new(&a, &a)
///     .config(&cfg)
///     .shards(2)
///     .pools(&mut pools2)
///     .run_sharded()
///     .unwrap();
/// assert_eq!(old.c, new.c); // bit-identical
/// ```
pub fn multiply_sharded_pooled(
    a: &Csr,
    b: &Csr,
    cfg: &OpSparseConfig,
    n_shards: usize,
    pools: &mut Vec<DevicePool>,
) -> Result<ShardedOutput> {
    let n = n_shards.max(1);
    while pools.len() < n {
        pools.push(DevicePool::new());
    }
    crate::spgemm::request::SpgemmRequest::new(a, b)
        .config(cfg)
        .shards(n)
        .pools(&mut pools[..n])
        .run_sharded()
}

/// [`multiply_sharded`] with an explicit plan, optional per-device
/// pools (one [`DevicePool`] per shard, recycled across calls by a warm
/// owner such as a coordinator worker or an
/// [`crate::apps::SpgemmContext`]), an [`OverlapConfig`] governing the
/// chunked-broadcast trace annotation, and optional per-shard symbolic
/// reuse entries ([`ShardReuse`], the shard-aware pattern-cache hook).
///
/// Shards execute concurrently on host threads — the service-layer
/// fan-out — and are stitched back in shard order, so the result is
/// deterministic regardless of scheduling, and **independent of
/// `overlap`**: overlap only annotates each shard's trace with
/// [`TraceOp::AwaitChunk`] dependencies (symbolic work gated on the
/// arrival of `B`'s row panels) for
/// [`crate::gpusim::MultiDevice::simulate_overlapped`]; the serial
/// simulation path ignores them, and the numerics never see them.
pub fn multiply_sharded_with(
    a: &Csr,
    b: &Csr,
    cfg: &OpSparseConfig,
    plan: &ShardPlan,
    pools: Option<&mut [DevicePool]>,
    overlap: OverlapConfig,
    reuse: Option<&ShardReuse>,
) -> Result<ShardedOutput> {
    ensure!(a.cols == b.rows, "dimension mismatch: {}x{} * {}x{}", a.rows, a.cols, b.rows, b.cols);
    ensure!(plan.rows() == a.rows, "plan covers {} rows, A has {}", plan.rows(), a.rows);
    let n = plan.n_shards();
    if let Some(r) = reuse {
        ensure!(r.entries.len() == n, "{} reuse entries for {} shards", r.entries.len(), n);
    }
    let mut slots: Vec<Option<&mut DevicePool>> = match pools {
        Some(ps) => {
            ensure!(ps.len() == n, "{} pools for {} shards", ps.len(), n);
            ps.iter_mut().map(Some).collect()
        }
        None => (0..n).map(|_| None).collect(),
    };

    let results: Vec<Result<SpgemmOutput>> = std::thread::scope(|scope| {
        let handles: Vec<_> = slots
            .drain(..)
            .enumerate()
            .map(|(s, slot)| {
                let (lo, hi) = plan.range(s);
                let entry = reuse.and_then(|r| r.entries[s].clone());
                scope.spawn(move || -> Result<SpgemmOutput> {
                    let a_s = row_slice(a, lo, hi)?;
                    multiply_reuse(&a_s, b, cfg, slot, entry.as_deref())
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| Err(anyhow!("shard worker panicked"))))
            .collect()
    });

    let mut shards = Vec::with_capacity(n);
    for r in results {
        shards.push(r?);
    }

    let b_bytes = b.device_bytes();
    if overlap.enabled && n > 1 {
        let chunks = overlap.chunks_for(b_bytes);
        for s in &mut shards {
            annotate_chunk_deps(&mut s.trace, chunks);
        }
    }

    let (c, nprod) = stitch_row_blocks(a.rows, b.cols, &shards)?;
    Ok(ShardedOutput { c, plan: plan.clone(), shards, nprod, overlap, b_bytes })
}

/// Annotate one shard's device trace with its chunked-broadcast
/// dependencies: `B` streams in as `chunks` row panels, the first
/// B-reading launch (the setup `n_prod` kernel) waits on panel 0, the
/// remaining panels gate evenly-spaced symbolic launches (already-
/// received panels feed the kernels in between — OpSparse's §5.4
/// overlap discipline applied to the interconnect), and every await
/// precedes the numeric phase, which scans all of `B`. On a trace with
/// no symbolic launches (a symbolic-reuse replay) the residual awaits
/// gate the first numeric launch instead. Serial replays are unaffected:
/// [`crate::gpusim::simulate`] treats the markers as free.
pub fn annotate_chunk_deps(trace: &mut Trace, chunks: usize) {
    let k = chunks.max(1);
    let n_sym = trace
        .ops
        .iter()
        .filter(|op| matches!(op, TraceOp::Launch(krn) if krn.step == "symbolic"))
        .count();
    // chunk -> how many awaits to emit before the i-th symbolic launch;
    // chunk 0 precedes the first launch of any step, leftovers precede
    // the first numeric launch
    let mut before_sym = vec![0usize; n_sym];
    let mut before_numeric = 0usize;
    for c in 1..k {
        let idx = c * n_sym / k;
        if idx < n_sym {
            before_sym[idx] += 1;
        } else {
            before_numeric += 1;
        }
    }
    let mut ops = Vec::with_capacity(trace.ops.len() + k);
    let mut next_chunk = 0usize;
    let mut sym_seen = 0usize;
    let mut numeric_seen = false;
    for op in trace.ops.drain(..) {
        if let TraceOp::Launch(krn) = &op {
            if next_chunk == 0 {
                ops.push(TraceOp::AwaitChunk { chunk: 0, step: krn.step });
                next_chunk = 1;
            }
            if krn.step == "symbolic" {
                for _ in 0..before_sym[sym_seen] {
                    ops.push(TraceOp::AwaitChunk { chunk: next_chunk, step: "symbolic" });
                    next_chunk += 1;
                }
                sym_seen += 1;
            }
            if krn.step == "numeric" && !numeric_seen {
                numeric_seen = true;
                for _ in 0..before_numeric {
                    ops.push(TraceOp::AwaitChunk { chunk: next_chunk, step: "numeric" });
                    next_chunk += 1;
                }
            }
        }
        ops.push(op);
    }
    // a trace with no launches at all (degenerate): park every await up
    // front so the dependency count still reflects the broadcast
    while next_chunk < k {
        ops.push(TraceOp::AwaitChunk { chunk: next_chunk, step: "cleanup" });
        next_chunk += 1;
    }
    trace.ops = ops;
}

/// Stitch per-shard `C` row blocks (in shard order) into one `rows`-row
/// CSR by offset-adjusting each block's row pointers, and sum the shard
/// `nprod` counts. Shared by [`multiply_sharded_with`] and the
/// coordinator's cross-worker reassembly barrier
/// ([`crate::coordinator::barrier::ShardBarrier`]), so both fan-out
/// paths reassemble bit-identically.
pub fn stitch_row_blocks(
    rows: usize,
    cols: usize,
    shards: &[SpgemmOutput],
) -> Result<(Csr, usize)> {
    let block_rows: usize = shards.iter().map(|s| s.c.rows).sum();
    ensure!(block_rows == rows, "row blocks cover {block_rows} rows, expected {rows}");
    let mut rpt = Vec::with_capacity(rows + 1);
    rpt.push(0usize);
    let total_nnz: usize = shards.iter().map(|s| s.c.nnz()).sum();
    let mut col = Vec::with_capacity(total_nnz);
    let mut val = Vec::with_capacity(total_nnz);
    let mut nprod = 0usize;
    for s in shards {
        let base = *rpt.last().unwrap();
        rpt.extend(s.c.rpt[1..].iter().map(|&p| p + base));
        col.extend_from_slice(&s.c.col);
        val.extend_from_slice(&s.c.val);
        nprod += s.nprod;
    }
    Ok((Csr { rows, cols, rpt, col, val }, nprod))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::uniform::Uniform;
    use crate::sparse::stats::nprod_per_row;
    use crate::spgemm::pipeline::multiply;
    use crate::util::rng::Rng;

    #[test]
    fn plan_partitions_all_rows_in_order() {
        let nprod = vec![5, 1, 1, 1, 20, 1, 1, 6, 2, 3];
        let plan = ShardPlan::balanced(&nprod, 3);
        assert_eq!(plan.n_shards(), 3);
        assert_eq!(plan.bounds()[0], 0);
        assert_eq!(plan.rows(), nprod.len());
        for w in plan.bounds().windows(2) {
            assert!(w[0] <= w[1]);
        }
        let total: u64 = plan.costs().iter().sum();
        assert_eq!(total, nprod.iter().map(|&p| p as u64 + 1).sum::<u64>());
    }

    #[test]
    fn plan_balances_skewed_work_better_than_equal_rows() {
        // one heavy prefix row: an equal-rows split would lump it with a
        // quarter of the tail; the balanced plan isolates it and spreads
        // the tail evenly over the remaining shards
        let mut nprod = vec![1usize; 64];
        nprod[0] = 1000;
        let plan = ShardPlan::balanced(&nprod, 4);
        assert_eq!(plan.range(0), (0, 1), "the giant row gets its own shard");
        let tail = &plan.costs()[1..];
        let (min, max) = (tail.iter().min().unwrap(), tail.iter().max().unwrap());
        assert!(*max <= min + 2, "tail shards must be near-equal: {tail:?}");
        // strictly better than the equal-rows split, whose first shard
        // carries the giant row plus a quarter of the tail
        let equal_rows_max = (1000 + 1) + 15 * 2;
        let balanced_max = *plan.costs().iter().max().unwrap();
        assert!(balanced_max < equal_rows_max, "{balanced_max} vs {equal_rows_max}");
    }

    #[test]
    fn from_history_equalizes_measured_time_not_products() {
        // the proxy sees uniform work (equal nprod), but the measurement
        // says the first half ran 3x slower per product (a bin-config
        // effect the proxy cannot see): the re-cut must shift rows off
        // the slow half
        let nprod = vec![8usize; 64];
        let proxy = ShardPlan::balanced(&nprod, 2);
        assert_eq!(proxy.range(0), (0, 32), "uniform proxy splits in half");
        let measured = vec![
            MeasuredShard { lo: 0, hi: 32, ns: 3000.0 },
            MeasuredShard { lo: 32, hi: 64, ns: 1000.0 },
        ];
        let plan = ShardPlan::from_history(&nprod, 2, &measured);
        assert_eq!(plan.rows(), 64);
        assert_eq!(plan.n_shards(), 2);
        let (_, hi0) = plan.range(0);
        assert!(hi0 < 32, "slow rows must shed work, got bound {hi0}");
        // modeled makespan (max shard measured-cost) strictly improves
        assert!(
            *plan.costs().iter().max().unwrap() < 3000,
            "re-cut must beat the proxy's 3000ns critical path: {:?}",
            plan.costs()
        );
    }

    #[test]
    fn from_history_never_degrades_modeled_makespan() {
        // across skewed and uniform measurements, the chosen plan's max
        // measured-cost shard never exceeds the proxy plan's
        let mut rng = Rng::new(96);
        for trial in 0..20 {
            let n = 40 + (trial % 5) * 17;
            let nprod: Vec<usize> = (0..n).map(|_| (rng.next_u64() % 50) as usize).collect();
            for shards in [2usize, 3, 4, 8] {
                let proxy = ShardPlan::balanced(&nprod, shards);
                let measured: Vec<MeasuredShard> = (0..shards)
                    .map(|s| {
                        let (lo, hi) = proxy.range(s);
                        MeasuredShard { lo, hi, ns: 100.0 + (rng.next_u64() % 5000) as f64 }
                    })
                    .collect();
                let plan = ShardPlan::from_history(&nprod, shards, &measured);
                // rebuild the measured row costs the same way from_history
                // does and compare critical paths
                let mut cost = vec![0.0f64; n];
                for m in &measured {
                    let w: f64 = (m.lo..m.hi).map(|i| nprod[i] as f64 + 1.0).sum();
                    for i in m.lo..m.hi {
                        cost[i] = m.ns * (nprod[i] as f64 + 1.0) / w;
                    }
                }
                let max_of = |b: &[usize]| -> f64 {
                    b.windows(2)
                        .map(|w| cost[w[0]..w[1]].iter().sum::<f64>())
                        .fold(0.0, f64::max)
                };
                assert!(
                    max_of(plan.bounds()) <= max_of(proxy.bounds()) + 1e-6,
                    "trial {trial} shards {shards}: replanned makespan degraded"
                );
                // and the plan is a valid partition
                assert_eq!(plan.bounds()[0], 0);
                assert_eq!(plan.rows(), n);
                assert_eq!(plan.n_shards(), shards);
                for w in plan.bounds().windows(2) {
                    assert!(w[0] <= w[1], "bounds must be monotone");
                }
            }
        }
    }

    #[test]
    fn from_history_keeps_a_good_incumbent_plan() {
        // hysteresis: a measured partition that is already balanced is
        // kept verbatim even though it differs from the proxy cut —
        // switching plans would invalidate warm per-shard cache keys
        // for no modeled gain (max shard cost can never drop below the
        // mean, and the incumbent already sits at it)
        let nprod = vec![4usize; 40];
        let measured = vec![
            MeasuredShard { lo: 0, hi: 9, ns: 1000.0 },
            MeasuredShard { lo: 9, hi: 20, ns: 1000.0 },
            MeasuredShard { lo: 20, hi: 31, ns: 1000.0 },
            MeasuredShard { lo: 31, hi: 40, ns: 1000.0 },
        ];
        let plan = ShardPlan::from_history(&nprod, 4, &measured);
        assert_eq!(plan.bounds(), &[0, 9, 20, 31, 40], "the incumbent must be kept");
        // and repeats stay stable: re-planning from the incumbent's own
        // (balanced) measurement returns the same bounds again
        let again = ShardPlan::from_history(&nprod, 4, &measured);
        assert_eq!(again.bounds(), plan.bounds());
    }

    #[test]
    fn from_history_falls_back_to_proxy_when_unusable() {
        let nprod = vec![5usize; 20];
        let proxy = ShardPlan::balanced(&nprod, 4);
        // empty, gapped, out-of-range, non-finite, and all-zero
        // measurements all fall back to the proxy bounds
        let cases: Vec<Vec<MeasuredShard>> = vec![
            vec![],
            vec![MeasuredShard { lo: 0, hi: 10, ns: 1.0 }],
            vec![
                MeasuredShard { lo: 0, hi: 10, ns: 1.0 },
                MeasuredShard { lo: 12, hi: 20, ns: 1.0 },
            ],
            vec![MeasuredShard { lo: 0, hi: 25, ns: 1.0 }],
            vec![
                MeasuredShard { lo: 0, hi: 10, ns: f64::NAN },
                MeasuredShard { lo: 10, hi: 20, ns: 1.0 },
            ],
            vec![
                MeasuredShard { lo: 0, hi: 10, ns: 0.0 },
                MeasuredShard { lo: 10, hi: 20, ns: 0.0 },
            ],
        ];
        for (i, measured) in cases.iter().enumerate() {
            let plan = ShardPlan::from_history(&nprod, 4, measured);
            assert_eq!(plan.bounds(), proxy.bounds(), "case {i} must fall back");
        }
    }

    #[test]
    fn from_history_replanned_run_is_bit_identical() {
        let mut rng = Rng::new(97);
        let a = Uniform { n: 280, per_row: 8, jitter: 4 }.generate(&mut rng);
        let cfg = OpSparseConfig::default();
        let nprod = nprod_per_row(&a, &a);
        let proxy = ShardPlan::balanced(&nprod, 4);
        let cold =
            multiply_sharded_with(&a, &a, &cfg, &proxy, None, OverlapConfig::default(), None)
                .unwrap();
        // a deliberately lopsided measurement forces a different cut
        let measured: Vec<MeasuredShard> = (0..4)
            .map(|s| {
                let (lo, hi) = proxy.range(s);
                MeasuredShard { lo, hi, ns: if s == 0 { 9000.0 } else { 1000.0 } }
            })
            .collect();
        let plan = ShardPlan::from_history(&nprod, 4, &measured);
        assert_ne!(plan.bounds(), proxy.bounds(), "measurement must change the cut");
        let warm =
            multiply_sharded_with(&a, &a, &cfg, &plan, None, OverlapConfig::default(), None)
                .unwrap();
        assert_eq!(warm.c, cold.c, "any valid partition stitches bit-identically");
    }

    #[test]
    fn aligned_plan_cuts_on_block_row_multiples() {
        let nprod: Vec<usize> = (0..100).map(|i| (i % 7) + 1).collect();
        let t = 16;
        let plan = ShardPlan::balanced_aligned(&nprod, 3, t);
        assert_eq!(plan.rows(), 100);
        assert_eq!(plan.n_shards(), 3);
        let b = plan.bounds();
        assert_eq!(b[0], 0);
        for &cut in &b[1..b.len() - 1] {
            assert!(cut % t == 0 || cut == 100, "interior cut {cut} not t-aligned");
        }
        for w in b.windows(2) {
            assert!(w[0] <= w[1], "bounds must stay monotone");
        }
        // alignment never loses rows; costs re-sum exactly
        assert_eq!(
            plan.costs().iter().sum::<u64>(),
            nprod.iter().map(|&p| p as u64 + 1).sum::<u64>()
        );
        // t <= 1 degenerates to the unaligned proxy plan
        let p1 = ShardPlan::balanced_aligned(&nprod, 3, 1);
        assert_eq!(p1.bounds(), ShardPlan::balanced(&nprod, 3).bounds());
        // more shards than blocks: empty shards are legal, partition holds
        let tiny = ShardPlan::balanced_aligned(&[1usize; 8], 4, 16);
        assert_eq!(tiny.rows(), 8);
        assert_eq!(tiny.bounds()[0], 0);
        for w in tiny.bounds().windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn plan_with_more_shards_than_rows_has_empty_tail() {
        let plan = ShardPlan::balanced(&[3, 3, 3], 8);
        assert_eq!(plan.n_shards(), 8);
        assert_eq!(plan.rows(), 3);
        let nonempty = (0..8).filter(|&s| plan.range(s).0 < plan.range(s).1).count();
        assert_eq!(nonempty, 3, "each row in its own shard, 5 empty");
    }

    #[test]
    fn single_row_shards_when_counts_match() {
        let plan = ShardPlan::balanced(&[2, 2, 2, 2], 4);
        for s in 0..4 {
            assert_eq!(plan.range(s), (s, s + 1));
        }
        assert!((plan.load_imbalance() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn sharded_matches_unsharded_bit_for_bit() {
        let mut rng = Rng::new(91);
        let a = Uniform { n: 300, per_row: 9, jitter: 4 }.generate(&mut rng);
        let cfg = OpSparseConfig::default();
        let gold = multiply(&a, &a, &cfg).unwrap();
        for shards in [1, 2, 3, 4, 8] {
            let out = multiply_sharded(&a, &a, &cfg, shards).unwrap();
            assert_eq!(out.c, gold.c, "{shards} shards must be bit-identical");
            assert_eq!(out.nprod, gold.nprod);
            assert_eq!(out.shards.len(), shards);
            out.c.validate().unwrap();
        }
    }

    #[test]
    fn per_device_pools_recycle_across_calls() {
        let mut rng = Rng::new(92);
        let a = Uniform { n: 240, per_row: 8, jitter: 4 }.generate(&mut rng);
        let cfg = OpSparseConfig::default();
        let plan = ShardPlan::balanced(&nprod_per_row(&a, &a), 3);
        let mut pools: Vec<DevicePool> = (0..3).map(|_| DevicePool::new()).collect();
        let cold = multiply_sharded_with(
            &a,
            &a,
            &cfg,
            &plan,
            Some(&mut pools),
            OverlapConfig::default(),
            None,
        )
        .unwrap();
        assert!(cold.traces().any(|t| t.malloc_calls() > 0), "cold call grows the pools");
        let warm = multiply_sharded_with(
            &a,
            &a,
            &cfg,
            &plan,
            Some(&mut pools),
            OverlapConfig::default(),
            None,
        )
        .unwrap();
        assert_eq!(warm.c, cold.c);
        for (s, t) in warm.traces().enumerate() {
            assert_eq!(t.malloc_calls(), 0, "shard {s} warm call must be malloc-free");
        }
    }

    #[test]
    fn pooled_helper_grows_and_recycles() {
        let mut rng = Rng::new(93);
        let a = Uniform { n: 200, per_row: 7, jitter: 3 }.generate(&mut rng);
        let cfg = OpSparseConfig::default();
        let mut pools = Vec::new();
        let cold = multiply_sharded_pooled(&a, &a, &cfg, 3, &mut pools).unwrap();
        assert_eq!(pools.len(), 3, "helper must grow the pool vector");
        let warm = multiply_sharded_pooled(&a, &a, &cfg, 3, &mut pools).unwrap();
        assert_eq!(warm.c, cold.c);
        assert!(warm.traces().all(|t| t.malloc_calls() == 0), "warm call must recycle");
        // dimension mismatch is a proper error, not a shard-planning panic
        let b = Csr::zero(7, 7);
        assert!(multiply_sharded_pooled(&a, &b, &cfg, 2, &mut pools).is_err());
    }

    #[test]
    fn wrong_pool_count_is_error() {
        let a = Csr::identity(8);
        let cfg = OpSparseConfig::default();
        let plan = ShardPlan::balanced(&nprod_per_row(&a, &a), 2);
        let mut pools = vec![DevicePool::new()];
        assert!(multiply_sharded_with(
            &a,
            &a,
            &cfg,
            &plan,
            Some(&mut pools),
            OverlapConfig::default(),
            None
        )
        .is_err());
    }

    #[test]
    fn overlap_annotation_covers_every_chunk_in_order() {
        let mut rng = Rng::new(94);
        let a = Uniform { n: 260, per_row: 8, jitter: 4 }.generate(&mut rng);
        let cfg = OpSparseConfig::default();
        let plan = ShardPlan::balanced(&nprod_per_row(&a, &a), 3);
        let overlap = OverlapConfig { enabled: true, chunk_bytes: a.device_bytes() / 7 + 1 };
        let out =
            multiply_sharded_with(&a, &a, &cfg, &plan, None, overlap, None).unwrap();
        let chunks = overlap.chunks_for(a.device_bytes());
        assert!(chunks > 1, "test needs a chunked broadcast");
        for (s, t) in out.traces().enumerate() {
            assert_eq!(t.chunk_deps(), chunks, "shard {s} must wait on every chunk");
            // awaits appear in increasing chunk order
            let seen: Vec<usize> = t
                .ops
                .iter()
                .filter_map(|op| match op {
                    TraceOp::AwaitChunk { chunk, .. } => Some(*chunk),
                    _ => None,
                })
                .collect();
            assert_eq!(seen, (0..chunks).collect::<Vec<_>>(), "shard {s}");
            // the numeric phase never precedes the last await
            let last_await = t
                .ops
                .iter()
                .rposition(|op| matches!(op, TraceOp::AwaitChunk { .. }))
                .unwrap();
            let first_numeric = t
                .ops
                .iter()
                .position(|op| matches!(op, TraceOp::Launch(k) if k.step == "numeric"));
            if let Some(fnum) = first_numeric {
                assert!(last_await < fnum, "shard {s}: numeric launched before chunk arrival");
            }
        }
        // overlap off (or a single device) leaves traces clean
        let off = multiply_sharded_with(&a, &a, &cfg, &plan, None, OverlapConfig::off(), None)
            .unwrap();
        assert!(off.traces().all(|t| t.chunk_deps() == 0));
        assert_eq!(off.c, out.c, "annotation must not change the numerics");
    }

    #[test]
    fn shard_reuse_entries_replay_per_shard_symbolic() {
        let mut rng = Rng::new(95);
        let a = Uniform { n: 300, per_row: 9, jitter: 4 }.generate(&mut rng);
        let cfg = OpSparseConfig::default();
        let plan = ShardPlan::balanced(&nprod_per_row(&a, &a), 4);
        let cold =
            multiply_sharded_with(&a, &a, &cfg, &plan, None, OverlapConfig::default(), None)
                .unwrap();
        let reuse = ShardReuse {
            entries: cold
                .shards
                .iter()
                .map(|s| Some(Arc::new(SymbolicReuse::from_output(s))))
                .collect(),
        };
        let warm = multiply_sharded_with(
            &a,
            &a,
            &cfg,
            &plan,
            None,
            OverlapConfig::default(),
            Some(&reuse),
        )
        .unwrap();
        assert_eq!(warm.c, cold.c, "shard-level symbolic replay must be bit-identical");
        assert!(warm.shards.iter().all(|s| s.symbolic_skipped), "every shard must skip");
        // entry count must match the plan
        let short = ShardReuse { entries: vec![None; 3] };
        assert!(multiply_sharded_with(
            &a,
            &a,
            &cfg,
            &plan,
            None,
            OverlapConfig::default(),
            Some(&short)
        )
        .is_err());
    }
}
