//! Uniform random generator: each row draws `per_row +- jitter` distinct
//! columns uniformly. Models the mid-CR matrices of Table 3 (poisson3Da,
//! 2cubes_sphere, offshore, cage12-like) where products rarely collide.

use super::build_rows;
use crate::sparse::Csr;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Uniform {
    pub n: usize,
    pub per_row: usize,
    /// +- jitter on the row size (uniform in [per_row-jitter, per_row+jitter]).
    pub jitter: usize,
}

impl Uniform {
    pub fn generate(&self, rng: &mut Rng) -> Csr {
        let n = self.n;
        let mut tmp = Vec::new();
        build_rows(n, n, rng, |_, rng, out| {
            let lo = self.per_row.saturating_sub(self.jitter).max(1);
            let hi = (self.per_row + self.jitter + 1).min(n + 1);
            let k = rng.range(lo, hi);
            rng.sample_distinct(n, k, &mut tmp);
            out.extend_from_slice(&tmp);
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::stats::MatrixStats;

    #[test]
    fn row_sizes_in_band() {
        let g = Uniform { n: 500, per_row: 20, jitter: 5 };
        let m = g.generate(&mut Rng::new(4));
        m.validate().unwrap();
        for i in 0..m.rows {
            let k = m.row_nnz(i);
            assert!((15..=25).contains(&k), "row {i} size {k} outside band");
        }
        let s = MatrixStats::of(&m);
        assert!((s.avg_row_nnz - 20.0).abs() < 2.0);
    }

    #[test]
    fn deterministic() {
        let g = Uniform { n: 200, per_row: 8, jitter: 2 };
        assert_eq!(g.generate(&mut Rng::new(6)), g.generate(&mut Rng::new(6)));
    }
}
