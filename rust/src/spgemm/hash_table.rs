//! Hash-table accumulator (paper §5.2, Algorithms 4–5).
//!
//! One table computes one output row. The CPU execution is semantically a
//! linear-probing open-addressing table; what the paper's optimization
//! changes is the *number of table accesses per probe iteration*, which we
//! account explicitly so the simulator can price shared-memory traffic and
//! bank conflicts:
//!
//! * [`HashVariant::SingleAccess`] (OpSparse): one `atomicCAS` per
//!   iteration; the swapped value is kept in a register → 1 access/iter.
//! * [`HashVariant::MultiAccess`] (nsparse/spECK): read the slot, branch,
//!   then CAS on the insert path → ~2 accesses/iter plus a re-read after a
//!   failed CAS under contention (we charge the deterministic 2).
//!
//! Table sizes that are powers of two use the `&`-mask address map
//! (symbolic step); other sizes use `%` (numeric step) — the simulator
//! prices the mod at a few extra cycles per probe (§5.2).

use super::HashVariant;

/// Sentinel for an unoccupied slot (column indices are < 2^31).
pub const EMPTY: u32 = u32::MAX;

/// Probe/traffic statistics accumulated while computing rows; the cost
/// model converts these into shared-memory time.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ProbeStats {
    /// Keys inserted or merged (one per intermediate product).
    pub inserts: u64,
    /// Probe-loop iterations (>= inserts; the excess is hash collisions).
    pub probe_iters: u64,
    /// Shared/global table word accesses (variant-dependent).
    pub table_accesses: u64,
    /// Iterations that used a `%` address map instead of `&`.
    pub mod_ops: u64,
}

impl ProbeStats {
    pub fn add(&mut self, o: &ProbeStats) {
        self.inserts += o.inserts;
        self.probe_iters += o.probe_iters;
        self.table_accesses += o.table_accesses;
        self.mod_ops += o.mod_ops;
    }

    /// Collision rate: extra probe iterations per insert.
    pub fn collision_rate(&self) -> f64 {
        if self.inserts == 0 {
            return 0.0;
        }
        (self.probe_iters - self.inserts) as f64 / self.inserts as f64
    }
}

/// A hash-table accumulator sized for one kernel's `t_size`.
///
/// Reused across rows via [`HashAccumulator::reset`] — the real kernels
/// re-initialize shared memory per row; we charge that as `t_size` accesses
/// in the stats (the `init_elems` of the block work model).
pub struct HashAccumulator {
    t_size: usize,
    pow2: bool,
    mask: usize,
    keys: Vec<u32>,
    vals: Vec<f64>,
    /// Epoch stamps: a slot is live iff `stamps[i] == epoch`. This makes
    /// [`HashAccumulator::reset`] O(1) on the CPU — the *simulated* init
    /// cost is still charged to the trace (`init_words` in the callers);
    /// this only removes the host-side memset from our hot loop (§Perf).
    stamps: Vec<u32>,
    epoch: u32,
    /// Lemire fastmod magic for non-pow2 tables: exact `h % t_size`
    /// without a hardware divide in the CPU hot loop (§Perf). The
    /// *simulated* cost still counts `mod_ops` — this only speeds up our
    /// emulation, the GPU algorithm is unchanged.
    fastmod_m: u64,
    variant: HashVariant,
    /// Reusable sort scratch for [`HashAccumulator::condense_sorted`]
    /// (avoids a per-row allocation in the numeric hot loop, §Perf).
    scratch: Vec<(u32, f64)>,
    pub stats: ProbeStats,
}

impl HashAccumulator {
    pub fn new(t_size: usize, variant: HashVariant) -> Self {
        let pow2 = t_size.is_power_of_two();
        HashAccumulator {
            t_size,
            pow2,
            mask: if pow2 { t_size - 1 } else { 0 },
            keys: vec![EMPTY; t_size],
            vals: vec![0.0; t_size],
            stamps: vec![0; t_size],
            epoch: 1,
            fastmod_m: if pow2 { 0 } else { u64::MAX / t_size as u64 + 1 },
            variant,
            scratch: Vec::new(),
            stats: ProbeStats::default(),
        }
    }

    #[inline]
    pub fn t_size(&self) -> usize {
        self.t_size
    }

    /// Clear all slots (the per-row shared-memory init): O(1) epoch bump,
    /// with a full flush on the (rare) u32 wraparound.
    pub fn reset(&mut self) {
        if self.epoch == u32::MAX {
            self.stamps.fill(0);
            self.epoch = 0;
        }
        self.epoch += 1;
    }

    /// Live slot check under the epoch scheme.
    #[inline]
    fn slot_key(&self, i: usize) -> u32 {
        if self.stamps[i] == self.epoch {
            self.keys[i]
        } else {
            EMPTY
        }
    }

    #[inline]
    fn first_slot(&mut self, key: u32) -> usize {
        let h = key.wrapping_mul(super::kernel_tables::HASH_SCALE);
        if self.pow2 {
            h as usize & self.mask
        } else {
            self.stats.mod_ops += 1;
            // exact h % t_size via Lemire's fastmod (no hardware divide)
            let lowbits = self.fastmod_m.wrapping_mul(h as u64);
            ((lowbits as u128 * self.t_size as u128) >> 64) as usize
        }
    }

    #[inline]
    fn next_slot(&mut self, hash: usize) -> usize {
        if self.pow2 {
            (hash + 1) & self.mask
        } else {
            // numeric step: `hash + 1 < t_size ? hash + 1 : 0` (Alg. 5 L11)
            if hash + 1 < self.t_size {
                hash + 1
            } else {
                0
            }
        }
    }

    #[inline]
    fn accesses_per_iter(&self) -> u64 {
        match self.variant {
            HashVariant::SingleAccess => 1,
            HashVariant::MultiAccess => 2,
        }
    }

    /// Symbolic insert (Algorithm 4): returns `true` if the key was new
    /// (the caller increments its `shared_nnz`), `false` on duplicate.
    /// Returns `None` if the table is full (kernel-7 overflow → the row is
    /// recorded for the global-table fallback kernel).
    #[inline]
    pub fn insert_symbolic(&mut self, key: u32) -> Option<bool> {
        debug_assert_ne!(key, EMPTY);
        let mut hash = self.first_slot(key);
        let acc = self.accesses_per_iter();
        // per-iteration counters stay in registers; stats flush once per
        // call (§Perf: 2 fewer memory RMWs per probe iteration)
        let mut iters = 0u64;
        let mut result = None;
        for _ in 0..self.t_size {
            iters += 1;
            let old = self.slot_key(hash); // atomicCAS(old := slot; slot = key if empty)
            if old == EMPTY {
                self.keys[hash] = key;
                self.stamps[hash] = self.epoch;
                result = Some(true);
                break;
            } else if old == key {
                result = Some(false);
                break;
            }
            hash = self.next_slot(hash);
        }
        self.stats.probe_iters += iters;
        self.stats.table_accesses += iters * acc;
        if result.is_some() {
            self.stats.inserts += 1;
        }
        result
    }

    /// Numeric insert (Algorithm 5): accumulate `val` under `key`.
    /// Returns `false` if the table is full.
    #[inline]
    pub fn insert_numeric(&mut self, key: u32, val: f64) -> bool {
        debug_assert_ne!(key, EMPTY);
        let mut hash = self.first_slot(key);
        let acc = self.accesses_per_iter();
        let mut iters = 0u64;
        let mut ok = false;
        for _ in 0..self.t_size {
            iters += 1;
            let old = self.slot_key(hash);
            if old == EMPTY || old == key {
                if old == EMPTY {
                    self.keys[hash] = key;
                    self.stamps[hash] = self.epoch;
                    self.vals[hash] = val;
                } else {
                    self.vals[hash] += val; // atomicAdd(shared_val + hash, a*b)
                }
                ok = true;
                break;
            }
            hash = self.next_slot(hash);
        }
        self.stats.probe_iters += iters;
        // + 1: the atomicAdd is a second shared access
        self.stats.table_accesses += iters * acc + u64::from(ok);
        self.stats.inserts += u64::from(ok);
        ok
    }

    /// Number of occupied slots.
    pub fn occupied(&self) -> usize {
        (0..self.t_size).filter(|&i| self.stamps[i] == self.epoch).count()
    }

    /// Condense + sort phase (numeric kernels, §5.6.2): gather occupied
    /// slots, sort by column, append to `cols`/`vals`. Uses the internal
    /// scratch buffer — no allocation after the first row.
    pub fn condense_sorted(&mut self, cols: &mut Vec<u32>, vals: &mut Vec<f64>) {
        self.scratch.clear();
        for i in 0..self.t_size {
            if self.stamps[i] == self.epoch {
                self.scratch.push((self.keys[i], self.vals[i]));
            }
        }
        self.scratch.sort_unstable_by_key(|&(c, _)| c);
        cols.extend(self.scratch.iter().map(|&(c, _)| c));
        vals.extend(self.scratch.iter().map(|&(_, v)| v));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::collections::BTreeMap;

    #[test]
    fn symbolic_counts_distinct_keys() {
        let mut t = HashAccumulator::new(64, HashVariant::SingleAccess);
        let keys = [5u32, 9, 5, 120, 9, 9, 3];
        let mut nnz = 0;
        for &k in &keys {
            if t.insert_symbolic(k).unwrap() {
                nnz += 1;
            }
        }
        assert_eq!(nnz, 4);
        assert_eq!(t.occupied(), 4);
        assert_eq!(t.stats.inserts, keys.len() as u64);
    }

    #[test]
    fn numeric_accumulates_duplicates() {
        let mut t = HashAccumulator::new(31, HashVariant::SingleAccess); // non-pow2 like kernel0
        assert!(t.insert_numeric(7, 1.5));
        assert!(t.insert_numeric(7, 2.5));
        assert!(t.insert_numeric(3, -1.0));
        let (mut c, mut v) = (Vec::new(), Vec::new());
        t.condense_sorted(&mut c, &mut v);
        assert_eq!(c, vec![3, 7]);
        assert_eq!(v, vec![-1.0, 4.0]);
        assert!(t.stats.mod_ops > 0, "non-pow2 table must use mod");
    }

    #[test]
    fn pow2_table_uses_mask_not_mod() {
        let mut t = HashAccumulator::new(512, HashVariant::SingleAccess);
        for k in 0..100u32 {
            t.insert_symbolic(k).unwrap();
        }
        assert_eq!(t.stats.mod_ops, 0);
    }

    #[test]
    fn full_table_reports_overflow() {
        let mut t = HashAccumulator::new(4, HashVariant::SingleAccess);
        for k in 0..4u32 {
            assert!(t.insert_symbolic(k * 16 + 1).is_some());
        }
        assert_eq!(t.insert_symbolic(999), None);
        assert!(!t.insert_numeric(999, 1.0));
    }

    #[test]
    fn multi_access_counts_double_traffic() {
        let mut single = HashAccumulator::new(256, HashVariant::SingleAccess);
        let mut multi = HashAccumulator::new(256, HashVariant::MultiAccess);
        let mut rng = Rng::new(5);
        let keys: Vec<u32> = (0..150).map(|_| rng.below(1 << 20) as u32).collect();
        for &k in &keys {
            single.insert_symbolic(k).unwrap();
            multi.insert_symbolic(k).unwrap();
        }
        assert_eq!(single.stats.probe_iters, multi.stats.probe_iters, "same semantics");
        assert_eq!(multi.stats.table_accesses, 2 * single.stats.table_accesses);
    }

    #[test]
    fn matches_btreemap_accumulation() {
        let mut rng = Rng::new(8);
        for _ in 0..20 {
            let mut t = HashAccumulator::new(1023, HashVariant::SingleAccess);
            let mut gold: BTreeMap<u32, f64> = BTreeMap::new();
            for _ in 0..rng.range(1, 500) {
                let k = rng.below(4096) as u32;
                let v = rng.value();
                assert!(t.insert_numeric(k, v));
                *gold.entry(k).or_insert(0.0) += v;
            }
            let (mut c, mut v) = (Vec::new(), Vec::new());
            t.condense_sorted(&mut c, &mut v);
            let gold_c: Vec<u32> = gold.keys().copied().collect();
            assert_eq!(c, gold_c);
            for (i, (_, gv)) in gold.iter().enumerate() {
                assert!((v[i] - gv).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn collision_rate_rises_with_occupancy() {
        // fill a table to 95% vs 40% and compare collision rates — the
        // §4.3 trade-off the binning ranges tune.
        let mut rng = Rng::new(13);
        let run = |fill: usize, rng: &mut Rng| {
            let mut t = HashAccumulator::new(1024, HashVariant::SingleAccess);
            let mut inserted = 0usize;
            while inserted < fill {
                let k = rng.below(1 << 24) as u32;
                if t.insert_symbolic(k) == Some(true) {
                    inserted += 1;
                }
            }
            t.stats.collision_rate()
        };
        let low = run(410, &mut rng);
        let high = run(973, &mut rng);
        assert!(
            high > 3.0 * low.max(0.01),
            "collision rate should explode near full occupancy: low={low:.3} high={high:.3}"
        );
    }
}
