//! Application workloads from the paper's introduction (§1): the reason
//! SpGEMM performance matters. Each app drives the OpSparse pipeline (or
//! a semiring variant) as its compute primitive:
//!
//! * [`amg`] — algebraic multigrid: the Galerkin triple product
//!   `A_coarse = R·A·P` is two SpGEMMs per level [1, 2].
//! * [`mcl`] — Markov clustering: the expansion step is `M²` [3].
//! * [`msbfs`] — multi-source BFS: frontier expansion is a boolean
//!   SpGEMM `F ⊗ A` [4].

pub mod amg;
pub mod mcl;
pub mod msbfs;
