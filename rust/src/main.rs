//! `opsparse` CLI — the L3 launcher.
//!
//! Subcommands:
//! * `gen --name <matrix> [--scale s] [--out f.mtx]` — emit a suite matrix
//! * `spgemm --a f.mtx [--b g.mtx] [--lib L] [--verify]` — one multiply
//! * `suite [--scale s] [--verify]` — all 26 matrices, all libraries
//! * `bench <fig5|fig6|fig7_8|fig9|fig10|fig11|tables|ablations|pool|shards|serve|chaos|corpus|engines|trace|all>`
//!   (`bench shards` takes `--interconnect pcie|nvlink|none`,
//!   `--overlap on|off`, `--chunk-kb <KiB>`, `--json <path>`,
//!   `--overlap-json <path>`, `--replan on|off`, and
//!   `--adaptive-json <path>`; `bench serve` takes `--jobs n` and
//!   `--json <path>`; `bench chaos` takes `--jobs n`, `--chaos-seed n`,
//!   and `--json <path>`; `bench corpus` takes `--dir <corpus dir>` and
//!   `--json <path>`, with `OPSPARSE_CORPUS_DIR` /
//!   `OPSPARSE_BENCH_JSON_CORPUS` as env fallbacks; `bench engines`
//!   takes `--reps n` and `--json <path>`, with
//!   `OPSPARSE_ENGINE_BENCH_REPS` / `OPSPARSE_BENCH_JSON_ENGINES` as
//!   env fallbacks; `bench trace` takes `--jobs n`, `--json <path>`,
//!   and `--events-json <path>`, with `OPSPARSE_BENCH_JSON_TRACE` /
//!   `OPSPARSE_BENCH_TRACE_EVENTS` as env fallbacks)
//! * `serve [--jobs n] [--workers w] [--engine fill|auto|hash|block]
//!   [--coalesce on|off] [--batch on|off]
//!   [--batch-max n] [--batch-age-ms n] [--queue-cap n] [--inflight n]
//!   [--persist on|off|path] [--replan on|off] [--history-cap n]
//!   [--overlap on|off] [--chunk-kb n] [--interconnect pcie|nvlink|none]
//!   [--speculate on|off] [--speculate-lag f]
//!   [--chaos off|gentle|aggressive] [--chaos-seed n]
//!   [--trace on|off] [--trace-dir d] [--trace-slow k] [--prometheus]`
//!   — the serving front door (coalescing, batching, admission control,
//!   warm-start persistence, straggler speculation, fault injection,
//!   request tracing) over the coordinator
//! * `trace [--jobs n] [--trace-dir d] [serve flags]` — a traced
//!   demonstration run (sharded + speculative + gentle-chaos traffic,
//!   tracing forced on): writes Perfetto-loadable trace files, prints
//!   the metrics snapshot and its Prometheus text exposition
//! * `sim-case webbase` — §6.3.4 / §6.3.5 case-study timeline
//!
//! Offline build: argument parsing is hand-rolled (no clap in the vendor
//! set).

use anyhow::{bail, Context, Result};
use opsparse::baselines::Library;
use opsparse::bench::{figures, gflops, run_and_simulate, tables};
use opsparse::coordinator::{Serve, ServeConfig, ServeResult};
use opsparse::gen::suite::{entries, suite_entry, SuiteScale};
use opsparse::gpusim::{simulate, V100};
use opsparse::sparse::mmio;
use opsparse::util::fmt;
use opsparse::util::rng::Rng;
use std::collections::HashMap;

fn parse_flags(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            pos.push(args[i].clone());
            i += 1;
        }
    }
    (pos, flags)
}

fn scale_of(flags: &HashMap<String, String>) -> SuiteScale {
    flags
        .get("scale")
        .and_then(|s| SuiteScale::parse(s))
        .unwrap_or(SuiteScale::Small)
}

fn lib_of(flags: &HashMap<String, String>) -> Result<Library> {
    match flags.get("lib").map(|s| s.as_str()).unwrap_or("opsparse") {
        "opsparse" => Ok(Library::OpSparse),
        "nsparse" => Ok(Library::Nsparse),
        "speck" => Ok(Library::Speck),
        "cusparse" => Ok(Library::Cusparse),
        other => bail!("unknown library {other} (opsparse|nsparse|speck|cusparse)"),
    }
}

fn cmd_gen(flags: &HashMap<String, String>) -> Result<()> {
    let name = flags.get("name").context("--name <suite matrix> required")?;
    let e = suite_entry(name).with_context(|| format!("unknown suite matrix {name}"))?;
    let a = e.generate(scale_of(flags));
    let out = flags.get("out").cloned().unwrap_or_else(|| format!("{name}.mtx"));
    mmio::write_file(&a, &out)?;
    println!("wrote {out}: {}x{} nnz {}", a.rows, a.cols, fmt::count(a.nnz()));
    Ok(())
}

fn cmd_spgemm(flags: &HashMap<String, String>) -> Result<()> {
    let a = mmio::read_file(flags.get("a").context("--a <file.mtx> required")?)?;
    let b = match flags.get("b") {
        Some(p) => mmio::read_file(p)?,
        None => a.clone(),
    };
    let lib = lib_of(flags)?;
    let t0 = std::time::Instant::now();
    let out = lib.run(&a, &b)?;
    let cpu_ns = t0.elapsed().as_nanos() as f64;
    let tl = simulate(&out.trace, &V100);
    println!("{}: C = {}x{} nnz {}", lib.name(), out.c.rows, out.c.cols, fmt::count(out.c.nnz()));
    println!(
        "  nprod {}  CR {:.2}",
        fmt::count(out.nprod),
        out.nprod as f64 / out.c.nnz().max(1) as f64
    );
    println!(
        "  cpu wall {}  simulated V100 {}  => {:.2} GFLOPS (sim)",
        fmt::ns(cpu_ns),
        fmt::ns(tl.total_ns),
        tl.gflops(out.flops())
    );
    if flags.contains_key("verify") {
        let gold = opsparse::spgemm::reference::spgemm_reference(&a, &b);
        match out.c.diff(&gold, 1e-9) {
            None => println!("  verify: OK (matches sort-merge reference)"),
            Some(d) => bail!("verify FAILED: {d}"),
        }
    }
    Ok(())
}

fn cmd_suite(flags: &HashMap<String, String>) -> Result<()> {
    let scale = scale_of(flags);
    let verify = flags.contains_key("verify");
    println!("suite at scale {scale:?} (verify={verify})");
    println!("{:<18} {:>12} {:>12} {:>12} {:>12}", "matrix", "cuSPARSE", "nsparse", "spECK", "OpSparse");
    for e in entries() {
        let a = e.generate(scale);
        let mut row = format!("{:<18}", e.name);
        for lib in Library::all() {
            if e.large && lib == Library::Cusparse {
                row.push_str(&format!("{:>12}", "OOM"));
                continue;
            }
            let (out, tl) = run_and_simulate(lib, &a, verify)?;
            row.push_str(&format!("{:>12.2}", gflops(&out, &tl)));
        }
        println!("{row}");
    }
    Ok(())
}

fn cmd_bench(pos: &[String], flags: &HashMap<String, String>) -> Result<()> {
    let scale = scale_of(flags);
    let verify = flags.contains_key("verify");
    let which = pos.first().map(|s| s.as_str()).unwrap_or("all");
    match which {
        "fig5" => {
            figures::fig5(scale, verify)?;
        }
        "fig6" => {
            figures::fig6(scale, verify)?;
        }
        "fig7_8" => {
            figures::fig7_8(scale)?;
        }
        "fig9" => {
            figures::fig9(scale)?;
        }
        "fig10" => {
            figures::fig10(scale)?;
        }
        "fig11" => {
            figures::fig11(scale)?;
        }
        "tables" => {
            tables::table1();
            tables::table2();
            tables::table4_5();
            tables::table3(scale)?;
        }
        "ablations" => figures::ablations(scale)?,
        "pool" => {
            let reps = flags.get("reps").map(|s| s.parse()).transpose()?.unwrap_or(5);
            figures::pool_ablation(scale, reps)?;
        }
        "shards" => {
            use opsparse::coordinator::feedback::parse_on_off;
            let name = flags.get("interconnect").map(|s| s.as_str()).unwrap_or("pcie");
            let ic = opsparse::gpusim::Interconnect::parse_opt(name)
                .with_context(|| format!("unknown interconnect {name} (pcie|nvlink|none)"))?;
            // overlap defaults come from the environment
            // (OPSPARSE_OVERLAP / OPSPARSE_OVERLAP_CHUNK_KB); flags win
            let mut overlap = opsparse::gpusim::OverlapConfig::from_env();
            if let Some(v) = flags.get("overlap") {
                overlap.enabled = parse_on_off(v)
                    .with_context(|| format!("unknown --overlap value {v} (on|off)"))?;
            }
            if let Some(kb) = flags.get("chunk-kb") {
                let kb: usize = kb.parse().context("--chunk-kb <KiB>")?;
                if kb == 0 {
                    bail!("--chunk-kb must be positive");
                }
                overlap.chunk_bytes = kb
                    .checked_mul(1024)
                    .with_context(|| format!("--chunk-kb {kb} overflows"))?;
            }
            let rows = figures::shard_scaling_with(scale, ic.as_ref(), overlap)?;
            if let Some(path) = flags.get("json") {
                opsparse::bench::write_shard_scaling_json(path, scale, &rows)?;
            }
            if let Some(path) = flags.get("overlap-json") {
                // the overlap JSON is the CI contract: its rows and its
                // embedded Welch-gate verdict come from the statistical
                // runner (seed-2026 repetition first), not the
                // flag-configured display run above
                let stat = opsparse::util::stats::AdaptiveConfig::from_env();
                let (grows, gate) = figures::overlap_gate(scale, &stat)?;
                opsparse::bench::write_overlap_json(path, scale, &grows, &[gate])?;
            }
            // --replan runs the adaptive cold-vs-warm ablation on top
            // and emits BENCH_adaptive.json. Env defaults, flags win —
            // the same pattern as the overlap knobs above.
            let mut replan_on = std::env::var("OPSPARSE_REPLAN")
                .ok()
                .and_then(|v| parse_on_off(&v))
                .unwrap_or(false);
            if let Some(v) = flags.get("replan") {
                replan_on = parse_on_off(v)
                    .with_context(|| format!("unknown --replan value {v} (on|off)"))?;
            }
            if replan_on {
                // per-cell warm <= cold stays enforced inside
                // adaptive_replan_seeded; the JSON verdict is the
                // aggregate Welch gate over adaptively many repetitions
                let stat = opsparse::util::stats::AdaptiveConfig::from_env();
                let (arows, gate) = figures::adaptive_gate(scale, &stat)?;
                let env_path = std::env::var("OPSPARSE_BENCH_JSON_ADAPTIVE").ok();
                let path = flags
                    .get("adaptive-json")
                    .map(String::as_str)
                    .or(env_path.as_deref())
                    .unwrap_or("BENCH_adaptive.json");
                opsparse::bench::write_adaptive_json(path, scale, &arows, &[gate])?;
            }
        }
        "perf" => {
            let m = flags.get("matrix").map(|s| s.as_str()).unwrap_or("consph");
            let reps = flags.get("reps").map(|s| s.parse()).transpose()?.unwrap_or(5);
            opsparse::bench::perf_l3(m, scale, reps)?;
        }
        "serve" => {
            let jobs = flags.get("jobs").map(|s| s.parse()).transpose()?.unwrap_or(32);
            let report = opsparse::bench::serve_bench::serve_load(jobs, scale)?;
            // --json wins over the env path, matching the shards bench
            let env_path = std::env::var("OPSPARSE_BENCH_JSON_SERVE").ok();
            if let Some(path) = flags.get("json").map(String::as_str).or(env_path.as_deref()) {
                opsparse::bench::write_serve_json(path, &report)?;
            }
        }
        "chaos" => {
            let jobs = flags.get("jobs").map(|s| s.parse()).transpose()?.unwrap_or(24);
            let seed = flags
                .get("chaos-seed")
                .map(|s| s.parse::<u64>())
                .transpose()
                .context("--chaos-seed <u64>")?
                .unwrap_or(opsparse::bench::chaos_bench::DEFAULT_CHAOS_SEED);
            let report = opsparse::bench::chaos_bench::chaos_fleet(jobs, seed)?;
            // --json wins over the env path, matching the serve bench
            let env_path = std::env::var("OPSPARSE_BENCH_JSON_CHAOS").ok();
            if let Some(path) = flags.get("json").map(String::as_str).or(env_path.as_deref()) {
                opsparse::bench::write_chaos_json(path, &report)?;
            }
        }
        "corpus" => {
            use opsparse::bench::corpus;
            let dir = corpus::resolve_corpus_dir(flags.get("dir").map(String::as_str));
            println!("corpus bench: loading .mtx fixtures from {}", dir.display());
            let report = corpus::run_corpus(&dir)?;
            println!(
                "{:<22} {:<11} {:>6} {:>6} {:>10} {:>9} {:>8} {:>5} {:>5} {:>5}",
                "matrix", "source", "rows", "nnz", "route", "speedup", "gflops", "shard", "serve",
                "mmio"
            );
            for r in &report.rows {
                println!(
                    "{:<22} {:<11} {:>6} {:>6} {:>10} {:>8.2}x {:>8.2} {:>5} {:>5} {:>5}",
                    r.name,
                    r.source,
                    r.rows,
                    r.nnz,
                    r.route,
                    r.speedup_vs_cusparse,
                    r.gflops,
                    r.bit_identical_sharded,
                    r.bit_identical_serve,
                    r.mmio_roundtrip
                );
            }
            println!(
                "corpus: {} fixtures + {} synthesized, all_bit_identical {}",
                report.fixtures, report.synthesized, report.all_bit_identical
            );
            let env_path = std::env::var("OPSPARSE_BENCH_JSON_CORPUS").ok();
            if let Some(path) = flags.get("json").map(String::as_str).or(env_path.as_deref()) {
                opsparse::bench::write_corpus_json(path, &report)?;
            }
        }
        "engines" => {
            use opsparse::bench::engines;
            let env_reps = std::env::var("OPSPARSE_ENGINE_BENCH_REPS").ok();
            let reps: usize = flags
                .get("reps")
                .map(String::as_str)
                .or(env_reps.as_deref())
                .map(|v| v.parse())
                .transpose()?
                .unwrap_or(engines::DEFAULT_ENGINE_REPS);
            let report = engines::engines_ablation(reps)?;
            println!(
                "{:<20} {:>6} {:>14} {:>14} {:>14} {:>6} {:>5}",
                "class", "blocky", "hash_ns", "block_ns", "dispatched_ns", "bpick", "bit"
            );
            for r in &report.rows {
                println!(
                    "{:<20} {:>6} {:>14.0} {:>14.0} {:>14.0} {:>4}/{} {:>5}",
                    r.class,
                    r.blocky,
                    r.hash_ns_mean,
                    r.block_ns_mean,
                    r.dispatched_ns_mean,
                    r.dispatched_block_picks,
                    r.reps,
                    r.bit_identical
                );
            }
            for g in &report.gates {
                println!("gate {:<45} pass {} p {:.4}", g.name, g.pass, g.p);
            }
            let env_path = std::env::var("OPSPARSE_BENCH_JSON_ENGINES").ok();
            if let Some(path) = flags.get("json").map(String::as_str).or(env_path.as_deref()) {
                opsparse::bench::write_engines_json(path, &report)?;
            }
        }
        "trace" => {
            let jobs = flags.get("jobs").map(|s| s.parse()).transpose()?.unwrap_or(16);
            let report = opsparse::bench::trace_bench::trace_overhead(jobs)?;
            // --json wins over the env path, matching the serve bench
            let env_path = std::env::var("OPSPARSE_BENCH_JSON_TRACE").ok();
            if let Some(path) = flags.get("json").map(String::as_str).or(env_path.as_deref()) {
                opsparse::bench::write_trace_json(path, &report)?;
            }
            let env_ev = std::env::var("OPSPARSE_BENCH_TRACE_EVENTS").ok();
            if let Some(path) =
                flags.get("events-json").map(String::as_str).or(env_ev.as_deref())
            {
                opsparse::bench::write_trace_events(path, &report)?;
            }
        }
        "all" => {
            tables::table1();
            tables::table2();
            tables::table4_5();
            tables::table3(scale)?;
            figures::fig5(scale, verify)?;
            figures::fig6(scale, verify)?;
            figures::fig7_8(scale)?;
            figures::fig9(scale)?;
            figures::fig10(scale)?;
            figures::fig11(scale)?;
            figures::ablations(scale)?;
            figures::pool_ablation(scale, 5)?;
            figures::shard_scaling(scale)?;
        }
        other => bail!("unknown bench target {other}"),
    }
    Ok(())
}

fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    let jobs: usize = flags.get("jobs").map(|s| s.parse()).transpose()?.unwrap_or(32);
    // every serving knob flows through one config with documented
    // CLI > env > default precedence (--workers, --coalesce, --batch,
    // --batch-max, --batch-age-ms, --queue-cap, --inflight, --persist,
    // --replan, --history-cap, --overlap, --chunk-kb, --interconnect)
    let cfg = ServeConfig::from_args(flags)?;
    let use_engine = !flags.contains_key("no-engine")
        && opsparse::runtime::pjrt_compiled()
        && opsparse::runtime::artifacts_available();
    println!(
        "serve: {} hash workers, engine mode: {}, block engine: {use_engine}, coalesce: {}, \
         batch: {}, queue cap {}, persist: {}",
        cfg.workers,
        cfg.engine.label(),
        if cfg.coalesce { "on" } else { "off" },
        if cfg.batch.enabled { "on" } else { "off" },
        cfg.queue_cap,
        cfg.persist.as_deref().unwrap_or("off")
    );
    println!(
        "replan: {} (history cap {}); overlap: {} ({} KiB chunks)",
        if cfg.replan.enabled { "on" } else { "off" },
        cfg.replan.history_cap,
        if cfg.overlap.enabled { "on" } else { "off" },
        cfg.overlap.chunk_bytes / 1024
    );
    println!(
        "speculate: {} (lag ×{:.1}); chaos: {}",
        if cfg.speculate.enabled { "on" } else { "off" },
        cfg.speculate.lag_factor,
        if cfg.chaos.is_off() {
            "off".to_string()
        } else {
            format!(
                "on (kill {:.2}, delay {}..{} ns, shrink {:.2}, seed {})",
                cfg.chaos.kill_prob,
                cfg.chaos.delay_ns_range.0,
                cfg.chaos.delay_ns_range.1,
                cfg.chaos.mem_pressure,
                cfg.chaos.seed
            )
        }
    );
    println!(
        "trace: {} (dir {}, slow exemplars {})",
        if cfg.trace.enabled { "on" } else { "off" },
        cfg.trace.dir.as_deref().unwrap_or("off"),
        cfg.trace.slow_k
    );
    let factory: Option<opsparse::coordinator::service::EngineFactory> = if use_engine {
        Some(Box::new(|| {
            // P=16: optimal batch for the interpret-mode CPU path (§Perf)
            opsparse::runtime::BlockEngine::load(
                &opsparse::runtime::default_artifacts_dir(),
                16,
                16,
            )
        }))
    } else {
        None
    };
    let serve = Serve::start_with_engine(cfg, factory)?;
    println!("router: ns_per_prod = {:.3} (live re-fit)", serve.fit().current());
    // mixed workload: alternating blocky (FEM) and scattered matrices,
    // submitted as two tenants through the front door
    let mut rng = Rng::new(2026);
    let t0 = std::time::Instant::now();
    let tickets: Vec<_> = (0..jobs as u64)
        .map(|id| {
            let (tenant, a) = if id % 2 == 0 {
                let m = opsparse::gen::banded::Banded {
                    n: 512,
                    per_row: 32,
                    band: 24,
                    contiguous_frac: 1.0,
                }
                .generate(&mut rng);
                ("fem", m)
            } else {
                let m = opsparse::gen::uniform::Uniform { n: 1024, per_row: 8, jitter: 4 }
                    .generate(&mut rng);
                ("scatter", m)
            };
            serve.submit(tenant, a.clone(), a)
        })
        .collect();
    let mut failed = 0usize;
    for (id, t) in tickets.into_iter().enumerate() {
        match t.wait() {
            ServeResult::Done { .. } => {}
            ServeResult::Failed { error, .. } => {
                eprintln!("job {id} failed: {error}");
                failed += 1;
            }
            ServeResult::Rejected { queue_full } => {
                eprintln!("job {id} rejected (queue_full={queue_full})");
                failed += 1;
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = serve.metrics_snapshot();
    println!("{snap}");
    println!(
        "throughput: {:.1} jobs/s, {:.2} Gprod/s  (ns_per_prod now {:.3} after {} refits)",
        jobs as f64 / wall,
        snap.nprod_total as f64 / wall / 1e9,
        serve.fit().current(),
        serve.fit().updates()
    );
    if flags.contains_key("prometheus") {
        println!("\n{}", serve.metrics().to_prometheus());
    }
    let tracer = serve.tracer().cloned();
    let trace_dir = serve.config().trace.dir.clone();
    serve.shutdown();
    if let Some(tr) = tracer {
        println!(
            "trace: {} spans retained ({} dropped), {} slow exemplars{}",
            tr.snapshot_spans().len(),
            tr.dropped(),
            tr.slow_exemplars().len(),
            trace_dir
                .map(|d| format!(", wrote {d}/serve-trace.json"))
                .unwrap_or_default()
        );
    }
    if failed > 0 {
        bail!("{failed} jobs failed");
    }
    Ok(())
}

/// `opsparse trace` — a traced demonstration run: sharded + speculative
/// + gentle-chaos traffic with tracing forced on, trace files written
/// (Perfetto-loadable), the metrics snapshot and its Prometheus text
/// exposition printed.
fn cmd_trace(flags: &HashMap<String, String>) -> Result<()> {
    let jobs: usize = flags.get("jobs").map(|s| s.parse()).transpose()?.unwrap_or(12);
    let mut cfg = ServeConfig::from_args(flags)?;
    cfg.trace.enabled = true;
    if cfg.trace.dir.is_none() {
        cfg.trace.dir = Some("opsparse-trace".to_string());
    }
    // the demonstration posture: every span source lights up unless the
    // flags say otherwise — shard fan-out (tiny device budget), backup
    // sub-jobs, seeded gentle chaos
    if !flags.contains_key("workers") {
        cfg.workers = 3;
    }
    if !flags.contains_key("speculate") {
        cfg.speculate = opsparse::coordinator::SpeculateConfig::on();
    }
    if !flags.contains_key("chaos") {
        cfg.chaos = opsparse::coordinator::ChaosConfig::gentle().with_seed(cfg.chaos.seed);
    }
    cfg.device_memory_bytes = 4096;
    cfg.max_devices = 4;
    cfg.interconnect = None;
    cfg.ns_per_prod = Some(1.0);
    let dir = cfg.trace.dir.clone().unwrap();
    println!(
        "trace run: {jobs} jobs over {} workers (speculate {}, chaos {}, seed {}), dir {dir}",
        cfg.workers,
        if cfg.speculate.enabled { "on" } else { "off" },
        if cfg.chaos.is_off() { "off" } else { "gentle" },
        cfg.chaos.seed
    );
    let serve = Serve::start(cfg)?;
    let tracer = serve.tracer().cloned().expect("tracing is forced on");
    // distinct matrices per job (no coalesce collapse): evens shard on
    // the 4 KiB budget, odds ride the hash route
    let mut rng = Rng::new(2029);
    let tickets: Vec<_> = (0..jobs)
        .map(|i| {
            let (tenant, m) = if i % 2 == 0 {
                let m = opsparse::gen::uniform::Uniform { n: 300, per_row: 6, jitter: 2 }
                    .generate(&mut rng);
                ("shard", m)
            } else {
                let m = opsparse::gen::uniform::Uniform { n: 140, per_row: 5, jitter: 2 }
                    .generate(&mut rng);
                ("hash", m)
            };
            serve.submit(tenant, m.clone(), m)
        })
        .collect();
    let mut failed = 0usize;
    for (i, t) in tickets.into_iter().enumerate() {
        match t.wait() {
            ServeResult::Done { .. } => {}
            other => {
                eprintln!("trace run job {i} did not complete: {other:?}");
                failed += 1;
            }
        }
    }
    let snap = serve.metrics_snapshot();
    println!("{snap}");
    println!("\n{}", serve.metrics().to_prometheus());
    serve.shutdown(); // writes <dir>/serve-trace.json (+ slow exemplars)
    let spans = tracer.snapshot_spans();
    opsparse::obs::check_well_formed(&spans)
        .map_err(|e| anyhow::anyhow!("trace not well-formed: {e}"))?;
    println!(
        "trace: {} spans retained ({} dropped), {} slow exemplars, wrote {dir}/serve-trace.json",
        spans.len(),
        tracer.dropped(),
        tracer.slow_exemplars().len()
    );
    println!("open in https://ui.perfetto.dev or chrome://tracing");
    if failed > 0 {
        bail!("{failed} jobs failed");
    }
    Ok(())
}

fn cmd_sim_case(pos: &[String], flags: &HashMap<String, String>) -> Result<()> {
    let which = pos.first().map(|s| s.as_str()).unwrap_or("webbase");
    if which != "webbase" {
        bail!("only the webbase case study is defined (§6.3.4/§6.3.5)");
    }
    let scale = scale_of(flags);
    let e = suite_entry("webbase-1M").unwrap();
    let a = e.generate(scale);
    println!(
        "webbase-1M stand-in at {scale:?}: {}x{} nnz {} max-row {}",
        a.rows,
        a.cols,
        fmt::count(a.nnz()),
        a.max_row_nnz()
    );
    let (out, tl) = run_and_simulate(Library::OpSparse, &a, false)?;
    let _ = out;
    println!("\n-- §6.3.4 SM load balance --");
    let giant = tl
        .kernels
        .iter()
        .find(|k| k.name == "num_kernel7_global")
        .map(|k| k.end - k.start)
        .unwrap_or(0.0);
    println!("  largest-row (global-table) kernel: {}", fmt::ns(giant));
    println!("  numeric step wall: {}", fmt::ns(tl.step_ns("numeric")));
    println!(
        "  total: {}   SM imbalance (max/mean busy): {:.2}",
        fmt::ns(tl.total_ns),
        tl.sm_imbalance()
    );
    println!("\n-- §6.3.5 malloc/kernel overlap --");
    for h in &tl.host {
        if h.what.starts_with("cudaMalloc(num_global_table") {
            println!(
                "  global-table malloc: {} at t={}",
                fmt::ns(h.end - h.start),
                fmt::ns(h.start)
            );
        }
    }
    println!("\n{}", tl.render_gantt(100));
    Ok(())
}

fn usage() -> ! {
    eprintln!(
        "usage: opsparse <command> [flags]\n\
         commands:\n\
           gen      --name <matrix> [--scale tiny|small|medium] [--out f.mtx]\n\
           spgemm   --a f.mtx [--b g.mtx] [--lib opsparse|nsparse|speck|cusparse] [--verify]\n\
           suite    [--scale s] [--verify]\n\
           bench    <fig5|fig6|fig7_8|fig9|fig10|fig11|tables|ablations|pool|shards|serve|chaos|corpus|engines|trace|all> [--scale s]\n\
                    shards also takes [--interconnect pcie|nvlink|none] [--overlap on|off]\n\
                    [--chunk-kb n] [--json out.json] [--overlap-json out.json]\n\
                    [--replan on|off] [--adaptive-json out.json]\n\
                    serve also takes [--jobs n] [--json out.json]\n\
                    chaos also takes [--jobs n] [--chaos-seed n] [--json out.json]\n\
                    corpus also takes [--dir corpus/] [--json out.json]\n\
                    engines also takes [--reps n] [--json out.json]\n\
                    trace also takes [--jobs n] [--json out.json] [--events-json out.json]\n\
           serve    [--jobs n] [--workers w] [--engine fill|auto|hash|block] [--no-engine]\n\
                    [--coalesce on|off]\n\
                    [--batch on|off] [--batch-max n] [--batch-age-ms n] [--queue-cap n]\n\
                    [--inflight n] [--persist on|off|path] [--replan on|off] [--history-cap n]\n\
                    [--overlap on|off] [--chunk-kb n] [--interconnect pcie|nvlink|none]\n\
                    [--speculate on|off] [--speculate-lag f] [--chaos off|gentle|aggressive]\n\
                    [--chaos-seed n] [--trace on|off] [--trace-dir d] [--trace-slow k]\n\
                    [--prometheus]\n\
           trace    [--jobs n] [--trace-dir d] [serve flags] — traced demo run + Prometheus text\n\
           sim-case webbase [--scale s]\n\
           list     (suite matrix names)"
    );
    std::process::exit(2)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
    }
    let cmd = args[0].clone();
    let (pos, flags) = parse_flags(&args[1..]);
    match cmd.as_str() {
        "gen" => cmd_gen(&flags),
        "spgemm" => cmd_spgemm(&flags),
        "suite" => cmd_suite(&flags),
        "bench" => cmd_bench(&pos, &flags),
        "serve" => cmd_serve(&flags),
        "trace" => cmd_trace(&flags),
        "sim-case" => cmd_sim_case(&pos, &flags),
        "apps" => {
            // the §1 motivating applications (see examples/applications.rs
            // for the full demo)
            match pos.first().map(|s| s.as_str()).unwrap_or("amg") {
                "amg" => {
                    let side: usize =
                        flags.get("side").map(|s| s.parse()).transpose()?.unwrap_or(64);
                    let a = opsparse::apps::amg::poisson2d(side);
                    let h = opsparse::apps::amg::AmgHierarchy::build(&a, 0.1, 64, 10)?;
                    let b = vec![1.0; a.rows];
                    let (_, iters, rel) = h.solve(&b, 1e-10, 60);
                    println!(
                        "amg: {} levels, {} setup products, {iters} V-cycles, rel residual {rel:.2e}",
                        h.levels.len(),
                        fmt::count(h.setup_spgemm_products)
                    );
                }
                "bfs" => {
                    let g = opsparse::gen::kron::Kron::default()
                        .generate(&mut Rng::new(3));
                    let res = opsparse::apps::msbfs::msbfs(&g, &[0, 1, 2, 3]);
                    println!(
                        "msbfs: {} vertices, {} rounds, source0 reaches {}",
                        g.rows,
                        res.iterations,
                        res.levels[0].iter().filter(|&&l| l != u32::MAX).count()
                    );
                }
                other => bail!("unknown app {other} (amg|bfs)"),
            }
            Ok(())
        }
        "list" => {
            for e in entries() {
                println!(
                    "{:<18} {} ({})",
                    e.name,
                    e.class,
                    if e.large { "large" } else { "normal" }
                );
            }
            Ok(())
        }
        _ => usage(),
    }
}
