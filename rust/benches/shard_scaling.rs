//! `cargo bench --bench shard_scaling` — row-sharded multi-device SpGEMM
//! on a power-law matrix at 1/2/4/8 shards: per-device makespan, planned
//! and measured load imbalance, and scaling efficiency vs one device.
//!
//! Env: `OPSPARSE_SCALE=tiny|small|medium` (default small).

use opsparse::bench::figures;
use opsparse::gen::suite::SuiteScale;

fn main() {
    let scale = std::env::var("OPSPARSE_SCALE")
        .ok()
        .and_then(|s| SuiteScale::parse(&s))
        .unwrap_or(SuiteScale::Small);
    figures::shard_scaling(scale).expect("shard_scaling bench");
}
