//! `bench serve` — the serving front door under a concurrent-identical
//! load, with the three contract checks CI blocks on:
//!
//! 1. **Coalescing** — N identical in-flight requests execute exactly
//!    one symbolic phase (`sym_executions == 1`, `coalesce_hits ==
//!    N−1`) and every waiter's matrix is bit-identical to an
//!    independent [`crate::spgemm::pipeline::multiply`]. The
//!    uncoalesced row is the ablation: same load with `--coalesce off`
//!    executes every member, so coalesced throughput must come out ≥
//!    uncoalesced.
//! 2. **Warm-start persistence** — a front door restarted on its saved
//!    state routes the warm pattern identically to the pre-restart run,
//!    with the restored fit bit-equal and the first submit re-planned
//!    from warm history (`replans == 1`, `replan_cold_misses == 0`).
//! 3. **Baseline parity** — every knob off reproduces the raw
//!    coordinator (PR 5) behavior: bit-identical results, identical
//!    routes, identical job/cache/product counters.
//!
//! Determinism of the coalescing count: the front door runs one worker
//! with `inflight_cap = 1`, and a **plug job** (a larger,
//! different-pattern multiply) is submitted first. The plug occupies
//! the only inflight slot, so the first identical request stays an
//! outstanding leader — every later identical submit must attach to it
//! while the plug grinds. Submitting the whole load takes microseconds
//! against the plug's milliseconds, so `coalesce_hits = N−1` exactly.
//! The plug rides both modes (same overhead on each side) and its own
//! counters are subtracted from the reported row.

use crate::coordinator::feedback::NsPerProdFit;
use crate::coordinator::serve::{Serve, ServeConfig, ServeResult};
use crate::coordinator::{Coordinator, Job, ReplanConfig, Router, RouterConfig};
use crate::gen::suite::SuiteScale;
use crate::gen::uniform::Uniform;
use crate::sparse::Csr;
use crate::spgemm::pipeline::{multiply, OpSparseConfig};
use crate::util::rng::Rng;
use crate::util::stats::{not_worse_gate, AdaptiveConfig, GateResult, Samples};
use anyhow::{ensure, Context, Result};
use std::sync::Arc;
use std::time::Instant;

/// One serving mode (coalesced or uncoalesced) under the identical
/// load. Counters are deltas with the plug job subtracted out.
#[derive(Clone, Debug)]
pub struct ServeModeRow {
    pub mode: &'static str,
    /// Identical requests served (the plug not counted).
    pub jobs: usize,
    /// First submit → last fan-out, ns (plug included on both modes).
    pub wall_ns: u64,
    pub throughput_jobs_per_s: f64,
    /// Multiplies the coordinator actually executed for the load.
    pub executed_jobs: u64,
    /// Symbolic phases computed for the load (the coalescing contract:
    /// exactly 1 in coalesced mode).
    pub sym_executions: u64,
    pub coalesce_hits: u64,
    pub rejected_jobs: u64,
    /// Serve-latency percentiles over every waiter (plug included).
    pub p50_ns: Option<u64>,
    pub p99_ns: Option<u64>,
    pub queue_depth_max: u64,
    /// Every waiter's matrix equals the independent-multiply reference.
    pub bit_identical: bool,
}

/// The full `bench serve` report: both mode rows plus the persistence
/// and baseline-parity verdicts CI blocks on.
#[derive(Clone, Debug)]
pub struct ServeBenchReport {
    pub jobs: usize,
    pub scale: SuiteScale,
    pub rows: Vec<ServeModeRow>,
    /// Restarted-on-saved-state front door routed the warm pattern
    /// identically (bit-equal fit, warm re-plan, same route).
    pub persist_route_stable: bool,
    /// All-knobs-off front door matched the raw coordinator bitwise
    /// (results, routes, counters).
    pub baseline_match: bool,
    /// Statistical verdicts CI blocks on (currently one: coalesced
    /// throughput not significantly below uncoalesced, one-sided Welch
    /// over adaptively many repetitions — real wall clock is noisy, so a
    /// point comparison of two single runs would flake).
    pub gates: Vec<GateResult>,
}

fn sizes(scale: SuiteScale) -> usize {
    match scale {
        SuiteScale::Tiny => 200,
        SuiteScale::Small => 400,
        SuiteScale::Medium => 800,
    }
}

/// Run the identical load through one front-door mode and report the
/// plug-subtracted counters.
fn run_mode(
    coalesce: bool,
    jobs: usize,
    a: &Csr,
    b: &Csr,
    plug: &Csr,
    expected: &Csr,
) -> Result<ServeModeRow> {
    let mut cfg = ServeConfig::default();
    cfg.workers = 1;
    cfg.coalesce = coalesce;
    cfg.inflight_cap = 1;
    cfg.ns_per_prod = Some(1.0);
    let serve = Serve::start(cfg)?;
    let t0 = Instant::now();
    // the plug holds the single inflight slot while the load submits
    let plug_ticket = serve.submit("bench", plug.clone(), plug.clone());
    let tickets: Vec<_> =
        (0..jobs).map(|_| serve.submit("bench", a.clone(), b.clone())).collect();
    ensure!(plug_ticket.wait().csr().is_some(), "plug job failed");
    let mut bit_identical = true;
    for t in tickets {
        match t.wait() {
            ServeResult::Done { c, .. } => bit_identical &= **c == *expected,
            other => {
                eprintln!("serve bench: request did not complete: {other:?}");
                bit_identical = false;
            }
        }
    }
    let wall_ns = t0.elapsed().as_nanos() as u64;
    let snap = serve.metrics_snapshot();
    serve.shutdown();
    Ok(ServeModeRow {
        mode: if coalesce { "coalesced" } else { "uncoalesced" },
        jobs,
        wall_ns,
        throughput_jobs_per_s: jobs as f64 / (wall_ns.max(1) as f64 / 1e9),
        // minus the plug's own completion / symbolic miss
        executed_jobs: snap.jobs_completed.saturating_sub(1),
        sym_executions: snap.sym_cache_misses.saturating_sub(1),
        coalesce_hits: snap.coalesce_hits,
        rejected_jobs: snap.rejected_jobs,
        p50_ns: snap.serve_p50_ns,
        p99_ns: snap.serve_p99_ns,
        queue_depth_max: snap.queue_depth_max,
        bit_identical,
    })
}

/// Save warm state on shutdown, restart on it, and check the warm
/// pattern routes identically (same route, bit-equal fit, first submit
/// re-planned from warm history).
fn persist_round_trip() -> Result<bool> {
    let path = std::env::temp_dir()
        .join(format!("opsparse-serve-bench-{}.state", std::process::id()));
    let path_s = path.to_string_lossy().into_owned();
    let _ = std::fs::remove_file(&path);
    let mk_cfg = || {
        let mut c = ServeConfig::default();
        c.workers = 2;
        c.ns_per_prod = Some(1.0);
        c.persist = Some(path_s.clone());
        // a 4 KiB device budget forces the pattern onto the sharded
        // route, which is the one warm history re-plans
        c.device_memory_bytes = 4096;
        c.max_devices = 4;
        c.interconnect = None;
        c
    };
    let a = Uniform { n: 300, per_row: 6, jitter: 2 }.generate(&mut Rng::new(21));
    let serve = Serve::start(mk_cfg())?;
    let mut route_before = None;
    for _ in 0..3 {
        let r = serve.submit("bench", a.clone(), a.clone()).wait();
        ensure!(r.csr().is_some(), "persistence-phase job failed");
        route_before = r.route();
    }
    let fit_before = serve.fit().current().to_bits();
    serve.shutdown(); // writes the state file
    let serve2 = Serve::start(mk_cfg())?;
    let fit_after = serve2.fit().current().to_bits();
    let r2 = serve2.submit("bench", a.clone(), a.clone()).wait();
    let snap2 = serve2.metrics_snapshot();
    serve2.shutdown();
    let _ = std::fs::remove_file(&path);
    let stable = route_before.is_some()
        && r2.route() == route_before
        && fit_after == fit_before
        && snap2.replan_cold_misses == 0
        && snap2.replans >= 1;
    if !stable {
        eprintln!(
            "serve bench: persistence NOT stable: route {:?} -> {:?}, fit {:016x} -> {:016x}, \
             replans {} cold_misses {}",
            route_before,
            r2.route(),
            fit_before,
            fit_after,
            snap2.replans,
            snap2.replan_cold_misses
        );
    }
    Ok(stable)
}

/// Every knob off vs the raw coordinator, over one serial job stream:
/// bitwise results, routes, and counters must match.
fn baseline_parity() -> Result<bool> {
    let mut cfg = ServeConfig::default();
    cfg.workers = 1;
    cfg.coalesce = false;
    cfg.ns_per_prod = Some(1.0);
    let serve = Serve::start(cfg)?;
    let fit = Arc::new(NsPerProdFit::new(1.0));
    let raw_rc = RouterConfig {
        ns_per_prod: fit.current(),
        fit: Some(fit),
        ..RouterConfig::default()
    };
    let coord = Coordinator::start_with(1, Router::new(raw_rc), None, ReplanConfig::default());
    let m1 = Uniform { n: 220, per_row: 6, jitter: 2 }.generate(&mut Rng::new(31));
    let m2 = Uniform { n: 180, per_row: 9, jitter: 3 }.generate(&mut Rng::new(32));
    // two patterns, twice each: the repeat exercises the symbolic cache
    // on both sides
    let stream = [&m1, &m2, &m1, &m2];
    let mut ok = true;
    for (i, m) in stream.iter().enumerate() {
        let sres = serve.submit("parity", (*m).clone(), (*m).clone()).wait();
        coord.submit(Job { id: i as u64, a: (*m).clone(), b: (*m).clone(), force_route: None });
        let cres = coord.recv().context("raw coordinator hung up")?;
        match (sres, cres.c) {
            (ServeResult::Done { c, route, .. }, Ok(raw_c)) => {
                ok &= *c == raw_c && route == cres.route;
            }
            _ => ok = false,
        }
    }
    let s = serve.metrics_snapshot();
    let r = coord.metrics.snapshot();
    ok &= (s.jobs_submitted, s.jobs_completed, s.jobs_failed)
        == (r.jobs_submitted, r.jobs_completed, r.jobs_failed);
    ok &= (s.hash_routed, s.block_routed, s.sharded_routed)
        == (r.hash_routed, r.block_routed, r.sharded_routed);
    ok &= (s.sym_cache_hits, s.sym_cache_misses, s.nprod_total)
        == (r.sym_cache_hits, r.sym_cache_misses, r.nprod_total);
    // the new gauges must stay untouched with the knobs off
    ok &= s.coalesce_hits == 0 && s.rejected_jobs == 0 && s.batches == 0 && s.batched_jobs == 0;
    serve.shutdown();
    coord.shutdown();
    if !ok {
        eprintln!("serve bench: all-knobs-off front door DIVERGED from the raw coordinator");
    }
    Ok(ok)
}

/// The `bench serve` entry: both mode rows plus the persistence and
/// parity verdicts, printed as a table and returned for JSON recording.
pub fn serve_load(jobs: usize, scale: SuiteScale) -> Result<ServeBenchReport> {
    let jobs = jobs.max(2);
    let n = sizes(scale);
    let a = Uniform { n, per_row: 8, jitter: 3 }.generate(&mut Rng::new(11));
    let b = Uniform { n, per_row: 8, jitter: 3 }.generate(&mut Rng::new(12));
    // the plug: different pattern, ~two orders of magnitude more work
    // than one fingerprinted submit
    let plug = Uniform { n: n * 6, per_row: 12, jitter: 4 }.generate(&mut Rng::new(13));
    let expected = multiply(&a, &b, &OpSparseConfig::default())?.c;
    println!("serve bench: {jobs} identical requests at {scale:?} (n={n})");
    let rows =
        vec![run_mode(true, jobs, &a, &b, &plug, &expected)?, run_mode(false, jobs, &a, &b, &plug, &expected)?];
    for row in &rows {
        println!(
            "  {:<12} wall {:>10} ns  {:>8.1} jobs/s  executed {:>3}  sym {:>2}  \
             coalesce_hits {:>3}  p50 {:?}  p99 {:?}  depth_max {}  bit_identical {}",
            row.mode,
            row.wall_ns,
            row.throughput_jobs_per_s,
            row.executed_jobs,
            row.sym_executions,
            row.coalesce_hits,
            row.p50_ns,
            row.p99_ns,
            row.queue_depth_max,
            row.bit_identical
        );
    }
    // statistical throughput gate: the displayed rows above are repetition
    // 0; keep re-running both modes until the throughput samples converge
    // (wall clock is genuinely noisy), then one-sided Welch at alpha
    let stat = AdaptiveConfig::from_env();
    let mut coalesced = Samples::from_values(vec![rows[0].throughput_jobs_per_s]);
    let mut uncoalesced = Samples::from_values(vec![rows[1].throughput_jobs_per_s]);
    while coalesced.n() < stat.max_reps.max(stat.min_reps).max(2)
        && !(stat.converged(&coalesced) && stat.converged(&uncoalesced))
    {
        coalesced.push(run_mode(true, jobs, &a, &b, &plug, &expected)?.throughput_jobs_per_s);
        uncoalesced.push(run_mode(false, jobs, &a, &b, &plug, &expected)?.throughput_jobs_per_s);
    }
    let gate =
        not_worse_gate("serve_coalesced_throughput", &coalesced, &uncoalesced, true, stat.alpha);
    println!(
        "  throughput gate: {} (p={:.4}, alpha={}, coalesced {:.1} vs uncoalesced {:.1} jobs/s \
         over {} reps)",
        if gate.pass { "pass" } else { "FAIL" },
        gate.p,
        gate.alpha,
        gate.candidate_mean,
        gate.reference_mean,
        gate.reps_candidate
    );
    let persist_route_stable = persist_round_trip()?;
    let baseline_match = baseline_parity()?;
    println!(
        "  persist_route_stable {persist_route_stable}  baseline_match {baseline_match}"
    );
    Ok(ServeBenchReport {
        jobs,
        scale,
        rows,
        persist_route_stable,
        baseline_match,
        gates: vec![gate],
    })
}
