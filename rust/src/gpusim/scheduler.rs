//! Event-driven scheduler: replays a [`Trace`] against a [`DeviceParams`]
//! model, producing a [`Timeline`].
//!
//! Semantics reproduced from CUDA (paper §4.5–§4.6, §5.4–§5.5):
//! * `cudaMalloc` blocks the **host** only — already-launched kernels keep
//!   executing (the overlap OpSparse exploits).
//! * `cudaFree` implicitly synchronizes the whole device before returning
//!   (the nsparse load-imbalance bug).
//! * Kernels in one stream serialize; kernels in different streams run
//!   concurrently, competing for SMs.
//! * Thread blocks dispatch to SMs in kernel **launch order** ("the thread
//!   blocks in the earlier launched kernel still execute earlier than or
//!   concurrently with the thread blocks in the later launched kernels",
//!   §5.5), subject to per-SM thread/shared-memory/block-slot limits.

use super::cost::KernelCost;
use super::device::DeviceParams;
use super::timeline::{HostSpan, KernelSpan, Timeline};
use super::trace::{Trace, TraceOp};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Ordered float for the event heap.
#[derive(PartialEq, PartialOrd)]
struct F(f64);
impl Eq for F {}
#[allow(clippy::derive_ord_xor_partial_ord)]
impl Ord for F {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.partial_cmp(other).unwrap_or(std::cmp::Ordering::Equal)
    }
}

struct SmState {
    free_threads: usize,
    free_shared: usize,
    free_slots: usize,
    busy_until: f64,
    busy_ns: f64,
}

struct PendingKernel {
    /// Index into the timeline's kernel span list.
    span_idx: usize,
    stream: usize,
    tb_size: usize,
    shared_bytes: usize,
    /// Earliest device time this kernel may start (host launch + latency).
    ready: f64,
    /// Per-block durations (ns), precomputed.
    block_ns: Vec<f64>,
    next_block: usize,
    blocks_done: usize,
    started: bool,
}

impl PendingKernel {
    fn finished(&self) -> bool {
        self.blocks_done == self.block_ns.len()
    }
}

/// Device simulator covering the window between two host-device syncs.
struct DeviceSim<'d> {
    dev: &'d DeviceParams,
    sms: Vec<SmState>,
    kernels: Vec<PendingKernel>,
    /// (end_time, sm, kernel_idx, threads, shared) for resident blocks.
    completions: BinaryHeap<Reverse<(F, usize, usize, usize, usize)>>,
    /// Completion time of the last kernel per stream (for stream ordering).
    stream_done: Vec<f64>,
    now: f64,
}

impl<'d> DeviceSim<'d> {
    fn new(dev: &'d DeviceParams) -> Self {
        let sms = (0..dev.sms)
            .map(|_| SmState {
                free_threads: dev.max_threads_per_sm,
                free_shared: dev.shared_per_sm,
                free_slots: dev.max_blocks_per_sm,
                busy_until: 0.0,
                busy_ns: 0.0,
            })
            .collect();
        DeviceSim {
            dev,
            sms,
            kernels: Vec::new(),
            completions: BinaryHeap::new(),
            stream_done: Vec::new(),
            now: 0.0,
        }
    }

    fn queue(&mut self, k: PendingKernel) {
        if k.stream >= self.stream_done.len() {
            self.stream_done.resize(k.stream + 1, 0.0);
        }
        self.kernels.push(k);
    }

    /// A kernel may dispatch once launched (ready) and all earlier kernels
    /// on its stream have fully completed.
    fn kernel_eligible(&self, idx: usize) -> bool {
        let k = &self.kernels[idx];
        if k.ready > self.now + 1e-9 || k.finished() || k.next_block >= k.block_ns.len() {
            return false;
        }
        // stream ordering: all earlier kernels in the same stream done
        for e in &self.kernels[..idx] {
            if e.stream == k.stream && !e.finished() {
                return false;
            }
        }
        true
    }

    /// Dispatch as many blocks as possible at the current time.
    fn dispatch(&mut self, spans: &mut [KernelSpan]) {
        loop {
            let mut dispatched = false;
            for ki in 0..self.kernels.len() {
                if !self.kernel_eligible(ki) {
                    continue;
                }
                let (tb, sh) = (self.kernels[ki].tb_size, self.kernels[ki].shared_bytes);
                // fill SMs round-robin while blocks remain
                for si in 0..self.sms.len() {
                    let k = &mut self.kernels[ki];
                    if k.next_block >= k.block_ns.len() {
                        break;
                    }
                    let sm = &mut self.sms[si];
                    if sm.free_threads >= tb && sm.free_shared >= sh && sm.free_slots >= 1 {
                        let dur = k.block_ns[k.next_block];
                        k.next_block += 1;
                        if !k.started {
                            k.started = true;
                            spans[k.span_idx].start = self.now;
                        }
                        sm.free_threads -= tb;
                        sm.free_shared -= sh;
                        sm.free_slots -= 1;
                        let end = self.now + dur;
                        sm.busy_ns += dur;
                        if end > sm.busy_until {
                            sm.busy_until = end;
                        }
                        self.completions.push(Reverse((F(end), si, ki, tb, sh)));
                        dispatched = true;
                    }
                }
            }
            if !dispatched {
                break;
            }
        }
    }

    /// Earliest future ready-time among kernels that still have blocks to
    /// dispatch.
    fn next_ready_after_now(&self) -> f64 {
        self.kernels
            .iter()
            .filter(|k| !k.finished() && k.next_block < k.block_ns.len())
            .map(|k| k.ready)
            .filter(|&r| r > self.now + 1e-9)
            .fold(f64::INFINITY, f64::min)
    }

    /// Advance the simulation until all queued kernels complete; returns
    /// the device-idle time.
    fn run_to_idle(&mut self, spans: &mut [KernelSpan]) -> f64 {
        loop {
            self.dispatch(spans);
            // a kernel may become ready (launch latency) before the next
            // block completion — advance to that instant and re-dispatch
            let next_ready = self.next_ready_after_now();
            let next_completion = self
                .completions
                .peek()
                .map(|Reverse((F(t), _, _, _, _))| *t)
                .unwrap_or(f64::INFINITY);
            if next_ready < next_completion {
                self.now = next_ready;
                continue;
            }
            match self.completions.pop() {
                Some(Reverse((F(t), si, ki, tb, sh))) => {
                    self.now = self.now.max(t);
                    let sm = &mut self.sms[si];
                    sm.free_threads += tb;
                    sm.free_shared += sh;
                    sm.free_slots += 1;
                    let k = &mut self.kernels[ki];
                    k.blocks_done += 1;
                    if k.finished() {
                        spans[k.span_idx].end = self.now;
                        let s = k.stream;
                        if self.now > self.stream_done[s] {
                            self.stream_done[s] = self.now;
                        }
                    }
                }
                None => {
                    if self.kernels.iter().all(|k| k.finished()) {
                        break;
                    }
                    // no in-flight blocks and nothing dispatchable: if some
                    // kernel is still pending its ready time, loop advances
                    // `now`; otherwise we are deadlocked (bug).
                    let pending: Vec<_> = self
                        .kernels
                        .iter()
                        .filter(|k| !k.finished())
                        .map(|k| k.ready)
                        .collect();
                    let next = pending.iter().fold(f64::INFINITY, |a, &b| a.min(b));
                    assert!(
                        next.is_finite() && next > self.now,
                        "device simulator deadlock: pending kernels cannot start"
                    );
                    self.now = next;
                }
            }
        }
        self.now
    }
}

/// Simulate a trace on the device model, returning the full timeline.
/// [`crate::gpusim::trace::TraceOp::AwaitChunk`] ops are free here (no
/// arrival times): annotated traces replay exactly like unannotated ones.
pub fn simulate(trace: &Trace, dev: &DeviceParams) -> Timeline {
    simulate_with_arrivals(trace, dev, &[])
}

/// [`simulate`] with inter-device broadcast chunk arrival times: an
/// `AwaitChunk { chunk }` op blocks the **host** until
/// `chunk_arrival_ns[chunk]` (already-launched kernels keep executing,
/// like a `cudaStreamWaitEvent` on the copy stream). Missing indices
/// count as already-arrived. This is the per-device half of the
/// overlapped multi-device model
/// ([`crate::gpusim::MultiDevice::simulate_overlapped`]).
pub fn simulate_with_arrivals(
    trace: &Trace,
    dev: &DeviceParams,
    chunk_arrival_ns: &[f64],
) -> Timeline {
    let mut tl = Timeline::default();
    let mut host = 0.0f64;
    let mut sim = DeviceSim::new(dev);
    // device time of the last completed sync window
    let mut device_base = 0.0f64;

    let sync_device = |sim: &mut DeviceSim,
                           tl: &mut Timeline,
                           host: f64,
                           device_base: &mut f64| {
        if sim.kernels.is_empty() {
            return host.max(*device_base);
        }
        // kernels become ready at absolute times; the sim runs in absolute ns
        sim.now = sim.now.max(*device_base);
        let idle = sim.run_to_idle(&mut tl.kernels);
        for (i, sm) in sim.sms.iter().enumerate() {
            if tl.sm_busy_ns.len() <= i {
                tl.sm_busy_ns.resize(i + 1, 0.0);
            }
            tl.sm_busy_ns[i] += sm.busy_ns;
        }
        *device_base = idle;
        *sim = DeviceSim::new(sim.dev);
        host.max(idle)
    };

    for op in &trace.ops {
        match op {
            TraceOp::Malloc { bytes, label, step } => {
                // host busy; device keeps running (no interaction needed:
                // queued kernels' ready times are already fixed)
                let d = dev.malloc_ns(*bytes);
                tl.host.push(HostSpan {
                    what: format!("cudaMalloc({label}, {bytes}B)"),
                    step: *step,
                    start: host,
                    end: host + d,
                });
                host += d;
            }
            TraceOp::Launch(k) => {
                let cost = KernelCost::of(k, dev);
                let block_ns: Vec<f64> =
                    k.blocks.iter().map(|w| cost.block_ns(w, dev)).collect();
                tl.host.push(HostSpan {
                    what: format!("launch {}", k.name),
                    step: k.step,
                    start: host,
                    end: host + dev.launch_overhead_ns,
                });
                host += dev.launch_overhead_ns;
                let span_idx = tl.kernels.len();
                tl.kernels.push(KernelSpan {
                    name: k.name.clone(),
                    step: k.step,
                    stream: k.stream,
                    start: f64::NAN,
                    end: f64::NAN,
                    blocks: k.blocks.len(),
                    occupancy: cost.occupancy,
                });
                sim.queue(PendingKernel {
                    span_idx,
                    stream: k.stream,
                    tb_size: k.tb_size,
                    shared_bytes: k.shared_bytes,
                    ready: host + dev.launch_latency_ns,
                    block_ns,
                    next_block: 0,
                    blocks_done: 0,
                    started: false,
                });
            }
            TraceOp::Free { label, step } => {
                // implicit cudaDeviceSynchronize
                host = sync_device(&mut sim, &mut tl, host, &mut device_base);
                tl.host.push(HostSpan {
                    what: format!("cudaFree({label})"),
                    step: *step,
                    start: host,
                    end: host + dev.free_base_ns,
                });
                host += dev.free_base_ns;
            }
            TraceOp::DeviceSync { step } => {
                let t0 = host;
                host = sync_device(&mut sim, &mut tl, host, &mut device_base);
                tl.host.push(HostSpan {
                    what: "cudaDeviceSynchronize".into(),
                    step: *step,
                    start: t0,
                    end: host,
                });
            }
            TraceOp::MemcpyH2D { bytes, step } => {
                // async H2D from pinned memory: host pays the transfer,
                // already-launched kernels keep executing
                let d = dev.memcpy_ns(*bytes);
                tl.host.push(HostSpan {
                    what: format!("memcpyH2D({bytes}B)"),
                    step: *step,
                    start: host,
                    end: host + d,
                });
                host += d;
            }
            TraceOp::AwaitChunk { chunk, step } => {
                // host blocks until the broadcast chunk lands; the device
                // keeps draining already-launched kernels (that overlap is
                // the point). Zero-length waits leave no span, so a serial
                // replay of an annotated trace is bit-identical.
                let arrival = chunk_arrival_ns.get(*chunk).copied().unwrap_or(0.0);
                if arrival > host {
                    tl.host.push(HostSpan {
                        what: format!("awaitChunk({chunk})"),
                        step: *step,
                        start: host,
                        end: arrival,
                    });
                    host = arrival;
                }
            }
            TraceOp::MemcpyD2H { bytes, step } => {
                // synchronous copy: waits for the device
                host = sync_device(&mut sim, &mut tl, host, &mut device_base);
                let d = dev.memcpy_ns(*bytes);
                tl.host.push(HostSpan {
                    what: format!("memcpyD2H({bytes}B)"),
                    step: *step,
                    start: host,
                    end: host + d,
                });
                host += d;
            }
        }
    }
    // final drain
    host = sync_device(&mut sim, &mut tl, host, &mut device_base);
    tl.total_ns = host.max(device_base);
    tl
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::device::V100;
    use crate::gpusim::trace::{BlockWork, Kernel, Trace};

    fn kernel(name: &str, stream: usize, nblocks: usize, bytes: u64) -> Kernel {
        Kernel {
            name: name.into(),
            step: "symbolic",
            stream,
            tb_size: 256,
            shared_bytes: 8 * 1024,
            blocks: vec![BlockWork { global_bytes: bytes, ..Default::default() }; nblocks],
        }
    }

    #[test]
    fn single_kernel_runs() {
        let mut t = Trace::new();
        t.launch(kernel("k1", 0, 100, 10_000));
        let tl = simulate(&t, &V100);
        assert_eq!(tl.kernels.len(), 1);
        assert!(tl.kernels[0].end > tl.kernels[0].start);
        assert!(tl.total_ns > 0.0);
    }

    #[test]
    fn same_stream_serializes_different_streams_overlap() {
        // 300 + 300 blocks, residency 8/SM over 80 SMs = 640 slots:
        // parallel streams fit in one wave, one stream needs two.
        let mk = |s1, s2| {
            let mut t = Trace::new();
            t.launch(kernel("a", s1, 300, 100_000));
            t.launch(kernel("b", s2, 300, 100_000));
            simulate(&t, &V100)
        };
        let serial = mk(0, 0);
        let parallel = mk(0, 1);
        // same stream: b starts after a ends
        assert!(serial.kernels[1].start >= serial.kernels[0].end - 1.0);
        // different streams with few blocks each: overlap
        assert!(
            parallel.total_ns < serial.total_ns * 0.95,
            "streams should overlap: {} vs {}",
            parallel.total_ns,
            serial.total_ns
        );
    }

    #[test]
    fn malloc_overlaps_running_kernel() {
        // launch-then-malloc must beat malloc-then-launch (§5.4): the
        // kernel (several ms) fully hides a 4MB malloc (~0.3ms)
        let malloc_bytes = 4 * 1024 * 1024;
        let mut overlap = Trace::new();
        overlap.launch(kernel("k", 0, 2000, 2_000_000));
        overlap.malloc(malloc_bytes, "table", "numeric");
        let mut serial = Trace::new();
        serial.malloc(malloc_bytes, "table", "numeric");
        serial.launch(kernel("k", 0, 2000, 2_000_000));
        let t_overlap = simulate(&overlap, &V100).total_ns;
        let t_serial = simulate(&serial, &V100).total_ns;
        assert!(
            t_overlap < t_serial - V100.malloc_ns(malloc_bytes) * 0.5,
            "malloc should hide behind the kernel: overlap={t_overlap} serial={t_serial}"
        );
    }

    #[test]
    fn free_synchronizes_device() {
        // free between two launches forces serialization even on
        // different streams (the nsparse §4.6 bug)
        let mut with_free = Trace::new();
        with_free.launch(kernel("a", 0, 300, 100_000));
        with_free.free("tmp", "symbolic");
        with_free.launch(kernel("b", 1, 300, 100_000));
        let mut without = Trace::new();
        without.launch(kernel("a", 0, 300, 100_000));
        without.launch(kernel("b", 1, 300, 100_000));
        without.free("tmp", "symbolic");
        let t_with = simulate(&with_free, &V100);
        let t_without = simulate(&without, &V100);
        assert!(
            t_without.total_ns < t_with.total_ns * 0.95,
            "deferred free should win: {} vs {}",
            t_without.total_ns,
            t_with.total_ns
        );
        // with the eager free, kernel b cannot overlap kernel a
        assert!(t_with.kernels[1].start >= t_with.kernels[0].end - 1.0);
    }

    #[test]
    fn giant_block_dominates_one_sm_while_others_finish() {
        // one kernel with 1 huge block + one with many small blocks:
        // total should be ~max(huge, rest), not the sum (§6.3.4)
        let mut t = Trace::new();
        let huge = Kernel {
            name: "giant".into(),
            step: "numeric",
            stream: 0,
            tb_size: 1024,
            shared_bytes: 4,
            blocks: vec![BlockWork { global_bytes: 50_000_000, ..Default::default() }],
        };
        t.launch(huge);
        t.launch(kernel("rest", 1, 5000, 100_000));
        let tl = simulate(&t, &V100);
        let giant_span = tl.kernels[0].end - tl.kernels[0].start;
        assert!(
            tl.total_ns < giant_span * 1.3,
            "small blocks should hide behind the giant: total={} giant={giant_span}",
            tl.total_ns
        );
    }

    #[test]
    fn sm_accounting_no_oversubscription() {
        let mut t = Trace::new();
        t.launch(kernel("a", 0, 10_000, 50_000));
        let tl = simulate(&t, &V100);
        // per-SM work time cannot exceed total wall time x residency
        // (8 blocks of this kernel co-reside per SM)
        for &b in &tl.sm_busy_ns {
            assert!(b <= tl.total_ns * 8.0 + 1.0, "sm busy {b} vs total {}", tl.total_ns);
        }
        let busy: f64 = tl.sm_busy_ns.iter().sum();
        assert!(busy > 0.0);
    }

    #[test]
    fn await_chunk_blocks_host_but_not_resident_kernels() {
        // launch, then await a late-arriving chunk, then launch again:
        // kernel a keeps executing through the wait, kernel b's start is
        // pushed past the arrival
        let mut t = Trace::new();
        t.launch(kernel("a", 0, 300, 100_000));
        t.await_chunk(0, "symbolic");
        t.launch(kernel("b", 1, 300, 100_000));
        let arrival = 1_000_000.0; // 1ms, far past a's launch
        let tl = simulate_with_arrivals(&t, &V100, &[arrival]);
        // the wait shows up as a host span ending at the arrival
        let wait = tl.host.iter().find(|h| h.what.starts_with("awaitChunk")).unwrap();
        assert!((wait.end - arrival).abs() < 1e-6);
        // kernel a started before the arrival (it was already launched)
        assert!(tl.kernels[0].start < arrival);
        // kernel b could not launch until the chunk landed
        assert!(tl.kernels[1].start > arrival);

        // without arrivals the annotated trace replays identically to the
        // unannotated one (bit-identical serial baseline)
        let mut clean = Trace::new();
        clean.launch(kernel("a", 0, 300, 100_000));
        clean.launch(kernel("b", 1, 300, 100_000));
        let tl_annotated = simulate(&t, &V100);
        let tl_clean = simulate(&clean, &V100);
        assert_eq!(tl_annotated.total_ns, tl_clean.total_ns);
        assert_eq!(tl_annotated.host.len(), tl_clean.host.len(), "no zero-length wait spans");
    }

    #[test]
    fn launch_order_priority() {
        // two kernels on different streams; first-launched starts first
        let mut t = Trace::new();
        t.launch(kernel("first", 0, 50_000, 10_000));
        t.launch(kernel("second", 1, 10, 10_000));
        let tl = simulate(&t, &V100);
        assert!(tl.kernels[0].start <= tl.kernels[1].start + 1.0);
    }
}
