//! `cargo bench --bench fig6_large` — regenerates paper Figure 6:
//! SpGEMM GFLOPS of nsparse/spECK/OpSparse on the 7 large matrices
//! (cuSPARSE omitted: out-of-memory on the originals, §6.1).

use opsparse::bench::figures;
use opsparse::gen::suite::SuiteScale;

fn main() {
    let scale = std::env::var("OPSPARSE_SCALE")
        .ok()
        .and_then(|s| SuiteScale::parse(&s))
        .unwrap_or(SuiteScale::Small);
    figures::fig6(scale, true).expect("fig6");
}
