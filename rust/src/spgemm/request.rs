//! One front door for every way to run a multiply: the
//! [`SpgemmRequest`] builder.
//!
//! The pipeline grew one entry point per capability — [`multiply`] for
//! the cold path, [`multiply_reuse`] adding the pool + symbolic-reuse
//! hooks, [`multiply_sharded`] / [`multiply_sharded_pooled`] /
//! [`multiply_sharded_with`] adding row sharding with progressively
//! more knobs — seven positional-argument spellings of the same
//! question. This module collapses the sprawl into one builder:
//!
//! ```text
//! SpgemmRequest::new(&a, &b)
//!     .config(&cfg)        // pipeline knobs        (default: OpSparseConfig::default())
//!     .pool(&mut pool)     // warm device pool      (default: per-call allocation)
//!     .reuse(&sym)         // cached symbolic phase (default: compute it)
//!     .shards(4)           // row-shard over n devices
//!     .plan(&plan)         // ...or an explicit row partition
//!     .pools(&mut pools)   // per-device pools for the sharded path
//!     .shard_reuse(&sr)    // per-shard symbolic reuse
//!     .overlap(ov)         // chunked-broadcast annotation
//!     .run()               // -> SpgemmOutput   (or .run_sharded() -> ShardedOutput)
//! ```
//!
//! The builder adds **no** third execution path: [`SpgemmRequest::run`]
//! dispatches to [`multiply_reuse`] (unsharded) and
//! [`SpgemmRequest::run_sharded`] to [`multiply_sharded_with`], which
//! remain the two engine entries. The legacy free functions survive as
//! thin wrappers over the builder (see their doctests proving identical
//! results), so existing callers keep working while new code states
//! only the options it uses.

use super::pipeline::{multiply_reuse, OpSparseConfig, SpgemmOutput, SymbolicReuse};
use super::sharded::{multiply_sharded_with, ShardPlan, ShardReuse, ShardedOutput};
use crate::gpusim::{DevicePool, OverlapConfig};
use crate::sparse::stats::nprod_per_row;
use crate::sparse::Csr;
use anyhow::{ensure, Result};

/// How a request partitions rows across devices (nothing, a shard
/// count balanced by intermediate products, or an explicit plan).
enum Sharding<'p> {
    None,
    Count(usize),
    Plan(&'p ShardPlan),
}

/// A multiply being assembled: operands first, then only the options
/// that matter, then [`run`](SpgemmRequest::run) (or
/// [`run_sharded`](SpgemmRequest::run_sharded) when the per-shard
/// outputs are wanted). See the [module docs](self) for the full menu.
pub struct SpgemmRequest<'r> {
    a: &'r Csr,
    b: &'r Csr,
    cfg: Option<&'r OpSparseConfig>,
    pool: Option<&'r mut DevicePool>,
    reuse: Option<&'r SymbolicReuse>,
    sharding: Sharding<'r>,
    pools: Option<&'r mut [DevicePool]>,
    shard_reuse: Option<&'r ShardReuse>,
    overlap: Option<OverlapConfig>,
}

impl<'r> SpgemmRequest<'r> {
    /// A request for `C = A * B` with every option at its default.
    pub fn new(a: &'r Csr, b: &'r Csr) -> Self {
        SpgemmRequest {
            a,
            b,
            cfg: None,
            pool: None,
            reuse: None,
            sharding: Sharding::None,
            pools: None,
            shard_reuse: None,
            overlap: None,
        }
    }

    /// Pipeline knobs (default: [`OpSparseConfig::default`]).
    pub fn config(mut self, cfg: &'r OpSparseConfig) -> Self {
        self.cfg = Some(cfg);
        self
    }

    /// Serve every device allocation from a warm grow-only pool
    /// (unsharded path; the sharded path takes [`pools`](Self::pools)).
    pub fn pool(mut self, pool: &'r mut DevicePool) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Replay a cached symbolic phase for this exact sparsity pattern
    /// (unsharded path).
    pub fn reuse(mut self, reuse: &'r SymbolicReuse) -> Self {
        self.reuse = Some(reuse);
        self
    }

    /// Row-shard across `n` devices, balancing shards by intermediate
    /// products. Overridden by [`plan`](Self::plan).
    pub fn shards(mut self, n: usize) -> Self {
        self.sharding = Sharding::Count(n);
        self
    }

    /// Row-shard by an explicit partition (wins over
    /// [`shards`](Self::shards)).
    pub fn plan(mut self, plan: &'r ShardPlan) -> Self {
        self.sharding = Sharding::Plan(plan);
        self
    }

    /// Per-device pools for the sharded path (one per shard; a short
    /// slice fails the run, as [`multiply_sharded_with`] always has).
    pub fn pools(mut self, pools: &'r mut [DevicePool]) -> Self {
        self.pools = Some(pools);
        self
    }

    /// Per-shard symbolic-reuse entries (the shard-aware pattern-cache
    /// hook).
    pub fn shard_reuse(mut self, reuse: &'r ShardReuse) -> Self {
        self.shard_reuse = Some(reuse);
        self
    }

    /// Chunked-broadcast overlap annotation for the sharded path
    /// (default: [`OverlapConfig::default`]; never changes numerics).
    pub fn overlap(mut self, overlap: OverlapConfig) -> Self {
        self.overlap = Some(overlap);
        self
    }

    /// Run the request. Unsharded requests dispatch to
    /// [`multiply_reuse`]; sharded ones run
    /// [`run_sharded`](Self::run_sharded) and collapse the result with
    /// [`ShardedOutput::into_output`] (note its merged trace
    /// *serializes* the devices — keep the [`ShardedOutput`] when the
    /// concurrent makespan matters).
    pub fn run(self) -> Result<SpgemmOutput> {
        match self.sharding {
            Sharding::None => {
                let default_cfg;
                let cfg = match self.cfg {
                    Some(c) => c,
                    None => {
                        default_cfg = OpSparseConfig::default();
                        &default_cfg
                    }
                };
                multiply_reuse(self.a, self.b, cfg, self.pool, self.reuse)
            }
            _ => Ok(self.run_sharded()?.into_output()),
        }
    }

    /// Run the request sharded, keeping the per-shard outputs. A
    /// request with no sharding configured runs as one shard.
    pub fn run_sharded(self) -> Result<ShardedOutput> {
        let default_cfg;
        let cfg = match self.cfg {
            Some(c) => c,
            None => {
                default_cfg = OpSparseConfig::default();
                &default_cfg
            }
        };
        let overlap = self.overlap.unwrap_or_default();
        match self.sharding {
            Sharding::Plan(plan) => multiply_sharded_with(
                self.a,
                self.b,
                cfg,
                plan,
                self.pools,
                overlap,
                self.shard_reuse,
            ),
            Sharding::Count(n) | Sharding::None => {
                let n = if let Sharding::Count(n) = self.sharding { n } else { 1 };
                ensure!(
                    self.a.cols == self.b.rows,
                    "dimension mismatch: {}x{} * {}x{}",
                    self.a.rows,
                    self.a.cols,
                    self.b.rows,
                    self.b.cols
                );
                let plan = ShardPlan::balanced(&nprod_per_row(self.a, self.b), n);
                multiply_sharded_with(
                    self.a,
                    self.b,
                    cfg,
                    &plan,
                    self.pools,
                    overlap,
                    self.shard_reuse,
                )
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::uniform::Uniform;
    use crate::spgemm::pipeline::multiply;
    use crate::spgemm::sharded::{multiply_sharded, multiply_sharded_pooled};
    use crate::util::rng::Rng;

    fn mat(seed: u64) -> Csr {
        Uniform { n: 120, per_row: 6, jitter: 2 }.generate(&mut Rng::new(seed))
    }

    #[test]
    fn builder_matches_every_legacy_spelling() {
        let (a, b) = (mat(1), mat(2));
        let cfg = OpSparseConfig::default();
        // unsharded
        let old = multiply(&a, &b, &cfg).unwrap();
        let new = SpgemmRequest::new(&a, &b).config(&cfg).run().unwrap();
        assert_eq!(old.c, new.c);
        assert_eq!(old.nprod, new.nprod);
        // defaulted config is the default config
        let defaulted = SpgemmRequest::new(&a, &b).run().unwrap();
        assert_eq!(defaulted.c, old.c);
        // sharded by count
        let old_s = multiply_sharded(&a, &b, &cfg, 3).unwrap();
        let new_s = SpgemmRequest::new(&a, &b).config(&cfg).shards(3).run_sharded().unwrap();
        assert_eq!(old_s.c, new_s.c);
        assert_eq!(old_s.plan.bounds(), new_s.plan.bounds());
        // sharded + pooled
        let mut pools = Vec::new();
        let old_p = multiply_sharded_pooled(&a, &b, &cfg, 2, &mut pools).unwrap();
        let mut pools2 = vec![DevicePool::new(), DevicePool::new()];
        let new_p = SpgemmRequest::new(&a, &b)
            .config(&cfg)
            .shards(2)
            .pools(&mut pools2)
            .run_sharded()
            .unwrap();
        assert_eq!(old_p.c, new_p.c);
        // sharded collapsed through run()
        let collapsed = SpgemmRequest::new(&a, &b).config(&cfg).shards(3).run().unwrap();
        assert_eq!(collapsed.c, old_s.c);
        // every spelling agrees with the unsharded result
        assert_eq!(old_s.c, old.c);
    }

    #[test]
    fn explicit_plan_and_reuse_flow_through() {
        let (a, b) = (mat(3), mat(4));
        let cfg = OpSparseConfig::default();
        let plan = ShardPlan::balanced(&nprod_per_row(&a, &b), 4);
        let via_plan =
            SpgemmRequest::new(&a, &b).config(&cfg).plan(&plan).run_sharded().unwrap();
        assert_eq!(via_plan.plan.bounds(), plan.bounds());
        // .plan() wins over .shards()
        let both = SpgemmRequest::new(&a, &b)
            .config(&cfg)
            .shards(2)
            .plan(&plan)
            .run_sharded()
            .unwrap();
        assert_eq!(both.plan.bounds(), plan.bounds());
        // unsharded reuse replays the symbolic phase
        let cold = SpgemmRequest::new(&a, &b).config(&cfg).run().unwrap();
        let sym = SymbolicReuse::from_output(&cold);
        let warm = SpgemmRequest::new(&a, &b).config(&cfg).reuse(&sym).run().unwrap();
        assert!(warm.symbolic_skipped);
        assert_eq!(warm.c, cold.c);
        // warm pool run stays bit-identical
        let mut pool = DevicePool::new();
        let pooled = SpgemmRequest::new(&a, &b).config(&cfg).pool(&mut pool).run().unwrap();
        assert_eq!(pooled.c, cold.c);
    }

    #[test]
    fn dimension_mismatch_is_an_error_on_both_paths() {
        let a = mat(5);
        let b = Uniform { n: 64, per_row: 4, jitter: 1 }.generate(&mut Rng::new(6));
        assert!(SpgemmRequest::new(&a, &b).run().is_err());
        assert!(SpgemmRequest::new(&a, &b).shards(2).run_sharded().is_err());
    }
}
