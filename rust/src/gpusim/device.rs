//! Device parameter sets. The defaults model the paper's evaluation
//! platform: NVIDIA Tesla V100 PCI-e, 16 GB HBM2, 900 GB/s, 80 SMs
//! (§6.1), with the paper's own `cudaMalloc` micro-benchmark numbers
//! (§4.4: allocating 4 MB ≈ 13.7 GB/s vs 124 GB/s access).

/// Static device model parameters (all times in nanoseconds).
#[derive(Clone, Debug)]
pub struct DeviceParams {
    pub name: &'static str,
    /// Streaming multiprocessors.
    pub sms: usize,
    /// Max resident threads per SM.
    pub max_threads_per_sm: usize,
    /// Max resident thread blocks per SM.
    pub max_blocks_per_sm: usize,
    /// Shared memory per SM in bytes (96 KB on Volta).
    pub shared_per_sm: usize,
    /// Peak HBM bandwidth in bytes/ns (== GB/s · 1e-9 · 1e9).
    pub hbm_bytes_per_ns: f64,
    /// Per-SM shared-memory throughput in 4-byte words per ns.
    pub shared_words_per_ns: f64,
    /// Per-SM FP64 throughput in flops/ns.
    pub fp64_flops_per_ns: f64,
    /// Host-side cost of one kernel launch.
    pub launch_overhead_ns: f64,
    /// Device-side launch-to-first-block latency.
    pub launch_latency_ns: f64,
    /// Fixed `cudaMalloc` overhead + bandwidth (paper §4.4 micro-bench).
    pub malloc_base_ns: f64,
    pub malloc_bytes_per_ns: f64,
    /// `cudaFree` host cost (after the implicit device sync).
    pub free_base_ns: f64,
    /// Contended global-memory atomic cost (serialized through L2).
    pub global_atomic_ns: f64,
    /// Per-block fixed scheduling overhead.
    pub block_overhead_ns: f64,
    /// Small D2H metadata copy: latency + bandwidth.
    pub memcpy_base_ns: f64,
    pub memcpy_bytes_per_ns: f64,
    /// Average slowdown factor applied to shared-memory traffic from bank
    /// conflicts under the hash table's random access pattern.
    pub bank_conflict_factor: f64,
}

/// NVIDIA Tesla V100 PCI-e (the paper's platform).
pub const V100: DeviceParams = DeviceParams {
    name: "Tesla V100 PCIe",
    sms: 80,
    max_threads_per_sm: 2048,
    max_blocks_per_sm: 32,
    shared_per_sm: 96 * 1024,
    hbm_bytes_per_ns: 900.0,         // 900 GB/s
    shared_words_per_ns: 44.0,       // 32 banks * 4B * 1.38 GHz per SM
    fp64_flops_per_ns: 98.0,         // 32 FP64 cores * 2 * 1.53 GHz per SM
    launch_overhead_ns: 5_000.0,     // ~5 us host-side per launch
    launch_latency_ns: 2_000.0,
    malloc_base_ns: 10_000.0,
    malloc_bytes_per_ns: 13.7,       // paper §4.4: 4MB at 13.7 GB/s
    free_base_ns: 10_000.0,
    global_atomic_ns: 30.0,
    block_overhead_ns: 300.0,
    memcpy_base_ns: 8_000.0,
    memcpy_bytes_per_ns: 12.0,
    bank_conflict_factor: 4.0,
};

impl DeviceParams {
    /// Per-SM share of HBM bandwidth in bytes/ns.
    pub fn hbm_per_sm(&self) -> f64 {
        self.hbm_bytes_per_ns / self.sms as f64
    }

    /// `cudaMalloc` duration for `bytes`.
    pub fn malloc_ns(&self, bytes: usize) -> f64 {
        self.malloc_base_ns + bytes as f64 / self.malloc_bytes_per_ns
    }

    /// Small synchronous D2H copy duration.
    pub fn memcpy_ns(&self, bytes: usize) -> f64 {
        self.memcpy_base_ns + bytes as f64 / self.memcpy_bytes_per_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn malloc_matches_paper_microbench() {
        // §4.4: allocating 4MB of global memory ~ 13.7 GB/s
        let t = V100.malloc_ns(4 * 1024 * 1024);
        let gbps = 4.0 * 1024.0 * 1024.0 / t; // bytes per ns == GB/s
        assert!((12.0..14.0).contains(&gbps), "malloc effective bw {gbps:.1} GB/s");
    }

    #[test]
    fn hbm_share() {
        assert!((V100.hbm_per_sm() - 11.25).abs() < 1e-9);
    }
}
