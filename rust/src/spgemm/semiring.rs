//! Semiring-generalized SpGEMM.
//!
//! The paper's motivating applications (§1) multiply over more than the
//! real `(+, ×)` ring: multi-source BFS uses the boolean `(∨, ∧)`
//! semiring, shortest-path relaxations use the tropical `(min, +)`
//! semiring. Values stay `f64`-encoded (bool as 0/1, tropical with
//! `+inf` as the additive identity) so the CSR substrate is reused.
//!
//! This path is sort-merge based (the apps are not the hot path); the
//! optimized hash pipeline covers the `(+, ×)` case.

use crate::sparse::Csr;

/// A semiring over f64-encoded values.
pub trait Semiring {
    /// Additive identity (the "structural zero" — entries equal to it are
    /// pruned from the output).
    const ZERO: f64;
    /// Semiring addition (accumulation).
    fn add(a: f64, b: f64) -> f64;
    /// Semiring multiplication.
    fn mul(a: f64, b: f64) -> f64;
}

/// The ordinary `(+, ×)` ring.
pub struct PlusTimes;
impl Semiring for PlusTimes {
    const ZERO: f64 = 0.0;
    fn add(a: f64, b: f64) -> f64 {
        a + b
    }
    fn mul(a: f64, b: f64) -> f64 {
        a * b
    }
}

/// Boolean `(∨, ∧)` on 0/1 values.
pub struct BoolOrAnd;
impl Semiring for BoolOrAnd {
    const ZERO: f64 = 0.0;
    fn add(a: f64, b: f64) -> f64 {
        if a != 0.0 || b != 0.0 {
            1.0
        } else {
            0.0
        }
    }
    fn mul(a: f64, b: f64) -> f64 {
        if a != 0.0 && b != 0.0 {
            1.0
        } else {
            0.0
        }
    }
}

/// Tropical `(min, +)`: shortest-path relaxation.
pub struct MinPlus;
impl Semiring for MinPlus {
    const ZERO: f64 = f64::INFINITY;
    fn add(a: f64, b: f64) -> f64 {
        a.min(b)
    }
    fn mul(a: f64, b: f64) -> f64 {
        a + b
    }
}

/// `C = A ⊗ B` over semiring `S` (row-wise sort-merge accumulation).
pub fn spgemm_semiring<S: Semiring>(a: &Csr, b: &Csr) -> Csr {
    assert_eq!(a.cols, b.rows, "inner dimension mismatch");
    let mut rpt = vec![0usize; a.rows + 1];
    let mut col: Vec<u32> = Vec::new();
    let mut val: Vec<f64> = Vec::new();
    let mut scratch: Vec<(u32, f64)> = Vec::new();
    for i in 0..a.rows {
        scratch.clear();
        let (acols, avals) = a.row(i);
        for (&k, &av) in acols.iter().zip(avals) {
            let (bcols, bvals) = b.row(k as usize);
            for (&c, &bv) in bcols.iter().zip(bvals) {
                scratch.push((c, S::mul(av, bv)));
            }
        }
        scratch.sort_unstable_by_key(|&(c, _)| c);
        let mut last: Option<u32> = None;
        for &(c, v) in scratch.iter() {
            if last == Some(c) {
                let acc = val.last_mut().unwrap();
                *acc = S::add(*acc, v);
            } else {
                col.push(c);
                val.push(v);
                last = Some(c);
            }
        }
        // prune structural zeros produced by the accumulation
        let row_start = rpt[i];
        let mut w = row_start;
        for r in row_start..col.len() {
            if val[r] != S::ZERO {
                col[w] = col[r];
                val[w] = val[r];
                w += 1;
            }
        }
        col.truncate(w);
        val.truncate(w);
        rpt[i + 1] = col.len();
    }
    Csr { rows: a.rows, cols: b.cols, rpt, col, val }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spgemm::reference::spgemm_reference;
    use crate::util::rng::Rng;

    fn random_csr(n: usize, per_row: usize, seed: u64) -> Csr {
        let mut rng = Rng::new(seed);
        let mut rpt = vec![0usize];
        let mut col = Vec::new();
        let mut val = Vec::new();
        let mut scratch = Vec::new();
        for _ in 0..n {
            let k = rng.range(0, per_row + 1);
            rng.sample_distinct(n, k, &mut scratch);
            for &c in &scratch {
                col.push(c);
                val.push(rng.value());
            }
            rpt.push(col.len());
        }
        Csr::from_parts(n, n, rpt, col, val).unwrap()
    }

    #[test]
    fn plus_times_matches_reference() {
        let a = random_csr(40, 5, 1);
        let b = random_csr(40, 5, 2);
        let s = spgemm_semiring::<PlusTimes>(&a, &b);
        let gold = spgemm_reference(&a, &b);
        // the semiring path additionally prunes exact-zero results; on
        // random values exact cancellation has measure zero
        assert!(s.approx_eq(&gold, 1e-12), "{:?}", s.diff(&gold, 1e-12));
    }

    #[test]
    fn boolean_reachability() {
        // path graph 0 -> 1 -> 2: A^2 over bool = 2-step reachability
        let a = Csr::from_parts(3, 3, vec![0, 1, 2, 2], vec![1, 2], vec![1.0, 1.0]).unwrap();
        let r2 = spgemm_semiring::<BoolOrAnd>(&a, &a);
        assert_eq!(r2.get(0, 2), 1.0);
        assert_eq!(r2.nnz(), 1);
    }

    #[test]
    fn boolean_is_idempotent_on_values() {
        let a = random_csr(30, 6, 3);
        // force all values to 1
        let ones = Csr { val: vec![1.0; a.nnz()], ..a.clone() };
        let c = spgemm_semiring::<BoolOrAnd>(&ones, &ones);
        assert!(c.val.iter().all(|&v| v == 1.0), "boolean output must be 0/1");
    }

    #[test]
    fn tropical_two_hop_shortest_paths() {
        // 0 -(2)-> 1 -(3)-> 2 and 0 -(10)-> 2 directly (as an edge in A);
        // A ⊗ A over (min,+) holds the best 2-hop distances
        let a = Csr::from_parts(
            3,
            3,
            vec![0, 2, 3, 3],
            vec![1, 2, 2],
            vec![2.0, 10.0, 3.0],
        )
        .unwrap();
        let d2 = spgemm_semiring::<MinPlus>(&a, &a);
        assert_eq!(d2.get(0, 2), 5.0, "min(2+3) beats nothing else");
    }

    #[test]
    fn zero_pruning() {
        // (min,+): entries that stay +inf must not be stored
        let a = Csr::from_parts(2, 2, vec![0, 1, 1], vec![0], vec![1.0]).unwrap();
        let c = spgemm_semiring::<MinPlus>(&a, &a);
        c.validate().unwrap();
        assert!(c.val.iter().all(|&v| v.is_finite()));
    }
}
