//! Deterministic, seedable RNG (splitmix64 + xoshiro256**). Offline build:
//! no `rand` crate, and determinism matters — the synthetic Table-3 suite
//! must be bit-reproducible across runs so EXPERIMENTS.md numbers are stable.

/// splitmix64: used to seed xoshiro and for cheap one-shot hashing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality, tiny.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` via Lemire's multiply-shift reduction.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Nonzero value in roughly `[-1, 1] \ {0}`; used for matrix values.
    #[inline]
    pub fn value(&mut self) -> f64 {
        let v = self.f64() * 2.0 - 1.0;
        if v == 0.0 {
            0.5
        } else {
            v
        }
    }

    /// Sample from a (truncated) power-law over `[1, max]` with exponent
    /// `alpha > 1` via inverse-CDF. Drives the webbase-like generator where a
    /// handful of rows are enormous (max nnz/row 4700 in Table 3).
    pub fn power_law(&mut self, max: usize, alpha: f64) -> usize {
        let u = self.f64();
        let m = max as f64;
        let one_m_a = 1.0 - alpha;
        // inverse CDF of p(x) ~ x^-alpha on [1, m]
        let x = ((m.powf(one_m_a) - 1.0) * u + 1.0).powf(1.0 / one_m_a);
        (x as usize).clamp(1, max)
    }

    /// Fisher–Yates sample of `k` distinct items from `[0, n)` (k << n uses
    /// rejection through a small set; otherwise partial shuffle).
    pub fn sample_distinct(&mut self, n: usize, k: usize, out: &mut Vec<u32>) {
        out.clear();
        if k == 0 || n == 0 {
            return;
        }
        let k = k.min(n);
        if k * 8 < n {
            // sparse: rejection sampling with sort-dedup fallback
            while out.len() < k {
                let c = self.below(n as u64) as u32;
                if !out.contains(&c) {
                    out.push(c);
                }
            }
        } else {
            // dense: reservoir over the full range
            let mut pool: Vec<u32> = (0..n as u32).collect();
            for i in 0..k {
                let j = self.range(i, n);
                pool.swap(i, j);
            }
            out.extend_from_slice(&pool[..k]);
        }
        out.sort_unstable();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(2);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn power_law_bounds_and_skew() {
        let mut r = Rng::new(3);
        let mut ones = 0;
        for _ in 0..10_000 {
            let x = r.power_law(1000, 2.2);
            assert!((1..=1000).contains(&x));
            if x == 1 {
                ones += 1;
            }
        }
        // heavy head: most draws are tiny
        assert!(ones > 4000, "power law should be head-heavy, got {ones}");
    }

    #[test]
    fn sample_distinct_properties() {
        let mut r = Rng::new(4);
        let mut out = Vec::new();
        for &(n, k) in &[(100usize, 5usize), (10, 10), (50, 40), (1, 1)] {
            r.sample_distinct(n, k, &mut out);
            assert_eq!(out.len(), k.min(n));
            let mut sorted = out.clone();
            sorted.dedup();
            assert_eq!(sorted.len(), out.len(), "duplicates in sample");
            assert!(out.iter().all(|&c| (c as usize) < n));
            assert!(out.windows(2).all(|w| w[0] < w[1]), "must be sorted");
        }
    }
}
