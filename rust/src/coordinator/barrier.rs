//! Reassembly barrier for cross-worker shard fan-out.
//!
//! [`crate::coordinator::Coordinator::submit`] splits a
//! [`Route::Sharded`] job into one sub-job per shard and fans them out
//! over the whole hash-worker pool, so one oversized multiply and many
//! small jobs share the fleet instead of the shards being trapped on one
//! worker's scoped threads. Each sub-job reports its `C` row block here;
//! when the last shard lands, the barrier stitches the blocks back in
//! shard order (bit-identical to the in-worker and unsharded paths, via
//! [`stitch_row_blocks`]) and emits **exactly one** [`JobResult`] for
//! the parent job:
//!
//! * all shards `Ok` → the stitched CSR;
//! * any shard `Err` (a failed worker, a poisoned shard caught by the
//!   worker's panic guard) → one failure carrying the first shard error,
//!   after every shard has reported — never a partial stitch;
//! * the barrier dropped with shards still outstanding (queued sub-jobs
//!   discarded because the coordinator was dropped mid-flight) → one
//!   failure from `Drop`, so a lost shard can never hang the parent.
//!
//! A clean [`crate::coordinator::Coordinator::shutdown`] does not hit
//! the `Drop` path: stop markers queue *behind* already-submitted
//! sub-jobs, so workers drain every in-flight barrier first.

use super::cache::PatternKey;
use super::feedback::{ExecHistory, RunObservation};
use super::metrics::Metrics;
use super::router::Route;
use super::service::{finish, JobResult};
use crate::sparse::Csr;
use crate::spgemm::pipeline::SpgemmOutput;
use crate::spgemm::sharded::{stitch_row_blocks, MeasuredShard};
use anyhow::{anyhow, Result};
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// What the barrier needs to feed the execution history when the parent
/// completes: the shared store, the pattern key, and the row ranges the
/// plan assigned (shard `s` of the observation is `ranges[s]` plus the
/// measured ns its worker reported). Attached only when adaptive
/// re-planning is on — with it off, the barrier does exactly what it
/// did before.
pub struct ShardFeedback {
    pub history: Arc<Mutex<ExecHistory>>,
    pub key: PatternKey,
    pub ranges: Vec<(usize, usize)>,
}

struct State {
    /// One slot per shard, filled by [`ShardBarrier::complete`].
    slots: Vec<Option<Result<SpgemmOutput>>>,
    /// Measured per-shard execution ns, parallel to `slots`. `None`
    /// when the worker reported no measurement (e.g. a symbolic-cache
    /// replay, whose trace time is not comparable to a cold shard's).
    ns: Vec<Option<f64>>,
    /// Shards still outstanding.
    remaining: usize,
    /// Set once the parent `JobResult` has been emitted.
    finished: bool,
}

/// Collects the per-shard results of one sharded job and emits the
/// parent [`JobResult`] when the last shard reports (or on `Drop`, if
/// the coordinator dies with shards outstanding).
pub struct ShardBarrier {
    job_id: u64,
    route: Route,
    /// Stitched result shape: `rows` = parent `A.rows`, `cols` = `B.cols`.
    rows: usize,
    cols: usize,
    t0: Instant,
    tx: mpsc::Sender<JobResult>,
    metrics: Arc<Metrics>,
    /// Execution-history hook, when adaptive re-planning is on.
    feedback: Option<ShardFeedback>,
    state: Mutex<State>,
}

impl ShardBarrier {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        job_id: u64,
        route: Route,
        n_shards: usize,
        rows: usize,
        cols: usize,
        tx: mpsc::Sender<JobResult>,
        metrics: Arc<Metrics>,
        t0: Instant,
        feedback: Option<ShardFeedback>,
    ) -> ShardBarrier {
        let n = n_shards.max(1);
        ShardBarrier {
            job_id,
            route,
            rows,
            cols,
            t0,
            tx,
            metrics,
            feedback,
            state: Mutex::new(State {
                slots: (0..n).map(|_| None).collect(),
                ns: vec![None; n],
                remaining: n,
                finished: false,
            }),
        }
    }

    /// Record shard `shard`'s result (plus its measured execution ns,
    /// when the worker timed it). The last arrival stitches and emits
    /// the parent result — and, with a [`ShardFeedback`] attached and a
    /// successful stitch, folds the measured per-shard timings into the
    /// execution history so the *next* submit of this pattern re-cuts
    /// from them. Duplicate or late reports are ignored.
    pub fn complete(&self, shard: usize, result: Result<SpgemmOutput>, measured_ns: Option<f64>) {
        let ready = {
            let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
            // defensive: a duplicate, out-of-range, or post-completion
            // report is ignored rather than corrupting the stitch
            if st.finished || shard >= st.slots.len() || st.slots[shard].is_some() {
                return;
            }
            st.slots[shard] = Some(result);
            st.ns[shard] = measured_ns;
            st.remaining -= 1;
            if st.remaining == 0 {
                st.finished = true;
                Some((std::mem::take(&mut st.slots), std::mem::take(&mut st.ns)))
            } else {
                None
            }
        };
        // stitch outside the lock: it is O(nnz(C)) of copying
        if let Some((slots, ns)) = ready {
            let (c, nprod) = Self::reassemble(self.rows, self.cols, slots);
            if c.is_ok() {
                self.observe(&ns, nprod);
            }
            finish(&self.metrics, &self.tx, self.job_id, self.route, c, nprod, self.t0);
        }
    }

    /// Fold this run into the execution history (successful parents
    /// only — a failed shard's timings describe nothing worth planning
    /// from) and refresh the occupancy gauges. A run where any shard
    /// reported no measurement (a symbolic-cache replay) is dropped
    /// whole: mixing replayed and cold shard times would hand the
    /// planner incomparable numbers, so only homogeneous all-cold runs
    /// update the plan history — at the cost of staleness for plans
    /// whose shards stay partially cache-warm (see the ROADMAP
    /// re-measurement follow-on).
    fn observe(&self, ns: &[Option<f64>], nprod: usize) {
        let Some(fb) = &self.feedback else { return };
        if ns.iter().any(|n| n.is_none()) {
            return;
        }
        let shards: Vec<MeasuredShard> = fb
            .ranges
            .iter()
            .zip(ns)
            .map(|(&(lo, hi), &ns)| MeasuredShard { lo, hi, ns: ns.unwrap_or(0.0) })
            .collect();
        let obs = RunObservation {
            shards,
            wall_ns: self.t0.elapsed().as_nanos() as f64,
            nprod: nprod as u64,
            chunk: None,
        };
        let mut h = fb.history.lock().unwrap_or_else(|e| e.into_inner());
        h.record(fb.key, obs);
        self.metrics.history_patterns.store(h.len() as u64, Ordering::Relaxed);
        self.metrics.history_evictions.store(h.evictions(), Ordering::Relaxed);
    }

    fn reassemble(
        rows: usize,
        cols: usize,
        slots: Vec<Option<Result<SpgemmOutput>>>,
    ) -> (Result<Csr>, usize) {
        let mut shards = Vec::with_capacity(slots.len());
        let mut failure: Option<anyhow::Error> = None;
        for (s, slot) in slots.into_iter().enumerate() {
            match slot {
                Some(Ok(out)) => shards.push(out),
                Some(Err(e)) => {
                    if failure.is_none() {
                        failure = Some(e.context(format!("shard {s} failed")));
                    }
                }
                None => {
                    if failure.is_none() {
                        failure = Some(anyhow!("shard {s} never reported"));
                    }
                }
            }
        }
        match failure {
            Some(e) => (Err(e), 0),
            None => match stitch_row_blocks(rows, cols, &shards) {
                Ok((c, nprod)) => (Ok(c), nprod),
                Err(e) => (Err(e), 0),
            },
        }
    }
}

impl Drop for ShardBarrier {
    fn drop(&mut self) {
        let st = self.state.get_mut().unwrap_or_else(|e| e.into_inner());
        if !st.finished {
            st.finished = true;
            let lost = st.remaining;
            let total = st.slots.len();
            finish(
                &self.metrics,
                &self.tx,
                self.job_id,
                self.route,
                Err(anyhow!("coordinator dropped with {lost} of {total} shards in flight")),
                0,
                self.t0,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spgemm::pipeline::{multiply, OpSparseConfig};

    fn barrier_for(
        n_shards: usize,
        rows: usize,
        cols: usize,
    ) -> (Arc<ShardBarrier>, mpsc::Receiver<JobResult>, Arc<Metrics>) {
        let (tx, rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        let b = Arc::new(ShardBarrier::new(
            7,
            Route::Sharded { n_devices: n_shards },
            n_shards,
            rows,
            cols,
            tx,
            Arc::clone(&metrics),
            Instant::now(),
            None,
        ));
        (b, rx, metrics)
    }

    fn shard_output(m: &Csr) -> SpgemmOutput {
        multiply(m, m, &OpSparseConfig::default()).unwrap()
    }

    #[test]
    fn out_of_order_completion_stitches_in_shard_order() {
        let m = Csr::identity(4);
        let gold = shard_output(&m).c;
        let (b, rx, metrics) = barrier_for(2, 8, 4);
        // two identity blocks, completed in reverse order
        b.complete(1, Ok(shard_output(&m)), None);
        assert!(rx.try_recv().is_err(), "barrier must wait for every shard");
        b.complete(0, Ok(shard_output(&m)), None);
        let r = rx.recv().unwrap();
        let c = r.c.unwrap();
        assert_eq!(c.rows, 8);
        assert_eq!(c.nnz(), 2 * gold.nnz());
        assert_eq!(metrics.snapshot().jobs_completed, 1);
    }

    #[test]
    fn one_failed_shard_fails_the_parent_exactly_once() {
        let m = Csr::identity(4);
        let (b, rx, metrics) = barrier_for(3, 12, 4);
        b.complete(0, Ok(shard_output(&m)), None);
        b.complete(2, Err(anyhow!("injected")), None);
        assert!(rx.try_recv().is_err(), "no partial result before all shards report");
        b.complete(1, Ok(shard_output(&m)), None);
        let r = rx.recv().unwrap();
        assert!(r.c.is_err());
        assert!(rx.try_recv().is_err(), "exactly one JobResult");
        let snap = metrics.snapshot();
        assert_eq!(snap.jobs_failed, 1);
        assert_eq!(snap.jobs_completed, 0);
    }

    #[test]
    fn dropping_an_open_barrier_fails_the_parent() {
        let m = Csr::identity(4);
        let (b, rx, metrics) = barrier_for(2, 8, 4);
        b.complete(0, Ok(shard_output(&m)), None);
        drop(b);
        let r = rx.recv().unwrap();
        assert!(r.c.is_err(), "a lost shard must fail the job, not hang it");
        assert_eq!(metrics.snapshot().jobs_failed, 1);
    }

    #[test]
    fn finished_barrier_drop_is_silent() {
        let m = Csr::identity(4);
        let (b, rx, metrics) = barrier_for(1, 4, 4);
        b.complete(0, Ok(shard_output(&m)), None);
        assert!(rx.recv().unwrap().c.is_ok());
        drop(b);
        assert!(rx.try_recv().is_err());
        assert_eq!(metrics.snapshot().jobs_completed, 1);
        assert_eq!(metrics.snapshot().jobs_failed, 0);
    }

    #[test]
    fn successful_parent_records_measured_shards_into_history() {
        let m = Csr::identity(4);
        let (tx, rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        let history = Arc::new(Mutex::new(ExecHistory::new(8)));
        let b = ShardBarrier::new(
            7,
            Route::Sharded { n_devices: 2 },
            2,
            8,
            4,
            tx,
            Arc::clone(&metrics),
            Instant::now(),
            Some(ShardFeedback {
                history: Arc::clone(&history),
                key: (11, 22),
                ranges: vec![(0, 4), (4, 8)],
            }),
        );
        b.complete(0, Ok(shard_output(&m)), Some(1500.0));
        b.complete(1, Ok(shard_output(&m)), Some(2500.0));
        assert!(rx.recv().unwrap().c.is_ok());
        let h = history.lock().unwrap();
        let stats = h.lookup((11, 22)).expect("completed parent must record");
        assert_eq!(
            stats.measured,
            vec![
                MeasuredShard { lo: 0, hi: 4, ns: 1500.0 },
                MeasuredShard { lo: 4, hi: 8, ns: 2500.0 }
            ]
        );
        assert!(stats.ewma_wall_ns > 0.0, "end-to-end wall time must be folded in");
        let snap = metrics.snapshot();
        assert_eq!(snap.history_patterns, 1, "occupancy gauge must refresh");
    }

    #[test]
    fn mixed_measurement_run_is_not_recorded() {
        // one shard reported no measurement (a symbolic-cache replay):
        // recording the other half would hand the planner incomparable
        // numbers, so the whole observation is dropped
        let m = Csr::identity(4);
        let (tx, rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        let history = Arc::new(Mutex::new(ExecHistory::new(8)));
        let b = ShardBarrier::new(
            9,
            Route::Sharded { n_devices: 2 },
            2,
            8,
            4,
            tx,
            Arc::clone(&metrics),
            Instant::now(),
            Some(ShardFeedback {
                history: Arc::clone(&history),
                key: (11, 22),
                ranges: vec![(0, 4), (4, 8)],
            }),
        );
        b.complete(0, Ok(shard_output(&m)), Some(1500.0));
        b.complete(1, Ok(shard_output(&m)), None);
        assert!(rx.recv().unwrap().c.is_ok(), "the job itself still succeeds");
        assert!(history.lock().unwrap().is_empty(), "mixed measurements must be dropped");
    }

    #[test]
    fn failed_parent_records_nothing() {
        let m = Csr::identity(4);
        let (tx, rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        let history = Arc::new(Mutex::new(ExecHistory::new(8)));
        let b = ShardBarrier::new(
            8,
            Route::Sharded { n_devices: 2 },
            2,
            8,
            4,
            tx,
            Arc::clone(&metrics),
            Instant::now(),
            Some(ShardFeedback {
                history: Arc::clone(&history),
                key: (11, 22),
                ranges: vec![(0, 4), (4, 8)],
            }),
        );
        b.complete(0, Ok(shard_output(&m)), Some(1500.0));
        b.complete(1, Err(anyhow!("injected")), None);
        assert!(rx.recv().unwrap().c.is_err());
        assert!(history.lock().unwrap().is_empty(), "failed runs must not pollute history");
    }
}
