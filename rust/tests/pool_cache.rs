//! Cross-call reuse integration: the device pool and the symbolic-reuse
//! cache must change allocation behaviour and *nothing else*.
//!
//! Property over the whole generator suite: a pooled multiply — and a
//! warm multiply replaying a cached symbolic result — produce the exact
//! same `Csr` (bit-identical `rpt`/`col`/`val`) as the plain per-call
//! pipeline, which itself matches the sort-merge reference.

use opsparse::coordinator::{Coordinator, Job, Route, Router};
use opsparse::gen::suite::{entries, SuiteScale};
use opsparse::gpusim::{simulate, DevicePool, TraceOp, V100};
use opsparse::spgemm::pipeline::{multiply, multiply_reuse, OpSparseConfig, SymbolicReuse};
use opsparse::spgemm::reference::spgemm_reference;

#[test]
fn pooled_and_cached_multiplies_are_bit_identical_across_the_suite() {
    let cfg = OpSparseConfig::default();
    let mut pool = DevicePool::new();
    for e in entries() {
        let a = e.generate(SuiteScale::Tiny);
        let cold = multiply(&a, &a, &cfg)
            .unwrap_or_else(|err| panic!("per-call multiply failed on {}: {err:#}", e.name));
        // the reference anchors correctness of the whole family
        let gold = spgemm_reference(&a, &a);
        assert!(
            cold.c.approx_eq(&gold, 1e-9),
            "{}: pipeline vs reference: {:?}",
            e.name,
            cold.c.diff(&gold, 1e-9)
        );
        // pooling must not perturb a single bit of the result
        let pooled = multiply_reuse(&a, &a, &cfg, Some(&mut pool), None)
            .unwrap_or_else(|err| panic!("pooled multiply failed on {}: {err:#}", e.name));
        assert_eq!(pooled.c, cold.c, "{}: pooled result diverged", e.name);
        // neither must a symbolic-cache replay
        let entry = SymbolicReuse::from_output(&cold);
        let warm = multiply_reuse(&a, &a, &cfg, Some(&mut pool), Some(&entry))
            .unwrap_or_else(|err| panic!("warm multiply failed on {}: {err:#}", e.name));
        assert_eq!(warm.c, cold.c, "{}: cached-symbolic result diverged", e.name);
        assert!(warm.symbolic_skipped);
        assert_eq!(warm.nprod, cold.nprod, "{}: cached nprod diverged", e.name);
    }
}

#[test]
fn second_multiply_with_same_pattern_allocates_zero_new_pool_bytes() {
    let e = entries().into_iter().find(|e| e.name == "cant").unwrap();
    let a = e.generate(SuiteScale::Tiny);
    let cfg = OpSparseConfig::default();
    let mut pool = DevicePool::new();

    let cold = multiply_reuse(&a, &a, &cfg, Some(&mut pool), None).unwrap();
    assert!(cold.trace.malloc_calls() > 0, "cold call must grow the pool");
    let entry = SymbolicReuse::from_output(&cold);
    let footprint = pool.footprint_bytes();
    let before = pool.stats();

    let warm = multiply_reuse(&a, &a, &cfg, Some(&mut pool), Some(&entry)).unwrap();
    let delta = pool.stats().delta_since(&before);
    assert_eq!(delta.device_bytes, 0, "warm call must allocate zero new pool bytes");
    assert_eq!(delta.device_mallocs, 0);
    assert_eq!(pool.footprint_bytes(), footprint, "footprint must not grow");
    assert!(delta.pool_hits > 0, "warm call must be served from the pool");
    assert_eq!(warm.trace.malloc_calls(), 0, "no cudaMalloc in the warm trace");
    let frees =
        warm.trace.ops.iter().filter(|op| matches!(op, TraceOp::Free { .. })).count();
    assert_eq!(frees, 0, "no cudaFree (and no implicit sync) in the warm trace");

    // the warm timeline strictly beats the cold one: no malloc stalls, no
    // symbolic phase, no nnz readback
    let t_cold = simulate(&cold.trace, &V100);
    let t_warm = simulate(&warm.trace, &V100);
    assert!(t_warm.total_ns < t_cold.total_ns);
    assert_eq!(t_warm.alloc_stall_ns(), 0.0);
}

#[test]
fn coordinator_reports_cache_hits_on_repeated_app_patterns() {
    // AMG operator + MCL-style graph, each submitted three times to one
    // warm worker — the serving shape of the apps/ iteration workloads
    let amg_a = opsparse::apps::amg::poisson2d(24);
    let mcl_m =
        opsparse::gen::kron::Kron::default().generate(&mut opsparse::util::rng::Rng::new(5));
    let coord = Coordinator::start(1, Router::default(), None);
    let mut id = 0u64;
    for _ in 0..3 {
        for m in [&amg_a, &mcl_m] {
            coord.submit(Job {
                id,
                a: m.clone(),
                b: m.clone(),
                force_route: Some(Route::Hash),
            });
            id += 1;
        }
    }
    for _ in 0..id {
        let r = coord.recv().expect("coordinator alive");
        r.c.unwrap_or_else(|err| panic!("job failed: {err:#}"));
    }
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.sym_cache_misses, 2, "one miss per distinct pattern");
    assert_eq!(snap.sym_cache_hits, 4, "every repeat must hit");
    assert!(snap.pool_reused_bytes > 0);
    assert!(snap.sym_cache_hit_rate() > 0.6);
    coord.shutdown();
}
