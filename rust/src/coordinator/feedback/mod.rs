//! Feedback-driven adaptive planning: close the loop between *measured*
//! execution and the planner stack.
//!
//! OpSparse's core insight is that measured behavior — not a static
//! proxy — should drive configuration (§5.3's binning ranges are tuned
//! from observed collision/utilization trade-offs). The serving layers
//! above the pipeline ran entirely on a-priori proxies until this
//! module: `ShardPlan::balanced` cuts on the `nprod` product proxy, the
//! router's `ns_per_prod` was fit once at startup, and the overlap
//! model's `chunk_bytes` was a fixed default. Every one of those
//! quantities is *observable* — per-shard device times, job wall times,
//! chunk-arrival stalls — so this module records them and feeds them
//! back:
//!
//! * [`history`] — [`ExecHistory`]: a bounded, pattern-fingerprint-keyed
//!   store of per-run observations (per-shard measured ns, end-to-end
//!   wall time, overlap chunk feedback).
//! * [`crate::spgemm::sharded::ShardPlan::from_history`] — re-cuts
//!   shard bounds by equalizing measured per-row-block ns (cold
//!   patterns fall back to the proxy; a re-cut never degrades the
//!   modeled makespan).
//! * [`refit`] — [`NsPerProdFit`]: a refreshable (exponentially
//!   weighted) fit of the router's ns-per-product compute proxy that
//!   folds in measured job execution times, replacing the write-once
//!   `OnceLock` table — the router reads it per decision.
//! * [`replan`] — [`tune_chunk_bytes`]: broadcast chunk-size selection
//!   from measured arrival slack (shrink when devices stall on
//!   `AwaitChunk`, grow when per-chunk latency keeps the pipeline from
//!   filling).
//! * [`persist`] — warm-start persistence: everything above is a
//!   function of patterns and the device model, so none of it expires
//!   with the process; the serving front door saves the history + fit
//!   on shutdown and reloads them on start (bit-stable round trip).
//!
//! Consumers: the coordinator's `RunShard` fan-out re-plans warm
//! sharded jobs and its barrier records completed ones; hash workers
//! fold execution times into the live fit; `apps::SpgemmContext`
//! threads a history through repeated sharded multiplies (AMG re-setup
//! re-plans between levels); the `bench shards --replan` ablation
//! records cold-vs-warm makespans to `BENCH_adaptive.json`, where CI
//! blocks any warm regression.

pub mod history;
pub mod persist;
pub mod refit;
pub mod replan;

pub use history::{Engine, EngineStats, ExecHistory, PatternStats, RunObservation};
pub use persist::{load_state, save_state, PersistedState};
pub use refit::{default_fit, NsPerProdFit};
pub use replan::{tune_chunk_bytes, ChunkFeedback, MAX_CHUNK_BYTES, MIN_CHUNK_BYTES};

/// Parse an on/off switch value (`on|1|true` / `off|0|false`,
/// case-insensitive); `None` for anything else. The one parser behind
/// every `--replan` flag and `OPSPARSE_REPLAN` env read, so the CLI,
/// the bench binary, and [`ReplanConfig::from_env`] accept exactly the
/// same spellings — callers decide whether an unknown value keeps a
/// default (env paths) or is rejected (CLI flags).
pub fn parse_on_off(s: &str) -> Option<bool> {
    match s.to_ascii_lowercase().as_str() {
        "on" | "1" | "true" => Some(true),
        "off" | "0" | "false" => Some(false),
        _ => None,
    }
}

/// Knobs of the adaptive re-planning loop, mirroring the overlap knobs:
/// `enabled: false` is the ablation baseline that reproduces the
/// proxy-planned (PR 4) behavior exactly — no history is recorded, no
/// plan is re-cut, no extra work is done on the job path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReplanConfig {
    /// Re-cut warm patterns from measured timelines (default on).
    pub enabled: bool,
    /// Patterns the execution history retains (FIFO eviction beyond it).
    pub history_cap: usize,
}

impl Default for ReplanConfig {
    fn default() -> Self {
        ReplanConfig { enabled: true, history_cap: 128 }
    }
}

impl ReplanConfig {
    /// The ablation baseline: no history, no re-planning — byte-for-byte
    /// the proxy-planned behavior.
    pub fn off() -> ReplanConfig {
        ReplanConfig { enabled: false, ..ReplanConfig::default() }
    }

    /// Defaults overridden by the environment, mirroring the overlap
    /// knobs: `OPSPARSE_REPLAN=off|0|false` disables re-planning
    /// (`on|1|true` enables; anything else keeps the default),
    /// `OPSPARSE_HISTORY_CAP=<n>` bounds the history (an unparseable or
    /// zero value keeps the default).
    pub fn from_env() -> ReplanConfig {
        let mut cfg = ReplanConfig::default();
        if let Some(on) = std::env::var("OPSPARSE_REPLAN").ok().and_then(|v| parse_on_off(&v)) {
            cfg.enabled = on;
        }
        if let Some(cap) = std::env::var("OPSPARSE_HISTORY_CAP")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n > 0)
        {
            cfg.history_cap = cap;
        }
        cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn on_off_parser_accepts_both_spellings_and_rejects_junk() {
        for v in ["on", "ON", "1", "true", "True"] {
            assert_eq!(parse_on_off(v), Some(true), "{v}");
        }
        for v in ["off", "OFF", "0", "false", "False"] {
            assert_eq!(parse_on_off(v), Some(false), "{v}");
        }
        for v in ["yes", "no", "", "2"] {
            assert_eq!(parse_on_off(v), None, "{v}");
        }
    }

    #[test]
    fn defaults_and_off() {
        let d = ReplanConfig::default();
        assert!(d.enabled);
        assert!(d.history_cap > 0);
        let off = ReplanConfig::off();
        assert!(!off.enabled);
        assert_eq!(off.history_cap, d.history_cap);
    }
}
