//! Theoretical occupancy calculator (paper §4.7, §5.6).
//!
//! On Volta, theoretical occupancy is limited by threads/SM (2048),
//! blocks/SM (32), and shared memory/SM (96 KB). The paper forces register
//! pressure out of the picture with `__launch_bounds__(1024, 2)`, so we
//! model the remaining three limits.

use super::device::DeviceParams;

/// Resident blocks per SM for a kernel of `tb_size` threads and
/// `shared_bytes` shared memory per block.
pub fn blocks_per_sm(tb_size: usize, shared_bytes: usize, dev: &DeviceParams) -> usize {
    if tb_size == 0 {
        return 0;
    }
    let by_threads = dev.max_threads_per_sm / tb_size;
    let by_shared = if shared_bytes == 0 {
        dev.max_blocks_per_sm
    } else {
        dev.shared_per_sm / shared_bytes
    };
    by_threads.min(by_shared).min(dev.max_blocks_per_sm)
}

/// Theoretical occupancy: resident threads / max threads.
pub fn occupancy(tb_size: usize, shared_bytes: usize, dev: &DeviceParams) -> f64 {
    let b = blocks_per_sm(tb_size, shared_bytes, dev);
    (b * tb_size) as f64 / dev.max_threads_per_sm as f64
}

/// Latency-hiding efficiency as a function of occupancy: SpGEMM is
/// memory-bound with irregular access (§4.7), so achieved bandwidth rises
/// with resident warps. Saturation near full occupancy; 50%-occupancy
/// kernels (symbolic kernel7, numeric kernel6) pay ~35% throughput.
pub fn latency_hiding(occ: f64) -> f64 {
    (0.25 + 0.75 * occ).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::device::V100;

    #[test]
    fn paper_section_5_6_1_examples() {
        // kernel1: 64 threads, 512-slot 4B table + 4B counter => 32 blocks/SM
        assert_eq!(blocks_per_sm(64, 512 * 4 + 4, &V100), 32);
        assert!(occupancy(64, 512 * 4 + 4, &V100) > 0.99);
        // kernel6: 1024 threads, 48KB => 2 blocks/SM = full occupancy
        assert_eq!(blocks_per_sm(1024, 48 * 1024, &V100), 2);
        assert!(occupancy(1024, 48 * 1024, &V100) > 0.99);
        // kernel7: 96KB shared => 1 block/SM = 50% occupancy
        assert_eq!(blocks_per_sm(1024, 96 * 1024 - 4, &V100), 1);
        assert!((occupancy(1024, 96 * 1024 - 4, &V100) - 0.5).abs() < 0.01);
    }

    #[test]
    fn numeric_kernel0_example() {
        // §5.6.2: 1024 threads, 128 tables of 31*12B + 4B = 48128B => 1..2 blocks
        let shared = 128 * (31 * 12 + 4);
        let b = blocks_per_sm(1024, shared, &V100);
        assert_eq!(b, 2, "numeric kernel0 should fit 2 blocks ({shared}B)");
    }

    #[test]
    fn blocks_capped_at_32() {
        assert_eq!(blocks_per_sm(32, 0, &V100), 32);
    }

    #[test]
    fn latency_hiding_monotone() {
        assert!(latency_hiding(1.0) > latency_hiding(0.5));
        assert!(latency_hiding(0.5) > latency_hiding(0.25));
        assert!((latency_hiding(1.0) - 1.0).abs() < 1e-9);
    }
}
