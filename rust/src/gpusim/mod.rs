//! Event-driven V100 cost-model simulator.
//!
//! The paper's measurements are architectural: occupancy, shared- vs
//! global-memory atomics, hash-probe traffic, `cudaMalloc` overheads,
//! `cudaFree`'s implicit synchronization, kernel launch order, and SM load
//! balance. None of these depend on actually owning a V100 — they are
//! properties of (a) the sequence of device operations a library issues
//! and (b) a device cost model. Every SpGEMM implementation in this repo
//! therefore emits a [`trace::Trace`] of its device ops with *measured*
//! per-block work counters (bytes moved, hash probes executed on the real
//! input data, atomics issued), and this module schedules that trace
//! against the V100 model to produce a [`timeline::Timeline`].
//!
//! See DESIGN.md §2 (substitution rule) for why this preserves exactly the
//! effects the paper evaluates.

pub mod cost;
pub mod device;
pub mod multi;
pub mod occupancy;
pub mod pool;
pub mod scheduler;
pub mod timeline;
pub mod trace;

pub use device::{DeviceParams, V100};
pub use multi::{Interconnect, MultiDevice, OverlapConfig, OverlapReport, Topology, MAX_CHUNKS};
pub use pool::{DevicePool, PoolStats};
pub use scheduler::{simulate, simulate_with_arrivals};
pub use timeline::{LaneSpan, OverlapLanes, Timeline};
pub use trace::{BlockWork, Kernel, Trace, TraceOp};
