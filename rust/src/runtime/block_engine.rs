//! BSR block engine: the accelerator numeric path (DESIGN.md
//! §Hardware-Adaptation).
//!
//! The symbolic phase — which block pairs meet, and the output block
//! structure — runs in Rust using the same hash accumulator the paper's
//! GPU kernels use (over block column indices). The numeric phase batches
//! the block pairs through the AOT-compiled Pallas `block_pair_matmul`
//! kernel (fixed batch `P`, block size `T`, zero-padded tail) and
//! scatter-accumulates the products into the output BSR blocks — the Rust
//! analog of the paper's fixed hash-table-size binning.

use super::client::PjrtRuntime;
use crate::gpusim::DeviceParams;
use crate::sparse::{Bsr, Csr};
use crate::spgemm::hash_table::HashAccumulator;
use crate::spgemm::HashVariant;
use anyhow::{anyhow, ensure, Result};
use std::path::PathBuf;

/// Fraction of the device's peak FP64 throughput the dense block-matmul
/// kernel sustains in the cost model. Dense T×T tiles stream through the
/// FP64 pipes with no hash probing or bank conflicts, but padding and
/// batch edges keep it off peak.
pub const BLOCK_MXU_EFFICIENCY: f64 = 0.5;

/// One block-pair product task: `C[c_idx] += A[a_idx] @ B[b_idx]`.
#[derive(Clone, Copy, Debug)]
struct PairTask {
    a_idx: usize,
    b_idx: usize,
    c_idx: usize,
}

/// Execution statistics of one BSR multiply.
#[derive(Clone, Copy, Debug, Default)]
pub struct BlockEngineStats {
    pub pairs: usize,
    pub batches: usize,
    pub padded_pairs: usize,
    pub c_blocks: usize,
}

/// How the numeric phase of a [`BlockEngine`] executes.
enum Backend {
    /// AOT-compiled Pallas kernel through PJRT (requires `make artifacts`
    /// and the `pjrt` feature). Batches P block pairs per execute call;
    /// each pair's T-deep dot products reduce inside the kernel, so its
    /// f64 association differs from the hash pipeline's per-product
    /// accumulation — use it for throughput, not bit-comparison.
    Pjrt { runtime: PjrtRuntime, artifact: PathBuf },
    /// Pure-Rust numeric phase, always available (no artifacts, no
    /// feature flags). Accumulates every scalar product into the output
    /// block one at a time in global-k-ascending order — exactly the
    /// hash numeric kernel's association — so its results are **bitwise
    /// identical** to the hash pipeline on the same operands. This is
    /// the backend the coordinator's block route and the engines bench
    /// run on.
    Native,
}

/// BSR SpGEMM engine for one `(P, T)` variant: Rust symbolic phase (the
/// paper's hash accumulator over block columns), numeric phase on either
/// the PJRT kernel or the native bit-exact backend.
pub struct BlockEngine {
    backend: Backend,
    /// Compiled batch size.
    pub p: usize,
    /// Compiled block size.
    pub t: usize,
    pub stats: BlockEngineStats,
}

impl BlockEngine {
    /// Load the `block_matmul_p{P}_t{T}_f64` artifact from `dir`.
    pub fn load(dir: &std::path::Path, p: usize, t: usize) -> Result<Self> {
        let artifact = dir.join(format!("block_matmul_p{p}_t{t}_f64.hlo.txt"));
        ensure!(
            artifact.exists(),
            "artifact {} not found — run `make artifacts`",
            artifact.display()
        );
        let mut runtime = PjrtRuntime::cpu()?;
        runtime.load(&artifact)?;
        Ok(BlockEngine {
            backend: Backend::Pjrt { runtime, artifact },
            p,
            t,
            stats: BlockEngineStats::default(),
        })
    }

    /// The native (pure-Rust, bit-exact) engine — constructible anywhere,
    /// no artifacts or PJRT toolchain required.
    pub fn native(p: usize, t: usize) -> Result<Self> {
        ensure!(p > 0 && t > 0, "batch and block size must be positive");
        Ok(BlockEngine { backend: Backend::Native, p, t, stats: BlockEngineStats::default() })
    }

    /// Whether this engine's numeric phase matches the hash pipeline's
    /// f64 association bit-for-bit (the native backend).
    pub fn bit_exact(&self) -> bool {
        matches!(self.backend, Backend::Native)
    }

    /// Deterministic simulated execution time (ns) of the *last*
    /// multiply under `dev` — the block-engine analog of
    /// `simulate(&trace, &V100).total_ns`, in the same clock domain, so
    /// engine-tagged history entries compare hash and block apples to
    /// apples. The model: one symbolic pass probing once per *block*
    /// pair (the T²-fold symbolic reduction over the scalar hash path),
    /// one numeric kernel launch streaming `batches · P` padded dense
    /// T×T×T products at [`BLOCK_MXU_EFFICIENCY`] of peak FP64, plus
    /// HBM traffic for the operand and output blocks. Scattered
    /// matrices degenerate to ~one block per scalar nonzero and are
    /// charged T³ flops per scalar product — the model penalizes them
    /// as hard as real hardware would.
    pub fn simulated_ns(&self, dev: &DeviceParams) -> f64 {
        let s = &self.stats;
        let tt = (self.t * self.t) as f64;
        let launches = 2.0; // block-symbolic + block-numeric
        let launch_ns = launches * (dev.launch_overhead_ns + dev.launch_latency_ns);
        let sym_ns = s.pairs as f64 * dev.global_atomic_ns / dev.sms as f64;
        let padded_total = (s.batches * self.p).max(s.pairs) as f64;
        let flops = 2.0 * padded_total * tt * self.t as f64;
        let num_ns =
            flops / (dev.sms as f64 * dev.fp64_flops_per_ns * BLOCK_MXU_EFFICIENCY);
        let bytes = (2.0 * s.pairs as f64 + s.c_blocks as f64) * tt * 8.0;
        let mem_ns = bytes / dev.hbm_bytes_per_ns;
        launch_ns + sym_ns + num_ns + mem_ns
    }

    /// Symbolic phase on the block structure: output block rows + the
    /// pair task list. Uses the paper's hash accumulator over block
    /// column indices.
    fn symbolic(&self, a: &Bsr, b: &Bsr) -> (Vec<usize>, Vec<u32>, Vec<PairTask>) {
        let mut c_rpt = vec![0usize; a.brows + 1];
        let mut c_bcol: Vec<u32> = Vec::new();
        let mut tasks: Vec<PairTask> = Vec::new();
        // per-block-row map from b block col -> c block index
        let t_size = (b.bcols.max(16)).next_power_of_two();
        let mut table = HashAccumulator::new(t_size, HashVariant::SingleAccess);
        let mut local: Vec<i64> = vec![-1; b.bcols];
        let mut touched: Vec<u32> = Vec::new();
        for i in 0..a.brows {
            table.reset();
            touched.clear();
            let row_begin = c_bcol.len();
            for ai in a.rpt[i]..a.rpt[i + 1] {
                let k = a.bcol[ai] as usize;
                for bi in b.rpt[k]..b.rpt[k + 1] {
                    let j = b.bcol[bi] as usize;
                    let c_idx = if local[j] < 0 {
                        // the hash insert mirrors the GPU symbolic probe
                        let _ = table.insert_symbolic(j as u32);
                        let idx = c_bcol.len();
                        local[j] = idx as i64;
                        c_bcol.push(j as u32);
                        touched.push(j as u32);
                        idx
                    } else {
                        local[j] as usize
                    };
                    tasks.push(PairTask { a_idx: ai, b_idx: bi, c_idx });
                }
            }
            // sort block row by column; remap pending tasks
            let n_in_row = c_bcol.len() - row_begin;
            if n_in_row > 1 {
                let mut order: Vec<usize> = (0..n_in_row).collect();
                order.sort_unstable_by_key(|&x| c_bcol[row_begin + x]);
                let old: Vec<u32> = c_bcol[row_begin..].to_vec();
                let mut remap = vec![0usize; n_in_row];
                for (new_pos, &old_pos) in order.iter().enumerate() {
                    c_bcol[row_begin + new_pos] = old[old_pos];
                    remap[old_pos] = new_pos;
                }
                for t in tasks.iter_mut().rev() {
                    if t.c_idx < row_begin {
                        break;
                    }
                    t.c_idx = row_begin + remap[t.c_idx - row_begin];
                }
            }
            for &j in &touched {
                local[j as usize] = -1;
            }
            c_rpt[i + 1] = c_bcol.len();
        }
        (c_rpt, c_bcol, tasks)
    }

    /// `C = A * B` over BSR operands (must share this engine's block size).
    pub fn spgemm_bsr(&mut self, a: &Bsr, b: &Bsr) -> Result<Bsr> {
        ensure!(a.t == self.t && b.t == self.t, "block size mismatch");
        ensure!(a.cols == b.rows, "dimension mismatch");
        let tt = self.t * self.t;
        let (c_rpt, c_bcol, tasks) = self.symbolic(a, b);
        let mut c_blocks = vec![0f64; c_bcol.len() * tt];

        // batch accounting is backend-independent so the cost model sees
        // the same figures either way
        self.stats = BlockEngineStats {
            pairs: tasks.len(),
            batches: tasks.len().div_ceil(self.p),
            padded_pairs: tasks.len().div_ceil(self.p) * self.p - tasks.len(),
            c_blocks: c_bcol.len(),
        };
        match &mut self.backend {
            Backend::Pjrt { runtime, artifact } => {
                // numeric phase: batches of P pairs through the PJRT kernel
                let mut a_batch = vec![0f64; self.p * tt];
                let mut b_batch = vec![0f64; self.p * tt];
                for chunk in tasks.chunks(self.p) {
                    a_batch.fill(0.0);
                    b_batch.fill(0.0);
                    for (s, task) in chunk.iter().enumerate() {
                        a_batch[s * tt..(s + 1) * tt].copy_from_slice(a.block(task.a_idx));
                        b_batch[s * tt..(s + 1) * tt].copy_from_slice(b.block(task.b_idx));
                    }
                    let dims = [self.p, self.t, self.t];
                    let out = runtime
                        .execute_f64(artifact, &[(&a_batch, &dims), (&b_batch, &dims)])
                        .map_err(|e| anyhow!("block engine batch failed: {e:?}"))?;
                    ensure!(out.len() == self.p * tt, "unexpected output size {}", out.len());
                    for (s, task) in chunk.iter().enumerate() {
                        let dst = &mut c_blocks[task.c_idx * tt..(task.c_idx + 1) * tt];
                        let src = &out[s * tt..(s + 1) * tt];
                        for (d, &v) in dst.iter_mut().zip(src) {
                            *d += v;
                        }
                    }
                }
            }
            Backend::Native => {
                // bit-exact numeric phase: every scalar product folds into
                // its output element one at a time, tasks in list order
                // (block-k ascending) and the T-deep loop innermost, so
                // each C element accumulates its products in exactly the
                // global-k-ascending order the hash numeric kernel uses —
                // same f64 association, bitwise-identical sums. Padding
                // zeros inside partial blocks contribute ±0.0 products,
                // which never perturb a running sum's bits.
                let t_sz = self.t;
                for task in &tasks {
                    let ab = a.block(task.a_idx);
                    let bb = b.block(task.b_idx);
                    let dst = &mut c_blocks[task.c_idx * tt..(task.c_idx + 1) * tt];
                    for lr in 0..t_sz {
                        for lc in 0..t_sz {
                            let d = &mut dst[lr * t_sz + lc];
                            for k in 0..t_sz {
                                *d += ab[lr * t_sz + k] * bb[k * t_sz + lc];
                            }
                        }
                    }
                }
            }
        }

        Ok(Bsr {
            t: self.t,
            brows: a.brows,
            bcols: b.bcols,
            rows: a.rows,
            cols: b.cols,
            rpt: c_rpt,
            bcol: c_bcol,
            blocks: c_blocks,
        })
    }

    /// Convenience: CSR in, CSR out (convert, multiply, convert back).
    pub fn spgemm_csr(&mut self, a: &Csr, b: &Csr) -> Result<Csr> {
        let ab = Bsr::from_csr(a, self.t)?;
        let bb = Bsr::from_csr(b, self.t)?;
        self.spgemm_bsr(&ab, &bb)?.to_csr()
    }
}

// NOTE: PJRT integration tests live in rust/tests/integration_runtime.rs —
// they require `make artifacts` to have run. The native backend tests
// below run everywhere.

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::banded::Banded;
    use crate::gen::uniform::Uniform;
    use crate::gpusim::V100;
    use crate::spgemm::pipeline::{multiply, OpSparseConfig};
    use crate::util::rng::Rng;

    #[test]
    fn native_engine_is_bitwise_identical_to_hash_pipeline() {
        let mut rng = Rng::new(7);
        for (tag, a) in [
            (
                "banded",
                Banded { n: 96, per_row: 12, band: 10, contiguous_frac: 1.0 }.generate(&mut rng),
            ),
            ("uniform", Uniform { n: 128, per_row: 6, jitter: 3 }.generate(&mut rng)),
        ] {
            let gold = multiply(&a, &a, &OpSparseConfig::default()).unwrap();
            let mut eng = BlockEngine::native(16, 8).unwrap();
            assert!(eng.bit_exact());
            let c = eng.spgemm_csr(&a, &a).unwrap();
            assert_eq!(c, gold.c, "{tag}: native block result must match hash bitwise");
            assert!(eng.stats.pairs > 0 && eng.stats.batches > 0);
        }
    }

    #[test]
    fn native_engine_handles_non_multiple_dims_and_empty_rows() {
        let mut rng = Rng::new(11);
        // 50 is not a multiple of t=16: ragged edge blocks are padded
        let a = Uniform { n: 50, per_row: 3, jitter: 2 }.generate(&mut rng);
        let gold = multiply(&a, &a, &OpSparseConfig::default()).unwrap();
        let mut eng = BlockEngine::native(16, 16).unwrap();
        let c = eng.spgemm_csr(&a, &a).unwrap();
        assert_eq!(c, gold.c);
    }

    #[test]
    fn simulated_time_is_deterministic_and_favors_dense_blocks() {
        let mut rng = Rng::new(3);
        let blocky =
            Banded { n: 128, per_row: 16, band: 12, contiguous_frac: 1.0 }.generate(&mut rng);
        let scattered = Uniform { n: 512, per_row: 4, jitter: 300 }.generate(&mut rng);
        let mut eng = BlockEngine::native(16, 16).unwrap();
        eng.spgemm_csr(&blocky, &blocky).unwrap();
        let t_blocky = eng.simulated_ns(&V100);
        let again = {
            let mut e2 = BlockEngine::native(16, 16).unwrap();
            e2.spgemm_csr(&blocky, &blocky).unwrap();
            e2.simulated_ns(&V100)
        };
        assert_eq!(t_blocky.to_bits(), again.to_bits(), "same input, same modeled time");
        eng.spgemm_csr(&scattered, &scattered).unwrap();
        let t_scattered = eng.simulated_ns(&V100);
        assert!(t_blocky.is_finite() && t_blocky > 0.0);
        assert!(
            t_scattered > t_blocky,
            "scattered structure must cost more per the block model \
             ({t_scattered:.0} vs {t_blocky:.0} ns)"
        );
    }
}
