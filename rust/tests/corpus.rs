//! Corpus integration tests: the checked-in Matrix Market fixtures load,
//! each one routes exactly where the provenance table in ARCHITECTURE.md
//! says it should, and the full corpus run is bit-identical across the
//! unsharded / sharded / serve paths.
//!
//! The route pins are deliberately table-driven *by fixture name*: adding
//! a matrix to `rust/corpus/` without adding a row here fails loudly, so
//! the routing contract stays documented next to the corpus itself.

use opsparse::bench::corpus::{
    self, load_corpus, resolve_corpus_dir, run_corpus, synthesized_entries, MIN_REAL_FIXTURES,
};
use opsparse::coordinator::{Route, Router};

/// Expected router decision per checked-in fixture, keyed by file stem.
/// Dense-block FEM-like matrices take the block engine; everything else
/// in the small-fixture corpus stays on the hash pipeline (they all fit
/// the 256 KiB corpus budget, so nothing shards).
const ROUTE_PINS: &[(&str, &str)] = &[
    ("band_wide_cage_like", "Hash"),
    ("blocky_bsr_like", "Block"),
    ("diag_dominant_jacobi", "Hash"),
    ("fem_cant_like", "Block"),
    ("fem_ship_like", "Block"),
    ("int_econ_like", "Hash"),
    ("pattern_road_like", "Hash"),
    ("power_patents_like", "Hash"),
    ("power_web_like", "Hash"),
    ("skew_circuit_like", "Hash"),
    ("stencil_lap2d_like", "Hash"),
    ("tridiag_near_diag", "Hash"),
];

#[test]
fn corpus_has_enough_real_fixtures() {
    let dir = resolve_corpus_dir(None);
    let entries = load_corpus(&dir).expect("load corpus");
    assert!(
        entries.len() >= MIN_REAL_FIXTURES,
        "corpus at {} holds {} fixtures, need at least {}",
        dir.display(),
        entries.len(),
        MIN_REAL_FIXTURES
    );
    for e in &entries {
        assert_eq!(e.source, "fixture");
        assert_eq!(e.a.rows, e.a.cols, "{}: corpus matrices are square", e.name);
        assert!(e.a.nnz() > 0, "{}: empty fixture", e.name);
    }
}

#[test]
fn every_fixture_routes_as_pinned() {
    let dir = resolve_corpus_dir(None);
    let entries = load_corpus(&dir).expect("load corpus");
    let router = Router::new(corpus::corpus_router_config());
    for e in &entries {
        let expected = ROUTE_PINS
            .iter()
            .find(|(name, _)| *name == e.name)
            .unwrap_or_else(|| {
                panic!(
                    "fixture {} has no route pin — add it to ROUTE_PINS (and to the \
                     provenance table in ARCHITECTURE.md)",
                    e.name
                )
            })
            .1;
        let route = router.route(&e.a, &e.a);
        let got = corpus::route_label(&route);
        assert_eq!(
            got, expected,
            "{}: router chose {} but the pin table says {}",
            e.name, got, expected
        );
    }
    // and the pin table must not reference fixtures that no longer exist
    for (name, _) in ROUTE_PINS {
        assert!(
            entries.iter().any(|e| e.name == *name),
            "route pin for {name} references a missing fixture"
        );
    }
}

#[test]
fn synthesized_large_regimes_route_to_sharded() {
    let router = Router::new(corpus::corpus_router_config());
    for e in synthesized_entries().expect("synthesized entries") {
        assert_eq!(e.source, "synthesized");
        let route = router.route(&e.a, &e.a);
        assert!(
            matches!(route, Route::Sharded { n_devices } if n_devices >= 2),
            "{}: synthesized regime must exceed the 256 KiB corpus budget and \
             shard, got {route:?}",
            e.name
        );
    }
}

#[test]
fn full_corpus_run_is_bit_identical_everywhere() {
    let dir = resolve_corpus_dir(None);
    let report = run_corpus(&dir).expect("run corpus");
    assert!(report.fixtures >= MIN_REAL_FIXTURES);
    assert_eq!(report.rows.len(), report.fixtures + report.synthesized);
    assert!(
        report.all_bit_identical,
        "a corpus matrix diverged across unsharded/sharded/serve/mmio paths"
    );
    for r in &report.rows {
        assert!(r.bit_identical_sharded, "{}: sharded stitch diverged", r.name);
        assert!(r.bit_identical_serve, "{}: serve path diverged", r.name);
        assert!(r.mmio_roundtrip, "{}: mmio round trip not bit-identical", r.name);
        assert!(
            r.speedup_vs_cusparse.is_finite() && r.speedup_vs_cusparse > 0.0,
            "{}: degenerate speedup {}",
            r.name,
            r.speedup_vs_cusparse
        );
        assert_eq!(
            r.bin_occupancy.iter().sum::<usize>(),
            r.rows,
            "{}: every row lands in exactly one symbolic bin",
            r.name
        );
    }
}
