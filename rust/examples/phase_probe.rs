//! §Perf probe: per-phase wall breakdown of multiply() (not part of the
//! public API surface; used by the EXPERIMENTS.md §Perf log).
use opsparse::gen::suite::{suite_entry, SuiteScale};
use opsparse::sparse::stats::nprod_per_row;
use opsparse::spgemm::binning::bin_rows;
use opsparse::spgemm::kernel_tables::{NumericRanges, SymbolicRanges};
use opsparse::spgemm::numeric::numeric_step;
use opsparse::spgemm::symbolic::symbolic_step;
use opsparse::spgemm::HashVariant;
use opsparse::util::exclusive_sum;
use std::time::Instant;

fn main() {
    let name = std::env::args().nth(1).unwrap_or_else(|| "webbase-1M".into());
    let a = suite_entry(&name).unwrap().generate(SuiteScale::Small);
    let t0 = Instant::now();
    let nprod = nprod_per_row(&a, &a);
    let t_nprod = t0.elapsed();
    let t0 = Instant::now();
    let sb = bin_rows(&nprod, &SymbolicRanges::Sym12x.ranges());
    let t_sbin = t0.elapsed();
    let t0 = Instant::now();
    let sym = symbolic_step(&a, &a, &sb, HashVariant::SingleAccess, "symbolic", 4);
    let t_sym = t0.elapsed();
    let t0 = Instant::now();
    let c_rpt = exclusive_sum(&sym.row_nnz);
    let nb = bin_rows(&sym.row_nnz, &NumericRanges::Num2x.ranges());
    let t_nbin = t0.elapsed();
    let t0 = Instant::now();
    let num = numeric_step(&a, &a, &c_rpt, &nb, HashVariant::SingleAccess, "numeric", 4);
    let t_num = t0.elapsed();
    println!("{name}: nprod {t_nprod:?} symbin {t_sbin:?} symbolic {t_sym:?} numbin {t_nbin:?} numeric {t_num:?} (nnzC {})", num.c.nnz());
}
