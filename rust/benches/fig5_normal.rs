//! `cargo bench --bench fig5_normal` — regenerates paper Figure 5:
//! SpGEMM GFLOPS of cuSPARSE/nsparse/spECK/OpSparse on the 19 normal
//! matrices (simulated V100; outputs verified against the reference).

use opsparse::bench::figures;
use opsparse::gen::suite::SuiteScale;

fn main() {
    let scale = scale_from_env();
    figures::fig5(scale, true).expect("fig5");
}

fn scale_from_env() -> SuiteScale {
    std::env::var("OPSPARSE_SCALE")
        .ok()
        .and_then(|s| SuiteScale::parse(&s))
        .unwrap_or(SuiteScale::Small)
}
