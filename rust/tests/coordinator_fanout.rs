//! Cross-worker shard fan-out must change *where* shards run and nothing
//! else.
//!
//! Matrix of properties across the generator families (uniform,
//! power-law, stencil, kron), shard counts 1/2/4/8, and worker counts
//! {1, 2, shards_max + 1}: a sharded job submitted to the coordinator —
//! whose shards are schedulable sub-jobs spread over the worker pool and
//! reassembled by a barrier — returns a CSR bit-identical (`rpt`/`col`/
//! `val`) to both the in-worker `multiply_sharded` fan-out and the
//! unsharded `multiply`. Includes the empty-row-shard edge cases from
//! `tests/sharded.rs`, driven through the coordinator.

use opsparse::coordinator::{Coordinator, Job, Route, Router};
use opsparse::gen::kron::Kron;
use opsparse::gen::powerlaw::PowerLaw;
use opsparse::gen::stencil::{Grid, Stencil};
use opsparse::gen::uniform::Uniform;
use opsparse::sparse::Csr;
use opsparse::spgemm::pipeline::{multiply, OpSparseConfig};
use opsparse::spgemm::sharded::multiply_sharded;
use opsparse::util::rng::Rng;
use std::collections::HashMap;

const SHARD_COUNTS: [usize; 4] = [1, 2, 4, 8];

/// One representative per generator family (the `tests/sharded.rs` set).
fn family_matrices() -> Vec<(&'static str, Csr)> {
    let mut rng = Rng::new(2077);
    vec![
        ("uniform", Uniform { n: 400, per_row: 8, jitter: 4 }.generate(&mut rng)),
        (
            "powerlaw",
            PowerLaw {
                n: 500,
                alpha: 2.0,
                max_row: 60,
                mean_row: 4.0,
                hub_frac: 0.2,
                forced_giant_rows: 1,
            }
            .generate(&mut rng),
        ),
        (
            "stencil",
            Stencil { n: 400, grid: Grid::D2, reach: 1, keep: 1.0, diagonal: true }
                .generate(&mut rng),
        ),
        ("kron", Kron { scale: 8, edge_factor: 8, a: 0.57, b: 0.19, c: 0.19 }.generate(&mut rng)),
    ]
}

#[test]
fn cross_worker_fanout_matches_in_worker_and_unsharded() {
    let cfg = OpSparseConfig::default();
    let families = family_matrices();

    // unsharded golds, and the in-worker fan-out cross-checked once
    let golds: Vec<(Csr, usize)> = families
        .iter()
        .map(|(name, a)| {
            let out = multiply(a, a, &cfg)
                .unwrap_or_else(|e| panic!("unsharded multiply failed on {name}: {e:#}"));
            (out.c, out.nprod)
        })
        .collect();
    for (f, (name, a)) in families.iter().enumerate() {
        for shards in SHARD_COUNTS {
            let inw = multiply_sharded(a, a, &cfg, shards)
                .unwrap_or_else(|e| panic!("{name}: in-worker {shards}-shard failed: {e:#}"));
            assert_eq!(inw.c, golds[f].0, "{name}: in-worker {shards}-shard diverged");
        }
    }

    // the cross-worker path, at every worker count
    for n_workers in [1usize, 2, SHARD_COUNTS[3] + 1] {
        let coord = Coordinator::start(n_workers, Router::default(), None);
        let mut expected: HashMap<u64, (usize, usize)> = HashMap::new();
        let mut id = 0u64;
        for (f, (_, a)) in families.iter().enumerate() {
            for shards in SHARD_COUNTS {
                coord.submit(Job {
                    id,
                    a: a.clone(),
                    b: a.clone(),
                    force_route: Some(Route::Sharded { n_devices: shards }),
                });
                expected.insert(id, (f, shards));
                id += 1;
            }
        }
        for _ in 0..id {
            let r = coord.recv().expect("coordinator alive");
            let (f, shards) = expected[&r.id];
            let name = families[f].0;
            assert_eq!(r.route, Route::Sharded { n_devices: shards }, "{name}");
            let c = r.c.unwrap_or_else(|e| {
                panic!("{name}: {shards} shards on {n_workers} workers failed: {e:#}")
            });
            assert_eq!(
                c, golds[f].0,
                "{name}: {shards} shards on {n_workers} workers diverged from unsharded"
            );
            assert_eq!(r.nprod, golds[f].1, "{name}: nprod must be preserved");
        }
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.sharded_routed, id);
        assert_eq!(snap.jobs_completed, id);
        assert_eq!(snap.jobs_failed, 0);
        let subjobs: usize = SHARD_COUNTS.iter().sum::<usize>() * families.len();
        assert_eq!(snap.shard_subjobs as usize, subjobs, "every sub-job accounted");
        if n_workers == 1 {
            assert_eq!(snap.shard_workers, 1);
        } else {
            assert!(
                snap.shard_workers >= 2,
                "{n_workers} workers: shards must spread over the pool, got {}",
                snap.shard_workers
            );
        }
        coord.shutdown();
    }
}

#[test]
fn empty_row_shards_reassemble_through_the_coordinator() {
    // the tests/sharded.rs edge cases, driven through the sub-job path:
    // more shards than rows (trailing empty shards) and an all-zero
    // matrix must stitch cleanly and bit-identically
    let cfg = OpSparseConfig::default();
    let mut rng = Rng::new(3001);
    let a = Uniform { n: 5, per_row: 3, jitter: 1 }.generate(&mut rng);
    let gold = multiply(&a, &a, &cfg).unwrap();
    let coord = Coordinator::start(2, Router::default(), None);
    coord.submit(Job {
        id: 0,
        a: a.clone(),
        b: a.clone(),
        force_route: Some(Route::Sharded { n_devices: 8 }),
    });
    let z = Csr::zero(10, 10);
    coord.submit(Job {
        id: 1,
        a: z.clone(),
        b: z,
        force_route: Some(Route::Sharded { n_devices: 4 }),
    });
    for _ in 0..2 {
        let r = coord.recv().unwrap();
        match r.id {
            0 => assert_eq!(r.c.unwrap(), gold.c, "5 rows over 8 shards must stitch exactly"),
            1 => {
                let c = r.c.unwrap();
                assert_eq!((c.rows, c.cols, c.nnz()), (10, 10, 0));
                c.validate().unwrap();
            }
            other => panic!("unexpected job id {other}"),
        }
    }
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.shard_subjobs, 12, "empty shards still execute as sub-jobs");
    assert_eq!(snap.jobs_completed, 2);
    coord.shutdown();
}

#[test]
fn one_row_per_shard_through_the_coordinator() {
    let cfg = OpSparseConfig::default();
    let a = Csr::identity(16);
    let gold = multiply(&a, &a, &cfg).unwrap();
    let coord = Coordinator::start(3, Router::default(), None);
    coord.submit(Job {
        id: 0,
        a: a.clone(),
        b: a,
        force_route: Some(Route::Sharded { n_devices: 16 }),
    });
    let r = coord.recv().unwrap();
    assert_eq!(r.c.unwrap(), gold.c);
    assert_eq!(coord.metrics.snapshot().shard_subjobs, 16);
    coord.shutdown();
}
