//! Minimal property-testing harness (offline build: no `proptest`).
//!
//! `check(name, cases, gen, prop)` runs `prop` on `cases` generated inputs
//! from independent seeds; on failure it retries the failing seed with a
//! sequence of "shrink" attempts produced by the generator itself (the
//! generator receives a `size` knob that the harness lowers on failure),
//! then panics with the seed + size so the case is reproducible.

use super::rng::Rng;

/// Run a property over `cases` random inputs. `make` builds an input from
/// `(rng, size)`; `prop` returns `Err(msg)` on violation.
pub fn check<T, F, P>(name: &str, cases: usize, base_size: usize, mut make: F, mut prop: P)
where
    T: std::fmt::Debug,
    F: FnMut(&mut Rng, usize) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0x5EED_0000u64 + case as u64;
        let mut rng = Rng::new(seed);
        let input = make(&mut rng, base_size);
        if let Err(msg) = prop(&input) {
            // try shrinking: regenerate at smaller sizes with the same seed
            let mut smallest: Option<(usize, T, String)> = None;
            let mut size = base_size / 2;
            while size >= 1 {
                let mut srng = Rng::new(seed);
                let small = make(&mut srng, size);
                if let Err(smsg) = prop(&small) {
                    smallest = Some((size, small, smsg));
                    size /= 2;
                } else {
                    break;
                }
            }
            match smallest {
                Some((ssize, sinput, smsg)) => panic!(
                    "property `{name}` failed (seed={seed:#x}, shrunk size={ssize}):\n  {smsg}\n  input: {sinput:?}"
                ),
                None => panic!(
                    "property `{name}` failed (seed={seed:#x}, size={base_size}):\n  {msg}\n  input: {input:?}"
                ),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check(
            "count",
            32,
            10,
            |rng, size| rng.range(0, size.max(1)),
            |_| {
                n += 1;
                Ok(())
            },
        );
        assert_eq!(n, 32);
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn failing_property_panics_with_seed() {
        check(
            "always-fails",
            4,
            8,
            |rng, _| rng.next_u64(),
            |_| Err("nope".into()),
        );
    }
}
