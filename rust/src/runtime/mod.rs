//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//! Python never runs at request time — the artifacts are self-contained
//! HLO text, compiled once per process by the PJRT CPU client.

pub mod block_engine;
pub mod client;
pub mod row_engine;

pub use block_engine::BlockEngine;
pub use client::{pjrt_compiled, PjrtRuntime};
pub use row_engine::RowWindowEngine;

use std::path::{Path, PathBuf};

/// Default artifacts directory (workspace-relative).
pub fn default_artifacts_dir() -> PathBuf {
    // prefer CWD/artifacts; fall back to the crate root
    let cwd = PathBuf::from("artifacts");
    if cwd.exists() {
        return cwd;
    }
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// True if artifacts have been built (`make artifacts`).
pub fn artifacts_available() -> bool {
    default_artifacts_dir().join("manifest.json").exists()
}
