//! Human-readable formatting helpers used by the CLI, benches, and reports.

/// Format a nanosecond duration as an adaptive human string.
pub fn ns(t: f64) -> String {
    if t < 1e3 {
        format!("{t:.0}ns")
    } else if t < 1e6 {
        format!("{:.2}us", t / 1e3)
    } else if t < 1e9 {
        format!("{:.3}ms", t / 1e6)
    } else {
        format!("{:.3}s", t / 1e9)
    }
}

/// Format a byte count (binary units).
pub fn bytes(b: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b}B")
    } else {
        format!("{v:.2}{}", UNITS[u])
    }
}

/// Format GFLOPS with 2 decimals.
pub fn gflops(flops: f64, time_ns: f64) -> f64 {
    if time_ns <= 0.0 {
        return 0.0;
    }
    flops / time_ns
}

/// Format a count with thousands separators (1,234,567).
pub fn count(n: usize) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ns_units() {
        assert_eq!(ns(500.0), "500ns");
        assert_eq!(ns(1500.0), "1.50us");
        assert_eq!(ns(2.5e6), "2.500ms");
        assert_eq!(ns(3.2e9), "3.200s");
    }

    #[test]
    fn bytes_units() {
        assert_eq!(bytes(512), "512B");
        assert_eq!(bytes(4 * 1024 * 1024), "4.00MiB");
    }

    #[test]
    fn count_separators() {
        assert_eq!(count(5), "5");
        assert_eq!(count(1234), "1,234");
        assert_eq!(count(1234567), "1,234,567");
    }
}
