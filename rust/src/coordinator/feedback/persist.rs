//! Warm-start persistence: the serving layer's execution history and
//! `ns_per_prod` fit, saved on shutdown and reloaded on start.
//!
//! Everything the feedback layer learns (PR 5) is a function of sparsity
//! patterns and the device model — none of it expires with the process —
//! yet until this module a restart forgot it all and the first job of
//! every pattern was planned cold again. The serving front door
//! ([`crate::coordinator::serve`]) saves this state when it shuts down
//! and reloads it when it starts, so the first post-restart submit of a
//! warm pattern is re-cut from measured timings exactly like the last
//! pre-restart one.
//!
//! The format is a versioned line-oriented text file with **every `f64`
//! stored as its IEEE-754 bit pattern in hex** — decimal formatting
//! would round, and the acceptance bar is a *bit-stable* round trip:
//! restored EWMA wall times, shard timings, and the fit constant compare
//! bitwise equal, so a reloaded router makes byte-for-byte the same
//! decisions the pre-restart one did. No serde in the dependency set;
//! the hand-rolled reader rejects unknown versions and malformed lines
//! loudly instead of planning from half-parsed state.

use super::history::{Engine, EngineStats, ExecHistory, PatternStats};
use super::refit::NsPerProdFit;
use crate::coordinator::cache::PatternKey;
use crate::spgemm::sharded::MeasuredShard;
use anyhow::{bail, Context, Result};

/// First line of every state file; the version bumps on layout changes.
/// v2 added per-engine `engine <hash|block> <runs> <ewma_hex>` lines
/// under each pattern (the multi-engine dispatch history).
pub const STATE_HEADER: &str = "opsparse-serve-state v2";

/// The pre-engine-tag layout. Still loads: every pattern in a v1 file
/// predates the block-engine recording path, so its whole run history is
/// re-tagged as hash measurements (logged once on load) — an upgraded
/// server restarts warm instead of refusing to serve.
pub const STATE_HEADER_V1: &str = "opsparse-serve-state v1";

/// Parsed contents of a state file: the fit snapshot plus the history's
/// patterns in insertion (eviction) order.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PersistedState {
    /// `ns_per_prod` fit constant (restored bitwise).
    pub fit_k: f64,
    /// Observations the fit had folded in.
    pub fit_updates: u64,
    /// Pattern stats, oldest-first — feed to
    /// [`ExecHistory::insert_stats`] in order.
    pub patterns: Vec<(PatternKey, PatternStats)>,
}

impl PersistedState {
    /// Snapshot live serving state for saving.
    pub fn capture(history: &ExecHistory, fit: &NsPerProdFit) -> PersistedState {
        let (fit_k, fit_updates) = fit.state();
        PersistedState {
            fit_k,
            fit_updates,
            patterns: history.iter_in_order().map(|(k, s)| (*k, s.clone())).collect(),
        }
    }

    /// Rebuild the fit this snapshot describes.
    pub fn restore_fit(&self) -> NsPerProdFit {
        NsPerProdFit::from_state(self.fit_k, self.fit_updates)
    }

    /// Replay the snapshot's patterns into `history` (oldest-first, so
    /// FIFO eviction order carries over; a smaller-capacity history
    /// evicts the oldest entries during the replay).
    pub fn restore_history(&self, history: &mut ExecHistory) {
        for (key, stats) in &self.patterns {
            history.insert_stats(*key, stats.clone());
        }
    }
}

fn render(state: &PersistedState) -> String {
    let mut out = String::new();
    out.push_str(STATE_HEADER);
    out.push('\n');
    out.push_str(&format!("fit {:016x} {}\n", state.fit_k.to_bits(), state.fit_updates));
    for (key, s) in &state.patterns {
        out.push_str(&format!(
            "pattern {:016x} {:016x} {} {:016x} {} {}\n",
            key.0,
            key.1,
            s.runs,
            s.ewma_wall_ns.to_bits(),
            s.last_nprod,
            s.chunk_bytes.map(|b| b.to_string()).unwrap_or_else(|| "-".to_string()),
        ));
        for m in &s.measured {
            out.push_str(&format!("shard {} {} {:016x}\n", m.lo, m.hi, m.ns.to_bits()));
        }
        for engine in [Engine::Hash, Engine::Block] {
            let es = s.engine(engine);
            if es.runs > 0 || es.ewma_ns != 0.0 {
                out.push_str(&format!(
                    "engine {} {} {:016x}\n",
                    engine.label(),
                    es.runs,
                    es.ewma_ns.to_bits()
                ));
            }
        }
    }
    out
}

/// Write `state` to `path` (atomically enough for a single writer: the
/// whole file in one `fs::write`).
pub fn save_state(path: &str, state: &PersistedState) -> Result<()> {
    std::fs::write(path, render(state))
        .with_context(|| format!("writing serve state to {path}"))
}

fn parse_hex_bits(s: &str, what: &str) -> Result<u64> {
    u64::from_str_radix(s, 16).with_context(|| format!("bad hex {what}: {s:?}"))
}

fn parse_state(text: &str, path: &str) -> Result<(PersistedState, bool)> {
    let mut lines = text.lines();
    let legacy = match lines.next() {
        Some(h) if h == STATE_HEADER => false,
        Some(h) if h == STATE_HEADER_V1 => true,
        Some(h) => bail!("{path}: unsupported state header {h:?} (want {STATE_HEADER:?})"),
        None => bail!("{path}: empty state file"),
    };
    let mut state = PersistedState::default();
    let mut saw_fit = false;
    for (lineno, line) in lines.enumerate() {
        let lineno = lineno + 2; // 1-based, after the header
        let fields: Vec<&str> = line.split_whitespace().collect();
        match fields[..] {
            [] => {}
            ["fit", k, updates] => {
                state.fit_k = f64::from_bits(parse_hex_bits(k, "fit constant")?);
                state.fit_updates =
                    updates.parse().with_context(|| format!("{path}:{lineno}: bad fit updates"))?;
                saw_fit = true;
            }
            ["pattern", a_fp, b_fp, runs, ewma, nprod, chunk] => {
                let key: PatternKey = (
                    parse_hex_bits(a_fp, "pattern fingerprint")?,
                    parse_hex_bits(b_fp, "pattern fingerprint")?,
                );
                let stats = PatternStats {
                    runs: runs
                        .parse()
                        .with_context(|| format!("{path}:{lineno}: bad run count"))?,
                    ewma_wall_ns: f64::from_bits(parse_hex_bits(ewma, "ewma wall ns")?),
                    last_nprod: nprod
                        .parse()
                        .with_context(|| format!("{path}:{lineno}: bad nprod"))?,
                    chunk_bytes: match chunk {
                        "-" => None,
                        c => Some(
                            c.parse()
                                .with_context(|| format!("{path}:{lineno}: bad chunk bytes"))?,
                        ),
                    },
                    ..Default::default()
                };
                state.patterns.push((key, stats));
            }
            ["shard", lo, hi, ns] => {
                let Some((_, stats)) = state.patterns.last_mut() else {
                    bail!("{path}:{lineno}: shard line before any pattern line");
                };
                stats.measured.push(MeasuredShard {
                    lo: lo.parse().with_context(|| format!("{path}:{lineno}: bad shard lo"))?,
                    hi: hi.parse().with_context(|| format!("{path}:{lineno}: bad shard hi"))?,
                    ns: f64::from_bits(parse_hex_bits(ns, "shard ns")?),
                });
            }
            ["engine", name, runs, ewma] => {
                if legacy {
                    bail!("{path}:{lineno}: engine line in a v1 state file");
                }
                let Some((_, stats)) = state.patterns.last_mut() else {
                    bail!("{path}:{lineno}: engine line before any pattern line");
                };
                let engine = Engine::parse(name)
                    .with_context(|| format!("{path}:{lineno}: unknown engine {name:?}"))?;
                *stats.engine_mut(engine) = EngineStats {
                    runs: runs
                        .parse()
                        .with_context(|| format!("{path}:{lineno}: bad engine run count"))?,
                    ewma_ns: f64::from_bits(parse_hex_bits(ewma, "engine ewma ns")?),
                };
            }
            _ => bail!("{path}:{lineno}: unrecognized state line {line:?}"),
        }
    }
    if !saw_fit {
        bail!("{path}: state file has no fit line");
    }
    if legacy {
        // pre-engine-tag file: everything it recorded ran on the hash
        // pipeline, so its run history re-tags as hash measurements
        for (_, stats) in &mut state.patterns {
            stats.hash = EngineStats { runs: stats.runs, ewma_ns: stats.ewma_wall_ns };
        }
    }
    Ok((state, legacy))
}

/// Read a state file written by [`save_state`]. Malformed content is an
/// error — a serving process must not come up half-warm from a file it
/// misread — but a *missing* file is the ordinary cold start, which
/// callers detect with [`std::path::Path::exists`] before calling this.
pub fn load_state(path: &str) -> Result<PersistedState> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading serve state {path}"))?;
    let (state, legacy) = parse_state(&text, path)?;
    if legacy {
        eprintln!(
            "serve: {path} is a {STATE_HEADER_V1:?} state file; loading its {} pattern(s) \
             as hash-tagged history (it will be rewritten as {STATE_HEADER:?} on shutdown)",
            state.patterns.len()
        );
    }
    Ok(state)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_state() -> PersistedState {
        let fit = NsPerProdFit::new(1.0);
        for i in 1..=9u64 {
            fit.observe(700.0 * i as f64, 200 * i);
        }
        let mut h = ExecHistory::new(8);
        let mut hist_obs = |key: PatternKey, ns: f64| {
            h.record(
                key,
                super::super::history::RunObservation {
                    shards: vec![
                        MeasuredShard { lo: 0, hi: 7, ns },
                        MeasuredShard { lo: 7, hi: 16, ns: ns * 1.5 },
                    ],
                    wall_ns: ns * 3.0,
                    nprod: 1234,
                    engine_ns: ns * 2.0,
                    ..Default::default()
                },
            );
        };
        hist_obs((11, 22), 1000.0);
        hist_obs((33, 44), 2000.0);
        hist_obs((11, 22), 1500.0); // fold a second run: non-trivial EWMA bits
        // a block-engine run on one pattern: engine lines must round-trip
        h.record(
            (33, 44),
            super::super::history::RunObservation {
                engine: Engine::Block,
                engine_ns: 777.5,
                wall_ns: 900.0,
                nprod: 1234,
                ..Default::default()
            },
        );
        PersistedState::capture(&h, &fit)
    }

    fn tmp_path(tag: &str) -> String {
        std::env::temp_dir()
            .join(format!("opsparse-persist-{tag}-{}.state", std::process::id()))
            .to_string_lossy()
            .into_owned()
    }

    #[test]
    fn round_trip_is_bit_stable() {
        let state = sample_state();
        let path = tmp_path("roundtrip");
        save_state(&path, &state).unwrap();
        let loaded = load_state(&path).unwrap();
        std::fs::remove_file(&path).ok();
        // PartialEq on f64 is exact equality, so this asserts the bits
        assert_eq!(loaded, state);
        assert_eq!(loaded.fit_k.to_bits(), state.fit_k.to_bits());
        let (a, b) = (&loaded.patterns, &state.patterns);
        assert_eq!(a.len(), 2, "insertion order and occupancy preserved");
        assert_eq!(a[0].0, (11, 22), "oldest pattern first");
        assert_eq!(
            a[0].1.ewma_wall_ns.to_bits(),
            b[0].1.ewma_wall_ns.to_bits(),
            "EWMA restored bitwise"
        );
        assert_eq!(a[0].1.measured, b[0].1.measured, "shard timings restored exactly");
        assert_eq!(
            a[1].1.block.ewma_ns.to_bits(),
            b[1].1.block.ewma_ns.to_bits(),
            "per-engine EWMA restored bitwise"
        );
        assert_eq!(a[1].1.block.runs, 1);
        assert_eq!(a[0].1.hash.runs, 2);
    }

    #[test]
    fn v1_state_file_loads_as_hash_tagged() {
        let path = tmp_path("v1compat");
        let ewma = 1234.5f64;
        std::fs::write(
            &path,
            format!(
                "{STATE_HEADER_V1}\nfit {:016x} 3\npattern {:016x} {:016x} 5 {:016x} 42 -\n\
                 shard 0 8 {:016x}\n",
                1.25f64.to_bits(),
                7u64,
                9u64,
                ewma.to_bits(),
                600.0f64.to_bits()
            ),
        )
        .unwrap();
        let loaded = load_state(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(loaded.patterns.len(), 1);
        let (key, s) = &loaded.patterns[0];
        assert_eq!(*key, (7, 9));
        assert_eq!(s.runs, 5);
        assert_eq!(s.hash.runs, 5, "v1 history re-tags as hash");
        assert_eq!(s.hash.ewma_ns.to_bits(), ewma.to_bits());
        assert_eq!(s.block, EngineStats::default(), "block side starts cold");
        assert_eq!(s.measured.len(), 1, "shard lines still restore");
        // an engine line inside a v1 file is malformed, not silently read
        std::fs::write(
            &path,
            format!(
                "{STATE_HEADER_V1}\nfit 0 0\npattern 1 1 1 0 0 -\nengine hash 1 0\n"
            ),
        )
        .unwrap();
        assert!(load_state(&path).unwrap_err().to_string().contains("v1 state file"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn restore_rebuilds_history_and_fit_exactly() {
        let state = sample_state();
        let mut h = ExecHistory::new(8);
        state.restore_history(&mut h);
        assert_eq!(h.len(), 2);
        let s = h.lookup((11, 22)).unwrap();
        assert_eq!(s.runs, 2);
        assert_eq!(s.measured.len(), 2);
        let fit = state.restore_fit();
        assert_eq!(fit.state().0.to_bits(), state.fit_k.to_bits());
        assert_eq!(fit.updates(), state.fit_updates);
    }

    #[test]
    fn missing_fit_unknown_header_and_junk_lines_are_rejected() {
        let path = tmp_path("malformed");
        std::fs::write(&path, "opsparse-serve-state v99\n").unwrap();
        assert!(load_state(&path).unwrap_err().to_string().contains("unsupported"));
        std::fs::write(&path, format!("{STATE_HEADER}\n")).unwrap();
        assert!(load_state(&path).unwrap_err().to_string().contains("no fit line"));
        std::fs::write(&path, format!("{STATE_HEADER}\nfit 0 0\nwat 1 2\n")).unwrap();
        assert!(load_state(&path).unwrap_err().to_string().contains("unrecognized"));
        std::fs::write(
            &path,
            format!("{STATE_HEADER}\nfit 0 0\nshard 0 4 {:016x}\n", 1.0f64.to_bits()),
        )
        .unwrap();
        assert!(load_state(&path)
            .unwrap_err()
            .to_string()
            .contains("before any pattern"));
        std::fs::remove_file(&path).ok();
        // a missing file is an error too (callers gate on exists())
        assert!(load_state(&tmp_path("absent")).is_err());
    }
}
