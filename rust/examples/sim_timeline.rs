//! The paper's §6.3.4 + §6.3.5 case studies on the webbase-1M stand-in:
//! SM load balance around the giant-row global-table kernel, and the
//! malloc-behind-kernel overlap — rendered as a timeline Gantt.
//!
//! Run: `cargo run --release --example sim_timeline [tiny|small|medium]`

use opsparse::gen::suite::{suite_entry, SuiteScale};
use opsparse::gpusim::{simulate, V100};
use opsparse::spgemm::pipeline::{multiply, OpSparseConfig};
use opsparse::util::fmt;

fn main() -> anyhow::Result<()> {
    let scale = std::env::args()
        .nth(1)
        .and_then(|s| SuiteScale::parse(&s))
        .unwrap_or(SuiteScale::Small);
    let a = suite_entry("webbase-1M").unwrap().generate(scale);
    println!(
        "webbase-1M stand-in ({scale:?}): {}x{}, nnz {}, max row {}",
        a.rows,
        a.cols,
        fmt::count(a.nnz()),
        a.max_row_nnz()
    );

    // --- with all optimizations (OpSparse) ---
    let opt = multiply(&a, &a, &OpSparseConfig::default())?;
    let tl_opt = simulate(&opt.trace, &V100);

    // --- §6.3.4: eager free + no overlap (the nsparse behaviour) ---
    let mut bad = OpSparseConfig::default();
    bad.deferred_free = false;
    bad.overlap_malloc = false;
    let unopt = multiply(&a, &a, &bad)?;
    let tl_bad = simulate(&unopt.trace, &V100);

    println!("\n-- §6.3.4 SM load balance --");
    let giant = tl_opt
        .kernels
        .iter()
        .filter(|k| k.name.contains("global") && k.end.is_finite())
        .map(|k| (k.name.clone(), k.end - k.start))
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    match &giant {
        Some((name, dur)) => println!("  largest-row kernel {name}: {}", fmt::ns(*dur)),
        None => println!("  (no global-table kernel at this scale)"),
    }
    println!(
        "  numeric wall {} vs sum-of-kernels {} (overlap hides the rest behind the giant)",
        fmt::ns(tl_opt.step_ns("numeric")),
        fmt::ns(tl_opt.step_kernel_sum_ns("numeric"))
    );
    println!("  SM imbalance (max/mean busy): {:.2}", tl_opt.sm_imbalance());

    println!("\n-- §6.3.5 malloc / kernel overlap --");
    for h in &tl_opt.host {
        if h.what.starts_with("cudaMalloc(num_global_table") {
            println!(
                "  optimized: global-table malloc {} issued at {} (kernels already running)",
                fmt::ns(h.end - h.start),
                fmt::ns(h.start)
            );
        }
    }
    println!(
        "  total: optimized {} vs eager-free/no-overlap {}  ({:.2}x)",
        fmt::ns(tl_opt.total_ns),
        fmt::ns(tl_bad.total_ns),
        tl_bad.total_ns / tl_opt.total_ns
    );

    println!("\n-- optimized timeline --\n{}", tl_opt.render_gantt(110));
    println!("-- unoptimized timeline --\n{}", tl_bad.render_gantt(110));
    Ok(())
}
