//! Thin wrapper over the `xla` crate's PJRT CPU client: load HLO text,
//! compile once, cache the executable, execute with f64 buffers.
//!
//! Interchange is HLO *text* — the crate's xla_extension 0.5.1 rejects
//! serialized protos from jax >= 0.5 (64-bit instruction ids); the text
//! parser reassigns ids (see /opt/xla-example/README.md and
//! python/compile/aot.py).
//!
//! The real client requires the `pjrt` cargo feature (which pulls the
//! `xla` crate and its native xla_extension toolchain). Without it this
//! module compiles a stub with the same API whose constructor fails, so
//! the rest of the stack — coordinator, router, engines' symbolic phases
//! — builds and tests everywhere, and block jobs degrade to a clean
//! runtime error instead of a missing-toolchain build break.

#[cfg(feature = "pjrt")]
mod imp {
    use anyhow::{anyhow, Context, Result};
    use std::collections::HashMap;
    use std::path::Path;

    /// A PJRT CPU client plus a cache of compiled executables keyed by
    /// artifact path.
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
        exes: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    impl PjrtRuntime {
        /// Create the CPU client.
        pub fn cpu() -> Result<Self> {
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
            Ok(PjrtRuntime { client, exes: HashMap::new() })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO text artifact (cached by path).
        pub fn load(&mut self, path: &Path) -> Result<()> {
            let key = path.to_string_lossy().to_string();
            if self.exes.contains_key(&key) {
                return Ok(());
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .map_err(|e| anyhow!("parse HLO text {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
            self.exes.insert(key, exe);
            Ok(())
        }

        /// Execute a loaded artifact on f64 inputs.
        ///
        /// `inputs`: `(data, dims)` pairs; the computation was lowered with
        /// `return_tuple=True`, so the single tuple output is unwrapped and
        /// returned as a flat f64 vector.
        pub fn execute_f64(
            &mut self,
            path: &Path,
            inputs: &[(&[f64], &[usize])],
        ) -> Result<Vec<f64>> {
            self.load(path)?;
            let key = path.to_string_lossy().to_string();
            let exe = self.exes.get(&key).expect("just loaded");
            let mut literals = Vec::with_capacity(inputs.len());
            for (data, dims) in inputs {
                let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(data)
                    .reshape(&dims_i64)
                    .map_err(|e| anyhow!("reshape input: {e:?}"))?;
                literals.push(lit);
            }
            let result = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| anyhow!("execute: {e:?}"))?;
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("to_literal: {e:?}"))?;
            let out = lit.to_tuple1().map_err(|e| anyhow!("to_tuple1: {e:?}"))?;
            out.to_vec::<f64>().map_err(|e| anyhow!("to_vec<f64>: {e:?}"))
        }

        /// Number of compiled executables in the cache.
        pub fn cached(&self) -> usize {
            self.exes.len()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use anyhow::{bail, Result};
    use std::path::Path;

    /// Build-anywhere stub: same API, fails at construction.
    pub struct PjrtRuntime {
        _private: (),
    }

    impl PjrtRuntime {
        pub fn cpu() -> Result<Self> {
            bail!("PJRT runtime unavailable: opsparse was built without the `pjrt` feature")
        }

        pub fn platform(&self) -> String {
            String::new()
        }

        pub fn load(&mut self, _path: &Path) -> Result<()> {
            bail!("PJRT runtime unavailable: opsparse was built without the `pjrt` feature")
        }

        pub fn execute_f64(
            &mut self,
            _path: &Path,
            _inputs: &[(&[f64], &[usize])],
        ) -> Result<Vec<f64>> {
            bail!("PJRT runtime unavailable: opsparse was built without the `pjrt` feature")
        }

        pub fn cached(&self) -> usize {
            0
        }
    }
}

pub use imp::PjrtRuntime;

/// True when the crate was compiled with the real PJRT client (`pjrt`
/// feature). Callers use this to skip engine paths gracefully.
pub fn pjrt_compiled() -> bool {
    cfg!(feature = "pjrt")
}
