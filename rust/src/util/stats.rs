//! Hypothesis-test machinery for the perf gates.
//!
//! Every blocking CI perf comparison used to be a single-point check
//! (`warm_ns <= cold_ns` on one run), which flaps on scheduler noise and
//! silently passes on luck. This module replaces those with N-repetition
//! **one-sided Welch t-tests** at a documented significance level
//! ([`DEFAULT_ALPHA`] = 0.01):
//!
//! * A gate **fails only when the candidate is *significantly worse* than
//!   the reference** — i.e. when the one-sided p-value for "candidate is
//!   worse" drops below `alpha`. Equal or better candidates pass, and a
//!   noisy-but-centered candidate passes too, so gates catch real
//!   regressions without flapping.
//! * Repetition counts are **adaptive** ([`sample_adaptive`]): sampling
//!   continues until the ~95% CI half-width (`2·s/√n`) shrinks below a
//!   relative threshold of the mean, or a rep cap is hit. Deterministic
//!   metrics converge at `min_reps`; noisy ones buy precision with reps.
//! * Pass/fail completion rates (the chaos gate) use an **exact binomial
//!   tail test** ([`completion_gate`]) against a target rate, so one rare
//!   retry-chain exhaustion in hundreds of trials no longer fails CI while
//!   a systematic completion regression still does.
//!
//! The special functions (`ln_gamma`, regularized incomplete beta) are
//! self-contained Lanczos/continued-fraction implementations — the build is
//! fully offline, so no `statrs`/`special` crates.

use std::fmt::Write as _;

/// Significance level shared by every blocking perf gate. One-sided: the
/// probability of failing a gate when the candidate is truly no worse than
/// the reference is at most this value (per gate, per run).
pub const DEFAULT_ALPHA: f64 = 0.01;

// ---------------------------------------------------------------------------
// samples

/// A sample set with the derived moments the tests need.
#[derive(Clone, Debug, Default)]
pub struct Samples {
    pub values: Vec<f64>,
}

impl Samples {
    pub fn new() -> Self {
        Samples { values: Vec::new() }
    }

    pub fn from_values(values: Vec<f64>) -> Self {
        Samples { values }
    }

    pub fn push(&mut self, v: f64) {
        self.values.push(v);
    }

    pub fn n(&self) -> usize {
        self.values.len()
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return 0.0;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    /// Unbiased sample variance (`n-1` denominator); 0 for fewer than two
    /// samples.
    pub fn var(&self) -> f64 {
        let n = self.values.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        self.values.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / (n - 1) as f64
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Approximate 95% confidence-interval half-width, `2·s/√n`. The exact
    /// width would use the t critical value (2.78 at n=5 down to 1.96 as
    /// n→∞); the fixed factor 2 keeps the stopping rule monotone and free
    /// of an inverse-CDF dependency, and errs slightly tight for small n.
    pub fn ci_half_width(&self) -> f64 {
        let n = self.values.len();
        if n < 2 {
            return f64::INFINITY;
        }
        2.0 * self.std() / (n as f64).sqrt()
    }
}

// ---------------------------------------------------------------------------
// special functions

/// `ln Γ(x)` for `x > 0` (Lanczos approximation, g=7, 9 coefficients;
/// |relative error| < 1e-13 over the domain the tests use).
pub fn ln_gamma(x: f64) -> f64 {
    const G: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // reflection: Γ(x)Γ(1-x) = π / sin(πx)
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = G[0];
    let t = x + 7.5;
    for (i, &g) in G.iter().enumerate().skip(1) {
        a += g / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Continued fraction for the incomplete beta (Numerical Recipes `betacf`).
fn betacf(a: f64, b: f64, x: f64) -> f64 {
    const MAX_IT: usize = 200;
    const EPS: f64 = 3.0e-14;
    const FPMIN: f64 = 1.0e-300;
    let qab = a + b;
    let qap = a + 1.0;
    let qam = a - 1.0;
    let mut c = 1.0;
    let mut d = 1.0 - qab * x / qap;
    if d.abs() < FPMIN {
        d = FPMIN;
    }
    d = 1.0 / d;
    let mut h = d;
    for m in 1..=MAX_IT {
        let m = m as f64;
        let m2 = 2.0 * m;
        let aa = m * (b - m) * x / ((qam + m2) * (a + m2));
        d = 1.0 + aa * d;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = 1.0 + aa / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        h *= d * c;
        let aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2));
        d = 1.0 + aa * d;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = 1.0 + aa / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < EPS {
            break;
        }
    }
    h
}

/// Regularized incomplete beta `I_x(a, b)` for `a, b > 0`, `x ∈ [0, 1]`.
pub fn betai(a: f64, b: f64, x: f64) -> f64 {
    if x <= 0.0 {
        return 0.0;
    }
    if x >= 1.0 {
        return 1.0;
    }
    let bt = (ln_gamma(a + b) - ln_gamma(a) - ln_gamma(b) + a * x.ln() + b * (1.0 - x).ln()).exp();
    if x < (a + 1.0) / (a + b + 2.0) {
        bt * betacf(a, b, x) / a
    } else {
        1.0 - bt * betacf(b, a, 1.0 - x) / b
    }
}

/// Survival function of Student's t: `P(T > t)` with `df` degrees of
/// freedom (`df` need not be an integer — Welch–Satterthwaite yields
/// fractional df).
pub fn student_t_sf(t: f64, df: f64) -> f64 {
    if !t.is_finite() {
        return if t > 0.0 { 0.0 } else { 1.0 };
    }
    let x = df / (df + t * t);
    let tail = 0.5 * betai(0.5 * df, 0.5, x);
    if t >= 0.0 {
        tail
    } else {
        1.0 - tail
    }
}

/// Exact binomial lower tail: `P(X <= k)` for `X ~ Binomial(n, p)`,
/// via `I_{1-p}(n-k, k+1)`.
pub fn binomial_cdf(k: usize, n: usize, p: f64) -> f64 {
    if k >= n {
        return 1.0;
    }
    betai((n - k) as f64, (k + 1) as f64, 1.0 - p)
}

// ---------------------------------------------------------------------------
// Welch's t-test

/// Result of a one-sided Welch two-sample t-test.
#[derive(Clone, Copy, Debug)]
pub struct WelchTest {
    /// t statistic for `mean(x) - mean(y)`.
    pub t: f64,
    /// Welch–Satterthwaite degrees of freedom.
    pub df: f64,
    /// One-sided p-value for H1: `mean(x) > mean(y)`.
    pub p_greater: f64,
}

/// Welch's unequal-variance t-test of `x` against `y`.
///
/// Degenerate inputs resolve deterministically rather than panic: with zero
/// variance on both sides the p-value is 0/1/0.5 by the sign of the mean
/// difference, and with fewer than two samples on either side the test is
/// treated the same way (no variance information).
pub fn welch_test(x: &Samples, y: &Samples) -> WelchTest {
    let (nx, ny) = (x.n(), y.n());
    let diff = x.mean() - y.mean();
    let (vx, vy) = (x.var(), y.var());
    let se2 = if nx > 0 && ny > 0 {
        vx / nx as f64 + vy / ny as f64
    } else {
        0.0
    };
    if se2 <= 0.0 || nx < 2 || ny < 2 {
        // no usable variance: the comparison is deterministic
        let p = if diff > 0.0 {
            0.0
        } else if diff < 0.0 {
            1.0
        } else {
            0.5
        };
        let t = if diff == 0.0 {
            0.0
        } else {
            diff.signum() * f64::INFINITY
        };
        return WelchTest { t, df: (nx + ny).saturating_sub(2).max(1) as f64, p_greater: p };
    }
    let t = diff / se2.sqrt();
    // Welch–Satterthwaite
    let num = se2 * se2;
    let den = (vx / nx as f64).powi(2) / (nx as f64 - 1.0)
        + (vy / ny as f64).powi(2) / (ny as f64 - 1.0);
    let df = if den > 0.0 { num / den } else { (nx + ny - 2) as f64 };
    WelchTest { t, df, p_greater: student_t_sf(t, df) }
}

// ---------------------------------------------------------------------------
// adaptive repetition

/// Stopping rule for adaptive repetition: sample at least `min_reps`, stop
/// as soon as the CI half-width drops below `rel_half_width · |mean|`, cap
/// at `max_reps`. Deterministic metrics (zero variance) stop at `min_reps`.
#[derive(Clone, Copy, Debug)]
pub struct AdaptiveConfig {
    pub min_reps: usize,
    pub max_reps: usize,
    /// Target relative precision of the mean (CI half-width / |mean|).
    pub rel_half_width: f64,
    /// Significance level the downstream gate will test at (recorded in
    /// every [`GateResult`]).
    pub alpha: f64,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig {
            min_reps: 5,
            max_reps: 20,
            rel_half_width: 0.05,
            alpha: DEFAULT_ALPHA,
        }
    }
}

impl AdaptiveConfig {
    /// Env-var overrides shared by every bench binary:
    /// `OPSPARSE_STAT_MIN_REPS`, `OPSPARSE_STAT_MAX_REPS`,
    /// `OPSPARSE_STAT_REL_HW`, `OPSPARSE_STAT_ALPHA`.
    pub fn from_env() -> Self {
        let mut cfg = AdaptiveConfig::default();
        if let Some(v) = env_parse::<usize>("OPSPARSE_STAT_MIN_REPS") {
            cfg.min_reps = v.max(2);
        }
        if let Some(v) = env_parse::<usize>("OPSPARSE_STAT_MAX_REPS") {
            cfg.max_reps = v;
        }
        if let Some(v) = env_parse::<f64>("OPSPARSE_STAT_REL_HW") {
            cfg.rel_half_width = v;
        }
        if let Some(v) = env_parse::<f64>("OPSPARSE_STAT_ALPHA") {
            cfg.alpha = v;
        }
        if cfg.max_reps < cfg.min_reps {
            cfg.max_reps = cfg.min_reps;
        }
        cfg
    }

    pub fn converged(&self, s: &Samples) -> bool {
        if s.n() < self.min_reps.max(2) {
            return false;
        }
        let hw = s.ci_half_width();
        let scale = s.mean().abs();
        // a zero mean can't anchor a relative threshold; fall back to an
        // absolute check against the spread itself
        hw <= self.rel_half_width * if scale > 0.0 { scale } else { 1.0 }
    }
}

fn env_parse<T: std::str::FromStr>(key: &str) -> Option<T> {
    std::env::var(key).ok().and_then(|v| v.parse().ok())
}

/// Run `measure(rep)` adaptively: at least `min_reps` times, then until the
/// CI half-width converges or `max_reps` is hit. The rep index lets callers
/// derive a fresh seed per repetition — the simulator itself is
/// deterministic, so repetition variance comes from varying the workload
/// seed, which is exactly the robustness the gates should test.
pub fn sample_adaptive(cfg: &AdaptiveConfig, mut measure: impl FnMut(usize) -> f64) -> Samples {
    let mut s = Samples::new();
    for rep in 0..cfg.max_reps.max(cfg.min_reps).max(2) {
        s.push(measure(rep));
        if cfg.converged(&s) {
            break;
        }
    }
    s
}

/// Paired variant: each repetition produces `(candidate, reference)` from
/// the same seeded workload; sampling stops when **both** sides converge.
pub fn sample_adaptive_paired(
    cfg: &AdaptiveConfig,
    mut measure: impl FnMut(usize) -> (f64, f64),
) -> (Samples, Samples) {
    let mut a = Samples::new();
    let mut b = Samples::new();
    for rep in 0..cfg.max_reps.max(cfg.min_reps).max(2) {
        let (x, y) = measure(rep);
        a.push(x);
        b.push(y);
        if cfg.converged(&a) && cfg.converged(&b) {
            break;
        }
    }
    (a, b)
}

// ---------------------------------------------------------------------------
// gates

/// Outcome of one blocking CI gate, serialized into the bench JSON so the
/// python check only reads a verdict it can re-derive.
#[derive(Clone, Debug)]
pub struct GateResult {
    pub name: String,
    /// `"welch_one_sided"` or `"binomial_exact"`.
    pub kind: String,
    pub pass: bool,
    /// One-sided p-value for "the candidate is worse than the reference".
    pub p: f64,
    pub alpha: f64,
    /// Mean of the candidate metric (observed rate for binomial gates).
    pub candidate_mean: f64,
    /// Mean of the reference metric (target rate for binomial gates).
    pub reference_mean: f64,
    pub reps_candidate: usize,
    pub reps_reference: usize,
    pub t: f64,
    pub df: f64,
    pub detail: String,
}

impl GateResult {
    /// Hand-rolled JSON object (the repo has no serde).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        let _ = write!(
            s,
            "{{\"name\":\"{}\",\"kind\":\"{}\",\"pass\":{},\"p\":{},\"alpha\":{},\
             \"candidate_mean\":{},\"reference_mean\":{},\"reps_candidate\":{},\
             \"reps_reference\":{},\"t\":{},\"df\":{},\"detail\":\"{}\"}}",
            self.name,
            self.kind,
            self.pass,
            jnum(self.p),
            jnum(self.alpha),
            jnum(self.candidate_mean),
            jnum(self.reference_mean),
            self.reps_candidate,
            self.reps_reference,
            jnum(self.t),
            jnum(self.df),
            self.detail.replace('"', "'"),
        );
        s
    }
}

/// Render an f64 as a JSON-safe number (non-finite values have no JSON
/// representation; clamp to huge-but-finite so parsers stay happy).
fn jnum(v: f64) -> String {
    if v.is_nan() {
        return "0".into();
    }
    if v.is_infinite() {
        return if v > 0.0 { "1e308".into() } else { "-1e308".into() };
    }
    // `Display` for f64 emits plain decimal or `5e-324`-style exponents,
    // both valid JSON numbers
    format!("{v}")
}

/// One-sided Welch gate: **fail only if the candidate is significantly
/// worse than the reference** at level `alpha`.
///
/// "Worse" depends on the metric direction: with `higher_is_better=false`
/// (latencies, makespans) worse means greater, so the test is
/// H1: `mean(candidate) > mean(reference)`; with `higher_is_better=true`
/// (throughput) the sides swap. `pass = p >= alpha`.
pub fn not_worse_gate(
    name: &str,
    candidate: &Samples,
    reference: &Samples,
    higher_is_better: bool,
    alpha: f64,
) -> GateResult {
    let w = if higher_is_better {
        welch_test(reference, candidate) // H1: reference > candidate
    } else {
        welch_test(candidate, reference) // H1: candidate > reference
    };
    let p = w.p_greater;
    GateResult {
        name: name.to_string(),
        kind: "welch_one_sided".to_string(),
        pass: p >= alpha,
        p,
        alpha,
        candidate_mean: candidate.mean(),
        reference_mean: reference.mean(),
        reps_candidate: candidate.n(),
        reps_reference: reference.n(),
        t: w.t,
        df: w.df,
        detail: format!(
            "H1: candidate {} reference; fail iff p < alpha",
            if higher_is_better { "<" } else { ">" }
        ),
    }
}

/// Exact binomial completion gate: **fail only if the observed success
/// count is significantly below the target rate `p0`** at level `alpha`
/// (`p = P(X <= completed | n, p0)`, fail iff `p < alpha`).
///
/// At `p0 = 0.995`, one lost job in 200 trials gives
/// `p = P(X <= 199) = 1 - 0.995^200 ≈ 0.63` — passes; a systematic drop to
/// 95% completion gives `p < 1e-6` — fails.
pub fn completion_gate(
    name: &str,
    completed: usize,
    total: usize,
    p0: f64,
    alpha: f64,
) -> GateResult {
    let p = if total == 0 { 1.0 } else { binomial_cdf(completed, total, p0) };
    let observed = if total == 0 { 1.0 } else { completed as f64 / total as f64 };
    GateResult {
        name: name.to_string(),
        kind: "binomial_exact".to_string(),
        pass: p >= alpha,
        p,
        alpha,
        candidate_mean: observed,
        reference_mean: p0,
        reps_candidate: total,
        reps_reference: 0,
        t: 0.0,
        df: 0.0,
        detail: format!(
            "exact binomial tail P(X <= {completed} | n={total}, p0={p0}); fail iff p < alpha"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx(a: f64, b: f64, tol: f64) {
        assert!((a - b).abs() <= tol, "{a} !~ {b} (tol {tol})");
    }

    #[test]
    fn ln_gamma_matches_factorials() {
        approx(ln_gamma(5.0), 24.0f64.ln(), 1e-12);
        approx(ln_gamma(1.0), 0.0, 1e-12);
        approx(ln_gamma(0.5), std::f64::consts::PI.sqrt().ln(), 1e-12);
    }

    #[test]
    fn betai_known_values() {
        // I_x(1, 1) = x
        approx(betai(1.0, 1.0, 0.3), 0.3, 1e-12);
        // symmetry: I_x(a,b) = 1 - I_{1-x}(b,a)
        approx(betai(2.5, 1.5, 0.4), 1.0 - betai(1.5, 2.5, 0.6), 1e-12);
        assert_eq!(betai(2.0, 3.0, 0.0), 0.0);
        assert_eq!(betai(2.0, 3.0, 1.0), 1.0);
    }

    #[test]
    fn t_sf_center_and_tails() {
        approx(student_t_sf(0.0, 7.0), 0.5, 1e-12);
        // standard normal limit: P(T > 1.96) -> 0.025 as df grows
        approx(student_t_sf(1.96, 1e6), 0.025, 1e-3);
        // symmetry
        approx(
            student_t_sf(-1.3, 9.0) + student_t_sf(1.3, 9.0),
            1.0,
            1e-12,
        );
        assert!(student_t_sf(100.0, 5.0) < 1e-6);
    }

    #[test]
    fn binomial_cdf_hand_computed() {
        // n=10, p=0.5: P(X<=2) = (1 + 10 + 45) / 1024
        approx(binomial_cdf(2, 10, 0.5), 56.0 / 1024.0, 1e-12);
        assert_eq!(binomial_cdf(10, 10, 0.5), 1.0);
        assert_eq!(binomial_cdf(12, 10, 0.5), 1.0);
    }

    #[test]
    fn welch_separated_samples_significant() {
        let x = Samples::from_values(vec![10.0, 10.1, 9.9, 10.2, 9.8]);
        let y = Samples::from_values(vec![5.0, 5.1, 4.9, 5.2, 4.8]);
        let w = welch_test(&x, &y);
        assert!(w.p_greater < 1e-4, "p={}", w.p_greater);
        let back = welch_test(&y, &x);
        assert!(back.p_greater > 0.999, "p={}", back.p_greater);
    }

    #[test]
    fn welch_zero_variance_is_deterministic() {
        let x = Samples::from_values(vec![3.0, 3.0, 3.0]);
        let y = Samples::from_values(vec![2.0, 2.0, 2.0]);
        assert_eq!(welch_test(&x, &y).p_greater, 0.0);
        assert_eq!(welch_test(&y, &x).p_greater, 1.0);
        assert_eq!(welch_test(&x, &x).p_greater, 0.5);
    }

    #[test]
    fn adaptive_stops_early_on_deterministic_metric() {
        let cfg = AdaptiveConfig { min_reps: 3, max_reps: 50, ..Default::default() };
        let s = sample_adaptive(&cfg, |_| 42.0);
        assert_eq!(s.n(), 3);
        approx(s.mean(), 42.0, 0.0);
    }

    #[test]
    fn adaptive_spends_reps_on_noisy_metric() {
        let cfg = AdaptiveConfig {
            min_reps: 3,
            max_reps: 8,
            rel_half_width: 1e-9,
            ..Default::default()
        };
        // alternating values never reach 1e-9 relative precision: cap hit
        let s = sample_adaptive(&cfg, |rep| if rep % 2 == 0 { 1.0 } else { 2.0 });
        assert_eq!(s.n(), 8);
    }

    #[test]
    fn paired_sampler_tracks_both_sides() {
        let cfg = AdaptiveConfig { min_reps: 4, max_reps: 10, ..Default::default() };
        let (a, b) = sample_adaptive_paired(&cfg, |rep| (1.0, rep as f64));
        assert_eq!(a.n(), b.n());
        assert!(a.n() >= 4);
    }

    #[test]
    fn not_worse_gate_directions() {
        let fast = Samples::from_values(vec![1.0, 1.1, 0.9, 1.0, 1.05]);
        let slow = Samples::from_values(vec![9.0, 9.1, 8.9, 9.0, 9.05]);
        // lower is better: fast candidate passes, slow candidate fails
        assert!(not_worse_gate("g", &fast, &slow, false, DEFAULT_ALPHA).pass);
        assert!(!not_worse_gate("g", &slow, &fast, false, DEFAULT_ALPHA).pass);
        // higher is better: the directions flip
        assert!(not_worse_gate("g", &slow, &fast, true, DEFAULT_ALPHA).pass);
        assert!(!not_worse_gate("g", &fast, &slow, true, DEFAULT_ALPHA).pass);
        // statistical tie passes both ways
        assert!(not_worse_gate("g", &fast, &fast, false, DEFAULT_ALPHA).pass);
    }

    #[test]
    fn completion_gate_tolerates_rare_loss_catches_regression() {
        let ok = completion_gate("c", 199, 200, 0.995, DEFAULT_ALPHA);
        assert!(ok.pass, "one loss in 200 at p0=0.995 must pass: p={}", ok.p);
        let bad = completion_gate("c", 190, 200, 0.995, DEFAULT_ALPHA);
        assert!(!bad.pass, "95% completion must fail: p={}", bad.p);
        assert!(completion_gate("c", 200, 200, 0.995, DEFAULT_ALPHA).pass);
    }

    #[test]
    fn gate_json_is_parseable_shape() {
        let g = completion_gate("chaos_gentle_completion", 200, 200, 0.995, 0.01);
        let j = g.to_json();
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"pass\":true"));
        assert!(j.contains("\"kind\":\"binomial_exact\""));
        assert!(!j.contains("inf") && !j.contains("NaN"));
    }
}
