"""L1 correctness: Pallas kernels vs pure-jnp oracles, swept over shapes
and dtypes with hypothesis. This is the CORE correctness signal for the
compile path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis drives the shape/dtype sweeps; environments without it (the
# offline container) skip this module — CI installs it from
# python/requirements.txt and runs the full sweep.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels.block_matmul import block_pair_matmul, row_window_accumulate
from compile.kernels.ref import block_pair_matmul_ref, row_window_accumulate_ref

jax.config.update("jax_enable_x64", True)


def rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


# ---------------------------------------------------------------------------
# block_pair_matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("p,t", [(1, 4), (3, 8), (8, 16), (2, 32)])
def test_block_pair_matches_ref(dtype, p, t):
    a = rand((p, t, t), dtype, 1)
    b = rand((p, t, t), dtype, 2)
    got = block_pair_matmul(a, b)
    want = block_pair_matmul_ref(a, b)
    tol = 1e-12 if dtype == jnp.float64 else 1e-5
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_block_pair_identity_blocks():
    t = 8
    eye = jnp.tile(jnp.eye(t, dtype=jnp.float64)[None], (4, 1, 1))
    x = rand((4, t, t), jnp.float64, 3)
    np.testing.assert_allclose(block_pair_matmul(eye, x), x, rtol=1e-14)
    np.testing.assert_allclose(block_pair_matmul(x, eye), x, rtol=1e-14)


def test_block_pair_zero_blocks():
    z = jnp.zeros((2, 16, 16), jnp.float64)
    x = rand((2, 16, 16), jnp.float64, 4)
    np.testing.assert_array_equal(block_pair_matmul(z, x), z)


@settings(max_examples=25, deadline=None)
@given(
    p=st.integers(min_value=1, max_value=6),
    t=st.sampled_from([2, 4, 8, 16]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_block_pair_hypothesis_sweep(p, t, seed):
    a = rand((p, t, t), jnp.float64, seed)
    b = rand((p, t, t), jnp.float64, seed + 1)
    np.testing.assert_allclose(
        block_pair_matmul(a, b), block_pair_matmul_ref(a, b), rtol=1e-11, atol=1e-11
    )


# ---------------------------------------------------------------------------
# row_window_accumulate
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("r,k,w", [(1, 4, 8), (4, 8, 16), (8, 16, 64), (2, 32, 128)])
def test_row_window_matches_ref(dtype, r, k, w):
    a = rand((r, k), dtype, 5)
    b = rand((r, k, w), dtype, 6)
    got = row_window_accumulate(a, b)
    want = row_window_accumulate_ref(a, b)
    tol = 1e-12 if dtype == jnp.float64 else 1e-4
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_row_window_zero_padding_is_neutral():
    # zero-padded K tail must not change the result (how the Rust router
    # pads short rows into the fixed-K artifact)
    r, k, w = 3, 8, 16
    a = rand((r, k), jnp.float64, 7)
    b = rand((r, k, w), jnp.float64, 8)
    a_pad = jnp.concatenate([a, jnp.zeros((r, 4), a.dtype)], axis=1)
    b_pad = jnp.concatenate([b, rand((r, 4, w), jnp.float64, 9)], axis=1)
    # padded a-values are zero => the (arbitrary) padded b rows are ignored
    np.testing.assert_allclose(
        row_window_accumulate(a_pad, b_pad),
        row_window_accumulate(a, b),
        rtol=1e-12,
    )


@settings(max_examples=25, deadline=None)
@given(
    r=st.integers(min_value=1, max_value=5),
    k=st.sampled_from([2, 4, 8]),
    w=st.sampled_from([4, 8, 32]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_row_window_hypothesis_sweep(r, k, w, seed):
    a = rand((r, k), jnp.float64, seed)
    b = rand((r, k, w), jnp.float64, seed + 1)
    np.testing.assert_allclose(
        row_window_accumulate(a, b),
        row_window_accumulate_ref(a, b),
        rtol=1e-11,
        atol=1e-11,
    )
