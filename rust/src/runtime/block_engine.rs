//! BSR block engine: the accelerator numeric path (DESIGN.md
//! §Hardware-Adaptation).
//!
//! The symbolic phase — which block pairs meet, and the output block
//! structure — runs in Rust using the same hash accumulator the paper's
//! GPU kernels use (over block column indices). The numeric phase batches
//! the block pairs through the AOT-compiled Pallas `block_pair_matmul`
//! kernel (fixed batch `P`, block size `T`, zero-padded tail) and
//! scatter-accumulates the products into the output BSR blocks — the Rust
//! analog of the paper's fixed hash-table-size binning.

use super::client::PjrtRuntime;
use crate::sparse::{Bsr, Csr};
use crate::spgemm::hash_table::HashAccumulator;
use crate::spgemm::HashVariant;
use anyhow::{anyhow, ensure, Result};
use std::path::PathBuf;

/// One block-pair product task: `C[c_idx] += A[a_idx] @ B[b_idx]`.
#[derive(Clone, Copy, Debug)]
struct PairTask {
    a_idx: usize,
    b_idx: usize,
    c_idx: usize,
}

/// Execution statistics of one BSR multiply.
#[derive(Clone, Copy, Debug, Default)]
pub struct BlockEngineStats {
    pub pairs: usize,
    pub batches: usize,
    pub padded_pairs: usize,
    pub c_blocks: usize,
}

/// PJRT-backed BSR SpGEMM engine for one compiled `(P, T)` variant.
pub struct BlockEngine {
    runtime: PjrtRuntime,
    artifact: PathBuf,
    /// Compiled batch size.
    pub p: usize,
    /// Compiled block size.
    pub t: usize,
    pub stats: BlockEngineStats,
}

impl BlockEngine {
    /// Load the `block_matmul_p{P}_t{T}_f64` artifact from `dir`.
    pub fn load(dir: &std::path::Path, p: usize, t: usize) -> Result<Self> {
        let artifact = dir.join(format!("block_matmul_p{p}_t{t}_f64.hlo.txt"));
        ensure!(
            artifact.exists(),
            "artifact {} not found — run `make artifacts`",
            artifact.display()
        );
        let mut runtime = PjrtRuntime::cpu()?;
        runtime.load(&artifact)?;
        Ok(BlockEngine { runtime, artifact, p, t, stats: BlockEngineStats::default() })
    }

    /// Symbolic phase on the block structure: output block rows + the
    /// pair task list. Uses the paper's hash accumulator over block
    /// column indices.
    fn symbolic(&self, a: &Bsr, b: &Bsr) -> (Vec<usize>, Vec<u32>, Vec<PairTask>) {
        let mut c_rpt = vec![0usize; a.brows + 1];
        let mut c_bcol: Vec<u32> = Vec::new();
        let mut tasks: Vec<PairTask> = Vec::new();
        // per-block-row map from b block col -> c block index
        let t_size = (b.bcols.max(16)).next_power_of_two();
        let mut table = HashAccumulator::new(t_size, HashVariant::SingleAccess);
        let mut local: Vec<i64> = vec![-1; b.bcols];
        let mut touched: Vec<u32> = Vec::new();
        for i in 0..a.brows {
            table.reset();
            touched.clear();
            let row_begin = c_bcol.len();
            for ai in a.rpt[i]..a.rpt[i + 1] {
                let k = a.bcol[ai] as usize;
                for bi in b.rpt[k]..b.rpt[k + 1] {
                    let j = b.bcol[bi] as usize;
                    let c_idx = if local[j] < 0 {
                        // the hash insert mirrors the GPU symbolic probe
                        let _ = table.insert_symbolic(j as u32);
                        let idx = c_bcol.len();
                        local[j] = idx as i64;
                        c_bcol.push(j as u32);
                        touched.push(j as u32);
                        idx
                    } else {
                        local[j] as usize
                    };
                    tasks.push(PairTask { a_idx: ai, b_idx: bi, c_idx });
                }
            }
            // sort block row by column; remap pending tasks
            let n_in_row = c_bcol.len() - row_begin;
            if n_in_row > 1 {
                let mut order: Vec<usize> = (0..n_in_row).collect();
                order.sort_unstable_by_key(|&x| c_bcol[row_begin + x]);
                let old: Vec<u32> = c_bcol[row_begin..].to_vec();
                let mut remap = vec![0usize; n_in_row];
                for (new_pos, &old_pos) in order.iter().enumerate() {
                    c_bcol[row_begin + new_pos] = old[old_pos];
                    remap[old_pos] = new_pos;
                }
                for t in tasks.iter_mut().rev() {
                    if t.c_idx < row_begin {
                        break;
                    }
                    t.c_idx = row_begin + remap[t.c_idx - row_begin];
                }
            }
            for &j in &touched {
                local[j as usize] = -1;
            }
            c_rpt[i + 1] = c_bcol.len();
        }
        (c_rpt, c_bcol, tasks)
    }

    /// `C = A * B` over BSR operands (must share this engine's block size).
    pub fn spgemm_bsr(&mut self, a: &Bsr, b: &Bsr) -> Result<Bsr> {
        ensure!(a.t == self.t && b.t == self.t, "block size mismatch");
        ensure!(a.cols == b.rows, "dimension mismatch");
        let tt = self.t * self.t;
        let (c_rpt, c_bcol, tasks) = self.symbolic(a, b);
        let mut c_blocks = vec![0f64; c_bcol.len() * tt];

        // numeric phase: batches of P pairs through the PJRT kernel
        let mut a_batch = vec![0f64; self.p * tt];
        let mut b_batch = vec![0f64; self.p * tt];
        self.stats = BlockEngineStats {
            pairs: tasks.len(),
            batches: 0,
            padded_pairs: 0,
            c_blocks: c_bcol.len(),
        };
        for chunk in tasks.chunks(self.p) {
            a_batch.fill(0.0);
            b_batch.fill(0.0);
            for (s, task) in chunk.iter().enumerate() {
                a_batch[s * tt..(s + 1) * tt].copy_from_slice(a.block(task.a_idx));
                b_batch[s * tt..(s + 1) * tt].copy_from_slice(b.block(task.b_idx));
            }
            let dims = [self.p, self.t, self.t];
            let out = self
                .runtime
                .execute_f64(&self.artifact, &[(&a_batch, &dims), (&b_batch, &dims)])
                .map_err(|e| anyhow!("block engine batch failed: {e:?}"))?;
            ensure!(out.len() == self.p * tt, "unexpected output size {}", out.len());
            for (s, task) in chunk.iter().enumerate() {
                let dst = &mut c_blocks[task.c_idx * tt..(task.c_idx + 1) * tt];
                let src = &out[s * tt..(s + 1) * tt];
                for (d, &v) in dst.iter_mut().zip(src) {
                    *d += v;
                }
            }
            self.stats.batches += 1;
            self.stats.padded_pairs += self.p - chunk.len();
        }

        Ok(Bsr {
            t: self.t,
            brows: a.brows,
            bcols: b.bcols,
            rows: a.rows,
            cols: b.cols,
            rpt: c_rpt,
            bcol: c_bcol,
            blocks: c_blocks,
        })
    }

    /// Convenience: CSR in, CSR out (convert, multiply, convert back).
    pub fn spgemm_csr(&mut self, a: &Csr, b: &Csr) -> Result<Csr> {
        let ab = Bsr::from_csr(a, self.t)?;
        let bb = Bsr::from_csr(b, self.t)?;
        self.spgemm_bsr(&ab, &bb)?.to_csr()
    }
}

// NOTE: PJRT integration tests live in rust/tests/integration_runtime.rs —
// they require `make artifacts` to have run.
