//! Kernel configuration and binning-range tables (paper §5.6–§5.7,
//! Tables 1, 2, 4, 5) for the NVIDIA Tesla V100 target.
//!
//! Each computation step (symbolic / numeric) classifies rows into 8 bins;
//! each bin is computed by a kernel with a fixed hash-table size and thread
//! block size. The *binning range* maps a row's size estimate (`n_prod` for
//! symbolic, `n_nz` for numeric) to a bin, trading hash-collision rate
//! against hardware utilization (§4.3): a 1× range fills tables to 100%
//! occupancy (max collisions), scaled ranges leave headroom.

use crate::gpusim::device::V100;

/// Number of bins in each step (paper: 8 bins).
pub const NUM_BINS: usize = 8;

/// Hash multiplier used by the probing sequence. nsparse and the paper use
/// a small odd constant; the exact value only changes which keys collide,
/// not the statistics.
pub const HASH_SCALE: u32 = 107;

/// Fraction of kernel7's symbolic table beyond which a row is recorded for
/// recomputation in the global-memory kernel8 (paper §5.6.1: 0.8×).
pub const SYMBOLIC_GLOBAL_FALLBACK_FRACTION: f64 = 0.8;

/// One computing kernel's static configuration.
#[derive(Clone, Copy, Debug)]
pub struct KernelConfig {
    /// Kernel index within the step (0..=8 symbolic, 0..=7 numeric).
    pub index: usize,
    /// Hash table slots; `None` for global-memory-table kernels.
    pub table_size: Option<usize>,
    /// Thread block size.
    pub tb_size: usize,
    /// Rows computed per thread block (kernel0 packs several tiny rows into
    /// one block; all other kernels compute one row per block).
    pub rows_per_block: usize,
    /// Threads cooperating on one row.
    pub threads_per_row: usize,
    /// Shared memory bytes per thread block (table + the 4-byte counter).
    pub shared_bytes: usize,
    /// True for the global-memory hash-table fallback kernel.
    pub global_table: bool,
}

impl KernelConfig {
    /// Theoretical occupancy on the V100 (fraction of 2048 threads/SM).
    pub fn theoretical_occupancy(&self) -> f64 {
        crate::gpusim::occupancy::occupancy(self.tb_size, self.shared_bytes, &V100)
    }
}

/// Bytes per hash-table slot: symbolic stores a 4-byte column key; numeric
/// stores a key + 8-byte double value (12 bytes, §5.6.2).
pub const SYM_SLOT_BYTES: usize = 4;
pub const NUM_SLOT_BYTES: usize = 12;

/// Symbolic-step kernels (paper Table 1). Shared memory = table + 4-byte
/// `shared_nnz` (per row for kernel0).
pub fn symbolic_kernels() -> [KernelConfig; 9] {
    let k = |index, table_size: Option<usize>, tb_size, rows_per_block, threads_per_row, shared_bytes| KernelConfig {
        index,
        table_size,
        tb_size,
        rows_per_block,
        threads_per_row,
        shared_bytes,
        global_table: table_size.is_none(),
    };
    [
        // kernel0: 4 threads/row, 256 rows per 1024-thread block,
        // 256 tables of 32 slots + 256 shared_nnz counters
        k(0, Some(32), 1024, 256, 4, 256 * (32 * SYM_SLOT_BYTES + 4)),
        k(1, Some(512), 64, 1, 64, 512 * SYM_SLOT_BYTES + 4),
        k(2, Some(1024), 128, 1, 128, 1024 * SYM_SLOT_BYTES + 4),
        k(3, Some(2048), 256, 1, 256, 2048 * SYM_SLOT_BYTES + 4),
        k(4, Some(4096), 512, 1, 512, 4096 * SYM_SLOT_BYTES + 4),
        k(5, Some(8192), 1024, 1, 1024, 8192 * SYM_SLOT_BYTES + 4),
        // kernel6: (48K-4) bytes table + 4 bytes shared_nnz = 48K
        k(6, Some(12287), 1024, 1, 1024, 12287 * SYM_SLOT_BYTES + 4),
        // kernel7: max shared memory (96KB), theoretical 50% occupancy
        k(7, Some(24575), 1024, 1, 1024, 24575 * SYM_SLOT_BYTES + 4),
        // kernel8: global table, 4 bytes of shared memory (shared_nnz)
        k(8, None, 1024, 1, 1024, 4),
    ]
}

/// Numeric-step kernels (paper Table 2). Slots are 12 bytes (key + f64);
/// +4 bytes `shared_offset` for the condense phase.
pub fn numeric_kernels() -> [KernelConfig; 8] {
    let k = |index, table_size: Option<usize>, tb_size, rows_per_block, threads_per_row, shared_bytes| KernelConfig {
        index,
        table_size,
        tb_size,
        rows_per_block,
        threads_per_row,
        shared_bytes,
        global_table: table_size.is_none(),
    };
    [
        // kernel0: 8 threads/row, 128 rows per 1024-thread block
        k(0, Some(31), 1024, 128, 8, 128 * (31 * NUM_SLOT_BYTES + 4)),
        k(1, Some(255), 64, 1, 64, 255 * NUM_SLOT_BYTES + 4),
        k(2, Some(511), 128, 1, 128, 511 * NUM_SLOT_BYTES + 4),
        k(3, Some(1023), 256, 1, 256, 1023 * NUM_SLOT_BYTES + 4),
        k(4, Some(2047), 512, 1, 512, 2047 * NUM_SLOT_BYTES + 4),
        k(5, Some(4095), 1024, 1, 1024, 4095 * NUM_SLOT_BYTES + 4),
        // kernel6: max shared memory, theoretical 50% occupancy
        k(6, Some(8191), 1024, 1, 1024, 8191 * NUM_SLOT_BYTES + 4),
        // kernel7: global table
        k(7, None, 1024, 1, 1024, 4),
    ]
}

/// A binning range: per-bin *inclusive* upper bounds on the row-size
/// estimate; the last bin is unbounded.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BinningRanges {
    pub name: &'static str,
    /// `upper[j]` = largest row size assigned to bin j (inclusive);
    /// `upper[NUM_BINS-1]` = usize::MAX.
    pub upper: [usize; NUM_BINS],
}

impl BinningRanges {
    /// Bin index for a row of size `s`.
    #[inline]
    pub fn bin_of(&self, s: usize) -> usize {
        // linear scan mirrors the GPU kernel's register-resident loop
        for (j, &u) in self.upper.iter().enumerate() {
            if s <= u {
                return j;
            }
        }
        NUM_BINS - 1
    }
}

/// Symbolic-step range presets (paper Table 4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SymbolicRanges {
    Sym1x,
    Sym12x,
    Sym15x,
}

impl SymbolicRanges {
    pub fn ranges(self) -> BinningRanges {
        const MAX: usize = usize::MAX;
        match self {
            // table fully occupied (upper == table size)
            SymbolicRanges::Sym1x => BinningRanges {
                name: "sym_1x",
                upper: [32, 512, 1024, 2048, 4096, 8192, 12287, MAX],
            },
            // paper's adopted config: table >= 1.2x the largest n_prod
            SymbolicRanges::Sym12x => BinningRanges {
                name: "sym_1.2x",
                upper: [26, 426, 853, 1706, 3413, 6826, 10240, MAX],
            },
            SymbolicRanges::Sym15x => BinningRanges {
                name: "sym_1.5x",
                upper: [21, 341, 682, 1365, 2730, 5461, 8191, MAX],
            },
        }
    }

    pub fn all() -> [SymbolicRanges; 3] {
        [SymbolicRanges::Sym1x, SymbolicRanges::Sym12x, SymbolicRanges::Sym15x]
    }
}

/// Numeric-step range presets (paper Table 5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NumericRanges {
    Num1x,
    Num15x,
    Num2x,
    Num3x,
}

impl NumericRanges {
    pub fn ranges(self) -> BinningRanges {
        const MAX: usize = usize::MAX;
        match self {
            NumericRanges::Num1x => BinningRanges {
                name: "num_1x",
                upper: [31, 255, 511, 1023, 2047, 4095, 8191, MAX],
            },
            NumericRanges::Num15x => BinningRanges {
                name: "num_1.5x",
                upper: [21, 192, 384, 768, 1536, 3072, 5460, MAX],
            },
            // paper's adopted config: table >= 2x the largest n_nz
            NumericRanges::Num2x => BinningRanges {
                name: "num_2x",
                upper: [16, 128, 256, 512, 1024, 2048, 4096, MAX],
            },
            NumericRanges::Num3x => BinningRanges {
                name: "num_3x",
                upper: [10, 85, 170, 341, 682, 1365, 2730, MAX],
            },
        }
    }

    pub fn all() -> [NumericRanges; 4] {
        [NumericRanges::Num1x, NumericRanges::Num15x, NumericRanges::Num2x, NumericRanges::Num3x]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn symbolic_kernel_table_matches_paper() {
        let ks = symbolic_kernels();
        assert_eq!(ks[0].table_size, Some(32));
        assert_eq!(ks[1].table_size, Some(512));
        assert_eq!(ks[6].table_size, Some(12287));
        assert_eq!(ks[7].table_size, Some(24575));
        assert!(ks[8].global_table);
        assert_eq!(ks[1].tb_size, 64);
        assert_eq!(ks[5].tb_size, 1024);
        assert_eq!(ks[0].rows_per_block, 256);
        assert_eq!(ks[0].threads_per_row, 4);
    }

    #[test]
    fn numeric_kernel_table_matches_paper() {
        let ks = numeric_kernels();
        assert_eq!(ks[0].table_size, Some(31));
        assert_eq!(ks[1].table_size, Some(255));
        assert_eq!(ks[6].table_size, Some(8191));
        assert!(ks[7].global_table);
        assert_eq!(ks[0].threads_per_row, 8);
        assert_eq!(ks[0].rows_per_block, 128);
    }

    #[test]
    fn occupancy_targets_match_section_5_6() {
        // kernel1..kernel5 symbolic: full occupancy; kernel7: 50%.
        let ks = symbolic_kernels();
        for k in &ks[1..=5] {
            let occ = k.theoretical_occupancy();
            assert!(occ > 0.99, "symbolic kernel{} occupancy {occ}", k.index);
        }
        let occ7 = ks[7].theoretical_occupancy();
        assert!((occ7 - 0.5).abs() < 0.01, "kernel7 occupancy {occ7}");
        let occ8 = ks[8].theoretical_occupancy();
        assert!(occ8 > 0.99, "kernel8 occupancy {occ8}");
        // numeric: kernel6 50%, kernel7 full
        let nk = numeric_kernels();
        let nocc6 = nk[6].theoretical_occupancy();
        assert!((nocc6 - 0.5).abs() < 0.01, "numeric kernel6 occupancy {nocc6}");
        assert!(nk[7].theoretical_occupancy() > 0.99);
        for k in &nk[1..=5] {
            let occ = k.theoretical_occupancy();
            assert!(occ > 0.99, "numeric kernel{} occupancy {occ}", k.index);
        }
    }

    #[test]
    fn shared_memory_fits_v100() {
        for k in symbolic_kernels().iter().chain(numeric_kernels().iter()) {
            assert!(
                k.shared_bytes <= 96 * 1024,
                "kernel{} shared {} exceeds 96KB",
                k.index,
                k.shared_bytes
            );
        }
    }

    #[test]
    fn ranges_match_paper_tables_4_and_5() {
        let s12 = SymbolicRanges::Sym12x.ranges();
        assert_eq!(s12.upper[0], 26);
        assert_eq!(s12.upper[1], 426);
        assert_eq!(s12.upper[6], 10240);
        let n2 = NumericRanges::Num2x.ranges();
        assert_eq!(n2.upper[0], 16);
        assert_eq!(n2.upper[1], 128);
        assert_eq!(n2.upper[6], 4096);
    }

    #[test]
    fn bin_of_is_monotone_and_partitions() {
        for r in SymbolicRanges::all().map(|r| r.ranges()) {
            let mut last = 0;
            for s in 0..20000 {
                let b = r.bin_of(s);
                assert!(b >= last || s == 0, "bin_of not monotone at {s}");
                last = b;
                // consistency: s <= upper[b] and (b == 0 or s > upper[b-1])
                assert!(s <= r.upper[b]);
                if b > 0 {
                    assert!(s > r.upper[b - 1]);
                }
            }
            assert_eq!(r.bin_of(usize::MAX), NUM_BINS - 1);
        }
    }

    #[test]
    fn range_scaling_relationship() {
        // tighter ranges (larger multiplier) => smaller upper bounds
        let s1 = SymbolicRanges::Sym1x.ranges();
        let s12 = SymbolicRanges::Sym12x.ranges();
        let s15 = SymbolicRanges::Sym15x.ranges();
        for j in 0..NUM_BINS - 1 {
            assert!(s1.upper[j] > s12.upper[j]);
            assert!(s12.upper[j] > s15.upper[j]);
        }
        let n1 = NumericRanges::Num1x.ranges();
        let n3 = NumericRanges::Num3x.ranges();
        for j in 0..NUM_BINS - 1 {
            assert!(n1.upper[j] > n3.upper[j]);
        }
    }
}
