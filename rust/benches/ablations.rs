//! `cargo bench --bench ablations` — per-optimization ablation: each of
//! the paper's optimizations is disabled individually and the slowdown
//! reported on representative matrices (DESIGN.md §1 mapping).

use opsparse::bench::figures;
use opsparse::gen::suite::SuiteScale;

fn main() {
    let scale = std::env::var("OPSPARSE_SCALE")
        .ok()
        .and_then(|s| SuiteScale::parse(&s))
        .unwrap_or(SuiteScale::Small);
    figures::ablations(scale).expect("ablations");
}
