//! The chaos harness proves the failure-domain contract: under
//! deterministic fault injection (worker kills, straggler delays, pool
//! teardowns) every submitted job either completes **bit-identically**
//! to the undisturbed reference or returns a clean typed error — never a
//! hang, never a torn stitch — and the same `ChaosConfig.seed`
//! reproduces the same kill/delay schedule and the same metrics
//! snapshot.
//!
//! Every receive in this file goes through a hang guard
//! (`recv_timeout`): a test that would hang instead fails loudly with
//! the case that stranded its parent job.

use opsparse::coordinator::barrier::SpeculateConfig;
use opsparse::coordinator::chaos::ChaosConfig;
use opsparse::coordinator::feedback::ReplanConfig;
use opsparse::coordinator::{Coordinator, Job, Route, Router};
use opsparse::gen::banded::Banded;
use opsparse::gen::powerlaw::PowerLaw;
use opsparse::gen::stencil::{Grid, Stencil};
use opsparse::gen::uniform::Uniform;
use opsparse::sparse::Csr;
use opsparse::spgemm::reference::spgemm_reference;
use opsparse::util::prop::check;
use opsparse::util::rng::Rng;
use std::time::Duration;

/// Per-receive hang guard: generous enough for a CI box under load,
/// small enough that a stranded parent fails the suite instead of
/// timing it out.
const HANG_GUARD: Duration = Duration::from_secs(60);

/// The four generator families of the property suite — one blocky, one
/// banded, one skewed with a giant row, one regular stencil, so the
/// shard cuts the chaos interleaves with range from trivial to lopsided.
fn family_matrix(family: usize, n: usize, rng: &mut Rng) -> Csr {
    match family % 4 {
        0 => Uniform { n, per_row: 6, jitter: 3 }.generate(rng),
        1 => Banded { n, per_row: 8, band: 12, contiguous_frac: 0.8 }.generate(rng),
        2 => PowerLaw {
            n,
            alpha: 2.2,
            max_row: 40,
            mean_row: 5.0,
            hub_frac: 0.2,
            forced_giant_rows: 1,
        }
        .generate(rng),
        _ => Stencil { n, grid: Grid::D2, reach: 2, keep: 0.9, diagonal: true }.generate(rng),
    }
}

fn coordinator_under_chaos(workers: usize, speculate: SpeculateConfig, chaos: ChaosConfig) -> Coordinator {
    Coordinator::start_full(
        workers,
        Router::default(),
        None,
        ReplanConfig::default(),
        speculate,
        chaos,
    )
}

/// Satellite property suite: any (chaos seed × preset × generator
/// family × shard count) yields a bit-identical result or a clean typed
/// error — never a hang, never a torn stitch — with speculation ON so
/// backups race primaries while workers die under them.
#[test]
fn any_seed_preset_family_shards_is_bitwise_or_typed_error() {
    check(
        "chaos-bitwise-or-error",
        24,
        260,
        |rng: &mut Rng, size| {
            let preset = rng.below(2); // 0 = gentle, 1 = aggressive
            let family = rng.below(4) as usize;
            let shards = 1usize << rng.below(4); // 1 | 2 | 4 | 8
            let chaos_seed = rng.next_u64();
            let mat_seed = rng.next_u64();
            let n = rng.range(40, size.max(41));
            (preset, family, shards, chaos_seed, mat_seed, n)
        },
        |&(preset, family, shards, chaos_seed, mat_seed, n)| {
            let cfg = if preset == 0 {
                ChaosConfig::gentle().with_seed(chaos_seed)
            } else {
                ChaosConfig::aggressive().with_seed(chaos_seed)
            };
            let a = family_matrix(family, n, &mut Rng::new(mat_seed));
            let gold = spgemm_reference(&a, &a);
            let coord = coordinator_under_chaos(3, SpeculateConfig::on(), cfg);
            coord.submit(Job {
                id: 1,
                a: a.clone(),
                b: a,
                force_route: Some(Route::Sharded { n_devices: shards }),
            });
            let verdict = match coord.recv_timeout(HANG_GUARD) {
                None => Err("parent job hung: no result within the guard".to_string()),
                Some(r) => match r.c {
                    Ok(c) if c == gold => Ok(()),
                    Ok(_) => Err("torn stitch: completed result diverged from reference".into()),
                    // a clean typed error is an allowed outcome under
                    // chaos (retry budget exhaustion)
                    Err(_) => Ok(()),
                },
            };
            coord.shutdown();
            verdict
        },
    );
}

/// Satellite determinism test: the same `ChaosConfig.seed` reproduces
/// the same kill/delay schedule — same per-job outcomes bitwise and the
/// same failure-domain metrics. One worker and sequential submits pin
/// the message order; speculation stays off so the monitor's wall-clock
/// sampling cannot add schedule-dependent launches.
#[test]
fn same_chaos_seed_reproduces_the_same_schedule_and_metrics() {
    let run = || {
        let a = Uniform { n: 220, per_row: 6, jitter: 3 }.generate(&mut Rng::new(9));
        let coord = coordinator_under_chaos(
            1,
            SpeculateConfig::default(),
            ChaosConfig::aggressive().with_seed(42),
        );
        let mut outcomes: Vec<Result<Csr, String>> = Vec::new();
        for id in 0..6u64 {
            let route = if id % 2 == 0 {
                Some(Route::Sharded { n_devices: 2 })
            } else {
                Some(Route::Hash)
            };
            coord.submit(Job { id, a: a.clone(), b: a.clone(), force_route: route });
            let r = coord.recv_timeout(HANG_GUARD).expect("no hang under seeded chaos");
            assert_eq!(r.id, id, "sequential submits report in order");
            outcomes.push(r.c.map_err(|e| format!("{e:#}")));
        }
        let snap = coord.metrics.snapshot();
        coord.shutdown();
        (outcomes, snap)
    };
    let (out1, snap1) = run();
    let (out2, snap2) = run();
    assert_eq!(out1, out2, "same seed, same per-job outcomes (bitwise results, same errors)");
    assert_eq!(
        (snap1.worker_deaths, snap1.requeued_shards, snap1.requeued_jobs),
        (snap2.worker_deaths, snap2.requeued_shards, snap2.requeued_jobs),
        "same kill schedule"
    );
    assert_eq!(
        (snap1.chaos_delays, snap1.chaos_pool_shrinks),
        (snap2.chaos_delays, snap2.chaos_pool_shrinks),
        "same delay/teardown schedule"
    );
    assert_eq!(
        (snap1.jobs_completed, snap1.jobs_failed),
        (snap2.jobs_completed, snap2.jobs_failed),
        "same verdicts"
    );
    // aggressive delays are drawn from (0, 2ms) at every boundary, so a
    // schedule that injects nothing at all means injection is broken
    assert!(
        snap1.chaos_delays > 0,
        "aggressive chaos must have injected faults (the schedule is live, not a no-op)"
    );
}

/// Under `gentle` chaos a recoverable worker death must never surface
/// to a parent: requeue absorbs every kill (budget exhaustion needs
/// `MAX_REQUEUES` consecutive deaths on one chain, p ≈ 0.02⁶), so the
/// whole load completes bit-identically.
#[test]
fn gentle_chaos_with_speculation_completes_everything_bit_identically() {
    let a = Uniform { n: 300, per_row: 6, jitter: 3 }.generate(&mut Rng::new(11));
    let gold = spgemm_reference(&a, &a);
    let jobs = 12u64;
    let coord = coordinator_under_chaos(
        3,
        SpeculateConfig::on(),
        ChaosConfig::gentle().with_seed(7),
    );
    for id in 0..jobs {
        coord.submit(Job {
            id,
            a: a.clone(),
            b: a.clone(),
            force_route: Some(Route::Sharded { n_devices: 4 }),
        });
    }
    for _ in 0..jobs {
        let r = coord.recv_timeout(HANG_GUARD).expect("no hang under gentle chaos");
        let c = r.c.unwrap_or_else(|e| panic!("job {} failed under gentle chaos: {e:#}", r.id));
        assert_eq!(c, gold, "job {}: stitched result must be bit-identical", r.id);
    }
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.jobs_completed, jobs);
    assert_eq!(snap.jobs_failed, 0, "gentle kills are absorbed by requeue, never surfaced");
    coord.shutdown();
}

/// Under `aggressive` chaos every parent still resolves exactly once:
/// completions are bit-identical, failures carry the typed
/// retry-budget error, and nothing hangs — while workers demonstrably
/// die under the load.
#[test]
fn aggressive_chaos_never_hangs_and_survivors_are_bit_identical() {
    let a = Uniform { n: 300, per_row: 6, jitter: 3 }.generate(&mut Rng::new(13));
    let gold = spgemm_reference(&a, &a);
    let jobs = 16u64;
    let coord = coordinator_under_chaos(
        4,
        SpeculateConfig::on(),
        ChaosConfig::aggressive().with_seed(1),
    );
    for id in 0..jobs {
        coord.submit(Job {
            id,
            a: a.clone(),
            b: a.clone(),
            force_route: Some(Route::Sharded { n_devices: 4 }),
        });
    }
    let mut resolved = 0u64;
    for _ in 0..jobs {
        let r = coord
            .recv_timeout(HANG_GUARD)
            .expect("every parent resolves under aggressive chaos — no hangs");
        resolved += 1;
        match r.c {
            Ok(c) => assert_eq!(c, gold, "job {}: survivor must be bit-identical", r.id),
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(
                    msg.contains("retry budget exhausted"),
                    "job {}: failure must be the typed requeue-exhaustion error, got: {msg}",
                    r.id
                );
            }
        }
    }
    assert_eq!(resolved, jobs);
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.jobs_completed + snap.jobs_failed, jobs, "exactly one verdict per parent");
    assert!(
        snap.worker_deaths > 0,
        "a 25% kill rate over {} sub-job boundaries fires with near certainty",
        jobs * 4
    );
    coord.shutdown();
}
