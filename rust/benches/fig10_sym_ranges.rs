//! `cargo bench --bench fig10_sym_ranges` — regenerates paper Figure 10:
//! symbolic-step performance across the sym_1x / 1.2x / 1.5x binning
//! ranges, normalized to sym_1x.

use opsparse::bench::figures;
use opsparse::gen::suite::SuiteScale;

fn main() {
    let scale = std::env::var("OPSPARSE_SCALE")
        .ok()
        .and_then(|s| SuiteScale::parse(&s))
        .unwrap_or(SuiteScale::Small);
    figures::fig10(scale).expect("fig10");
}
