//! Small self-contained utilities: deterministic RNG, property-test helper,
//! human-readable formatting. The build is fully offline, so we avoid the
//! `rand`/`proptest` crates and keep these in-house.

pub mod fmt;
pub mod prop;
pub mod rng;
pub mod stats;

/// Exclusive prefix sum over `v`, returning a vector one element longer whose
/// last entry is the total. This is the CPU analog of
/// `cub::DeviceScan::ExclusiveSum` used throughout the paper's pipeline.
pub fn exclusive_sum(v: &[usize]) -> Vec<usize> {
    let mut out = Vec::with_capacity(v.len() + 1);
    let mut acc = 0usize;
    out.push(0);
    for &x in v {
        acc += x;
        out.push(acc);
    }
    out
}

/// In-place exclusive prefix sum over `v` where the live counts occupy
/// `v[..v.len()-1]`; mirrors the in-place CUB scan the paper relies on when
/// it reuses `C.rpt` for the per-row nnz counts (§5.3).
pub fn exclusive_sum_in_place(v: &mut [usize]) {
    let mut acc = 0usize;
    for slot in v.iter_mut() {
        let x = *slot;
        *slot = acc;
        acc += x;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exclusive_sum_basic() {
        assert_eq!(exclusive_sum(&[1, 2, 3]), vec![0, 1, 3, 6]);
        assert_eq!(exclusive_sum(&[]), vec![0]);
    }

    #[test]
    fn exclusive_sum_in_place_matches() {
        let src = [5usize, 0, 7, 1];
        let mut buf = vec![0usize; src.len() + 1];
        buf[..src.len()].copy_from_slice(&src);
        exclusive_sum_in_place(&mut buf);
        assert_eq!(buf, exclusive_sum(&src));
    }
}
