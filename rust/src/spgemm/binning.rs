//! Binning method (paper §5.1, Algorithms 1–3, Fig. 3–4): classify rows by
//! size estimate into `NUM_BINS` bins for global load balance.
//!
//! The functional result is a grouped row-id array plus per-bin
//! sizes/offsets, stored in **one** array of length `M` (the minimized
//! metadata layout of Fig. 3). The module also emits the binning kernels'
//! trace work in one of three behavioral variants:
//!
//! * [`BinningVariant::SharedMemory`] (OpSparse): per-block shared-memory
//!   counters; only `NUM_BINS` global atomics per thread block, plus the
//!   Algorithm-3 fast path when every row fits bin 0.
//! * [`BinningVariant::GlobalAtomic`] (nsparse): one global atomic per row.
//! * [`BinningVariant::GlobalWide`] (spECK): one global atomic per row and
//!   an `M × NUM_BINS` metadata layout (the wide malloc is charged by the
//!   pipeline).

use super::kernel_tables::{BinningRanges, NUM_BINS};
use super::BinningVariant;
use crate::gpusim::trace::{BlockWork, Kernel, Trace};

/// Rows processed per binning thread block.
pub const BINNING_TB: usize = 1024;

/// Result of classifying rows into bins.
#[derive(Clone, Debug)]
pub struct BinningResult {
    /// Row ids grouped by bin: rows of bin `j` occupy
    /// `bins[bin_offset[j] .. bin_offset[j] + bin_size[j]]`.
    pub bins: Vec<u32>,
    pub bin_size: [usize; NUM_BINS],
    pub bin_offset: [usize; NUM_BINS],
    /// Maximum row size observed (drives the Algorithm-3 fast path).
    pub max_row_size: usize,
    /// True if the fast path applied (all rows in bin 0).
    pub fast_path: bool,
}

impl BinningResult {
    /// Row ids of bin `j`.
    pub fn bin_rows(&self, j: usize) -> &[u32] {
        &self.bins[self.bin_offset[j]..self.bin_offset[j] + self.bin_size[j]]
    }
}

/// Two-pass binning (Algorithms 1–2), block-structured exactly like the
/// GPU kernels so the within-bin order matches a deterministic replay:
/// rows appear in block order, then row order within the block.
pub fn bin_rows(sizes: &[usize], ranges: &BinningRanges) -> BinningResult {
    let m = sizes.len();
    // ---- pass 1: count bin sizes (+ track max) ----
    let mut bin_size = [0usize; NUM_BINS];
    let mut max_row_size = 0usize;
    for &s in sizes {
        bin_size[ranges.bin_of(s)] += 1;
        if s > max_row_size {
            max_row_size = s;
        }
    }
    // exclusive sum -> offsets
    let mut bin_offset = [0usize; NUM_BINS];
    let mut acc = 0usize;
    for j in 0..NUM_BINS {
        bin_offset[j] = acc;
        acc += bin_size[j];
    }
    // ---- fast path (Algorithm 3): everything in bin 0 ----
    if bin_size[0] == m {
        return BinningResult {
            bins: (0..m as u32).collect(),
            bin_size,
            bin_offset,
            max_row_size,
            fast_path: true,
        };
    }
    // ---- pass 2: scatter row ids ----
    let mut cursor = bin_offset;
    let mut bins = vec![0u32; m];
    for (i, &s) in sizes.iter().enumerate() {
        let j = ranges.bin_of(s);
        bins[cursor[j]] = i as u32;
        cursor[j] += 1;
    }
    BinningResult { bins, bin_size, bin_offset, max_row_size, fast_path: false }
}

/// Emit the binning kernels for a binning step onto `trace`.
///
/// `step` tags the kernels ("sym_binning" / "num_binning"); `result` must
/// come from [`bin_rows`] on the same sizes.
pub fn emit_binning_kernels(
    trace: &mut Trace,
    step: &'static str,
    m: usize,
    result: &BinningResult,
    variant: BinningVariant,
    stream: usize,
) {
    let nblocks = m.div_ceil(BINNING_TB);
    let rows_of_block = |b: usize| -> u64 {
        let start = b * BINNING_TB;
        (BINNING_TB.min(m - start)) as u64
    };

    // ---- pass 1 (count) ----
    let blocks: Vec<BlockWork> = (0..nblocks)
        .map(|b| {
            let rows = rows_of_block(b);
            match variant {
                BinningVariant::SharedMemory => BlockWork {
                    // read row sizes; shared atomics for counts + max;
                    // NUM_BINS + 1 global atomics per block
                    global_bytes: rows * 4,
                    shared_accesses: 2 * rows + NUM_BINS as u64,
                    global_atomics: NUM_BINS as u64 + 1,
                    ..Default::default()
                },
                BinningVariant::GlobalAtomic | BinningVariant::GlobalWide => BlockWork {
                    // every row atomically increments a global counter
                    global_bytes: rows * 4,
                    shared_accesses: 0,
                    global_atomics: rows,
                    ..Default::default()
                },
            }
        })
        .collect();
    trace.launch(Kernel {
        name: format!("{step}_pass1"),
        step,
        stream,
        tb_size: BINNING_TB,
        shared_bytes: match variant {
            BinningVariant::SharedMemory => (NUM_BINS + 1) * 4,
            _ => 0,
        },
        blocks,
    });

    // ---- exclusive sum over NUM_BINS (one tiny block) ----
    trace.launch(Kernel {
        name: format!("{step}_exscan"),
        step,
        stream,
        tb_size: 32,
        shared_bytes: NUM_BINS * 4,
        blocks: vec![BlockWork {
            global_bytes: (NUM_BINS * 8) as u64,
            shared_accesses: 2 * NUM_BINS as u64,
            ..Default::default()
        }],
    });

    // ---- pass 2 (scatter) or Algorithm-3 fast path ----
    if result.fast_path && variant == BinningVariant::SharedMemory {
        // d_bins[i] = i: pure streaming write, no comparisons
        let blocks: Vec<BlockWork> = (0..nblocks)
            .map(|b| BlockWork { global_bytes: rows_of_block(b) * 4, ..Default::default() })
            .collect();
        trace.launch(Kernel {
            name: format!("{step}_fastpath"),
            step,
            stream,
            tb_size: BINNING_TB,
            shared_bytes: 0,
            blocks,
        });
        return;
    }
    let blocks: Vec<BlockWork> = (0..nblocks)
        .map(|b| {
            let rows = rows_of_block(b);
            match variant {
                BinningVariant::SharedMemory => BlockWork {
                    global_bytes: rows * 4 * 2, // read sizes, write row ids
                    shared_accesses: 3 * rows + 2 * NUM_BINS as u64,
                    global_atomics: NUM_BINS as u64,
                    ..Default::default()
                },
                BinningVariant::GlobalAtomic => BlockWork {
                    global_bytes: rows * 4 * 2,
                    shared_accesses: 0,
                    global_atomics: rows,
                    ..Default::default()
                },
                BinningVariant::GlobalWide => BlockWork {
                    // spECK writes into the M x NUM_BINS layout: strided
                    // (uncoalesced) stores cost ~a full transaction per row
                    global_bytes: rows * 4 + rows * 32,
                    shared_accesses: 0,
                    global_atomics: rows,
                    ..Default::default()
                },
            }
        })
        .collect();
    trace.launch(Kernel {
        name: format!("{step}_pass2"),
        step,
        stream,
        tb_size: BINNING_TB,
        shared_bytes: match variant {
            BinningVariant::SharedMemory => (3 * NUM_BINS + 1) * 4,
            _ => 0,
        },
        blocks,
    });
}

/// Metadata bytes the binning method needs under each variant (§4.4):
/// OpSparse/nsparse store row ids in one length-`M` array; spECK uses the
/// two-dimensional `M × NUM_BINS` layout.
pub fn metadata_bytes(m: usize, variant: BinningVariant) -> usize {
    let base = 4 * m // bins array
        + 4 * NUM_BINS * 2 // bin_size + bin_offset
        + 4; // max_row
    match variant {
        BinningVariant::GlobalWide => 4 * m * NUM_BINS + 4 * NUM_BINS * 2 + 4,
        _ => base,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spgemm::kernel_tables::SymbolicRanges;
    use crate::util::prop;

    fn ranges() -> BinningRanges {
        SymbolicRanges::Sym12x.ranges()
    }

    #[test]
    fn partition_is_exact() {
        let sizes = vec![0, 5, 30, 500, 10_000, 20_000, 26, 27];
        let r = bin_rows(&sizes, &ranges());
        // every row appears exactly once
        let mut seen = vec![false; sizes.len()];
        for &row in &r.bins {
            assert!(!seen[row as usize], "row {row} duplicated");
            seen[row as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
        // rows are in the bins their sizes dictate
        for j in 0..NUM_BINS {
            for &row in r.bin_rows(j) {
                assert_eq!(ranges().bin_of(sizes[row as usize]), j);
            }
        }
        assert_eq!(r.max_row_size, 20_000);
        assert!(!r.fast_path);
    }

    #[test]
    fn fast_path_when_all_tiny() {
        let sizes = vec![3usize; 100]; // all <= 26 => bin0
        let r = bin_rows(&sizes, &ranges());
        assert!(r.fast_path);
        assert_eq!(r.bin_size[0], 100);
        assert_eq!(r.bins, (0..100u32).collect::<Vec<_>>());
    }

    #[test]
    fn offsets_are_exclusive_sums() {
        let sizes: Vec<usize> = (0..1000).map(|i| (i * 37) % 15_000).collect();
        let r = bin_rows(&sizes, &ranges());
        let mut acc = 0;
        for j in 0..NUM_BINS {
            assert_eq!(r.bin_offset[j], acc);
            acc += r.bin_size[j];
        }
        assert_eq!(acc, sizes.len());
    }

    #[test]
    fn prop_binning_partitions_any_input() {
        prop::check(
            "binning-partition",
            32,
            200,
            |rng, size| (0..size).map(|_| rng.below(30_000) as usize).collect::<Vec<_>>(),
            |sizes| {
                let r = bin_rows(sizes, &ranges());
                let total: usize = r.bin_size.iter().sum();
                if total != sizes.len() {
                    return Err(format!("bin sizes sum {total} != {}", sizes.len()));
                }
                let mut sorted: Vec<u32> = r.bins.clone();
                sorted.sort_unstable();
                for (i, &v) in sorted.iter().enumerate() {
                    if v != i as u32 {
                        return Err(format!("bins not a permutation at {i}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn shared_variant_uses_fewer_global_atomics() {
        let sizes: Vec<usize> = (0..5000).map(|i| (i % 700) + 1).collect();
        let r = bin_rows(&sizes, &ranges());
        let mut t_shared = Trace::new();
        emit_binning_kernels(&mut t_shared, "sym_binning", sizes.len(), &r, BinningVariant::SharedMemory, 0);
        let mut t_global = Trace::new();
        emit_binning_kernels(&mut t_global, "sym_binning", sizes.len(), &r, BinningVariant::GlobalAtomic, 0);
        let atomics = |t: &Trace| -> u64 {
            t.ops
                .iter()
                .filter_map(|op| match op {
                    crate::gpusim::trace::TraceOp::Launch(k) => Some(k.total_work().global_atomics),
                    _ => None,
                })
                .sum()
        };
        assert!(
            atomics(&t_global) > 50 * atomics(&t_shared),
            "global-atomic binning must issue far more atomics: {} vs {}",
            atomics(&t_global),
            atomics(&t_shared)
        );
    }

    #[test]
    fn speck_metadata_is_num_bins_wider() {
        let m = 10_000;
        assert!(
            metadata_bytes(m, BinningVariant::GlobalWide)
                > (NUM_BINS - 1) * metadata_bytes(m, BinningVariant::SharedMemory)
        );
    }

    #[test]
    fn fastpath_emits_three_kernels_sharedmem() {
        let sizes = vec![2usize; 2048];
        let r = bin_rows(&sizes, &ranges());
        let mut t = Trace::new();
        emit_binning_kernels(&mut t, "sym_binning", sizes.len(), &r, BinningVariant::SharedMemory, 0);
        assert_eq!(t.launches(), 3);
        // fast path kernel should be last and atomic-free
        if let crate::gpusim::trace::TraceOp::Launch(k) = &t.ops[2] {
            assert!(k.name.ends_with("fastpath"));
            assert_eq!(k.total_work().global_atomics, 0);
        } else {
            panic!("expected launch");
        }
    }
}
