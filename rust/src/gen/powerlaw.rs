//! Power-law (web-graph-like) generator: row sizes follow a truncated
//! power law, and column targets are drawn with preferential skew so hub
//! columns appear in many rows. Models webbase-1M (max 4700 nnz/row),
//! patents_main, wb-edu, scircuit from Table 3 — including the
//! one-enormous-row behaviour behind the paper's §6.3.4 load-balance and
//! §6.3.5 overlap case studies.

use super::build_rows;
use crate::sparse::Csr;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct PowerLaw {
    pub n: usize,
    /// Power-law exponent for row sizes (larger = more head-heavy).
    pub alpha: f64,
    /// Maximum row size (Table 3 "Max nnz/row").
    pub max_row: usize,
    /// Mean row-size target; row sizes are rescaled to hit this on average.
    pub mean_row: f64,
    /// Column skew: probability mass routed to a hub region of the column
    /// space (hubs make A² rows collide, lowering CR like real web graphs).
    pub hub_frac: f64,
    /// Number of rows forced to exactly `max_row` nonzeros (webbase-1M has
    /// a single giant row that dominates the numeric step).
    pub forced_giant_rows: usize,
}

impl PowerLaw {
    pub fn generate(&self, rng: &mut Rng) -> Csr {
        let n = self.n;
        let hub_cols = ((n as f64) * 0.01).max(8.0) as usize;
        // Pre-draw row sizes so we can rescale to the requested mean.
        let mut sizes: Vec<usize> = (0..n).map(|_| rng.power_law(self.max_row, self.alpha)).collect();
        let mean: f64 = sizes.iter().sum::<usize>() as f64 / n as f64;
        let scale = self.mean_row / mean.max(1e-9);
        for s in &mut sizes {
            *s = ((*s as f64 * scale).round() as usize).clamp(1, self.max_row).min(n);
        }
        for g in 0..self.forced_giant_rows.min(n) {
            // spread giants deterministically across the matrix
            let idx = (g * 2654435761) % n;
            sizes[idx] = self.max_row.min(n);
        }
        let mut tmp: Vec<u32> = Vec::new();
        let mut seen: std::collections::HashSet<u32> = std::collections::HashSet::new();
        build_rows(n, n, rng, |i, rng, out| {
            let k = sizes[i].min(n);
            if k * 4 >= n {
                // giant row: distinct uniform sample for speed
                rng.sample_distinct(n, k, &mut tmp);
                out.extend_from_slice(&tmp);
                return;
            }
            // draw until k *distinct* columns collected (build_rows dedups,
            // so duplicates would silently shrink the row)
            seen.clear();
            while seen.len() < k {
                let c = if rng.f64() < self.hub_frac {
                    rng.below(hub_cols as u64) as u32
                } else {
                    rng.below(n as u64) as u32
                };
                if seen.insert(c) {
                    out.push(c);
                }
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::stats::MatrixStats;

    fn webbase_like(n: usize) -> PowerLaw {
        PowerLaw {
            n,
            alpha: 2.0,
            max_row: n / 10,
            mean_row: 3.1,
            hub_frac: 0.3,
            forced_giant_rows: 1,
        }
    }

    #[test]
    fn has_giant_row() {
        let g = webbase_like(5000);
        let m = g.generate(&mut Rng::new(11));
        m.validate().unwrap();
        let s = MatrixStats::of(&m);
        assert!(
            s.max_row_nnz >= 400,
            "expected a giant row ~n/10, got max {}",
            s.max_row_nnz
        );
        assert!(s.avg_row_nnz < 10.0, "mean should stay small, got {}", s.avg_row_nnz);
    }

    #[test]
    fn skewed_row_distribution() {
        let g = PowerLaw { n: 2000, alpha: 2.2, max_row: 200, mean_row: 5.0, hub_frac: 0.2, forced_giant_rows: 0 };
        let m = g.generate(&mut Rng::new(5));
        let sizes: Vec<usize> = (0..m.rows).map(|i| m.row_nnz(i)).collect();
        let small = sizes.iter().filter(|&&s| s <= 5).count();
        let large = sizes.iter().filter(|&&s| s >= 50).count();
        assert!(small > m.rows / 2, "most rows should be small");
        assert!(large > 0, "tail should exist");
    }

    #[test]
    fn deterministic() {
        let g = webbase_like(1000);
        assert_eq!(g.generate(&mut Rng::new(1)), g.generate(&mut Rng::new(1)));
    }
}
