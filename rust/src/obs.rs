//! Request-scoped structured tracing: every request served by
//! [`crate::coordinator::Serve`] gets a trace ID (its job id) and a span
//! tree — admit, queue-wait, coalesce-attach, batch-residency, the
//! route decision with the rejected alternatives' modeled ns, per-shard
//! sub-job spans on the worker that ran them (with requeue /
//! speculation attempt chains), the barrier stitch — and the simulated
//! device phases (symbolic, numeric, setup…) attach as child spans of
//! the executing span, projected into the same host clock domain.
//!
//! Design rules, in order of importance:
//!
//! * **Off is free.** The tracer is threaded as `Option<Arc<Tracer>>`;
//!   with tracing off every hook is a `None` check — no clock reads, no
//!   allocations, no atomics — so the serve hot path reproduces the
//!   untraced baseline bit for bit.
//! * **Record at close.** A span is handed to the tracer only once it
//!   is finished (including abandoned attempts, which the failure paths
//!   record with an error tag). There is no "open span" registry to
//!   leak: a kill, requeue, or lost speculation can at worst *drop* a
//!   span, never leave one dangling.
//! * **Lock-cheap, bounded.** Spans land in per-lane sharded ring
//!   buffers ([`RING_SHARDS`] mutexes, [`RING_CAP`] spans each); a full
//!   ring evicts its oldest span and counts it in
//!   [`Tracer::dropped`]. Workers on different lanes contend on
//!   different shards.
//! * **One clock domain.** Every timestamp is host wall nanoseconds
//!   since the tracer's epoch. Simulated device time has no host clock,
//!   so device phases are *projected*: laid out proportionally to their
//!   simulated duration inside the executing span's host interval
//!   (raw simulated ns ride along in span args). Projection preserves
//!   nesting by construction, which is what the well-formedness
//!   property ([`check_well_formed`]) verifies.
//!
//! Export is Chrome trace-event JSON ([`chrome_trace_json`]) loadable
//! in Perfetto or `chrome://tracing`: one lane (tid) per worker plus a
//! front-door lane, complete (`"X"`) events for spans, instant (`"i"`)
//! events for chaos injections / requeues / speculation launches.

use std::collections::HashMap;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Lane (Perfetto tid) of the front door + dispatcher + barrier.
pub const LANE_FRONT: u64 = 0;
/// Lane of the dedicated block-engine worker.
pub const LANE_BLOCK: u64 = 1;

/// Lane of hash worker `id` (workers keep their lane across
/// generations: a respawned worker is the same failure domain).
pub fn lane_worker(id: usize) -> u64 {
    2 + id as u64
}

/// Human name for a lane, used in the exported thread-name metadata.
pub fn lane_name(lane: u64) -> String {
    match lane {
        LANE_FRONT => "front-door".to_string(),
        LANE_BLOCK => "block-worker".to_string(),
        w => format!("worker {}", w - 2),
    }
}

/// Tracing knobs (`--trace`, `--trace-dir`, `--trace-slow`,
/// `OPSPARSE_TRACE`, `OPSPARSE_TRACE_DIR`, `OPSPARSE_TRACE_SLOW`).
/// Default is off: no tracer is constructed at all.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceConfig {
    /// Collect spans. `--trace-dir` and `--trace-slow` imply `on`
    /// unless `--trace off` is given explicitly.
    pub enabled: bool,
    /// Directory the dispatcher writes `serve-trace.json` (and
    /// `serve-trace-slow.json`, when exemplars exist) into on shutdown.
    /// `None` keeps spans in memory only (tests read them through
    /// [`Tracer::snapshot_spans`]).
    pub dir: Option<String>,
    /// How many worst-serve-latency span trees to keep as exemplars.
    pub slow_k: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { enabled: false, dir: None, slow_k: 8 }
    }
}

/// One finished span (or instant event, when `instant` is set).
/// `parent == 0` means a root; ids are process-unique and start at 1.
#[derive(Clone, Debug)]
pub struct Span {
    /// Trace this span belongs to — the serve request / job id.
    pub trace: u64,
    pub id: u64,
    /// Parent span id, `0` for a root.
    pub parent: u64,
    pub name: String,
    /// Display lane: [`LANE_FRONT`], [`LANE_BLOCK`], or
    /// [`lane_worker`].
    pub lane: u64,
    /// Host ns since the tracer epoch.
    pub t0_ns: u64,
    pub t1_ns: u64,
    /// Key/value annotations (route, attempt, simulated ns, …).
    pub args: Vec<(String, String)>,
    /// Closed on a failure path (abandoned attempt, failed multiply).
    pub error: bool,
    /// A point event (chaos injection, requeue, speculation launch):
    /// `t1_ns == t0_ns` and it renders as a Perfetto instant.
    pub instant: bool,
}

/// Ring shards — lanes map onto these round-robin, so distinct workers
/// almost never contend on one mutex.
pub const RING_SHARDS: usize = 16;
/// Spans retained per shard before the oldest is evicted.
pub const RING_CAP: usize = 16_384;

struct RootOpen {
    span_id: u64,
    t0_ns: u64,
}

/// One kept slow-request exemplar: the whole span tree of one of the K
/// worst serve-latency requests seen so far.
#[derive(Clone, Debug)]
pub struct SlowTrace {
    pub trace: u64,
    pub wall_ns: u64,
    pub spans: Vec<Span>,
}

/// The collector. One per [`crate::coordinator::Serve`] (shared by the
/// front door, the dispatcher, the coordinator, and every worker).
pub struct Tracer {
    epoch: Instant,
    next_id: AtomicU64,
    shards: Vec<Mutex<VecDeque<Span>>>,
    dropped: AtomicU64,
    /// Open request roots: trace id → (root span id, start). An entry
    /// exists exactly while the request is in flight; hooks that may
    /// outlive the request (speculation losers) parent to the root only
    /// if it is still open — see [`Tracer::parent_for`].
    roots: Mutex<HashMap<u64, RootOpen>>,
    slow: Mutex<Vec<SlowTrace>>,
    slow_k: usize,
}

impl Tracer {
    pub fn new(cfg: &TraceConfig) -> Tracer {
        Tracer {
            epoch: Instant::now(),
            next_id: AtomicU64::new(1),
            shards: (0..RING_SHARDS).map(|_| Mutex::new(VecDeque::new())).collect(),
            dropped: AtomicU64::new(0),
            roots: Mutex::new(HashMap::new()),
            slow: Mutex::new(Vec::new()),
            slow_k: cfg.slow_k.max(1),
        }
    }

    /// Host ns since the tracer epoch — the one clock domain.
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// A fresh process-unique span id (never 0).
    pub fn next_span_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// File a finished span into its lane's ring.
    pub fn record(&self, span: Span) {
        let shard = &self.shards[(span.lane as usize) % RING_SHARDS];
        let mut ring = shard.lock().unwrap_or_else(|e| e.into_inner());
        if ring.len() >= RING_CAP {
            ring.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        ring.push_back(span);
    }

    /// Record an instant event (chaos injection, requeue, speculation
    /// launch): a point on the timeline, not an interval.
    pub fn instant(
        &self,
        trace: u64,
        parent: u64,
        lane: u64,
        name: &str,
        args: Vec<(String, String)>,
    ) {
        let t = self.now_ns();
        self.record(Span {
            trace,
            id: self.next_span_id(),
            parent,
            name: name.to_string(),
            lane,
            t0_ns: t,
            t1_ns: t,
            args,
            error: false,
            instant: true,
        });
    }

    /// Open the request root for `trace` and return its span id. The
    /// root is *recorded* later, by [`Tracer::close_root`] — until then
    /// it exists only as the map entry children look up.
    pub fn open_root(&self, trace: u64) -> u64 {
        let span_id = self.next_span_id();
        let t0_ns = self.now_ns();
        let mut roots = self.roots.lock().unwrap_or_else(|e| e.into_inner());
        roots.insert(trace, RootOpen { span_id, t0_ns });
        span_id
    }

    /// Root span id for an in-flight trace, or 0 if the request already
    /// resolved (a speculation loser finishing late parents to nothing
    /// and tags itself `late` — the tree stays well-formed because the
    /// root's recorded interval has already ended). Take your span's
    /// `t1` timestamp *before* calling this: the root closes at a time
    /// ≥ the lookup, so "entry present at lookup" implies your interval
    /// nests inside the root's.
    pub fn parent_for(&self, trace: u64) -> u64 {
        let roots = self.roots.lock().unwrap_or_else(|e| e.into_inner());
        roots.get(&trace).map(|r| r.span_id).unwrap_or(0)
    }

    /// Close and record the request root: removes the open entry first,
    /// then stamps `t1`, so every child that saw the root open has an
    /// interval inside the recorded one.
    pub fn close_root(&self, trace: u64, error: bool, args: Vec<(String, String)>) {
        let open = {
            let mut roots = self.roots.lock().unwrap_or_else(|e| e.into_inner());
            roots.remove(&trace)
        };
        let Some(open) = open else { return };
        let t1_ns = self.now_ns();
        self.record(Span {
            trace,
            id: open.span_id,
            parent: 0,
            name: "request".to_string(),
            lane: LANE_FRONT,
            t0_ns: open.t0_ns,
            t1_ns,
            args,
            error,
            instant: false,
        });
    }

    /// Project simulated device phases onto the executing span's host
    /// interval: each phase gets a child span sized proportionally to
    /// its simulated ns, laid out sequentially, with the raw simulated
    /// ns in args. Nesting inside `[host_t0, host_t1]` holds by
    /// construction — the clock-domain rule of this module.
    pub fn record_phases(
        &self,
        trace: u64,
        parent: u64,
        lane: u64,
        host_t0: u64,
        host_t1: u64,
        phases: &[(String, f64)],
    ) {
        let total: f64 = phases.iter().map(|(_, ns)| ns.max(0.0)).sum();
        if total <= 0.0 || host_t1 <= host_t0 {
            return;
        }
        let span_len = (host_t1 - host_t0) as f64;
        let mut cum = 0.0;
        for (name, sim_ns) in phases {
            let w = sim_ns.max(0.0);
            let t0 = host_t0 + (span_len * (cum / total)) as u64;
            cum += w;
            let t1 = host_t0 + (span_len * (cum / total)) as u64;
            self.record(Span {
                trace,
                id: self.next_span_id(),
                parent,
                name: format!("phase:{name}"),
                lane,
                t0_ns: t0.min(host_t1),
                t1_ns: t1.min(host_t1),
                args: vec![("sim_ns".to_string(), format!("{sim_ns:.0}"))],
                error: false,
                instant: false,
            });
        }
    }

    /// Consider `trace` (whose root must already be closed) for the
    /// slow-exemplar store: the K worst serve latencies keep their
    /// whole span tree in memory.
    pub fn note_slow(&self, trace: u64, wall_ns: u64) {
        let mut slow = self.slow.lock().unwrap_or_else(|e| e.into_inner());
        if slow.len() >= self.slow_k {
            let min = slow.iter().map(|s| s.wall_ns).min().unwrap_or(0);
            if wall_ns <= min {
                return;
            }
        }
        let spans = self.spans_of(trace);
        slow.push(SlowTrace { trace, wall_ns, spans });
        slow.sort_by(|x, y| y.wall_ns.cmp(&x.wall_ns).then(x.trace.cmp(&y.trace)));
        slow.truncate(self.slow_k);
    }

    /// All retained spans of one trace (copied, rings untouched).
    pub fn spans_of(&self, trace: u64) -> Vec<Span> {
        let mut out = Vec::new();
        for shard in &self.shards {
            let ring = shard.lock().unwrap_or_else(|e| e.into_inner());
            out.extend(ring.iter().filter(|s| s.trace == trace).cloned());
        }
        out.sort_by_key(|s| (s.t0_ns, s.id));
        out
    }

    /// Every retained span, ordered by start time (copied — callers can
    /// snapshot after shutdown, the rings stay intact).
    pub fn snapshot_spans(&self) -> Vec<Span> {
        let mut out = Vec::new();
        for shard in &self.shards {
            let ring = shard.lock().unwrap_or_else(|e| e.into_inner());
            out.extend(ring.iter().cloned());
        }
        out.sort_by_key(|s| (s.t0_ns, s.id));
        out
    }

    /// Spans evicted from full rings so far.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// The kept slow-request exemplars, worst first.
    pub fn slow_exemplars(&self) -> Vec<SlowTrace> {
        self.slow.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// The whole buffer as Chrome trace-event JSON.
    pub fn export_chrome(&self) -> String {
        chrome_trace_json(&self.snapshot_spans())
    }
}

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn write_args(out: &mut String, s: &Span) {
    out.push_str(&format!(
        "\"args\":{{\"trace\":{},\"span\":{},\"parent\":{}",
        s.trace, s.id, s.parent
    ));
    if s.error {
        out.push_str(",\"error\":true");
    }
    for (k, v) in &s.args {
        out.push_str(&format!(",\"{}\":\"{}\"", esc(k), esc(v)));
    }
    out.push('}');
}

/// Render spans as Chrome trace-event JSON (the object form, with a
/// `traceEvents` array), loadable in Perfetto / `chrome://tracing`.
/// Spans become complete (`"X"`) events, instants become `"i"` events,
/// and each lane gets a `thread_name` metadata event. Timestamps are
/// microseconds with ns resolution kept in the fraction.
pub fn chrome_trace_json(spans: &[Span]) -> String {
    let mut out = String::from("{\"displayTimeUnit\":\"ns\",\"traceEvents\":[\n");
    let mut first = true;
    let mut push = |ev: String, first: &mut bool| {
        if !*first {
            out.push_str(",\n");
        }
        *first = false;
        out.push_str(&ev);
    };
    push(
        "{\"ph\":\"M\",\"pid\":1,\"name\":\"process_name\",\
         \"args\":{\"name\":\"opsparse-serve\"}}"
            .to_string(),
        &mut first,
    );
    let mut lanes: Vec<u64> = spans.iter().map(|s| s.lane).collect();
    lanes.sort_unstable();
    lanes.dedup();
    for lane in lanes {
        push(
            format!(
                "{{\"ph\":\"M\",\"pid\":1,\"tid\":{lane},\"name\":\"thread_name\",\
                 \"args\":{{\"name\":\"{}\"}}}}",
                esc(&lane_name(lane))
            ),
            &mut first,
        );
    }
    for s in spans {
        let ts = s.t0_ns as f64 / 1000.0;
        let mut ev = if s.instant {
            format!(
                "{{\"name\":\"{}\",\"ph\":\"i\",\"s\":\"t\",\"ts\":{ts:.3},\
                 \"pid\":1,\"tid\":{},",
                esc(&s.name),
                s.lane
            )
        } else {
            let dur = s.t1_ns.saturating_sub(s.t0_ns) as f64 / 1000.0;
            format!(
                "{{\"name\":\"{}\",\"ph\":\"X\",\"ts\":{ts:.3},\"dur\":{dur:.3},\
                 \"pid\":1,\"tid\":{},",
                esc(&s.name),
                s.lane
            )
        };
        write_args(&mut ev, s);
        ev.push('}');
        push(ev, &mut first);
    }
    out.push_str("\n]}\n");
    out
}

/// The span-tree well-formedness property the trace suite gates on:
/// unique span ids; monotone non-negative durations; every non-root
/// parent id resolves to a recorded span of the same trace; children
/// (and instants) sit inside their parent's interval. Stable under
/// chaos kill / requeue / speculation because spans are recorded only
/// at close — an abandoned attempt closes with an error tag rather
/// than leaking an open span.
pub fn check_well_formed(spans: &[Span]) -> Result<(), String> {
    let mut by_id: HashMap<u64, &Span> = HashMap::with_capacity(spans.len());
    for s in spans {
        if s.id == 0 {
            return Err(format!("span id 0 is reserved (name {:?})", s.name));
        }
        if by_id.insert(s.id, s).is_some() {
            return Err(format!("duplicate span id {} (name {:?})", s.id, s.name));
        }
        if s.t1_ns < s.t0_ns {
            return Err(format!(
                "span {} ({:?}) has negative duration: t0={} t1={}",
                s.id, s.name, s.t0_ns, s.t1_ns
            ));
        }
        if s.instant && s.t1_ns != s.t0_ns {
            return Err(format!("instant {} ({:?}) has an interval", s.id, s.name));
        }
    }
    for s in spans {
        if s.parent == 0 {
            continue;
        }
        let Some(p) = by_id.get(&s.parent) else {
            return Err(format!(
                "span {} ({:?}) is an orphan: parent {} not recorded",
                s.id, s.name, s.parent
            ));
        };
        if p.trace != s.trace {
            return Err(format!(
                "span {} ({:?}) crosses traces: {} under parent trace {}",
                s.id, s.name, s.trace, p.trace
            ));
        }
        if p.instant {
            return Err(format!("span {} parents to instant {}", s.id, p.id));
        }
        if s.t0_ns < p.t0_ns || s.t1_ns > p.t1_ns {
            return Err(format!(
                "span {} ({:?}) [{}, {}] escapes parent {} ({:?}) [{}, {}]",
                s.id, s.name, s.t0_ns, s.t1_ns, p.id, p.name, p.t0_ns, p.t1_ns
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(trace: u64, id: u64, parent: u64, t0: u64, t1: u64) -> Span {
        Span {
            trace,
            id,
            parent,
            name: format!("s{id}"),
            lane: LANE_FRONT,
            t0_ns: t0,
            t1_ns: t1,
            args: vec![],
            error: false,
            instant: false,
        }
    }

    #[test]
    fn well_formedness_accepts_nested_and_rejects_escapes() {
        let good = vec![span(1, 1, 0, 0, 100), span(1, 2, 1, 10, 40), span(1, 3, 2, 12, 39)];
        assert!(check_well_formed(&good).is_ok());
        let escape = vec![span(1, 1, 0, 0, 100), span(1, 2, 1, 10, 140)];
        assert!(check_well_formed(&escape).unwrap_err().contains("escapes"));
        let orphan = vec![span(1, 2, 9, 10, 20)];
        assert!(check_well_formed(&orphan).unwrap_err().contains("orphan"));
        let negative = vec![span(1, 1, 0, 50, 10)];
        assert!(check_well_formed(&negative).unwrap_err().contains("negative"));
        let dup = vec![span(1, 1, 0, 0, 10), span(1, 1, 0, 0, 10)];
        assert!(check_well_formed(&dup).unwrap_err().contains("duplicate"));
        let cross = vec![span(1, 1, 0, 0, 100), span(2, 2, 1, 10, 20)];
        assert!(check_well_formed(&cross).unwrap_err().contains("crosses"));
    }

    #[test]
    fn root_lifecycle_nests_children_and_survives_late_closers() {
        let tr = Tracer::new(&TraceConfig::default());
        let root = tr.open_root(7);
        assert_eq!(tr.parent_for(7), root);
        let t0 = tr.now_ns();
        let t1 = tr.now_ns();
        let parent = tr.parent_for(7);
        tr.record(span(7, tr.next_span_id(), parent, t0, t1));
        tr.close_root(7, false, vec![("route".into(), "hash".into())]);
        // a speculation loser looking up the root after close parents
        // to nothing instead of escaping the closed interval
        assert_eq!(tr.parent_for(7), 0);
        let spans = tr.snapshot_spans();
        assert_eq!(spans.len(), 2);
        check_well_formed(&spans).unwrap();
        let root_span = spans.iter().find(|s| s.id == root).unwrap();
        assert_eq!(root_span.name, "request");
        assert!(root_span.args.iter().any(|(k, v)| k == "route" && v == "hash"));
    }

    #[test]
    fn ring_is_bounded_and_counts_drops() {
        let tr = Tracer::new(&TraceConfig::default());
        let n = RING_CAP + 100;
        for i in 0..n {
            tr.record(span(1, i as u64 + 1, 0, i as u64, i as u64 + 1));
        }
        assert_eq!(tr.snapshot_spans().len(), RING_CAP);
        assert_eq!(tr.dropped(), 100);
        // the oldest spans were the ones evicted
        assert!(tr.snapshot_spans().first().unwrap().id > 100);
    }

    #[test]
    fn phase_projection_stays_inside_the_host_interval() {
        let tr = Tracer::new(&TraceConfig::default());
        let parent_id = tr.next_span_id();
        tr.record(Span { args: vec![], ..span(3, parent_id, 0, 1_000, 2_000) });
        let phases = vec![
            ("setup".to_string(), 10.0),
            ("symbolic".to_string(), 30.0),
            ("numeric".to_string(), 60.0),
        ];
        tr.record_phases(3, parent_id, LANE_FRONT, 1_000, 2_000, &phases);
        let spans = tr.snapshot_spans();
        check_well_formed(&spans).unwrap();
        let kids: Vec<&Span> = spans.iter().filter(|s| s.parent == parent_id).collect();
        assert_eq!(kids.len(), 3);
        // proportional layout: numeric gets 60% of the host interval
        let numeric = kids.iter().find(|s| s.name == "phase:numeric").unwrap();
        assert_eq!(numeric.t1_ns - numeric.t0_ns, 600);
        assert!(kids.iter().all(|s| s.t0_ns >= 1_000 && s.t1_ns <= 2_000));
        // zero-total phases record nothing
        tr.record_phases(3, parent_id, LANE_FRONT, 1_000, 2_000, &[("x".to_string(), 0.0)]);
        assert_eq!(tr.snapshot_spans().len(), spans.len());
    }

    #[test]
    fn slow_store_keeps_the_k_worst() {
        let mut cfg = TraceConfig::default();
        cfg.slow_k = 3;
        let tr = Tracer::new(&cfg);
        for trace in 1..=10u64 {
            tr.open_root(trace);
            tr.close_root(trace, false, vec![]);
            tr.note_slow(trace, trace * 100);
        }
        let slow = tr.slow_exemplars();
        assert_eq!(slow.len(), 3);
        let walls: Vec<u64> = slow.iter().map(|s| s.wall_ns).collect();
        assert_eq!(walls, vec![1000, 900, 800], "worst first, bounded at K");
        assert!(slow.iter().all(|s| !s.spans.is_empty()), "exemplars carry their span tree");
    }

    #[test]
    fn chrome_export_has_lanes_events_and_escaping() {
        let tr = Tracer::new(&TraceConfig::default());
        let root = tr.open_root(1);
        tr.instant(1, root, lane_worker(0), "chaos_delay\"quote", vec![]);
        tr.close_root(1, false, vec![]);
        let json = tr.export_chrome();
        assert!(json.starts_with("{\"displayTimeUnit\":\"ns\",\"traceEvents\":[\n"));
        assert!(json.contains("\"ph\":\"X\""), "complete event for the root span");
        assert!(json.contains("\"ph\":\"i\""), "instant event");
        assert!(json.contains("chaos_delay\\\"quote"), "names are JSON-escaped");
        assert!(json.contains("\"thread_name\""));
        assert!(json.contains("worker 0"));
        assert!(json.contains("front-door"));
        assert_eq!(json.matches("\"ph\":\"X\"").count(), 1);
        // braces balance — cheap structural sanity without a parser
        assert_eq!(json.matches('{').count(), json.matches('}').count());
    }
}
