//! The OpSparse pipeline (paper Fig. 2): six-step two-phase SpGEMM with
//! the paper's optimizations as switchable flags, so the same code path
//! expresses OpSparse, the nsparse/spECK-like baselines, and the ablation
//! benches.
//!
//! Steps: **setup** (metadata malloc + n_prod kernel, overlapped §5.4) →
//! **symbolic binning** → **symbolic** (per-bin hash kernels, large bins
//! first §5.5, global-table fallback malloc overlapped) → **alloc C**
//! (exclusive-sum of nnz reusing `C.rpt` §5.3, C.col/C.val mallocs
//! interleaved §5.4) → **numeric binning** → **numeric** → **cleanup**
//! (all frees deferred here §5.5).
//!
//! Two cross-call reuse mechanisms extend the paper's per-call view for
//! serving workloads (see [`multiply_reuse`]):
//! * a [`DevicePool`] recycles every allocation, so warm calls issue zero
//!   `cudaMalloc`s and zero `cudaFree`s;
//! * a [`SymbolicReuse`] entry (cached per sparsity pattern) replays the
//!   symbolic phase's result, skipping the n_prod kernel, both symbolic
//!   binning passes, every symbolic hash kernel, and the nnz readback —
//!   the host uploads the cached `C.rpt` instead (async H2D).

use super::binning::{bin_rows, emit_binning_kernels, metadata_bytes, BinningResult};
use super::hash_table::ProbeStats;
use super::kernel_tables::{NumericRanges, SymbolicRanges, NUM_BINS};
use super::numeric::numeric_step;
use super::symbolic::symbolic_step;
use super::{BinningVariant, HashVariant};
use crate::gpusim::pool::DevicePool;
use crate::gpusim::trace::{BlockWork, Kernel, Trace};
use crate::sparse::stats::nprod_per_row;
use crate::sparse::Csr;
use crate::util::exclusive_sum;
use anyhow::{ensure, Result};

/// Pipeline configuration. `Default` is full OpSparse; the baselines and
/// ablations flip individual flags.
#[derive(Clone, Debug)]
pub struct OpSparseConfig {
    /// Binning range preset for the symbolic step (§5.7; paper: 1.2×).
    pub sym_ranges: SymbolicRanges,
    /// Binning range preset for the numeric step (§5.7; paper: 2×).
    pub num_ranges: NumericRanges,
    /// Hash-probe implementation (§5.2; paper: single-access).
    pub hash_variant: HashVariant,
    /// Binning implementation (§5.1; paper: shared-memory).
    pub binning_variant: BinningVariant,
    /// Allocate all metadata with one `cudaMalloc` (§5.3).
    pub combined_metadata_malloc: bool,
    /// Launch kernels before mallocs they don't depend on (§5.4).
    pub overlap_malloc: bool,
    /// Defer every `cudaFree` to the cleanup step (§5.5; nsparse frees the
    /// global hash table eagerly, serializing the device).
    pub deferred_free: bool,
    /// Reuse `C.rpt` for the n_prod / nnz arrays instead of separate
    /// allocations (§5.3; nsparse allocates two extra M-arrays).
    pub reuse_crpt: bool,
    /// CUDA streams for concurrent kernels (§5.5).
    pub num_streams: usize,
}

impl Default for OpSparseConfig {
    fn default() -> Self {
        OpSparseConfig {
            sym_ranges: SymbolicRanges::Sym12x,
            num_ranges: NumericRanges::Num2x,
            hash_variant: HashVariant::SingleAccess,
            binning_variant: BinningVariant::SharedMemory,
            combined_metadata_malloc: true,
            overlap_malloc: true,
            deferred_free: true,
            reuse_crpt: true,
            num_streams: 4,
        }
    }
}

impl OpSparseConfig {
    /// nsparse-like baseline: global-atomic binning, multi-access hashing,
    /// fully-occupied (1×) binning ranges, separate metadata mallocs, no
    /// overlap, eager `cudaFree` after the global-table kernel (§4).
    pub fn nsparse_like() -> Self {
        OpSparseConfig {
            sym_ranges: SymbolicRanges::Sym1x,
            num_ranges: NumericRanges::Num1x,
            hash_variant: HashVariant::MultiAccess,
            binning_variant: BinningVariant::GlobalAtomic,
            combined_metadata_malloc: false,
            overlap_malloc: false,
            deferred_free: false,
            reuse_crpt: false,
            num_streams: 4,
        }
    }

    /// spECK-like baseline: global-atomic binning over an `M × NUM_BINS`
    /// metadata layout, multi-access hashing, 1.5× numeric ranges (2/3
    /// table occupancy, §4.3), deferred free (§4.6), no malloc overlap.
    pub fn speck_like() -> Self {
        OpSparseConfig {
            sym_ranges: SymbolicRanges::Sym1x,
            num_ranges: NumericRanges::Num15x,
            hash_variant: HashVariant::MultiAccess,
            binning_variant: BinningVariant::GlobalWide,
            combined_metadata_malloc: false,
            overlap_malloc: false,
            deferred_free: true,
            reuse_crpt: false,
            num_streams: 4,
        }
    }
}

/// Everything a pipeline run produces: the result matrix, the device
/// trace (for simulation), and measured statistics.
#[derive(Clone, Debug)]
pub struct SpgemmOutput {
    pub c: Csr,
    pub trace: Trace,
    /// Total intermediate products (FLOPs = 2 × this).
    pub nprod: usize,
    /// Probe statistics: symbolic + numeric.
    pub sym_stats: ProbeStats,
    pub num_stats: ProbeStats,
    /// Rows recomputed by the symbolic global-table kernel.
    pub sym_fallback_rows: usize,
    /// True when the symbolic phase was replayed from a [`SymbolicReuse`]
    /// cache entry instead of computed.
    pub symbolic_skipped: bool,
}

impl SpgemmOutput {
    pub fn flops(&self) -> f64 {
        2.0 * self.nprod as f64
    }
}

/// The pattern-determined result of the symbolic phase, cacheable across
/// calls that share both operands' sparsity patterns (same `rpt`/`col`;
/// values are free to differ — see [`Csr::pattern_fingerprint`]).
///
/// **Contract:** an entry may only be replayed against operands whose
/// patterns exactly match the originating pair. [`multiply_reuse`]
/// rejects the wrong row *count* with an error; a same-sized but
/// different pattern cannot be detected cheaply and makes the numeric
/// phase panic on the first row whose nnz disagrees (it never silently
/// mis-sizes C). Key entries by both fingerprints, as the coordinator
/// cache does, and this is a ~2^-64-per-pair event.
#[derive(Clone, Debug)]
pub struct SymbolicReuse {
    /// Per-row nnz of C (what the paper stores in the reused `C.rpt`).
    pub row_nnz: Vec<usize>,
    /// Total intermediate products (the setup kernel's reduction).
    pub nprod: usize,
    /// Fallback-row count of the originating run (reporting only).
    pub fallback_rows: usize,
}

impl SymbolicReuse {
    /// Capture the cacheable part of a finished multiply.
    pub fn from_output(out: &SpgemmOutput) -> Self {
        let row_nnz = out.c.rpt.windows(2).map(|w| w[1] - w[0]).collect();
        SymbolicReuse { row_nnz, nprod: out.nprod, fallback_rows: out.sym_fallback_rows }
    }
}

/// Re-export of the setup-step n_prod kernel for the one-phase baseline.
pub fn nprod_kernel_for_tests(a: &Csr, stream: usize) -> Kernel {
    nprod_kernel(a, stream)
}

/// The n_prod kernel of the setup step: one thread per row of A walking
/// `B.rpt` lookups.
pub(crate) fn nprod_kernel(a: &Csr, stream: usize) -> Kernel {
    const TB: usize = 256;
    let nblocks = a.rows.div_ceil(TB).max(1);
    let blocks: Vec<BlockWork> = (0..nblocks)
        .map(|blk| {
            let lo = blk * TB;
            let hi = ((blk + 1) * TB).min(a.rows);
            let a_nnz: u64 = (lo..hi).map(|r| a.row_nnz(r) as u64).sum();
            BlockWork {
                // read a.rpt pairs + a.col, read b.rpt per element, write nprod
                global_bytes: (hi - lo) as u64 * 8 + a_nnz * 4 + a_nnz * 8 + (hi - lo) as u64 * 4,
                ..Default::default()
            }
        })
        .collect();
    Kernel {
        name: "setup_nprod".into(),
        step: "setup",
        stream,
        tb_size: TB,
        shared_bytes: 0,
        blocks,
    }
}

/// Route one allocation either through the pool (recycled on warm calls,
/// real `cudaMalloc` only on growth) or straight to the trace.
fn emit_malloc(
    trace: &mut Trace,
    pool: &mut Option<&mut DevicePool>,
    bytes: usize,
    label: &str,
    step: &'static str,
) {
    match pool.as_deref_mut() {
        Some(p) => {
            p.alloc(trace, bytes, label, step);
        }
        None => trace.malloc(bytes, label.to_string(), step),
    }
}

/// Emit the setup-step metadata mallocs per the configuration.
fn emit_metadata_mallocs(
    trace: &mut Trace,
    pool: &mut Option<&mut DevicePool>,
    m: usize,
    cfg: &OpSparseConfig,
) {
    let crpt_bytes = 4 * (m + 1);
    if cfg.combined_metadata_malloc {
        let meta = metadata_bytes(m, cfg.binning_variant)
            + if cfg.reuse_crpt { 0 } else { 2 * 4 * m }
            + 1024; // cub exclusive-sum temp storage (§5.3)
        emit_malloc(trace, pool, crpt_bytes + meta, "metadata+crpt", "setup");
    } else {
        emit_malloc(trace, pool, crpt_bytes, "c_rpt", "setup");
        emit_malloc(trace, pool, 4 * m, "bins", "setup");
        emit_malloc(trace, pool, 4 * NUM_BINS * 2 + 4, "bin_sizes", "setup");
        if !cfg.reuse_crpt {
            emit_malloc(trace, pool, 4 * m, "d_nprod", "setup");
            emit_malloc(trace, pool, 4 * m, "d_nnz", "setup");
        }
        if cfg.binning_variant == BinningVariant::GlobalWide {
            emit_malloc(trace, pool, 4 * m * NUM_BINS, "bins_wide", "setup");
        }
        emit_malloc(trace, pool, 1024, "cub_temp", "setup");
    }
}

/// Run the full two-phase SpGEMM pipeline: computes `C = A * B` on the
/// CPU while emitting the device trace the equivalent CUDA implementation
/// would execute. Per-call allocation, no cross-call reuse.
///
/// # Example
///
/// The quickstart in one breath: generate a suite matrix, compute `A²`,
/// verify it against the sort-merge reference, and simulate the trace on
/// the V100 model (see `examples/quickstart.rs` for the narrated
/// version):
///
/// ```
/// use opsparse::gen::suite::{suite_entry, SuiteScale};
/// use opsparse::gpusim::{simulate, V100};
/// use opsparse::spgemm::reference::spgemm_reference;
/// use opsparse::spgemm::{multiply, OpSparseConfig};
///
/// let a = suite_entry("poisson3Da").unwrap().generate(SuiteScale::Tiny);
/// let out = multiply(&a, &a, &OpSparseConfig::default()).unwrap();
/// assert!(out.c.approx_eq(&spgemm_reference(&a, &a), 1e-9));
///
/// let tl = simulate(&out.trace, &V100);
/// assert!(tl.gflops(out.flops()) > 0.0);
/// ```
///
/// Prefer [`crate::spgemm::request::SpgemmRequest`] in new code — this
/// wrapper is the builder with no options set, kept for existing
/// callers:
///
/// ```
/// use opsparse::sparse::Csr;
/// use opsparse::spgemm::{multiply, OpSparseConfig, SpgemmRequest};
///
/// let a = Csr::identity(64);
/// let cfg = OpSparseConfig::default();
/// let old = multiply(&a, &a, &cfg).unwrap();
/// let new = SpgemmRequest::new(&a, &a).config(&cfg).run().unwrap();
/// assert_eq!(old.c, new.c); // bit-identical
/// ```
pub fn multiply(a: &Csr, b: &Csr, cfg: &OpSparseConfig) -> Result<SpgemmOutput> {
    crate::spgemm::request::SpgemmRequest::new(a, b).config(cfg).run()
}

/// Run several multiplies back-to-back against one warm pool — the
/// batched entry the serving front door's
/// [`crate::coordinator::Coordinator::submit_batch`] path executes per
/// member. Each pair runs the exact singleton pipeline
/// ([`multiply_reuse`]), so outputs are bit-identical to one-at-a-time
/// calls; the batch only shares the pool (after the first member, a
/// same-shape member's trace is malloc-free) and amortizes the caller's
/// per-job overhead.
///
/// Per-pair results: one failed member (e.g. a dimension mismatch)
/// fails only its own slot.
///
/// ```
/// use opsparse::gpusim::DevicePool;
/// use opsparse::sparse::Csr;
/// use opsparse::spgemm::{multiply, multiply_batch, OpSparseConfig};
///
/// let a = Csr::identity(32);
/// let cfg = OpSparseConfig::default();
/// let solo = multiply(&a, &a, &cfg).unwrap();
/// let mut pool = DevicePool::new();
/// let batch = multiply_batch(&[(&a, &a), (&a, &a)], &cfg, Some(&mut pool));
/// for out in &batch {
///     assert_eq!(out.as_ref().unwrap().c, solo.c); // bit-identical
/// }
/// ```
pub fn multiply_batch(
    pairs: &[(&Csr, &Csr)],
    cfg: &OpSparseConfig,
    mut pool: Option<&mut DevicePool>,
) -> Vec<Result<SpgemmOutput>> {
    pairs.iter().map(|(a, b)| multiply_reuse(a, b, cfg, pool.as_deref_mut(), None)).collect()
}

/// [`multiply`] with the cross-call reuse hooks a warm worker provides:
///
/// * `pool` — every `cudaMalloc` of the pipeline (metadata, symbolic /
///   numeric global hash tables, `C.col`, `C.val`) is served from the
///   pool; the cleanup step releases stream-ordered instead of freeing,
///   so a warm call's trace contains **no** malloc and **no** free ops.
/// * `reuse` — a cached symbolic result for this exact sparsity pattern:
///   steps 1–3 collapse to one async H2D upload of the cached `C.rpt` +
///   bin ids, and the synchronizing nnz readback of step 4 disappears.
///
/// # Example
///
/// A warm worker's loop: the cold call grows the pool and yields a
/// cacheable symbolic result; the warm call recycles every allocation and
/// replays the symbolic phase:
///
/// ```
/// use opsparse::gpusim::DevicePool;
/// use opsparse::sparse::Csr;
/// use opsparse::spgemm::{multiply_reuse, OpSparseConfig, SymbolicReuse};
///
/// let a = Csr::identity(64);
/// let cfg = OpSparseConfig::default();
/// let mut pool = DevicePool::new();
///
/// let cold = multiply_reuse(&a, &a, &cfg, Some(&mut pool), None).unwrap();
/// let entry = SymbolicReuse::from_output(&cold);
///
/// let warm = multiply_reuse(&a, &a, &cfg, Some(&mut pool), Some(&entry)).unwrap();
/// assert_eq!(warm.c, cold.c); // bit-identical
/// assert!(warm.symbolic_skipped);
/// assert_eq!(warm.trace.malloc_calls(), 0); // pooled: no cudaMalloc
/// ```
pub fn multiply_reuse(
    a: &Csr,
    b: &Csr,
    cfg: &OpSparseConfig,
    mut pool: Option<&mut DevicePool>,
    reuse: Option<&SymbolicReuse>,
) -> Result<SpgemmOutput> {
    ensure!(a.cols == b.rows, "dimension mismatch: {}x{} * {}x{}", a.rows, a.cols, b.rows, b.cols);
    if let Some(r) = reuse {
        ensure!(
            r.row_nnz.len() == a.rows,
            "symbolic reuse entry is for a {}-row pattern, A has {} rows",
            r.row_nnz.len(),
            a.rows
        );
    }
    let m = a.rows;
    let mut trace = Trace::new();
    let mut sym_global_bytes = 0usize;

    // ---------------- step 1: setup ----------------
    if reuse.is_some() {
        // symbolic cache hit: the n_prod kernel exists only to feed the
        // symbolic binning we are about to skip. Metadata buffers are
        // still needed for C.rpt + the numeric bin arrays.
        emit_metadata_mallocs(&mut trace, &mut pool, m, cfg);
    } else if cfg.overlap_malloc {
        // launch the n_prod kernel first, then allocate metadata while it
        // runs (§5.4, Fig. 2)
        trace.launch(nprod_kernel(a, 0));
        emit_metadata_mallocs(&mut trace, &mut pool, m, cfg);
    } else {
        emit_metadata_mallocs(&mut trace, &mut pool, m, cfg);
        trace.launch(nprod_kernel(a, 0));
    }

    // ---------------- steps 2+3: symbolic (computed or replayed) --------
    let (sym_row_nnz, sym_stats, sym_fallback_count, nprod_total) = match reuse {
        Some(r) => {
            // upload the cached C.rpt and numeric bin ids from pinned host
            // memory; async, so it costs host time only
            trace.memcpy_h2d(4 * (m + 1) + 4 * m, "setup");
            (r.row_nnz.clone(), ProbeStats::default(), r.fallback_rows, r.nprod)
        }
        None => {
            let nprod = nprod_per_row(a, b);
            let nprod_total: usize = nprod.iter().sum();

            // step 2: symbolic binning
            let sym_binning: BinningResult = bin_rows(&nprod, &cfg.sym_ranges.ranges());
            emit_binning_kernels(&mut trace, "sym_binning", m, &sym_binning, cfg.binning_variant, 0);

            // step 3: symbolic
            let sym = symbolic_step(a, b, &sym_binning, cfg.hash_variant, "symbolic", cfg.num_streams);
            // global-table malloc for kernel8 rows: sized by their n_prod
            sym_global_bytes = sym
                .fallback_rows
                .iter()
                .map(|&r| {
                    let np: usize =
                        a.row_cols(r as usize).iter().map(|&k| b.row_nnz(k as usize)).sum();
                    (np.next_power_of_two().max(1024) * 2) * 4
                })
                .sum();
            let mut sym_kernels = sym.kernels.clone();
            let has_global_sym =
                sym_kernels.last().map(|k| k.name.contains("global")).unwrap_or(false);
            let global_sym_kernel = if has_global_sym { sym_kernels.pop() } else { None };
            if cfg.overlap_malloc && !sym_kernels.is_empty() && sym_global_bytes > 0 {
                // launch the first shared-table kernel, then malloc the global
                // table behind it (§5.4)
                let first = sym_kernels.remove(0);
                trace.launch(first);
                emit_malloc(&mut trace, &mut pool, sym_global_bytes, "sym_global_table", "symbolic");
                for k in sym_kernels {
                    trace.launch(k);
                }
            } else {
                if sym_global_bytes > 0 {
                    emit_malloc(&mut trace, &mut pool, sym_global_bytes, "sym_global_table", "symbolic");
                }
                for k in sym_kernels {
                    trace.launch(k);
                }
            }
            if let Some(k) = global_sym_kernel {
                trace.launch(k);
                if !cfg.deferred_free && sym_global_bytes > 0 && pool.is_none() {
                    // nsparse: cudaFree immediately after the global kernel,
                    // implicitly synchronizing the device (§4.6)
                    trace.free("sym_global_table", "symbolic");
                }
            }
            (sym.row_nnz, sym.stats, sym.fallback_rows.len(), nprod_total)
        }
    };

    // ---------------- step 4: alloc C ----------------
    let c_rpt = exclusive_sum(&sym_row_nnz);
    let c_nnz = *c_rpt.last().unwrap();
    let num_binning = bin_rows(&sym_row_nnz, &cfg.num_ranges.ranges());

    if reuse.is_some() {
        // the cached entry already knows nnz(C) host-side: no readback, no
        // exscan, no binning pass — straight to the result allocations
        emit_malloc(&mut trace, &mut pool, 4 * c_nnz, "c_col", "alloc_c");
        emit_malloc(&mut trace, &mut pool, 8 * c_nnz, "c_val", "alloc_c");
    } else {
        // readback of the total nnz (tiny D2H copy, synchronizes)
        trace.memcpy_d2h(8, "alloc_c");
        // exclusive sum on C.rpt (in-place cub DeviceScan, §5.3): a
        // streaming multi-block kernel
        let exscan = Kernel {
            name: "exscan_crpt".into(),
            step: "alloc_c",
            stream: 0,
            tb_size: 256,
            shared_bytes: 2048,
            blocks: (0..m.div_ceil(2048).max(1))
                .map(|blk| {
                    let lo = blk * 2048;
                    let rows = 2048.min(m + 1 - lo.min(m + 1));
                    BlockWork { global_bytes: rows as u64 * 8, ..Default::default() }
                })
                .collect(),
        };
        if cfg.overlap_malloc {
            // §5.4: the binning pass kernels and the C.rpt scan run on the
            // device while the C.col / C.val mallocs execute on the host
            emit_binning_kernels(&mut trace, "num_binning", m, &num_binning, cfg.binning_variant, 0);
            trace.launch(exscan);
            emit_malloc(&mut trace, &mut pool, 4 * c_nnz, "c_col", "alloc_c");
            emit_malloc(&mut trace, &mut pool, 8 * c_nnz, "c_val", "alloc_c");
        } else {
            emit_binning_kernels(&mut trace, "num_binning", m, &num_binning, cfg.binning_variant, 0);
            trace.launch(exscan);
            trace.device_sync("num_binning");
            emit_malloc(&mut trace, &mut pool, 4 * c_nnz, "c_col", "alloc_c");
            emit_malloc(&mut trace, &mut pool, 8 * c_nnz, "c_val", "alloc_c");
        }
    }

    // ---------------- step 5: numeric ----------------
    let num = numeric_step(a, b, &c_rpt, &num_binning, cfg.hash_variant, "numeric", cfg.num_streams);
    // global tables for kernel7 rows
    let num_global_bytes: usize = num_binning
        .bin_rows(NUM_BINS - 1)
        .iter()
        .map(|&r| {
            let nnz = c_rpt[r as usize + 1] - c_rpt[r as usize];
            (nnz.next_power_of_two().max(1024) * 2) * 12
        })
        .sum();
    let mut num_kernels = num.kernels.clone();
    let has_global_num = num_kernels.first().map(|k| k.name.contains("global")).unwrap_or(false);
    if cfg.overlap_malloc && has_global_num && num_kernels.len() > 1 {
        // §6.3.5: launch one shared-table kernel first, then the global
        // table malloc hides behind it; the global kernel follows.
        let global = num_kernels.remove(0); // kernel7 is emitted first
        // hide the global-table malloc behind the *largest* shared-table
        // kernel (the paper's kernel runs >1ms at full scale, §6.3.5)
        let biggest = num_kernels
            .iter()
            .enumerate()
            .max_by_key(|(_, k)| {
                let w = k.total_work();
                w.global_bytes + w.shared_accesses
            })
            .map(|(i, _)| i)
            .unwrap_or(0);
        let first_shared = num_kernels.remove(biggest);
        trace.launch(first_shared);
        emit_malloc(&mut trace, &mut pool, num_global_bytes, "num_global_table", "numeric");
        trace.launch(global);
        if !cfg.deferred_free && pool.is_none() {
            // nsparse behaviour: free right after the global kernel,
            // implicitly synchronizing before the remaining launches
            trace.free("num_global_table", "numeric");
        }
        for k in num_kernels {
            trace.launch(k);
        }
    } else {
        if num_global_bytes > 0 {
            emit_malloc(&mut trace, &mut pool, num_global_bytes, "num_global_table", "numeric");
        }
        let eager_free = !cfg.deferred_free && has_global_num && pool.is_none();
        for (i, k) in num_kernels.into_iter().enumerate() {
            let was_global = i == 0 && has_global_num;
            trace.launch(k);
            if was_global && eager_free {
                trace.free("num_global_table", "numeric");
            }
        }
    }

    // ---------------- step 6: cleanup ----------------
    trace.device_sync("cleanup");
    match pool.as_deref_mut() {
        Some(p) => {
            // stream-ordered release back to the pool: no cudaFree, no
            // implicit device synchronization — the §5.5 deferral taken to
            // its cross-call conclusion
            p.end_call();
        }
        None => {
            if cfg.deferred_free {
                if sym_global_bytes > 0 {
                    trace.free("sym_global_table", "cleanup");
                }
                if num_global_bytes > 0 {
                    trace.free("num_global_table", "cleanup");
                }
            }
            trace.free("metadata", "cleanup");
        }
    }

    Ok(SpgemmOutput {
        c: num.c,
        trace,
        nprod: nprod_total,
        sym_stats,
        num_stats: num.stats,
        sym_fallback_rows: sym_fallback_count,
        symbolic_skipped: reuse.is_some(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::suite::{suite_entry, SuiteScale};
    use crate::gen::uniform::Uniform;
    use crate::gpusim::{simulate, V100};
    use crate::spgemm::reference::spgemm_reference;
    use crate::util::rng::Rng;

    #[test]
    fn opsparse_matches_reference() {
        let mut rng = Rng::new(11);
        let a = Uniform { n: 300, per_row: 12, jitter: 6 }.generate(&mut rng);
        let out = multiply(&a, &a, &OpSparseConfig::default()).unwrap();
        let gold = spgemm_reference(&a, &a);
        assert!(out.c.approx_eq(&gold, 1e-12), "{:?}", out.c.diff(&gold, 1e-12));
        out.c.validate().unwrap();
    }

    #[test]
    fn baselines_match_reference_too() {
        let mut rng = Rng::new(12);
        let a = Uniform { n: 200, per_row: 10, jitter: 5 }.generate(&mut rng);
        let gold = spgemm_reference(&a, &a);
        for cfg in [OpSparseConfig::nsparse_like(), OpSparseConfig::speck_like()] {
            let out = multiply(&a, &a, &cfg).unwrap();
            assert!(out.c.approx_eq(&gold, 1e-12));
        }
    }

    #[test]
    fn rectangular_multiply() {
        let mut rng = Rng::new(13);
        let a = {
            let m = Uniform { n: 120, per_row: 6, jitter: 3 }.generate(&mut rng);
            crate::sparse::ops::row_slice(&m, 0, 80).unwrap() // 80 x 120
        };
        let b = Uniform { n: 120, per_row: 6, jitter: 3 }.generate(&mut rng);
        let out = multiply(&a, &b, &OpSparseConfig::default()).unwrap();
        let gold = spgemm_reference(&a, &b);
        assert!(out.c.approx_eq(&gold, 1e-12));
    }

    #[test]
    fn trace_simulates_and_opsparse_beats_baselines() {
        let e = suite_entry("cant").unwrap();
        let a = e.generate(SuiteScale::Tiny);
        let ops = multiply(&a, &a, &OpSparseConfig::default()).unwrap();
        let nsp = multiply(&a, &a, &OpSparseConfig::nsparse_like()).unwrap();
        let spk = multiply(&a, &a, &OpSparseConfig::speck_like()).unwrap();
        let t_ops = simulate(&ops.trace, &V100).total_ns;
        let t_nsp = simulate(&nsp.trace, &V100).total_ns;
        let t_spk = simulate(&spk.trace, &V100).total_ns;
        assert!(
            t_ops < t_nsp && t_ops < t_spk,
            "OpSparse should win: ops={t_ops} nsparse={t_nsp} speck={t_spk}"
        );
    }

    #[test]
    fn opsparse_allocates_less_metadata_than_speck() {
        let mut rng = Rng::new(14);
        let a = Uniform { n: 500, per_row: 8, jitter: 4 }.generate(&mut rng);
        let ops = multiply(&a, &a, &OpSparseConfig::default()).unwrap();
        let spk = multiply(&a, &a, &OpSparseConfig::speck_like()).unwrap();
        assert!(ops.trace.malloc_bytes() < spk.trace.malloc_bytes());
        assert!(ops.trace.malloc_calls() < spk.trace.malloc_calls());
    }

    #[test]
    fn empty_and_identity_edge_cases() {
        let z = Csr::zero(10, 10);
        let out = multiply(&z, &z, &OpSparseConfig::default()).unwrap();
        assert_eq!(out.c.nnz(), 0);
        let i = Csr::identity(50);
        let out = multiply(&i, &i, &OpSparseConfig::default()).unwrap();
        assert!(out.c.approx_eq(&Csr::identity(50), 1e-15));
    }

    #[test]
    fn dimension_mismatch_is_error() {
        let a = Csr::zero(3, 4);
        let b = Csr::zero(5, 3);
        assert!(multiply(&a, &b, &OpSparseConfig::default()).is_err());
    }

    #[test]
    fn flops_equal_twice_nprod() {
        let mut rng = Rng::new(15);
        let a = Uniform { n: 100, per_row: 7, jitter: 3 }.generate(&mut rng);
        let out = multiply(&a, &a, &OpSparseConfig::default()).unwrap();
        let nprod: usize = crate::sparse::stats::nprod_per_row(&a, &a).iter().sum();
        assert_eq!(out.nprod, nprod);
        assert_eq!(out.flops(), 2.0 * nprod as f64);
    }

    #[test]
    fn pooled_multiply_matches_unpooled_bit_for_bit() {
        let mut rng = Rng::new(16);
        let a = Uniform { n: 250, per_row: 10, jitter: 5 }.generate(&mut rng);
        let cfg = OpSparseConfig::default();
        let cold = multiply(&a, &a, &cfg).unwrap();
        let mut pool = DevicePool::new();
        let pooled = multiply_reuse(&a, &a, &cfg, Some(&mut pool), None).unwrap();
        assert_eq!(pooled.c, cold.c, "pooling must not change the numerics");
        assert_eq!(pooled.nprod, cold.nprod);
    }

    #[test]
    fn warm_pooled_call_issues_no_mallocs_or_frees() {
        let mut rng = Rng::new(17);
        let a = Uniform { n: 300, per_row: 9, jitter: 4 }.generate(&mut rng);
        let cfg = OpSparseConfig::default();
        let mut pool = DevicePool::new();
        let first = multiply_reuse(&a, &a, &cfg, Some(&mut pool), None).unwrap();
        assert!(first.trace.malloc_calls() > 0, "cold call grows the pool");
        let before = pool.stats();
        let second = multiply_reuse(&a, &a, &cfg, Some(&mut pool), None).unwrap();
        assert_eq!(second.trace.malloc_calls(), 0, "warm call must be malloc-free");
        let frees = second
            .trace
            .ops
            .iter()
            .filter(|op| matches!(op, crate::gpusim::TraceOp::Free { .. }))
            .count();
        assert_eq!(frees, 0, "pooled cleanup must not cudaFree");
        assert_eq!(pool.stats().delta_since(&before).device_bytes, 0);
    }

    #[test]
    fn symbolic_reuse_skips_the_symbolic_phase_and_matches() {
        let mut rng = Rng::new(18);
        let a = Uniform { n: 280, per_row: 11, jitter: 5 }.generate(&mut rng);
        let cfg = OpSparseConfig::default();
        let cold = multiply(&a, &a, &cfg).unwrap();
        let entry = SymbolicReuse::from_output(&cold);

        // same pattern, different values: reuse must still be exact
        let mut a2 = a.clone();
        for (i, v) in a2.val.iter_mut().enumerate() {
            *v += (i % 7) as f64 * 0.25;
        }
        let warm = multiply_reuse(&a2, &a2, &cfg, None, Some(&entry)).unwrap();
        let gold = spgemm_reference(&a2, &a2);
        assert!(warm.c.approx_eq(&gold, 1e-12), "{:?}", warm.c.diff(&gold, 1e-12));
        assert!(warm.symbolic_skipped);
        assert_eq!(warm.nprod, cold.nprod);
        // no symbolic work in the trace
        let sym_kernels = warm
            .trace
            .ops
            .iter()
            .filter(|op| op.step() == "symbolic" || op.step() == "sym_binning")
            .count();
        assert_eq!(sym_kernels, 0, "symbolic phase must be skipped");
        // and the simulated timeline is strictly faster
        let t_cold = simulate(&cold.trace, &V100).total_ns;
        let t_warm = simulate(&warm.trace, &V100).total_ns;
        assert!(t_warm < t_cold, "reuse should win: warm={t_warm} cold={t_cold}");
    }

    #[test]
    fn symbolic_reuse_rejects_wrong_shape() {
        let a = Csr::identity(8);
        let entry = SymbolicReuse { row_nnz: vec![1; 4], nprod: 4, fallback_rows: 0 };
        assert!(multiply_reuse(&a, &a, &OpSparseConfig::default(), None, Some(&entry)).is_err());
    }
}
