//! Structural operations on CSR matrices: transpose, scaling, filtering,
//! sub-matrix extraction, and equality helpers used across the framework.

use super::csr::Csr;
use anyhow::Result;

/// Transpose (counting-sort over columns; O(nnz + rows + cols)).
pub fn transpose(m: &Csr) -> Csr {
    let mut counts = vec![0usize; m.cols + 1];
    for &c in &m.col {
        counts[c as usize + 1] += 1;
    }
    for j in 0..m.cols {
        counts[j + 1] += counts[j];
    }
    let rpt = counts.clone();
    let mut col = vec![0u32; m.nnz()];
    let mut val = vec![0f64; m.nnz()];
    let mut cursor = counts;
    for i in 0..m.rows {
        let (cols, vals) = m.row(i);
        for (&c, &v) in cols.iter().zip(vals) {
            let p = cursor[c as usize];
            col[p] = i as u32;
            val[p] = v;
            cursor[c as usize] += 1;
        }
    }
    Csr { rows: m.cols, cols: m.rows, rpt, col, val }
}

/// Scale all values by `s`.
pub fn scale(m: &Csr, s: f64) -> Csr {
    let mut out = m.clone();
    for v in &mut out.val {
        *v *= s;
    }
    out
}

/// Drop entries with `|v| <= threshold` (structural filter).
pub fn drop_small(m: &Csr, threshold: f64) -> Csr {
    let mut rpt = vec![0usize; m.rows + 1];
    let mut col = Vec::with_capacity(m.nnz());
    let mut val = Vec::with_capacity(m.nnz());
    for i in 0..m.rows {
        let (cols, vals) = m.row(i);
        for (&c, &v) in cols.iter().zip(vals) {
            if v.abs() > threshold {
                col.push(c);
                val.push(v);
            }
        }
        rpt[i + 1] = col.len();
    }
    Csr { rows: m.rows, cols: m.cols, rpt, col, val }
}

/// Extract the sub-matrix of rows `[r0, r1)` (columns unchanged).
pub fn row_slice(m: &Csr, r0: usize, r1: usize) -> Result<Csr> {
    anyhow::ensure!(r0 <= r1 && r1 <= m.rows, "bad row slice [{r0},{r1}) of {}", m.rows);
    let base = m.rpt[r0];
    let rpt: Vec<usize> = m.rpt[r0..=r1].iter().map(|&p| p - base).collect();
    let col = m.col[m.rpt[r0]..m.rpt[r1]].to_vec();
    let val = m.val[m.rpt[r0]..m.rpt[r1]].to_vec();
    Csr::from_parts(r1 - r0, m.cols, rpt, col, val)
}

/// Element-wise sum `A + B` (same shape), merging sorted rows.
pub fn add(a: &Csr, b: &Csr) -> Result<Csr> {
    anyhow::ensure!(a.rows == b.rows && a.cols == b.cols, "shape mismatch in add");
    let mut rpt = vec![0usize; a.rows + 1];
    let mut col = Vec::with_capacity(a.nnz() + b.nnz());
    let mut val = Vec::with_capacity(a.nnz() + b.nnz());
    for i in 0..a.rows {
        let (ac, av) = a.row(i);
        let (bc, bv) = b.row(i);
        let (mut p, mut q) = (0usize, 0usize);
        while p < ac.len() || q < bc.len() {
            let take_a = q >= bc.len() || (p < ac.len() && ac[p] <= bc[q]);
            let take_b = p >= ac.len() || (q < bc.len() && bc[q] <= ac[p]);
            if take_a && take_b && ac[p] == bc[q] {
                let s = av[p] + bv[q];
                if s != 0.0 {
                    col.push(ac[p]);
                    val.push(s);
                }
                p += 1;
                q += 1;
            } else if take_a {
                col.push(ac[p]);
                val.push(av[p]);
                p += 1;
            } else {
                col.push(bc[q]);
                val.push(bv[q]);
                q += 1;
            }
        }
        rpt[i + 1] = col.len();
    }
    Csr::from_parts(a.rows, a.cols, rpt, col, val)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::dense::Dense;
    use crate::util::rng::Rng;

    pub(crate) fn random_csr(rows: usize, cols: usize, per_row: usize, seed: u64) -> Csr {
        let mut rng = Rng::new(seed);
        let mut rpt = vec![0usize];
        let mut col = Vec::new();
        let mut val = Vec::new();
        let mut scratch = Vec::new();
        for _ in 0..rows {
            let k = rng.range(0, per_row + 1);
            rng.sample_distinct(cols, k, &mut scratch);
            for &c in &scratch {
                col.push(c);
                val.push(rng.value());
            }
            rpt.push(col.len());
        }
        Csr::from_parts(rows, cols, rpt, col, val).unwrap()
    }

    #[test]
    fn transpose_involution() {
        for seed in 0..4 {
            let m = random_csr(23, 31, 5, seed);
            let tt = transpose(&transpose(&m));
            assert_eq!(m, tt);
        }
    }

    #[test]
    fn transpose_matches_dense() {
        let m = random_csr(8, 6, 3, 11);
        let t = transpose(&m);
        t.validate().unwrap();
        let dm = Dense::from(&m);
        let dt = Dense::from(&t);
        for i in 0..m.rows {
            for j in 0..m.cols {
                assert_eq!(dm.get(i, j), dt.get(j, i));
            }
        }
    }

    #[test]
    fn scale_and_drop() {
        let m = random_csr(10, 10, 4, 5);
        let s = scale(&m, 2.0);
        assert!(s.val.iter().zip(&m.val).all(|(a, b)| *a == 2.0 * b));
        let d = drop_small(&m, 1.0); // all |v| <= 1
        assert_eq!(d.nnz(), 0);
        let d0 = drop_small(&m, 0.0);
        assert_eq!(d0.nnz(), m.nnz());
    }

    #[test]
    fn row_slice_valid() {
        let m = random_csr(12, 9, 4, 8);
        let s = row_slice(&m, 3, 9).unwrap();
        assert_eq!(s.rows, 6);
        for i in 0..6 {
            assert_eq!(s.row(i), m.row(i + 3));
        }
        assert!(row_slice(&m, 5, 20).is_err());
    }

    #[test]
    fn add_matches_dense() {
        let a = random_csr(9, 9, 4, 21);
        let b = random_csr(9, 9, 4, 22);
        let c = add(&a, &b).unwrap();
        c.validate().unwrap();
        let (da, db, dc) = (Dense::from(&a), Dense::from(&b), Dense::from(&c));
        for i in 0..9 {
            for j in 0..9 {
                assert!((da.get(i, j) + db.get(i, j) - dc.get(i, j)).abs() < 1e-12);
            }
        }
    }
}

/// Sparse matrix-vector product `y = A·x` (used by the AMG smoother and
/// the application examples).
pub fn spmv(a: &Csr, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols, x.len(), "spmv dimension mismatch");
    let mut y = vec![0.0; a.rows];
    for i in 0..a.rows {
        let (cols, vals) = a.row(i);
        let mut acc = 0.0;
        for (&c, &v) in cols.iter().zip(vals) {
            acc += v * x[c as usize];
        }
        y[i] = acc;
    }
    y
}

/// Euclidean norm of a vector.
pub fn norm2(x: &[f64]) -> f64 {
    x.iter().map(|v| v * v).sum::<f64>().sqrt()
}

/// Diagonal of a square CSR matrix (0.0 where unset).
pub fn diagonal(a: &Csr) -> Vec<f64> {
    assert_eq!(a.rows, a.cols);
    (0..a.rows).map(|i| a.get(i, i)).collect()
}

#[cfg(test)]
mod spmv_tests {
    use super::*;
    use crate::sparse::Dense;
    use crate::util::rng::Rng;

    #[test]
    fn spmv_matches_dense() {
        let mut rng = Rng::new(17);
        let m = super::tests::random_csr(12, 9, 4, 21);
        let x: Vec<f64> = (0..9).map(|_| rng.value()).collect();
        let y = spmv(&m, &x);
        let d = Dense::from(&m);
        for i in 0..12 {
            let gold: f64 = (0..9).map(|j| d.get(i, j) * x[j]).sum();
            assert!((y[i] - gold).abs() < 1e-12);
        }
    }

    #[test]
    fn diagonal_and_norm() {
        let i3 = Csr::identity(3);
        assert_eq!(diagonal(&i3), vec![1.0, 1.0, 1.0]);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }
}
