//! `cargo bench --bench fig9_hashing` — regenerates paper Figure 9:
//! symbolic/numeric step time under single- vs multi-access hashing.

use opsparse::bench::figures;
use opsparse::gen::suite::SuiteScale;

fn main() {
    let scale = std::env::var("OPSPARSE_SCALE")
        .ok()
        .and_then(|s| SuiteScale::parse(&s))
        .unwrap_or(SuiteScale::Small);
    figures::fig9(scale).expect("fig9");
}
