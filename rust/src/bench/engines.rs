//! Engine-dispatch ablation: fixed-hash vs fixed-block vs measured
//! dispatch (`EngineMode::Auto`) over structurally distinct corpus
//! classes, recorded into `BENCH_engines.json`.
//!
//! Each class is a generator family at a fixed shape; repetitions vary
//! the seed, so the cross-seed spread is the sample variance the Welch
//! gates test against. Per seed the harness runs the full dispatch
//! lifecycle the coordinator runs in production: route cold (sampled
//! estimate seeds the priors), execute the picked engine, record the
//! engine-tagged measurement, and re-route until the pick is stable
//! under the [`DISPATCH_SWITCH_GAIN`] hysteresis band. Both engines are
//! always measured in the **same clock domain** — the hash side through
//! `simulate(&trace, &V100)`, the block side through
//! [`BlockEngine::simulated_ns`] — exactly the figures the engine-tagged
//! history folds.
//!
//! Blocking verdicts (CI reads the embedded gate objects):
//! * on **every** class, dispatched is statistically no worse than the
//!   better fixed engine at `DEFAULT_ALPHA`;
//! * on the blocky/FEM classes, dispatched is **strictly faster** than
//!   fixed hash (the dispatch win the tentpole claims);
//! * the native block engine's result is bitwise identical to the hash
//!   pipeline on every seed of every class.

use crate::coordinator::feedback::{Engine, ExecHistory, RunObservation};
use crate::coordinator::{EngineMode, Route, Router, RouterConfig};
use crate::gen::banded::Banded;
use crate::gen::powerlaw::PowerLaw;
use crate::gen::uniform::Uniform;
use crate::gpusim::{simulate, V100};
use crate::runtime::BlockEngine;
use crate::sparse::Csr;
use crate::spgemm::pipeline::{multiply, OpSparseConfig};
use crate::util::rng::Rng;
use crate::util::stats::{not_worse_gate, welch_test, GateResult, Samples, DEFAULT_ALPHA};
use anyhow::{ensure, Result};
use std::sync::{Arc, Mutex};

/// Default seed repetitions per class; enough spread for the Welch gates
/// without making `cargo bench --bench engines` minutes long.
pub const DEFAULT_ENGINE_REPS: usize = 5;

/// One corpus class: a named generator family plus whether the class is
/// blocky/FEM-structured (where the strict dispatched-beats-hash gate
/// applies).
struct ClassSpec {
    name: &'static str,
    blocky: bool,
    gen: fn(&mut Rng) -> Csr,
}

fn class_specs() -> [ClassSpec; 4] {
    [
        ClassSpec {
            name: "fem_banded_wide",
            blocky: true,
            gen: |rng| {
                Banded { n: 1000, per_row: 48, band: 40, contiguous_frac: 1.0 }.generate(rng)
            },
        },
        ClassSpec {
            name: "fem_banded_narrow",
            blocky: true,
            gen: |rng| {
                Banded { n: 800, per_row: 32, band: 28, contiguous_frac: 1.0 }.generate(rng)
            },
        },
        ClassSpec {
            name: "scattered_uniform",
            blocky: false,
            gen: |rng| Uniform { n: 2000, per_row: 6, jitter: 3 }.generate(rng),
        },
        ClassSpec {
            name: "scattered_powerlaw",
            blocky: false,
            gen: |rng| {
                PowerLaw {
                    n: 1500,
                    alpha: 2.1,
                    max_row: 64,
                    mean_row: 8.0,
                    hub_frac: 0.1,
                    forced_giant_rows: 0,
                }
                .generate(rng)
            },
        },
    ]
}

/// Per-class measurements for `BENCH_engines.json`.
#[derive(Clone, Debug)]
pub struct EngineClassRow {
    pub class: String,
    /// Whether the strict dispatched-beats-hash gate applies here.
    pub blocky: bool,
    pub reps: usize,
    pub hash_ns_mean: f64,
    pub block_ns_mean: f64,
    pub dispatched_ns_mean: f64,
    /// Seeds where the converged dispatch pick was the block engine.
    pub dispatched_block_picks: usize,
    /// Seeds where the cold (estimate-seeded) pick already matched the
    /// converged measured pick — the prior quality figure.
    pub cold_agreed: usize,
    /// Native block result bitwise identical to the hash pipeline on
    /// every seed.
    pub bit_identical: bool,
}

/// Whole-ablation report.
pub struct EnginesReport {
    pub reps: usize,
    pub rows: Vec<EngineClassRow>,
    pub gates: Vec<GateResult>,
    pub all_bit_identical: bool,
}

/// Strict one-sided gate: **pass only if the candidate is significantly
/// faster than the reference** (`H1: reference > candidate`, pass iff
/// `p < alpha`) — the inverse posture of
/// [`crate::util::stats::not_worse_gate`], for claims that must show a
/// win, not just parity.
fn strictly_faster_gate(
    name: &str,
    candidate: &Samples,
    reference: &Samples,
    alpha: f64,
) -> GateResult {
    let w = welch_test(reference, candidate); // H1: reference > candidate
    GateResult {
        name: name.to_string(),
        kind: "welch_one_sided".to_string(),
        pass: w.p_greater < alpha,
        p: w.p_greater,
        alpha,
        candidate_mean: candidate.mean(),
        reference_mean: reference.mean(),
        reps_candidate: candidate.n(),
        reps_reference: reference.n(),
        t: w.t,
        df: w.df,
        detail: "H1: reference > candidate; pass iff p < alpha (strict win)".to_string(),
    }
}

/// Run the dispatch lifecycle on one matrix: cold route, execute the
/// pick, record the engine-tagged measurement, re-route until stable.
/// Returns `(converged engine, cold engine)`.
fn dispatch_lifecycle(
    router: &Router,
    history: &Arc<Mutex<ExecHistory>>,
    a: &Csr,
    hash_ns: f64,
    block_ns: f64,
    nprod: u64,
) -> (Engine, Engine) {
    let key = (a.pattern_fingerprint(), a.pattern_fingerprint());
    let engine_of = |route: Route| match route {
        Route::Block | Route::ShardedBlock { .. } => Engine::Block,
        Route::Hash | Route::Sharded { .. } => Engine::Hash,
    };
    let ns_of = |e: Engine| match e {
        Engine::Hash => hash_ns,
        Engine::Block => block_ns,
    };
    let cold = engine_of(router.route(a, a));
    let mut pick = cold;
    // at most one switch can survive the hysteresis band, so two
    // measure-and-re-route rounds always converge
    for _ in 0..3 {
        let ns = ns_of(pick);
        history.lock().unwrap_or_else(|e| e.into_inner()).record(
            key,
            RunObservation {
                wall_ns: ns,
                nprod,
                engine: pick,
                engine_ns: ns,
                ..Default::default()
            },
        );
        let next = engine_of(router.route(a, a));
        if next == pick {
            break;
        }
        pick = next;
    }
    (pick, cold)
}

/// The whole ablation: every class × `reps` seeds × three engines.
pub fn engines_ablation(reps: usize) -> Result<EnginesReport> {
    let reps = reps.max(2);
    let cfg = OpSparseConfig::default();
    let mut rows = Vec::new();
    let mut gates = Vec::new();
    for (ci, spec) in class_specs().iter().enumerate() {
        let mut hash = Samples::new();
        let mut block = Samples::new();
        let mut dispatched = Samples::new();
        let mut block_picks = 0usize;
        let mut cold_agreed = 0usize;
        let mut bit_identical = true;
        for rep in 0..reps {
            let mut rng = Rng::new(0xE16_0000 + (ci as u64) * 1009 + rep as u64);
            let a = (spec.gen)(&mut rng);

            // fixed hash: the paper pipeline under the device simulator
            let out = multiply(&a, &a, &cfg)?;
            let hash_ns = simulate(&out.trace, &V100).total_ns;

            // fixed block: the native bit-exact engine, closed-form model
            let t = RouterConfig::default().t;
            let mut eng = BlockEngine::native(16, t)?;
            let c_block = eng.spgemm_csr(&a, &a)?;
            let block_ns = eng.simulated_ns(&V100);
            bit_identical &= c_block == out.c;
            ensure!(
                hash_ns > 0.0 && block_ns > 0.0,
                "{}: degenerate engine time (hash {hash_ns}, block {block_ns})",
                spec.name
            );

            // measured dispatch: fresh history per seed (each seed is an
            // independent deployment), default memory budget so the
            // engine choice is the only variable
            let history = Arc::new(Mutex::new(ExecHistory::new(16)));
            let router = Router::new(RouterConfig {
                engine_mode: EngineMode::Auto,
                dispatch_history: Some(Arc::clone(&history)),
                ..Default::default()
            });
            let (pick, cold) =
                dispatch_lifecycle(&router, &history, &a, hash_ns, block_ns, out.nprod as u64);
            let dispatched_ns = match pick {
                Engine::Hash => hash_ns,
                Engine::Block => block_ns,
            };
            if pick == Engine::Block {
                block_picks += 1;
            }
            if cold == pick {
                cold_agreed += 1;
            }
            hash.push(hash_ns);
            block.push(block_ns);
            dispatched.push(dispatched_ns);
        }

        // gate 1 (every class): dispatched no worse than the better
        // fixed engine
        let better = if hash.mean() <= block.mean() { &hash } else { &block };
        gates.push(not_worse_gate(
            &format!("engines_{}_dispatch_not_worse", spec.name),
            &dispatched,
            better,
            false,
            DEFAULT_ALPHA,
        ));
        // gate 2 (blocky/FEM classes): dispatched strictly beats fixed
        // hash — the measured-dispatch win, not just parity
        if spec.blocky {
            gates.push(strictly_faster_gate(
                &format!("engines_{}_dispatch_beats_hash", spec.name),
                &dispatched,
                &hash,
                DEFAULT_ALPHA,
            ));
        }
        rows.push(EngineClassRow {
            class: spec.name.to_string(),
            blocky: spec.blocky,
            reps,
            hash_ns_mean: hash.mean(),
            block_ns_mean: block.mean(),
            dispatched_ns_mean: dispatched.mean(),
            dispatched_block_picks: block_picks,
            cold_agreed,
            bit_identical,
        });
    }
    let all_bit_identical = rows.iter().all(|r| r.bit_identical);
    Ok(EnginesReport { reps, rows, gates, all_bit_identical })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engines_ablation_gates_pass_at_small_reps() {
        // 3 reps keeps the test fast; the gates must already hold — the
        // engine gap on these classes is orders of magnitude, not noise
        let report = engines_ablation(3).unwrap();
        assert_eq!(report.rows.len(), 4);
        assert!(report.all_bit_identical, "native block must match hash bitwise");
        for g in &report.gates {
            assert!(g.pass, "gate {} failed: p={} detail={}", g.name, g.p, g.detail);
        }
        for r in &report.rows {
            if r.blocky {
                assert_eq!(
                    r.dispatched_block_picks, r.reps,
                    "{}: dispatch must converge on block every seed",
                    r.class
                );
                assert!(r.block_ns_mean < r.hash_ns_mean, "{}: block must win", r.class);
            } else {
                assert_eq!(
                    r.dispatched_block_picks, 0,
                    "{}: dispatch must converge on hash every seed",
                    r.class
                );
                assert!(r.hash_ns_mean < r.block_ns_mean, "{}: hash must win", r.class);
            }
            assert_eq!(r.cold_agreed, r.reps, "{}: the cold estimate should agree", r.class);
        }
    }

    #[test]
    fn dispatch_lifecycle_recovers_from_a_wrong_cold_pick() {
        // force the cold estimate wrong by feeding the lifecycle engine
        // times that contradict the structural prior: a blocky matrix
        // (cold pick: block) whose "measured" block time is catastrophic
        // — far above even the pessimistic seeded hash prior (~nprod ns
        // here). The recorded measurement must hand dispatch to the hash
        // prior, whose own measurement then confirms the switch.
        let mut rng = Rng::new(0xBAD_C01D);
        let a = Banded { n: 1000, per_row: 48, band: 40, contiguous_frac: 1.0 }.generate(&mut rng);
        let history = Arc::new(Mutex::new(ExecHistory::new(16)));
        let router = Router::new(RouterConfig {
            engine_mode: EngineMode::Auto,
            dispatch_history: Some(Arc::clone(&history)),
            ..Default::default()
        });
        let (pick, cold) =
            dispatch_lifecycle(&router, &history, &a, 10_000.0, 1.0e9, 1_000);
        assert_eq!(cold, Engine::Block, "structural estimate picks block on FEM structure");
        assert_eq!(pick, Engine::Hash, "measurements must outvote the wrong estimate");
    }
}
