//! Sparsity-pattern cache: the serving-layer complement of the paper's
//! symbolic/numeric split.
//!
//! The symbolic phase depends only on the operands' sparsity patterns, so
//! a worker that sees the same `(A, B)` pattern twice — AMG re-setup on a
//! fixed mesh, MCL expansion after the pattern stabilizes, any `A·A`
//! power iteration — can replay the cached per-row nnz instead of
//! recomputing it (see [`crate::spgemm::SymbolicReuse`]). Entries are
//! keyed by both operands' [`crate::sparse::Csr::pattern_fingerprint`];
//! the cache is per-worker and bounded with insertion-order eviction
//! (FIFO beats LRU bookkeeping at this entry count, and the workloads
//! that benefit loop over a handful of patterns).

use crate::spgemm::SymbolicReuse;
use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

/// Key: fingerprints of A's and B's sparsity patterns.
pub type PatternKey = (u64, u64);

/// Bounded map from operand-pattern pairs to cached symbolic results.
#[derive(Debug)]
pub struct PatternCache {
    map: HashMap<PatternKey, Arc<SymbolicReuse>>,
    order: VecDeque<PatternKey>,
    capacity: usize,
    hits: u64,
    misses: u64,
}

impl PatternCache {
    /// `capacity` of 0 disables caching (every lookup misses).
    pub fn new(capacity: usize) -> Self {
        PatternCache {
            map: HashMap::new(),
            order: VecDeque::new(),
            capacity,
            hits: 0,
            misses: 0,
        }
    }

    /// Look up a pattern pair, counting the hit or miss.
    pub fn lookup(&mut self, key: PatternKey) -> Option<Arc<SymbolicReuse>> {
        match self.map.get(&key) {
            Some(e) => {
                self.hits += 1;
                Some(Arc::clone(e))
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert (or refresh) an entry, evicting the oldest beyond capacity.
    pub fn insert(&mut self, key: PatternKey, entry: Arc<SymbolicReuse>) {
        if self.capacity == 0 {
            return;
        }
        if self.map.insert(key, entry).is_none() {
            self.order.push_back(key);
            while self.order.len() > self.capacity {
                if let Some(old) = self.order.pop_front() {
                    self.map.remove(&old);
                }
            }
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(n: usize) -> Arc<SymbolicReuse> {
        Arc::new(SymbolicReuse { row_nnz: vec![1; n], nprod: n, fallback_rows: 0 })
    }

    #[test]
    fn hit_after_insert_miss_before() {
        let mut c = PatternCache::new(4);
        assert!(c.lookup((1, 2)).is_none());
        c.insert((1, 2), entry(3));
        let got = c.lookup((1, 2)).expect("hit");
        assert_eq!(got.row_nnz.len(), 3);
        assert_eq!(c.hits(), 1);
        assert_eq!(c.misses(), 1);
    }

    #[test]
    fn evicts_oldest_at_capacity() {
        let mut c = PatternCache::new(2);
        c.insert((1, 1), entry(1));
        c.insert((2, 2), entry(2));
        c.insert((3, 3), entry(3));
        assert_eq!(c.len(), 2);
        assert!(c.lookup((1, 1)).is_none(), "oldest entry must be evicted");
        assert!(c.lookup((2, 2)).is_some());
        assert!(c.lookup((3, 3)).is_some());
    }

    #[test]
    fn zero_capacity_disables() {
        let mut c = PatternCache::new(0);
        c.insert((1, 1), entry(1));
        assert!(c.lookup((1, 1)).is_none());
        assert!(c.is_empty());
    }

    #[test]
    fn reinsert_same_key_does_not_grow_order() {
        let mut c = PatternCache::new(2);
        c.insert((1, 1), entry(1));
        c.insert((1, 1), entry(5));
        c.insert((2, 2), entry(2));
        assert_eq!(c.len(), 2);
        assert_eq!(c.lookup((1, 1)).unwrap().row_nnz.len(), 5);
    }
}
