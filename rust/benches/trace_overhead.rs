//! `cargo bench --bench trace_overhead` — the tracing contracts: the
//! traced front door's throughput gated against a 5% overhead allowance
//! (one-sided Welch over adaptively many repetitions), plus the schema
//! contract run (sharded + speculative + chaos-gentle + batched traffic
//! with tracing on) whose span set must pass `check_well_formed` and
//! whose Chrome trace-event export feeds the CI python validator.
//!
//! Env:
//! * `OPSPARSE_BENCH_TRACE_JOBS=<n>` — jobs per repetition (default 16)
//! * `OPSPARSE_BENCH_JSON_TRACE=<path>` — record the report as JSON; CI
//!   writes `BENCH_trace.json` this way and blocks on the embedded
//!   overhead-gate verdict, `well_formed == true`, and
//!   `completed == jobs`.
//! * `OPSPARSE_BENCH_TRACE_EVENTS=<path>` — write the contract run's
//!   Chrome trace itself (CI: `BENCH_trace_events.json`), which the
//!   python gate loads with a real JSON parser and structurally checks.
//! * `OPSPARSE_STAT_{MIN_REPS,MAX_REPS,REL_HW,ALPHA}` — statistical
//!   runner knobs (see `util::stats::AdaptiveConfig::from_env`).
//!
//! The bench itself enforces the hard contracts too, so a plain
//! `cargo bench --bench trace_overhead` fails loudly without CI.

use opsparse::bench::{trace_bench, write_trace_events, write_trace_json};

fn main() {
    let jobs = std::env::var("OPSPARSE_BENCH_TRACE_JOBS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or(16);
    let report = trace_bench::trace_overhead(jobs).expect("trace_overhead bench");
    assert!(
        report.well_formed,
        "traced contract run produced a malformed span tree: {:?}",
        report.well_formed_err
    );
    assert_eq!(
        report.completed, report.jobs,
        "a contract-run request did not resolve Done under gentle chaos"
    );
    assert!(report.spans > 0 && report.shard_spans > 0, "contract run recorded no shard spans");
    assert!(
        report.chrome_json.contains("\"traceEvents\""),
        "chrome export missing the traceEvents array"
    );
    for g in &report.gates {
        assert!(
            g.pass,
            "{}: traced throughput significantly below the overhead allowance \
             (p={:.4} < alpha={}, {:.1} vs {:.1} over {} reps)",
            g.name, g.p, g.alpha, g.candidate_mean, g.reference_mean, g.reps_candidate
        );
    }
    if let Ok(path) = std::env::var("OPSPARSE_BENCH_JSON_TRACE") {
        write_trace_json(&path, &report).expect("write trace json");
    }
    if let Ok(path) = std::env::var("OPSPARSE_BENCH_TRACE_EVENTS") {
        write_trace_events(&path, &report).expect("write trace events");
    }
}
