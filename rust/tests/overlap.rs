//! Overlapped multi-device execution: the pipelined
//! broadcast/compute/gather schedule must only ever *re-time* the run,
//! never change it.
//!
//! Two property layers:
//!
//! 1. **Makespan dominance.** For every generator family, topology, and
//!    chunk size, the overlapped makespan is ≤ the serial makespan of
//!    the same traces; equality is reserved for the cases with nothing
//!    to pipeline (overlap disabled). On the power-law family with a
//!    chunked broadcast over PCIe, the saving must be strictly positive
//!    — the acceptance bar of the overlap PR.
//! 2. **Bit-identity.** Sharded results with overlap on vs off are
//!    identical (`rpt`/`col`/`val`) across the 4 generator families ×
//!    1/2/4/8 shards: the overlap annotation is simulation metadata, not
//!    a numeric path.

use opsparse::gen::kron::Kron;
use opsparse::gen::powerlaw::PowerLaw;
use opsparse::gen::stencil::{Grid, Stencil};
use opsparse::gen::uniform::Uniform;
use opsparse::gpusim::{Interconnect, MultiDevice, OverlapConfig, Topology, V100};
use opsparse::sparse::stats::nprod_per_row;
use opsparse::sparse::Csr;
use opsparse::spgemm::pipeline::OpSparseConfig;
use opsparse::spgemm::sharded::{multiply_sharded_with, ShardPlan};
use opsparse::util::prop::check;
use opsparse::util::rng::Rng;

/// One representative per generator family (the sharding test matrix).
fn family_matrices() -> Vec<(&'static str, Csr)> {
    let mut rng = Rng::new(4077);
    vec![
        ("uniform", Uniform { n: 400, per_row: 8, jitter: 4 }.generate(&mut rng)),
        (
            "powerlaw",
            PowerLaw {
                n: 500,
                alpha: 2.0,
                max_row: 60,
                mean_row: 4.0,
                hub_frac: 0.2,
                forced_giant_rows: 1,
            }
            .generate(&mut rng),
        ),
        (
            "stencil",
            Stencil { n: 400, grid: Grid::D2, reach: 1, keep: 1.0, diagonal: true }
                .generate(&mut rng),
        ),
        ("kron", Kron { scale: 8, edge_factor: 8, a: 0.57, b: 0.19, c: 0.19 }.generate(&mut rng)),
    ]
}

fn sharded_with_overlap(
    a: &Csr,
    shards: usize,
    overlap: OverlapConfig,
) -> opsparse::spgemm::ShardedOutput {
    let cfg = OpSparseConfig::default();
    let plan = ShardPlan::balanced(&nprod_per_row(a, a), shards);
    multiply_sharded_with(a, a, &cfg, &plan, None, overlap, None).expect("sharded multiply")
}

#[test]
fn overlapped_makespan_never_exceeds_serial_for_all_topologies_and_chunks() {
    let topologies = [
        Interconnect::pcie3(),
        Interconnect::nvlink(),
        Interconnect { topology: Topology::Ring, ..Interconnect::pcie3() },
        Interconnect { topology: Topology::OneToAll, ..Interconnect::nvlink() },
    ];
    for (name, a) in family_matrices() {
        let b_bytes = a.device_bytes();
        for shards in [2usize, 4, 8] {
            for chunk_bytes in [b_bytes + 1, b_bytes / 3 + 1, 64 << 10, 8 << 10] {
                let overlap = OverlapConfig { enabled: true, chunk_bytes };
                let out = sharded_with_overlap(&a, shards, overlap);
                for ic in &topologies {
                    let md = MultiDevice::simulate_overlapped(
                        out.traces(),
                        &V100,
                        ic,
                        b_bytes,
                        &out.c_block_bytes(),
                    )
                    .unwrap();
                    let serial = md.makespan_ns();
                    let over = md.overlapped_makespan_ns().unwrap();
                    assert!(
                        over <= serial + 1e-6,
                        "{name}: {shards} shards, chunk {chunk_bytes}B, {:?} {:.0}GB/s: \
                         overlapped {over} > serial {serial}",
                        ic.topology,
                        ic.bandwidth_gbps
                    );
                    assert!(md.overlap_saved_ns() >= -1e-6);
                }
            }
        }
    }
}

#[test]
fn overlapped_makespan_strictly_less_on_chunked_powerlaw_over_pcie() {
    // the acceptance strictness clause: a power-law matrix, PCIe
    // one-to-all, broadcast split into multiple chunks — pipelining must
    // actually save wall time at every multi-device count
    let (_, a) = family_matrices().into_iter().find(|(n, _)| *n == "powerlaw").unwrap();
    let b_bytes = a.device_bytes();
    let overlap = OverlapConfig { enabled: true, chunk_bytes: (b_bytes / 6).max(1) };
    assert!(overlap.chunks_for(b_bytes) > 1, "broadcast must be chunked");
    let ic = Interconnect::pcie3();
    for shards in [2usize, 4, 8] {
        let out = sharded_with_overlap(&a, shards, overlap);
        let md =
            MultiDevice::simulate_overlapped(out.traces(), &V100, &ic, b_bytes, &out.c_block_bytes())
                .unwrap();
        assert!(
            md.overlap_saved_ns() > 0.0,
            "{shards} shards: chunked pipelining saved nothing \
             (serial {:.1}us, overlapped {:.1}us)",
            md.makespan_ns() / 1e3,
            md.overlapped_makespan_ns().unwrap() / 1e3
        );
    }
}

#[test]
fn overlap_disabled_replays_the_serial_timeline_exactly() {
    // with overlap off the traces carry no chunk dependencies, and the
    // serial simulation of those traces equals PR 3's model: the same
    // timelines, the same makespan, nothing saved
    let (_, a) = family_matrices().into_iter().next().unwrap();
    let out = sharded_with_overlap(&a, 4, OverlapConfig::off());
    assert!(out.traces().all(|t| t.chunk_deps() == 0), "off = unannotated traces");
    let ic = Interconnect::pcie3();
    let serial = MultiDevice::simulate_with_interconnect(
        out.traces(),
        &V100,
        &ic,
        out.b_bytes,
        &out.c_block_bytes(),
    )
    .unwrap();
    let annotated = sharded_with_overlap(&a, 4, OverlapConfig::default());
    let serial_of_annotated = MultiDevice::simulate_with_interconnect(
        annotated.traces(),
        &V100,
        &ic,
        annotated.b_bytes,
        &annotated.c_block_bytes(),
    )
    .unwrap();
    // AwaitChunk markers are free on the serial path: identical figures
    assert_eq!(serial.makespan_ns(), serial_of_annotated.makespan_ns());
    assert_eq!(serial.compute_makespan_ns(), serial_of_annotated.compute_makespan_ns());
    for (t0, t1) in serial.timelines.iter().zip(&serial_of_annotated.timelines) {
        assert_eq!(t0.total_ns, t1.total_ns, "annotation changed a serial device timeline");
    }
}

#[test]
fn sharded_results_bit_identical_with_overlap_on_and_off() {
    // 4 families × 1/2/4/8 shards × overlap {on, off, tiny chunks}: the
    // stitched C never moves a bit
    let configs = [
        OverlapConfig::off(),
        OverlapConfig::default(),
        OverlapConfig { enabled: true, chunk_bytes: 4 << 10 },
    ];
    for (name, a) in family_matrices() {
        let gold = sharded_with_overlap(&a, 1, OverlapConfig::off()).c;
        for shards in [1usize, 2, 4, 8] {
            for (i, overlap) in configs.iter().enumerate() {
                let out = sharded_with_overlap(&a, shards, *overlap);
                assert_eq!(
                    out.c, gold,
                    "{name}: {shards} shards, overlap config #{i} changed the result"
                );
                out.c.validate().unwrap();
            }
        }
    }
}

#[test]
fn overlapped_makespan_bounded_on_every_suite_matrix_and_shard_count() {
    // the acceptance sweep: every generator-suite matrix at Tiny scale,
    // every shard count, default PCIe — overlapped ≤ serial, always
    use opsparse::gen::suite::{entries, SuiteScale};
    let ic = Interconnect::pcie3();
    let overlap = OverlapConfig { enabled: true, chunk_bytes: 64 << 10 };
    for e in entries() {
        let a = e.generate(SuiteScale::Tiny);
        let b_bytes = a.device_bytes();
        for shards in [2usize, 4, 8] {
            let out = sharded_with_overlap(&a, shards, overlap);
            let md = MultiDevice::simulate_overlapped(
                out.traces(),
                &V100,
                &ic,
                b_bytes,
                &out.c_block_bytes(),
            )
            .unwrap();
            assert!(
                md.overlapped_makespan_ns().unwrap() <= md.makespan_ns() + 1e-6,
                "{}: {shards} shards: overlapped {:.1}us > serial {:.1}us",
                e.name,
                md.overlapped_makespan_ns().unwrap() / 1e3,
                md.makespan_ns() / 1e3
            );
        }
    }
}

#[test]
fn overlap_dominance_property_randomized() {
    // randomized sweep on top of the fixed family matrix: random uniform
    // matrices, random shard counts and chunk sizes, both topologies —
    // overlapped ≤ serial must hold everywhere
    check(
        "overlapped_makespan_le_serial",
        24,
        300,
        |rng, size| {
            let n = rng.range(32, size.max(33));
            let a = Uniform { n, per_row: 6, jitter: 3 }.generate(rng);
            let shards = 1usize << rng.range(1, 4); // 2, 4, or 8
            let chunk_bytes = 1usize << rng.range(10, 22);
            let ring = rng.range(0, 2) == 1;
            (a, shards, chunk_bytes, ring)
        },
        |(a, shards, chunk_bytes, ring)| {
            let overlap = OverlapConfig { enabled: true, chunk_bytes: *chunk_bytes };
            let out = sharded_with_overlap(a, *shards, overlap);
            let ic = if *ring { Interconnect::nvlink() } else { Interconnect::pcie3() };
            let md = MultiDevice::simulate_overlapped(
                out.traces(),
                &V100,
                &ic,
                a.device_bytes(),
                &out.c_block_bytes(),
            )
            .map_err(|e| format!("simulate_overlapped failed: {e:#}"))?;
            let (serial, over) = (md.makespan_ns(), md.overlapped_makespan_ns().unwrap());
            if over > serial + 1e-6 {
                return Err(format!("overlapped {over} > serial {serial}"));
            }
            Ok(())
        },
    );
}
