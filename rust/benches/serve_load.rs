//! `cargo bench --bench serve_load` — the serving front door under a
//! concurrent-identical load: coalesced vs uncoalesced rows (throughput,
//! executed jobs, symbolic executions, coalesce hits, p50/p99 serve
//! latency, max queue depth, bit-identity), plus the warm-start
//! persistence round trip and the all-knobs-off baseline-parity check.
//!
//! Env:
//! * `OPSPARSE_SCALE=tiny|small|medium` (default tiny)
//! * `OPSPARSE_BENCH_SERVE_JOBS=<n>` — identical requests (default 32)
//! * `OPSPARSE_BENCH_JSON_SERVE=<path>` — record the report as JSON; CI
//!   writes `BENCH_serve.json` this way, next to the other `BENCH_*`
//!   baselines, and blocks on: the embedded Welch-gate verdict (coalesced
//!   throughput not significantly below uncoalesced over adaptively many
//!   repetitions), `sym_executions == 1` and `coalesce_hits == jobs − 1`
//!   on the coalesced row, bit-identical fan-out on both rows, and the
//!   `persist_route_stable` / `baseline_match` verdicts.
//! * `OPSPARSE_STAT_{MIN_REPS,MAX_REPS,REL_HW,ALPHA}` — statistical
//!   runner knobs (see `util::stats::AdaptiveConfig::from_env`).
//!
//! The bench itself enforces the hard contracts too, so a plain
//! `cargo bench --bench serve_load` fails loudly without CI. The
//! throughput comparison is a hypothesis test, not a point comparison —
//! real wall clock is noisy, and a one-run flake must not fail the gate.

use opsparse::bench::{serve_bench, write_serve_json};
use opsparse::gen::suite::SuiteScale;

fn main() {
    let scale = std::env::var("OPSPARSE_SCALE")
        .ok()
        .and_then(|s| SuiteScale::parse(&s))
        .unwrap_or(SuiteScale::Tiny);
    let jobs = std::env::var("OPSPARSE_BENCH_SERVE_JOBS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or(32);
    let report = serve_bench::serve_load(jobs, scale).expect("serve_load bench");
    let coalesced = &report.rows[0];
    let uncoalesced = &report.rows[1];
    assert!(coalesced.bit_identical, "coalesced results diverged from independent multiplies");
    assert!(uncoalesced.bit_identical, "uncoalesced results diverged from independent multiplies");
    assert_eq!(
        coalesced.sym_executions, 1,
        "{} identical in-flight requests must execute exactly one symbolic phase",
        report.jobs
    );
    assert_eq!(
        coalesced.coalesce_hits,
        report.jobs as u64 - 1,
        "every request after the leader must coalesce"
    );
    for g in &report.gates {
        assert!(
            g.pass,
            "{}: candidate significantly worse than reference \
             (p={:.4} < alpha={}, {:.1} vs {:.1} over {} reps)",
            g.name, g.p, g.alpha, g.candidate_mean, g.reference_mean, g.reps_candidate
        );
    }
    assert!(report.persist_route_stable, "warm-start persistence round trip not route-stable");
    assert!(report.baseline_match, "all-knobs-off front door diverged from the raw coordinator");
    if let Ok(path) = std::env::var("OPSPARSE_BENCH_JSON_SERVE") {
        write_serve_json(&path, &report).expect("write serve json");
    }
}
