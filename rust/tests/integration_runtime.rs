//! Integration tests for the PJRT runtime + BSR block engine.
//! Require the `pjrt` cargo feature and `make artifacts` to have run (the
//! Makefile test target does). Without the feature the whole suite skips:
//! the stub client cannot execute artifacts.

use opsparse::gen::banded::Banded;
use opsparse::runtime::{artifacts_available, default_artifacts_dir, BlockEngine, PjrtRuntime};
use opsparse::sparse::{Bsr, Csr};
use opsparse::spgemm::reference::spgemm_reference;
use opsparse::util::rng::Rng;

/// True when the PJRT-backed tests can run; prints a skip note otherwise.
fn pjrt_ready() -> bool {
    if !opsparse::runtime::pjrt_compiled() {
        eprintln!("skipping: opsparse built without the `pjrt` feature");
        return false;
    }
    true
}

fn need_artifacts() {
    assert!(
        artifacts_available(),
        "artifacts missing — run `make artifacts` before `cargo test`"
    );
}

#[test]
fn pjrt_client_boots() {
    if !pjrt_ready() {
        assert!(PjrtRuntime::cpu().is_err(), "stub client must refuse to boot");
        return;
    }
    let rt = PjrtRuntime::cpu().expect("PJRT cpu client");
    assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
}

#[test]
fn block_matmul_artifact_executes_correct_numerics() {
    if !pjrt_ready() {
        return;
    }
    need_artifacts();
    let dir = default_artifacts_dir();
    let mut rt = PjrtRuntime::cpu().unwrap();
    let (p, t) = (64usize, 16usize);
    let path = dir.join(format!("block_matmul_p{p}_t{t}_f64.hlo.txt"));
    // identity in the first pair slot, zeros elsewhere
    let mut a = vec![0f64; p * t * t];
    let mut b = vec![0f64; p * t * t];
    for i in 0..t {
        a[i * t + i] = 1.0; // A[0] = I
    }
    for i in 0..t * t {
        b[i] = i as f64; // B[0] = ramp
    }
    let dims = [p, t, t];
    let out = rt.execute_f64(&path, &[(&a, &dims), (&b, &dims)]).unwrap();
    assert_eq!(out.len(), p * t * t);
    // C[0] = I @ B[0] = B[0]
    for i in 0..t * t {
        assert!((out[i] - b[i]).abs() < 1e-12, "slot {i}: {} vs {}", out[i], b[i]);
    }
    // all other pairs are zero
    assert!(out[t * t..].iter().all(|&v| v == 0.0));
    assert_eq!(rt.cached(), 1);
}

#[test]
fn row_window_artifact_executes() {
    if !pjrt_ready() {
        return;
    }
    need_artifacts();
    let dir = default_artifacts_dir();
    let mut rt = PjrtRuntime::cpu().unwrap();
    let (r, k, w) = (64usize, 32usize, 256usize);
    let path = dir.join(format!("row_window_r{r}_k{k}_w{w}_f64.hlo.txt"));
    let mut a = vec![0f64; r * k];
    let mut b = vec![0f64; r * k * w];
    // row 0: a = [1, 2, 0...], b[0] rows 0/1 = ones
    a[0] = 1.0;
    a[1] = 2.0;
    for j in 0..w {
        b[j] = 1.0; // row 0, k=0
        b[w + j] = 1.0; // row 0, k=1
    }
    let out = rt
        .execute_f64(&path, &[(&a, &[r, k]), (&b, &[r, k, w])])
        .unwrap();
    assert_eq!(out.len(), r * w);
    for j in 0..w {
        assert!((out[j] - 3.0).abs() < 1e-12, "col {j}: {}", out[j]);
    }
}

#[test]
fn block_engine_bsr_spgemm_matches_reference() {
    if !pjrt_ready() {
        return;
    }
    need_artifacts();
    let mut engine = BlockEngine::load(&default_artifacts_dir(), 64, 16).unwrap();
    let mut rng = Rng::new(505);
    // blocky FEM-like matrix: the engine's natural workload
    let a = Banded { n: 160, per_row: 24, band: 20, contiguous_frac: 1.0 }.generate(&mut rng);
    let got = engine.spgemm_csr(&a, &a).unwrap();
    let gold = spgemm_reference(&a, &a);
    assert!(
        got.approx_eq(&gold, 1e-9),
        "block engine mismatch: {:?}",
        got.diff(&gold, 1e-9)
    );
    assert!(engine.stats.pairs > 0);
    assert!(engine.stats.batches > 0);
}

#[test]
fn block_engine_rectangular_and_padding() {
    if !pjrt_ready() {
        return;
    }
    need_artifacts();
    let mut engine = BlockEngine::load(&default_artifacts_dir(), 64, 16).unwrap();
    let mut rng = Rng::new(506);
    // dims not divisible by T exercise the BSR padding path
    let a = Banded { n: 77, per_row: 10, band: 9, contiguous_frac: 0.8 }.generate(&mut rng);
    let got = engine.spgemm_csr(&a, &a).unwrap();
    let gold = spgemm_reference(&a, &a);
    assert!(got.approx_eq(&gold, 1e-9), "{:?}", got.diff(&gold, 1e-9));
}

#[test]
fn block_engine_empty_matrix() {
    if !pjrt_ready() {
        return;
    }
    need_artifacts();
    let mut engine = BlockEngine::load(&default_artifacts_dir(), 64, 16).unwrap();
    let z = Csr::zero(32, 32);
    let got = engine.spgemm_csr(&z, &z).unwrap();
    assert_eq!(got.nnz(), 0);
}

#[test]
fn bsr_roundtrip_through_engine_block_size() {
    let mut rng = Rng::new(507);
    let a = Banded { n: 64, per_row: 8, band: 8, contiguous_frac: 0.5 }.generate(&mut rng);
    let b = Bsr::from_csr(&a, 16).unwrap();
    assert_eq!(b.to_csr().unwrap(), a);
}

#[test]
fn row_window_engine_matches_reference_rows() {
    if !pjrt_ready() {
        return;
    }
    need_artifacts();
    use opsparse::runtime::RowWindowEngine;
    let mut engine = RowWindowEngine::load(&default_artifacts_dir(), 64, 32, 256).unwrap();
    let mut rng = Rng::new(606);
    // banded matrix: every row's window span is bounded by the band
    let a = Banded { n: 300, per_row: 12, band: 40, contiguous_frac: 0.5 }.generate(&mut rng);
    let rows: Vec<u32> = (0..a.rows as u32).collect();
    let (results, overflow) = engine.compute_rows(&a, &a, &rows).unwrap();
    assert!(overflow.len() < a.rows / 4, "most rows should fit: {} overflow", overflow.len());
    let gold = spgemm_reference(&a, &a);
    for (row, sparse) in &results {
        let i = *row as usize;
        let (gc, gv) = gold.row(i);
        let got_cols: Vec<u32> = sparse.iter().map(|&(c, _)| c).collect();
        assert_eq!(got_cols, gc, "row {i} structure");
        for (j, &(_, v)) in sparse.iter().enumerate() {
            assert!((v - gv[j]).abs() < 1e-9 * gv[j].abs().max(1.0), "row {i} value {j}");
        }
    }
    assert!(engine.stats.batches > 0);
}

#[test]
fn row_window_engine_rejects_wide_rows() {
    if !pjrt_ready() {
        return;
    }
    need_artifacts();
    use opsparse::runtime::RowWindowEngine;
    let engine = RowWindowEngine::load(&default_artifacts_dir(), 64, 32, 256).unwrap();
    // a row referencing columns 0 and 10_000 cannot fit a 256-wide window
    let a = Csr::from_parts(
        2,
        20_000,
        vec![0, 2, 2],
        vec![0, 10_000],
        vec![1.0, 1.0],
    )
    .unwrap();
    let b = Csr::identity(20_000);
    assert!(!engine.row_fits(&a, &b, 0));
}
