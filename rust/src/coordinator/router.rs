//! Job routing: decide per matrix pair whether to run the hash pipeline
//! or the PJRT block engine.
//!
//! The block engine wins when the matrices are *blocky* — their nonzeros
//! cluster into dense `T×T` tiles (FEM matrices with contiguous runs, the
//! high-CR half of Table 3). For scattered matrices the padding overhead
//! of dense blocks dominates and the hash path wins. The router estimates
//! block fill on a row sample, mirroring spECK's lightweight pre-analysis
//! (§3) — cheap, structure-only, value-free.

use crate::sparse::Csr;

/// Execution path for a job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    /// Two-phase hash pipeline (the paper's OpSparse).
    Hash,
    /// PJRT BSR block engine.
    Block,
}

/// Router configuration.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Block size of the compiled engine.
    pub t: usize,
    /// Minimum estimated tile fill ratio to route to the block engine.
    pub min_fill: f64,
    /// Rows sampled for the estimate.
    pub sample_rows: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig { t: 16, min_fill: 0.25, sample_rows: 256 }
    }
}

/// Structure-only router.
#[derive(Clone, Debug, Default)]
pub struct Router {
    pub cfg: RouterConfig,
}

impl Router {
    pub fn new(cfg: RouterConfig) -> Self {
        Router { cfg }
    }

    /// Estimate the dense-tile fill ratio of `m` on a row sample: for each
    /// sampled row, count (tile, elements-in-tile) and return
    /// elements / (tiles × T) — the column-direction fill a BSR
    /// conversion would see.
    pub fn estimate_fill(&self, m: &Csr) -> f64 {
        if m.rows == 0 || m.nnz() == 0 {
            return 0.0;
        }
        let t = self.cfg.t;
        let step = (m.rows / self.cfg.sample_rows.max(1)).max(1);
        let mut elems = 0usize;
        let mut tiles = 0usize;
        for r in (0..m.rows).step_by(step) {
            let mut last_tile = u32::MAX;
            for &c in m.row_cols(r) {
                let tile = c / t as u32;
                if tile != last_tile {
                    tiles += 1;
                    last_tile = tile;
                }
                elems += 1;
            }
        }
        if tiles == 0 {
            0.0
        } else {
            elems as f64 / (tiles * t) as f64
        }
    }

    /// Route a job by the joint fill of both operands.
    pub fn route(&self, a: &Csr, b: &Csr) -> Route {
        let fill = self.estimate_fill(a).min(self.estimate_fill(b));
        if fill >= self.cfg.min_fill {
            Route::Block
        } else {
            Route::Hash
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::banded::Banded;
    use crate::gen::uniform::Uniform;
    use crate::util::rng::Rng;

    #[test]
    fn fem_contiguous_matrix_routes_to_block() {
        let mut rng = Rng::new(41);
        let a = Banded { n: 1000, per_row: 48, band: 40, contiguous_frac: 1.0 }.generate(&mut rng);
        let r = Router::default();
        assert!(r.estimate_fill(&a) > 0.4, "fill={}", r.estimate_fill(&a));
        assert_eq!(r.route(&a, &a), Route::Block);
    }

    #[test]
    fn scattered_matrix_routes_to_hash() {
        let mut rng = Rng::new(42);
        let a = Uniform { n: 2000, per_row: 6, jitter: 3 }.generate(&mut rng);
        let r = Router::default();
        assert!(r.estimate_fill(&a) < 0.25, "fill={}", r.estimate_fill(&a));
        assert_eq!(r.route(&a, &a), Route::Hash);
    }

    #[test]
    fn empty_matrix_fill_zero() {
        let z = Csr::zero(10, 10);
        assert_eq!(Router::default().estimate_fill(&z), 0.0);
        assert_eq!(Router::default().route(&z, &z), Route::Hash);
    }
}
