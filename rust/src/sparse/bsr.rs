//! Block Sparse Row format — the TPU-adaptation substrate (DESIGN.md
//! §Hardware-Adaptation). Dense `T×T` blocks let the numeric phase run as
//! batched MXU matmuls through the PJRT-loaded Pallas kernel instead of a
//! shared-memory hash scatter, which a TPU does not have.

use super::csr::Csr;
use anyhow::{ensure, Result};

/// BSR sparse matrix: CSR over block rows/columns with dense `t*t` blocks
/// stored row-major in `blocks` (one contiguous `t*t` chunk per entry).
#[derive(Clone, Debug)]
pub struct Bsr {
    /// Block size (T).
    pub t: usize,
    /// Number of block rows / columns.
    pub brows: usize,
    pub bcols: usize,
    /// Original (unpadded) element dimensions.
    pub rows: usize,
    pub cols: usize,
    pub rpt: Vec<usize>,
    pub bcol: Vec<u32>,
    /// Dense block storage: `blocks[k*t*t .. (k+1)*t*t]` is block `k`.
    pub blocks: Vec<f64>,
}

impl Bsr {
    /// Number of stored blocks.
    pub fn nblocks(&self) -> usize {
        self.bcol.len()
    }

    /// Block `k` as a slice of `t*t` row-major values.
    #[inline]
    pub fn block(&self, k: usize) -> &[f64] {
        &self.blocks[k * self.t * self.t..(k + 1) * self.t * self.t]
    }

    /// Convert a CSR matrix to BSR with block size `t` (zero-padded at the
    /// right/bottom edges).
    pub fn from_csr(m: &Csr, t: usize) -> Result<Self> {
        ensure!(t > 0, "block size must be positive");
        let brows = m.rows.div_ceil(t);
        let bcols = m.cols.div_ceil(t);
        let tt = t * t;
        let mut rpt = vec![0usize; brows + 1];
        let mut bcol: Vec<u32> = Vec::new();
        let mut blocks: Vec<f64> = Vec::new();
        // map from block column -> position in current block row
        let mut pos: Vec<i64> = vec![-1; bcols];
        for br in 0..brows {
            let row_begin = bcol.len();
            for r in br * t..((br + 1) * t).min(m.rows) {
                let (cols, vals) = m.row(r);
                for (&c, &v) in cols.iter().zip(vals) {
                    let bc = c as usize / t;
                    let k = if pos[bc] < 0 {
                        let k = bcol.len();
                        pos[bc] = k as i64;
                        bcol.push(bc as u32);
                        blocks.resize(blocks.len() + tt, 0.0);
                        k
                    } else {
                        pos[bc] as usize
                    };
                    let lr = r - br * t;
                    let lc = c as usize - bc * t;
                    blocks[k * tt + lr * t + lc] = v;
                }
            }
            // sort the block row by block column (blocks were appended in
            // first-touch order)
            let n_in_row = bcol.len() - row_begin;
            if n_in_row > 1 {
                let mut order: Vec<usize> = (0..n_in_row).collect();
                order.sort_unstable_by_key(|&i| bcol[row_begin + i]);
                let old_cols: Vec<u32> =
                    bcol[row_begin..].to_vec();
                let old_blocks: Vec<f64> =
                    blocks[row_begin * tt..].to_vec();
                for (dst, &src) in order.iter().enumerate() {
                    bcol[row_begin + dst] = old_cols[src];
                    blocks[(row_begin + dst) * tt..(row_begin + dst + 1) * tt]
                        .copy_from_slice(&old_blocks[src * tt..(src + 1) * tt]);
                }
            }
            for &c in &bcol[row_begin..] {
                pos[c as usize] = -1;
            }
            rpt[br + 1] = bcol.len();
        }
        Ok(Bsr { t, brows, bcols, rows: m.rows, cols: m.cols, rpt, bcol, blocks })
    }

    /// Convert back to CSR, dropping explicit zeros introduced by padding.
    pub fn to_csr(&self) -> Result<Csr> {
        let tt = self.t * self.t;
        let mut rpt = vec![0usize; self.rows + 1];
        let mut col: Vec<u32> = Vec::new();
        let mut val: Vec<f64> = Vec::new();
        for r in 0..self.rows {
            let br = r / self.t;
            let lr = r % self.t;
            for k in self.rpt[br]..self.rpt[br + 1] {
                let bc = self.bcol[k] as usize;
                let b = &self.blocks[k * tt + lr * self.t..k * tt + (lr + 1) * self.t];
                for (lc, &v) in b.iter().enumerate() {
                    let c = bc * self.t + lc;
                    if v != 0.0 && c < self.cols {
                        col.push(c as u32);
                        val.push(v);
                    }
                }
            }
            rpt[r + 1] = col.len();
        }
        Csr::from_parts(self.rows, self.cols, rpt, col, val)
    }

    /// Structural fill ratio: stored nonzero elements / dense block capacity.
    pub fn fill_ratio(&self) -> f64 {
        let nz = self.blocks.iter().filter(|&&v| v != 0.0).count();
        if self.blocks.is_empty() {
            return 0.0;
        }
        nz as f64 / self.blocks.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_csr(rows: usize, cols: usize, per_row: usize, seed: u64) -> Csr {
        let mut rng = Rng::new(seed);
        let mut rpt = vec![0usize];
        let mut col = Vec::new();
        let mut val = Vec::new();
        let mut scratch = Vec::new();
        for _ in 0..rows {
            let k = rng.range(0, per_row + 1);
            rng.sample_distinct(cols, k, &mut scratch);
            for &c in &scratch {
                col.push(c);
                val.push(rng.value());
            }
            rpt.push(col.len());
        }
        Csr::from_parts(rows, cols, rpt, col, val).unwrap()
    }

    #[test]
    fn csr_bsr_roundtrip() {
        for seed in 0..5 {
            let m = random_csr(37, 29, 6, seed);
            let b = Bsr::from_csr(&m, 8).unwrap();
            let back = b.to_csr().unwrap();
            assert_eq!(m, back, "roundtrip failed for seed {seed}");
        }
    }

    #[test]
    fn block_columns_sorted() {
        let m = random_csr(64, 64, 10, 99);
        let b = Bsr::from_csr(&m, 16).unwrap();
        for br in 0..b.brows {
            let cols = &b.bcol[b.rpt[br]..b.rpt[br + 1]];
            assert!(cols.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn exact_division_dims() {
        let m = random_csr(32, 32, 4, 7);
        let b = Bsr::from_csr(&m, 8).unwrap();
        assert_eq!(b.brows, 4);
        assert_eq!(b.bcols, 4);
        assert_eq!(b.to_csr().unwrap(), m);
    }

    #[test]
    fn fill_ratio_bounds() {
        let m = random_csr(40, 40, 5, 3);
        let b = Bsr::from_csr(&m, 8).unwrap();
        let f = b.fill_ratio();
        assert!((0.0..=1.0).contains(&f));
        if m.nnz() > 0 {
            assert!(f > 0.0);
        }
    }
}
