//! Adaptive re-planning invariants: `ShardPlan::from_history` must
//! yield a valid partition, stitch bit-identically to proxy-planned
//! runs across every generator family × shard count, and never degrade
//! the modeled makespan on a warm pattern — plus the acceptance case:
//! on a power-law (hub-imbalanced) pattern the warm re-cut strictly
//! reduces the modeled makespan-imbalance the proxy plan measured, and
//! AMG re-setup re-plans between timesteps without moving a bit of the
//! hierarchy.

use opsparse::apps::amg::{poisson2d, AmgHierarchy};
use opsparse::apps::SpgemmContext;
use opsparse::coordinator::feedback::{ExecHistory, ReplanConfig, RunObservation};
use opsparse::coordinator::router::{Router, RouterConfig};
use opsparse::gen::kron::Kron;
use opsparse::gen::powerlaw::PowerLaw;
use opsparse::gen::stencil::{Grid, Stencil};
use opsparse::gen::uniform::Uniform;
use opsparse::gpusim::{MultiDevice, OverlapConfig, V100};
use opsparse::sparse::stats::nprod_per_row;
use opsparse::sparse::Csr;
use opsparse::spgemm::sharded::{multiply_sharded_with, MeasuredShard, ShardPlan};
use opsparse::spgemm::{multiply, OpSparseConfig};
use opsparse::util::prop::check;
use opsparse::util::rng::Rng;

fn families(rng: &mut Rng) -> Vec<(&'static str, Csr)> {
    vec![
        ("uniform", Uniform { n: 500, per_row: 8, jitter: 4 }.generate(rng)),
        (
            "powerlaw",
            PowerLaw {
                n: 500,
                alpha: 2.2,
                max_row: 64,
                mean_row: 6.0,
                hub_frac: 0.15,
                forced_giant_rows: 1,
            }
            .generate(rng),
        ),
        ("stencil", Stencil { n: 484, grid: Grid::D2, reach: 1, keep: 1.0, diagonal: true }.generate(rng)),
        ("kron", Kron { scale: 8, edge_factor: 8, a: 0.57, b: 0.19, c: 0.19 }.generate(rng)),
    ]
}

/// Max shard cost of `bounds` under the measured-cost model
/// `from_history` plans with: each measured shard's ns spread over its
/// rows proportionally to `nprod + 1`.
fn modeled_max(nprod: &[usize], measured: &[MeasuredShard], bounds: &[usize]) -> f64 {
    let mut cost = vec![0.0f64; nprod.len()];
    for m in measured {
        if m.hi == m.lo {
            continue;
        }
        let w: f64 = (m.lo..m.hi).map(|i| nprod[i] as f64 + 1.0).sum();
        for i in m.lo..m.hi {
            cost[i] = m.ns * (nprod[i] as f64 + 1.0) / w;
        }
    }
    bounds.windows(2).map(|w| cost[w[0]..w[1]].iter().sum::<f64>()).fold(0.0, f64::max)
}

fn assert_valid_partition(plan: &ShardPlan, rows: usize, shards: usize) {
    let b = plan.bounds();
    assert_eq!(b.len(), shards + 1, "one bound per shard edge");
    assert_eq!(b[0], 0, "must start at row 0");
    assert_eq!(plan.rows(), rows, "must cover every row");
    for w in b.windows(2) {
        assert!(w[0] <= w[1], "bounds must be monotone: {b:?}");
    }
}

#[test]
fn replanned_runs_are_bit_identical_across_families_and_shard_counts() {
    let mut rng = Rng::new(0xADA7);
    let cfg = OpSparseConfig::default();
    for (family, a) in families(&mut rng) {
        let gold = multiply(&a, &a, &cfg).unwrap();
        let nprod = nprod_per_row(&a, &a);
        for shards in [1usize, 2, 4, 8] {
            let cold_plan = ShardPlan::balanced(&nprod, shards);
            let cold = multiply_sharded_with(
                &a,
                &a,
                &cfg,
                &cold_plan,
                None,
                OverlapConfig::default(),
                None,
            )
            .unwrap();
            assert_eq!(cold.c, gold.c, "{family}/{shards}: proxy plan");
            // the execution history records the run's simulated device
            // times; the warm plan re-cuts from them
            let md = MultiDevice::simulate(cold.traces(), &V100);
            let mut h = ExecHistory::new(4);
            h.record(
                (1, 2),
                RunObservation::from_device_ns(
                    &cold_plan,
                    &md.device_total_ns(),
                    md.makespan_ns(),
                    cold.nprod as u64,
                ),
            );
            let measured = h.lookup((1, 2)).unwrap().measured.clone();
            assert_eq!(measured.len(), shards);
            let warm_plan = ShardPlan::from_history(&nprod, shards, &measured);
            assert_valid_partition(&warm_plan, a.rows, shards);
            // never degrade the modeled makespan vs the proxy cut
            assert!(
                modeled_max(&nprod, &measured, warm_plan.bounds())
                    <= modeled_max(&nprod, &measured, cold_plan.bounds()) + 1e-6,
                "{family}/{shards}: warm plan degraded the modeled makespan"
            );
            let warm = multiply_sharded_with(
                &a,
                &a,
                &cfg,
                &warm_plan,
                None,
                OverlapConfig::default(),
                None,
            )
            .unwrap();
            assert_eq!(warm.c, gold.c, "{family}/{shards}: replanned run must not move a bit");
            assert_eq!(warm.nprod, gold.nprod);
            warm.c.validate().unwrap();
        }
    }
}

#[test]
fn from_history_property_suite() {
    // random work vectors × random measured partitions × random shard
    // counts: the re-cut is always a valid partition, deterministic,
    // and never degrades the modeled makespan
    check(
        "from_history-invariants",
        64,
        160,
        |rng, size| {
            let n = 1 + rng.range(0, size.max(2));
            let nprod: Vec<usize> = (0..n).map(|_| (rng.next_u64() % 100) as usize).collect();
            let shards = 1 + rng.range(0, 8);
            // a random valid partition (cut by random weights), timed by
            // random per-shard ns
            let k = 1 + rng.range(0, 6);
            let weights: Vec<usize> = (0..n).map(|_| (rng.next_u64() % 7) as usize).collect();
            let mplan = ShardPlan::balanced(&weights, k);
            let measured: Vec<MeasuredShard> = (0..k)
                .map(|s| {
                    let (lo, hi) = mplan.range(s);
                    MeasuredShard { lo, hi, ns: (rng.next_u64() % 10_000) as f64 }
                })
                .collect();
            (nprod, shards, measured)
        },
        |(nprod, shards, measured)| {
            let plan = ShardPlan::from_history(nprod, *shards, measured);
            let b = plan.bounds();
            if b.len() != shards + 1 || b[0] != 0 || plan.rows() != nprod.len() {
                return Err(format!("invalid partition: bounds {b:?} for {} rows", nprod.len()));
            }
            if b.windows(2).any(|w| w[0] > w[1]) {
                return Err(format!("non-monotone bounds {b:?}"));
            }
            let again = ShardPlan::from_history(nprod, *shards, measured);
            if again.bounds() != b {
                return Err("re-planning must be deterministic".into());
            }
            let proxy = ShardPlan::balanced(nprod, *shards);
            let (warm, cold) = (
                modeled_max(nprod, measured, b),
                modeled_max(nprod, measured, proxy.bounds()),
            );
            if warm > cold + 1e-6 {
                return Err(format!("modeled makespan degraded: {warm} > {cold}"));
            }
            Ok(())
        },
    );
}

#[test]
fn powerlaw_warm_replan_reduces_modeled_makespan_imbalance() {
    // the acceptance case: on a hub-imbalanced power-law pattern the
    // nprod proxy misses the per-bin kernel-cost skew, so the measured
    // per-shard times come back imbalanced — and the warm re-cut must
    // strictly reduce the modeled critical path (hence the modeled
    // makespan-imbalance: the measured total is conserved by the model,
    // so max and max/mean move together)
    let a = PowerLaw {
        n: 1200,
        alpha: 2.2,
        max_row: 128,
        mean_row: 6.0,
        hub_frac: 0.15,
        forced_giant_rows: 2,
    }
    .generate(&mut Rng::new(7));
    let cfg = OpSparseConfig::default();
    let nprod = nprod_per_row(&a, &a);
    let shards = 4;
    let cold_plan = ShardPlan::balanced(&nprod, shards);
    let cold =
        multiply_sharded_with(&a, &a, &cfg, &cold_plan, None, OverlapConfig::default(), None)
            .unwrap();
    let md = MultiDevice::simulate(cold.traces(), &V100);
    let device_ns = md.device_total_ns();
    let measured: Vec<MeasuredShard> = (0..shards)
        .map(|s| {
            let (lo, hi) = cold_plan.range(s);
            MeasuredShard { lo, hi, ns: device_ns[s] }
        })
        .collect();
    let mean: f64 = device_ns.iter().sum::<f64>() / shards as f64;
    let cold_max = device_ns.iter().cloned().fold(0.0, f64::max);
    assert!(
        cold_max / mean > 1.02,
        "precondition: the proxy cut must measure imbalanced on power-law, got {:.4}",
        cold_max / mean
    );
    let warm_plan = ShardPlan::from_history(&nprod, shards, &measured);
    assert_ne!(warm_plan.bounds(), cold_plan.bounds(), "the re-cut must actually move");
    let warm_max = modeled_max(&nprod, &measured, warm_plan.bounds());
    assert!(
        warm_max < cold_max - 1e-6,
        "warm re-cut must strictly reduce the modeled critical path: {warm_max} vs {cold_max}"
    );
    // and the re-cut run still stitches bit-identically
    let warm =
        multiply_sharded_with(&a, &a, &cfg, &warm_plan, None, OverlapConfig::default(), None)
            .unwrap();
    assert_eq!(warm.c, cold.c);
}

#[test]
fn amg_resetup_replans_between_timesteps_bit_identically() {
    // the AMG re-setup workload the tentpole names: the same mesh
    // rebuilt per timestep. Pass 1 is cold (proxy-planned, recorded);
    // pass 2 re-cuts every sharded Galerkin product from the measured
    // history — and builds the identical hierarchy.
    let a = poisson2d(24);
    let mut plain = SpgemmContext::new();
    let h_plain = AmgHierarchy::build_with(&mut plain, &a, 0.1, 50, 10).unwrap();
    let router = || {
        Router::new(RouterConfig {
            device_memory_bytes: 8 * 1024,
            max_devices: 4,
            interconnect: None,
            ..Default::default()
        })
    };
    let mut ctx = SpgemmContext::with_router_replan(router(), ReplanConfig::default());
    let h1 = AmgHierarchy::build_with(&mut ctx, &a, 0.1, 50, 10).unwrap();
    assert!(ctx.sharded_multiplies() > 0, "the finest products must shard");
    assert!(ctx.replan_cold_misses() > 0, "first setup records cold patterns");
    assert_eq!(ctx.replans(), 0, "nothing is warm yet");
    assert!(ctx.history_patterns() > 0, "the history must fill");
    for (l, lp) in h1.levels.iter().zip(&h_plain.levels) {
        assert_eq!(l.a, lp.a, "cold adaptive setup must match the plain hierarchy");
    }
    // next timestep: refreshed coefficients, unchanged stencil — warm
    // patterns re-plan from the recorded measurements
    let mut a2 = a.clone();
    for v in &mut a2.val {
        *v *= 1.5;
    }
    let h2 = AmgHierarchy::build_with(&mut ctx, &a2, 0.1, 50, 10).unwrap();
    assert!(ctx.replans() > 0, "re-setup must re-plan its warm sharded products");
    assert_eq!(h1.levels.len(), h2.levels.len(), "replanning must not change the hierarchy");
    for (l1, l2) in h1.levels.iter().zip(&h2.levels) {
        assert_eq!(l1.a.rpt, l2.a.rpt, "pattern must be unchanged");
        assert_eq!(l1.a.col, l2.a.col, "pattern must be unchanged");
    }
}
