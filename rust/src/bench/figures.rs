//! Figure regenerators: one function per figure of the paper's §6,
//! printing the same rows/series the paper plots.

use super::{gflops, run_and_simulate};
use crate::baselines::Library;
use crate::gen::suite::{entries, large_entries, normal_entries, SuiteScale};
use crate::gpusim::{simulate, Interconnect, OverlapConfig, V100};
use crate::spgemm::pipeline::{multiply, OpSparseConfig};
use crate::spgemm::{HashVariant, NumericRanges, SymbolicRanges};
use anyhow::Result;

fn print_header(cols: &[&str]) {
    println!("{:<18} {}", "matrix", cols.iter().map(|c| format!("{c:>12}")).collect::<Vec<_>>().join(" "));
}

/// Fig 5: GFLOPS of the 4 libraries on the 19 normal matrices.
pub fn fig5(scale: SuiteScale, verify: bool) -> Result<Vec<(String, Vec<f64>)>> {
    println!("\n=== Figure 5: SpGEMM GFLOPS, normal matrices (scale {scale:?}) ===");
    let libs = Library::all();
    print_header(&libs.map(|l| l.name()));
    let mut rows = Vec::new();
    for e in normal_entries() {
        let a = e.generate(scale);
        let mut vals = Vec::new();
        for lib in libs {
            let (out, tl) = run_and_simulate(lib, &a, verify)?;
            vals.push(gflops(&out, &tl));
        }
        println!(
            "{:<18} {}",
            e.name,
            vals.iter().map(|v| format!("{v:>12.2}")).collect::<Vec<_>>().join(" ")
        );
        rows.push((e.name.to_string(), vals));
    }
    summarize_speedups(&rows, &libs.map(|l| l.name()));
    Ok(rows)
}

/// Fig 6: GFLOPS of the 3 large-capable libraries on the 7 large matrices.
pub fn fig6(scale: SuiteScale, verify: bool) -> Result<Vec<(String, Vec<f64>)>> {
    println!("\n=== Figure 6: SpGEMM GFLOPS, large matrices (scale {scale:?}) ===");
    println!("(cuSPARSE omitted: out of device memory on these inputs, §6.1)");
    let libs = Library::large_capable();
    print_header(&libs.map(|l| l.name()));
    let mut rows = Vec::new();
    for e in large_entries() {
        let a = e.generate(scale);
        let mut vals = Vec::new();
        for lib in libs {
            let (out, tl) = run_and_simulate(lib, &a, verify)?;
            vals.push(gflops(&out, &tl));
        }
        println!(
            "{:<18} {}",
            e.name,
            vals.iter().map(|v| format!("{v:>12.2}")).collect::<Vec<_>>().join(" ")
        );
        rows.push((e.name.to_string(), vals));
    }
    summarize_speedups(&rows, &libs.map(|l| l.name()));
    Ok(rows)
}

fn summarize_speedups(rows: &[(String, Vec<f64>)], names: &[&str]) {
    if rows.is_empty() {
        return;
    }
    let n = names.len();
    let last = n - 1; // OpSparse is last
    println!("-- OpSparse speedup (geomean / max) --");
    for j in 0..last {
        let mut log_sum = 0.0;
        let mut max = 0.0f64;
        for (_, vals) in rows {
            let s = vals[last] / vals[j].max(1e-12);
            log_sum += s.ln();
            max = max.max(s);
        }
        let geo = (log_sum / rows.len() as f64).exp();
        println!("  vs {:<10} geomean {geo:.2}x   max {max:.2}x", names[j]);
    }
}

/// Figs 7+8: binning-step execution time, absolute and as % of total, for
/// nsparse / spECK / OpSparse.
pub fn fig7_8(scale: SuiteScale) -> Result<Vec<(String, Vec<(f64, f64)>)>> {
    println!("\n=== Figures 7+8: binning time (abs us / % of total) (scale {scale:?}) ===");
    let libs = [Library::Nsparse, Library::Speck, Library::OpSparse];
    print_header(&libs.map(|l| l.name()));
    let mut rows = Vec::new();
    for e in entries() {
        let a = e.generate(scale);
        let mut vals = Vec::new();
        for lib in libs {
            let (out, tl) = run_and_simulate(lib, &a, false)?;
            let _ = out;
            let bin_ns = tl.step_ns("sym_binning") + tl.step_ns("num_binning");
            let pct = 100.0 * bin_ns / tl.total_ns;
            vals.push((bin_ns / 1e3, pct));
        }
        println!(
            "{:<18} {}",
            e.name,
            vals.iter()
                .map(|(us, pct)| format!("{us:>7.1}us {pct:>4.1}%"))
                .collect::<Vec<_>>()
                .join(" ")
        );
        rows.push((e.name.to_string(), vals));
    }
    // paper headline: avg % for each library + speedup of OpSparse binning
    for (j, lib) in libs.iter().enumerate() {
        let avg: f64 = rows.iter().map(|(_, v)| v[j].1).sum::<f64>() / rows.len() as f64;
        let worst = rows.iter().map(|(_, v)| v[j].1).fold(0.0f64, f64::max);
        println!("  {:<10} binning avg {avg:.1}% of total, worst {worst:.1}%", lib.name());
    }
    let speedup = |j: usize| {
        let mut log_sum = 0.0;
        for (_, v) in &rows {
            log_sum += (v[j].0 / v[2].0.max(1e-12)).ln();
        }
        (log_sum / rows.len() as f64).exp()
    };
    println!("  OpSparse binning speedup: {:.1}x vs nsparse, {:.1}x vs spECK", speedup(0), speedup(1));
    Ok(rows)
}

/// Fig 9: symbolic/numeric step time with single- vs multi-access hashing.
pub fn fig9(scale: SuiteScale) -> Result<Vec<(String, [f64; 4])>> {
    println!("\n=== Figure 9: single- vs multi-access hashing (step times, us) (scale {scale:?}) ===");
    println!("{:<18} {:>12} {:>12} {:>12} {:>12}", "matrix", "sym_single", "sym_multi", "num_single", "num_multi");
    let mut rows = Vec::new();
    for e in entries() {
        let a = e.generate(scale);
        let mut cfg = OpSparseConfig::default();
        cfg.hash_variant = HashVariant::SingleAccess;
        let single = multiply(&a, &a, &cfg)?;
        cfg.hash_variant = HashVariant::MultiAccess;
        let multi = multiply(&a, &a, &cfg)?;
        let tl_s = simulate(&single.trace, &V100);
        let tl_m = simulate(&multi.trace, &V100);
        let vals = [
            tl_s.step_ns("symbolic") / 1e3,
            tl_m.step_ns("symbolic") / 1e3,
            tl_s.step_ns("numeric") / 1e3,
            tl_m.step_ns("numeric") / 1e3,
        ];
        println!(
            "{:<18} {:>12.1} {:>12.1} {:>12.1} {:>12.1}",
            e.name, vals[0], vals[1], vals[2], vals[3]
        );
        rows.push((e.name.to_string(), vals));
    }
    let geo = |num: usize, den: usize| {
        let s: f64 = rows.iter().map(|(_, v)| (v[num] / v[den].max(1e-12)).ln()).sum();
        (s / rows.len() as f64).exp()
    };
    println!("  single-access speedup: sym {:.3}x, num {:.3}x", geo(1, 0), geo(3, 2));
    Ok(rows)
}

/// Fig 10: symbolic-step performance across the sym_1x/1.2x/1.5x ranges,
/// normalized to sym_1x (higher = faster).
pub fn fig10(scale: SuiteScale) -> Result<Vec<(String, [f64; 3])>> {
    println!("\n=== Figure 10: symbolic step vs binning ranges (normalized to sym_1x) (scale {scale:?}) ===");
    println!("{:<18} {:>10} {:>10} {:>10}", "matrix", "sym_1x", "sym_1.2x", "sym_1.5x");
    let mut rows = Vec::new();
    for e in entries() {
        let a = e.generate(scale);
        let mut times = [0f64; 3];
        for (i, r) in SymbolicRanges::all().iter().enumerate() {
            let mut cfg = OpSparseConfig::default();
            cfg.sym_ranges = *r;
            let out = multiply(&a, &a, &cfg)?;
            let tl = simulate(&out.trace, &V100);
            times[i] = tl.step_ns("symbolic");
        }
        let norm = [1.0, times[0] / times[1], times[0] / times[2]];
        println!("{:<18} {:>10.3} {:>10.3} {:>10.3}", e.name, norm[0], norm[1], norm[2]);
        rows.push((e.name.to_string(), norm));
    }
    for (i, name) in ["sym_1x", "sym_1.2x", "sym_1.5x"].iter().enumerate() {
        let s: f64 = rows.iter().map(|(_, v)| v[i].ln()).sum();
        println!("  {name} geomean speedup vs 1x: {:.3}x", (s / rows.len() as f64).exp());
    }
    Ok(rows)
}

/// Fig 11: numeric-step performance across num_1x/1.5x/2x/3x ranges,
/// normalized to num_1x.
pub fn fig11(scale: SuiteScale) -> Result<Vec<(String, [f64; 4])>> {
    println!("\n=== Figure 11: numeric step vs binning ranges (normalized to num_1x) (scale {scale:?}) ===");
    println!("{:<18} {:>10} {:>10} {:>10} {:>10}", "matrix", "num_1x", "num_1.5x", "num_2x", "num_3x");
    let mut rows = Vec::new();
    for e in entries() {
        let a = e.generate(scale);
        let mut times = [0f64; 4];
        for (i, r) in NumericRanges::all().iter().enumerate() {
            let mut cfg = OpSparseConfig::default();
            cfg.num_ranges = *r;
            let out = multiply(&a, &a, &cfg)?;
            let tl = simulate(&out.trace, &V100);
            times[i] = tl.step_ns("numeric");
        }
        let norm = [1.0, times[0] / times[1], times[0] / times[2], times[0] / times[3]];
        println!(
            "{:<18} {:>10.3} {:>10.3} {:>10.3} {:>10.3}",
            e.name, norm[0], norm[1], norm[2], norm[3]
        );
        rows.push((e.name.to_string(), norm));
    }
    for (i, name) in ["num_1x", "num_1.5x", "num_2x", "num_3x"].iter().enumerate() {
        let s: f64 = rows.iter().map(|(_, v)| v[i].ln()).sum();
        println!("  {name} geomean speedup vs 1x: {:.3}x", (s / rows.len() as f64).exp());
    }
    Ok(rows)
}

/// Ablation bench (DESIGN.md): flip each OpSparse optimization off
/// individually and report the slowdown on a representative matrix set.
pub fn ablations(scale: SuiteScale) -> Result<()> {
    println!("\n=== Ablations: one optimization off at a time (scale {scale:?}) ===");
    let names = ["webbase-1M", "cant", "mono_500Hz", "pdb1HYS"];
    println!(
        "{:<28} {}",
        "config",
        names.iter().map(|n| format!("{n:>14}")).collect::<Vec<_>>().join(" ")
    );
    let mats: Vec<_> = names
        .iter()
        .map(|n| crate::gen::suite::suite_entry(n).unwrap().generate(scale))
        .collect();
    let run = |label: &str, cfg: &OpSparseConfig, mats: &[crate::sparse::Csr]| -> Result<Vec<f64>> {
        let mut out = Vec::new();
        for a in mats {
            let o = multiply(a, a, cfg)?;
            let tl = simulate(&o.trace, &V100);
            out.push(tl.total_ns);
        }
        println!(
            "{:<28} {}",
            label,
            out.iter().map(|v| format!("{:>12.2}us", v / 1e3)).collect::<Vec<_>>().join(" ")
        );
        Ok(out)
    };
    let base = run("opsparse (all on)", &OpSparseConfig::default(), &mats)?;
    let mut variants: Vec<(&str, OpSparseConfig)> = Vec::new();
    let mut c = OpSparseConfig::default();
    c.binning_variant = crate::spgemm::BinningVariant::GlobalAtomic;
    variants.push(("- shared-mem binning", c));
    let mut c = OpSparseConfig::default();
    c.hash_variant = HashVariant::MultiAccess;
    variants.push(("- single-access hashing", c));
    let mut c = OpSparseConfig::default();
    c.sym_ranges = SymbolicRanges::Sym1x;
    c.num_ranges = NumericRanges::Num1x;
    variants.push(("- tuned binning ranges", c));
    let mut c = OpSparseConfig::default();
    c.combined_metadata_malloc = false;
    c.reuse_crpt = false;
    variants.push(("- combined metadata malloc", c));
    let mut c = OpSparseConfig::default();
    c.overlap_malloc = false;
    variants.push(("- malloc/kernel overlap", c));
    let mut c = OpSparseConfig::default();
    c.deferred_free = false;
    variants.push(("- deferred cudaFree", c));
    for (label, cfg) in &variants {
        let t = run(label, cfg, &mats)?;
        let slow: Vec<String> =
            t.iter().zip(&base).map(|(x, b)| format!("{:.3}x", x / b)).collect();
        println!("{:<28} {}", "   slowdown", slow.iter().map(|s| format!("{s:>14}")).collect::<Vec<_>>().join(" "));
    }
    // §2.2: the one-phase method with upper-bound allocation
    let mut one = Vec::new();
    for a in &mats {
        let o = crate::spgemm::one_phase::multiply_one_phase(a, a)?;
        let tl = simulate(&o.trace, &V100);
        one.push(tl.total_ns);
    }
    println!(
        "{:<28} {}",
        "one-phase (§2.2 baseline)",
        one.iter().map(|v| format!("{:>12.2}us", v / 1e3)).collect::<Vec<_>>().join(" ")
    );
    let slow: Vec<String> =
        one.iter().zip(&base).map(|(x, b)| format!("{:.3}x", x / b)).collect();
    println!("{:<28} {}", "   slowdown", slow.iter().map(|s| format!("{s:>14}")).collect::<Vec<_>>().join(" "));
    Ok(())
}

/// One matrix's row of the pool/cache ablation.
#[derive(Clone, Debug)]
pub struct PoolAblationRow {
    pub matrix: String,
    /// `cudaMalloc` calls every per-call rep pays.
    pub percall_mallocs: usize,
    /// Simulated time of one per-call rep (ns).
    pub percall_ns: f64,
    /// Host ns stalled in `cudaMalloc`/`cudaFree` per per-call rep.
    pub percall_stall_ns: f64,
    /// `cudaMalloc` calls of the cold pooled rep (pool growth).
    pub cold_mallocs: usize,
    /// `cudaMalloc` calls per warm rep (0 once the pool is grown).
    pub warm_mallocs: usize,
    /// Mean simulated time of the warm pooled+cached reps (ns).
    pub warm_ns: f64,
    /// Mean allocation-stall ns of the warm reps (0 when fully pooled).
    pub warm_stall_ns: f64,
}

/// Serving ablation (beyond the paper's per-call view): repeated-pattern
/// traffic on a warm worker — device pool + symbolic-reuse cache — vs
/// re-allocating and re-analyzing on every call. Also drives the same
/// repeated AMG/MCL-shaped jobs through a one-worker coordinator and
/// prints its pool/cache metrics.
pub fn pool_ablation(scale: SuiteScale, reps: usize) -> Result<Vec<PoolAblationRow>> {
    use crate::apps::SpgemmContext;
    let reps = reps.max(2);
    println!(
        "\n=== Ablation: device pool + symbolic reuse vs per-call allocation \
         (scale {scale:?}, {reps} reps/pattern) ==="
    );
    println!(
        "{:<12} {:>8} {:>11} {:>11} {:>8} {:>11} {:>11} {:>8}",
        "matrix", "mallocs", "time", "stall", "warm_mal", "warm_time", "warm_stall", "speedup"
    );
    let cfg = OpSparseConfig::default();
    let mut rows = Vec::new();
    for name in ["cant", "filter3D", "pdb1HYS"] {
        let a = crate::gen::suite::suite_entry(name).unwrap().generate(scale);
        // per-call baseline: every rep costs this
        let percall_out = multiply(&a, &a, &cfg)?;
        let percall_tl = simulate(&percall_out.trace, &V100);
        // warm worker: the cold rep grows the pool and fills the cache;
        // warm reps recycle allocations and replay the symbolic phase
        let mut ctx = SpgemmContext::new();
        let cold_out = ctx.multiply(&a, &a)?;
        let cold_mallocs = cold_out.trace.malloc_calls();
        let (mut warm_ns, mut warm_stall, mut warm_mallocs) = (0.0f64, 0.0f64, 0usize);
        for _ in 1..reps {
            let out = ctx.multiply(&a, &a)?;
            let tl = simulate(&out.trace, &V100);
            warm_ns += tl.total_ns;
            warm_stall += tl.alloc_stall_ns();
            warm_mallocs += out.trace.malloc_calls();
        }
        warm_ns /= (reps - 1) as f64;
        warm_stall /= (reps - 1) as f64;
        let row = PoolAblationRow {
            matrix: name.to_string(),
            percall_mallocs: percall_out.trace.malloc_calls(),
            percall_ns: percall_tl.total_ns,
            percall_stall_ns: percall_tl.alloc_stall_ns(),
            cold_mallocs,
            warm_mallocs,
            warm_ns,
            warm_stall_ns: warm_stall,
        };
        println!(
            "{:<12} {:>8} {:>9.1}us {:>9.1}us {:>8} {:>9.1}us {:>9.1}us {:>7.2}x",
            row.matrix,
            row.percall_mallocs,
            row.percall_ns / 1e3,
            row.percall_stall_ns / 1e3,
            row.warm_mallocs,
            row.warm_ns / 1e3,
            row.warm_stall_ns / 1e3,
            row.percall_ns / row.warm_ns.max(1e-9)
        );
        rows.push(row);
    }

    // the same effect observed end-to-end: AMG re-setup and MCL expansion
    // patterns served repeatedly by one warm coordinator worker
    println!("\n-- coordinator: repeated AMG/MCL-pattern jobs on one warm worker --");
    let amg_a = crate::apps::amg::poisson2d(32);
    let mcl_m = crate::gen::kron::Kron::default().generate(&mut crate::util::rng::Rng::new(5));
    let coord = crate::coordinator::Coordinator::start(1, crate::coordinator::Router::default(), None);
    let mut id = 0u64;
    for _ in 0..reps {
        for m in [&amg_a, &mcl_m] {
            coord.submit(crate::coordinator::Job {
                id,
                a: m.clone(),
                b: m.clone(),
                force_route: Some(crate::coordinator::Route::Hash),
            });
            id += 1;
        }
    }
    for _ in 0..id {
        let r = coord.recv().expect("coordinator alive");
        r.c?;
    }
    let snap = coord.metrics.snapshot();
    print!("{snap}");
    coord.shutdown();
    Ok(rows)
}

/// One shard count's row of the multi-device scaling bench.
#[derive(Clone, Debug)]
pub struct ShardScalingRow {
    pub shards: usize,
    /// Serial end-to-end critical path (ns): `B` broadcast + slowest
    /// device's compute + `C` row-block gather. Equals `compute_ns` when
    /// the run charges no interconnect.
    pub makespan_ns: f64,
    /// Overlapped (pipelined) end-to-end critical path: chunked
    /// broadcast feeding compute, early finishers gathering under
    /// stragglers. Equals `makespan_ns` when overlap is disabled or no
    /// interconnect is charged; never exceeds it.
    pub overlapped_makespan_ns: f64,
    /// `makespan_ns - overlapped_makespan_ns`: transfer time the
    /// pipelined schedule hid behind compute.
    pub overlap_saved_ns: f64,
    /// Compute-only critical path: the slowest device's wall time (ns).
    pub compute_ns: f64,
    /// Modeled `B` replication cost at this shard count (ns).
    pub broadcast_ns: f64,
    /// Modeled `C` row-block gather cost at this shard count (ns).
    pub gather_ns: f64,
    /// Per-device simulated wall times (ns), in shard order.
    pub device_ns: Vec<f64>,
    /// Planned imbalance: max/mean shard `nprod` work.
    pub plan_imbalance: f64,
    /// Measured imbalance: max/mean device wall time.
    pub time_imbalance: f64,
    /// Speedup over the 1-shard makespan (serial schedule).
    pub speedup: f64,
    /// Serial speedup / shard count (1.0 = linear scaling).
    pub efficiency: f64,
    /// Overlapped speedup / shard count (≥ `efficiency`: the pipelined
    /// schedule can only shorten the sharded makespan).
    pub efficiency_overlapped: f64,
}

/// Multi-device scaling with the default PCIe interconnect charged and
/// the default overlap model (env-overridable; see
/// [`shard_scaling_with`]).
pub fn shard_scaling(scale: SuiteScale) -> Result<Vec<ShardScalingRow>> {
    shard_scaling_with(scale, Some(&Interconnect::pcie3()), OverlapConfig::from_env())
}

/// Multi-device scaling: row-sharded SpGEMM on a power-law matrix (the
/// adversarial case for load balance — work is concentrated in hub-coupled
/// rows) at 1/2/4/8 shards, reporting per-device makespan (serial **and**
/// overlapped — the pipelined broadcast/compute/gather schedule), the
/// modeled `B`-broadcast and `C`-gather costs, planned and measured load
/// imbalance, and both scaling-efficiency columns. With an interconnect
/// the efficiency figures are honest — replication is charged, so they
/// cannot exceed 1.0 and degrade as communication amortizes worse;
/// `ic: None` keeps the transfer-free PR 2 view and **skips the transfer
/// columns** (there is nothing to report, not a column of zeros). The
/// stitched result is verified bit-identical to the unsharded pipeline
/// once up front, with the overlap annotation on — overlap must never
/// change a bit of the result.
pub fn shard_scaling_with(
    scale: SuiteScale,
    ic: Option<&Interconnect>,
    overlap: OverlapConfig,
) -> Result<Vec<ShardScalingRow>> {
    shard_scaling_run(scale, ic, overlap, 2026, true)
}

/// Seeded, optionally quiet variant of [`shard_scaling_with`]. The
/// statistical overlap gate ([`overlap_gate`]) re-runs this with a fresh
/// generator seed per repetition — the simulator is deterministic, so
/// repetition variance comes entirely from the matrix draw.
pub fn shard_scaling_run(
    scale: SuiteScale,
    ic: Option<&Interconnect>,
    overlap: OverlapConfig,
    seed: u64,
    verbose: bool,
) -> Result<Vec<ShardScalingRow>> {
    use crate::gen::powerlaw::PowerLaw;
    use crate::gpusim::MultiDevice;
    use crate::sparse::stats::nprod_per_row;
    use crate::spgemm::sharded::{multiply_sharded_with, ShardPlan};

    let n = match scale {
        SuiteScale::Tiny => 8192,
        SuiteScale::Small => 24576,
        SuiteScale::Medium => 65536,
    };
    let a = PowerLaw {
        n,
        alpha: 2.2,
        max_row: (n / 32).max(64),
        mean_row: 8.0,
        hub_frac: 0.15,
        forced_giant_rows: 0,
    }
    .generate(&mut crate::util::rng::Rng::new(seed));
    let charged = ic.is_some();
    if verbose {
        match ic {
            Some(ic) => println!(
                "\n=== Shard scaling: row-sharded SpGEMM, power-law A ({n} rows, nnz {}), \
                 interconnect {:.0} GB/s {:?} (lat {:.1}us), overlap {} (chunk {} KiB) ===",
                a.nnz(),
                ic.bandwidth_gbps,
                ic.topology,
                ic.latency_us,
                if overlap.enabled { "on" } else { "off" },
                overlap.chunk_bytes >> 10
            ),
            None => println!(
                "\n=== Shard scaling: row-sharded SpGEMM, power-law A ({n} rows, nnz {}), \
                 free interconnect (transfer columns skipped) ===",
                a.nnz()
            ),
        }
        if charged {
            println!(
                "{:>7} {:>12} {:>12} {:>10} {:>11} {:>11} {:>9} {:>9} {:>9} {:>9}",
                "shards", "serial-mk", "overlap-mk", "saved", "broadcast", "gather", "plan-imb",
                "time-imb", "eff-ser", "eff-ovl"
            );
        } else {
            println!(
                "{:>7} {:>12} {:>10} {:>10} {:>9} {:>11}",
                "shards", "makespan", "plan-imb", "time-imb", "speedup", "efficiency"
            );
        }
    }
    let cfg = OpSparseConfig::default();
    let b_bytes = a.device_bytes();
    let nprod = nprod_per_row(&a, &a);
    let mut rows: Vec<ShardScalingRow> = Vec::new();
    // the 1-shard run IS the unsharded pipeline (one shard = whole A), so
    // it doubles as the bit-identity baseline for every other shard count
    let mut baseline_c = None;
    for shards in [1usize, 2, 4, 8] {
        let plan = ShardPlan::balanced(&nprod, shards);
        let out = multiply_sharded_with(&a, &a, &cfg, &plan, None, overlap, None)?;
        match &baseline_c {
            None => baseline_c = Some(out.c.clone()),
            Some(g) => {
                anyhow::ensure!(out.c == *g, "{shards}-shard result must be bit-identical")
            }
        }
        let md = match (ic, overlap.enabled) {
            (Some(ic), true) => MultiDevice::simulate_overlapped(
                out.traces(),
                &V100,
                ic,
                b_bytes,
                &out.c_block_bytes(),
            )?,
            (Some(ic), false) => MultiDevice::simulate_with_interconnect(
                out.traces(),
                &V100,
                ic,
                b_bytes,
                &out.c_block_bytes(),
            )?,
            (None, _) => MultiDevice::simulate(out.traces(), &V100),
        };
        let serial_mk = md.makespan_ns();
        let overlapped_mk = md.overlapped_makespan_ns().unwrap_or(serial_mk);
        let single = rows.first().map(|r| r.makespan_ns).unwrap_or(serial_mk);
        let eff_overlapped = if overlapped_mk > 0.0 && shards > 0 {
            (single / overlapped_mk) / shards as f64
        } else {
            0.0
        };
        let row = ShardScalingRow {
            shards,
            makespan_ns: serial_mk,
            overlapped_makespan_ns: overlapped_mk,
            overlap_saved_ns: serial_mk - overlapped_mk,
            compute_ns: md.compute_makespan_ns(),
            broadcast_ns: md.broadcast_ns,
            gather_ns: md.gather_ns,
            device_ns: md.device_total_ns(),
            plan_imbalance: out.plan.load_imbalance(),
            time_imbalance: md.time_imbalance(),
            speedup: md.speedup_vs(single),
            efficiency: md.efficiency_vs(single),
            efficiency_overlapped: eff_overlapped,
        };
        if verbose && charged {
            println!(
                "{:>7} {:>10.1}us {:>10.1}us {:>8.1}us {:>9.1}us {:>9.1}us {:>8.3}x {:>8.3}x \
                 {:>8.1}% {:>8.1}%",
                row.shards,
                row.makespan_ns / 1e3,
                row.overlapped_makespan_ns / 1e3,
                row.overlap_saved_ns / 1e3,
                row.broadcast_ns / 1e3,
                row.gather_ns / 1e3,
                row.plan_imbalance,
                row.time_imbalance,
                row.efficiency * 100.0,
                row.efficiency_overlapped * 100.0
            );
        } else if verbose {
            println!(
                "{:>7} {:>10.1}us {:>9.3}x {:>9.3}x {:>8.2}x {:>10.1}%",
                row.shards,
                row.makespan_ns / 1e3,
                row.plan_imbalance,
                row.time_imbalance,
                row.speedup,
                row.efficiency * 100.0
            );
        }
        rows.push(row);
    }
    Ok(rows)
}

/// One (family, shard count) cell of the adaptive re-planning ablation.
#[derive(Clone, Debug)]
pub struct AdaptiveRow {
    pub family: &'static str,
    pub shards: usize,
    /// Compute makespan under the cold, proxy-cut plan
    /// (`ShardPlan::balanced`), ns.
    pub cold_makespan_ns: f64,
    /// Compute makespan of the warm pass — the plan the adaptive
    /// planner *keeps*: the measured re-cut when it wins, the proxy cut
    /// when the re-cut did not pay (rollback). `<= cold_makespan_ns` by
    /// construction — the CI contract on `BENCH_adaptive.json`.
    pub warm_makespan_ns: f64,
    /// Raw re-simulated makespan of the measured re-cut, before the
    /// keep-the-better-plan rollback (honesty column: how the re-cut
    /// itself did).
    pub replanned_makespan_ns: f64,
    /// Measured device-time imbalance (max/mean) under each plan.
    pub cold_imbalance: f64,
    pub warm_imbalance: f64,
    /// Whether a *changed* cut was adopted: the measured re-cut moved
    /// the bounds and its re-measured run beat the proxy plan. `false`
    /// when the hysteresis kept the proxy bounds (no re-cut happened)
    /// or the re-cut lost and was rolled back.
    pub kept_replan: bool,
}

/// Adaptive re-planning ablation: for each generator family × shard
/// count, run the proxy-cut plan cold, record its simulated per-device
/// times as the execution history would, re-cut via
/// `ShardPlan::from_history`, and re-run warm. The warm makespan is the
/// *kept* plan's — like bhSPARSE's progressive re-allocation, the
/// planner measures the re-cut and rolls back if it lost — so
/// warm ≤ cold on every row; the raw re-cut figure is reported
/// alongside. Results are verified bit-identical across plans.
pub fn adaptive_replan(scale: SuiteScale) -> Result<Vec<AdaptiveRow>> {
    adaptive_replan_seeded(scale, 2026, true)
}

/// Seeded, optionally quiet variant of [`adaptive_replan`]. The
/// statistical warm-≤-cold gate ([`adaptive_gate`]) re-runs this with a
/// fresh generator seed per repetition.
pub fn adaptive_replan_seeded(
    scale: SuiteScale,
    seed: u64,
    verbose: bool,
) -> Result<Vec<AdaptiveRow>> {
    use crate::gen::kron::Kron;
    use crate::gen::powerlaw::PowerLaw;
    use crate::gen::stencil::{Grid, Stencil};
    use crate::gen::uniform::Uniform;
    use crate::gpusim::MultiDevice;
    use crate::sparse::stats::nprod_per_row;
    use crate::spgemm::sharded::{multiply_sharded_with, MeasuredShard, ShardPlan};

    let (n, kron_scale) = match scale {
        SuiteScale::Tiny => (2048usize, 10u32),
        SuiteScale::Small => (8192, 12),
        SuiteScale::Medium => (24576, 13),
    };
    let mut rng = crate::util::rng::Rng::new(seed);
    let mats: Vec<(&'static str, crate::sparse::Csr)> = vec![
        ("uniform", Uniform { n, per_row: 8, jitter: 4 }.generate(&mut rng)),
        (
            "powerlaw",
            PowerLaw {
                n,
                alpha: 2.2,
                max_row: (n / 32).max(64),
                mean_row: 8.0,
                hub_frac: 0.15,
                forced_giant_rows: 0,
            }
            .generate(&mut rng),
        ),
        (
            "stencil",
            Stencil { n, grid: Grid::D2, reach: 1, keep: 1.0, diagonal: true }
                .generate(&mut rng),
        ),
        (
            "kron",
            Kron { scale: kron_scale, edge_factor: 8, a: 0.57, b: 0.19, c: 0.19 }
                .generate(&mut rng),
        ),
    ];
    if verbose {
        println!(
            "\n=== Adaptive re-planning: cold (proxy-cut) vs warm (measured re-cut, \
             rollback on loss) compute makespan (scale {scale:?}) ==="
        );
        println!(
            "{:<10} {:>7} {:>12} {:>12} {:>12} {:>9} {:>9} {:>6}",
            "family", "shards", "cold-mk", "warm-mk", "recut-mk", "cold-imb", "warm-imb", "kept"
        );
    }
    let cfg = OpSparseConfig::default();
    let mut rows = Vec::new();
    for (family, a) in &mats {
        let nprod = nprod_per_row(a, a);
        for shards in [2usize, 4, 8] {
            let cold_plan = ShardPlan::balanced(&nprod, shards);
            let cold_out =
                multiply_sharded_with(a, a, &cfg, &cold_plan, None, OverlapConfig::off(), None)?;
            let cold_md = MultiDevice::simulate(cold_out.traces(), &V100);
            let cold_mk = cold_md.compute_makespan_ns();
            // the history's observation: the cold plan's ranges plus the
            // per-device simulated times
            let measured: Vec<MeasuredShard> = (0..shards)
                .map(|s| {
                    let (lo, hi) = cold_plan.range(s);
                    MeasuredShard { lo, hi, ns: cold_md.timelines[s].total_ns }
                })
                .collect();
            let warm_plan = ShardPlan::from_history(&nprod, shards, &measured);
            let warm_out =
                multiply_sharded_with(a, a, &cfg, &warm_plan, None, OverlapConfig::off(), None)?;
            anyhow::ensure!(
                warm_out.c == cold_out.c,
                "{family}/{shards}: re-planned result must be bit-identical"
            );
            let warm_md = MultiDevice::simulate(warm_out.traces(), &V100);
            let recut_mk = warm_md.compute_makespan_ns();
            // progressive re-allocation: adopt the re-cut only if it is
            // an actual re-cut (the hysteresis may keep the proxy
            // bounds verbatim — that is not a "kept re-cut") and the
            // re-measured run beat the proxy plan
            let kept = warm_plan.bounds() != cold_plan.bounds() && recut_mk <= cold_mk;
            let (warm_mk, warm_imb) = if kept {
                (recut_mk, warm_md.time_imbalance())
            } else {
                (cold_mk, cold_md.time_imbalance())
            };
            if verbose {
                println!(
                    "{:<10} {:>7} {:>10.1}us {:>10.1}us {:>10.1}us {:>8.3}x {:>8.3}x {:>6}",
                    family,
                    shards,
                    cold_mk / 1e3,
                    warm_mk / 1e3,
                    recut_mk / 1e3,
                    cold_md.time_imbalance(),
                    warm_imb,
                    if kept { "yes" } else { "no" }
                );
            }
            // the rollback above makes this structural; asserting it
            // HERE (not in each caller) is the one place a regression
            // could originate — the CLI, the bench binary, and CI all
            // inherit the guarantee
            anyhow::ensure!(
                warm_mk <= cold_mk + 1e-6,
                "{family}/{shards} shards: warm replanned makespan {:.1}us exceeds cold \
                 {:.1}us — the rollback guarantee is broken",
                warm_mk / 1e3,
                cold_mk / 1e3
            );
            rows.push(AdaptiveRow {
                family: *family,
                shards,
                cold_makespan_ns: cold_mk,
                warm_makespan_ns: warm_mk,
                replanned_makespan_ns: recut_mk,
                cold_imbalance: cold_md.time_imbalance(),
                warm_imbalance: warm_imb,
                kept_replan: kept,
            });
        }
    }
    Ok(rows)
}

/// Statistical overlap-dominance gate: run the shard-scaling bench with
/// the overlapped schedule on across adaptively many repetitions (fresh
/// power-law draw per rep; seed 2026 first so `BENCH_overlap.json` rows
/// stay comparable run-to-run), summing the serial and overlapped
/// makespans over all shard counts per rep, then test "overlapped not
/// significantly worse than serial" one-sided at `cfg.alpha`. Returns the
/// first repetition's rows (the JSON display) plus the verdict CI blocks
/// on. The loop is manual rather than [`crate::util::stats::sample_adaptive_paired`]
/// because each repetition can fail and the error must propagate.
pub fn overlap_gate(
    scale: SuiteScale,
    cfg: &crate::util::stats::AdaptiveConfig,
) -> Result<(Vec<ShardScalingRow>, crate::util::stats::GateResult)> {
    use crate::util::stats::{not_worse_gate, Samples};
    let ic = Interconnect::pcie3();
    let overlap = OverlapConfig { enabled: true, ..OverlapConfig::from_env() };
    let mut serial = Samples::new();
    let mut overlapped = Samples::new();
    let mut first_rows: Option<Vec<ShardScalingRow>> = None;
    for rep in 0..cfg.max_reps.max(cfg.min_reps).max(2) {
        let rows = shard_scaling_run(scale, Some(&ic), overlap, 2026 + rep as u64, rep == 0)?;
        serial.push(rows.iter().map(|r| r.makespan_ns).sum());
        overlapped.push(rows.iter().map(|r| r.overlapped_makespan_ns).sum());
        if first_rows.is_none() {
            first_rows = Some(rows);
        }
        if cfg.converged(&serial) && cfg.converged(&overlapped) {
            break;
        }
    }
    let gate = not_worse_gate("overlap_dominance", &overlapped, &serial, false, cfg.alpha);
    println!(
        "overlap gate: {} (p={:.4}, alpha={}, overlapped {:.1}us vs serial {:.1}us over {} reps)",
        if gate.pass { "pass" } else { "FAIL" },
        gate.p,
        gate.alpha,
        gate.candidate_mean / 1e3,
        gate.reference_mean / 1e3,
        gate.reps_candidate
    );
    Ok((first_rows.expect("at least one repetition"), gate))
}

/// Statistical warm-≤-cold gate for adaptive re-planning: re-run the
/// ablation across adaptively many repetitions (fresh generator seed per
/// rep; seed 2026 first, kept as the `BENCH_adaptive.json` rows), summing
/// cold and warm compute makespans over every (family × shard count) cell
/// per rep, then test "warm not significantly worse than cold" one-sided
/// at `cfg.alpha`. The per-cell structural rollback guarantee stays a
/// hard `ensure!` inside [`adaptive_replan_seeded`]; this gate is the
/// aggregate, noise-aware CI verdict.
pub fn adaptive_gate(
    scale: SuiteScale,
    cfg: &crate::util::stats::AdaptiveConfig,
) -> Result<(Vec<AdaptiveRow>, crate::util::stats::GateResult)> {
    use crate::util::stats::{not_worse_gate, Samples};
    let mut cold = Samples::new();
    let mut warm = Samples::new();
    let mut first_rows: Option<Vec<AdaptiveRow>> = None;
    for rep in 0..cfg.max_reps.max(cfg.min_reps).max(2) {
        let rows = adaptive_replan_seeded(scale, 2026 + rep as u64, rep == 0)?;
        cold.push(rows.iter().map(|r| r.cold_makespan_ns).sum());
        warm.push(rows.iter().map(|r| r.warm_makespan_ns).sum());
        if first_rows.is_none() {
            first_rows = Some(rows);
        }
        if cfg.converged(&cold) && cfg.converged(&warm) {
            break;
        }
    }
    let gate = not_worse_gate("adaptive_warm_le_cold", &warm, &cold, false, cfg.alpha);
    println!(
        "adaptive gate: {} (p={:.4}, alpha={}, warm {:.1}us vs cold {:.1}us over {} reps)",
        if gate.pass { "pass" } else { "FAIL" },
        gate.p,
        gate.alpha,
        gate.candidate_mean / 1e3,
        gate.reference_mean / 1e3,
        gate.reps_candidate
    );
    Ok((first_rows.expect("at least one repetition"), gate))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Smoke tests at Tiny scale on a subset — the full figures run in
    // `cargo bench` / the CLI.

    #[test]
    fn fig9_mechanism_holds_on_one_matrix() {
        let e = crate::gen::suite::suite_entry("cant").unwrap();
        let a = e.generate(SuiteScale::Tiny);
        let mut cfg = OpSparseConfig::default();
        cfg.hash_variant = HashVariant::SingleAccess;
        let s = multiply(&a, &a, &cfg).unwrap();
        cfg.hash_variant = HashVariant::MultiAccess;
        let m = multiply(&a, &a, &cfg).unwrap();
        let tl_s = simulate(&s.trace, &V100);
        let tl_m = simulate(&m.trace, &V100);
        assert!(
            tl_s.step_ns("numeric") < tl_m.step_ns("numeric"),
            "single access should be faster: {} vs {}",
            tl_s.step_ns("numeric"),
            tl_m.step_ns("numeric")
        );
    }

    #[test]
    fn pooled_ablation_mechanism_holds() {
        let rows = pool_ablation(SuiteScale::Tiny, 3).unwrap();
        assert!(!rows.is_empty());
        for r in &rows {
            assert_eq!(r.warm_mallocs, 0, "{}: warm reps must be malloc-free", r.matrix);
            assert!(
                r.warm_ns < r.percall_ns,
                "{}: pooled+cached should beat per-call ({} vs {})",
                r.matrix,
                r.warm_ns,
                r.percall_ns
            );
            assert!(
                r.warm_stall_ns < r.percall_stall_ns,
                "{}: warm allocation stalls should vanish",
                r.matrix
            );
        }
    }

    #[test]
    fn binning_fraction_is_small_for_opsparse() {
        let e = crate::gen::suite::suite_entry("offshore").unwrap();
        let a = e.generate(SuiteScale::Tiny);
        let (_, tl) = run_and_simulate(Library::OpSparse, &a, false).unwrap();
        let bin = tl.step_ns("sym_binning") + tl.step_ns("num_binning");
        let frac = bin / tl.total_ns;
        assert!(frac < 0.15, "OpSparse binning should be cheap, got {:.1}%", frac * 100.0);
    }

    #[test]
    fn adaptive_replan_warm_never_exceeds_cold() {
        let rows = adaptive_replan(SuiteScale::Tiny).unwrap();
        assert_eq!(rows.len(), 12, "4 families x 3 shard counts");
        for r in &rows {
            assert!(
                r.warm_makespan_ns <= r.cold_makespan_ns + 1e-6,
                "{}/{} shards: warm {:.1}us exceeds cold {:.1}us",
                r.family,
                r.shards,
                r.warm_makespan_ns / 1e3,
                r.cold_makespan_ns / 1e3
            );
            assert!(r.replanned_makespan_ns > 0.0 && r.cold_makespan_ns > 0.0);
        }
    }

    #[test]
    fn shard_scaling_makespan_decreases_and_stays_balanced() {
        let rows = shard_scaling(SuiteScale::Tiny).unwrap();
        assert_eq!(rows.len(), 4);
        // the compute critical path must decrease monotonically from
        // 1 -> 4 shards (the PR 2 property, untouched by transfers)
        for w in rows.windows(2).take(2) {
            assert!(
                w[1].compute_ns < w[0].compute_ns,
                "{} shards ({:.1}us) must beat {} shards ({:.1}us)",
                w[1].shards,
                w[1].compute_ns / 1e3,
                w[0].shards,
                w[0].compute_ns / 1e3
            );
        }
        // nprod-balanced partitioning keeps both planned and measured
        // load imbalance tight through 4 shards on the power-law input
        for r in rows.iter().filter(|r| r.shards <= 4) {
            assert!(
                r.plan_imbalance < 1.25,
                "{} shards: planned imbalance {:.3}",
                r.shards,
                r.plan_imbalance
            );
            assert!(
                r.time_imbalance < 1.25,
                "{} shards: measured imbalance {:.3}",
                r.shards,
                r.time_imbalance
            );
        }
    }

    #[test]
    fn shard_scaling_charges_transfers_and_reports_honest_efficiency() {
        let rows = shard_scaling(SuiteScale::Tiny).unwrap();
        // one shard = one device: nothing to replicate or gather
        assert_eq!(rows[0].broadcast_ns, 0.0);
        assert_eq!(rows[0].gather_ns, 0.0);
        // multi-shard rows pay for the B broadcast and the C gather, and
        // one-to-all replication grows with the fleet
        for w in rows.windows(2).skip(1) {
            assert!(w[1].broadcast_ns > w[0].broadcast_ns, "broadcast grows with devices");
            assert!(w[1].gather_ns > w[0].gather_ns, "gather grows with devices");
        }
        assert!(rows[1].broadcast_ns > 0.0 && rows[1].gather_ns > 0.0);
        for r in &rows {
            assert!(
                r.makespan_ns >= r.compute_ns,
                "{} shards: transfers cannot shorten the critical path",
                r.shards
            );
        }
        // honest efficiency: never super-linear, and monotone-degrading
        // as communication amortizes worse at this (tiny) job size
        for r in &rows {
            assert!(
                r.efficiency <= 1.0 + 1e-9,
                "{} shards: efficiency {:.3} over-reports",
                r.shards,
                r.efficiency
            );
        }
        for w in rows.windows(2) {
            assert!(
                w[1].efficiency <= w[0].efficiency + 1e-9,
                "efficiency must degrade: {} shards {:.3} vs {} shards {:.3}",
                w[1].shards,
                w[1].efficiency,
                w[0].shards,
                w[0].efficiency
            );
        }
        // the transfer-free view still reports the PR 2 figures
        let free =
            shard_scaling_with(SuiteScale::Tiny, None, OverlapConfig::default()).unwrap();
        for r in &free {
            assert_eq!(r.broadcast_ns, 0.0);
            assert_eq!(r.gather_ns, 0.0);
            assert_eq!(r.makespan_ns, r.compute_ns);
            assert_eq!(r.overlapped_makespan_ns, r.makespan_ns, "nothing to overlap");
            assert_eq!(r.overlap_saved_ns, 0.0);
        }
    }

    #[test]
    fn shard_scaling_overlap_beats_serial_and_never_exceeds_it() {
        // the acceptance property on the bench itself: with a chunked
        // broadcast (chunk << B) on the power-law input, the overlapped
        // makespan is <= serial at every shard count and strictly less
        // on the multi-shard rows
        let overlap = OverlapConfig { enabled: true, chunk_bytes: 128 << 10 };
        let rows =
            shard_scaling_with(SuiteScale::Tiny, Some(&Interconnect::pcie3()), overlap).unwrap();
        for r in &rows {
            assert!(
                r.overlapped_makespan_ns <= r.makespan_ns + 1e-6,
                "{} shards: overlapped {:.1}us > serial {:.1}us",
                r.shards,
                r.overlapped_makespan_ns / 1e3,
                r.makespan_ns / 1e3
            );
            assert!(r.overlap_saved_ns >= -1e-6);
            assert!(
                r.efficiency_overlapped >= r.efficiency - 1e-9,
                "{} shards: overlapped efficiency cannot be worse",
                r.shards
            );
        }
        // one shard has no transfers to hide
        assert_eq!(rows[0].overlap_saved_ns, 0.0);
        // the ISSUE's strictness clause: on this powerlaw config with a
        // chunked broadcast, pipelining must actually save time
        assert!(
            rows.iter().skip(1).all(|r| r.overlap_saved_ns > 0.0),
            "multi-shard rows must save: {:?}",
            rows.iter().map(|r| r.overlap_saved_ns).collect::<Vec<_>>()
        );
        // overlap off is the serial baseline, bit-for-bit on the figures
        let off = shard_scaling_with(
            SuiteScale::Tiny,
            Some(&Interconnect::pcie3()),
            OverlapConfig::off(),
        )
        .unwrap();
        for r in &off {
            assert_eq!(r.overlapped_makespan_ns, r.makespan_ns);
            assert_eq!(r.overlap_saved_ns, 0.0);
        }
    }
}
