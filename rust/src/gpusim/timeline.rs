//! Simulation output: kernel/host spans, per-step wall-time attribution,
//! SM load statistics, and a text Gantt renderer for the case-study
//! example (`examples/sim_timeline.rs`).

use crate::util::fmt;

/// A kernel's device execution span.
#[derive(Clone, Debug)]
pub struct KernelSpan {
    pub name: String,
    pub step: &'static str,
    pub stream: usize,
    pub start: f64,
    pub end: f64,
    pub blocks: usize,
    pub occupancy: f64,
}

/// A host-side operation span (mallocs, launches, frees, syncs).
#[derive(Clone, Debug)]
pub struct HostSpan {
    pub what: String,
    pub step: &'static str,
    pub start: f64,
    pub end: f64,
}

/// Full simulation result.
#[derive(Clone, Debug, Default)]
pub struct Timeline {
    pub kernels: Vec<KernelSpan>,
    pub host: Vec<HostSpan>,
    /// Total busy ns per SM (load-balance metric, §6.3.4).
    pub sm_busy_ns: Vec<f64>,
    pub total_ns: f64,
    /// Externally injected straggler delay folded into `total_ns`
    /// (chaos harness; 0 on every real simulation). Kept separate so
    /// consumers can recover the undelayed makespan.
    pub injected_delay_ns: f64,
}

/// Union length of a set of `[start, end)` intervals.
pub(crate) fn union_ns(mut spans: Vec<(f64, f64)>) -> f64 {
    spans.retain(|&(s, e)| e > s && s.is_finite() && e.is_finite());
    spans.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut total = 0.0;
    let mut cur: Option<(f64, f64)> = None;
    for (s, e) in spans {
        match cur {
            None => cur = Some((s, e)),
            Some((cs, ce)) => {
                if s <= ce {
                    cur = Some((cs, ce.max(e)));
                } else {
                    total += ce - cs;
                    cur = Some((s, e));
                }
            }
        }
    }
    if let Some((cs, ce)) = cur {
        total += ce - cs;
    }
    total
}

impl Timeline {
    /// Wall-clock time attributable to a pipeline step: union of the
    /// step's kernel spans and host spans.
    pub fn step_ns(&self, step: &str) -> f64 {
        let mut spans: Vec<(f64, f64)> = self
            .kernels
            .iter()
            .filter(|k| k.step == step)
            .map(|k| (k.start, k.end))
            .collect();
        spans.extend(
            self.host
                .iter()
                .filter(|h| h.step == step)
                .map(|h| (h.start, h.end)),
        );
        union_ns(spans)
    }

    /// Per-phase attribution for the tracing layer ([`crate::obs`]):
    /// the timeline's distinct pipeline steps in first-appearance order
    /// (kernels before host spans), each with its [`Timeline::step_ns`]
    /// union duration. Zero-duration steps are dropped — they would
    /// render as empty child spans.
    pub fn phase_spans(&self) -> Vec<(String, f64)> {
        let mut steps: Vec<&'static str> = Vec::new();
        for s in self
            .kernels
            .iter()
            .map(|k| k.step)
            .chain(self.host.iter().map(|h| h.step))
        {
            if !steps.contains(&s) {
                steps.push(s);
            }
        }
        steps
            .into_iter()
            .map(|s| (s.to_string(), self.step_ns(s)))
            .filter(|(_, ns)| *ns > 0.0)
            .collect()
    }

    /// Sum of kernel device durations for a step (ignores overlap; used
    /// for per-kernel accounting).
    pub fn step_kernel_sum_ns(&self, step: &str) -> f64 {
        self.kernels
            .iter()
            .filter(|k| k.step == step && k.end.is_finite())
            .map(|k| k.end - k.start)
            .sum()
    }

    /// SM load-balance coefficient: max busy / mean busy (1.0 = perfect).
    pub fn sm_imbalance(&self) -> f64 {
        if self.sm_busy_ns.is_empty() {
            return 1.0;
        }
        let max = self.sm_busy_ns.iter().cloned().fold(0.0, f64::max);
        let mean: f64 =
            self.sm_busy_ns.iter().sum::<f64>() / self.sm_busy_ns.len() as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    /// Host time spent inside `cudaMalloc` / `cudaFree` spans — the
    /// allocation-stall component of the timeline (§4.4–§4.6). The pooled
    /// ablation compares this against warm pooled calls, where it is 0.
    pub fn alloc_stall_ns(&self) -> f64 {
        self.host
            .iter()
            .filter(|h| h.what.starts_with("cudaMalloc") || h.what.starts_with("cudaFree"))
            .map(|h| h.end - h.start)
            .sum()
    }

    /// Fold an externally injected delay (a chaos-harness straggler)
    /// into the makespan. The per-shard timing view and the feedback
    /// history both read `total_ns`, so an injected delay makes the
    /// shard *look* slow exactly the way a real straggler would — which
    /// is what lets speculation react to it.
    pub fn inject_delay(&mut self, ns: f64) {
        self.injected_delay_ns += ns;
        self.total_ns += ns;
    }

    /// GFLOPS given a FLOP count (the paper's metric: 2·n_prod / time).
    pub fn gflops(&self, flops: f64) -> f64 {
        if self.total_ns <= 0.0 {
            0.0
        } else {
            flops / self.total_ns
        }
    }

    /// Render a text Gantt chart (width columns), kernels grouped by
    /// stream, plus host row.
    pub fn render_gantt(&self, width: usize) -> String {
        let mut out = String::new();
        if self.total_ns <= 0.0 {
            return "empty timeline\n".into();
        }
        let scale = width as f64 / self.total_ns;
        let bar = |s: f64, e: f64, c: char| -> String {
            let b = (s * scale) as usize;
            let l = (((e - s) * scale) as usize).max(1);
            format!("{}{}", " ".repeat(b.min(width)), c.to_string().repeat(l.min(width - b.min(width) + 1)))
        };
        out.push_str(&format!(
            "total {}  (1 col = {})\n",
            fmt::ns(self.total_ns),
            fmt::ns(self.total_ns / width as f64)
        ));
        out.push_str("HOST  |");
        let mut host_row = vec![' '; width + 2];
        for h in &self.host {
            let b = ((h.start * scale) as usize).min(width);
            let e = (((h.end) * scale) as usize).min(width + 1);
            let c = if h.what.starts_with("cudaMalloc") {
                'M'
            } else if h.what.starts_with("cudaFree") {
                'F'
            } else if h.what.starts_with("launch") {
                'L'
            } else {
                's'
            };
            for slot in host_row.iter_mut().take(e.max(b + 1)).skip(b) {
                *slot = c;
            }
        }
        out.push_str(&host_row.iter().collect::<String>());
        out.push('\n');
        for k in &self.kernels {
            if !k.start.is_finite() {
                continue;
            }
            out.push_str(&format!("s{:02}   |{}  {} [{}] ({} blk, occ {:.0}%)\n",
                k.stream,
                bar(k.start, k.end, '█'),
                k.name,
                k.step,
                k.blocks,
                k.occupancy * 100.0
            ));
        }
        out
    }
}

/// One occupied interval on an overlapped-execution lane (a broadcast
/// chunk in flight, a device computing, a gather block on the wire).
#[derive(Clone, Debug)]
pub struct LaneSpan {
    pub what: String,
    pub start: f64,
    pub end: f64,
}

impl LaneSpan {
    pub fn new(what: impl Into<String>, start: f64, end: f64) -> LaneSpan {
        LaneSpan { what: what.into(), start, end }
    }
}

/// Lane occupancy of one overlapped multi-device run: the **transfer**
/// lane (broadcast chunks + gather blocks on the interconnect) and the
/// **compute** lane (per-device busy windows). The serial model keeps
/// these lanes disjoint — transfer, then compute, then transfer — so the
/// time both lanes are busy at once is exactly what overlapping bought
/// (see [`OverlapLanes::overlapped_busy_ns`] and
/// `MultiDevice::overlap_saved_ns`).
#[derive(Clone, Debug, Default)]
pub struct OverlapLanes {
    /// Interconnect activity: broadcast chunk arrivals and gather blocks.
    pub transfer: Vec<LaneSpan>,
    /// Per-device compute windows (first issued op to device drain).
    pub compute: Vec<LaneSpan>,
    /// End of the overlapped timeline (the pipelined makespan).
    pub end_ns: f64,
}

impl OverlapLanes {
    fn union(spans: &[LaneSpan]) -> f64 {
        union_ns(spans.iter().map(|s| (s.start, s.end)).collect())
    }

    /// Wall time the interconnect lane is busy.
    pub fn transfer_busy_ns(&self) -> f64 {
        Self::union(&self.transfer)
    }

    /// Wall time at least one device is computing.
    pub fn compute_busy_ns(&self) -> f64 {
        Self::union(&self.compute)
    }

    /// Wall time both lanes are busy at once — the transfer cost hidden
    /// behind compute. Zero on a serial (non-overlapped) timeline.
    pub fn overlapped_busy_ns(&self) -> f64 {
        let mut boundaries: Vec<f64> = Vec::new();
        for s in self.transfer.iter().chain(&self.compute) {
            boundaries.push(s.start);
            boundaries.push(s.end);
        }
        boundaries.retain(|b| b.is_finite());
        boundaries.sort_by(|a, b| a.partial_cmp(b).unwrap());
        boundaries.dedup();
        let busy = |spans: &[LaneSpan], lo: f64, hi: f64| {
            spans.iter().any(|s| s.start < hi && s.end > lo)
        };
        boundaries
            .windows(2)
            .filter(|w| busy(&self.transfer, w[0], w[1]) && busy(&self.compute, w[0], w[1]))
            .map(|w| w[1] - w[0])
            .sum()
    }

    /// Occupancy of (transfer, compute) as fractions of the makespan.
    pub fn occupancy(&self) -> (f64, f64) {
        if self.end_ns <= 0.0 {
            return (0.0, 0.0);
        }
        (self.transfer_busy_ns() / self.end_ns, self.compute_busy_ns() / self.end_ns)
    }

    /// Render the two lanes as a text diagram (`width` columns): one
    /// `XFER` row plus one row per compute span.
    pub fn render(&self, width: usize) -> String {
        let mut out = String::new();
        if self.end_ns <= 0.0 {
            return "empty lanes\n".into();
        }
        let scale = width as f64 / self.end_ns;
        let mut row = |label: &str, spans: &[&LaneSpan], c: char| {
            let mut cells = vec![' '; width + 1];
            for s in spans {
                let b = ((s.start * scale) as usize).min(width);
                let e = (((s.end * scale) as usize).max(b + 1)).min(width + 1);
                for slot in cells.iter_mut().take(e).skip(b) {
                    *slot = c;
                }
            }
            out.push_str(&format!("{label:<6}|{}\n", cells.iter().collect::<String>()));
        };
        row("XFER", &self.transfer.iter().collect::<Vec<_>>(), '▒');
        for s in &self.compute {
            row(&s.what, &[s], '█');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn union_of_overlapping_intervals() {
        assert_eq!(union_ns(vec![(0.0, 10.0), (5.0, 15.0)]), 15.0);
        assert_eq!(union_ns(vec![(0.0, 5.0), (10.0, 12.0)]), 7.0);
        assert_eq!(union_ns(vec![]), 0.0);
    }

    #[test]
    fn step_attribution() {
        let tl = Timeline {
            kernels: vec![
                KernelSpan { name: "a".into(), step: "symbolic", stream: 0, start: 0.0, end: 10.0, blocks: 1, occupancy: 1.0 },
                KernelSpan { name: "b".into(), step: "numeric", stream: 0, start: 10.0, end: 30.0, blocks: 1, occupancy: 1.0 },
            ],
            host: vec![],
            sm_busy_ns: vec![],
            total_ns: 30.0,
            injected_delay_ns: 0.0,
        };
        assert_eq!(tl.step_ns("symbolic"), 10.0);
        assert_eq!(tl.step_ns("numeric"), 20.0);
        assert_eq!(tl.step_ns("setup"), 0.0);
        assert_eq!(
            tl.phase_spans(),
            vec![("symbolic".to_string(), 10.0), ("numeric".to_string(), 20.0)],
            "ordered distinct steps with union durations"
        );
    }

    #[test]
    fn imbalance_metric() {
        let tl = Timeline { sm_busy_ns: vec![10.0, 10.0, 10.0, 10.0], ..Default::default() };
        assert!((tl.sm_imbalance() - 1.0).abs() < 1e-9);
        let tl2 = Timeline { sm_busy_ns: vec![40.0, 0.0, 0.0, 0.0], ..Default::default() };
        assert!((tl2.sm_imbalance() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn gantt_renders() {
        let tl = Timeline {
            kernels: vec![KernelSpan { name: "k".into(), step: "numeric", stream: 0, start: 0.0, end: 100.0, blocks: 2, occupancy: 0.5 }],
            host: vec![HostSpan { what: "cudaMalloc(x, 4B)".into(), step: "setup", start: 0.0, end: 50.0 }],
            sm_busy_ns: vec![],
            total_ns: 100.0,
            injected_delay_ns: 0.0,
        };
        let g = tl.render_gantt(40);
        assert!(g.contains("k [numeric]"));
        assert!(g.contains('M'));
    }

    #[test]
    fn lane_occupancy_and_overlap() {
        let lanes = OverlapLanes {
            transfer: vec![LaneSpan::new("bcast", 0.0, 10.0), LaneSpan::new("gather", 25.0, 30.0)],
            compute: vec![LaneSpan::new("dev0", 5.0, 25.0)],
            end_ns: 30.0,
        };
        assert!((lanes.transfer_busy_ns() - 15.0).abs() < 1e-9);
        assert!((lanes.compute_busy_ns() - 20.0).abs() < 1e-9);
        // transfer ∩ compute: [5, 10) only
        assert!((lanes.overlapped_busy_ns() - 5.0).abs() < 1e-9);
        let (t, c) = lanes.occupancy();
        assert!((t - 0.5).abs() < 1e-9);
        assert!((c - 2.0 / 3.0).abs() < 1e-9);
        let diagram = lanes.render(30);
        assert!(diagram.contains("XFER"));
        assert!(diagram.contains("dev0"));
    }

    #[test]
    fn injected_delay_extends_the_makespan_and_is_recoverable() {
        let mut tl = Timeline { total_ns: 100.0, ..Default::default() };
        tl.inject_delay(40.0);
        tl.inject_delay(10.0);
        assert_eq!(tl.total_ns, 150.0);
        assert_eq!(tl.injected_delay_ns, 50.0);
        assert_eq!(tl.total_ns - tl.injected_delay_ns, 100.0);
    }

    #[test]
    fn disjoint_lanes_have_zero_overlap() {
        let lanes = OverlapLanes {
            transfer: vec![LaneSpan::new("bcast", 0.0, 10.0)],
            compute: vec![LaneSpan::new("dev0", 10.0, 20.0)],
            end_ns: 20.0,
        };
        assert_eq!(lanes.overlapped_busy_ns(), 0.0);
    }
}
