//! Chunk-size selection from measured arrival slack — the third
//! feedback loop.
//!
//! The overlapped broadcast streams `B` in row-panel chunks
//! ([`crate::gpusim::OverlapConfig::chunk_bytes`], a fixed 1 MiB by
//! default). The right granularity is workload-dependent and
//! observable:
//!
//! * **Devices stall on `AwaitChunk`** (compute finishes later than the
//!   serial compute time because panels arrive too slowly) → *shrink*
//!   chunks, so the first panels land earlier and the symbolic kernels
//!   start sooner.
//! * **The pipeline cannot fill** (the per-chunk hop latency exceeds a
//!   chunk's wire time, so chunking pays latency without buying
//!   overlap) → *grow* chunks, amortizing the per-message cost.
//!
//! [`tune_chunk_bytes`] applies one multiplicative step per observed
//! run; the history ([`super::history::ExecHistory`]) stores the tuned
//! size per pattern, and warm runs broadcast at the tuned granularity.

use crate::gpusim::MAX_CHUNKS;

/// Smallest chunk the tuner will choose: below this the per-chunk
/// launch/latency overheads dominate any pipelining win.
pub const MIN_CHUNK_BYTES: usize = 64 << 10;

/// Largest chunk the tuner will choose (a whole-transfer chunk is the
/// unpipelined broadcast; there is no point growing past it).
pub const MAX_CHUNK_BYTES: usize = 64 << 20;

/// Worst per-device stall above this fraction of the compute makespan
/// triggers a shrink step.
const STALL_SHRINK_FRAC: f64 = 0.05;

/// One overlapped run's chunk-granularity measurements, extracted from
/// the simulated schedule (`MultiDevice::overlap_stall_ns` and the
/// interconnect parameters).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ChunkFeedback {
    /// Chunk size the run was configured with (bytes).
    pub chunk_bytes: usize,
    /// Chunks the broadcast actually split into (after clamping).
    pub chunks: usize,
    /// Broadcast payload (bytes of `B`).
    pub b_bytes: usize,
    /// Worst per-device time lost waiting on chunk arrivals (the max
    /// over `MultiDevice::overlap_stall_ns`): the arrival *slack* the
    /// schedule failed to hide on the critical path. Per-device — not
    /// summed over the fleet — so the shrink threshold means the same
    /// thing at 2 devices and at 8.
    pub stall_ns: f64,
    /// Compute makespan of the run (the scale stalls are judged
    /// against).
    pub compute_ns: f64,
    /// Interconnect per-message (hop) latency, ns.
    pub hop_latency_ns: f64,
    /// Wire time of one chunk at the link bandwidth, ns.
    pub chunk_xfer_ns: f64,
}

/// One multiplicative tuning step from a measured run: shrink on
/// arrival stall, grow when per-chunk latency keeps the pipeline from
/// filling, otherwise keep. Always returns a value in
/// [`MIN_CHUNK_BYTES`], [`MAX_CHUNK_BYTES`].
pub fn tune_chunk_bytes(fb: &ChunkFeedback) -> usize {
    let cur = fb.chunk_bytes.clamp(MIN_CHUNK_BYTES, MAX_CHUNK_BYTES);
    if fb.b_bytes == 0 || fb.chunks == 0 {
        return cur;
    }
    let stall_frac = if fb.compute_ns > 0.0 { fb.stall_ns / fb.compute_ns } else { 0.0 };
    if stall_frac > STALL_SHRINK_FRAC && fb.chunks < MAX_CHUNKS {
        // panels arrive too late: finer chunks land the first panel
        // earlier. (At MAX_CHUNKS the clamp makes shrinking a no-op:
        // the stall is bandwidth, not granularity.)
        return (cur / 2).max(MIN_CHUNK_BYTES);
    }
    if fb.chunks > 1 && fb.hop_latency_ns > fb.chunk_xfer_ns {
        // each chunk pays more latency than wire time: the pipeline
        // cannot fill, so chunking is pure overhead — coarsen
        return cur.saturating_mul(2).min(MAX_CHUNK_BYTES);
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> ChunkFeedback {
        ChunkFeedback {
            chunk_bytes: 1 << 20,
            chunks: 8,
            b_bytes: 8 << 20,
            stall_ns: 0.0,
            compute_ns: 1_000_000.0,
            hop_latency_ns: 5_000.0,
            chunk_xfer_ns: 80_000.0,
        }
    }

    #[test]
    fn stall_shrinks_chunks() {
        let fb = ChunkFeedback { stall_ns: 200_000.0, ..base() };
        assert_eq!(tune_chunk_bytes(&fb), (1 << 20) / 2);
    }

    #[test]
    fn latency_bound_pipeline_grows_chunks() {
        // hop latency above one chunk's wire time, no stall: coarsen
        let fb = ChunkFeedback { hop_latency_ns: 100_000.0, ..base() };
        assert_eq!(tune_chunk_bytes(&fb), 2 << 20);
    }

    #[test]
    fn balanced_run_keeps_the_size() {
        assert_eq!(tune_chunk_bytes(&base()), 1 << 20);
    }

    #[test]
    fn bounds_hold_under_repeated_steps() {
        // repeated shrink bottoms out at MIN, repeated grow tops out at MAX
        let mut fb = ChunkFeedback { stall_ns: 500_000.0, ..base() };
        for _ in 0..32 {
            fb.chunk_bytes = tune_chunk_bytes(&fb);
        }
        assert_eq!(fb.chunk_bytes, MIN_CHUNK_BYTES);
        let mut fb = ChunkFeedback { hop_latency_ns: 1e9, ..base() };
        for _ in 0..32 {
            fb.chunk_bytes = tune_chunk_bytes(&fb);
        }
        assert_eq!(fb.chunk_bytes, MAX_CHUNK_BYTES);
    }

    #[test]
    fn clamped_chunk_count_does_not_shrink_further() {
        // already at the chunk-count clamp: the stall is bandwidth-bound,
        // shrinking buys nothing
        let fb = ChunkFeedback { stall_ns: 500_000.0, chunks: MAX_CHUNKS, ..base() };
        assert_eq!(tune_chunk_bytes(&fb), 1 << 20);
    }

    #[test]
    fn degenerate_feedback_is_identity() {
        let fb = ChunkFeedback { b_bytes: 0, ..base() };
        assert_eq!(tune_chunk_bytes(&fb), 1 << 20);
        let fb = ChunkFeedback { chunks: 0, ..base() };
        assert_eq!(tune_chunk_bytes(&fb), 1 << 20);
        // out-of-band configured size is clamped on the way through
        let fb = ChunkFeedback { chunk_bytes: 1, ..base() };
        assert_eq!(tune_chunk_bytes(&fb), MIN_CHUNK_BYTES);
    }
}
