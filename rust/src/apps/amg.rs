//! Algebraic multigrid (aggregation-based) — the paper's first motivating
//! application [1, 2]. The setup phase is SpGEMM-bound: each level's
//! coarse operator is the Galerkin triple product `A_c = R·(A·P)` with
//! `R = Pᵀ`, computed here through the full OpSparse pipeline.
//!
//! The solver is a V-cycle with weighted-Jacobi smoothing and a dense
//! direct solve on the coarsest level — enough to demonstrate real
//! convergence on Poisson problems from the suite's stencil generator.

use super::SpgemmContext;
use crate::sparse::ops::{diagonal, norm2, spmv, transpose};
use crate::sparse::{Csr, Dense};
use anyhow::{ensure, Context, Result};

/// One multigrid level.
pub struct Level {
    pub a: Csr,
    /// Prolongation to this level from the next-coarser one (absent on
    /// the coarsest level).
    pub p: Option<Csr>,
    inv_diag: Vec<f64>,
}

/// Aggregation-based AMG hierarchy.
pub struct AmgHierarchy {
    pub levels: Vec<Level>,
    /// Dense LU-ish factor of the coarsest operator (plain Gaussian
    /// elimination; the coarsest level is small by construction).
    coarse: Dense,
    /// SpGEMM statistics of the setup phase (the paper's workload).
    pub setup_spgemm_products: usize,
}

/// Two-pass standard aggregation (Vaněk-style): pass 1 seeds aggregates
/// at nodes whose strong neighbourhood is fully unaggregated (capturing
/// the whole stencil star), pass 2 attaches leftovers to a neighbouring
/// aggregate. Produces stencil-sized aggregates (≈5 on a 5-point grid),
/// which is what makes the V-cycle converge.
fn aggregate(a: &Csr, theta: f64) -> Vec<u32> {
    let n = a.rows;
    let diag = diagonal(a);
    let strong = |i: usize, j: usize, v: f64| {
        j != i && v.abs() > theta * (diag[i].abs() * diag[j].abs()).sqrt()
    };
    let mut agg: Vec<i64> = vec![-1; n];
    let mut next = 0u32;
    // pass 1: seed where the whole strong neighbourhood is free
    for i in 0..n {
        if agg[i] >= 0 {
            continue;
        }
        let (cols, vals) = a.row(i);
        let free = cols
            .iter()
            .zip(vals)
            .filter(|(&c, &v)| strong(i, c as usize, v))
            .all(|(&c, _)| agg[c as usize] < 0);
        if !free {
            continue;
        }
        agg[i] = next as i64;
        for (&c, &v) in cols.iter().zip(vals) {
            if strong(i, c as usize, v) {
                agg[c as usize] = next as i64;
            }
        }
        next += 1;
    }
    // pass 2: attach leftovers to any strongly-connected aggregate
    for i in 0..n {
        if agg[i] >= 0 {
            continue;
        }
        let (cols, vals) = a.row(i);
        let joined = cols
            .iter()
            .zip(vals)
            .filter(|(&c, &v)| strong(i, c as usize, v) && agg[c as usize] >= 0)
            .map(|(&c, _)| agg[c as usize])
            .next();
        match joined {
            Some(id) => agg[i] = id,
            None => {
                agg[i] = next as i64;
                next += 1;
            }
        }
    }
    agg.into_iter().map(|x| x as u32).collect()
}

/// Piecewise-constant prolongation from an aggregation.
fn prolongation(agg: &[u32]) -> Csr {
    let n = agg.len();
    let ncoarse = agg.iter().copied().max().map(|m| m as usize + 1).unwrap_or(0);
    let rpt: Vec<usize> = (0..=n).collect();
    let col: Vec<u32> = agg.to_vec();
    let val = vec![1.0; n];
    Csr { rows: n, cols: ncoarse, rpt, col, val }
}

impl AmgHierarchy {
    /// Build the hierarchy for a symmetric M-matrix-ish `a` with a fresh
    /// [`SpgemmContext`] (one-shot setup).
    pub fn build(a: &Csr, theta: f64, coarse_limit: usize, max_levels: usize) -> Result<Self> {
        AmgHierarchy::build_with(&mut SpgemmContext::new(), a, theta, coarse_limit, max_levels)
    }

    /// Build the hierarchy through a caller-owned context. Re-setup on a
    /// fixed mesh — new operator values, same stencil every timestep —
    /// replays every level's cached symbolic phase and recycles every
    /// allocation from the context's pool.
    pub fn build_with(
        ctx: &mut SpgemmContext,
        a: &Csr,
        theta: f64,
        coarse_limit: usize,
        max_levels: usize,
    ) -> Result<Self> {
        ensure!(a.rows == a.cols, "AMG needs a square operator");
        let mut levels = Vec::new();
        let mut cur = a.clone();
        let mut products = 0usize;
        while cur.rows > coarse_limit && levels.len() + 1 < max_levels {
            let agg = aggregate(&cur, theta);
            let p_tent = prolongation(&agg);
            if p_tent.cols >= cur.rows {
                break; // aggregation stalled
            }
            // smoothed aggregation: P = (I - w D^-1 A) P_tent — one extra
            // SpGEMM per level, and the classic fix for the slow
            // piecewise-constant two-grid rate
            let ap_tent = ctx.multiply(&cur, &p_tent).context("A*P_tent")?;
            products += ap_tent.nprod;
            let inv_d = diagonal(&cur);
            let mut damped = ap_tent.c;
            const W_SMOOTH: f64 = 2.0 / 3.0;
            for i in 0..damped.rows {
                let s = if inv_d[i] != 0.0 { W_SMOOTH / inv_d[i] } else { 0.0 };
                let (lo, hi) = (damped.rpt[i], damped.rpt[i + 1]);
                for v in &mut damped.val[lo..hi] {
                    *v *= s;
                }
            }
            let p = crate::sparse::ops::add(&p_tent, &crate::sparse::ops::scale(&damped, -1.0))
                .context("P smoothing")?;
            let r = transpose(&p);
            // Galerkin triple product through the OpSparse pipeline
            let ap = ctx.multiply(&cur, &p).context("A*P")?;
            let rap = ctx.multiply(&r, &ap.c).context("R*(AP)")?;
            products += ap.nprod + rap.nprod;
            let inv_diag = diagonal(&cur).iter().map(|&d| if d != 0.0 { 1.0 / d } else { 0.0 }).collect();
            levels.push(Level { a: cur, p: Some(p), inv_diag });
            cur = rap.c;
        }
        let inv_diag = diagonal(&cur).iter().map(|&d| if d != 0.0 { 1.0 / d } else { 0.0 }).collect();
        let coarse = Dense::from(&cur);
        levels.push(Level { a: cur, p: None, inv_diag });
        Ok(AmgHierarchy { levels, coarse, setup_spgemm_products: products })
    }

    /// Weighted Jacobi: `x += w * D^-1 (b - A x)`.
    fn smooth(level: &Level, x: &mut [f64], b: &[f64], sweeps: usize) {
        const W: f64 = 0.8;
        for _ in 0..sweeps {
            let ax = spmv(&level.a, x);
            for i in 0..x.len() {
                x[i] += W * level.inv_diag[i] * (b[i] - ax[i]);
            }
        }
    }

    /// Dense Gaussian elimination on the coarsest level.
    fn coarse_solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.coarse.rows;
        let mut m = self.coarse.data.clone();
        let mut rhs = b.to_vec();
        // forward elimination with partial pivoting
        let mut perm: Vec<usize> = (0..n).collect();
        for k in 0..n {
            let piv = (k..n)
                .max_by(|&i, &j| {
                    m[perm[i] * n + k].abs().partial_cmp(&m[perm[j] * n + k].abs()).unwrap()
                })
                .unwrap();
            perm.swap(k, piv);
            let pk = perm[k];
            let d = m[pk * n + k];
            if d.abs() < 1e-300 {
                continue;
            }
            for i in k + 1..n {
                let pi = perm[i];
                let f = m[pi * n + k] / d;
                if f == 0.0 {
                    continue;
                }
                for j in k..n {
                    m[pi * n + j] -= f * m[pk * n + j];
                }
                rhs[pi] -= f * rhs[pk];
            }
        }
        // back substitution
        let mut x = vec![0.0; n];
        for k in (0..n).rev() {
            let pk = perm[k];
            let mut s = rhs[pk];
            for j in k + 1..n {
                s -= m[pk * n + j] * x[j];
            }
            let d = m[pk * n + k];
            x[k] = if d.abs() < 1e-300 { 0.0 } else { s / d };
        }
        x
    }

    fn vcycle(&self, lvl: usize, x: &mut Vec<f64>, b: &[f64]) {
        let level = &self.levels[lvl];
        if level.p.is_none() {
            *x = self.coarse_solve(b);
            return;
        }
        Self::smooth(level, x, b, 2);
        // restrict the residual
        let ax = spmv(&level.a, x);
        let r: Vec<f64> = b.iter().zip(&ax).map(|(bi, ai)| bi - ai).collect();
        let p = level.p.as_ref().unwrap();
        let rt = transpose(p);
        let rc = spmv(&rt, &r);
        let mut xc = vec![0.0; rc.len()];
        self.vcycle(lvl + 1, &mut xc, &rc);
        // prolongate + correct
        let corr = spmv(p, &xc);
        for i in 0..x.len() {
            x[i] += corr[i];
        }
        Self::smooth(level, x, b, 2);
    }

    /// Solve `A x = b` to relative residual `tol`; returns (x, iterations,
    /// final relative residual).
    pub fn solve(&self, b: &[f64], tol: f64, max_iters: usize) -> (Vec<f64>, usize, f64) {
        let a = &self.levels[0].a;
        let bnorm = norm2(b).max(1e-300);
        let mut x = vec![0.0; a.rows];
        for it in 0..max_iters {
            self.vcycle(0, &mut x, b);
            let ax = spmv(a, &x);
            let r: Vec<f64> = b.iter().zip(&ax).map(|(bi, ai)| bi - ai).collect();
            let rel = norm2(&r) / bnorm;
            if rel < tol {
                return (x, it + 1, rel);
            }
        }
        let ax = spmv(a, &x);
        let r: Vec<f64> = b.iter().zip(&ax).map(|(bi, ai)| bi - ai).collect();
        (x, max_iters, norm2(&r) / bnorm)
    }
}

/// 2D Poisson operator (5-point, Dirichlet) on a `side x side` grid —
/// the classic AMG test problem.
pub fn poisson2d(side: usize) -> Csr {
    let n = side * side;
    let mut rpt = vec![0usize; n + 1];
    let mut col = Vec::new();
    let mut val = Vec::new();
    for i in 0..n {
        let (x, y) = (i % side, i / side);
        let mut push = |c: usize, v: f64| {
            col.push(c as u32);
            val.push(v);
        };
        // sorted column order: up, left, center, right, down
        if y > 0 {
            push(i - side, -1.0);
        }
        if x > 0 {
            push(i - 1, -1.0);
        }
        push(i, 4.0);
        if x + 1 < side {
            push(i + 1, -1.0);
        }
        if y + 1 < side {
            push(i + side, -1.0);
        }
        rpt[i + 1] = col.len();
    }
    Csr { rows: n, cols: n, rpt, col, val }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spgemm::pipeline::{multiply, OpSparseConfig};
    use crate::util::rng::Rng;

    #[test]
    fn poisson_operator_is_valid_and_spd_ish() {
        let a = poisson2d(16);
        a.validate().unwrap();
        assert_eq!(a.rows, 256);
        // diagonally dominant
        for i in 0..a.rows {
            let (cols, vals) = a.row(i);
            let off: f64 = cols
                .iter()
                .zip(vals)
                .filter(|(&c, _)| c as usize != i)
                .map(|(_, &v)| v.abs())
                .sum();
            assert!(a.get(i, i) >= off, "row {i} not diagonally dominant");
        }
    }

    #[test]
    fn hierarchy_coarsens() {
        let a = poisson2d(24);
        let h = AmgHierarchy::build(&a, 0.1, 50, 10).unwrap();
        assert!(h.levels.len() >= 2, "should build >= 2 levels");
        for w in h.levels.windows(2) {
            assert!(w[1].a.rows < w[0].a.rows, "levels must shrink");
        }
        assert!(h.setup_spgemm_products > 0);
    }

    #[test]
    fn timestep_resetup_hits_the_symbolic_cache() {
        let a = poisson2d(24);
        let mut ctx = SpgemmContext::new();
        let h1 = AmgHierarchy::build_with(&mut ctx, &a, 0.1, 50, 10).unwrap();
        assert_eq!(ctx.sym_cache_hits(), 0, "first setup computes everything");
        // same mesh at the next timestep: refreshed coefficient values,
        // unchanged stencil — aggregation and every product pattern repeat
        let mut a2 = a.clone();
        for v in &mut a2.val {
            *v *= 1.5;
        }
        let h2 = AmgHierarchy::build_with(&mut ctx, &a2, 0.1, 50, 10).unwrap();
        assert!(ctx.sym_cache_hits() > 0, "re-setup must replay symbolic phases");
        assert_eq!(h1.levels.len(), h2.levels.len());
        assert!(ctx.pool_stats().pool_hits > 0, "re-setup must recycle pool buckets");
    }

    #[test]
    fn setup_on_an_operator_that_only_fits_sharded() {
        use crate::coordinator::router::{Router, RouterConfig};
        let a = poisson2d(24);
        let mut plain = SpgemmContext::new();
        let h_plain = AmgHierarchy::build_with(&mut plain, &a, 0.1, 50, 10).unwrap();
        // a device budget far below the finest-level Galerkin products:
        // the same build now runs its big multiplies row-sharded
        // memory-only routing: force the sharded path regardless of the
        // modeled replication cost (a 24x24 Poisson operator is small)
        let router = Router::new(RouterConfig {
            device_memory_bytes: 8 * 1024,
            max_devices: 4,
            interconnect: None,
            ..Default::default()
        });
        let mut ctx = SpgemmContext::with_router(router);
        let h = AmgHierarchy::build_with(&mut ctx, &a, 0.1, 50, 10).unwrap();
        assert!(ctx.sharded_multiplies() > 0, "the finest products must shard");
        assert_eq!(h.levels.len(), h_plain.levels.len());
        for (l, lp) in h.levels.iter().zip(&h_plain.levels) {
            assert_eq!(l.a, lp.a, "sharded setup must build identical operators");
        }
        let b = vec![1.0; a.rows];
        let (_, iters, rel) = h.solve(&b, 1e-8, 60);
        assert!(rel < 1e-8, "sharded-setup hierarchy must converge: rel={rel} after {iters}");
    }

    #[test]
    fn galerkin_operator_is_consistent() {
        // RAP computed by the pipeline must equal the reference triple
        // product
        let a = poisson2d(12);
        let agg = super::aggregate(&a, 0.1);
        let p = super::prolongation(&agg);
        let r = transpose(&p);
        let cfg = OpSparseConfig::default();
        let rap_pipeline =
            multiply(&r, &multiply(&a, &p, &cfg).unwrap().c, &cfg).unwrap().c;
        let gold = crate::spgemm::reference::spgemm_reference(
            &r,
            &crate::spgemm::reference::spgemm_reference(&a, &p),
        );
        assert!(rap_pipeline.approx_eq(&gold, 1e-12));
    }

    #[test]
    fn vcycle_converges_on_poisson() {
        let a = poisson2d(32);
        let h = AmgHierarchy::build(&a, 0.1, 40, 8).unwrap();
        let mut rng = Rng::new(7);
        let xstar: Vec<f64> = (0..a.rows).map(|_| rng.value()).collect();
        let b = spmv(&a, &xstar);
        let (x, iters, rel) = h.solve(&b, 1e-8, 60);
        assert!(rel < 1e-8, "did not converge: rel={rel} after {iters} iters");
        // the Poisson condition number amplifies residual into solution
        // error by O(h^-2); 1e-8 residual => ~1e-5 error at this size
        let err: f64 = x.iter().zip(&xstar).map(|(a, b)| (a - b).powi(2)).sum::<f64>().sqrt();
        assert!(err < 1e-3, "solution error {err}");
    }

    #[test]
    fn coarse_solver_exact_on_small_system() {
        let a = poisson2d(4); // 16x16 — goes straight to the dense solve
        let h = AmgHierarchy::build(&a, 0.1, 100, 8).unwrap();
        assert_eq!(h.levels.len(), 1);
        let b = vec![1.0; a.rows];
        let (x, _, rel) = h.solve(&b, 1e-12, 3);
        assert!(rel < 1e-12, "direct solve should be exact: {rel}");
        let _ = x;
    }
}
