//! Application workloads from the paper's introduction (§1): the reason
//! SpGEMM performance matters. Each app drives the OpSparse pipeline (or
//! a semiring variant) as its compute primitive:
//!
//! * [`amg`] — algebraic multigrid: the Galerkin triple product
//!   `A_coarse = R·A·P` is two SpGEMMs per level [1, 2].
//! * [`mcl`] — Markov clustering: the expansion step is `M²` [3].
//! * [`msbfs`] — multi-source BFS: frontier expansion is a boolean
//!   SpGEMM `F ⊗ A` [4].
//!
//! These apps are exactly the repeated-pattern workloads the device pool
//! and symbolic-reuse cache target: AMG re-setup on a fixed mesh reruns
//! the same Galerkin products every timestep, and MCL's expansion pattern
//! stabilizes as the clustering converges. [`SpgemmContext`] bundles a
//! [`DevicePool`] and a [`PatternCache`] so an app (or a caller looping
//! an app) reuses allocations and symbolic results across its multiplies.

pub mod amg;
pub mod mcl;
pub mod msbfs;

use crate::coordinator::cache::{PatternCache, PatternKey};
use crate::coordinator::feedback::{ChunkFeedback, ExecHistory, ReplanConfig, RunObservation};
use crate::coordinator::router::Router;
use crate::gpusim::{DevicePool, MultiDevice, OverlapConfig, PoolStats, V100};
use crate::sparse::stats::nprod_per_row;
use crate::sparse::Csr;
use crate::spgemm::pipeline::{multiply_reuse, OpSparseConfig, SpgemmOutput, SymbolicReuse};
use crate::spgemm::sharded::{multiply_sharded_with, ShardPlan, ShardReuse, ShardedOutput};
use anyhow::Result;
use std::sync::Arc;

/// Warm multiply state for an application: one device pool plus one
/// sparsity-pattern cache, threaded through every SpGEMM the app issues.
/// With a router attached ([`SpgemmContext::with_router`]) a multiply
/// whose working set exceeds the router's single-device budget runs
/// row-sharded across per-device pools instead — an app like AMG setup
/// then handles operators that only fit sharded without code changes.
/// With re-planning on top ([`SpgemmContext::with_router_replan`]) the
/// context also threads a pattern-keyed execution history through the
/// sharded path: each run records its simulated per-device times (and
/// chunk-arrival stalls), and the *next* multiply of the same pattern —
/// AMG re-setup on the same level operators — re-cuts its shard bounds
/// from the measurement and broadcasts at the tuned chunk size.
pub struct SpgemmContext {
    pool: DevicePool,
    /// Per-device pools for the sharded path, grown on demand.
    shard_pools: Vec<DevicePool>,
    cache: PatternCache,
    router: Option<Router>,
    sharded_multiplies: u64,
    /// Pattern-keyed measured-run store for the adaptive loop.
    history: ExecHistory,
    replan: ReplanConfig,
    replans: u64,
    replan_cold: u64,
    pub cfg: OpSparseConfig,
}

impl SpgemmContext {
    /// Default-capacity context (64 cached patterns).
    pub fn new() -> Self {
        SpgemmContext::with_capacity(64)
    }

    pub fn with_capacity(patterns: usize) -> Self {
        // re-planning is opt-in per context (`with_router_replan`): a
        // plain context keeps the proxy-planned behavior exactly
        let replan = ReplanConfig::off();
        SpgemmContext {
            pool: DevicePool::new(),
            shard_pools: Vec::new(),
            cache: PatternCache::new(patterns),
            router: None,
            sharded_multiplies: 0,
            history: ExecHistory::new(replan.history_cap),
            replan,
            replans: 0,
            replan_cold: 0,
            cfg: OpSparseConfig::default(),
        }
    }

    /// A context that consults `router` before every multiply and takes
    /// the row-sharded multi-device path when the router says the job
    /// exceeds one device's memory budget.
    pub fn with_router(router: Router) -> Self {
        let mut ctx = SpgemmContext::new();
        ctx.router = Some(router);
        ctx
    }

    /// [`SpgemmContext::with_router`] with the adaptive feedback loop
    /// on: sharded multiplies record measured (simulated) per-device
    /// times into an execution history, and repeats of a pattern re-cut
    /// their shard bounds from it ([`ShardPlan::from_history`]) instead
    /// of the `nprod` proxy — the AMG re-setup loop re-plans between
    /// levels. Results stay bit-identical whatever the plan; only time
    /// moves.
    pub fn with_router_replan(router: Router, replan: ReplanConfig) -> Self {
        let mut ctx = SpgemmContext::new();
        ctx.router = Some(router);
        ctx.history = ExecHistory::new(replan.history_cap);
        ctx.replan = replan;
        ctx
    }

    /// `C = A·B` through the pooled pipeline, replaying the symbolic
    /// phase when this context has seen the pattern pair before. When a
    /// router is attached and the working set exceeds its device budget,
    /// the multiply runs row-sharded; the returned output's trace is then
    /// the serialized concatenation of the per-device traces (see
    /// [`crate::spgemm::ShardedOutput::into_output`]). The symbolic
    /// cache covers this path too, with **shard-aware keys**
    /// `(fingerprint(A[lo..hi]), fingerprint(B))`: repeated sharded
    /// traffic — AMG re-setup on an operator that only fits sharded —
    /// skips every per-shard symbolic phase on the second pass.
    pub fn multiply(&mut self, a: &Csr, b: &Csr) -> Result<SpgemmOutput> {
        // shard_count, not route(): the context has no block engine, so
        // the router's tile-fill sampling would be wasted on every call
        if let Some(n_devices) = self.router.as_ref().and_then(|r| r.shard_count(a, b)) {
            self.sharded_multiplies += 1;
            let n = n_devices.max(1);
            while self.shard_pools.len() < n {
                self.shard_pools.push(DevicePool::new());
            }
            let nprod = nprod_per_row(a, b);
            let b_fp = b.pattern_fingerprint();
            // without re-planning the plan is a pure function of
            // (A, B, n); with it, a warm pattern re-cuts from the last
            // run's measured device times and broadcasts at the tuned
            // chunk granularity — either way the stitched result is
            // bit-identical, so the loop only moves time
            let mut overlap = OverlapConfig::default();
            let (plan, hist_key) = if self.replan.enabled {
                let key = (a.pattern_fingerprint(), b_fp);
                let (measured, chunk_bytes) = match self.history.lookup(key) {
                    Some(s) => (
                        Some(s.measured.clone()).filter(|m| !m.is_empty()),
                        s.chunk_bytes,
                    ),
                    None => (None, None),
                };
                if let Some(cb) = chunk_bytes {
                    overlap.chunk_bytes = cb;
                }
                let plan = match &measured {
                    Some(m) => {
                        self.replans += 1;
                        ShardPlan::from_history(&nprod, n, m)
                    }
                    None => {
                        self.replan_cold += 1;
                        ShardPlan::balanced(&nprod, n)
                    }
                };
                (plan, Some(key))
            } else {
                (ShardPlan::balanced(&nprod, n), None)
            };
            let keys: Vec<(u64, u64)> = (0..n)
                .map(|s| {
                    let (lo, hi) = plan.range(s);
                    (a.pattern_fingerprint_rows(lo, hi), b_fp)
                })
                .collect();
            let reuse = ShardReuse {
                entries: keys.iter().map(|&k| self.cache.lookup(k)).collect(),
            };
            let out = multiply_sharded_with(
                a,
                b,
                &self.cfg,
                &plan,
                Some(&mut self.shard_pools[..n]),
                overlap,
                Some(&reuse),
            )?;
            for (s, key) in keys.into_iter().enumerate() {
                if reuse.entries[s].is_none() {
                    self.cache
                        .insert(key, Arc::new(SymbolicReuse::from_output(&out.shards[s])));
                }
            }
            if let Some(key) = hist_key {
                self.observe_sharded(key, &plan, &out, overlap);
            }
            return Ok(out.into_output());
        }
        let key = (a.pattern_fingerprint(), b.pattern_fingerprint());
        let reuse = self.cache.lookup(key);
        let out = multiply_reuse(a, b, &self.cfg, Some(&mut self.pool), reuse.as_deref())?;
        if reuse.is_none() {
            self.cache.insert(key, Arc::new(SymbolicReuse::from_output(&out)));
        }
        Ok(out)
    }

    /// Record one sharded run into the execution history: per-device
    /// simulated times (the measurement `ShardPlan::from_history`
    /// re-cuts from) and, when the router models an interconnect, the
    /// overlapped schedule's chunk-arrival stalls (the measurement
    /// chunk-size tuning reads). The simulator plays the role CUDA
    /// events would on hardware, which also keeps re-planning
    /// deterministic: the same operands always measure the same.
    ///
    /// Runs where any shard replayed its symbolic phase are **not**
    /// recorded: a replayed shard's trace has no symbolic ops, so its
    /// time is incomparable with a cold shard's and would skew the
    /// re-cut. The cost of that filter is staleness: a re-cut that
    /// leaves some shard ranges unchanged replays those shards from
    /// cache and is never re-measured, so the history keeps the last
    /// all-cold measurement (the one the current plan was cut from)
    /// and chunk tuning advances only on cold observations.
    /// Reuse-aware cost normalization to re-measure warm runs is a
    /// ROADMAP follow-on.
    fn observe_sharded(
        &mut self,
        key: PatternKey,
        plan: &ShardPlan,
        out: &ShardedOutput,
        overlap: OverlapConfig,
    ) {
        if out.shards.iter().any(|s| s.symbolic_skipped) {
            return;
        }
        let ic = self.router.as_ref().and_then(|r| r.cfg.interconnect);
        let n = plan.n_shards();
        let (md, chunk) = match ic {
            Some(ic) if overlap.enabled && n > 1 => {
                match MultiDevice::simulate_overlapped(
                    out.traces(),
                    &V100,
                    &ic,
                    out.b_bytes,
                    &out.c_block_bytes(),
                ) {
                    Ok(md) => {
                        let chunks = md.overlap.as_ref().map(|o| o.chunks).unwrap_or(1);
                        let fb = ChunkFeedback {
                            chunk_bytes: overlap.chunk_bytes,
                            chunks,
                            b_bytes: out.b_bytes,
                            stall_ns: md
                                .overlap_stall_ns()
                                .into_iter()
                                .fold(0.0, f64::max),
                            compute_ns: md.compute_makespan_ns(),
                            hop_latency_ns: ic.hop_latency_ns(),
                            chunk_xfer_ns: ic.chunk_xfer_ns(out.b_bytes, chunks),
                        };
                        (md, Some(fb))
                    }
                    // an unusable interconnect model must not fail the
                    // multiply — fall back to the transfer-free view
                    Err(_) => (MultiDevice::simulate(out.traces(), &V100), None),
                }
            }
            _ => (MultiDevice::simulate(out.traces(), &V100), None),
        };
        let mut obs = RunObservation::from_device_ns(
            plan,
            &md.device_total_ns(),
            md.makespan_ns(),
            out.nprod as u64,
        );
        obs.chunk = chunk;
        self.history.record(key, obs);
    }

    /// Symbolic phases skipped so far. Unlike the coordinator's metrics
    /// (which split whole-job and shard-level counters), a context has
    /// one cache and one counter pair: a sharded multiply over `n`
    /// devices contributes `n` lookups here, one per shard.
    pub fn sym_cache_hits(&self) -> u64 {
        self.cache.hits()
    }

    /// Symbolic phases computed (and cached) so far (same granularity
    /// note as [`SpgemmContext::sym_cache_hits`]).
    pub fn sym_cache_misses(&self) -> u64 {
        self.cache.misses()
    }

    /// Multiplies that took the row-sharded multi-device path.
    pub fn sharded_multiplies(&self) -> u64 {
        self.sharded_multiplies
    }

    /// Sharded multiplies planned from measured history (warm-pattern
    /// consults — the re-cut applies only when it improves the modeled
    /// makespan; only with [`SpgemmContext::with_router_replan`]).
    pub fn replans(&self) -> u64 {
        self.replans
    }

    /// Sharded multiplies planned by the `nprod` proxy because the
    /// pattern had no history yet.
    pub fn replan_cold_misses(&self) -> u64 {
        self.replan_cold
    }

    /// Patterns currently held by the execution history.
    pub fn history_patterns(&self) -> usize {
        self.history.len()
    }

    /// Cumulative device-pool counters (the single-device pool).
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }

    /// Cumulative counters of the per-device shard pools.
    pub fn shard_pool_stats(&self) -> Vec<PoolStats> {
        self.shard_pools.iter().map(|p| p.stats()).collect()
    }
}

impl Default for SpgemmContext {
    fn default() -> Self {
        SpgemmContext::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::uniform::Uniform;
    use crate::spgemm::reference::spgemm_reference;
    use crate::util::rng::Rng;

    #[test]
    fn context_power_iteration_reuses_everything() {
        let mut rng = Rng::new(41);
        let a = Uniform { n: 150, per_row: 7, jitter: 3 }.generate(&mut rng);
        let mut ctx = SpgemmContext::new();
        let gold = spgemm_reference(&a, &a);
        for i in 0..3 {
            let out = ctx.multiply(&a, &a).unwrap();
            assert!(out.c.approx_eq(&gold, 1e-12), "iteration {i}");
            assert_eq!(out.symbolic_skipped, i > 0);
        }
        assert_eq!(ctx.sym_cache_misses(), 1);
        assert_eq!(ctx.sym_cache_hits(), 2);
        assert!(ctx.pool_stats().pool_hits > 0);
    }

    #[test]
    fn sharded_context_is_bit_identical_and_recycles_shard_pools() {
        use crate::coordinator::router::RouterConfig;
        let mut rng = Rng::new(42);
        let a = Uniform { n: 260, per_row: 8, jitter: 4 }.generate(&mut rng);
        let mut plain = SpgemmContext::new();
        let gold = plain.multiply(&a, &a).unwrap();
        // memory-only routing: the point here is the sharded machinery,
        // not the cost model (which would decline so small a multiply)
        let router = Router::new(RouterConfig {
            device_memory_bytes: 4096,
            max_devices: 4,
            interconnect: None,
            ..Default::default()
        });
        let mut ctx = SpgemmContext::with_router(router);
        let out = ctx.multiply(&a, &a).unwrap();
        assert_eq!(out.c, gold.c, "sharded context must not change the numerics");
        assert_eq!(ctx.sharded_multiplies(), 1);
        // the second identical multiply recycles every per-device pool
        // AND replays every shard's symbolic phase via the shard-aware
        // cache keys (the AMG re-setup property)
        let hits_before = ctx.sym_cache_hits();
        let out2 = ctx.multiply(&a, &a).unwrap();
        assert_eq!(out2.c, gold.c);
        assert_eq!(out2.trace.malloc_calls(), 0, "warm shard pools must be malloc-free");
        assert!(ctx.shard_pool_stats().iter().any(|s| s.pool_hits > 0));
        assert!(out2.symbolic_skipped, "every shard must replay its symbolic phase");
        assert!(
            ctx.sym_cache_hits() >= hits_before + 2,
            "per-shard entries must hit on the repeat"
        );
        // a plain router context never consults or fills the history
        assert_eq!(ctx.replans(), 0);
        assert_eq!(ctx.replan_cold_misses(), 0);
        assert_eq!(ctx.history_patterns(), 0);
    }

    #[test]
    fn replanning_context_recuts_warm_patterns_bit_identically() {
        use crate::coordinator::router::RouterConfig;
        use crate::gen::powerlaw::PowerLaw;
        // the AMG re-setup shape: the same (imbalanced, power-law)
        // operator multiplied repeatedly. The first pass is proxy-cut
        // and records measured device times; every repeat re-cuts from
        // them — and the result never moves a bit.
        let mut rng = Rng::new(43);
        let a = PowerLaw {
            n: 600,
            alpha: 2.2,
            max_row: 64,
            mean_row: 6.0,
            hub_frac: 0.15,
            forced_giant_rows: 2,
        }
        .generate(&mut rng);
        let mut plain = SpgemmContext::new();
        let gold = plain.multiply(&a, &a).unwrap();
        let router = Router::new(RouterConfig {
            device_memory_bytes: 4096,
            max_devices: 4,
            interconnect: None,
            ..Default::default()
        });
        let mut ctx = SpgemmContext::with_router_replan(router, ReplanConfig::default());
        for i in 0..3 {
            let out = ctx.multiply(&a, &a).unwrap();
            assert_eq!(out.c, gold.c, "pass {i}: re-planning must not change the numerics");
        }
        assert_eq!(ctx.sharded_multiplies(), 3);
        assert_eq!(ctx.replan_cold_misses(), 1, "only the first pass is cold");
        assert_eq!(ctx.replans(), 2, "every repeat re-plans from history");
        assert_eq!(ctx.history_patterns(), 1);
        // replan: off on the same workload is the PR 4 baseline: no
        // history, no re-cut
        let router = Router::new(RouterConfig {
            device_memory_bytes: 4096,
            max_devices: 4,
            interconnect: None,
            ..Default::default()
        });
        let mut off = SpgemmContext::with_router_replan(router, ReplanConfig::off());
        let o = off.multiply(&a, &a).unwrap();
        assert_eq!(o.c, gold.c);
        assert_eq!(off.replans() + off.replan_cold_misses(), 0);
        assert_eq!(off.history_patterns(), 0);
    }
}
