//! The observability contract suite:
//!
//! * a traced contract run (sharded + speculative + chaos-gentle +
//!   batched) produces a **well-formed** span tree that survives kill /
//!   requeue / speculation, and its Chrome export carries the span data;
//! * tracing **off** is free: no tracer exists and the served results,
//!   routes, and deterministic counters are bit-identical to a traced
//!   run of the same stream;
//! * [`check_well_formed`] is a real property — random well-formed
//!   forests pass, and every corruption class is caught
//!   (`util::prop::check`, shrinking);
//! * chaos-injected faults are **replayable from the trace alone**: the
//!   `chaos_*` instants (tagged seed / worker / generation) match the
//!   schedule an independent [`WorkerChaos`] replica predicts, across
//!   kills and worker generation bumps;
//! * slow-request exemplars are bounded by `slow_k` and kept worst-first.

use opsparse::coordinator::barrier::SpeculateConfig;
use opsparse::coordinator::chaos::{ChaosConfig, WorkerChaos};
use opsparse::coordinator::router::EngineMode;
use opsparse::coordinator::serve::{Serve, ServeConfig, ServeResult};
use opsparse::gen::uniform::Uniform;
use opsparse::obs::{check_well_formed, Span, LANE_FRONT};
use opsparse::sparse::Csr;
use opsparse::util::prop::check;
use opsparse::util::rng::Rng;

/// Mirrors `service::MAX_REQUEUES` (private there): a kill chain longer
/// than this abandons the attempt instead of requeueing again.
const MAX_REQUEUES: u32 = 5;

fn uniform(n: usize, per_row: usize, seed: u64) -> Csr {
    Uniform { n, per_row, jitter: 2 }.generate(&mut Rng::new(seed))
}

fn arg<'a>(s: &'a Span, key: &str) -> Option<&'a str> {
    s.args.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
}

/// The traced contract run of `bench trace`, in miniature: every span
/// source at once, and the tree must still be well-formed.
#[test]
fn traced_contract_run_is_well_formed() {
    let mut cfg = ServeConfig::default();
    cfg.workers = 3;
    cfg.ns_per_prod = Some(1.0);
    cfg.coalesce = false;
    cfg.batch.enabled = true;
    cfg.batch.max_jobs = 4;
    cfg.speculate = SpeculateConfig::on();
    cfg.chaos = ChaosConfig::gentle().with_seed(0x0B5E);
    // 4 KiB device budget: the big pattern must take the sharded route
    cfg.device_memory_bytes = 4096;
    cfg.max_devices = 4;
    cfg.interconnect = None;
    cfg.trace.enabled = true;
    cfg.trace.slow_k = 3;
    let serve = Serve::start(cfg).expect("serve start");
    let tracer = serve.tracer().cloned().expect("tracing on constructs a tracer");
    let big = uniform(300, 6, 41);
    let small = uniform(120, 5, 42);
    let tickets: Vec<_> = (0..12)
        .map(|i| {
            let m = if i % 2 == 0 { &big } else { &small };
            serve.submit(if i % 2 == 0 { "shard" } else { "hash" }, m.clone(), m.clone())
        })
        .collect();
    for t in tickets {
        assert!(
            matches!(t.wait(), ServeResult::Done { .. }),
            "gentle chaos must not fail a request"
        );
    }
    serve.shutdown();

    let spans = tracer.snapshot_spans();
    check_well_formed(&spans).expect("contract-run span tree is well-formed");
    assert_eq!(tracer.dropped(), 0, "a 12-job run must not evict spans");
    for name in ["request", "admit", "queue_wait", "route_decision", "shard", "stitch"] {
        assert!(
            spans.iter().any(|s| s.name == name),
            "span {name:?} missing from the contract run"
        );
    }
    assert!(
        spans.iter().any(|s| s.name.starts_with("phase:")),
        "no simulated device phase was projected as a child span"
    );
    // every request root is on the front lane and closed error-free
    let roots: Vec<_> = spans.iter().filter(|s| s.name == "request").collect();
    assert_eq!(roots.len(), 12, "one root per admitted request");
    for r in &roots {
        assert_eq!(r.lane, LANE_FRONT);
        assert!(!r.error, "trace {} closed with an error", r.trace);
        assert!(arg(r, "route").is_some(), "request roots carry the chosen route");
    }
    // exemplar store: bounded by slow_k, ordered worst-first, and each
    // exemplar keeps its request root
    let slow = tracer.slow_exemplars();
    assert!(!slow.is_empty() && slow.len() <= 3, "slow_k=3 bounds the exemplars");
    assert!(
        slow.windows(2).all(|w| w[0].wall_ns >= w[1].wall_ns),
        "exemplars are kept worst-first"
    );
    for ex in &slow {
        assert!(
            ex.spans.iter().any(|s| s.name == "request" && s.trace == ex.trace),
            "exemplar {} lost its request root",
            ex.trace
        );
    }
    // the Chrome export carries the span set: metadata naming, one
    // complete event per non-instant span, instants as phase "i"
    let json = tracer.export_chrome();
    assert!(json.contains("\"traceEvents\""));
    assert!(json.contains("opsparse-serve"));
    assert!(json.contains("\"queue_wait\""));
    let completes = json.matches("\"ph\":\"X\"").count();
    assert_eq!(completes, spans.iter().filter(|s| !s.instant).count());
    let instants = json.matches("\"ph\":\"i\"").count();
    assert_eq!(instants, spans.iter().filter(|s| s.instant).count());
}

/// Tracing off is the PR 9 baseline: no tracer is even constructed, and
/// the same stream produces bit-identical results, routes, and
/// deterministic counters either way.
#[test]
fn trace_off_is_free_and_bit_identical() {
    let run = |trace_on: bool| {
        let mut cfg = ServeConfig::default();
        cfg.workers = 1;
        cfg.ns_per_prod = Some(1.0);
        cfg.coalesce = false;
        cfg.trace.enabled = trace_on;
        let serve = Serve::start(cfg).expect("serve start");
        if trace_on {
            assert!(serve.tracer().is_some(), "--trace on must construct a tracer");
        } else {
            assert!(serve.tracer().is_none(), "--trace off must not construct a tracer");
        }
        let mut out = Vec::new();
        for i in 0..6u64 {
            let m = uniform(100 + 10 * (i as usize % 3), 5, 100 + i);
            let t = serve.submit("parity", m.clone(), m.clone());
            match t.wait() {
                ServeResult::Done { c, route, .. } => out.push(((*c).clone(), route)),
                other => panic!("parity job failed: {other:?}"),
            }
        }
        let snap = serve.metrics_snapshot();
        serve.shutdown();
        (out, snap)
    };
    let (on_out, on_snap) = run(true);
    let (off_out, off_snap) = run(false);
    assert_eq!(on_out.len(), off_out.len());
    for (i, ((c_on, r_on), (c_off, r_off))) in on_out.iter().zip(&off_out).enumerate() {
        assert_eq!(r_on, r_off, "job {i} routed differently under tracing");
        assert_eq!(c_on, c_off, "job {i} result differs under tracing");
    }
    // the deterministic counters (wall-clock percentiles excluded) agree
    for (name, a, b) in [
        ("jobs_submitted", on_snap.jobs_submitted, off_snap.jobs_submitted),
        ("jobs_completed", on_snap.jobs_completed, off_snap.jobs_completed),
        ("jobs_failed", on_snap.jobs_failed, off_snap.jobs_failed),
        ("hash_routed", on_snap.hash_routed, off_snap.hash_routed),
        ("block_routed", on_snap.block_routed, off_snap.block_routed),
        ("sharded_routed", on_snap.sharded_routed, off_snap.sharded_routed),
        ("nprod_total", on_snap.nprod_total, off_snap.nprod_total),
        ("sym_cache_hits", on_snap.sym_cache_hits, off_snap.sym_cache_hits),
        ("sym_cache_misses", on_snap.sym_cache_misses, off_snap.sym_cache_misses),
        ("coalesce_hits", on_snap.coalesce_hits, off_snap.coalesce_hits),
        ("rejected_jobs", on_snap.rejected_jobs, off_snap.rejected_jobs),
    ] {
        assert_eq!(a, b, "counter {name} drifts when tracing is toggled");
    }
}

/// Every coalesce attach leaves exactly one `coalesce_attach` instant
/// in the leader's trace — the counter and the trace never disagree.
#[test]
fn coalesce_attaches_are_traced_one_to_one() {
    let mut cfg = ServeConfig::default();
    cfg.workers = 1;
    cfg.ns_per_prod = Some(1.0);
    cfg.trace.enabled = true;
    let serve = Serve::start(cfg).expect("serve start");
    let tracer = serve.tracer().cloned().expect("tracer");
    let m = uniform(400, 6, 7);
    let tickets: Vec<_> = (0..8).map(|_| serve.submit("co", m.clone(), m.clone())).collect();
    for t in tickets {
        assert!(matches!(t.wait(), ServeResult::Done { .. }));
    }
    let hits = serve.metrics_snapshot().coalesce_hits;
    serve.shutdown();
    let spans = tracer.snapshot_spans();
    check_well_formed(&spans).expect("coalesced run is well-formed");
    let attaches = spans.iter().filter(|s| s.name == "coalesce_attach").count() as u64;
    assert_eq!(attaches, hits, "coalesce_hits and coalesce_attach instants disagree");
}

/// Batched members get `batch_residency` spans (held-in-batcher time)
/// and still run their per-member `exec` span in the worker visit.
#[test]
fn batched_jobs_carry_residency_and_exec_spans() {
    let mut cfg = ServeConfig::default();
    cfg.workers = 1;
    cfg.ns_per_prod = Some(1.0);
    cfg.coalesce = false;
    cfg.batch.enabled = true;
    cfg.batch.max_jobs = 2;
    cfg.engine = EngineMode::Hash;
    cfg.trace.enabled = true;
    let serve = Serve::start(cfg).expect("serve start");
    let tracer = serve.tracer().cloned().expect("tracer");
    let tickets: Vec<_> =
        (0..4u64).map(|i| {
            let m = uniform(60, 4, 500 + i);
            serve.submit("batch", m.clone(), m.clone())
        }).collect();
    for t in tickets {
        assert!(matches!(t.wait(), ServeResult::Done { .. }));
    }
    let batched = serve.metrics_snapshot().batched_jobs;
    serve.shutdown();
    let spans = tracer.snapshot_spans();
    check_well_formed(&spans).expect("batched run is well-formed");
    let residency = spans.iter().filter(|s| s.name == "batch_residency").count() as u64;
    assert_eq!(residency, batched, "every batched member gets a residency span");
    assert!(batched > 0, "max_jobs=2 over 4 small hash jobs must batch someone");
    let execs = spans.iter().filter(|s| s.name == "exec").count();
    assert_eq!(execs, 4, "each member still runs its own exec span");
}

/// Build a random well-formed span forest: a few roots, children drawn
/// inside a live ancestor's interval, some as instants.
fn gen_forest(rng: &mut Rng, size: usize) -> Vec<Span> {
    let mk = |trace: u64, id: u64, parent: u64, t0: u64, t1: u64, instant: bool| Span {
        trace,
        id,
        parent,
        name: format!("s{id}"),
        lane: rng_lane(id),
        t0_ns: t0,
        t1_ns: t1,
        args: vec![],
        error: false,
        instant,
    };
    fn rng_lane(id: u64) -> u64 {
        id % 3
    }
    let mut spans = Vec::new();
    let mut next_id = 1u64;
    let roots = 1 + size / 6;
    for trace in 1..=roots as u64 {
        let t0 = rng.below(1_000);
        let t1 = t0 + 100 + rng.below(10_000);
        let root = next_id;
        next_id += 1;
        spans.push(mk(trace, root, 0, t0, t1, false));
        let mut open = vec![(root, t0, t1)];
        for _ in 0..rng.below(size.max(1) as u64) {
            let (pid, p0, p1) = open[rng.below(open.len() as u64) as usize];
            if p1 <= p0 {
                continue;
            }
            let c0 = p0 + rng.below(p1 - p0);
            let c1 = c0 + rng.below(p1 - c0 + 1);
            let id = next_id;
            next_id += 1;
            if rng.below(4) == 0 {
                spans.push(mk(trace, id, pid, c0, c0, true));
            } else {
                spans.push(mk(trace, id, pid, c0, c1, false));
                open.push((id, c0, c1));
            }
        }
    }
    spans
}

/// `check_well_formed` accepts every random well-formed forest and
/// rejects each corruption class applied to it.
#[test]
fn well_formedness_property_holds_and_corruptions_are_caught() {
    check(
        "obs::well_formed_forest",
        60,
        24,
        |rng, size| (gen_forest(rng, size), rng.below(5)),
        |(forest, corruption)| {
            if let Err(e) = check_well_formed(forest) {
                return Err(format!("clean forest rejected: {e}"));
            }
            let mut bad = forest.clone();
            let applied = match corruption {
                // duplicate span id
                0 if bad.len() >= 2 => {
                    bad[1].id = bad[0].id;
                    true
                }
                // the reserved id 0
                1 => {
                    bad[0].id = 0;
                    true
                }
                // negative duration
                2 => {
                    let s = &mut bad[0];
                    s.instant = false;
                    s.t0_ns = s.t1_ns + 1;
                    true
                }
                // orphaned parent pointer
                3 => match bad.iter_mut().find(|s| s.parent != 0) {
                    Some(s) => {
                        s.parent = u64::MAX;
                        true
                    }
                    None => false,
                },
                // child escapes its parent's interval
                _ => {
                    let bounds: Option<(usize, u64)> = bad
                        .iter()
                        .enumerate()
                        .find(|(_, s)| s.parent != 0 && !s.instant)
                        .map(|(i, s)| (i, s.parent));
                    match bounds {
                        Some((i, pid)) => {
                            let p_t1 =
                                bad.iter().find(|s| s.id == pid).map(|p| p.t1_ns).unwrap_or(0);
                            bad[i].t1_ns = p_t1 + 1;
                            true
                        }
                        None => false,
                    }
                }
            };
            if applied && check_well_formed(&bad).is_ok() {
                return Err(format!("corruption class {corruption} not caught"));
            }
            Ok(())
        },
    );
}

/// Predict the exact ordered `chaos_*` instant schedule for a serial
/// single-worker stream: one boundary per delivered message, a kill
/// requeues the message onto the generation-bumped replacement (until
/// the retry budget abandons it), and each generation's stream is an
/// independent [`WorkerChaos`].
fn predicted_chaos_instants(cfg: &ChaosConfig, deliveries: usize) -> Vec<(String, u64, u64)> {
    let mut out = Vec::new();
    let mut generation = 0u64;
    let mut stream = WorkerChaos::new(cfg, 0, generation);
    for _ in 0..deliveries {
        let mut attempts = 0u32;
        loop {
            let f = stream.at_boundary();
            if f.delay_ns > 0 {
                out.push(("chaos_delay".to_string(), generation, f.delay_ns));
            }
            if f.shrink_pool {
                out.push(("chaos_pool_shrink".to_string(), generation, 0));
            }
            if !f.kill {
                break;
            }
            out.push(("chaos_kill".to_string(), generation, 0));
            // the replacement always spawns with generation + 1; the
            // message is redelivered unless its retry budget is spent
            generation += 1;
            stream = WorkerChaos::new(cfg, 0, generation);
            if attempts >= MAX_REQUEUES {
                break;
            }
            attempts += 1;
        }
    }
    out
}

fn chaos_replay_run(chaos: ChaosConfig, jobs: usize) -> Vec<(String, u64, u64)> {
    let mut cfg = ServeConfig::default();
    cfg.workers = 1;
    cfg.inflight_cap = 1;
    cfg.coalesce = false;
    cfg.batch.enabled = false;
    cfg.engine = EngineMode::Hash;
    cfg.ns_per_prod = Some(1.0);
    cfg.chaos = chaos;
    cfg.trace.enabled = true;
    let serve = Serve::start(cfg).expect("serve start");
    let tracer = serve.tracer().cloned().expect("tracer");
    let m = uniform(60, 4, 9);
    for _ in 0..jobs {
        // serial submit-and-wait: exactly one message in flight, so the
        // delivery order (and thus the boundary order) is deterministic
        let _ = serve.submit("replay", m.clone(), m.clone()).wait();
    }
    serve.shutdown();
    let spans = tracer.snapshot_spans();
    check_well_formed(&spans).expect("chaos run is well-formed");
    spans
        .iter()
        .filter(|s| s.name.starts_with("chaos_"))
        .map(|s| {
            assert!(s.instant, "chaos injections are instants");
            assert_eq!(arg(s, "seed"), Some(chaos.seed.to_string().as_str()));
            assert_eq!(arg(s, "worker"), Some("0"), "single-worker run");
            let generation: u64 =
                arg(s, "generation").expect("generation tag").parse().expect("numeric generation");
            let delay: u64 = arg(s, "delay_ns").map(|v| v.parse().expect("numeric delay")).unwrap_or(0);
            (s.name.clone(), generation, delay)
        })
        .collect()
}

/// The chaos-observability satellite: a trace alone is enough to replay
/// the injection schedule. The emitted `chaos_*` instants — names,
/// order, generation tags, delay magnitudes — must equal what an
/// independent replica of the seeded fault stream predicts.
#[test]
fn chaos_instants_replay_the_seeded_schedule() {
    // the gentle preset (the CI chaos tier), fixed seed
    let gentle = ChaosConfig::gentle().with_seed(0xC0DE);
    let actual = chaos_replay_run(gentle, 16);
    assert!(!actual.is_empty(), "gentle chaos over 16 boundaries injects something");
    assert_eq!(actual, predicted_chaos_instants(&gentle, 16), "gentle schedule replays");

    // a hotter mix so the kill → generation-bump → redelivery chain is
    // exercised with near-certainty (P[no kill] ≈ 0.7^24)
    let hot = ChaosConfig {
        kill_prob: 0.3,
        delay_ns_range: (0, 50_000),
        mem_pressure: 0.3,
        seed: 0xFEED,
    };
    let actual = chaos_replay_run(hot, 24);
    let expected = predicted_chaos_instants(&hot, 24);
    assert_eq!(actual, expected, "hot schedule replays across kills");
    assert!(
        expected.iter().any(|(n, _, _)| n == "chaos_kill"),
        "hot run drew no kill — raise kill_prob or jobs"
    );
    assert!(
        expected.iter().any(|(_, g, _)| *g > 0),
        "no generation bump observed after a kill"
    );
}
