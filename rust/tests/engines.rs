//! Multi-engine dispatch integration tests, table-driven over the
//! checked-in corpus: every `rust/corpus/*.mtx` fixture must produce
//! **bitwise identical** results on the hash pipeline, the native block
//! engine, the sharded hash path, the block-sharded coordinator path,
//! and a measured-dispatch (`EngineMode::Auto`) coordinator — whatever
//! engine dispatch picks. Plus the dispatch hysteresis property: the
//! engine [`choose_engine`] returns is never worse than the alternative
//! by more than the [`DISPATCH_SWITCH_GAIN`] band.

use opsparse::bench::corpus::{load_corpus, resolve_corpus_dir};
use opsparse::coordinator::feedback::{Engine, EngineStats, PatternStats};
use opsparse::coordinator::{
    choose_engine, Coordinator, EngineMode, Job, Route, Router, RouterConfig,
    DISPATCH_SWITCH_GAIN,
};
use opsparse::runtime::BlockEngine;
use opsparse::spgemm::multiply_sharded;
use opsparse::spgemm::pipeline::{multiply, OpSparseConfig};
use opsparse::util::rng::Rng;

#[test]
fn every_fixture_is_bit_identical_across_engines_unsharded() {
    let dir = resolve_corpus_dir(None);
    let entries = load_corpus(&dir).expect("load corpus");
    let cfg = OpSparseConfig::default();
    for e in &entries {
        let gold = multiply(&e.a, &e.a, &cfg).expect("hash pipeline").c;
        let mut eng = BlockEngine::native(16, 16).expect("native engine");
        let block = eng.spgemm_csr(&e.a, &e.a).expect("block engine");
        assert_eq!(block, gold, "{}: block engine must match hash bitwise", e.name);
    }
}

#[test]
fn every_fixture_is_bit_identical_across_engines_sharded() {
    let dir = resolve_corpus_dir(None);
    let entries = load_corpus(&dir).expect("load corpus");
    let cfg = OpSparseConfig::default();

    // one coordinator serves all fixtures: the block-sharded path runs
    // per-shard native engines on the hash pool, no factory needed
    let coord = Coordinator::start(2, Router::default(), None);
    for (i, e) in entries.iter().enumerate() {
        let gold = multiply(&e.a, &e.a, &cfg).expect("hash pipeline").c;

        // sharded hash stitches to the unsharded hash result
        let sharded = multiply_sharded(&e.a, &e.a, &cfg, 3)
            .unwrap_or_else(|err| panic!("{}: sharded hash: {err}", e.name));
        assert_eq!(sharded.c, gold, "{}: sharded hash must stitch bitwise", e.name);

        // block-sharded coordinator path stitches to the same bits
        coord.submit(Job {
            id: i as u64,
            a: e.a.clone(),
            b: e.a.clone(),
            force_route: Some(Route::ShardedBlock { n_devices: 3 }),
        });
        let r = coord.recv().expect("coordinator result");
        assert_eq!(r.route, Route::ShardedBlock { n_devices: 3 });
        let c = r.c.unwrap_or_else(|err| panic!("{}: sharded block: {err}", e.name));
        assert_eq!(c, gold, "{}: sharded block must stitch bitwise", e.name);
    }
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.sharded_block_routed as usize, entries.len());
    assert_eq!(snap.block_fallbacks, 0, "shards self-build native engines");
    coord.shutdown();
}

#[test]
fn dispatched_results_match_hash_reference_on_every_fixture() {
    let dir = resolve_corpus_dir(None);
    let entries = load_corpus(&dir).expect("load corpus");
    let cfg = OpSparseConfig::default();

    // a measured-dispatch coordinator with a real block engine: whatever
    // engine Auto converges on per fixture, the bits must not move
    let router = Router::new(RouterConfig {
        engine_mode: EngineMode::Auto,
        ..Default::default()
    });
    let coord =
        Coordinator::start(2, router, Some(Box::new(|| BlockEngine::native(16, 16))));
    for round in 0..2u64 {
        // two rounds: round 0 routes on the cold estimate, round 1 on
        // the engine-tagged measurements round 0 recorded
        for (i, e) in entries.iter().enumerate() {
            coord.submit(Job {
                id: round * 1000 + i as u64,
                a: e.a.clone(),
                b: e.a.clone(),
                force_route: None,
            });
        }
        for _ in 0..entries.len() {
            let r = coord.recv().expect("coordinator result");
            let name = &entries[(r.id % 1000) as usize].name;
            let e = &entries[(r.id % 1000) as usize];
            let gold = multiply(&e.a, &e.a, &cfg).expect("hash pipeline").c;
            let c = r.c.unwrap_or_else(|err| panic!("{name}: dispatched: {err}"));
            assert_eq!(c, gold, "{name}: dispatched result must match hash bitwise");
        }
    }
    // the dispatcher actually measured: the history is warm for every
    // distinct pattern it saw
    let h = coord.history().lock().unwrap();
    assert!(!h.is_empty(), "auto dispatch must have recorded engine-tagged runs");
    assert!(
        h.iter_in_order().any(|(_, s)| s.hash.warm() || s.block.warm()),
        "at least one pattern must hold a warm engine measurement"
    );
    drop(h);
    coord.shutdown();
}

#[test]
fn choose_engine_never_picks_outside_the_hysteresis_band() {
    // property sweep: over randomized per-engine stats, the chosen
    // engine's EWMA is never worse than the alternative's by more than
    // the DISPATCH_SWITCH_GAIN band (and one-sided stats always pick
    // the only measured engine)
    let mut rng = Rng::new(0x11f57);
    for case in 0..2000 {
        let gen_stats = |rng: &mut Rng| EngineStats {
            runs: rng.range(0, 10) as u64,
            ewma_ns: if rng.f64() < 0.2 {
                0.0
            } else {
                1_000.0 + rng.f64() * 1_000_000.0
            },
        };
        let stats = PatternStats {
            hash: gen_stats(&mut rng),
            block: gen_stats(&mut rng),
            ..Default::default()
        };
        let pick = choose_engine(&stats);
        let (own, alt) = match pick {
            Engine::Hash => (stats.hash.ewma_ns, stats.block.ewma_ns),
            Engine::Block => (stats.block.ewma_ns, stats.hash.ewma_ns),
        };
        let usable = |ns: f64| ns > 0.0 && ns.is_finite();
        match (usable(own), usable(alt)) {
            (true, true) => assert!(
                own <= alt / DISPATCH_SWITCH_GAIN,
                "case {case}: picked {pick:?} at {own} ns vs {alt} ns — outside the band \
                 (stats {stats:?})"
            ),
            (false, true) => panic!(
                "case {case}: picked unmeasured {pick:?} over a measured alternative \
                 (stats {stats:?})"
            ),
            // nothing measured (or only the pick measured): any pick is
            // within contract
            _ => {}
        }
    }
}

#[test]
fn choose_engine_is_deterministic_and_sticky_at_the_band_edge() {
    // exactly on the band edge the incumbent keeps the route: dispatch
    // cannot flap between two engines trading sub-band wins
    let base = PatternStats {
        hash: EngineStats { runs: 1, ewma_ns: 1_000.0 * DISPATCH_SWITCH_GAIN },
        block: EngineStats { runs: 5, ewma_ns: 1_000.0 },
        ..Default::default()
    };
    assert_eq!(choose_engine(&base), Engine::Block, "edge case stays with the incumbent");
    let just_inside = PatternStats {
        hash: EngineStats { runs: 1, ewma_ns: 1_000.0 * DISPATCH_SWITCH_GAIN - 0.01 },
        block: EngineStats { runs: 5, ewma_ns: 1_000.0 },
        ..Default::default()
    };
    assert_eq!(choose_engine(&just_inside), Engine::Hash, "beyond the band the challenger wins");
}
