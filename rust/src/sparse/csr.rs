//! Compressed Sparse Row storage (paper §2.1.1, Fig. 1).

use anyhow::{bail, ensure, Result};

/// CSR sparse matrix in double precision.
///
/// Invariants (checked by [`Csr::validate`]):
/// * `rpt.len() == rows + 1`, `rpt[0] == 0`, `rpt` non-decreasing,
///   `rpt[rows] == col.len() == val.len()`
/// * within each row, column indices are strictly increasing and `< cols`.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    /// Row pointer array (`rpt` in the paper), length `rows + 1`.
    pub rpt: Vec<usize>,
    /// Column indices, length nnz.
    pub col: Vec<u32>,
    /// Nonzero values, length nnz.
    pub val: Vec<f64>,
}

impl Csr {
    /// An empty `rows x cols` matrix (no nonzeros).
    pub fn zero(rows: usize, cols: usize) -> Self {
        Csr { rows, cols, rpt: vec![0; rows + 1], col: Vec::new(), val: Vec::new() }
    }

    /// Identity matrix of order `n`.
    pub fn identity(n: usize) -> Self {
        Csr {
            rows: n,
            cols: n,
            rpt: (0..=n).collect(),
            col: (0..n as u32).collect(),
            val: vec![1.0; n],
        }
    }

    /// Build from raw parts, validating the invariants.
    pub fn from_parts(
        rows: usize,
        cols: usize,
        rpt: Vec<usize>,
        col: Vec<u32>,
        val: Vec<f64>,
    ) -> Result<Self> {
        let m = Csr { rows, cols, rpt, col, val };
        m.validate()?;
        Ok(m)
    }

    /// Number of stored nonzeros.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.col.len()
    }

    /// Number of nonzeros in row `i`.
    #[inline]
    pub fn row_nnz(&self, i: usize) -> usize {
        self.rpt[i + 1] - self.rpt[i]
    }

    /// Column indices of row `i`.
    #[inline]
    pub fn row_cols(&self, i: usize) -> &[u32] {
        &self.col[self.rpt[i]..self.rpt[i + 1]]
    }

    /// Values of row `i`.
    #[inline]
    pub fn row_vals(&self, i: usize) -> &[f64] {
        &self.val[self.rpt[i]..self.rpt[i + 1]]
    }

    /// `(cols, vals)` of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let (s, e) = (self.rpt[i], self.rpt[i + 1]);
        (&self.col[s..e], &self.val[s..e])
    }

    /// Check every CSR invariant; returns a descriptive error on violation.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.rpt.len() == self.rows + 1, "rpt length {} != rows+1 {}", self.rpt.len(), self.rows + 1);
        ensure!(self.rpt[0] == 0, "rpt[0] = {} != 0", self.rpt[0]);
        ensure!(
            self.col.len() == self.val.len(),
            "col/val length mismatch: {} vs {}",
            self.col.len(),
            self.val.len()
        );
        ensure!(
            *self.rpt.last().unwrap() == self.col.len(),
            "rpt[rows] = {} != nnz = {}",
            self.rpt.last().unwrap(),
            self.col.len()
        );
        for i in 0..self.rows {
            let (s, e) = (self.rpt[i], self.rpt[i + 1]);
            if s > e {
                bail!("rpt decreasing at row {i}: {s} > {e}");
            }
            let cols = &self.col[s..e];
            for w in cols.windows(2) {
                if w[0] >= w[1] {
                    bail!("row {i}: columns not strictly increasing ({} >= {})", w[0], w[1]);
                }
            }
            if let Some(&last) = cols.last() {
                ensure!((last as usize) < self.cols, "row {i}: column {last} out of bounds (cols={})", self.cols);
            }
        }
        Ok(())
    }

    /// Value at `(i, j)` via binary search (0.0 if not stored).
    pub fn get(&self, i: usize, j: usize) -> f64 {
        let cols = self.row_cols(i);
        match cols.binary_search(&(j as u32)) {
            Ok(p) => self.row_vals(i)[self.rpt[i] + p - self.rpt[i]],
            Err(_) => 0.0,
        }
    }

    /// Order-sensitive FNV-1a fingerprint of the sparsity *pattern*:
    /// shape + `rpt` + `col`, values excluded. Two matrices with equal
    /// fingerprints share their symbolic phase, which is what the
    /// coordinator's symbolic-reuse cache keys on. A collision
    /// (~2^-64 per pair) makes the replayed `row_nnz` lie, which the
    /// numeric phase detects by panicking on the first mismatched row —
    /// never by silently corrupting C — and the coordinator worker
    /// converts that panic into a failed job.
    pub fn pattern_fingerprint(&self) -> u64 {
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        fn mix(h: &mut u64, x: u64) {
            for b in x.to_le_bytes() {
                *h ^= b as u64;
                *h = h.wrapping_mul(PRIME);
            }
        }
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        mix(&mut h, self.rows as u64);
        mix(&mut h, self.cols as u64);
        for &r in &self.rpt {
            mix(&mut h, r as u64);
        }
        for &c in &self.col {
            mix(&mut h, c as u64);
        }
        h
    }

    /// [`Csr::pattern_fingerprint`] of the row block `[lo, hi)` without
    /// materializing the slice: identical to
    /// `row_slice(self, lo, hi).pattern_fingerprint()` (the rebased
    /// `rpt`, the sliced `col`, and the slice's shape are hashed), so
    /// shard-aware cache keys can be computed from the whole operand —
    /// no allocation, `O(hi - lo + nnz of the block)`.
    pub fn pattern_fingerprint_rows(&self, lo: usize, hi: usize) -> u64 {
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        fn mix(h: &mut u64, x: u64) {
            for b in x.to_le_bytes() {
                *h ^= b as u64;
                *h = h.wrapping_mul(PRIME);
            }
        }
        let (lo, hi) = (lo.min(self.rows), hi.min(self.rows));
        let (lo, hi) = (lo, hi.max(lo));
        let base = self.rpt[lo];
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        mix(&mut h, (hi - lo) as u64);
        mix(&mut h, self.cols as u64);
        for &r in &self.rpt[lo..=hi] {
            mix(&mut h, (r - base) as u64);
        }
        for &c in &self.col[self.rpt[lo]..self.rpt[hi]] {
            mix(&mut h, c as u64);
        }
        h
    }

    /// Order-sensitive FNV-1a fingerprint of the numeric *values* (raw
    /// `f64` bits, pattern excluded). Combined with
    /// [`Csr::pattern_fingerprint`] this identifies a matrix up to hash
    /// collision: the serving layer's request-coalescing key uses both,
    /// because two requests may only share one *numeric* result when
    /// patterns **and** values match — the pattern fingerprint alone
    /// would let a coalesced waiter receive another matrix's product.
    pub fn value_fingerprint(&self) -> u64 {
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        fn mix(h: &mut u64, x: u64) {
            for b in x.to_le_bytes() {
                *h ^= b as u64;
                *h = h.wrapping_mul(PRIME);
            }
        }
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        mix(&mut h, self.val.len() as u64);
        for &v in &self.val {
            mix(&mut h, v.to_bits());
        }
        h
    }

    /// Maximum nnz over all rows ("Max nnz/row" column of Table 3).
    pub fn max_row_nnz(&self) -> usize {
        (0..self.rows).map(|i| self.row_nnz(i)).max().unwrap_or(0)
    }

    /// Device-memory footprint in bytes under the CSR layout the paper uses
    /// (4-byte column indices + 8-byte values + 4-byte row pointers, as in
    /// nsparse's Volta build).
    pub fn device_bytes(&self) -> usize {
        4 * (self.rows + 1) + 4 * self.nnz() + 8 * self.nnz()
    }

    /// Approximate equality: identical structure, values within
    /// `rel` relative tolerance.
    pub fn approx_eq(&self, other: &Csr, rel: f64) -> bool {
        if self.rows != other.rows
            || self.cols != other.cols
            || self.rpt != other.rpt
            || self.col != other.col
        {
            return false;
        }
        self.val.iter().zip(&other.val).all(|(a, b)| {
            let scale = a.abs().max(b.abs()).max(1e-300);
            (a - b).abs() <= rel * scale
        })
    }

    /// Describe the first difference to `other`, if any — used by tests and
    /// the `--verify` path of the bench harness for actionable failures.
    pub fn diff(&self, other: &Csr, rel: f64) -> Option<String> {
        if self.rows != other.rows || self.cols != other.cols {
            return Some(format!(
                "shape mismatch: {}x{} vs {}x{}",
                self.rows, self.cols, other.rows, other.cols
            ));
        }
        if self.rpt != other.rpt {
            for i in 0..self.rows {
                if self.row_nnz(i) != other.row_nnz(i) {
                    return Some(format!(
                        "row {i} nnz mismatch: {} vs {}",
                        self.row_nnz(i),
                        other.row_nnz(i)
                    ));
                }
            }
        }
        if self.col != other.col {
            for i in 0..self.rows {
                if self.row_cols(i) != other.row_cols(i) {
                    return Some(format!("row {i} column indices differ"));
                }
            }
        }
        for i in 0..self.rows {
            let (sc, sv) = self.row(i);
            let (_, ov) = other.row(i);
            for (k, (a, b)) in sv.iter().zip(ov).enumerate() {
                let scale = a.abs().max(b.abs()).max(1e-300);
                if (a - b).abs() > rel * scale {
                    return Some(format!(
                        "value mismatch at ({i},{}): {a} vs {b}",
                        sc[k]
                    ));
                }
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        // [[1 0 2], [0 0 0], [3 4 0]]
        Csr::from_parts(3, 3, vec![0, 2, 2, 4], vec![0, 2, 0, 1], vec![1.0, 2.0, 3.0, 4.0]).unwrap()
    }

    #[test]
    fn construction_and_accessors() {
        let m = sample();
        assert_eq!(m.nnz(), 4);
        assert_eq!(m.row_nnz(0), 2);
        assert_eq!(m.row_nnz(1), 0);
        assert_eq!(m.row_cols(2), &[0, 1]);
        assert_eq!(m.row_vals(2), &[3.0, 4.0]);
        assert_eq!(m.max_row_nnz(), 2);
    }

    #[test]
    fn zero_and_identity() {
        let z = Csr::zero(4, 5);
        z.validate().unwrap();
        assert_eq!(z.nnz(), 0);
        let i = Csr::identity(3);
        i.validate().unwrap();
        assert_eq!(i.nnz(), 3);
        assert_eq!(i.get(1, 1), 1.0);
        assert_eq!(i.get(0, 1), 0.0);
    }

    #[test]
    fn validate_rejects_bad_rpt() {
        let r = Csr::from_parts(2, 2, vec![0, 2], vec![0, 1], vec![1.0, 1.0]);
        assert!(r.is_err());
    }

    #[test]
    fn validate_rejects_unsorted_columns() {
        let r = Csr::from_parts(1, 3, vec![0, 2], vec![2, 0], vec![1.0, 1.0]);
        assert!(r.is_err());
    }

    #[test]
    fn validate_rejects_duplicate_columns() {
        let r = Csr::from_parts(1, 3, vec![0, 2], vec![1, 1], vec![1.0, 1.0]);
        assert!(r.is_err());
    }

    #[test]
    fn validate_rejects_out_of_bounds_column() {
        let r = Csr::from_parts(1, 2, vec![0, 1], vec![5], vec![1.0]);
        assert!(r.is_err());
    }

    #[test]
    fn pattern_fingerprint_ignores_values_not_structure() {
        let a = sample();
        let mut b = sample();
        b.val[0] = 99.0;
        assert_eq!(a.pattern_fingerprint(), b.pattern_fingerprint());
        // different column => different pattern
        let c =
            Csr::from_parts(3, 3, vec![0, 2, 2, 4], vec![0, 1, 0, 1], vec![1.0, 2.0, 3.0, 4.0])
                .unwrap();
        assert_ne!(a.pattern_fingerprint(), c.pattern_fingerprint());
        // same nnz layout but different shape
        let i2 = Csr::identity(2);
        let mut wide = Csr::identity(2);
        wide.cols = 3;
        assert_ne!(i2.pattern_fingerprint(), wide.pattern_fingerprint());
    }

    #[test]
    fn value_fingerprint_tracks_values_not_structure() {
        let a = sample();
        let mut b = sample();
        assert_eq!(a.value_fingerprint(), b.value_fingerprint());
        b.val[0] = 99.0;
        assert_ne!(a.value_fingerprint(), b.value_fingerprint(), "changed value must show");
        // same values in a different pattern hash equal here (the
        // pattern fingerprint covers that axis; the coalesce key uses
        // both)
        let c =
            Csr::from_parts(3, 3, vec![0, 2, 2, 4], vec![0, 1, 0, 1], vec![1.0, 2.0, 3.0, 4.0])
                .unwrap();
        assert_eq!(a.value_fingerprint(), c.value_fingerprint());
        // -0.0 and 0.0 differ bitwise, and the fingerprint is bitwise
        let mut neg = sample();
        neg.val[0] = -0.0;
        let mut pos = sample();
        pos.val[0] = 0.0;
        assert_ne!(neg.value_fingerprint(), pos.value_fingerprint());
    }

    #[test]
    fn range_fingerprint_matches_materialized_slice() {
        let a = sample();
        for (lo, hi) in [(0, 3), (0, 1), (1, 3), (2, 2), (0, 0)] {
            let sliced = crate::sparse::ops::row_slice(&a, lo, hi).unwrap();
            assert_eq!(
                a.pattern_fingerprint_rows(lo, hi),
                sliced.pattern_fingerprint(),
                "range [{lo},{hi})"
            );
        }
        // the whole-matrix range equals the plain fingerprint
        assert_eq!(a.pattern_fingerprint_rows(0, a.rows), a.pattern_fingerprint());
        // different ranges of the same matrix disagree (they are
        // different patterns)
        assert_ne!(a.pattern_fingerprint_rows(0, 1), a.pattern_fingerprint_rows(1, 2));
    }

    #[test]
    fn approx_eq_and_diff() {
        let a = sample();
        let mut b = sample();
        assert!(a.approx_eq(&b, 1e-12));
        assert!(a.diff(&b, 1e-12).is_none());
        b.val[1] += 1e-3;
        assert!(!a.approx_eq(&b, 1e-12));
        let d = a.diff(&b, 1e-12).unwrap();
        assert!(d.contains("value mismatch"), "{d}");
    }
}
