//! Job routing: decide per matrix pair whether to run the hash pipeline,
//! the PJRT block engine, or the row-sharded multi-device path.
//!
//! Three cheap, structure-only estimates drive the decision:
//!
//! 1. **Working set** ([`working_set_bytes`]): operands + a result upper
//!    bound. When it exceeds a single device's memory budget the job is
//!    a sharding candidate, with enough devices to fit
//!    (see [`crate::spgemm::sharded`]).
//! 2. **Replication cost** ([`RouterConfig::interconnect`]): row
//!    sharding broadcasts `B` to every device and gathers the `C` row
//!    blocks back, so the router charges both against the interconnect
//!    model and refines the device count — or declines
//!    [`Route::Sharded`] outright when the modeled transfers eat the
//!    compute win (small jobs over a tight budget).
//! 3. **Tile fill** ([`Router::estimate_fill`]): the block engine wins
//!    when the matrices are *blocky* — their nonzeros cluster into dense
//!    `T×T` tiles (FEM matrices with contiguous runs, the high-CR half of
//!    Table 3). For scattered matrices the padding overhead of dense
//!    blocks dominates and the hash path wins. Fill is estimated on a row
//!    sample, mirroring spECK's lightweight pre-analysis (§3) — cheap,
//!    structure-only, value-free.
//!
//! # Example
//!
//! ```
//! use opsparse::coordinator::{Route, Router, RouterConfig};
//! use opsparse::sparse::Csr;
//!
//! // scattered identity: low tile fill, fits in memory -> hash pipeline
//! let a = Csr::identity(512);
//! assert_eq!(Router::default().route(&a, &a), Route::Hash);
//!
//! // budget just below the working set, but the job is tiny: replicating
//! // B over the modeled PCIe costs more than the split saves, so the
//! // cost-aware router declines the sharded route
//! let tight = Router::new(RouterConfig { device_memory_bytes: 16 * 1024, ..Default::default() });
//! assert_eq!(tight.route(&a, &a), Route::Hash);
//!
//! // with interconnect modeling off, the memory budget alone decides
//! let hard = Router::new(RouterConfig {
//!     device_memory_bytes: 16 * 1024,
//!     interconnect: None,
//!     ..Default::default()
//! });
//! match hard.route(&a, &a) {
//!     Route::Sharded { n_devices } => assert!(n_devices >= 2),
//!     other => panic!("expected a sharded route, got {other:?}"),
//! }
//! ```

use super::feedback::{Engine, ExecHistory, NsPerProdFit, PatternStats};
use crate::gpusim::{Interconnect, OverlapConfig, V100};
use crate::runtime::block_engine::BLOCK_MXU_EFFICIENCY;
use crate::sparse::stats::total_nprod;
use crate::sparse::Csr;
use std::sync::{Arc, Mutex};

/// Execution path for a job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    /// Two-phase hash pipeline (the paper's OpSparse).
    Hash,
    /// BSR block engine (PJRT kernel or the native bit-exact backend).
    Block,
    /// Row-sharded multi-device hash pipeline
    /// ([`crate::spgemm::multiply_sharded`]): chosen when the estimated
    /// working set exceeds one device's memory budget.
    Sharded {
        /// Devices the job is split across.
        n_devices: usize,
    },
    /// Block-row-sharded multi-device block engine: the shard plan's
    /// cuts are aligned to multiples of the engine block size `T`
    /// ([`crate::spgemm::sharded::ShardPlan::balanced_aligned`]), each
    /// sub-job runs the BSR engine on its own device, and the barrier
    /// stitches the row blocks bit-identically to the unsharded block
    /// result.
    ShardedBlock {
        /// Devices the job is split across.
        n_devices: usize,
    },
}

/// Which engine family the router commits jobs to.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum EngineMode {
    /// Structure-only routing (the pre-dispatch behavior): the static
    /// tile-fill threshold picks hash vs block. The default, so every
    /// deployment that never touches the knob routes exactly as before.
    #[default]
    Fill,
    /// Measured multi-engine dispatch: warm patterns pick the engine
    /// with the lower per-engine EWMA ([`choose_engine`]); cold patterns
    /// fall back to the sampled fill/compression estimate
    /// ([`Router::sampled_engine_estimate`]), which also seeds the
    /// history prior so the first real run lands on a comparable entry.
    Auto,
    /// Force the hash pipeline (modulo memory sharding) — the ablation
    /// baseline with dispatch off.
    Hash,
    /// Force the block engine (modulo memory sharding).
    Block,
}

impl EngineMode {
    /// Stable lowercase label (CLI/env value, JSON).
    pub fn label(self) -> &'static str {
        match self {
            EngineMode::Fill => "fill",
            EngineMode::Auto => "auto",
            EngineMode::Hash => "hash",
            EngineMode::Block => "block",
        }
    }

    /// Inverse of [`EngineMode::label`] (`--engine auto|hash|block`, plus
    /// the explicit `fill` spelling of the default); `None` for junk.
    pub fn parse(s: &str) -> Option<EngineMode> {
        match s.to_ascii_lowercase().as_str() {
            "fill" => Some(EngineMode::Fill),
            "auto" => Some(EngineMode::Auto),
            "hash" => Some(EngineMode::Hash),
            "block" => Some(EngineMode::Block),
            _ => None,
        }
    }
}

/// Hysteresis band of the measured dispatcher, mirroring
/// `REPLAN_SWITCH_GAIN` on the shard-replanning side: the challenger
/// engine must beat the incumbent's EWMA by at least this factor before
/// dispatch switches, so two engines trading sub-noise wins cannot make
/// the route flap — dispatch converges on one engine per pattern.
pub const DISPATCH_SWITCH_GAIN: f64 = 0.995;

/// Pick an engine from a pattern's per-engine stats (measured EWMAs
/// and/or seeded priors). The incumbent is the engine with more recorded
/// runs (ties go to hash, the conservative default); the challenger must
/// beat it by the [`DISPATCH_SWITCH_GAIN`] band to win. Consequence: the
/// chosen engine's EWMA is never worse than the alternative's by more
/// than the band — the property the dispatch tests pin.
pub fn choose_engine(stats: &PatternStats) -> Engine {
    let usable = |ns: f64| ns > 0.0 && ns.is_finite();
    let (h, b) = (stats.hash.ewma_ns, stats.block.ewma_ns);
    match (usable(h), usable(b)) {
        (true, false) => Engine::Hash,
        (false, true) => Engine::Block,
        (false, false) => Engine::Hash,
        (true, true) => {
            let incumbent = if stats.block.runs > stats.hash.runs {
                Engine::Block
            } else {
                Engine::Hash
            };
            let (inc_ns, ch_ns) = match incumbent {
                Engine::Hash => (h, b),
                Engine::Block => (b, h),
            };
            if ch_ns < inc_ns * DISPATCH_SWITCH_GAIN {
                incumbent.other()
            } else {
                incumbent
            }
        }
    }
}

/// Router configuration.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Block size of the compiled engine.
    pub t: usize,
    /// Minimum estimated tile fill ratio to route to the block engine.
    pub min_fill: f64,
    /// Rows sampled for the estimate.
    pub sample_rows: usize,
    /// Single-device memory budget in bytes; jobs whose
    /// [`working_set_bytes`] exceeds it shard. Default: the V100's 16 GB.
    pub device_memory_bytes: usize,
    /// Most devices a sharded job may span. Below 2 the sharded route is
    /// disabled entirely (single-device deployment): oversized jobs stay
    /// on the hash path and fail there if they truly cannot fit.
    pub max_devices: usize,
    /// Interconnect model used to weigh a sharded route: the `B`
    /// broadcast and `C` row-block gather are charged against it when
    /// choosing `n_devices`, and a job whose modeled sharded time is no
    /// better than unsharded **declines** the route (the budget is a
    /// planning target, not an allocator — small jobs that barely
    /// overshoot it run faster unsplit than replicated). `None` restores
    /// pure memory-budget routing: shard whenever the working set
    /// exceeds the budget, whatever it costs.
    pub interconnect: Option<Interconnect>,
    /// Modeled single-device compute time per intermediate product, in
    /// ns — the same cheap structure-only proxy `ShardPlan::balanced`
    /// load-balances with, here scaled to time so broadcast/gather costs
    /// compare against the compute they amortize. The default is a
    /// placeholder constant; [`RouterConfig::calibrated`] replaces it
    /// with a least-squares fit of simulated timelines over the
    /// generator suite ([`calibrate_ns_per_prod`]).
    pub ns_per_prod: f64,
    /// Overlap model the sharded-route cost comparison uses: with
    /// overlap enabled (the default) the `B` broadcast and `C` gather
    /// are costed *pipelined* against compute
    /// ([`Interconnect::overlapped_estimate_ns`]), which shifts the
    /// break-even toward more shards; `OverlapConfig::off()` restores
    /// the serial three-phase comparison.
    pub overlap: OverlapConfig,
    /// Live (refreshable) ns-per-product fit. When set, the router
    /// reads [`NsPerProdFit::current`] **per decision** instead of the
    /// frozen `ns_per_prod` constant, so measured job times folded into
    /// the shared fit (the coordinator's workers do this) move every
    /// subsequent shard-vs-stay decision — the online re-fit loop.
    /// `None` keeps the static constant.
    pub fit: Option<Arc<NsPerProdFit>>,
    /// Which engine family jobs are committed to; see [`EngineMode`].
    /// The default ([`EngineMode::Fill`]) routes exactly as before this
    /// knob existed — measured dispatch is strictly opt-in.
    pub engine_mode: EngineMode,
    /// Engine-tagged execution history the [`EngineMode::Auto`]
    /// dispatcher consults (and seeds with cold estimates). Normally the
    /// same store the coordinator records measured runs into, so warm
    /// patterns route on measurements. `None` makes `Auto` fall back to
    /// the sampled estimate on every decision.
    pub dispatch_history: Option<Arc<Mutex<ExecHistory>>>,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            t: 16,
            min_fill: 0.25,
            sample_rows: 256,
            device_memory_bytes: 16 * (1 << 30),
            max_devices: 8,
            interconnect: Some(Interconnect::pcie3()),
            ns_per_prod: 1.0,
            overlap: OverlapConfig::default(),
            fit: None,
            engine_mode: EngineMode::Fill,
            dispatch_history: None,
        }
    }
}

impl RouterConfig {
    /// [`RouterConfig::default`] with `ns_per_prod` snapshotted from the
    /// simulated-suite calibration instead of the hard-coded constant
    /// (see [`calibrate_ns_per_prod`]). The snapshot does not refresh;
    /// use [`RouterConfig::with_live_fit`] for a router that tracks
    /// measured traffic.
    pub fn calibrated() -> Self {
        RouterConfig { ns_per_prod: calibrate_ns_per_prod(), ..Default::default() }
    }

    /// [`RouterConfig::default`] reading `fit` on every routing
    /// decision: the compute proxy starts at the fit's current value
    /// and follows every measured observation folded into it.
    pub fn with_live_fit(fit: Arc<NsPerProdFit>) -> Self {
        RouterConfig { ns_per_prod: fit.current(), fit: Some(fit), ..Default::default() }
    }

    /// The compute proxy in effect right now: the live fit when one is
    /// attached, the static constant otherwise.
    pub fn ns_per_prod_now(&self) -> f64 {
        self.fit.as_ref().map(|f| f.current()).unwrap_or(self.ns_per_prod)
    }
}

/// Fraction of the pipeline's simulated wall time the router attributes
/// to the chunk-gated symbolic phase (setup + binning + symbolic) when
/// estimating overlapped makespans. The suite's simulated timelines put
/// the pre-numeric phases at roughly a third of the pipeline; the
/// estimate only shapes *how much* broadcast hides behind compute, never
/// the serial bound, so a rough constant is safe here.
const ROUTER_SYM_FRACTION: f64 = 0.35;

/// Current value of the process-wide default ns-per-product fit
/// ([`crate::coordinator::feedback::default_fit`]): seeded lazily from
/// the simulated-suite least-squares calibration (the
/// `fit_ns_per_prod_suite` fit below) and *refreshable* — observations folded
/// into the default fit move every later read. Reads with no
/// intervening observations are bit-stable. (This replaces a
/// write-once `OnceLock<f64>` table that could never be refreshed
/// in-process.)
pub fn calibrate_ns_per_prod() -> f64 {
    super::feedback::default_fit().current()
}

/// Least-squares calibration of [`RouterConfig::ns_per_prod`]: run the
/// pipeline on one representative of each generator family (uniform,
/// power-law, stencil, Kronecker — the same families the sharding test
/// matrix uses) at two sizes, simulate each trace on the V100 model, and
/// fit `total_ns ≈ k · n_prod` through the origin
/// (`k = Σ tᵢpᵢ / Σ pᵢ²`). Deterministic and moderately expensive —
/// callers seed a [`NsPerProdFit`] with it once rather than refitting
/// per read.
pub(crate) fn fit_ns_per_prod_suite() -> f64 {
    use crate::gen::kron::Kron;
    use crate::gen::powerlaw::PowerLaw;
    use crate::gen::stencil::{Grid, Stencil};
    use crate::gen::uniform::Uniform;
    use crate::gpusim::{simulate, V100};
    use crate::spgemm::pipeline::{multiply, OpSparseConfig};
    use crate::util::rng::Rng;

    let mut rng = Rng::new(0xca11b);
    let mut mats: Vec<Csr> = Vec::new();
    for n in [512usize, 1536] {
        mats.push(Uniform { n, per_row: 8, jitter: 4 }.generate(&mut rng));
        mats.push(
            PowerLaw {
                n,
                alpha: 2.1,
                max_row: (n / 16).max(32),
                mean_row: 6.0,
                hub_frac: 0.15,
                forced_giant_rows: 0,
            }
            .generate(&mut rng),
        );
        mats.push(Stencil { n, grid: Grid::D2, reach: 1, keep: 1.0, diagonal: true }
            .generate(&mut rng));
    }
    mats.push(Kron { scale: 9, edge_factor: 8, a: 0.57, b: 0.19, c: 0.19 }.generate(&mut rng));

    let cfg = OpSparseConfig::default();
    let (mut num, mut den) = (0.0f64, 0.0f64);
    for a in &mats {
        let Ok(out) = multiply(a, a, &cfg) else { continue };
        if out.nprod == 0 {
            continue;
        }
        let tl = simulate(&out.trace, &V100);
        num += tl.total_ns * out.nprod as f64;
        den += (out.nprod as f64) * (out.nprod as f64);
    }
    if den <= 0.0 {
        return 1.0; // degenerate suite: keep the placeholder
    }
    // clamp to a physically plausible band: one product costs at least a
    // fraction of an HBM access and at most a page of them
    (num / den).clamp(0.05, 50.0)
}

/// Compression-ratio guess used to size the gathered `C` from the
/// intermediate-product upper bound (`nnz(C) ≈ n_prod / 4`; Table 3's
/// suite median is ~3–5). Only the routing *estimate* uses this — the
/// simulator charges the gather on the real row-block sizes.
const C_GATHER_COMPRESSION: f64 = 4.0;

/// How far over the memory budget a job may be and still *decline* the
/// sharded route on cost grounds. The working-set estimate is a
/// pessimistic upper bound (`nnz(C) = n_prod`), so a small job barely
/// overshooting it typically fits fine unsplit; a job beyond this factor
/// genuinely cannot run on one device and must shard no matter what the
/// transfers cost.
const DECLINE_SPILL_FACTOR: f64 = 2.0;

/// Upper-bound device working set of `C = A * B` under the paper's CSR
/// layout: both operands resident, plus `C` sized by the intermediate
/// product count (`nnz(C) <= n_prod`, 12 B per entry: 4 B column + 8 B
/// value) plus the `C.rpt` metadata. Transient hash tables are excluded —
/// they are bounded by the same `n_prod` term. `O(nnz(A))` to compute,
/// value-free.
pub fn working_set_bytes(a: &Csr, b: &Csr) -> usize {
    // a mismatched pair never reaches a device: estimate operands only and
    // let the pipeline report the dimension error
    let nprod = if a.cols == b.rows { total_nprod(a, b) } else { 0 };
    a.device_bytes() + b.device_bytes() + 12 * nprod + 4 * (a.rows + 1)
}

/// Structure-only router.
#[derive(Clone, Debug, Default)]
pub struct Router {
    pub cfg: RouterConfig,
}

impl Router {
    pub fn new(cfg: RouterConfig) -> Self {
        Router { cfg }
    }

    /// Estimate the dense-tile fill ratio of `m` on a row sample: for each
    /// sampled row, count (tile, elements-in-tile) and return
    /// elements / (tiles × T) — the column-direction fill a BSR
    /// conversion would see.
    pub fn estimate_fill(&self, m: &Csr) -> f64 {
        if m.rows == 0 || m.nnz() == 0 {
            return 0.0;
        }
        let t = self.cfg.t;
        let step = (m.rows / self.cfg.sample_rows.max(1)).max(1);
        let mut elems = 0usize;
        let mut tiles = 0usize;
        for r in (0..m.rows).step_by(step) {
            let mut last_tile = u32::MAX;
            for &c in m.row_cols(r) {
                let tile = c / t as u32;
                if tile != last_tile {
                    tiles += 1;
                    last_tile = tile;
                }
                elems += 1;
            }
        }
        if tiles == 0 {
            0.0
        } else {
            elems as f64 / (tiles * t) as f64
        }
    }

    /// Device count a job should shard over, or `None` when it fits on
    /// one device (or sharding would lose to replication cost).
    ///
    /// Memory first: row sharding replicates `B` on every device, so only
    /// the `A`/`C` portion of the working set divides by the device
    /// count — `k` must satisfy `B + (A + C)/k <= budget`. A `B` that
    /// alone exceeds the budget is infeasible for row sharding
    /// (column-sharding `B` is a ROADMAP item) — the memory-minimal count
    /// is then `max_devices`. Mismatched dimensions never shard: the job
    /// goes to the hash path, which reports the dimension error.
    ///
    /// With an [`Interconnect`] configured, the count is then refined by
    /// modeled time: for each feasible `k`, charge the one-to-all/ring
    /// `B` broadcast plus the `C` row-block gather around `compute / k`,
    /// pick the fastest `k` — and **decline the route entirely** when
    /// even the best sharded time is no better than running unsharded.
    /// That is what stops small jobs from sharding: their compute is
    /// cheap, so replicating `B` eats the win, exactly the
    /// communication-bound regime the SpGEMM surveys report. Declining
    /// is bounded by [`DECLINE_SPILL_FACTOR`]: a job that overshoots the
    /// budget beyond it (or whose `B` alone exceeds the budget) cannot
    /// run unsharded at all, so the cost model only picks its `k`, never
    /// vetoes the split.
    pub fn shard_count(&self, a: &Csr, b: &Csr) -> Option<usize> {
        if a.cols != b.rows || self.cfg.max_devices < 2 {
            return None;
        }
        let budget = self.cfg.device_memory_bytes.max(1);
        // cheap screen first: `n_prod <= nnz(A) · max nnz/row of B`, so if
        // even that pessimistic working set fits, skip the exact O(nnz(A))
        // fold — submit-path routing stays O(rows) for the common case
        let base = a.device_bytes() + b.device_bytes() + 4 * (a.rows + 1);
        let upper =
            base.saturating_add(12usize.saturating_mul(a.nnz().saturating_mul(b.max_row_nnz())));
        debug_assert!(
            upper >= working_set_bytes(a, b),
            "screen must stay an upper bound of the exact estimate"
        );
        if upper <= budget {
            return None;
        }
        // one exact O(nnz(A)) fold serves both the working-set estimate
        // and the cost model below (`working_set_bytes` would refold it)
        let nprod = total_nprod(a, b);
        let est = base + 12 * nprod;
        debug_assert_eq!(est, working_set_bytes(a, b));
        if est <= budget {
            return None;
        }
        let max = self.cfg.max_devices;
        let b_rep = b.device_bytes();
        if b_rep >= budget {
            // row sharding replicates B, so no k makes this fit; span
            // the whole fleet as the best available (PR 2 behavior) —
            // the cost model has no unsharded baseline to prefer here
            return Some(max);
        }
        let n_mem = (est - b_rep).div_ceil(budget - b_rep).clamp(2, max);
        let Some(ic) = self.cfg.interconnect.as_ref() else {
            return Some(n_mem);
        };
        // warm dispatched patterns broadcast (and are costed) with their
        // tuned chunk size; outside Auto this is exactly `cfg.overlap`
        let overlap = self.overlap_for(a, b);

        // read the compute proxy *now*: with a live fit attached, every
        // decision tracks the latest measured re-fit
        let unsharded_ns = nprod as f64 * self.cfg.ns_per_prod_now();
        let c_gather_bytes = 12.0 * nprod as f64 / C_GATHER_COMPRESSION;
        let mut best: Option<(usize, f64)> = None;
        for k in n_mem..=max {
            let blocks = vec![(c_gather_bytes / k as f64) as usize; k];
            // overlapped by default: broadcast chunks hide behind the
            // symbolic kernels and early shards gather under stragglers,
            // so the modeled sharded time shrinks and the break-even
            // shifts toward more shards; `overlap: off` restores the
            // serial three-phase sum. An unusable interconnect model
            // (zero bandwidth) cannot veto a memory-mandated shard: fall
            // back to the memory count.
            let modeled = if overlap.enabled {
                ic.overlapped_estimate_ns(
                    b_rep,
                    unsharded_ns / k as f64,
                    ROUTER_SYM_FRACTION,
                    &blocks,
                    &overlap,
                )
            } else {
                match (ic.broadcast_ns(b_rep, k), ic.gather_ns(&blocks)) {
                    (Ok(bcast), Ok(gather)) => Ok(bcast + unsharded_ns / k as f64 + gather),
                    (Err(e), _) | (_, Err(e)) => Err(e),
                }
            };
            let Ok(t) = modeled else {
                return Some(n_mem);
            };
            if best.map_or(true, |(_, bt)| t < bt) {
                best = Some((k, t));
            }
        }
        let (k, sharded_ns) = best?;
        // declining is only honest while the unsharded baseline is
        // actually runnable — a job far over budget must shard anyway
        let barely_overshoots = (est as f64) <= DECLINE_SPILL_FACTOR * budget as f64;
        if barely_overshoots && sharded_ns >= unsharded_ns {
            return None; // replication eats the win: stay unsharded
        }
        Some(k)
    }

    /// Route a job: memory and replication cost first (an over-budget job
    /// shards — unless it only barely overshoots *and* the modeled
    /// transfers eat the win, in which case it stays on the hash path;
    /// see [`Router::shard_count`]), then the engine choice under
    /// [`RouterConfig::engine_mode`]: the static tile-fill threshold
    /// (`Fill`, the default), the measured dispatcher (`Auto`), or a
    /// forced engine. A dimension-mismatched pair always routes to the
    /// hash path, which rejects it with a proper error (the block engine
    /// would panic instead of failing the job).
    pub fn route(&self, a: &Csr, b: &Csr) -> Route {
        if a.cols != b.rows {
            return Route::Hash;
        }
        let shard = self.shard_count(a, b);
        let engine = match self.cfg.engine_mode {
            EngineMode::Hash => Engine::Hash,
            EngineMode::Block => Engine::Block,
            EngineMode::Auto => self.dispatch_engine(a, b),
            EngineMode::Fill => {
                // the pre-dispatch behavior, bit for bit: sharding always
                // took the hash path, and the fill threshold only decided
                // hash vs block for jobs that fit on one device
                if shard.is_some() {
                    Engine::Hash
                } else {
                    let fill = self.estimate_fill(a).min(self.estimate_fill(b));
                    if fill >= self.cfg.min_fill {
                        Engine::Block
                    } else {
                        Engine::Hash
                    }
                }
            }
        };
        match (engine, shard) {
            (Engine::Hash, Some(n_devices)) => Route::Sharded { n_devices },
            (Engine::Hash, None) => Route::Hash,
            (Engine::Block, Some(n_devices)) => Route::ShardedBlock { n_devices },
            (Engine::Block, None) => Route::Block,
        }
    }

    /// The measured dispatcher ([`EngineMode::Auto`]): look the pattern
    /// up in the engine-tagged history; when it is cold, run the sampled
    /// estimate and seed the priors so the entry is comparable (and so
    /// the first measured run folds onto the estimate instead of landing
    /// blind); then choose under the hysteresis band ([`choose_engine`]).
    pub fn dispatch_engine(&self, a: &Csr, b: &Csr) -> Engine {
        let Some(history) = self.cfg.dispatch_history.as_ref() else {
            let (hash_ns, block_ns) = self.sampled_engine_estimate(a, b);
            return if block_ns < hash_ns { Engine::Block } else { Engine::Hash };
        };
        let key = (a.pattern_fingerprint(), b.pattern_fingerprint());
        let mut h = history.lock().unwrap_or_else(|e| e.into_inner());
        let warm = h
            .lookup(key)
            .is_some_and(|s| s.hash.ewma_ns > 0.0 || s.block.ewma_ns > 0.0);
        if !warm {
            let (hash_ns, block_ns) = self.sampled_engine_estimate(a, b);
            h.seed_engine_priors(key, hash_ns, block_ns);
        }
        h.lookup(key).map(choose_engine).unwrap_or_default()
    }

    /// Ocean-style cold-start estimate: on a bounded row sample of `A`,
    /// estimate the intermediate-product count and `A`'s tile fill in one
    /// pass (`B`'s fill via [`Router::estimate_fill`]), derive the block
    /// pair count from the fill-compression ratio (each dense `T×T` pair
    /// absorbs `fill_a·T × fill_b·T` scalar products), and convert both
    /// engines' work models to ns — the hash side through the live
    /// ns-per-product proxy, the block side through the same closed-form
    /// model as [`crate::runtime::BlockEngine::simulated_ns`]. Returns
    /// `(hash_ns, block_ns)`. Cheap (`O(sampled nnz)`), structure-only,
    /// value-free; it seeds the history prior, it never outvotes a
    /// measurement.
    pub fn sampled_engine_estimate(&self, a: &Csr, b: &Csr) -> (f64, f64) {
        let t = self.cfg.t.max(1);
        let step = (a.rows / self.cfg.sample_rows.max(1)).max(1);
        let mut rows_seen = 0usize;
        let mut sampled_nprod = 0usize;
        let mut a_elems = 0usize;
        let mut a_tiles = 0usize;
        for r in (0..a.rows).step_by(step) {
            rows_seen += 1;
            let mut last_tile = u32::MAX;
            for &c in a.row_cols(r) {
                sampled_nprod += b.row_cols(c as usize).len();
                let tile = c / t as u32;
                if tile != last_tile {
                    a_tiles += 1;
                    last_tile = tile;
                }
                a_elems += 1;
            }
        }
        let scale = if rows_seen == 0 { 0.0 } else { a.rows as f64 / rows_seen as f64 };
        let est_nprod = sampled_nprod as f64 * scale;
        let hash_ns = est_nprod * self.cfg.ns_per_prod_now();

        let fill_a =
            if a_tiles == 0 { 0.0 } else { a_elems as f64 / (a_tiles * t) as f64 };
        let fill_b = self.estimate_fill(b);
        // scalar products per block pair: the column-direction fill of
        // each operand bounds how densely a T×T product tile is used
        let per_pair = (fill_a * t as f64).max(1.0) * (fill_b * t as f64).max(1.0);
        let pairs = (est_nprod / per_pair).max(1.0);
        let tt = (t * t) as f64;
        let dev = &V100;
        let launch_ns = 2.0 * (dev.launch_overhead_ns + dev.launch_latency_ns);
        let sym_ns = pairs * dev.global_atomic_ns / dev.sms as f64;
        let flops = 2.0 * pairs * tt * t as f64;
        let num_ns = flops / (dev.sms as f64 * dev.fp64_flops_per_ns * BLOCK_MXU_EFFICIENCY);
        let bytes = 3.0 * pairs * tt * 8.0;
        let mem_ns = bytes / dev.hbm_bytes_per_ns;
        (hash_ns, launch_ns + sym_ns + num_ns + mem_ns)
    }

    /// The overlap model a sharded decision for `(a, b)` should use:
    /// the static config, with `chunk_bytes` replaced by the pattern's
    /// tuned size ([`super::feedback::tune_chunk_bytes`] output, stored
    /// per pattern by the context path or restored from a persisted
    /// warm start) when the measured dispatcher holds a history. This
    /// is the serve-path half of the chunk-tuning loop: a warm
    /// dispatched pattern's broadcast is planned with its tuned panels,
    /// not the fleet-wide default. Without a dispatch store (every
    /// non-`Auto` mode) this returns `cfg.overlap` untouched, so the
    /// pre-dispatch routing is reproduced exactly.
    pub fn overlap_for(&self, a: &Csr, b: &Csr) -> OverlapConfig {
        let mut overlap = self.cfg.overlap;
        if overlap.enabled {
            if let Some(history) = self.cfg.dispatch_history.as_ref() {
                let key = (a.pattern_fingerprint(), b.pattern_fingerprint());
                let h = history.lock().unwrap_or_else(|e| e.into_inner());
                if let Some(cb) = h.lookup(key).and_then(|s| s.chunk_bytes) {
                    overlap.chunk_bytes = cb;
                }
            }
        }
        overlap
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::banded::Banded;
    use crate::gen::uniform::Uniform;
    use crate::util::rng::Rng;

    #[test]
    fn fem_contiguous_matrix_routes_to_block() {
        let mut rng = Rng::new(41);
        let a = Banded { n: 1000, per_row: 48, band: 40, contiguous_frac: 1.0 }.generate(&mut rng);
        let r = Router::default();
        assert!(r.estimate_fill(&a) > 0.4, "fill={}", r.estimate_fill(&a));
        assert_eq!(r.route(&a, &a), Route::Block);
    }

    #[test]
    fn scattered_matrix_routes_to_hash() {
        let mut rng = Rng::new(42);
        let a = Uniform { n: 2000, per_row: 6, jitter: 3 }.generate(&mut rng);
        let r = Router::default();
        assert!(r.estimate_fill(&a) < 0.25, "fill={}", r.estimate_fill(&a));
        assert_eq!(r.route(&a, &a), Route::Hash);
    }

    #[test]
    fn empty_matrix_fill_zero() {
        let z = Csr::zero(10, 10);
        assert_eq!(Router::default().estimate_fill(&z), 0.0);
        assert_eq!(Router::default().route(&z, &z), Route::Hash);
    }

    #[test]
    fn oversized_working_set_routes_sharded() {
        let mut rng = Rng::new(43);
        let a = Uniform { n: 1000, per_row: 8, jitter: 4 }.generate(&mut rng);
        let est = working_set_bytes(&a, &a);
        assert!(est > a.device_bytes() * 2, "estimate must include the C upper bound");
        // budget just below the estimate: minimal split (memory-only
        // routing — the cost-aware behavior has its own tests below)
        let r = Router::new(RouterConfig {
            device_memory_bytes: est - 1,
            interconnect: None,
            ..Default::default()
        });
        assert_eq!(r.route(&a, &a), Route::Sharded { n_devices: 2 });
        // budget a quarter of the estimate: more devices, still capped
        let r4 = Router::new(RouterConfig {
            device_memory_bytes: est / 4,
            max_devices: 8,
            interconnect: None,
            ..Default::default()
        });
        match r4.route(&a, &a) {
            Route::Sharded { n_devices } => assert!((4..=8).contains(&n_devices)),
            other => panic!("expected sharded, got {other:?}"),
        }
    }

    #[test]
    fn shard_count_honors_max_devices() {
        let mut rng = Rng::new(44);
        let a = Uniform { n: 500, per_row: 6, jitter: 3 }.generate(&mut rng);
        let r = Router::new(RouterConfig {
            device_memory_bytes: 1,
            max_devices: 4,
            interconnect: None,
            ..Default::default()
        });
        assert_eq!(r.shard_count(&a, &a), Some(4));
        // memory routing outranks tile fill
        assert!(matches!(r.route(&a, &a), Route::Sharded { n_devices: 4 }));
    }

    #[test]
    fn shard_count_accounts_for_b_replication() {
        // B is replicated on every device, so the naive est/budget split
        // would under-provision: with budget = est/2 a 2-way split leaves
        // each device holding B + half of A/C > budget
        let mut rng = Rng::new(46);
        let a = Uniform { n: 400, per_row: 6, jitter: 3 }.generate(&mut rng);
        let est = working_set_bytes(&a, &a);
        let b_rep = a.device_bytes();
        let budget = est.div_ceil(2);
        let r = Router::new(RouterConfig {
            device_memory_bytes: budget,
            interconnect: None,
            ..Default::default()
        });
        let n = r.shard_count(&a, &a).expect("over budget");
        assert!(n > 2, "naive est/budget sizing would give 2, got {n}");
        assert!(
            b_rep + (est - b_rep).div_ceil(n) <= budget,
            "chosen n={n} must actually fit the budget"
        );
    }

    #[test]
    fn max_devices_below_two_disables_sharding() {
        let mut rng = Rng::new(47);
        let a = Uniform { n: 300, per_row: 6, jitter: 3 }.generate(&mut rng);
        for max_devices in [0, 1] {
            let r = Router::new(RouterConfig {
                device_memory_bytes: 1,
                max_devices,
                ..Default::default()
            });
            assert_eq!(r.shard_count(&a, &a), None, "max_devices={max_devices}");
            assert_eq!(r.route(&a, &a), Route::Hash);
        }
    }

    #[test]
    fn mismatched_dims_never_route_sharded() {
        // a job the pipeline will reject must reach the hash path so the
        // caller gets the dimension error, not a shard-planning panic
        let a = Csr::zero(3, 4);
        let b = Csr::zero(5, 5);
        let r = Router::new(RouterConfig { device_memory_bytes: 1, ..Default::default() });
        assert_eq!(r.shard_count(&a, &b), None);
        assert_eq!(r.route(&a, &b), Route::Hash);
    }

    #[test]
    fn blocky_but_oversized_still_shards() {
        let mut rng = Rng::new(45);
        let a = Banded { n: 800, per_row: 48, band: 40, contiguous_frac: 1.0 }.generate(&mut rng);
        let r = Router::new(RouterConfig {
            device_memory_bytes: 1024,
            interconnect: None,
            ..Default::default()
        });
        assert!(matches!(r.route(&a, &a), Route::Sharded { .. }));
    }

    #[test]
    fn small_job_declines_sharding_when_replication_eats_the_win() {
        // the same matrix + budget that shards under memory-only routing
        // (PR 2 behavior) stays unsharded once the B broadcast and C
        // gather are charged: its compute is microseconds, the modeled
        // PCIe transfers are not
        let mut rng = Rng::new(48);
        let a = Uniform { n: 300, per_row: 6, jitter: 3 }.generate(&mut rng);
        let est = working_set_bytes(&a, &a);
        let memory_only = Router::new(RouterConfig {
            device_memory_bytes: est - 1,
            interconnect: None,
            ..Default::default()
        });
        assert!(
            matches!(memory_only.route(&a, &a), Route::Sharded { .. }),
            "baseline: memory-only routing shards this job"
        );
        let cost_aware = Router::new(RouterConfig {
            device_memory_bytes: est - 1,
            ..Default::default()
        });
        assert_eq!(cost_aware.shard_count(&a, &a), None);
        assert_eq!(cost_aware.route(&a, &a), Route::Hash, "replication eats the win");
    }

    #[test]
    fn big_job_still_shards_under_interconnect_cost() {
        // enough intermediate products that splitting the compute pays
        // for replicating B many times over
        let mut rng = Rng::new(49);
        let a = Uniform { n: 20_000, per_row: 16, jitter: 4 }.generate(&mut rng);
        let est = working_set_bytes(&a, &a);
        let r = Router::new(RouterConfig {
            device_memory_bytes: est / 2,
            ..Default::default()
        });
        match r.route(&a, &a) {
            Route::Sharded { n_devices } => assert!(n_devices >= 2),
            other => panic!("expected sharded, got {other:?}"),
        }
    }

    #[test]
    fn cost_aware_count_never_undershoots_the_memory_minimum() {
        // the refined device count must stay memory-feasible: k >= the
        // minimal count that fits B + (A+C)/k under the budget
        let mut rng = Rng::new(50);
        let a = Uniform { n: 20_000, per_row: 16, jitter: 4 }.generate(&mut rng);
        let est = working_set_bytes(&a, &a);
        let budget = est / 3;
        let memory_only = Router::new(RouterConfig {
            device_memory_bytes: budget,
            interconnect: None,
            ..Default::default()
        });
        let n_mem = memory_only.shard_count(&a, &a).expect("over budget");
        let cost_aware =
            Router::new(RouterConfig { device_memory_bytes: budget, ..Default::default() });
        if let Some(n) = cost_aware.shard_count(&a, &a) {
            assert!(n >= n_mem, "cost-aware count {n} under memory minimum {n_mem}");
        }
    }

    #[test]
    fn unusable_interconnect_falls_back_to_memory_routing() {
        use crate::gpusim::Topology;
        let mut rng = Rng::new(51);
        let a = Uniform { n: 300, per_row: 6, jitter: 3 }.generate(&mut rng);
        let dead = Interconnect {
            bandwidth_gbps: 0.0,
            latency_us: 1.0,
            topology: Topology::OneToAll,
        };
        // budget above B's footprint (so the cost model is consulted at
        // all) but below the working set (so the job is a candidate)
        let budget = (a.device_bytes() + working_set_bytes(&a, &a)) / 2;
        let r = Router::new(RouterConfig {
            device_memory_bytes: budget,
            interconnect: Some(dead),
            ..Default::default()
        });
        // zero bandwidth cannot veto a memory-mandated shard
        assert!(matches!(r.route(&a, &a), Route::Sharded { .. }));
    }

    #[test]
    fn far_over_budget_job_shards_despite_transfer_cost() {
        // a job beyond the decline spill factor has no runnable
        // unsharded baseline: the cost model picks k but cannot veto,
        // however badly the modeled transfers compare to the compute
        let mut rng = Rng::new(52);
        let a = Uniform { n: 300, per_row: 6, jitter: 3 }.generate(&mut rng);
        let est = working_set_bytes(&a, &a);
        let budget = (est / 4).max(a.device_bytes() + 1); // b_rep < budget << est
        let r =
            Router::new(RouterConfig { device_memory_bytes: budget, ..Default::default() });
        match r.route(&a, &a) {
            Route::Sharded { n_devices } => assert!(n_devices >= 2),
            other => panic!("must shard, got {other:?}"),
        }
        // and a B that alone exceeds the budget keeps the forced
        // whole-fleet split (row sharding cannot shrink B)
        let r_tiny = Router::new(RouterConfig {
            device_memory_bytes: 1024,
            ..Default::default()
        });
        assert_eq!(r_tiny.shard_count(&a, &a), Some(RouterConfig::default().max_devices));
    }

    #[test]
    fn calibrated_ns_per_prod_is_sane_and_cached() {
        let k1 = calibrate_ns_per_prod();
        assert!(k1.is_finite() && k1 > 0.0, "fit must be positive, got {k1}");
        assert!((0.05..=50.0).contains(&k1), "fit {k1} outside the plausible band");
        // second call reads the cached fit
        let k2 = calibrate_ns_per_prod();
        assert_eq!(k1, k2);
        let cfg = RouterConfig::calibrated();
        assert_eq!(cfg.ns_per_prod, k1);
        // the placeholder constant is replaced, not echoed, unless the
        // fit happens to land exactly on it (it does not on this model)
        assert_ne!(cfg.ns_per_prod, RouterConfig::default().ns_per_prod);
    }

    #[test]
    fn live_fit_moves_the_routing_decision_between_reads() {
        // the online re-fit loop, end to end at the router: the same
        // Router instance declines sharding while the fit says compute
        // is cheap, then shards once measured observations say each
        // product costs ~50 ns (compute now dwarfs the transfers). No
        // router rebuild in between — the fit is read per decision.
        let mut rng = Rng::new(56);
        let a = Uniform { n: 20_000, per_row: 16, jitter: 4 }.generate(&mut rng);
        let est = working_set_bytes(&a, &a);
        let fit = Arc::new(NsPerProdFit::new(0.05));
        let r = Router::new(RouterConfig {
            device_memory_bytes: est / 2,
            fit: Some(Arc::clone(&fit)),
            ..Default::default()
        });
        assert_eq!(
            r.shard_count(&a, &a),
            None,
            "at 0.05 ns/product the modeled transfers eat the win"
        );
        let nprod = crate::sparse::stats::total_nprod(&a, &a) as u64;
        for _ in 0..64 {
            assert!(fit.observe(nprod as f64 * 50.0, nprod));
        }
        assert!(r.cfg.ns_per_prod_now() > 40.0, "fit must have converged upward");
        assert!(
            r.shard_count(&a, &a).is_some(),
            "with measured compute 1000x costlier, the same router must shard"
        );
    }

    #[test]
    fn overlap_never_declines_what_serial_routing_accepts() {
        // the overlapped sharded estimate is ≤ the serial one at every
        // device count, so any job the serial cost model shards must
        // still shard under the overlapped model
        let mut rng = Rng::new(53);
        for n in [2_000usize, 6_000, 12_000, 20_000] {
            let a = Uniform { n, per_row: 12, jitter: 4 }.generate(&mut rng);
            let est = working_set_bytes(&a, &a);
            let budget = est / 2;
            let serial = Router::new(RouterConfig {
                device_memory_bytes: budget,
                overlap: crate::gpusim::OverlapConfig::off(),
                ..Default::default()
            });
            let overlapped =
                Router::new(RouterConfig { device_memory_bytes: budget, ..Default::default() });
            if serial.shard_count(&a, &a).is_some() {
                assert!(
                    overlapped.shard_count(&a, &a).is_some(),
                    "n={n}: overlapped router declined a job the serial router shards"
                );
            }
        }
    }

    #[test]
    fn overlap_shifts_the_sharding_break_even_toward_sharding() {
        // the tentpole's routing claim: there are jobs whose serial
        // modeled sharded time loses to unsharded (decline) but whose
        // overlapped time wins (shard) — pipelining moves the break-even.
        // Sweep the compute scale (ns_per_prod) geometrically and find
        // the window; B is several MB so the broadcast really chunks.
        let mut rng = Rng::new(55);
        let a = Uniform { n: 30_000, per_row: 12, jitter: 4 }.generate(&mut rng);
        assert!(a.device_bytes() > 2 << 20, "B must span multiple broadcast chunks");
        let est = working_set_bytes(&a, &a);
        let budget = est - 1; // sharding candidate, decline allowed
        let mut found = None;
        let mut nspp = 0.02f64;
        while nspp < 2.0 {
            let serial = Router::new(RouterConfig {
                device_memory_bytes: budget,
                ns_per_prod: nspp,
                overlap: crate::gpusim::OverlapConfig::off(),
                ..Default::default()
            });
            let overlapped = Router::new(RouterConfig {
                device_memory_bytes: budget,
                ns_per_prod: nspp,
                ..Default::default()
            });
            let (s, o) = (serial.shard_count(&a, &a), overlapped.shard_count(&a, &a));
            if s.is_none() && o.is_some() {
                found = Some(nspp);
                break;
            }
            nspp *= 1.09;
        }
        assert!(
            found.is_some(),
            "no compute scale where overlap shards a serial-declined job — \
             the overlapped model is not moving the break-even"
        );
    }

    #[test]
    fn engine_mode_labels_round_trip_and_default_is_fill() {
        assert_eq!(EngineMode::default(), EngineMode::Fill);
        for m in [EngineMode::Fill, EngineMode::Auto, EngineMode::Hash, EngineMode::Block] {
            assert_eq!(EngineMode::parse(m.label()), Some(m));
        }
        assert_eq!(EngineMode::parse("AUTO"), Some(EngineMode::Auto));
        assert_eq!(EngineMode::parse("cuda"), None);
        assert_eq!(EngineMode::parse(""), None);
    }

    #[test]
    fn forced_engine_modes_override_the_fill_heuristic() {
        let mut rng = Rng::new(60);
        let blocky =
            Banded { n: 1000, per_row: 48, band: 40, contiguous_frac: 1.0 }.generate(&mut rng);
        let scattered = Uniform { n: 2000, per_row: 6, jitter: 3 }.generate(&mut rng);
        let hash_only = Router::new(RouterConfig {
            engine_mode: EngineMode::Hash,
            ..Default::default()
        });
        assert_eq!(hash_only.route(&blocky, &blocky), Route::Hash, "forced hash");
        let block_only = Router::new(RouterConfig {
            engine_mode: EngineMode::Block,
            ..Default::default()
        });
        assert_eq!(block_only.route(&scattered, &scattered), Route::Block, "forced block");
        // forced block on an over-budget job takes the block-sharded route
        let block_sharded = Router::new(RouterConfig {
            engine_mode: EngineMode::Block,
            device_memory_bytes: 1024,
            interconnect: None,
            ..Default::default()
        });
        match block_sharded.route(&blocky, &blocky) {
            Route::ShardedBlock { n_devices } => assert!(n_devices >= 2),
            other => panic!("expected ShardedBlock, got {other:?}"),
        }
    }

    #[test]
    fn sampled_estimate_favors_block_on_blocky_and_hash_on_scattered() {
        let mut rng = Rng::new(61);
        let blocky =
            Banded { n: 1000, per_row: 48, band: 40, contiguous_frac: 1.0 }.generate(&mut rng);
        let scattered = Uniform { n: 2000, per_row: 6, jitter: 3 }.generate(&mut rng);
        let r = Router::default();
        let (h_b, b_b) = r.sampled_engine_estimate(&blocky, &blocky);
        assert!(h_b > 0.0 && b_b > 0.0 && h_b.is_finite() && b_b.is_finite());
        assert!(b_b < h_b, "blocky: block estimate must win ({b_b:.0} vs {h_b:.0} ns)");
        let (h_s, b_s) = r.sampled_engine_estimate(&scattered, &scattered);
        assert!(h_s < b_s, "scattered: hash estimate must win ({h_s:.0} vs {b_s:.0} ns)");
    }

    #[test]
    fn cold_auto_dispatch_seeds_priors_and_routes_by_the_estimate() {
        let mut rng = Rng::new(62);
        let blocky =
            Banded { n: 1000, per_row: 48, band: 40, contiguous_frac: 1.0 }.generate(&mut rng);
        let scattered = Uniform { n: 2000, per_row: 6, jitter: 3 }.generate(&mut rng);
        let history = Arc::new(Mutex::new(ExecHistory::new(16)));
        let r = Router::new(RouterConfig {
            engine_mode: EngineMode::Auto,
            dispatch_history: Some(Arc::clone(&history)),
            ..Default::default()
        });
        assert_eq!(r.route(&blocky, &blocky), Route::Block);
        assert_eq!(r.route(&scattered, &scattered), Route::Hash);
        let key = (blocky.pattern_fingerprint(), blocky.pattern_fingerprint());
        let h = history.lock().unwrap();
        let s = h.lookup(key).expect("cold dispatch must seed the pattern");
        assert_eq!(s.runs, 0, "a seed is not a run");
        assert!(s.hash.ewma_ns > 0.0 && s.block.ewma_ns > 0.0, "both priors seeded");
        assert!(s.block.ewma_ns < s.hash.ewma_ns);
    }

    #[test]
    fn warm_auto_dispatch_routes_on_measurements_not_structure() {
        use crate::coordinator::feedback::{EngineStats, PatternStats};
        // a blocky matrix whose *measured* history says hash is faster:
        // measurements must outvote the structural estimate
        let mut rng = Rng::new(63);
        let blocky =
            Banded { n: 1000, per_row: 48, band: 40, contiguous_frac: 1.0 }.generate(&mut rng);
        let key = (blocky.pattern_fingerprint(), blocky.pattern_fingerprint());
        let history = Arc::new(Mutex::new(ExecHistory::new(16)));
        history.lock().unwrap().insert_stats(
            key,
            PatternStats {
                hash: EngineStats { runs: 4, ewma_ns: 10_000.0 },
                block: EngineStats { runs: 1, ewma_ns: 80_000.0 },
                ..Default::default()
            },
        );
        let r = Router::new(RouterConfig {
            engine_mode: EngineMode::Auto,
            dispatch_history: Some(Arc::clone(&history)),
            ..Default::default()
        });
        assert_eq!(r.route(&blocky, &blocky), Route::Hash);
        // flip the measurements: block wins the same structure
        history.lock().unwrap().insert_stats(
            key,
            PatternStats {
                hash: EngineStats { runs: 4, ewma_ns: 80_000.0 },
                block: EngineStats { runs: 6, ewma_ns: 10_000.0 },
                ..Default::default()
            },
        );
        assert_eq!(r.route(&blocky, &blocky), Route::Block);
    }

    #[test]
    fn dispatch_hysteresis_keeps_the_incumbent_inside_the_band() {
        use crate::coordinator::feedback::{EngineStats, PatternStats};
        // block is the incumbent (more runs); hash is faster but within
        // the band: no switch
        let inside = PatternStats {
            hash: EngineStats { runs: 1, ewma_ns: 999.0 },
            block: EngineStats { runs: 8, ewma_ns: 1000.0 },
            ..Default::default()
        };
        assert_eq!(choose_engine(&inside), Engine::Block, "sub-band win must not flap");
        // beyond the band the challenger takes over
        let outside = PatternStats {
            hash: EngineStats { runs: 1, ewma_ns: 900.0 },
            block: EngineStats { runs: 8, ewma_ns: 1000.0 },
            ..Default::default()
        };
        assert_eq!(choose_engine(&outside), Engine::Hash);
        // run-count ties are conservative: hash is the incumbent
        let tie = PatternStats {
            hash: EngineStats { runs: 2, ewma_ns: 1000.0 },
            block: EngineStats { runs: 2, ewma_ns: 998.0 },
            ..Default::default()
        };
        assert_eq!(choose_engine(&tie), Engine::Hash);
        // one-sided stats pick the only measured engine
        let only_block = PatternStats {
            block: EngineStats { runs: 1, ewma_ns: 500.0 },
            ..Default::default()
        };
        assert_eq!(choose_engine(&only_block), Engine::Block);
        assert_eq!(choose_engine(&PatternStats::default()), Engine::Hash);
    }

    #[test]
    fn warm_dispatched_pattern_is_costed_with_its_tuned_chunk_size() {
        use crate::coordinator::feedback::PatternStats;
        // the serve-path half of the chunk-tuning loop: a pattern whose
        // history holds a tuned broadcast chunk size must have its
        // sharded-route cost model (which shard_count routes through
        // overlap_for) consult that size, not the fleet default
        let mut rng = Rng::new(64);
        let a = Uniform { n: 1000, per_row: 8, jitter: 4 }.generate(&mut rng);
        let key = (a.pattern_fingerprint(), a.pattern_fingerprint());
        let history = Arc::new(Mutex::new(ExecHistory::new(16)));
        let r = Router::new(RouterConfig {
            engine_mode: EngineMode::Auto,
            dispatch_history: Some(Arc::clone(&history)),
            ..Default::default()
        });
        let default_chunk = OverlapConfig::default().chunk_bytes;
        assert_eq!(
            r.overlap_for(&a, &a).chunk_bytes,
            default_chunk,
            "cold pattern: the static chunk size"
        );
        history.lock().unwrap().insert_stats(
            key,
            PatternStats { chunk_bytes: Some(256 * 1024), ..Default::default() },
        );
        assert_eq!(
            r.overlap_for(&a, &a).chunk_bytes,
            256 * 1024,
            "warm pattern: the tuned size is consulted"
        );
        // other patterns keep the default; overlap-off ignores tuning;
        // and without a dispatch store (non-Auto modes) nothing changes
        let mut rng2 = Rng::new(65);
        let other = Uniform { n: 900, per_row: 8, jitter: 4 }.generate(&mut rng2);
        assert_eq!(r.overlap_for(&other, &other).chunk_bytes, default_chunk);
        let off = Router::new(RouterConfig {
            engine_mode: EngineMode::Auto,
            dispatch_history: Some(Arc::clone(&history)),
            overlap: crate::gpusim::OverlapConfig::off(),
            ..Default::default()
        });
        assert!(!off.overlap_for(&a, &a).enabled);
        assert_eq!(off.overlap_for(&a, &a).chunk_bytes, OverlapConfig::off().chunk_bytes);
        let plain = Router::default();
        assert_eq!(plain.overlap_for(&a, &a), plain.cfg.overlap);
    }

    #[test]
    fn overlapped_router_still_declines_transfer_dominated_jobs() {
        // the decline guard survives the overlap model: a tiny job's
        // compute cannot hide a per-hop 5us latency regardless of
        // chunking, so replication still eats the win
        let mut rng = Rng::new(54);
        let a = Uniform { n: 300, per_row: 6, jitter: 3 }.generate(&mut rng);
        let est = working_set_bytes(&a, &a);
        let r = Router::new(RouterConfig { device_memory_bytes: est - 1, ..Default::default() });
        assert!(r.cfg.overlap.enabled, "default routing must be overlap-aware");
        assert_eq!(r.shard_count(&a, &a), None);
        assert_eq!(r.route(&a, &a), Route::Hash);
    }
}
