//! Pattern-keyed execution history: the bounded store the adaptive
//! planning loops read from and write to.
//!
//! Entries are keyed like the symbolic-reuse cache — both operands'
//! [`crate::sparse::Csr::pattern_fingerprint`] — because every quantity
//! recorded here is a function of the sparsity patterns and the device
//! model, not the values: per-shard device times, intermediate-product
//! counts, chunk-arrival stalls. Eviction is insertion-order (FIFO),
//! matching [`crate::coordinator::cache::PatternCache`]: the workloads
//! that benefit (AMG re-setup, MCL expansion) loop over a handful of
//! patterns.

use super::replan::{tune_chunk_bytes, ChunkFeedback};
use crate::coordinator::cache::PatternKey;
use crate::spgemm::sharded::{MeasuredShard, ShardPlan};
use std::collections::{HashMap, VecDeque};

/// Decay of the exponentially-weighted wall-time average: new runs get
/// this weight. High enough to track drift (a changed fleet), low
/// enough that one noisy run does not whipsaw the plan.
const WALL_EWMA_ALPHA: f64 = 0.3;

/// Which execution engine produced a run: the two-phase hash pipeline
/// or the BSR block engine. Observations are tagged so the router can
/// compare *measured* per-engine timings for a warm pattern instead of
/// re-deriving the choice from the structural fill heuristic.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Engine {
    #[default]
    Hash,
    Block,
}

impl Engine {
    /// Stable lowercase label (persistence lines, metrics, JSON).
    pub fn label(self) -> &'static str {
        match self {
            Engine::Hash => "hash",
            Engine::Block => "block",
        }
    }

    /// Inverse of [`Engine::label`]; `None` for anything else.
    pub fn parse(s: &str) -> Option<Engine> {
        match s.to_ascii_lowercase().as_str() {
            "hash" => Some(Engine::Hash),
            "block" => Some(Engine::Block),
            _ => None,
        }
    }

    pub fn other(self) -> Engine {
        match self {
            Engine::Hash => Engine::Block,
            Engine::Block => Engine::Hash,
        }
    }
}

/// Measured timing summary of one engine on one pattern. The ns domain
/// is the **simulated device timeline** (the same clock the router's
/// cost model predicts in), so hash and block figures are directly
/// comparable — never host wall clock, which would fold in queue wait.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EngineStats {
    /// Runs of this engine recorded for the pattern.
    pub runs: u64,
    /// Exponentially-weighted simulated execution time (ns); 0 until
    /// the first observation carrying an `engine_ns` lands.
    pub ewma_ns: f64,
}

impl EngineStats {
    /// Whether this engine has a usable measurement.
    pub fn warm(&self) -> bool {
        self.runs > 0 && self.ewma_ns > 0.0 && self.ewma_ns.is_finite()
    }

    fn fold(&mut self, ns: f64) {
        self.runs += 1;
        if ns > 0.0 && ns.is_finite() {
            self.ewma_ns = if self.ewma_ns > 0.0 {
                (1.0 - WALL_EWMA_ALPHA) * self.ewma_ns + WALL_EWMA_ALPHA * ns
            } else {
                ns
            };
        }
    }

    /// Seed a prior measurement (cold-estimate seeding): only applies
    /// when nothing real has been recorded yet, so one real run always
    /// outweighs the estimate's influence beyond the EWMA fold.
    pub fn seed(&mut self, ns: f64) {
        if self.runs == 0 && self.ewma_ns == 0.0 && ns > 0.0 && ns.is_finite() {
            self.ewma_ns = ns;
        }
    }
}

/// Everything the history remembers about one pattern pair.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PatternStats {
    /// Per-shard measured timings of the most recent run — what
    /// [`ShardPlan::from_history`] re-cuts from. The shard count of the
    /// *next* run need not match: the re-cut reconstructs per-row costs
    /// and cuts them into whatever count the router asks for.
    pub measured: Vec<MeasuredShard>,
    /// Runs recorded for this pattern.
    pub runs: u64,
    /// Exponentially-weighted end-to-end time of this pattern's runs
    /// (ns), **in the recorder's clock domain**: host wall clock on the
    /// coordinator path (queue wait included), simulated makespan on
    /// the context path. Diagnostic/forecasting state for future
    /// consumers (admission control, capacity-weighted planning) — the
    /// three current loops plan from `measured` and the chunk feedback,
    /// never from this field, so the domains must not be mixed by
    /// whatever reads it next.
    pub ewma_wall_ns: f64,
    /// Intermediate products of the last run (same diagnostic role).
    pub last_nprod: u64,
    /// Tuned broadcast chunk size, once overlap feedback has been
    /// observed ([`tune_chunk_bytes`]); `None` until then.
    pub chunk_bytes: Option<usize>,
    /// Measured hash-pipeline timings (simulated-ns domain) — what the
    /// dispatcher compares against `block`.
    pub hash: EngineStats,
    /// Measured block-engine timings (simulated-ns domain).
    pub block: EngineStats,
}

impl PatternStats {
    pub fn engine(&self, e: Engine) -> &EngineStats {
        match e {
            Engine::Hash => &self.hash,
            Engine::Block => &self.block,
        }
    }

    pub fn engine_mut(&mut self, e: Engine) -> &mut EngineStats {
        match e {
            Engine::Hash => &mut self.hash,
            Engine::Block => &mut self.block,
        }
    }
}

/// One run's worth of observations, recorded after the run completes.
#[derive(Clone, Debug, Default)]
pub struct RunObservation {
    /// Per-shard measured timings (row range + ns), in shard order.
    pub shards: Vec<MeasuredShard>,
    /// End-to-end wall time of the run (ns); 0 when unknown.
    pub wall_ns: f64,
    /// Total intermediate products of the run.
    pub nprod: u64,
    /// Overlap feedback (chunk-arrival stalls), when the run was
    /// simulated under the pipelined schedule.
    pub chunk: Option<ChunkFeedback>,
    /// Engine that executed the run ([`Engine::Hash`] by default, so
    /// every pre-existing recording site stays hash-tagged).
    pub engine: Engine,
    /// Simulated execution time of the run on that engine (ns); 0 when
    /// no simulated figure is available (the per-engine EWMA then skips
    /// this run — `wall_ns` stays host-clock diagnostic state).
    pub engine_ns: f64,
}

impl RunObservation {
    /// Build an observation from a plan and the per-device measured
    /// times it produced (e.g. `MultiDevice::device_total_ns`). Extra
    /// entries on either side are ignored — the observation covers the
    /// shards both describe.
    pub fn from_device_ns(
        plan: &ShardPlan,
        device_ns: &[f64],
        wall_ns: f64,
        nprod: u64,
    ) -> RunObservation {
        let shards = (0..plan.n_shards().min(device_ns.len()))
            .map(|s| {
                let (lo, hi) = plan.range(s);
                MeasuredShard { lo, hi, ns: device_ns[s] }
            })
            .collect();
        RunObservation { shards, wall_ns, nprod, ..Default::default() }
    }
}

/// Bounded, pattern-fingerprint-keyed store of [`PatternStats`].
#[derive(Debug)]
pub struct ExecHistory {
    map: HashMap<PatternKey, PatternStats>,
    order: VecDeque<PatternKey>,
    capacity: usize,
    evictions: u64,
}

impl ExecHistory {
    /// `capacity` of 0 disables the history (records are dropped).
    pub fn new(capacity: usize) -> Self {
        ExecHistory { map: HashMap::new(), order: VecDeque::new(), capacity, evictions: 0 }
    }

    /// Fold one run's observations into the pattern's stats, evicting
    /// the oldest pattern beyond capacity.
    pub fn record(&mut self, key: PatternKey, obs: RunObservation) {
        if self.capacity == 0 {
            return;
        }
        if !self.map.contains_key(&key) {
            self.map.insert(key, PatternStats::default());
            self.order.push_back(key);
            while self.order.len() > self.capacity {
                if let Some(old) = self.order.pop_front() {
                    self.map.remove(&old);
                    self.evictions += 1;
                }
            }
        }
        // the entry can only be absent if this key was the one just
        // evicted, which cannot happen: it was pushed last
        let Some(stats) = self.map.get_mut(&key) else { return };
        stats.runs += 1;
        if !obs.shards.is_empty() {
            stats.measured = obs.shards;
        }
        if obs.wall_ns > 0.0 && obs.wall_ns.is_finite() {
            stats.ewma_wall_ns = if stats.ewma_wall_ns > 0.0 {
                (1.0 - WALL_EWMA_ALPHA) * stats.ewma_wall_ns + WALL_EWMA_ALPHA * obs.wall_ns
            } else {
                obs.wall_ns
            };
        }
        if obs.nprod > 0 {
            stats.last_nprod = obs.nprod;
        }
        if let Some(fb) = obs.chunk {
            stats.chunk_bytes = Some(tune_chunk_bytes(&fb));
        }
        stats.engine_mut(obs.engine).fold(obs.engine_ns);
    }

    /// Seed a cold pattern's per-engine priors from an upfront estimate
    /// (the Ocean-style sampled estimator). Creates the entry if absent
    /// but records no run; real measurements fold on top via the EWMA,
    /// and a seed never overwrites an existing measurement.
    pub fn seed_engine_priors(&mut self, key: PatternKey, hash_ns: f64, block_ns: f64) {
        if self.capacity == 0 {
            return;
        }
        if !self.map.contains_key(&key) {
            self.map.insert(key, PatternStats::default());
            self.order.push_back(key);
            while self.order.len() > self.capacity {
                if let Some(old) = self.order.pop_front() {
                    self.map.remove(&old);
                    self.evictions += 1;
                }
            }
        }
        let Some(stats) = self.map.get_mut(&key) else { return };
        stats.hash.seed(hash_ns);
        stats.block.seed(block_ns);
    }

    /// The stats recorded for a pattern, if it is warm.
    pub fn lookup(&self, key: PatternKey) -> Option<&PatternStats> {
        self.map.get(&key)
    }

    /// Patterns currently held.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Patterns evicted since construction.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Iterate the held patterns oldest-first (insertion order — the
    /// order FIFO eviction consumes). Persistence walks this so a saved
    /// file restored through [`ExecHistory::insert_stats`] reproduces
    /// both the contents and the eviction order.
    pub fn iter_in_order(&self) -> impl Iterator<Item = (&PatternKey, &PatternStats)> {
        self.order.iter().filter_map(move |k| self.map.get(k).map(|s| (k, s)))
    }

    /// Install a fully-formed stats record, bypassing the per-run fold
    /// of [`ExecHistory::record`] — the persistence-reload path, where
    /// the stats were already folded before they were saved. A new key
    /// takes the next insertion-order slot (evicting beyond capacity,
    /// e.g. when a file saved under a larger cap is loaded into a
    /// smaller one); an existing key keeps its slot and is overwritten.
    pub fn insert_stats(&mut self, key: PatternKey, stats: PatternStats) {
        if self.capacity == 0 {
            return;
        }
        if !self.map.contains_key(&key) {
            self.order.push_back(key);
            while self.order.len() > self.capacity {
                if let Some(old) = self.order.pop_front() {
                    self.map.remove(&old);
                    self.evictions += 1;
                }
            }
        }
        self.map.insert(key, stats);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(n: usize, ns: f64) -> RunObservation {
        RunObservation {
            shards: vec![MeasuredShard { lo: 0, hi: n, ns }],
            wall_ns: ns,
            nprod: 10,
            engine_ns: ns,
            ..Default::default()
        }
    }

    #[test]
    fn record_then_lookup() {
        let mut h = ExecHistory::new(4);
        assert!(h.lookup((1, 2)).is_none());
        h.record((1, 2), obs(8, 500.0));
        let s = h.lookup((1, 2)).expect("warm");
        assert_eq!(s.runs, 1);
        assert_eq!(s.measured, vec![MeasuredShard { lo: 0, hi: 8, ns: 500.0 }]);
        assert_eq!(s.ewma_wall_ns, 500.0);
        assert_eq!(s.last_nprod, 10);
    }

    #[test]
    fn ewma_tracks_and_latest_measurement_wins() {
        let mut h = ExecHistory::new(4);
        h.record((1, 1), obs(8, 1000.0));
        h.record((1, 1), obs(8, 2000.0));
        let s = h.lookup((1, 1)).unwrap();
        assert_eq!(s.runs, 2);
        assert!((s.ewma_wall_ns - (0.7 * 1000.0 + 0.3 * 2000.0)).abs() < 1e-9);
        assert_eq!(s.measured[0].ns, 2000.0, "measured shards are the latest run's");
    }

    #[test]
    fn fifo_eviction_is_bounded_and_counted() {
        let mut h = ExecHistory::new(2);
        h.record((1, 1), obs(4, 1.0));
        h.record((2, 2), obs(4, 1.0));
        h.record((3, 3), obs(4, 1.0));
        assert_eq!(h.len(), 2);
        assert_eq!(h.evictions(), 1);
        assert!(h.lookup((1, 1)).is_none(), "oldest pattern evicted");
        assert!(h.lookup((2, 2)).is_some());
        assert!(h.lookup((3, 3)).is_some());
        // re-recording a live key must not evict anything
        h.record((3, 3), obs(4, 2.0));
        assert_eq!(h.len(), 2);
        assert_eq!(h.evictions(), 1);
    }

    #[test]
    fn in_order_iteration_and_reinsertion_reproduce_the_store() {
        let mut h = ExecHistory::new(4);
        h.record((3, 3), obs(4, 30.0));
        h.record((1, 1), obs(4, 10.0));
        h.record((2, 2), obs(4, 20.0));
        let keys: Vec<PatternKey> = h.iter_in_order().map(|(k, _)| *k).collect();
        assert_eq!(keys, vec![(3, 3), (1, 1), (2, 2)], "oldest-first insertion order");
        // rebuild through insert_stats: contents and eviction order match
        let mut r = ExecHistory::new(4);
        for (k, s) in h.iter_in_order() {
            r.insert_stats(*k, s.clone());
        }
        assert_eq!(r.len(), 3);
        for (k, s) in h.iter_in_order() {
            assert_eq!(r.lookup(*k), Some(s), "{k:?}");
        }
        // FIFO order carried over: the next eviction hits (3,3) first
        r.record((9, 9), obs(4, 1.0));
        r.record((8, 8), obs(4, 1.0));
        assert!(r.lookup((3, 3)).is_none(), "oldest restored key evicts first");
        assert!(r.lookup((1, 1)).is_some());
    }

    #[test]
    fn insert_stats_respects_capacity_and_overwrites_in_place() {
        let mut h = ExecHistory::new(2);
        h.insert_stats((1, 1), PatternStats { runs: 1, ..Default::default() });
        h.insert_stats((2, 2), PatternStats { runs: 2, ..Default::default() });
        h.insert_stats((3, 3), PatternStats { runs: 3, ..Default::default() });
        assert_eq!(h.len(), 2);
        assert_eq!(h.evictions(), 1);
        assert!(h.lookup((1, 1)).is_none(), "loading beyond capacity evicts oldest");
        // overwriting a live key keeps its slot and does not evict
        h.insert_stats((2, 2), PatternStats { runs: 20, ..Default::default() });
        assert_eq!(h.len(), 2);
        assert_eq!(h.evictions(), 1);
        assert_eq!(h.lookup((2, 2)).unwrap().runs, 20);
        // capacity 0 stays disabled
        let mut off = ExecHistory::new(0);
        off.insert_stats((1, 1), PatternStats::default());
        assert!(off.is_empty());
    }

    #[test]
    fn engine_tagged_observations_fold_per_engine() {
        let mut h = ExecHistory::new(4);
        h.record((1, 2), obs(8, 1000.0)); // hash by default
        h.record((1, 2), RunObservation { engine: Engine::Block, engine_ns: 400.0, ..obs(8, 400.0) });
        h.record((1, 2), RunObservation { engine: Engine::Block, engine_ns: 200.0, ..obs(8, 200.0) });
        let s = h.lookup((1, 2)).unwrap();
        assert_eq!(s.runs, 3, "total run count spans engines");
        assert_eq!(s.hash.runs, 1);
        assert_eq!(s.hash.ewma_ns, 1000.0);
        assert_eq!(s.block.runs, 2);
        assert!((s.block.ewma_ns - (0.7 * 400.0 + 0.3 * 200.0)).abs() < 1e-9);
        assert!(s.hash.warm() && s.block.warm());
    }

    #[test]
    fn zero_engine_ns_counts_the_run_but_skips_the_ewma() {
        let mut h = ExecHistory::new(4);
        h.record((1, 1), RunObservation { engine_ns: 0.0, ..obs(8, 500.0) });
        let s = h.lookup((1, 1)).unwrap();
        assert_eq!(s.hash.runs, 1);
        assert_eq!(s.hash.ewma_ns, 0.0);
        assert!(!s.hash.warm(), "no usable measurement yet");
    }

    #[test]
    fn seeded_priors_yield_to_real_measurements() {
        let mut h = ExecHistory::new(4);
        h.seed_engine_priors((5, 5), 900.0, 300.0);
        let s = h.lookup((5, 5)).unwrap();
        assert_eq!(s.runs, 0, "a seed is not a run");
        assert_eq!(s.hash.ewma_ns, 900.0);
        assert_eq!(s.block.ewma_ns, 300.0);
        assert!(!s.hash.warm(), "seeds alone are not warm");
        // a real run folds on top of the seed via the EWMA
        h.record((5, 5), RunObservation { engine: Engine::Block, engine_ns: 500.0, ..obs(8, 500.0) });
        let s = h.lookup((5, 5)).unwrap();
        assert!((s.block.ewma_ns - (0.7 * 300.0 + 0.3 * 500.0)).abs() < 1e-9);
        assert!(s.block.warm());
        // re-seeding a measured pattern is a no-op
        h.seed_engine_priors((5, 5), 1.0, 1.0);
        let s = h.lookup((5, 5)).unwrap();
        assert_eq!(s.hash.ewma_ns, 900.0);
        assert!(s.block.ewma_ns > 1.0);
    }

    #[test]
    fn engine_labels_round_trip() {
        for e in [Engine::Hash, Engine::Block] {
            assert_eq!(Engine::parse(e.label()), Some(e));
            assert_eq!(e.other().other(), e);
        }
        assert_eq!(Engine::parse("cuda"), None);
        assert_eq!(Engine::default(), Engine::Hash);
    }

    #[test]
    fn zero_capacity_disables() {
        let mut h = ExecHistory::new(0);
        h.record((1, 1), obs(4, 1.0));
        assert!(h.is_empty());
        assert!(h.lookup((1, 1)).is_none());
    }

    #[test]
    fn observation_from_device_ns_follows_the_plan() {
        let plan = ShardPlan::balanced(&[1, 1, 1, 1, 1, 1], 3);
        let o = RunObservation::from_device_ns(&plan, &[10.0, 20.0, 30.0], 60.0, 6);
        assert_eq!(o.shards.len(), 3);
        for (s, m) in o.shards.iter().enumerate() {
            assert_eq!((m.lo, m.hi), plan.range(s));
        }
        assert_eq!(o.shards[2].ns, 30.0);
        // a short device list truncates instead of panicking
        let short = RunObservation::from_device_ns(&plan, &[10.0], 10.0, 6);
        assert_eq!(short.shards.len(), 1);
    }
}
