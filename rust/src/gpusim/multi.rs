//! Multi-device view: aggregate per-device timelines into makespan and
//! scaling figures, charging inter-device transfers against an
//! [`Interconnect`] model.
//!
//! A sharded SpGEMM run produces one [`Trace`] per simulated device (see
//! [`crate::spgemm::sharded`]). The devices execute concurrently — each
//! has its own host thread, streams, and SMs — so the compute figure is
//! the **makespan**: the critical path, i.e. the slowest device's wall
//! time. Row sharding additionally replicates `B` on every device (a
//! one-to-all broadcast before compute) and gathers the `C` row blocks
//! back to the root device afterwards; both ride the interconnect, not
//! HBM, and on small jobs they dominate — this is exactly where
//! bhSPARSE-style heterogeneous frameworks report communication-bound
//! scaling. [`MultiDevice::simulate_with_interconnect`] charges both
//! phases, so efficiency figures stop over-reporting for small jobs;
//! [`MultiDevice::simulate`] keeps the transfer-free view (both costs 0).

use super::device::DeviceParams;
use super::scheduler::simulate;
use super::timeline::Timeline;
use super::trace::Trace;
use anyhow::{ensure, Result};

/// Fan-out pattern of the inter-device links.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    /// The root device pushes a full copy to every peer through its own
    /// link, one peer at a time (PCIe devices under one host bridge):
    /// broadcast cost grows linearly with the device count.
    OneToAll,
    /// Devices form a ring and broadcasts pipeline chunks around it
    /// (NVLink-style): the bandwidth term flattens out as the fleet
    /// grows, so a ring beats one-to-all at high device counts.
    Ring,
}

/// Inter-device interconnect: per-link bandwidth, per-message latency,
/// and topology. `bandwidth_gbps` is in GB/s, which conveniently equals
/// bytes/ns.
///
/// # Example
///
/// ```
/// use opsparse::gpusim::{Interconnect, Topology};
///
/// let pcie = Interconnect::pcie3();
/// let one_to_all = pcie.broadcast_ns(1 << 20, 8).unwrap();
/// let ring =
///     Interconnect { topology: Topology::Ring, ..pcie }.broadcast_ns(1 << 20, 8).unwrap();
/// assert!(ring < one_to_all, "pipelined ring wins at high device counts");
/// ```
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Interconnect {
    /// Per-link bandwidth in GB/s (== bytes/ns). Must be positive.
    pub bandwidth_gbps: f64,
    /// Per-message latency in microseconds.
    pub latency_us: f64,
    pub topology: Topology,
}

impl Interconnect {
    /// PCIe 3.0 x16 under one host bridge: ~12 GB/s effective, one
    /// transfer at a time through the root's link.
    pub const fn pcie3() -> Interconnect {
        Interconnect { bandwidth_gbps: 12.0, latency_us: 5.0, topology: Topology::OneToAll }
    }

    /// NVLink ring (V100 DGX-style): ~150 GB/s per direction, pipelined
    /// ring collectives.
    pub const fn nvlink() -> Interconnect {
        Interconnect { bandwidth_gbps: 150.0, latency_us: 1.5, topology: Topology::Ring }
    }

    /// Parse a preset name (`pcie` | `nvlink`), for CLI/env flags.
    pub fn parse(s: &str) -> Option<Interconnect> {
        match s {
            "pcie" | "pcie3" => Some(Interconnect::pcie3()),
            "nvlink" => Some(Interconnect::nvlink()),
            _ => None,
        }
    }

    /// [`Interconnect::parse`] plus the `none` sentinel (no interconnect
    /// charged): `Some(None)` for `"none"`, `Some(Some(_))` for a known
    /// preset, `None` for anything else. The one parser shared by the
    /// `bench shards` CLI flag and the `OPSPARSE_INTERCONNECT` env var,
    /// so both accept exactly the same names.
    pub fn parse_opt(s: &str) -> Option<Option<Interconnect>> {
        if s == "none" {
            Some(None)
        } else {
            Interconnect::parse(s).map(Some)
        }
    }

    fn check(&self) -> Result<()> {
        ensure!(
            self.bandwidth_gbps.is_finite() && self.bandwidth_gbps > 0.0,
            "interconnect bandwidth must be positive and finite, got {} GB/s",
            self.bandwidth_gbps
        );
        ensure!(
            self.latency_us.is_finite() && self.latency_us >= 0.0,
            "interconnect latency must be non-negative, got {} us",
            self.latency_us
        );
        Ok(())
    }

    fn latency_ns(&self) -> f64 {
        self.latency_us * 1e3
    }

    /// Time to replicate `bytes` from the root onto the other
    /// `n_devices - 1` devices. Zero for a single device. Errors on a
    /// non-positive bandwidth instead of dividing by zero.
    pub fn broadcast_ns(&self, bytes: usize, n_devices: usize) -> Result<f64> {
        self.check()?;
        if n_devices <= 1 {
            return Ok(0.0);
        }
        let hops = (n_devices - 1) as f64;
        let xfer = bytes as f64 / self.bandwidth_gbps;
        Ok(match self.topology {
            Topology::OneToAll => hops * (self.latency_ns() + xfer),
            // pipelined ring (scatter + forward): the bandwidth term
            // approaches 2x one link's transfer time as the ring grows
            Topology::Ring => hops * self.latency_ns() + xfer * 2.0 * hops / n_devices as f64,
        })
    }

    /// Time to gather per-device result blocks onto the root device
    /// (`block_bytes[0]` is the root's own block and moves nothing).
    /// Zero for a single device; errors on a non-positive bandwidth.
    pub fn gather_ns(&self, block_bytes: &[usize]) -> Result<f64> {
        self.check()?;
        if block_bytes.len() <= 1 {
            return Ok(0.0);
        }
        let hops = (block_bytes.len() - 1) as f64;
        let nonroot: f64 = block_bytes[1..].iter().map(|&b| b as f64).sum();
        // same cost on both topologies: whether blocks serialize through
        // the root's link directly (one-to-all) or forward around the
        // ring, the link into the root carries every non-root byte
        Ok(hops * self.latency_ns() + nonroot / self.bandwidth_gbps)
    }
}

/// Per-device simulation results of one multi-device run, plus the
/// modeled interconnect transfers that bracket the compute phase.
#[derive(Clone, Debug, Default)]
pub struct MultiDevice {
    /// One timeline per device, in device order.
    pub timelines: Vec<Timeline>,
    /// Modeled `B` replication cost before compute (0 when simulated
    /// without an interconnect, or with a single device).
    pub broadcast_ns: f64,
    /// Modeled `C` row-block gather cost after compute (0 when simulated
    /// without an interconnect, or with a single device).
    pub gather_ns: f64,
}

impl MultiDevice {
    /// Simulate one trace per device against the same device model, with
    /// free inter-device transfers (the PR 2 view; see
    /// [`MultiDevice::simulate_with_interconnect`] for the honest one).
    pub fn simulate<'a, I>(traces: I, dev: &DeviceParams) -> MultiDevice
    where
        I: IntoIterator<Item = &'a Trace>,
    {
        MultiDevice {
            timelines: traces.into_iter().map(|t| simulate(t, dev)).collect(),
            broadcast_ns: 0.0,
            gather_ns: 0.0,
        }
    }

    /// [`MultiDevice::simulate`], charging the one-to-all/ring `B`
    /// broadcast (`b_bytes` replicated onto every non-root device) and
    /// the `C` row-block gather (`c_block_bytes`, one entry per device)
    /// against `ic`. `c_block_bytes` must have one entry per trace.
    pub fn simulate_with_interconnect<'a, I>(
        traces: I,
        dev: &DeviceParams,
        ic: &Interconnect,
        b_bytes: usize,
        c_block_bytes: &[usize],
    ) -> Result<MultiDevice>
    where
        I: IntoIterator<Item = &'a Trace>,
    {
        let mut md = MultiDevice::simulate(traces, dev);
        ensure!(
            c_block_bytes.len() == md.n_devices(),
            "{} C blocks for {} devices",
            c_block_bytes.len(),
            md.n_devices()
        );
        md.broadcast_ns = ic.broadcast_ns(b_bytes, md.n_devices())?;
        md.gather_ns = ic.gather_ns(c_block_bytes)?;
        Ok(md)
    }

    pub fn n_devices(&self) -> usize {
        self.timelines.len()
    }

    /// Compute critical path: the slowest device's wall time (devices
    /// run concurrently), excluding interconnect transfers.
    pub fn compute_makespan_ns(&self) -> f64 {
        self.timelines.iter().map(|t| t.total_ns).fold(0.0, f64::max)
    }

    /// Modeled interconnect time bracketing the compute phase.
    pub fn comm_ns(&self) -> f64 {
        self.broadcast_ns + self.gather_ns
    }

    /// End-to-end critical path: `B` broadcast, then the slowest device's
    /// compute, then the `C` gather. Equals the compute makespan when no
    /// interconnect was charged.
    pub fn makespan_ns(&self) -> f64 {
        self.comm_ns() + self.compute_makespan_ns()
    }

    /// Per-device wall times in device order.
    pub fn device_total_ns(&self) -> Vec<f64> {
        self.timelines.iter().map(|t| t.total_ns).collect()
    }

    /// Measured compute load imbalance: max device wall time / mean
    /// device wall time (1.0 = perfect; idle devices count toward the
    /// mean). Interconnect time is excluded — it is not imbalance.
    pub fn time_imbalance(&self) -> f64 {
        if self.timelines.is_empty() {
            return 1.0;
        }
        let mean: f64 =
            self.timelines.iter().map(|t| t.total_ns).sum::<f64>() / self.timelines.len() as f64;
        if mean == 0.0 {
            1.0
        } else {
            self.compute_makespan_ns() / mean
        }
    }

    /// Speedup over a single-device wall time (interconnect included).
    pub fn speedup_vs(&self, single_device_ns: f64) -> f64 {
        let m = self.makespan_ns();
        if m <= 0.0 {
            0.0
        } else {
            single_device_ns / m
        }
    }

    /// Scaling efficiency: speedup divided by device count (1.0 = linear).
    pub fn efficiency_vs(&self, single_device_ns: f64) -> f64 {
        if self.timelines.is_empty() {
            return 0.0;
        }
        self.speedup_vs(single_device_ns) / self.timelines.len() as f64
    }

    /// GFLOPS under the makespan (the paper's metric over the fleet).
    pub fn gflops(&self, flops: f64) -> f64 {
        let m = self.makespan_ns();
        if m <= 0.0 {
            0.0
        } else {
            flops / m
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::device::V100;
    use crate::gpusim::trace::{BlockWork, Kernel};

    fn trace_with_blocks(nblocks: usize) -> Trace {
        let mut t = Trace::new();
        t.launch(Kernel {
            name: "k".into(),
            step: "numeric",
            stream: 0,
            tb_size: 256,
            shared_bytes: 0,
            blocks: vec![BlockWork { global_bytes: 100_000, ..Default::default() }; nblocks],
        });
        t
    }

    #[test]
    fn makespan_is_slowest_device() {
        let fast = trace_with_blocks(10);
        let slow = trace_with_blocks(4000);
        let md = MultiDevice::simulate([&fast, &slow], &V100);
        assert_eq!(md.n_devices(), 2);
        let per = md.device_total_ns();
        assert!((md.makespan_ns() - per[1]).abs() < 1e-6);
        assert!(per[1] > per[0]);
        assert!(md.time_imbalance() > 1.0);
        assert_eq!(md.comm_ns(), 0.0, "no interconnect charged by default");
    }

    #[test]
    fn balanced_devices_have_low_imbalance_and_good_efficiency() {
        let traces: Vec<Trace> = (0..4).map(|_| trace_with_blocks(1000)).collect();
        let md = MultiDevice::simulate(traces.iter(), &V100);
        assert!((md.time_imbalance() - 1.0).abs() < 1e-9);
        let single = simulate(&trace_with_blocks(4000), &V100).total_ns;
        let eff = md.efficiency_vs(single);
        assert!(eff > 0.5, "4-way split of a 4x trace should scale: eff={eff}");
    }

    #[test]
    fn empty_fleet_is_degenerate_but_defined() {
        let md = MultiDevice::default();
        assert_eq!(md.makespan_ns(), 0.0);
        assert_eq!(md.time_imbalance(), 1.0);
        assert_eq!(md.efficiency_vs(1.0), 0.0);
    }

    #[test]
    fn one_to_all_broadcast_scales_linearly_in_bytes_and_devices() {
        // zero latency isolates the bandwidth term
        let ic = Interconnect { bandwidth_gbps: 10.0, latency_us: 0.0, topology: Topology::OneToAll };
        let base = ic.broadcast_ns(1 << 20, 2).unwrap();
        assert!(base > 0.0);
        let double_bytes = ic.broadcast_ns(2 << 20, 2).unwrap();
        assert!((double_bytes - 2.0 * base).abs() < 1e-6, "linear in bytes");
        let five_devices = ic.broadcast_ns(1 << 20, 5).unwrap();
        assert!((five_devices - 4.0 * base).abs() < 1e-6, "linear in peer count");
        // latency is charged per hop
        let with_lat =
            Interconnect { latency_us: 5.0, ..ic }.broadcast_ns(1 << 20, 5).unwrap();
        assert!((with_lat - (five_devices + 4.0 * 5_000.0)).abs() < 1e-6);
    }

    #[test]
    fn ring_beats_one_to_all_at_high_device_counts() {
        let one = Interconnect { bandwidth_gbps: 12.0, latency_us: 2.0, topology: Topology::OneToAll };
        let ring = Interconnect { topology: Topology::Ring, ..one };
        let bytes = 64 << 20;
        // a two-device "ring" is the same single link
        let o2 = one.broadcast_ns(bytes, 2).unwrap();
        let r2 = ring.broadcast_ns(bytes, 2).unwrap();
        assert!((o2 - r2).abs() < 1e-6);
        // at 8 devices the pipelined ring amortizes the replication
        let o8 = one.broadcast_ns(bytes, 8).unwrap();
        let r8 = ring.broadcast_ns(bytes, 8).unwrap();
        assert!(r8 < o8 / 2.0, "ring {r8} should clearly beat one-to-all {o8}");
        // and the ring's bandwidth term stays bounded as the fleet grows
        let r64 = ring.broadcast_ns(bytes, 64).unwrap();
        let xfer = bytes as f64 / 12.0;
        assert!(r64 - 63.0 * 2_000.0 < 2.0 * xfer + 1e-6);
    }

    #[test]
    fn zero_bandwidth_is_an_error_not_a_division() {
        let dead = Interconnect { bandwidth_gbps: 0.0, latency_us: 1.0, topology: Topology::OneToAll };
        assert!(dead.broadcast_ns(1024, 4).is_err());
        assert!(dead.gather_ns(&[10, 10]).is_err());
        let neg = Interconnect { bandwidth_gbps: -3.0, ..dead };
        assert!(neg.broadcast_ns(1024, 4).is_err());
    }

    #[test]
    fn single_device_pays_no_interconnect() {
        let ic = Interconnect::pcie3();
        assert_eq!(ic.broadcast_ns(1 << 30, 1).unwrap(), 0.0);
        assert_eq!(ic.gather_ns(&[1 << 30]).unwrap(), 0.0);
    }

    #[test]
    fn gather_counts_only_non_root_blocks() {
        let ic = Interconnect { bandwidth_gbps: 1.0, latency_us: 0.0, topology: Topology::OneToAll };
        // root block (index 0) never moves
        let g = ic.gather_ns(&[1_000_000, 100, 200]).unwrap();
        assert!((g - 300.0).abs() < 1e-9, "got {g}");
    }

    #[test]
    fn interconnect_charges_show_up_in_makespan() {
        let traces: Vec<Trace> = (0..4).map(|_| trace_with_blocks(100)).collect();
        let free = MultiDevice::simulate(traces.iter(), &V100);
        let ic = Interconnect::pcie3();
        let charged = MultiDevice::simulate_with_interconnect(
            traces.iter(),
            &V100,
            &ic,
            8 << 20,
            &[1 << 20; 4],
        )
        .unwrap();
        assert!(charged.broadcast_ns > 0.0);
        assert!(charged.gather_ns > 0.0);
        assert!(
            charged.makespan_ns() > free.makespan_ns(),
            "transfers must lengthen the critical path"
        );
        assert_eq!(charged.compute_makespan_ns(), free.compute_makespan_ns());
        // block-count mismatch is an error
        assert!(MultiDevice::simulate_with_interconnect(
            traces.iter(),
            &V100,
            &ic,
            8 << 20,
            &[1 << 20; 3],
        )
        .is_err());
    }
}
