//! Matrix statistics driving the paper's evaluation: per-row intermediate
//! product counts (`n_prod`), total FLOPs, and the compression ratio of
//! `A·B` (paper §2.1.2, Table 3 columns).

use super::csr::Csr;

/// Per-row intermediate-product counts for `C = A * B`:
/// `nprod[i] = sum over k in A(i,:) of nnz(B(k,:))`.
///
/// This is the *upper bound* row size used by the symbolic binning step
/// (paper Fig. 2 "setup: compute n_prod"), computed without touching values.
pub fn nprod_per_row(a: &Csr, b: &Csr) -> Vec<usize> {
    assert_eq!(a.cols, b.rows, "inner dimension mismatch");
    let mut out = vec![0usize; a.rows];
    for i in 0..a.rows {
        let mut acc = 0usize;
        for &k in a.row_cols(i) {
            acc += b.row_nnz(k as usize);
        }
        out[i] = acc;
    }
    out
}

/// Total intermediate products (`n_prod` of Table 3). A fold over `A`'s
/// stored entries — no per-row vector is materialized, so this is safe on
/// hot paths like the coordinator's submit-side routing.
pub fn total_nprod(a: &Csr, b: &Csr) -> usize {
    assert_eq!(a.cols, b.rows, "inner dimension mismatch");
    a.col.iter().map(|&k| b.row_nnz(k as usize)).sum()
}

/// FLOP count of the multiply: the paper's GFLOPS metric is
/// `2 * n_prod / time` (§6, "twice the number of the intermediate products").
pub fn flops(a: &Csr, b: &Csr) -> f64 {
    2.0 * total_nprod(a, b) as f64
}

/// Compression ratio (paper Eq. 3): total n_prod / nnz(C).
pub fn compression_ratio(nprod_total: usize, c_nnz: usize) -> f64 {
    if c_nnz == 0 {
        return 0.0;
    }
    nprod_total as f64 / c_nnz as f64
}

/// Summary statistics of one matrix — the columns of Table 3.
#[derive(Clone, Debug)]
pub struct MatrixStats {
    pub rows: usize,
    pub cols: usize,
    pub nnz: usize,
    pub avg_row_nnz: f64,
    pub max_row_nnz: usize,
}

impl MatrixStats {
    pub fn of(m: &Csr) -> Self {
        MatrixStats {
            rows: m.rows,
            cols: m.cols,
            nnz: m.nnz(),
            avg_row_nnz: if m.rows == 0 { 0.0 } else { m.nnz() as f64 / m.rows as f64 },
            max_row_nnz: m.max_row_nnz(),
        }
    }
}

/// Row-size histogram over power-of-two buckets — used to sanity-check the
/// synthetic suite against the paper's binning ranges.
pub fn row_size_histogram(sizes: &[usize]) -> Vec<(usize, usize)> {
    let mut hist: Vec<(usize, usize)> = Vec::new();
    let mut bound = 1usize;
    loop {
        let count = sizes.iter().filter(|&&s| s < bound && s * 2 >= bound).count();
        // bucket [bound/2, bound)
        if bound == 1 {
            let zeros = sizes.iter().filter(|&&s| s == 0).count();
            hist.push((0, zeros));
        } else {
            hist.push((bound / 2, count));
        }
        if sizes.iter().all(|&s| s < bound) {
            break;
        }
        bound *= 2;
        if bound > (1 << 40) {
            break;
        }
    }
    hist
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> (Csr, Csr) {
        // A = [[1,1,0],[0,0,1]], B = [[1,0],[1,1],[0,1]] (3x2, all ones)
        let a = Csr::from_parts(2, 3, vec![0, 2, 3], vec![0, 1, 2], vec![1.0; 3]).unwrap();
        let b = Csr::from_parts(3, 2, vec![0, 1, 3, 4], vec![0, 0, 1, 1], vec![1.0; 4]).unwrap();
        (a, b)
    }

    #[test]
    fn nprod_counts() {
        let (a, b) = tiny();
        // row0: nnz(B0)+nnz(B1) = 1+2 = 3; row1: nnz(B2) = 1
        assert_eq!(nprod_per_row(&a, &b), vec![3, 1]);
        assert_eq!(total_nprod(&a, &b), 4);
        assert_eq!(flops(&a, &b), 8.0);
    }

    #[test]
    fn cr_math() {
        assert!((compression_ratio(100, 50) - 2.0).abs() < 1e-12);
        assert_eq!(compression_ratio(10, 0), 0.0);
    }

    #[test]
    fn stats_of_identity() {
        let m = Csr::identity(5);
        let s = MatrixStats::of(&m);
        assert_eq!(s.nnz, 5);
        assert_eq!(s.max_row_nnz, 1);
        assert!((s.avg_row_nnz - 1.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_covers_all_rows() {
        let sizes = vec![0, 1, 1, 3, 8, 100];
        let hist = row_size_histogram(&sizes);
        let total: usize = hist.iter().map(|&(_, c)| c).sum();
        assert_eq!(total, sizes.len());
    }
}
