"""AOT bridge: lower the L2 models to HLO **text** for the Rust runtime.

HLO text (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published ``xla`` crate) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage (from the ``python/`` directory)::

    python -m compile.aot --out-dir ../artifacts

Emits one ``.hlo.txt`` per (model, shape) variant plus ``manifest.json``
describing the shapes so the Rust runtime can size its buffers.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

jax.config.update("jax_enable_x64", True)

# Default compiled variants. The block engine pads its pair batches to P;
# the row-window engine pads row batches to R with window W and fanout K —
# the AOT analog of the paper's fixed per-kernel hash-table sizes.
# Batch-size note (§Perf): under interpret=True each Pallas grid step
# lowers to a dynamic-update-slice over the whole (P,T,T) output, so CPU
# batch cost grows ~P^2 — small P wins on the CPU PJRT path (measured
# optimum P=16). On a real TPU (Mosaic lowering) larger P amortizes launch
# overhead instead; keep both compiled.
BLOCK_VARIANTS = [
    {"p": 16, "t": 16},
    {"p": 64, "t": 16},
    {"p": 256, "t": 16},
    {"p": 64, "t": 32},
]
ROW_WINDOW_VARIANTS = [
    {"r": 64, "k": 32, "w": 256},
]
DTYPE = "f64"


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_block_engine(p: int, t: int) -> str:
    specs = model.block_engine_specs(p, t)
    return to_hlo_text(jax.jit(model.block_engine_model).lower(*specs))


def lower_row_window(r: int, k: int, w: int) -> str:
    specs = model.row_window_specs(r, k, w)
    return to_hlo_text(jax.jit(model.row_window_model).lower(*specs))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"dtype": DTYPE, "block_engine": [], "row_window": []}

    for v in BLOCK_VARIANTS:
        name = f"block_matmul_p{v['p']}_t{v['t']}_{DTYPE}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        text = lower_block_engine(v["p"], v["t"])
        with open(path, "w") as f:
            f.write(text)
        manifest["block_engine"].append({**v, "file": name})
        print(f"wrote {path} ({len(text)} chars)")

    for v in ROW_WINDOW_VARIANTS:
        name = f"row_window_r{v['r']}_k{v['k']}_w{v['w']}_{DTYPE}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        text = lower_row_window(v["r"], v["k"], v["w"])
        with open(path, "w") as f:
            f.write(text)
        manifest["row_window"].append({**v, "file": name})
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
