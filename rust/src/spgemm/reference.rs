//! Gold-standard SpGEMM used to validate every pipeline in the repo:
//! row-wise sort-merge accumulation with exact duplicate merging. Slow-ish
//! but simple enough to be obviously correct; also doubles as the
//! "pure-CPU roofline" reference in EXPERIMENTS.md §Perf.

use crate::sparse::Csr;

/// Reference SpGEMM: `C = A * B` with sorted CSR output.
///
/// Per output row: gather all intermediate products `(col, val)`, sort by
/// column, merge duplicates. O(nprod log nprod) per row.
pub fn spgemm_reference(a: &Csr, b: &Csr) -> Csr {
    assert_eq!(a.cols, b.rows, "inner dimension mismatch: {}x{} * {}x{}", a.rows, a.cols, b.rows, b.cols);
    let mut rpt = vec![0usize; a.rows + 1];
    let mut col: Vec<u32> = Vec::new();
    let mut val: Vec<f64> = Vec::new();
    let mut scratch: Vec<(u32, f64)> = Vec::new();
    for i in 0..a.rows {
        scratch.clear();
        let (acols, avals) = a.row(i);
        for (&k, &av) in acols.iter().zip(avals) {
            let (bcols, bvals) = b.row(k as usize);
            for (&c, &bv) in bcols.iter().zip(bvals) {
                scratch.push((c, av * bv));
            }
        }
        scratch.sort_unstable_by_key(|&(c, _)| c);
        let mut last: Option<u32> = None;
        for &(c, v) in scratch.iter() {
            if last == Some(c) {
                *val.last_mut().unwrap() += v;
            } else {
                col.push(c);
                val.push(v);
                last = Some(c);
            }
        }
        rpt[i + 1] = col.len();
    }
    Csr { rows: a.rows, cols: b.cols, rpt, col, val }
}

/// Symbolic-only reference: per-row nnz of `C` without computing values.
pub fn symbolic_reference(a: &Csr, b: &Csr) -> Vec<usize> {
    assert_eq!(a.cols, b.rows);
    let mut out = vec![0usize; a.rows];
    let mut scratch: Vec<u32> = Vec::new();
    for i in 0..a.rows {
        scratch.clear();
        for &k in a.row_cols(i) {
            scratch.extend_from_slice(b.row_cols(k as usize));
        }
        scratch.sort_unstable();
        scratch.dedup();
        out[i] = scratch.len();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Dense;
    use crate::util::prop;
    use crate::util::rng::Rng;

    pub(crate) fn random_csr(rows: usize, cols: usize, per_row: usize, rng: &mut Rng) -> Csr {
        let mut rpt = vec![0usize];
        let mut col = Vec::new();
        let mut val = Vec::new();
        let mut scratch = Vec::new();
        for _ in 0..rows {
            let k = rng.range(0, per_row + 1);
            rng.sample_distinct(cols, k, &mut scratch);
            for &c in &scratch {
                col.push(c);
                val.push(rng.value());
            }
            rpt.push(col.len());
        }
        Csr::from_parts(rows, cols, rpt, col, val).unwrap()
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(1);
        let a = random_csr(20, 20, 5, &mut rng);
        let i = Csr::identity(20);
        assert_eq!(spgemm_reference(&a, &i), a);
        assert_eq!(spgemm_reference(&i, &a), a);
    }

    #[test]
    fn matches_dense_oracle() {
        let mut rng = Rng::new(2);
        for _ in 0..10 {
            let a = random_csr(12, 9, 4, &mut rng);
            let b = random_csr(9, 14, 4, &mut rng);
            let c = spgemm_reference(&a, &b);
            c.validate().unwrap();
            let dc = Dense::from(&a).matmul(&Dense::from(&b));
            let got = Dense::from(&c);
            for i in 0..12 {
                for j in 0..14 {
                    assert!(
                        (dc.get(i, j) - got.get(i, j)).abs() < 1e-12,
                        "mismatch at ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn symbolic_matches_numeric_structure() {
        let mut rng = Rng::new(3);
        let a = random_csr(30, 25, 6, &mut rng);
        let b = random_csr(25, 30, 6, &mut rng);
        let c = spgemm_reference(&a, &b);
        let sym = symbolic_reference(&a, &b);
        for i in 0..30 {
            assert_eq!(sym[i], c.row_nnz(i), "row {i}");
        }
    }

    #[test]
    fn prop_output_always_valid_csr() {
        prop::check(
            "reference-valid-csr",
            24,
            24,
            |rng, size| {
                let a = random_csr(size, size, 5, rng);
                let b = random_csr(size, size, 5, rng);
                (a, b)
            },
            |(a, b)| {
                let c = spgemm_reference(a, b);
                c.validate().map_err(|e| e.to_string())
            },
        );
    }

    #[test]
    fn empty_matrices() {
        let z = Csr::zero(5, 5);
        let c = spgemm_reference(&z, &z);
        assert_eq!(c.nnz(), 0);
        assert_eq!(c.rows, 5);
    }

    #[test]
    #[should_panic(expected = "inner dimension mismatch")]
    fn dimension_mismatch_panics() {
        let a = Csr::zero(2, 3);
        let b = Csr::zero(4, 2);
        spgemm_reference(&a, &b);
    }
}
