//! Kronecker (RMAT-style) graph generator: recursive quadrant descent with
//! probability matrix [[a,b],[c,d]]. Produces community-structured graphs
//! with heavy-tailed degrees; used for graph-analytics-style workloads and
//! as extra coverage beyond the Table-3 classes.

use crate::sparse::{Coo, Csr};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Kron {
    /// log2 of the number of vertices.
    pub scale: u32,
    /// Average directed edges per vertex.
    pub edge_factor: usize,
    /// RMAT quadrant probabilities (a + b + c + d = 1).
    pub a: f64,
    pub b: f64,
    pub c: f64,
}

impl Default for Kron {
    fn default() -> Self {
        // Graph500 parameters
        Kron { scale: 10, edge_factor: 16, a: 0.57, b: 0.19, c: 0.19 }
    }
}

impl Kron {
    pub fn generate(&self, rng: &mut Rng) -> Csr {
        let n = 1usize << self.scale;
        let edges = n * self.edge_factor;
        let mut coo = Coo::with_capacity(n, n, edges);
        for _ in 0..edges {
            let (mut r, mut c) = (0usize, 0usize);
            for level in (0..self.scale).rev() {
                let p = rng.f64();
                let bit = 1usize << level;
                if p < self.a {
                    // top-left
                } else if p < self.a + self.b {
                    c |= bit;
                } else if p < self.a + self.b + self.c {
                    r |= bit;
                } else {
                    r |= bit;
                    c |= bit;
                }
            }
            coo.push(r, c, rng.value());
        }
        // duplicates merge in the conversion (edge multiplicity is summed)
        coo.to_csr().expect("kron generator produced invalid COO")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_validity() {
        let g = Kron { scale: 8, edge_factor: 8, ..Default::default() };
        let m = g.generate(&mut Rng::new(9));
        m.validate().unwrap();
        assert_eq!(m.rows, 256);
        assert!(m.nnz() > 0 && m.nnz() <= 256 * 8);
    }

    #[test]
    fn heavy_tail() {
        let g = Kron { scale: 10, edge_factor: 16, ..Default::default() };
        let m = g.generate(&mut Rng::new(2));
        let max = m.max_row_nnz();
        let avg = m.nnz() as f64 / m.rows as f64;
        assert!(
            max as f64 > 4.0 * avg,
            "RMAT should be heavy-tailed: max {max} vs avg {avg:.1}"
        );
    }

    #[test]
    fn deterministic() {
        let g = Kron { scale: 7, edge_factor: 4, ..Default::default() };
        assert_eq!(g.generate(&mut Rng::new(5)), g.generate(&mut Rng::new(5)));
    }
}
