//! The 26-matrix evaluation suite (paper Table 3), rebuilt synthetically.
//!
//! Each entry pairs the *paper's* published statistics with a generator
//! recipe whose structural class matches the original SuiteSparse matrix
//! (see family docs in [`super`]). Matrices are scaled down by
//! [`SuiteScale`] so the full suite runs on one machine; intensive
//! quantities (nnz/row, band structure, compression ratio) are preserved,
//! extensive ones (rows, nnz, n_prod) shrink by the scale divisor.
//!
//! `opsparse bench tables` regenerates Table 3 for the synthetic suite so
//! the paper-vs-build match is auditable (EXPERIMENTS.md).

use super::banded::Banded;
use super::powerlaw::PowerLaw;
use super::stencil::{Grid, Stencil};
use super::uniform::Uniform;
use crate::sparse::Csr;
use crate::util::rng::Rng;

/// Statistics from the paper's Table 3 (the original SuiteSparse matrix).
#[derive(Clone, Copy, Debug)]
pub struct PaperStats {
    pub rows: usize,
    pub nnz: usize,
    pub nnz_per_row: f64,
    pub max_row_nnz: usize,
    pub nprod: usize,
    pub nnz_c: usize,
    pub cr: f64,
}

/// Suite scaling: divisor applied to the paper's row counts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SuiteScale {
    /// CI-test scale (fast): normal /128, large /1024.
    Tiny,
    /// Bench scale (default): normal /16, large /128.
    Small,
    /// Stress scale: normal /4, large /64.
    Medium,
}

impl SuiteScale {
    pub fn divisor(self, large: bool) -> usize {
        match (self, large) {
            (SuiteScale::Tiny, false) => 128,
            (SuiteScale::Tiny, true) => 1024,
            (SuiteScale::Small, false) => 16,
            (SuiteScale::Small, true) => 128,
            (SuiteScale::Medium, false) => 4,
            (SuiteScale::Medium, true) => 64,
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "tiny" => Some(SuiteScale::Tiny),
            "small" => Some(SuiteScale::Small),
            "medium" => Some(SuiteScale::Medium),
            _ => None,
        }
    }
}

/// One suite entry: paper identity + synthetic recipe.
#[derive(Clone, Debug)]
pub struct SuiteEntry {
    /// Table 3 id (1-based).
    pub id: usize,
    pub name: &'static str,
    /// Structural class of the stand-in generator.
    pub class: &'static str,
    /// True for the bottom 7 "large" matrices (cuSPARSE OOMs on these).
    pub large: bool,
    pub paper: PaperStats,
}

impl SuiteEntry {
    /// Scaled row count for this entry.
    pub fn scaled_rows(&self, scale: SuiteScale) -> usize {
        (self.paper.rows / scale.divisor(self.large)).max(256)
    }

    /// Generate the synthetic stand-in at `scale` (deterministic).
    pub fn generate(&self, scale: SuiteScale) -> Csr {
        let n = self.scaled_rows(scale);
        let mut rng = Rng::new(0xC0FFEE ^ (self.id as u64) << 32 | self.id as u64);
        build_entry(self.id, n, &mut rng)
    }
}

fn banded(n: usize, per_row: usize, band: usize, contiguous_frac: f64, rng: &mut Rng) -> Csr {
    Banded { n, per_row, band, contiguous_frac }.generate(rng)
}

/// Generator dispatch per Table-3 id. Parameters are chosen so the measured
/// compression ratio of A² lands near the paper's (see module docs).
fn build_entry(id: usize, n: usize, rng: &mut Rng) -> Csr {
    match id {
        // --- normal matrices (1..=19) ---
        1 => Uniform { n, per_row: 4, jitter: 0 }.generate(rng), // m133-b3
        2 => PowerLaw { n, alpha: 2.5, max_row: 44, mean_row: 6.2, hub_frac: 0.05, forced_giant_rows: 0 }
            .generate(rng), // mac_econ_fwd500
        3 => PowerLaw { n, alpha: 2.3, max_row: 206.min(n / 4), mean_row: 2.3, hub_frac: 0.1, forced_giant_rows: 0 }
            .generate(rng), // patents_main
        4 => PowerLaw {
            n,
            alpha: 2.0,
            // paper: 4700 of 1M rows. The floor keeps the giant row's
            // *output* beyond the fixed kernel7 boundary (4096) at
            // reduced scale, so the §6.3.4/§6.3.5 case studies exercise
            // the global-table path like the original matrix does.
            max_row: (n / 213).max(2048).min(n / 2),
            mean_row: 3.1,
            hub_frac: 0.3,
            forced_giant_rows: 1,
        }
        .generate(rng), // webbase-1M
        5 => Stencil { n, grid: Grid::D2, reach: 1, keep: 1.0, diagonal: false }.generate(rng), // mc2depi
        6 => PowerLaw { n, alpha: 2.2, max_row: 353.min(n / 4), mean_row: 5.6, hub_frac: 0.2, forced_giant_rows: 0 }
            .generate(rng), // scircuit
        7 => Stencil { n, grid: Grid::D2, reach: 1, keep: 1.0, diagonal: true }.generate(rng), // mario002
        8 => banded(n, 15, 60, 0.1, rng),   // cage12
        9 => banded(n, 11, 12, 0.5, rng),   // majorbasis
        10 => banded(n, 16, 22, 0.2, rng),  // offshore
        11 => banded(n, 16, 22, 0.2, rng),  // 2cubes_sphere
        12 => banded(n, 26, 42, 0.2, rng),  // poisson3Da
        13 => banded(n, 25, 37, 0.2, rng),  // filter3D
        14 => banded(n, 30, 46, 0.3, rng),  // mono_500Hz
        15 => banded(n, 39, 55, 0.3, rng),  // conf5_4-8x8-05
        16 => banded(n, 64, 64, 0.9, rng),  // cant
        17 => banded(n, 72, 70, 0.9, rng),  // consph
        18 => banded(n, 55, 26, 0.9, rng),  // shipsec1
        19 => banded(n, 51, 16, 0.9, rng),  // rma10
        // --- large matrices (20..=26) ---
        20 => banded(n, 6, 6, 0.1, rng), // delaunay_n24
        21 => banded(n, 19, 43, 0.1, rng), // cage15
        22 => PowerLaw {
            n,
            alpha: 2.1,
            max_row: (n / 64).max(64), // wb-edu: 3841 of 9.8M
            mean_row: 5.8,
            hub_frac: 0.25,
            forced_giant_rows: 2,
        }
        .generate(rng), // wb-edu
        23 => banded(n, 22, 23, 0.2, rng), // cop20k_A
        24 => banded(n, 49, 24, 0.9, rng), // hood
        25 => banded(n, 53, 20, 0.9, rng), // pwtk
        26 => banded(n, 119, 131, 0.9, rng), // pdb1HYS
        _ => panic!("suite id {id} out of range 1..=26"),
    }
}

/// Full suite table: (id, name, class, large?, paper Table-3 columns).
pub fn entries() -> Vec<SuiteEntry> {
    #[rustfmt::skip]
    let raw: [(usize, &'static str, &'static str, bool, usize, usize, f64, usize, usize, usize, f64); 26] = [
        (1,  "m133-b3",         "uniform-4",       false, 200_200,    800_800,     4.0,   4,    3_203_200,     3_182_751,   1.01),
        (2,  "mac_econ_fwd500", "powerlaw-mild",   false, 206_500,    1_273_389,   6.2,   44,   7_556_897,     6_704_899,   1.13),
        (3,  "patents_main",    "powerlaw",        false, 240_547,    560_943,     2.3,   206,  2_604_790,     2_281_308,   1.14),
        (4,  "webbase-1M",      "powerlaw-giant",  false, 1_000_005,  3_105_536,   3.1,   4700, 69_524_195,    51_111_996,  1.36),
        (5,  "mc2depi",         "stencil-2d",      false, 525_825,    2_100_225,   4.0,   4,    8_391_680,     5_245_952,   1.60),
        (6,  "scircuit",        "powerlaw",        false, 170_998,    958_936,     5.6,   353,  8_676_313,     5_222_525,   1.66),
        (7,  "mario002",        "stencil-2d+diag", false, 389_874,    2_101_242,   5.4,   7,    12_829_364,    6_449_598,   1.99),
        (8,  "cage12",          "banded-wide",     false, 130_228,    2_032_536,   15.6,  33,   34_610_826,    15_231_874,  2.27),
        (9,  "majorbasis",      "banded",          false, 160_000,    1_750_416,   10.9,  11,   19_178_064,    8_243_392,   2.33),
        (10, "offshore",        "banded",          false, 259_789,    4_242_673,   16.3,  31,   71_342_515,    23_356_245,  3.05),
        (11, "2cubes_sphere",   "banded",          false, 101_492,    1_647_264,   16.2,  31,   27_450_606,    8_974_526,   3.06),
        (12, "poisson3Da",      "banded",          false, 13_514,     352_762,     26.1,  110,  11_768_678,    2_957_530,   3.98),
        (13, "filter3D",        "banded",          false, 106_437,    2_707_179,   25.4,  112,  85_957_185,    20_161_619,  4.26),
        (14, "mono_500Hz",      "banded",          false, 169_410,    5_036_288,   29.7,  719,  204_030_968,   41_377_964,  4.93),
        (15, "conf5_4-8x8-05",  "banded",          false, 49_152,     1_916_928,   39.0,  39,   74_760_192,    10_911_744,  6.85),
        (16, "cant",            "fem-contig",      false, 62_451,     4_007_383,   64.2,  78,   269_486_473,   17_440_029,  15.45),
        (17, "consph",          "fem-contig",      false, 83_334,     6_010_480,   72.1,  81,   463_845_030,   26_539_736,  17.48),
        (18, "shipsec1",        "fem-contig",      false, 140_874,    7_813_404,   55.5,  102,  450_639_288,   24_086_412,  18.71),
        (19, "rma10",           "fem-contig",      false, 46_835,     2_374_001,   50.7,  145,  156_480_259,   7_900_917,   19.81),
        (20, "delaunay_n24",    "banded-narrow",   true,  16_777_216, 100_663_202, 6.0,   26,   633_914_372,   347_322_258, 1.83),
        (21, "cage15",          "banded-wide",     true,  5_154_859,  99_199_551,  19.2,  47,   2_078_631_615, 929_023_247, 2.24),
        (22, "wb-edu",          "powerlaw-giant",  true,  9_845_725,  57_156_537,  5.8,   3841, 1_559_579_990, 630_077_764, 2.48),
        (23, "cop20k_A",        "banded",          true,  121_192,    2_624_331,   21.7,  81,   79_883_385,    18_705_069,  4.27),
        (24, "hood",            "fem-contig",      true,  220_542,    10_768_436,  48.8,  77,   562_028_138,   34_242_180,  16.41),
        (25, "pwtk",            "fem-contig",      true,  217_918,    11_634_424,  53.4,  180,  626_054_402,   32_772_236,  19.10),
        (26, "pdb1HYS",         "fem-contig",      true,  36_417,     4_344_765,   119.3, 204,  555_322_659,   19_594_581,  28.34),
    ];
    raw.iter()
        .map(|&(id, name, class, large, rows, nnz, npr, maxr, nprod, nnzc, cr)| SuiteEntry {
            id,
            name,
            class,
            large,
            paper: PaperStats {
                rows,
                nnz,
                nnz_per_row: npr,
                max_row_nnz: maxr,
                nprod,
                nnz_c: nnzc,
                cr,
            },
        })
        .collect()
}

/// The 19 "normal" matrices (cuSPARSE can compute these).
pub fn normal_entries() -> Vec<SuiteEntry> {
    entries().into_iter().filter(|e| !e.large).collect()
}

/// The 7 "large" matrices (cuSPARSE runs out of device memory).
pub fn large_entries() -> Vec<SuiteEntry> {
    entries().into_iter().filter(|e| e.large).collect()
}

/// Names of all entries, Table-3 order.
pub fn suite_names() -> Vec<&'static str> {
    entries().iter().map(|e| e.name).collect()
}

/// Look up an entry by name.
pub fn suite_entry(name: &str) -> Option<SuiteEntry> {
    entries().into_iter().find(|e| e.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::stats::{compression_ratio, total_nprod, MatrixStats};
    use crate::spgemm_reference_for_tests as reference;

    #[test]
    fn suite_has_26_entries_19_normal_7_large() {
        assert_eq!(entries().len(), 26);
        assert_eq!(normal_entries().len(), 19);
        assert_eq!(large_entries().len(), 7);
    }

    #[test]
    fn all_entries_generate_valid_matrices_at_tiny() {
        for e in entries() {
            let m = e.generate(SuiteScale::Tiny);
            m.validate().unwrap_or_else(|err| panic!("{}: {err}", e.name));
            assert!(m.nnz() > 0, "{} is empty", e.name);
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let e = suite_entry("webbase-1M").unwrap();
        assert_eq!(e.generate(SuiteScale::Tiny), e.generate(SuiteScale::Tiny));
    }

    #[test]
    fn mean_row_nnz_tracks_paper() {
        for e in entries() {
            let m = e.generate(SuiteScale::Tiny);
            let s = MatrixStats::of(&m);
            let ratio = s.avg_row_nnz / e.paper.nnz_per_row;
            assert!(
                (0.4..=2.5).contains(&ratio),
                "{}: avg nnz/row {:.1} vs paper {:.1}",
                e.name,
                s.avg_row_nnz,
                e.paper.nnz_per_row
            );
        }
    }

    #[test]
    fn compression_ratio_classes_hold() {
        // CR of A^2 should land in the right regime per structural class.
        for e in entries() {
            let m = e.generate(SuiteScale::Tiny);
            let c = reference(&m, &m);
            let cr = compression_ratio(total_nprod(&m, &m), c.nnz());
            if e.paper.cr < 1.5 {
                assert!(cr < 3.0, "{}: CR {cr:.2} too high (paper {:.2})", e.name, e.paper.cr);
            }
            if e.paper.cr > 10.0 {
                assert!(cr > 4.0, "{}: CR {cr:.2} too low (paper {:.2})", e.name, e.paper.cr);
            }
        }
    }

    #[test]
    fn webbase_like_entry_has_giant_row() {
        let e = suite_entry("webbase-1M").unwrap();
        let m = e.generate(SuiteScale::Small);
        let max = m.max_row_nnz();
        let avg = m.nnz() as f64 / m.rows as f64;
        assert!(max as f64 > 20.0 * avg, "giant row missing: max {max}, avg {avg:.1}");
    }

    #[test]
    fn scaled_rows_ordering() {
        let e = suite_entry("cant").unwrap();
        assert!(e.scaled_rows(SuiteScale::Tiny) < e.scaled_rows(SuiteScale::Small));
        assert!(e.scaled_rows(SuiteScale::Small) < e.scaled_rows(SuiteScale::Medium));
    }
}
