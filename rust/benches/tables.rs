//! `cargo bench --bench tables` — regenerates paper Tables 1, 2, 4, 5
//! (kernel configurations, occupancy, binning ranges) and Table 3 (suite
//! statistics: paper columns next to the synthetic stand-ins' measured
//! columns).

use opsparse::bench::tables;
use opsparse::gen::suite::SuiteScale;

fn main() {
    let scale = std::env::var("OPSPARSE_SCALE")
        .ok()
        .and_then(|s| SuiteScale::parse(&s))
        .unwrap_or(SuiteScale::Small);
    tables::table1();
    tables::table2();
    tables::table4_5();
    tables::table3(scale).expect("table3");
}
