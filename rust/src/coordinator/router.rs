//! Job routing: decide per matrix pair whether to run the hash pipeline,
//! the PJRT block engine, or the row-sharded multi-device path.
//!
//! Two cheap, structure-only estimates drive the decision:
//!
//! 1. **Working set** ([`working_set_bytes`]): operands + a result upper
//!    bound. When it exceeds a single device's memory budget the job
//!    cannot run unsharded at all, so it routes to
//!    [`Route::Sharded`] with enough devices to fit
//!    (see [`crate::spgemm::sharded`]).
//! 2. **Tile fill** ([`Router::estimate_fill`]): the block engine wins
//!    when the matrices are *blocky* — their nonzeros cluster into dense
//!    `T×T` tiles (FEM matrices with contiguous runs, the high-CR half of
//!    Table 3). For scattered matrices the padding overhead of dense
//!    blocks dominates and the hash path wins. Fill is estimated on a row
//!    sample, mirroring spECK's lightweight pre-analysis (§3) — cheap,
//!    structure-only, value-free.
//!
//! # Example
//!
//! ```
//! use opsparse::coordinator::{Route, Router, RouterConfig};
//! use opsparse::sparse::Csr;
//!
//! // scattered identity: low tile fill, fits in memory -> hash pipeline
//! let a = Csr::identity(512);
//! assert_eq!(Router::default().route(&a, &a), Route::Hash);
//!
//! // shrink the device budget below the working set -> sharded route
//! let tiny = Router::new(RouterConfig { device_memory_bytes: 1024, ..Default::default() });
//! match tiny.route(&a, &a) {
//!     Route::Sharded { n_devices } => assert!(n_devices >= 2),
//!     other => panic!("expected a sharded route, got {other:?}"),
//! }
//! ```

use crate::sparse::stats::total_nprod;
use crate::sparse::Csr;

/// Execution path for a job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    /// Two-phase hash pipeline (the paper's OpSparse).
    Hash,
    /// PJRT BSR block engine.
    Block,
    /// Row-sharded multi-device hash pipeline
    /// ([`crate::spgemm::multiply_sharded`]): chosen when the estimated
    /// working set exceeds one device's memory budget.
    Sharded {
        /// Devices the job is split across.
        n_devices: usize,
    },
}

/// Router configuration.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Block size of the compiled engine.
    pub t: usize,
    /// Minimum estimated tile fill ratio to route to the block engine.
    pub min_fill: f64,
    /// Rows sampled for the estimate.
    pub sample_rows: usize,
    /// Single-device memory budget in bytes; jobs whose
    /// [`working_set_bytes`] exceeds it shard. Default: the V100's 16 GB.
    pub device_memory_bytes: usize,
    /// Most devices a sharded job may span. Below 2 the sharded route is
    /// disabled entirely (single-device deployment): oversized jobs stay
    /// on the hash path and fail there if they truly cannot fit.
    pub max_devices: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            t: 16,
            min_fill: 0.25,
            sample_rows: 256,
            device_memory_bytes: 16 * (1 << 30),
            max_devices: 8,
        }
    }
}

/// Upper-bound device working set of `C = A * B` under the paper's CSR
/// layout: both operands resident, plus `C` sized by the intermediate
/// product count (`nnz(C) <= n_prod`, 12 B per entry: 4 B column + 8 B
/// value) plus the `C.rpt` metadata. Transient hash tables are excluded —
/// they are bounded by the same `n_prod` term. `O(nnz(A))` to compute,
/// value-free.
pub fn working_set_bytes(a: &Csr, b: &Csr) -> usize {
    // a mismatched pair never reaches a device: estimate operands only and
    // let the pipeline report the dimension error
    let nprod = if a.cols == b.rows { total_nprod(a, b) } else { 0 };
    a.device_bytes() + b.device_bytes() + 12 * nprod + 4 * (a.rows + 1)
}

/// Structure-only router.
#[derive(Clone, Debug, Default)]
pub struct Router {
    pub cfg: RouterConfig,
}

impl Router {
    pub fn new(cfg: RouterConfig) -> Self {
        Router { cfg }
    }

    /// Estimate the dense-tile fill ratio of `m` on a row sample: for each
    /// sampled row, count (tile, elements-in-tile) and return
    /// elements / (tiles × T) — the column-direction fill a BSR
    /// conversion would see.
    pub fn estimate_fill(&self, m: &Csr) -> f64 {
        if m.rows == 0 || m.nnz() == 0 {
            return 0.0;
        }
        let t = self.cfg.t;
        let step = (m.rows / self.cfg.sample_rows.max(1)).max(1);
        let mut elems = 0usize;
        let mut tiles = 0usize;
        for r in (0..m.rows).step_by(step) {
            let mut last_tile = u32::MAX;
            for &c in m.row_cols(r) {
                let tile = c / t as u32;
                if tile != last_tile {
                    tiles += 1;
                    last_tile = tile;
                }
                elems += 1;
            }
        }
        if tiles == 0 {
            0.0
        } else {
            elems as f64 / (tiles * t) as f64
        }
    }

    /// Device count a job needs under the memory budget, or `None` when it
    /// fits on one device. Row sharding replicates `B` on every device, so
    /// only the `A`/`C` portion of the working set divides by the device
    /// count: `k` must satisfy `B + (A + C)/k <= budget`. A `B` that alone
    /// exceeds the budget is infeasible for row sharding (column-sharding
    /// `B` is a ROADMAP item) — the router then returns `max_devices` as
    /// the best it can do. Mismatched dimensions never shard: the job goes
    /// to the hash path, which reports the dimension error.
    pub fn shard_count(&self, a: &Csr, b: &Csr) -> Option<usize> {
        if a.cols != b.rows || self.cfg.max_devices < 2 {
            return None;
        }
        let budget = self.cfg.device_memory_bytes.max(1);
        // cheap screen first: `n_prod <= nnz(A) · max nnz/row of B`, so if
        // even that pessimistic working set fits, skip the exact O(nnz(A))
        // fold — submit-path routing stays O(rows) for the common case
        let base = a.device_bytes() + b.device_bytes() + 4 * (a.rows + 1);
        let upper =
            base.saturating_add(12usize.saturating_mul(a.nnz().saturating_mul(b.max_row_nnz())));
        debug_assert!(
            upper >= working_set_bytes(a, b),
            "screen must stay an upper bound of the exact estimate"
        );
        if upper <= budget {
            return None;
        }
        let est = working_set_bytes(a, b);
        if est <= budget {
            return None;
        }
        let max = self.cfg.max_devices;
        let b_rep = b.device_bytes();
        let n = if b_rep >= budget {
            max
        } else {
            (est - b_rep).div_ceil(budget - b_rep)
        };
        Some(n.clamp(2, max))
    }

    /// Route a job: memory first (a job that cannot fit must shard), then
    /// the joint tile fill of both operands. A dimension-mismatched pair
    /// always routes to the hash path, which rejects it with a proper
    /// error (the block engine would panic instead of failing the job).
    pub fn route(&self, a: &Csr, b: &Csr) -> Route {
        if a.cols != b.rows {
            return Route::Hash;
        }
        if let Some(n_devices) = self.shard_count(a, b) {
            return Route::Sharded { n_devices };
        }
        let fill = self.estimate_fill(a).min(self.estimate_fill(b));
        if fill >= self.cfg.min_fill {
            Route::Block
        } else {
            Route::Hash
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::banded::Banded;
    use crate::gen::uniform::Uniform;
    use crate::util::rng::Rng;

    #[test]
    fn fem_contiguous_matrix_routes_to_block() {
        let mut rng = Rng::new(41);
        let a = Banded { n: 1000, per_row: 48, band: 40, contiguous_frac: 1.0 }.generate(&mut rng);
        let r = Router::default();
        assert!(r.estimate_fill(&a) > 0.4, "fill={}", r.estimate_fill(&a));
        assert_eq!(r.route(&a, &a), Route::Block);
    }

    #[test]
    fn scattered_matrix_routes_to_hash() {
        let mut rng = Rng::new(42);
        let a = Uniform { n: 2000, per_row: 6, jitter: 3 }.generate(&mut rng);
        let r = Router::default();
        assert!(r.estimate_fill(&a) < 0.25, "fill={}", r.estimate_fill(&a));
        assert_eq!(r.route(&a, &a), Route::Hash);
    }

    #[test]
    fn empty_matrix_fill_zero() {
        let z = Csr::zero(10, 10);
        assert_eq!(Router::default().estimate_fill(&z), 0.0);
        assert_eq!(Router::default().route(&z, &z), Route::Hash);
    }

    #[test]
    fn oversized_working_set_routes_sharded() {
        let mut rng = Rng::new(43);
        let a = Uniform { n: 1000, per_row: 8, jitter: 4 }.generate(&mut rng);
        let est = working_set_bytes(&a, &a);
        assert!(est > a.device_bytes() * 2, "estimate must include the C upper bound");
        // budget just below the estimate: minimal split
        let r = Router::new(RouterConfig {
            device_memory_bytes: est - 1,
            ..Default::default()
        });
        assert_eq!(r.route(&a, &a), Route::Sharded { n_devices: 2 });
        // budget a quarter of the estimate: more devices, still capped
        let r4 = Router::new(RouterConfig {
            device_memory_bytes: est / 4,
            max_devices: 8,
            ..Default::default()
        });
        match r4.route(&a, &a) {
            Route::Sharded { n_devices } => assert!((4..=8).contains(&n_devices)),
            other => panic!("expected sharded, got {other:?}"),
        }
    }

    #[test]
    fn shard_count_honors_max_devices() {
        let mut rng = Rng::new(44);
        let a = Uniform { n: 500, per_row: 6, jitter: 3 }.generate(&mut rng);
        let r = Router::new(RouterConfig {
            device_memory_bytes: 1,
            max_devices: 4,
            ..Default::default()
        });
        assert_eq!(r.shard_count(&a, &a), Some(4));
        // memory routing outranks tile fill
        assert!(matches!(r.route(&a, &a), Route::Sharded { n_devices: 4 }));
    }

    #[test]
    fn shard_count_accounts_for_b_replication() {
        // B is replicated on every device, so the naive est/budget split
        // would under-provision: with budget = est/2 a 2-way split leaves
        // each device holding B + half of A/C > budget
        let mut rng = Rng::new(46);
        let a = Uniform { n: 400, per_row: 6, jitter: 3 }.generate(&mut rng);
        let est = working_set_bytes(&a, &a);
        let b_rep = a.device_bytes();
        let budget = est.div_ceil(2);
        let r =
            Router::new(RouterConfig { device_memory_bytes: budget, ..Default::default() });
        let n = r.shard_count(&a, &a).expect("over budget");
        assert!(n > 2, "naive est/budget sizing would give 2, got {n}");
        assert!(
            b_rep + (est - b_rep).div_ceil(n) <= budget,
            "chosen n={n} must actually fit the budget"
        );
    }

    #[test]
    fn max_devices_below_two_disables_sharding() {
        let mut rng = Rng::new(47);
        let a = Uniform { n: 300, per_row: 6, jitter: 3 }.generate(&mut rng);
        for max_devices in [0, 1] {
            let r = Router::new(RouterConfig {
                device_memory_bytes: 1,
                max_devices,
                ..Default::default()
            });
            assert_eq!(r.shard_count(&a, &a), None, "max_devices={max_devices}");
            assert_eq!(r.route(&a, &a), Route::Hash);
        }
    }

    #[test]
    fn mismatched_dims_never_route_sharded() {
        // a job the pipeline will reject must reach the hash path so the
        // caller gets the dimension error, not a shard-planning panic
        let a = Csr::zero(3, 4);
        let b = Csr::zero(5, 5);
        let r = Router::new(RouterConfig { device_memory_bytes: 1, ..Default::default() });
        assert_eq!(r.shard_count(&a, &b), None);
        assert_eq!(r.route(&a, &b), Route::Hash);
    }

    #[test]
    fn blocky_but_oversized_still_shards() {
        let mut rng = Rng::new(45);
        let a = Banded { n: 800, per_row: 48, band: 40, contiguous_frac: 1.0 }.generate(&mut rng);
        let r = Router::new(RouterConfig { device_memory_bytes: 1024, ..Default::default() });
        assert!(matches!(r.route(&a, &a), Route::Sharded { .. }));
    }
}
