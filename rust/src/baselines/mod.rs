//! Behavioral baselines of the three libraries the paper compares against
//! (§3, §6.2): cuSPARSE, nsparse, spECK. The nsparse/spECK baselines are
//! expressed as [`OpSparseConfig`] flag sets (they share the binned
//! two-phase structure); cuSPARSE has its own unbinned pipeline.
//!
//! Every baseline computes bit-validated results — they differ from
//! OpSparse only in the *architectural inefficiencies* the paper
//! identifies (§4), which show up in their device traces.

pub mod cusparse_like;

use crate::sparse::Csr;
use crate::spgemm::pipeline::{multiply, OpSparseConfig, SpgemmOutput};
use anyhow::Result;

/// The four libraries of the paper's evaluation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Library {
    OpSparse,
    Nsparse,
    Speck,
    Cusparse,
}

impl Library {
    pub fn name(&self) -> &'static str {
        match self {
            Library::OpSparse => "OpSparse",
            Library::Nsparse => "nsparse",
            Library::Speck => "spECK",
            Library::Cusparse => "cuSPARSE",
        }
    }

    /// All four, in the paper's plotting order.
    pub fn all() -> [Library; 4] {
        [Library::Cusparse, Library::Nsparse, Library::Speck, Library::OpSparse]
    }

    /// The three that can compute the large matrices (Fig 6; cuSPARSE
    /// runs out of device memory on those, §6.1).
    pub fn large_capable() -> [Library; 3] {
        [Library::Nsparse, Library::Speck, Library::OpSparse]
    }

    /// Run this library's SpGEMM on `A * B`.
    pub fn run(&self, a: &Csr, b: &Csr) -> Result<SpgemmOutput> {
        match self {
            Library::OpSparse => multiply(a, b, &OpSparseConfig::default()),
            Library::Nsparse => multiply(a, b, &OpSparseConfig::nsparse_like()),
            Library::Speck => multiply(a, b, &OpSparseConfig::speck_like()),
            Library::Cusparse => cusparse_like::multiply_cusparse(a, b),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::uniform::Uniform;
    use crate::spgemm::reference::spgemm_reference;
    use crate::util::rng::Rng;

    #[test]
    fn all_libraries_agree_with_reference() {
        let mut rng = Rng::new(55);
        let a = Uniform { n: 220, per_row: 9, jitter: 4 }.generate(&mut rng);
        let gold = spgemm_reference(&a, &a);
        for lib in Library::all() {
            let out = lib.run(&a, &a).unwrap();
            assert!(
                out.c.approx_eq(&gold, 1e-12),
                "{} diverges: {:?}",
                lib.name(),
                out.c.diff(&gold, 1e-12)
            );
        }
    }

    #[test]
    fn names_and_groups() {
        assert_eq!(Library::all().len(), 4);
        assert_eq!(Library::large_capable().len(), 3);
        assert!(!Library::large_capable().contains(&Library::Cusparse));
    }
}
