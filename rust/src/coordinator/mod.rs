//! L3 coordinator: the multi-tenant SpGEMM service layer.
//!
//! The paper's contribution is a *library*; a production deployment wraps
//! it in a service that accepts multiply jobs, routes each to the right
//! execution path, and reports metrics. This module provides that layer:
//!
//! * [`router`] — picks the execution path per job: the hash pipeline
//!   (CPU + device-trace simulation), the PJRT BSR block engine (dense
//!   blocky matrices, DESIGN.md §Hardware-Adaptation), or the row-sharded
//!   multi-device path ([`crate::spgemm::sharded`]) when the estimated
//!   working set exceeds a single device's memory budget.
//! * [`service`] — a worker-pool job queue (std threads + channels; the
//!   build is offline so no tokio) with latency metrics. Each hash worker
//!   owns a grow-only [`crate::gpusim::DevicePool`] and a [`cache`]
//!   entry set, so warm repeated-pattern traffic pays neither
//!   `cudaMalloc` nor the symbolic phase. A sharded job is split at
//!   submit time into per-shard **sub-jobs** that fan out across the
//!   whole worker pool and reassemble through a [`barrier`], so one
//!   oversized multiply and many small jobs share the fleet.
//! * [`barrier`] — the per-job shard reassembly barrier (exactly one
//!   result per parent job, even when shards fail or are lost), plus
//!   the straggler view that drives speculative backup sub-jobs
//!   (first result wins, loser discarded, stitch bit-identical).
//! * [`chaos`] — deterministic fault injection at sub-job boundaries
//!   (worker kill / straggler delay / pool teardown) so the
//!   speculation + requeue machinery is provable under test.
//! * [`cache`] — the per-worker sparsity-pattern (symbolic-reuse) cache.
//! * [`feedback`] — the adaptive planning loop: a pattern-keyed
//!   execution history fed by measured timelines, consumed to re-cut
//!   shard plans, re-fit the router's compute proxy online, and tune
//!   the broadcast chunk size.
//! * [`metrics`] — counters, latency percentiles, pool/cache/shard
//!   telemetry.
//! * [`serve`] — the serving front door over the coordinator: request
//!   coalescing (N identical in-flight multiplies pay one symbolic
//!   phase and share one `Arc`'d result), admission control (bounded
//!   queue with explicit rejection, per-tenant fair dequeue), warm-start
//!   persistence (the [`feedback`] history + fit survive restarts), and
//!   the unified [`ServeConfig`] that replaces scattered `OPSPARSE_*`
//!   env reads with documented CLI > env > default layering.
//! * [`batch`] — the front door's size/age-watermarked batcher: many
//!   small hash-routed requests become one worker visit.
//!
//! Every layer above carries optional request-scoped tracing hooks
//! ([`crate::obs`]): with `--trace on` each serve request grows a span
//! tree (admit → queue-wait → batch-residency → route-decision →
//! exec/shards → stitch) exportable as Chrome trace JSON, and
//! [`Metrics::to_prometheus`] exposes every counter plus per-phase
//! latency histograms in Prometheus text format. With tracing off (the
//! default) none of the hooks allocate or read a clock.

pub mod barrier;
pub mod batch;
pub mod cache;
pub mod chaos;
pub mod feedback;
pub mod metrics;
pub mod router;
pub mod serve;
pub mod service;

pub use barrier::{ShardBarrier, SpeculateConfig};
pub use batch::{BatchConfig, Batcher};
pub use chaos::ChaosConfig;
pub use cache::{PatternCache, PatternKey};
pub use feedback::{
    Engine, ExecHistory, NsPerProdFit, PersistedState, ReplanConfig, RunObservation,
};
pub use metrics::Metrics;
pub use router::{choose_engine, EngineMode, Route, Router, RouterConfig, DISPATCH_SWITCH_GAIN};
pub use serve::{Serve, ServeConfig, ServeResult, ServeTicket};
pub use service::{Coordinator, Job, JobResult};
