//! `cargo bench --bench shard_scaling` — row-sharded multi-device SpGEMM
//! on a power-law matrix at 1/2/4/8 shards: per-device makespan, modeled
//! `B`-broadcast and `C`-gather interconnect costs, planned and measured
//! load imbalance, and (honest, communication-charged) scaling
//! efficiency vs one device.
//!
//! Env:
//! * `OPSPARSE_SCALE=tiny|small|medium` (default small)
//! * `OPSPARSE_INTERCONNECT=pcie|nvlink|none` (default pcie)
//! * `OPSPARSE_BENCH_JSON=<path>` — also record the rows as JSON; CI
//!   writes `BENCH_shards.json` this way, next to `BENCH_seed.json`.

use opsparse::bench::{figures, write_shard_scaling_json};
use opsparse::gen::suite::SuiteScale;
use opsparse::gpusim::Interconnect;

fn main() {
    let scale = std::env::var("OPSPARSE_SCALE")
        .ok()
        .and_then(|s| SuiteScale::parse(&s))
        .unwrap_or(SuiteScale::Small);
    let ic = match std::env::var("OPSPARSE_INTERCONNECT").as_deref() {
        Ok(name) => Interconnect::parse_opt(name).expect("pcie|nvlink|none"),
        Err(_) => Some(Interconnect::pcie3()),
    };
    let rows = figures::shard_scaling_with(scale, ic.as_ref()).expect("shard_scaling bench");
    if let Ok(path) = std::env::var("OPSPARSE_BENCH_JSON") {
        write_shard_scaling_json(&path, scale, &rows).expect("write bench json");
    }
}
