//! The three-layer path end-to-end: a blocky FEM-like matrix is routed
//! through the **PJRT block engine** — Rust symbolic phase (the paper's
//! hashing over block columns) + AOT-compiled Pallas batched block-matmul
//! numeric phase — and validated against both the pure-Rust hash pipeline
//! and the sort-merge reference.
//!
//! Requires `make artifacts`. Run:
//! `cargo run --release --example block_spgemm_pjrt`

use opsparse::baselines::Library;
use opsparse::coordinator::{Route, Router};
use opsparse::gen::banded::Banded;
use opsparse::runtime::{artifacts_available, default_artifacts_dir, BlockEngine};
use opsparse::sparse::Bsr;
use opsparse::spgemm::reference::spgemm_reference;
use opsparse::util::fmt;
use opsparse::util::rng::Rng;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    anyhow::ensure!(
        artifacts_available(),
        "artifacts missing — run `make artifacts` first"
    );
    let mut rng = Rng::new(99);
    // cant-like FEM matrix: contiguous nonzero runs => dense 16x16 tiles
    let a = Banded { n: 1024, per_row: 48, band: 40, contiguous_frac: 1.0 }.generate(&mut rng);
    println!("A: {}x{}, nnz {}", a.rows, a.cols, fmt::count(a.nnz()));

    // the router sees the blocky structure and picks the block path
    let router = Router::default();
    let fill = router.estimate_fill(&a);
    println!("router: tile fill {:.2} => {:?}", fill, router.route(&a, &a));
    assert_eq!(router.route(&a, &a), Route::Block);

    // BSR conversion stats
    let bsr = Bsr::from_csr(&a, 16)?;
    println!(
        "BSR: {} blocks of 16x16, fill ratio {:.2}",
        fmt::count(bsr.nblocks()),
        bsr.fill_ratio()
    );

    // PJRT block engine multiply
    let mut engine = BlockEngine::load(&default_artifacts_dir(), 16, 16)?;
    let t0 = Instant::now();
    let c_block = engine.spgemm_csr(&a, &a)?;
    let t_block = t0.elapsed();
    println!(
        "block engine: {} pairs in {} batches ({} padded), {:?}",
        fmt::count(engine.stats.pairs),
        engine.stats.batches,
        engine.stats.padded_pairs,
        t_block
    );

    // cross-validate against the hash pipeline and the reference
    let t1 = Instant::now();
    let c_hash = Library::OpSparse.run(&a, &a)?.c;
    let t_hash = t1.elapsed();
    let gold = spgemm_reference(&a, &a);
    match (c_block.diff(&gold, 1e-9), c_hash.diff(&gold, 1e-9)) {
        (None, None) => println!("verify: block path == hash path == reference  OK"),
        (b, h) => anyhow::bail!("mismatch: block={b:?} hash={h:?}"),
    }
    println!(
        "C: nnz {} | block path {:?}, hash path {:?} (CPU wall; the block \
         path pays PJRT buffer copies at this scale — on TPU the same HLO \
         feeds the MXU)",
        fmt::count(gold.nnz()),
        t_block,
        t_hash,
    );
    Ok(())
}
