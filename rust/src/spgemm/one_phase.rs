//! One-phase SpGEMM (paper §2.2): compute row sizes, columns, and values
//! **simultaneously**, writing into a temporary buffer sized by the
//! per-row upper bound (`n_prod`), then copy into exact CSR storage.
//!
//! The paper explains why the two-phase method wins on GPUs: the upper
//! bound over-allocates by the compression ratio (up to 28× on pdb1HYS),
//! and the final compaction copy is pure extra memory traffic. This
//! module implements the method faithfully so the trade-off is
//! measurable on the simulator (`opsparse bench ablations` prints it).

use super::binning::bin_rows;
use super::hash_table::HashAccumulator;
use super::kernel_tables::{numeric_kernels, SymbolicRanges};
use super::pipeline::SpgemmOutput;
use super::HashVariant;
use crate::gpusim::trace::{BlockWork, Kernel, Trace};
use crate::sparse::stats::nprod_per_row;
use crate::sparse::Csr;
use crate::util::exclusive_sum;
use anyhow::{ensure, Result};

/// One-phase SpGEMM: `C = A * B` with upper-bound temporary allocation.
pub fn multiply_one_phase(a: &Csr, b: &Csr) -> Result<SpgemmOutput> {
    ensure!(a.cols == b.rows, "dimension mismatch");
    let m = a.rows;
    let mut trace = Trace::new();
    let nprod = nprod_per_row(a, b);
    let nprod_total: usize = nprod.iter().sum();

    // upper-bound temporary storage for columns + values (the §2.2
    // over-allocation), plus C.rpt
    trace.malloc(4 * (m + 1), "c_rpt", "setup");
    trace.malloc((4 + 8) * nprod_total, "temp_upper_bound", "setup");
    trace.launch(super::pipeline::nprod_kernel_for_tests(a, 0));

    // single computation pass, binned by n_prod (the row-size estimate —
    // there is no symbolic phase to give exact sizes)
    let binning = bin_rows(&nprod, &SymbolicRanges::Sym12x.ranges());
    let temp_rpt = exclusive_sum(&nprod);
    let mut temp_col = vec![0u32; nprod_total];
    let mut temp_val = vec![0f64; nprod_total];
    let mut row_nnz = vec![0usize; m];

    let configs = numeric_kernels();
    let b_reuse = (b.nnz() as f64 / nprod_total.max(1) as f64).clamp(0.15, 1.0);
    let mut stats = super::hash_table::ProbeStats::default();
    let mut row_cols: Vec<u32> = Vec::new();
    let mut row_vals: Vec<f64> = Vec::new();
    for bin in (0..super::kernel_tables::NUM_BINS).rev() {
        let rows = binning.bin_rows(bin);
        if rows.is_empty() {
            continue;
        }
        let cfg = &configs[bin.min(7)];
        let mut blocks: Vec<BlockWork> = Vec::with_capacity(rows.len());
        // tables must hold n_prod-many distinct keys in the worst case:
        // size by the bin's nprod bound, not the (unknown) nnz
        let mut shared_table: Option<HashAccumulator> = None;
        for &r in rows {
            let r = r as usize;
            let need = nprod[r].next_power_of_two().max(32) * 2;
            let table = match shared_table.as_mut() {
                Some(t) if t.t_size() >= need => {
                    t.reset();
                    t
                }
                _ => {
                    let mut fresh = HashAccumulator::new(need, HashVariant::SingleAccess);
                    if let Some(old) = shared_table.take() {
                        fresh.stats = old.stats;
                    }
                    shared_table = Some(fresh);
                    shared_table.as_mut().unwrap()
                }
            };
            let before = table.stats;
            let (acols, avals) = a.row(r);
            for (&k, &av) in acols.iter().zip(avals) {
                let (bcols, bvals) = b.row(k as usize);
                for (&c, &bv) in bcols.iter().zip(bvals) {
                    ensure!(table.insert_numeric(c, av * bv), "one-phase table overflow");
                }
            }
            row_cols.clear();
            row_vals.clear();
            table.condense_sorted(&mut row_cols, &mut row_vals);
            row_nnz[r] = row_cols.len();
            temp_col[temp_rpt[r]..temp_rpt[r] + row_cols.len()].copy_from_slice(&row_cols);
            temp_val[temp_rpt[r]..temp_rpt[r] + row_vals.len()].copy_from_slice(&row_vals);

            let a_nnz = a.row_nnz(r) as u64;
            let b_elems: u64 =
                a.row_cols(r).iter().map(|&k| b.row_nnz(k as usize) as u64).sum();
            let delta_acc = table.stats.table_accesses - before.table_accesses;
            blocks.push(BlockWork {
                global_bytes: a_nnz * 20
                    + (b_elems as f64 * 12.0 * b_reuse) as u64
                    + row_nnz[r] as u64 * 12,
                shared_accesses: delta_acc + row_nnz[r] as u64 * 3,
                global_atomics: 0,
                mod_ops: 0,
                flops: 2 * b_elems,
            });
        }
        if let Some(t) = shared_table {
            stats.add(&t.stats);
        }
        trace.launch(Kernel {
            name: format!("onephase_kernel{}", cfg.index),
            step: "numeric",
            stream: bin % 4,
            tb_size: cfg.tb_size,
            shared_bytes: cfg.shared_bytes,
            blocks,
        });
    }

    // exact allocation + compaction copy (the §2.2 extra pass)
    let c_rpt = exclusive_sum(&row_nnz);
    let c_nnz = *c_rpt.last().unwrap();
    trace.device_sync("alloc_c");
    trace.malloc(4 * c_nnz, "c_col", "alloc_c");
    trace.malloc(8 * c_nnz, "c_val", "alloc_c");
    let mut c_col = vec![0u32; c_nnz];
    let mut c_val = vec![0f64; c_nnz];
    for r in 0..m {
        let n = row_nnz[r];
        c_col[c_rpt[r]..c_rpt[r + 1]].copy_from_slice(&temp_col[temp_rpt[r]..temp_rpt[r] + n]);
        c_val[c_rpt[r]..c_rpt[r + 1]].copy_from_slice(&temp_val[temp_rpt[r]..temp_rpt[r] + n]);
    }
    trace.launch(Kernel {
        name: "onephase_compact".into(),
        step: "alloc_c",
        stream: 0,
        tb_size: 256,
        shared_bytes: 0,
        blocks: (0..m.div_ceil(2048).max(1))
            .map(|blk| {
                let lo = blk * 2048;
                let hi = (lo + 2048).min(m);
                let bytes: u64 =
                    (lo..hi).map(|r| 2 * row_nnz[r] as u64 * 12).sum();
                BlockWork { global_bytes: bytes, ..Default::default() }
            })
            .collect(),
    });
    trace.device_sync("cleanup");
    trace.free("temp_upper_bound", "cleanup");

    let c = Csr { rows: m, cols: b.cols, rpt: c_rpt, col: c_col, val: c_val };
    Ok(SpgemmOutput {
        c,
        trace,
        nprod: nprod_total,
        sym_stats: super::hash_table::ProbeStats::default(),
        num_stats: stats,
        sym_fallback_rows: 0,
        symbolic_skipped: false,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::suite::{suite_entry, SuiteScale};
    use crate::gen::uniform::Uniform;
    use crate::spgemm::pipeline::{multiply, OpSparseConfig};
    use crate::spgemm::reference::spgemm_reference;
    use crate::util::rng::Rng;

    #[test]
    fn matches_reference() {
        let mut rng = Rng::new(61);
        let a = Uniform { n: 250, per_row: 10, jitter: 5 }.generate(&mut rng);
        let out = multiply_one_phase(&a, &a).unwrap();
        let gold = spgemm_reference(&a, &a);
        assert!(out.c.approx_eq(&gold, 1e-12), "{:?}", out.c.diff(&gold, 1e-12));
    }

    #[test]
    fn over_allocates_by_the_compression_ratio() {
        // §2.2: on high-CR matrices the one-phase temp buffer is CR times
        // the exact storage
        let a = suite_entry("cant").unwrap().generate(SuiteScale::Tiny);
        let one = multiply_one_phase(&a, &a).unwrap();
        let two = multiply(&a, &a, &OpSparseConfig::default()).unwrap();
        assert!(
            one.trace.malloc_bytes() > 5 * two.trace.malloc_bytes(),
            "one-phase should over-allocate heavily: {} vs {}",
            one.trace.malloc_bytes(),
            two.trace.malloc_bytes()
        );
    }

    #[test]
    fn two_phase_wins_on_simulated_time_for_high_cr() {
        let a = suite_entry("pdb1HYS").unwrap().generate(SuiteScale::Tiny);
        let one = multiply_one_phase(&a, &a).unwrap();
        let two = multiply(&a, &a, &OpSparseConfig::default()).unwrap();
        let t1 = crate::gpusim::simulate(&one.trace, &crate::gpusim::V100).total_ns;
        let t2 = crate::gpusim::simulate(&two.trace, &crate::gpusim::V100).total_ns;
        assert!(t2 < t1, "two-phase should win on high-CR input: {t2} vs {t1}");
    }
}
