//! Property/fuzz tests for `sparse::mmio` via the in-house
//! `util::prop::check` harness: write→read round trips must be
//! bit-identical for every generator family × field × symmetry the
//! writer supports, and malformed input must be rejected with a *typed*
//! [`MmioError`] — never a panic, never a silently corrupted matrix.
//!
//! (`pattern × skew-symmetric` is excluded from the round-trip matrix:
//! the mirror of a pattern `1.0` entry is `-1.0`, which pattern storage
//! cannot represent — the writer rejects it, and a test pins that.)

use opsparse::sparse::mmio::{self, Field, MmioError, Symmetry};
use opsparse::sparse::Csr;
use opsparse::util::prop::check;
use opsparse::util::rng::Rng;
use std::collections::BTreeMap;

/// Build a CSR from per-row column→value maps (sorted, deduplicated).
fn csr_from_rows(n: usize, rows: Vec<BTreeMap<usize, f64>>) -> Csr {
    let mut rpt = vec![0usize];
    let mut col = Vec::new();
    let mut val = Vec::new();
    for r in &rows {
        for (&c, &v) in r {
            col.push(c as u32);
            val.push(v);
        }
        rpt.push(col.len());
    }
    Csr::from_parts(n, n, rpt, col, val).unwrap()
}

/// A value representable in `field`: dyadic k/8 reals (exact in text),
/// small nonzero integers, or the pattern constant 1.0.
fn field_value(rng: &mut Rng, field: Field) -> f64 {
    match field {
        Field::Pattern => 1.0,
        Field::Integer => {
            let v = 1.0 + rng.below(9) as f64;
            if rng.below(2) == 1 {
                -v
            } else {
                v
            }
        }
        Field::Real => {
            let v = (1 + rng.below(13)) as f64 / 8.0;
            if rng.below(2) == 1 {
                -v
            } else {
                v
            }
        }
    }
}

/// Generate a random square matrix representable in `(field, sym)` with
/// the sparsity structure of one generator family: 0 = uniform scatter,
/// 1 = near-diagonal band, 2 = power-law (hub rows), 3 = diagonal-heavy.
fn gen_matrix(rng: &mut Rng, size: usize, family: usize, field: Field, sym: Symmetry) -> Csr {
    let n = size.clamp(2, 64);
    let mut rows: Vec<BTreeMap<usize, f64>> = vec![BTreeMap::new(); n];
    let mut put = |rows: &mut Vec<BTreeMap<usize, f64>>, r: usize, c: usize, v: f64| match sym {
        Symmetry::General => {
            rows[r].insert(c, v);
        }
        Symmetry::Symmetric => {
            // always install the pair together so later overwrites keep
            // the matrix exactly symmetric
            rows[r].insert(c, v);
            rows[c].insert(r, v);
        }
        Symmetry::SkewSymmetric => {
            if r != c {
                rows[r].insert(c, v);
                rows[c].insert(r, -v);
            }
        }
    };
    for r in 0..n {
        let deg = match family {
            0 => 1 + rng.range(0, 3),
            1 => 2,
            2 => {
                if r < n / 8 + 1 {
                    1 + rng.range(0, n.min(6))
                } else {
                    1
                }
            }
            _ => 1 + rng.range(0, 2),
        };
        for _ in 0..deg {
            let c = match family {
                // uniform / power-law: anywhere
                0 | 2 => rng.range(0, n),
                // band: within ±2 of the diagonal
                1 => (r + rng.range(0, 5)).saturating_sub(2).min(n - 1),
                // diagonal-heavy: the diagonal itself plus a rare scatter
                _ => {
                    if rng.below(4) == 0 {
                        rng.range(0, n)
                    } else {
                        r
                    }
                }
            };
            let v = field_value(rng, field);
            put(&mut rows, r, c, v);
        }
    }
    csr_from_rows(n, rows)
}

#[test]
fn roundtrip_bit_identical_per_family_field_symmetry() {
    for family in 0..4usize {
        for field in Field::ALL {
            for sym in Symmetry::ALL {
                if field == Field::Pattern && sym == Symmetry::SkewSymmetric {
                    continue; // unrepresentable by construction
                }
                let name = format!(
                    "mmio-roundtrip/family{family}/{}/{}",
                    field.as_str(),
                    sym.as_str()
                );
                check(
                    &name,
                    8,
                    24,
                    |rng, size| gen_matrix(rng, size, family, field, sym),
                    |m| {
                        let mut buf = Vec::new();
                        mmio::write_matrix_market_with(m, field, sym, &mut buf)
                            .map_err(|e| format!("write failed: {e:#}"))?;
                        let back = mmio::read_matrix_market(buf.as_slice())
                            .map_err(|e| format!("read failed: {e:#}"))?;
                        if back != *m {
                            return Err(format!(
                                "round trip not bit-identical: {} nnz in, {} nnz out",
                                m.nnz(),
                                back.nnz()
                            ));
                        }
                        Ok(())
                    },
                );
            }
        }
    }
}

#[test]
fn general_real_writer_roundtrips_any_finite_matrix() {
    // the default writer must round-trip arbitrary f64 values (17
    // significant digits), not just the dyadic ones above
    check(
        "mmio-roundtrip/general-real-arbitrary",
        16,
        32,
        |rng, size| {
            let n = size.clamp(2, 64);
            let mut rows: Vec<BTreeMap<usize, f64>> = vec![BTreeMap::new(); n];
            for r in 0..n {
                for _ in 0..1 + rng.range(0, 3) {
                    let c = rng.range(0, n);
                    rows[r].insert(c, rng.value());
                }
            }
            csr_from_rows(n, rows)
        },
        |m| {
            let mut buf = Vec::new();
            mmio::write_matrix_market(m, &mut buf).map_err(|e| format!("write: {e:#}"))?;
            let back =
                mmio::read_matrix_market(buf.as_slice()).map_err(|e| format!("read: {e:#}"))?;
            if back != *m {
                return Err("general real round trip not bit-identical".to_string());
            }
            Ok(())
        },
    );
}

/// Corrupt a well-formed file in one of several typed ways and demand the
/// reader rejects it with the matching [`MmioError`] variant — and never
/// panics on any of them.
#[test]
fn malformed_input_rejected_with_typed_errors() {
    let expect = |text: &str| -> MmioError {
        let err = mmio::read_matrix_market(text.as_bytes())
            .expect_err("malformed input must be rejected");
        err.downcast_ref::<MmioError>()
            .unwrap_or_else(|| panic!("untyped rejection for:\n{text}\n  error: {err:#}"))
            .clone()
    };

    check(
        "mmio-reject/typed",
        24,
        16,
        |rng, size| {
            let m = gen_matrix(rng, size, 0, Field::Real, Symmetry::General);
            let mutation = rng.below(6);
            (m, mutation)
        },
        |(m, mutation)| {
            let mut buf = Vec::new();
            mmio::write_matrix_market(m, &mut buf).map_err(|e| format!("write: {e:#}"))?;
            let text = String::from_utf8(buf).map_err(|e| e.to_string())?;
            let mut lines: Vec<String> = text.lines().map(|s| s.to_string()).collect();
            // lines[0] header, lines[1] comment, lines[2] size, body after
            if lines.len() < 4 {
                return Ok(()); // nothing to corrupt on an empty body
            }
            let got = match mutation {
                0 => {
                    // truncate the body
                    lines.pop();
                    expect(&(lines.join("\n") + "\n"))
                }
                1 => {
                    // append a duplicate of the last entry
                    lines.push(lines.last().unwrap().clone());
                    expect(&(lines.join("\n") + "\n"))
                }
                2 => {
                    // out-of-range row index
                    let last = lines.last().unwrap().clone();
                    let mut toks: Vec<&str> = last.split_whitespace().collect();
                    let big = format!("{}", m.rows + 7);
                    toks[0] = &big;
                    *lines.last_mut().unwrap() = toks.join(" ");
                    expect(&(lines.join("\n") + "\n"))
                }
                3 => {
                    // non-finite real value
                    let last = lines.last().unwrap().clone();
                    let mut toks: Vec<&str> = last.split_whitespace().collect();
                    toks[2] = "nan";
                    *lines.last_mut().unwrap() = toks.join(" ");
                    expect(&(lines.join("\n") + "\n"))
                }
                4 => {
                    // complex field in the header
                    lines[0] = "%%MatrixMarket matrix coordinate complex general".to_string();
                    expect(&(lines.join("\n") + "\n"))
                }
                _ => {
                    // extra entry beyond the declared count (fresh
                    // coordinate so the duplicate check can't fire first)
                    lines.push(format!("{} {} 9.0", m.rows, m.cols));
                    let e = mmio::read_matrix_market((lines.join("\n") + "\n").as_bytes())
                        .expect_err("extra entry must be rejected");
                    match e.downcast_ref::<MmioError>() {
                        Some(t) => t.clone(),
                        None => return Err(format!("untyped rejection: {e:#}")),
                    }
                }
            };
            let ok = matches!(
                (mutation, &got),
                (0, MmioError::EntryCountMismatch { .. })
                    | (1, MmioError::Duplicate { .. })
                    | (2, MmioError::OutOfRange { .. })
                    | (3, MmioError::BadReal { .. })
                    | (4, MmioError::UnsupportedField(_))
                    | (5, MmioError::EntryCountMismatch { .. })
                    | (5, MmioError::Duplicate { .. })
            );
            if !ok {
                return Err(format!("mutation {mutation} produced unexpected error {got:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn skew_with_diagonal_rejected_both_directions() {
    // reader: a skew file storing a diagonal entry
    let text = "%%MatrixMarket matrix coordinate real skew-symmetric\n3 3 2\n2 1 1.0\n3 3 2.0\n";
    let err = mmio::read_matrix_market(text.as_bytes()).expect_err("skew diagonal must fail");
    assert_eq!(
        err.downcast_ref::<MmioError>(),
        Some(&MmioError::SkewDiagonal { row: 3 })
    );
    // writer: a matrix with a nonzero diagonal cannot be written skew
    let m = Csr::from_parts(2, 2, vec![0, 1, 2], vec![0, 1], vec![1.0, 1.0]).unwrap();
    assert!(mmio::write_matrix_market_with(&m, Field::Real, Symmetry::SkewSymmetric, Vec::new())
        .is_err());
}

#[test]
fn pattern_skew_symmetric_is_rejected_by_the_writer() {
    let m = Csr::from_parts(2, 2, vec![0, 1, 2], vec![1, 0], vec![1.0, -1.0]).unwrap();
    // values are a valid skew pair but -1.0 is not a pattern value
    assert!(
        mmio::write_matrix_market_with(&m, Field::Pattern, Symmetry::SkewSymmetric, Vec::new())
            .is_err()
    );
}

#[test]
fn arbitrary_garbage_never_panics() {
    // bytes that merely *look* like MatrixMarket must produce Err, not
    // a panic, whatever the corruption
    check(
        "mmio-reject/garbage",
        64,
        12,
        |rng, size| {
            let mut s = String::from("%%MatrixMarket matrix coordinate real general\n");
            for _ in 0..rng.range(0, size.max(1)) {
                match rng.below(5) {
                    0 => s.push_str("1 1 1.0\n"),
                    1 => s.push_str(&format!("{} {} {}\n", rng.below(9), rng.below(9), rng.f64())),
                    2 => s.push_str("% comment\n"),
                    3 => s.push_str("not numbers at all\n"),
                    _ => s.push_str(&format!("{} {}\n", rng.below(5), rng.below(5))),
                }
            }
            s
        },
        |text| {
            // success or typed failure are both fine; a panic is the only
            // losing outcome, and the harness would surface it
            let _ = mmio::read_matrix_market(text.as_bytes());
            Ok(())
        },
    );
}
