//! `cargo bench --bench fig11_num_ranges` — regenerates paper Figure 11:
//! numeric-step performance across the num_1x / 1.5x / 2x / 3x binning
//! ranges, normalized to num_1x.

use opsparse::bench::figures;
use opsparse::gen::suite::SuiteScale;

fn main() {
    let scale = std::env::var("OPSPARSE_SCALE")
        .ok()
        .and_then(|s| SuiteScale::parse(&s))
        .unwrap_or(SuiteScale::Small);
    figures::fig11(scale).expect("fig11");
}
