//! The paper's §1 motivating applications running on the OpSparse
//! pipeline: AMG setup+solve on a Poisson problem, Markov clustering on
//! a community graph, multi-source BFS on an RMAT graph.
//!
//! Run: `cargo run --release --example applications`

use opsparse::apps::amg::{poisson2d, AmgHierarchy};
use opsparse::apps::mcl::{mcl, MclParams};
use opsparse::apps::msbfs::{bfs_scalar, msbfs};
use opsparse::apps::SpgemmContext;
use opsparse::coordinator::{Router, RouterConfig};
use opsparse::gen::kron::Kron;
use opsparse::sparse::ops::spmv;
use opsparse::sparse::Coo;
use opsparse::util::fmt;
use opsparse::util::rng::Rng;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    // ---------------- 1. algebraic multigrid ----------------
    println!("== AMG: 2D Poisson 96x96 (Galerkin products via OpSparse) ==");
    let a = poisson2d(96);
    let t0 = Instant::now();
    let h = AmgHierarchy::build(&a, 0.1, 64, 10)?;
    let t_setup = t0.elapsed();
    println!(
        "  hierarchy: {} levels ({}), setup SpGEMM products {}",
        h.levels.len(),
        h.levels.iter().map(|l| l.a.rows.to_string()).collect::<Vec<_>>().join(" -> "),
        fmt::count(h.setup_spgemm_products)
    );
    let mut rng = Rng::new(5);
    let xstar: Vec<f64> = (0..a.rows).map(|_| rng.value()).collect();
    let b = spmv(&a, &xstar);
    let t0 = Instant::now();
    let (_, iters, rel) = h.solve(&b, 1e-10, 60);
    println!(
        "  solve: {iters} V-cycles to rel residual {rel:.2e} (setup {t_setup:?}, solve {:?})",
        t0.elapsed()
    );
    anyhow::ensure!(rel < 1e-10, "AMG failed to converge");

    // ---- 1b. the same setup on an operator that only fits sharded ----
    // shrink the simulated device's memory budget below the finest-level
    // Galerkin products: the router shards them row-wise across devices
    // and the hierarchy comes out bit-identical
    println!("\n== AMG, row-sharded: device budget below the working set ==");
    // memory-only routing (`interconnect: None`): the demo forces the
    // sharded path on a deliberately tiny budget; with the default
    // interconnect model the router would decline to replicate B for so
    // small a multiply
    let router = Router::new(RouterConfig {
        device_memory_bytes: 64 * 1024,
        max_devices: 4,
        interconnect: None,
        ..Default::default()
    });
    let mut ctx = SpgemmContext::with_router(router);
    let t0 = Instant::now();
    let h_sharded = AmgHierarchy::build_with(&mut ctx, &a, 0.1, 64, 10)?;
    println!(
        "  {} levels, {} multiplies took the sharded route (setup {:?})",
        h_sharded.levels.len(),
        ctx.sharded_multiplies(),
        t0.elapsed()
    );
    anyhow::ensure!(ctx.sharded_multiplies() > 0, "expected sharded Galerkin products");
    anyhow::ensure!(
        h_sharded.levels.last().unwrap().a == h.levels.last().unwrap().a,
        "sharded setup must build bit-identical coarse operators"
    );

    // ---------------- 2. Markov clustering ----------------
    println!("\n== MCL: 4-community graph (expansion = M^2 via OpSparse) ==");
    let k = 12;
    let mut coo = Coo::new(4 * k, 4 * k);
    let mut rng = Rng::new(9);
    for c in 0..4 {
        for i in 0..k {
            for j in 0..k {
                if i != j && rng.f64() < 0.7 {
                    coo.push(c * k + i, c * k + j, 1.0);
                }
            }
        }
        // a weak bridge to the next community
        coo.push(c * k, ((c + 1) % 4) * k, 0.05);
        coo.push(((c + 1) % 4) * k, c * k, 0.05);
    }
    let g = coo.to_csr()?;
    let r = mcl(&g, &MclParams::default())?;
    let n_clusters = r.clusters.iter().collect::<std::collections::HashSet<_>>().len();
    println!(
        "  {} nodes -> {n_clusters} clusters in {} iterations ({} products)",
        g.rows,
        r.iterations,
        fmt::count(r.spgemm_products)
    );
    anyhow::ensure!(n_clusters == 4, "expected 4 communities, got {n_clusters}");

    // ---------------- 3. multi-source BFS ----------------
    println!("\n== MS-BFS: RMAT scale-11 graph, 16 sources (boolean SpGEMM) ==");
    let g = Kron { scale: 11, edge_factor: 8, ..Default::default() }.generate(&mut rng);
    let sources: Vec<u32> = (0..16).map(|i| i * 97 % g.rows as u32).collect();
    let t0 = Instant::now();
    let res = msbfs(&g, &sources);
    let t_bfs = t0.elapsed();
    // spot-check against the scalar oracle
    let gold = bfs_scalar(&g, sources[3]);
    anyhow::ensure!(res.levels[3] == gold, "BFS mismatch vs scalar oracle");
    let reached: usize = res.levels[0].iter().filter(|&&l| l != u32::MAX).count();
    println!(
        "  {} vertices, {} BFS rounds in {t_bfs:?}; source0 reaches {} vertices; verified vs scalar oracle",
        g.rows, res.iterations, reached
    );
    println!("\nall three applications verified OK");
    Ok(())
}
