//! End-to-end driver: the paper's headline experiment (Figs 5+6) on the
//! full 26-matrix synthetic suite — every library, every matrix, results
//! verified, GFLOPS from the simulated V100 timeline, and the headline
//! speedup summary the abstract reports.
//!
//! Run: `cargo run --release --example e2e_suite [tiny|small|medium]`

use opsparse::bench::figures;
use opsparse::gen::suite::SuiteScale;

fn main() -> anyhow::Result<()> {
    let scale = std::env::args()
        .nth(1)
        .and_then(|s| SuiteScale::parse(&s))
        .unwrap_or(SuiteScale::Small);
    // verify=true: every result is checked against the sort-merge
    // reference before its timing is reported
    let normal = figures::fig5(scale, true)?;
    let large = figures::fig6(scale, true)?;
    println!(
        "\ne2e summary: {} normal + {} large matrices, all outputs verified",
        normal.len(),
        large.len()
    );
    println!("paper expectation: OpSparse > spECK ~ nsparse >> cuSPARSE,");
    println!("  avg 7.35x vs cuSPARSE, 1.43x vs nsparse, 1.52x vs spECK (V100)");
    Ok(())
}
