//! Real-matrix corpus harness (the Fig. 5/6 reproduction at real-world
//! diversity): drive the full pipeline → router → sharding → serve stack
//! over a Matrix Market corpus and record per-matrix speedup vs
//! `baselines::cusparse_like`, the route taken, bin-range occupancy, and
//! the simulated makespan into `BENCH_corpus.json`.
//!
//! The corpus has two halves:
//! * **Checked-in fixtures** under `rust/corpus/` (see `gen_fixtures.py`
//!   there): ~12 small `.mtx` stand-ins mirroring the structure of the
//!   paper's SuiteSparse regimes — banded/FEM, power-law, near-diagonal,
//!   symmetric, skew-symmetric, pattern-only, integer. They are sized so
//!   the corpus router's cheap working-set screen proves "fits on one
//!   device", making every route pin deterministic
//!   ([`corpus_router_config`]).
//! * **Synthesized large regimes**: `gen` families big enough that the
//!   router *must* shard them (working set beyond
//!   `DECLINE_SPILL_FACTOR ×` budget), materialized through a
//!   `sparse::mmio` write→read round-trip so the interchange path is
//!   exercised at scale, not just on toy fixtures.
//!
//! Every entry is checked **bit-identical across the unsharded, sharded,
//! and serve paths** — the blocking per-matrix identity gate in CI.

use super::run_and_simulate;
use crate::baselines::Library;
use crate::coordinator::serve::{Serve, ServeConfig};
use crate::coordinator::{Route, Router, RouterConfig};
use crate::gen::banded::Banded;
use crate::gen::powerlaw::PowerLaw;
use crate::gen::stencil::{Grid, Stencil};
use crate::gen::uniform::Uniform;
use crate::gpusim::{simulate, V100};
use crate::sparse::stats::nprod_per_row;
use crate::sparse::{mmio, Csr};
use crate::spgemm::binning::bin_rows;
use crate::spgemm::kernel_tables::{SymbolicRanges, NUM_BINS};
use crate::spgemm::multiply_sharded;
use crate::spgemm::pipeline::OpSparseConfig;
use crate::util::rng::Rng;
use anyhow::{bail, ensure, Context, Result};
use std::path::{Path, PathBuf};

/// The acceptance floor on checked-in `.mtx` fixtures; enforced by
/// `tests/corpus.rs` and the CI corpus gate.
pub const MIN_REAL_FIXTURES: usize = 10;

/// One corpus member: a named square matrix plus where it came from.
pub struct CorpusEntry {
    pub name: String,
    /// `"fixture"` (checked-in `.mtx`) or `"synthesized"` (gen family
    /// materialized through an mmio round-trip).
    pub source: &'static str,
    pub a: Csr,
}

/// Per-matrix measurements for `BENCH_corpus.json`.
#[derive(Clone, Debug)]
pub struct CorpusRow {
    pub name: String,
    pub source: &'static str,
    pub rows: usize,
    pub nnz: usize,
    /// Route the corpus router picks for `A*A` ([`route_label`]).
    pub route: String,
    /// Simulated OpSparse pipeline time (unsharded, V100 model).
    pub opsparse_ns: f64,
    /// Simulated `baselines::cusparse_like` time on the same product.
    pub cusparse_ns: f64,
    /// `cusparse_ns / opsparse_ns` — the Fig. 5/6 metric.
    pub speedup_vs_cusparse: f64,
    pub gflops: f64,
    /// Makespan of the route actually taken: unsharded pipeline time for
    /// `Hash`/`Block`, slowest-shard compute time for `Sharded`.
    pub makespan_ns: f64,
    /// Symbolic-phase bin occupancy (paper Table 4, `sym_1.2x` ranges).
    pub bin_occupancy: [usize; NUM_BINS],
    /// Whether the Algorithm-3 fast path applied (all rows in bin 0).
    pub fast_path: bool,
    pub bit_identical_sharded: bool,
    pub bit_identical_serve: bool,
    pub mmio_roundtrip: bool,
}

/// Whole-corpus report.
pub struct CorpusReport {
    pub dir: String,
    pub fixtures: usize,
    pub synthesized: usize,
    /// Every row bit-identical across unsharded/sharded/serve AND
    /// mmio-round-trip clean — the blocking CI verdict.
    pub all_bit_identical: bool,
    pub rows: Vec<CorpusRow>,
}

/// Router configuration the corpus is sized against: a deliberately tight
/// 256 KiB device budget and a 4-device fleet, so the checked-in fixtures
/// provably fit unsharded (their pessimistic working set stays under the
/// budget) while the synthesized large regimes overshoot
/// `DECLINE_SPILL_FACTOR ×` budget and *must* shard. `ns_per_prod` stays
/// the static 1.0 proxy — no live fit — so routes cannot drift between
/// runs.
pub fn corpus_router_config() -> RouterConfig {
    RouterConfig {
        device_memory_bytes: 256 * 1024,
        max_devices: 4,
        ..Default::default()
    }
}

/// Locate the corpus directory: explicit argument, then
/// `OPSPARSE_CORPUS_DIR`, then the first of `corpus/` / `rust/corpus/`
/// that exists (the bench runs from either the repo root or `rust/`).
pub fn resolve_corpus_dir(explicit: Option<&str>) -> PathBuf {
    if let Some(d) = explicit {
        return PathBuf::from(d);
    }
    if let Ok(d) = std::env::var("OPSPARSE_CORPUS_DIR") {
        if !d.is_empty() {
            return PathBuf::from(d);
        }
    }
    for cand in ["corpus", "rust/corpus", "../corpus"] {
        let p = PathBuf::from(cand);
        if p.is_dir() {
            return p;
        }
    }
    PathBuf::from("corpus")
}

/// Load every `.mtx` fixture in `dir`, sorted by name for stable output.
pub fn load_corpus(dir: &Path) -> Result<Vec<CorpusEntry>> {
    let mut entries = Vec::new();
    let rd = std::fs::read_dir(dir)
        .with_context(|| format!("corpus dir {} (set OPSPARSE_CORPUS_DIR?)", dir.display()))?;
    let mut paths: Vec<PathBuf> = rd
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "mtx"))
        .collect();
    paths.sort();
    for p in paths {
        let name = p
            .file_stem()
            .and_then(|s| s.to_str())
            .context("non-utf8 fixture name")?
            .to_string();
        let a = mmio::read_file(&p).with_context(|| format!("fixture {}", p.display()))?;
        ensure!(a.rows == a.cols, "fixture {name} must be square for A*A");
        entries.push(CorpusEntry { name, source: "fixture", a });
    }
    ensure!(!entries.is_empty(), "no .mtx fixtures found in {}", dir.display());
    Ok(entries)
}

/// Synthesize the large regimes the fixtures are too small for, each
/// materialized through an mmio `write→read` round-trip that must be
/// bit-identical (the round-tripped matrix is what the harness runs on).
/// All are sized past `2×` the corpus budget, so the router must shard.
pub fn synthesized_entries() -> Result<Vec<CorpusEntry>> {
    let mut out = Vec::new();
    let specs: [(&str, Box<dyn Fn(&mut Rng) -> Csr>); 4] = [
        (
            "syn_uniform_large",
            Box::new(|rng| Uniform { n: 2048, per_row: 12, jitter: 4 }.generate(rng)),
        ),
        (
            "syn_powerlaw_large",
            Box::new(|rng| {
                PowerLaw {
                    n: 2048,
                    alpha: 2.2,
                    max_row: 200,
                    mean_row: 8.0,
                    hub_frac: 0.1,
                    forced_giant_rows: 0,
                }
                .generate(rng)
            }),
        ),
        (
            "syn_banded_large",
            Box::new(|rng| {
                Banded { n: 2048, per_row: 16, band: 48, contiguous_frac: 1.0 }.generate(rng)
            }),
        ),
        (
            "syn_stencil_large",
            Box::new(|rng| {
                Stencil { n: 2025, grid: Grid::D2, reach: 2, keep: 1.0, diagonal: true }
                    .generate(rng)
            }),
        ),
    ];
    for (i, (name, build)) in specs.iter().enumerate() {
        let mut rng = Rng::new(0x5EED_C0DE + i as u64);
        let a = build(&mut rng);
        // materialize through the interchange format: write → read must be
        // bit-identical, and the round-tripped matrix is what runs
        let mut buf = Vec::new();
        mmio::write_matrix_market(&a, &mut buf)?;
        let back = mmio::read_matrix_market(buf.as_slice())
            .with_context(|| format!("round-trip {name}"))?;
        ensure!(back == a, "mmio round-trip not bit-identical for {name}");
        out.push(CorpusEntry { name: name.to_string(), source: "synthesized", a: back });
    }
    Ok(out)
}

/// Stable display form of a route for JSON and route-pin tests.
pub fn route_label(route: &Route) -> String {
    match route {
        Route::Hash => "Hash".to_string(),
        Route::Block => "Block".to_string(),
        Route::Sharded { n_devices } => format!("Sharded:{n_devices}"),
        Route::ShardedBlock { n_devices } => format!("ShardedBlock:{n_devices}"),
    }
}

/// Run the whole corpus (fixtures in `dir` + synthesized regimes) through
/// pipeline, baseline, router, sharded execution, and the serve front
/// door.
pub fn run_corpus(dir: &Path) -> Result<CorpusReport> {
    let mut entries = load_corpus(dir)?;
    let fixtures = entries.len();
    entries.extend(synthesized_entries()?);
    let synthesized = entries.len() - fixtures;

    let router = Router::new(corpus_router_config());
    let serve_cfg = ServeConfig {
        workers: 2,
        device_memory_bytes: 256 * 1024,
        max_devices: 4,
        ns_per_prod: Some(1.0),
        ..Default::default()
    };
    let serve = Serve::start(serve_cfg)?;

    // collect, then shut the serve stack down before propagating failures
    let mut rows: Vec<Result<CorpusRow>> = Vec::with_capacity(entries.len());
    for e in &entries {
        rows.push(run_entry(e, &router, &serve));
    }
    serve.shutdown();
    let rows: Vec<CorpusRow> = rows.into_iter().collect::<Result<_>>()?;

    let all_bit_identical = rows
        .iter()
        .all(|r| r.bit_identical_sharded && r.bit_identical_serve && r.mmio_roundtrip);
    Ok(CorpusReport {
        dir: dir.display().to_string(),
        fixtures,
        synthesized,
        all_bit_identical,
        rows,
    })
}

fn run_entry(e: &CorpusEntry, router: &Router, serve: &Serve) -> Result<CorpusRow> {
    let a = &e.a;
    // unsharded pipeline (verified against the dense reference) + baseline
    let (out, tl) = run_and_simulate(Library::OpSparse, a, true)
        .with_context(|| format!("{}: opsparse", e.name))?;
    let (_cus_out, cus_tl) = run_and_simulate(Library::Cusparse, a, false)
        .with_context(|| format!("{}: cusparse_like", e.name))?;
    let opsparse_ns = tl.total_ns;
    let cusparse_ns = cus_tl.total_ns;

    // route + symbolic bin occupancy under the paper's adopted ranges
    let route = router.route(a, a);
    let sizes = nprod_per_row(a, a);
    let binned = bin_rows(&sizes, &SymbolicRanges::Sym12x.ranges());

    // sharded execution must stitch bit-identically on every matrix, not
    // just the ones the router would shard
    let n_shards = match route {
        Route::Sharded { n_devices } => n_devices,
        _ => 2,
    };
    let cfg = OpSparseConfig::default();
    let sharded = multiply_sharded(a, a, &cfg, n_shards)
        .with_context(|| format!("{}: sharded x{n_shards}", e.name))?;
    let bit_identical_sharded = sharded.c == out.c;
    let makespan_ns = match route {
        Route::Sharded { .. } => sharded
            .traces()
            .map(|t| simulate(t, &V100).total_ns)
            .fold(0.0f64, f64::max),
        _ => opsparse_ns,
    };

    // serve front door: same request through coalesce/batch/admission
    let ticket = serve.submit("corpus", a.clone(), a.clone());
    let result = ticket.wait();
    let served = result
        .csr()
        .with_context(|| format!("{}: serve path returned no result", e.name))?;
    let bit_identical_serve = **served == out.c;

    // interchange: the general-form writer must round-trip every corpus
    // member bit-identically (fixtures included, whatever their original
    // field/symmetry storage was)
    let mut buf = Vec::new();
    mmio::write_matrix_market(a, &mut buf)?;
    let mmio_roundtrip = mmio::read_matrix_market(buf.as_slice())? == *a;

    if opsparse_ns <= 0.0 {
        bail!("{}: degenerate simulated time", e.name);
    }
    Ok(CorpusRow {
        name: e.name.clone(),
        source: e.source,
        rows: a.rows,
        nnz: a.nnz(),
        route: route_label(&route),
        opsparse_ns,
        cusparse_ns,
        speedup_vs_cusparse: cusparse_ns / opsparse_ns,
        gflops: super::gflops(&out, &tl),
        makespan_ns,
        bin_occupancy: binned.bin_size,
        fast_path: binned.fast_path,
        bit_identical_sharded,
        bit_identical_serve,
        mmio_roundtrip,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_router_budget_is_tight_and_fleet_small() {
        let cfg = corpus_router_config();
        assert_eq!(cfg.device_memory_bytes, 256 * 1024);
        assert_eq!(cfg.max_devices, 4);
    }

    #[test]
    fn synthesized_regimes_all_shard() {
        let router = Router::new(corpus_router_config());
        for e in synthesized_entries().unwrap() {
            let route = router.route(&e.a, &e.a);
            assert!(
                matches!(route, Route::Sharded { .. }),
                "{} must shard under the corpus budget, got {route:?}",
                e.name
            );
        }
    }

    #[test]
    fn route_labels_are_stable() {
        assert_eq!(route_label(&Route::Hash), "Hash");
        assert_eq!(route_label(&Route::Block), "Block");
        assert_eq!(route_label(&Route::Sharded { n_devices: 3 }), "Sharded:3");
        assert_eq!(
            route_label(&Route::ShardedBlock { n_devices: 3 }),
            "ShardedBlock:3"
        );
    }
}
