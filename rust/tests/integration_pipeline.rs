//! End-to-end pipeline integration: all four libraries on the full Tiny
//! suite, verified element-exact against the sort-merge reference, plus
//! file IO round trips and the coordinator service.

use opsparse::baselines::Library;
use opsparse::coordinator::{Coordinator, Job, Router};
use opsparse::gen::suite::{entries, SuiteScale};
use opsparse::sparse::mmio;
use opsparse::spgemm::reference::spgemm_reference;

#[test]
fn full_tiny_suite_all_libraries_verified() {
    for e in entries() {
        let a = e.generate(SuiteScale::Tiny);
        let gold = spgemm_reference(&a, &a);
        for lib in Library::all() {
            // mirror the paper: cuSPARSE skips the large matrices
            if e.large && lib == Library::Cusparse {
                continue;
            }
            let out = lib
                .run(&a, &a)
                .unwrap_or_else(|err| panic!("{} failed on {}: {err:#}", lib.name(), e.name));
            if let Some(d) = out.c.diff(&gold, 1e-9) {
                panic!("{} wrong on {}: {d}", lib.name(), e.name);
            }
            out.c.validate().unwrap();
        }
    }
}

#[test]
fn mtx_roundtrip_preserves_spgemm_result() {
    let e = entries().into_iter().find(|e| e.name == "poisson3Da").unwrap();
    let a = e.generate(SuiteScale::Tiny);
    let tmp = std::env::temp_dir().join("opsparse_roundtrip.mtx");
    mmio::write_file(&a, &tmp).unwrap();
    let back = mmio::read_file(&tmp).unwrap();
    assert_eq!(a, back);
    let _ = std::fs::remove_file(&tmp);
}

#[test]
fn coordinator_processes_suite_jobs_concurrently() {
    let coord = Coordinator::start(4, Router::default(), None);
    let mats: Vec<_> = entries()
        .into_iter()
        .filter(|e| !e.large)
        .take(6)
        .map(|e| e.generate(SuiteScale::Tiny))
        .collect();
    for (i, a) in mats.iter().enumerate() {
        coord.submit(Job { id: i as u64, a: a.clone(), b: a.clone(), force_route: None });
    }
    for _ in 0..mats.len() {
        let r = coord.recv().unwrap();
        let a = &mats[r.id as usize];
        let gold = spgemm_reference(a, a);
        assert!(r.c.unwrap().approx_eq(&gold, 1e-9), "job {}", r.id);
    }
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.jobs_completed, mats.len() as u64);
    coord.shutdown();
}

#[test]
fn opsparse_wins_on_trace_efficiency_metrics() {
    // structural assertions that hold regardless of the cost model:
    // fewer mallocs, fewer malloc'd bytes, fewer global atomics
    let e = entries().into_iter().find(|e| e.name == "filter3D").unwrap();
    let a = e.generate(SuiteScale::Tiny);
    let ops = Library::OpSparse.run(&a, &a).unwrap();
    let nsp = Library::Nsparse.run(&a, &a).unwrap();
    let spk = Library::Speck.run(&a, &a).unwrap();
    assert!(ops.trace.malloc_calls() < nsp.trace.malloc_calls());
    assert!(ops.trace.malloc_bytes() < spk.trace.malloc_bytes());
    let atomics = |t: &opsparse::gpusim::Trace| -> u64 {
        t.ops
            .iter()
            .filter_map(|op| match op {
                opsparse::gpusim::TraceOp::Launch(k) => Some(k.total_work().global_atomics),
                _ => None,
            })
            .sum()
    };
    assert!(atomics(&ops.trace) < atomics(&nsp.trace) / 10);
}
