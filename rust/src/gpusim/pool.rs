//! Reusable device-memory pool: the cross-call allocation layer the paper
//! motivates in §4.4/§5.3–§5.5 but never builds.
//!
//! OpSparse minimizes the *per-call* cost of `cudaMalloc` (combining
//! metadata allocations, overlapping mallocs with kernels, deferring
//! frees). A serving system multiplies matrices millions of times, so the
//! next step is to stop paying `cudaMalloc` at all on warm calls: a
//! grow-only, size-bucketed arena in the style of `cudaMallocAsync` /
//! RMM's pool resource. Allocations round up to power-of-two buckets;
//! a bucket hit costs only host bookkeeping (no trace op — the real
//! pooled allocator is ~100 ns of free-list work), while a miss issues a
//! real `cudaMalloc` into the [`Trace`] and grows the footprint
//! permanently. Releases are stream-ordered: blocks return to the free
//! lists with **no** `cudaFree` (and therefore none of `cudaFree`'s
//! implicit device synchronization, §4.6) until [`DevicePool::drain`].
//!
//! # Example
//!
//! ```
//! use opsparse::gpusim::{DevicePool, Trace};
//!
//! let mut pool = DevicePool::new();
//! let mut cold = Trace::new();
//! pool.alloc(&mut cold, 1 << 20, "c_val", "alloc_c");
//! pool.end_call(); // stream-ordered release: no cudaFree emitted
//! assert_eq!(cold.malloc_calls(), 1); // first call grows the pool
//!
//! let mut warm = Trace::new();
//! pool.alloc(&mut warm, 1 << 20, "c_val", "alloc_c");
//! pool.end_call();
//! assert_eq!(warm.malloc_calls(), 0); // bucket hit: no cudaMalloc
//! assert_eq!(pool.stats().pool_hits, 1);
//! ```

use super::trace::Trace;

/// Smallest bucket: `cudaMalloc` granularity is 256 B on every modern GPU.
pub const MIN_BUCKET_BYTES: usize = 256;

const MIN_BUCKET_LOG2: u32 = MIN_BUCKET_BYTES.trailing_zeros();

/// Cumulative pool counters. All byte counts are in rounded (bucketed)
/// bytes, matching what the device would actually reserve.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Allocation requests served (hits + misses).
    pub requests: u64,
    /// Requests satisfied from a free bucket (no `cudaMalloc`).
    pub pool_hits: u64,
    /// Real `cudaMalloc` calls issued (pool growth).
    pub device_mallocs: u64,
    /// Cumulative bytes obtained from `cudaMalloc` (never decreases; the
    /// current reservation is [`DevicePool::footprint_bytes`]).
    pub device_bytes: u64,
    /// Bytes served from recycled buckets instead of the device.
    pub reused_bytes: u64,
    /// Peak bytes simultaneously checked out of the pool.
    pub high_water_bytes: u64,
}

impl PoolStats {
    /// Counter increments since `earlier` (a snapshot taken before some
    /// window of work). `high_water_bytes` carries the later absolute peak.
    pub fn delta_since(&self, earlier: &PoolStats) -> PoolStats {
        PoolStats {
            requests: self.requests - earlier.requests,
            pool_hits: self.pool_hits - earlier.pool_hits,
            device_mallocs: self.device_mallocs - earlier.device_mallocs,
            device_bytes: self.device_bytes - earlier.device_bytes,
            reused_bytes: self.reused_bytes - earlier.reused_bytes,
            high_water_bytes: self.high_water_bytes,
        }
    }

    /// Fraction of requests served without touching `cudaMalloc`.
    pub fn hit_rate(&self) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        self.pool_hits as f64 / self.requests as f64
    }
}

/// Size-bucketed, grow-only device memory arena with call-scoped
/// stream-ordered release. One pool per worker (single owner, like a CUDA
/// context) — no interior locking.
#[derive(Debug, Default)]
pub struct DevicePool {
    /// Free block count per power-of-two bucket (`bucket 0` ==
    /// [`MIN_BUCKET_BYTES`]).
    free: Vec<u32>,
    /// Buckets handed out since the last [`DevicePool::end_call`].
    live: Vec<usize>,
    in_use_bytes: u64,
    /// Bytes currently reserved from the device (drops on drain; the
    /// counters in `stats` are strictly cumulative so deltas never
    /// underflow across a drain).
    footprint_bytes: u64,
    stats: PoolStats,
}

/// Bucket index and rounded size for a request.
fn bucket_of(bytes: usize) -> (usize, usize) {
    let rounded = bytes.max(1).next_power_of_two().max(MIN_BUCKET_BYTES);
    ((rounded.trailing_zeros() - MIN_BUCKET_LOG2) as usize, rounded)
}

impl DevicePool {
    pub fn new() -> Self {
        DevicePool::default()
    }

    /// Cumulative counters.
    pub fn stats(&self) -> PoolStats {
        self.stats
    }

    /// The device footprint in bytes (grow-only until [`DevicePool::drain`]).
    pub fn footprint_bytes(&self) -> u64 {
        self.footprint_bytes
    }

    /// Bytes currently checked out.
    pub fn in_use_bytes(&self) -> u64 {
        self.in_use_bytes
    }

    /// Allocate `bytes` for the current call. A pooled block is recycled
    /// silently; otherwise a real `cudaMalloc` of the rounded size is
    /// emitted on `trace`. Returns the rounded size.
    pub fn alloc(
        &mut self,
        trace: &mut Trace,
        bytes: usize,
        label: &str,
        step: &'static str,
    ) -> usize {
        let (bucket, rounded) = bucket_of(bytes);
        if self.free.len() <= bucket {
            self.free.resize(bucket + 1, 0);
        }
        self.stats.requests += 1;
        if self.free[bucket] > 0 {
            self.free[bucket] -= 1;
            self.stats.pool_hits += 1;
            self.stats.reused_bytes += rounded as u64;
        } else {
            self.stats.device_mallocs += 1;
            self.stats.device_bytes += rounded as u64;
            self.footprint_bytes += rounded as u64;
            trace.malloc(rounded, format!("pool:{label}"), step);
        }
        self.in_use_bytes += rounded as u64;
        if self.in_use_bytes > self.stats.high_water_bytes {
            self.stats.high_water_bytes = self.in_use_bytes;
        }
        self.live.push(bucket);
        rounded
    }

    /// Return every allocation of the current call to the free lists —
    /// the pooled analog of the cleanup step's deferred frees, except no
    /// `cudaFree` (and no implicit device sync) ever runs.
    pub fn end_call(&mut self) {
        for bucket in self.live.drain(..) {
            self.free[bucket] += 1;
            self.in_use_bytes -= (MIN_BUCKET_BYTES << bucket) as u64;
        }
    }

    /// Release the whole footprint back to the device (process teardown).
    /// Emits a single `cudaFree` op: real pools free their arenas in one
    /// sweep. Outstanding call allocations are returned first.
    pub fn drain(&mut self, trace: &mut Trace, step: &'static str) {
        self.end_call();
        if self.footprint_bytes > 0 {
            trace.free("device_pool", step);
        }
        self.free.clear();
        self.in_use_bytes = 0;
        self.footprint_bytes = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rounds_to_power_of_two_buckets() {
        assert_eq!(bucket_of(0), (0, 256));
        assert_eq!(bucket_of(1), (0, 256));
        assert_eq!(bucket_of(256), (0, 256));
        assert_eq!(bucket_of(257), (1, 512));
        assert_eq!(bucket_of(4096), (4, 4096));
        assert_eq!(bucket_of(5000), (5, 8192));
    }

    #[test]
    fn second_call_with_same_sizes_is_all_hits() {
        let mut pool = DevicePool::new();
        let mut t1 = Trace::new();
        pool.alloc(&mut t1, 1000, "meta", "setup");
        pool.alloc(&mut t1, 50_000, "c_col", "alloc_c");
        pool.alloc(&mut t1, 100_000, "c_val", "alloc_c");
        pool.end_call();
        assert_eq!(t1.malloc_calls(), 3);
        let before = pool.stats();

        let mut t2 = Trace::new();
        pool.alloc(&mut t2, 1000, "meta", "setup");
        pool.alloc(&mut t2, 50_000, "c_col", "alloc_c");
        pool.alloc(&mut t2, 100_000, "c_val", "alloc_c");
        pool.end_call();
        assert_eq!(t2.malloc_calls(), 0, "warm call must not touch cudaMalloc");
        let d = pool.stats().delta_since(&before);
        assert_eq!(d.device_bytes, 0);
        assert_eq!(d.pool_hits, 3);
        assert!(d.reused_bytes > 0);
    }

    #[test]
    fn bigger_request_grows_smaller_reuses() {
        let mut pool = DevicePool::new();
        let mut t = Trace::new();
        pool.alloc(&mut t, 10_000, "a", "setup"); // 16 KiB bucket
        pool.end_call();
        // smaller request in the same bucket range still misses (different
        // bucket), but an equal-bucket request hits
        let mut t2 = Trace::new();
        pool.alloc(&mut t2, 9_000, "b", "setup"); // also 16 KiB
        pool.end_call();
        assert_eq!(t2.malloc_calls(), 0);
        let mut t3 = Trace::new();
        pool.alloc(&mut t3, 20_000, "c", "setup"); // 32 KiB: grow
        pool.end_call();
        assert_eq!(t3.malloc_calls(), 1);
    }

    #[test]
    fn high_water_tracks_concurrent_use() {
        let mut pool = DevicePool::new();
        let mut t = Trace::new();
        pool.alloc(&mut t, 256, "a", "setup");
        pool.alloc(&mut t, 256, "b", "setup");
        pool.end_call();
        // two buckets live at once => 512 peak, even though later calls
        // use one at a time
        pool.alloc(&mut t, 256, "c", "setup");
        pool.end_call();
        assert_eq!(pool.stats().high_water_bytes, 512);
        assert_eq!(pool.in_use_bytes(), 0);
    }

    #[test]
    fn drain_emits_one_free_and_resets_footprint() {
        let mut pool = DevicePool::new();
        let mut t = Trace::new();
        pool.alloc(&mut t, 4096, "a", "setup");
        pool.drain(&mut t, "cleanup");
        assert_eq!(pool.footprint_bytes(), 0);
        let frees = t
            .ops
            .iter()
            .filter(|op| matches!(op, crate::gpusim::TraceOp::Free { .. }))
            .count();
        assert_eq!(frees, 1);
        // after a drain the next alloc is a fresh device malloc
        let mut t2 = Trace::new();
        pool.alloc(&mut t2, 4096, "a", "setup");
        assert_eq!(t2.malloc_calls(), 1);
    }
}
