//! `cargo bench --bench shard_scaling` — row-sharded multi-device SpGEMM
//! on a power-law matrix at 1/2/4/8 shards: per-device makespan under the
//! serial **and** the overlapped (pipelined broadcast/compute/gather)
//! schedule, modeled `B`-broadcast and `C`-gather interconnect costs,
//! planned and measured load imbalance, and both (honest,
//! communication-charged) scaling-efficiency columns.
//!
//! Env:
//! * `OPSPARSE_SCALE=tiny|small|medium` (default small)
//! * `OPSPARSE_INTERCONNECT=pcie|nvlink|none` (default pcie)
//! * `OPSPARSE_OVERLAP=off` — disable the pipelined schedule (ablation)
//! * `OPSPARSE_OVERLAP_CHUNK_KB=<n>` — broadcast chunk size (default 1024)
//! * `OPSPARSE_BENCH_JSON=<path>` — record the full rows as JSON; CI
//!   writes `BENCH_shards.json` this way, next to `BENCH_seed.json`.
//! * `OPSPARSE_BENCH_JSON_OVERLAP=<path>` — record the serial-vs-
//!   overlapped makespan ablation (`BENCH_overlap.json` in CI, whose
//!   blocking check reads the embedded Welch-gate verdict).
//! * `OPSPARSE_REPLAN=on` — also run the adaptive re-planning ablation
//!   (cold proxy-cut vs warm measured re-cut per generator family and
//!   shard count) through its statistical gate.
//! * `OPSPARSE_BENCH_JSON_ADAPTIVE=<path>` — record that ablation
//!   (`BENCH_adaptive.json` in CI, gated the same way).
//! * `OPSPARSE_STAT_{MIN_REPS,MAX_REPS,REL_HW,ALPHA}` — statistical
//!   runner knobs (see `util::stats::AdaptiveConfig::from_env`).
//!
//! Both invariants run as one-sided Welch hypothesis tests over
//! adaptively many seeded repetitions (`util::stats`): the bench fails
//! only when the candidate is *significantly* worse at alpha, never on a
//! single unlucky draw.

use opsparse::bench::{figures, write_adaptive_json, write_overlap_json, write_shard_scaling_json};
use opsparse::gen::suite::SuiteScale;
use opsparse::gpusim::{Interconnect, OverlapConfig};
use opsparse::util::stats::AdaptiveConfig;

fn main() {
    let scale = std::env::var("OPSPARSE_SCALE")
        .ok()
        .and_then(|s| SuiteScale::parse(&s))
        .unwrap_or(SuiteScale::Small);
    let ic = match std::env::var("OPSPARSE_INTERCONNECT").as_deref() {
        Ok(name) => Interconnect::parse_opt(name).expect("pcie|nvlink|none"),
        Err(_) => Some(Interconnect::pcie3()),
    };
    let overlap = OverlapConfig::from_env();
    let rows =
        figures::shard_scaling_with(scale, ic.as_ref(), overlap).expect("shard_scaling bench");
    if let Ok(path) = std::env::var("OPSPARSE_BENCH_JSON") {
        write_shard_scaling_json(&path, scale, &rows).expect("write bench json");
    }
    // overlap dominance, statistically: sum serial and overlapped
    // makespans per seeded repetition, Welch one-sided at alpha
    let stat = AdaptiveConfig::from_env();
    let (grows, gate) = figures::overlap_gate(scale, &stat).expect("overlap gate");
    if let Ok(path) = std::env::var("OPSPARSE_BENCH_JSON_OVERLAP") {
        write_overlap_json(&path, scale, &grows, std::slice::from_ref(&gate))
            .expect("write overlap json");
    }
    assert!(
        gate.pass,
        "{}: overlapped makespan significantly worse than serial \
         (p={:.4} < alpha={}, {:.1}us vs {:.1}us over {} reps)",
        gate.name,
        gate.p,
        gate.alpha,
        gate.candidate_mean / 1e3,
        gate.reference_mean / 1e3,
        gate.reps_candidate
    );
    let replan_on = std::env::var("OPSPARSE_REPLAN")
        .ok()
        .and_then(|v| opsparse::coordinator::feedback::parse_on_off(&v))
        .unwrap_or(false);
    if replan_on {
        // per-cell warm <= cold stays a hard ensure! inside
        // adaptive_replan_seeded; this is the aggregate statistical gate
        let (arows, agate) = figures::adaptive_gate(scale, &stat).expect("adaptive gate");
        if let Ok(path) = std::env::var("OPSPARSE_BENCH_JSON_ADAPTIVE") {
            write_adaptive_json(&path, scale, &arows, std::slice::from_ref(&agate))
                .expect("write adaptive json");
        }
        assert!(
            agate.pass,
            "{}: warm makespan significantly worse than cold \
             (p={:.4} < alpha={}, {:.1}us vs {:.1}us over {} reps)",
            agate.name,
            agate.p,
            agate.alpha,
            agate.candidate_mean / 1e3,
            agate.reference_mean / 1e3,
            agate.reps_candidate
        );
    }
}
