//! Dense row-major matrix — used as a brute-force oracle in tests and for
//! tiny examples; never on the hot path.

use super::csr::Csr;

/// Dense row-major f64 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Dense {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Dense {
    pub fn zero(rows: usize, cols: usize) -> Self {
        Dense { rows, cols, data: vec![0.0; rows * cols] }
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    /// Dense matmul oracle (O(n^3)); for tiny test matrices only.
    pub fn matmul(&self, other: &Dense) -> Dense {
        assert_eq!(self.cols, other.rows);
        let mut out = Dense::zero(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.get(i, k);
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out.data[i * other.cols + j] += a * other.get(k, j);
                }
            }
        }
        out
    }

    /// Convert to CSR, dropping exact zeros.
    pub fn to_csr(&self) -> Csr {
        let mut rpt = vec![0usize; self.rows + 1];
        let mut col = Vec::new();
        let mut val = Vec::new();
        for i in 0..self.rows {
            for j in 0..self.cols {
                let v = self.get(i, j);
                if v != 0.0 {
                    col.push(j as u32);
                    val.push(v);
                }
            }
            rpt[i + 1] = col.len();
        }
        Csr { rows: self.rows, cols: self.cols, rpt, col, val }
    }
}

impl From<&Csr> for Dense {
    fn from(m: &Csr) -> Self {
        let mut out = Dense::zero(m.rows, m.cols);
        for i in 0..m.rows {
            let (cols, vals) = m.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                out.set(i, c as usize, v);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_csr_roundtrip() {
        let mut d = Dense::zero(3, 4);
        d.set(0, 1, 2.0);
        d.set(2, 3, -1.5);
        let c = d.to_csr();
        c.validate().unwrap();
        assert_eq!(Dense::from(&c), d);
    }

    #[test]
    fn matmul_identity() {
        let i3 = Dense::from(&Csr::identity(3));
        let mut a = Dense::zero(3, 3);
        a.set(0, 2, 5.0);
        a.set(1, 1, -2.0);
        assert_eq!(a.matmul(&i3), a);
        assert_eq!(i3.matmul(&a), a);
    }

    #[test]
    fn matmul_known() {
        // [[1,2],[3,4]] * [[5,6],[7,8]] = [[19,22],[43,50]]
        let a = Dense { rows: 2, cols: 2, data: vec![1.0, 2.0, 3.0, 4.0] };
        let b = Dense { rows: 2, cols: 2, data: vec![5.0, 6.0, 7.0, 8.0] };
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }
}
