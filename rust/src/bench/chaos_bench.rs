//! `bench chaos` — the shard fleet under deterministic fault injection,
//! with and without straggler speculation.
//!
//! Four rows: {gentle, aggressive} × {speculate off, speculate on}.
//! Every row drives the same force-sharded job stream through a
//! 4-worker coordinator with the chaos preset active, then reports the
//! failure-domain contract figures CI blocks on:
//!
//! * **completion rate** — parents that produced an `Ok` result. Under
//!   `gentle` the rare kills are always absorbed by the requeue path (a
//!   chain fails only after `MAX_REQUEUES` consecutive deaths,
//!   p ≈ 0.02⁶ per chain); CI blocks on an exact binomial test of the
//!   pooled rate against [`GENTLE_COMPLETION_P0`], adding repetitions
//!   on a marginal verdict rather than failing one unlucky seed. Under
//!   `aggressive` the budget can genuinely exhaust — the contract there
//!   is the next bullet.
//! * **bit-identity** — every `Ok` result equals the undisturbed
//!   reference bitwise, whatever the kill/delay/requeue interleaving
//!   did. A failed parent must carry a typed error; a hang (any parent
//!   that never reported) fails the bench.
//! * **p50/p99 makespan** — end-to-end parent wall time, with and
//!   without speculation, so `BENCH_chaos.json` records what backup
//!   sub-jobs buy under injected stragglers.
//!
//! The chaos schedule is seeded (`--chaos-seed`, default below), so a
//! CI failure replays locally with the same kill/delay stream per
//! worker generation. (Which *worker* dequeues which sub-job still
//! depends on thread scheduling; determinism of the full metrics
//! snapshot needs one worker — `tests/chaos.rs` pins that separately.)

use crate::coordinator::barrier::SpeculateConfig;
use crate::coordinator::chaos::ChaosConfig;
use crate::coordinator::feedback::ReplanConfig;
use crate::coordinator::router::Route;
use crate::coordinator::{Coordinator, Job, Router};
use crate::gen::uniform::Uniform;
use crate::sparse::Csr;
use crate::spgemm::reference::spgemm_reference;
use crate::util::rng::Rng;
use crate::util::stats::{completion_gate, AdaptiveConfig, GateResult};
use anyhow::Result;
use std::time::Duration;

/// Default root seed for the deterministic chaos schedule.
pub const DEFAULT_CHAOS_SEED: u64 = 0xC0FFEE;

/// Null-hypothesis per-job completion probability for the `gentle`
/// preset. The requeue path absorbs a chain only after `MAX_REQUEUES`
/// consecutive deaths (p ≈ 0.02⁶ per chain), so the true rate is far
/// above this; the gate fails only when the pooled evidence says the
/// rate has genuinely dropped below it.
pub const GENTLE_COMPLETION_P0: f64 = 0.995;

/// Workers in the fleet under test (shards fan out over all of them).
const WORKERS: usize = 4;

/// Shards per parent job (forced, so routing noise never changes the
/// sub-job count).
const SHARDS: usize = 4;

/// Longest we wait for any single parent before declaring a hang — the
/// one outcome the failure-domain contract forbids outright.
const HANG_GUARD: Duration = Duration::from_secs(60);

/// One (preset × speculation) row of the chaos bench.
#[derive(Clone, Debug)]
pub struct ChaosRow {
    pub preset: &'static str,
    pub speculate: bool,
    pub jobs: usize,
    /// Parents that produced an `Ok` result.
    pub completed: u64,
    /// Parents that produced a typed error (retry budget exhausted).
    pub failed: u64,
    pub completion_rate: f64,
    /// Every `Ok` result matched the undisturbed reference bitwise.
    pub bit_identical: bool,
    /// A parent never reported within the hang guard (contract breach).
    pub hung: bool,
    /// End-to-end parent makespan percentiles over completed parents.
    pub p50_makespan_ns: Option<u64>,
    pub p99_makespan_ns: Option<u64>,
    pub worker_deaths: u64,
    pub requeued_shards: u64,
    pub speculative_launches: u64,
    pub speculative_wins: u64,
}

/// The full `bench chaos` report (`BENCH_chaos.json`).
#[derive(Clone, Debug)]
pub struct ChaosReport {
    pub jobs: usize,
    pub seed: u64,
    pub rows: Vec<ChaosRow>,
    /// Pooled gentle-preset completions across every statistical
    /// repetition (the displayed rows are repetition 0 only).
    pub gentle_completed: usize,
    pub gentle_total: usize,
    /// Statistical verdicts CI blocks on (currently one: gentle-preset
    /// completion rate tested against [`GENTLE_COMPLETION_P0`] with an
    /// exact binomial tail, repetitions added adaptively on a marginal
    /// verdict instead of failing on one unlucky seed).
    pub gates: Vec<GateResult>,
}

fn percentile(sorted: &[u64], q: f64) -> Option<u64> {
    if sorted.is_empty() {
        return None;
    }
    let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    Some(sorted[idx.min(sorted.len() - 1)])
}

fn run_row(
    preset: &'static str,
    chaos: ChaosConfig,
    speculate: bool,
    mats: &[Csr],
    golds: &[Csr],
    jobs: usize,
) -> ChaosRow {
    let spec = if speculate { SpeculateConfig::on() } else { SpeculateConfig::default() };
    let coord = Coordinator::start_full(
        WORKERS,
        Router::default(),
        None,
        ReplanConfig::default(),
        spec,
        chaos,
    );
    for id in 0..jobs as u64 {
        let m = &mats[id as usize % mats.len()];
        coord.submit(Job {
            id,
            a: m.clone(),
            b: m.clone(),
            force_route: Some(Route::Sharded { n_devices: SHARDS }),
        });
    }
    let mut completed = 0u64;
    let mut failed = 0u64;
    let mut bit_identical = true;
    let mut hung = false;
    let mut makespans: Vec<u64> = Vec::new();
    for _ in 0..jobs {
        match coord.recv_timeout(HANG_GUARD) {
            Some(r) => match r.c {
                Ok(c) => {
                    completed += 1;
                    makespans.push(r.wall_ns);
                    bit_identical &= c == golds[r.id as usize % golds.len()];
                }
                Err(_) => failed += 1,
            },
            None => {
                // the contract forbids exactly this: a parent that
                // neither completed nor failed
                hung = true;
                break;
            }
        }
    }
    let snap = coord.metrics.snapshot();
    coord.shutdown();
    makespans.sort_unstable();
    ChaosRow {
        preset,
        speculate,
        jobs,
        completed,
        failed,
        completion_rate: completed as f64 / jobs.max(1) as f64,
        bit_identical,
        hung,
        p50_makespan_ns: percentile(&makespans, 0.50),
        p99_makespan_ns: percentile(&makespans, 0.99),
        worker_deaths: snap.worker_deaths,
        requeued_shards: snap.requeued_shards,
        speculative_launches: snap.speculative_launches,
        speculative_wins: snap.speculative_wins,
    }
}

/// The `bench chaos` entry: four rows, printed as a table and returned
/// for JSON recording. The hard contracts (no hang, bit-identity, 100%
/// completion under `gentle`) are asserted by the bench binary and the
/// CI check on `BENCH_chaos.json`, not here — this function only
/// measures.
pub fn chaos_fleet(jobs: usize, seed: u64) -> Result<ChaosReport> {
    let jobs = jobs.max(4);
    let mut rng = Rng::new(2027);
    let mats: Vec<Csr> = (0..3)
        .map(|_| Uniform { n: 400, per_row: 8, jitter: 4 }.generate(&mut rng))
        .collect();
    let golds: Vec<Csr> = mats.iter().map(|m| spgemm_reference(m, m)).collect();
    println!(
        "chaos bench: {jobs} force-sharded jobs ({SHARDS} shards each) over {WORKERS} workers, \
         seed {seed:#x}"
    );
    let mut rows = Vec::new();
    for (preset, cfg) in [
        ("gentle", ChaosConfig::gentle().with_seed(seed)),
        ("aggressive", ChaosConfig::aggressive().with_seed(seed)),
    ] {
        for speculate in [false, true] {
            let row = run_row(preset, cfg, speculate, &mats, &golds, jobs);
            println!(
                "  {:<10} speculate {:<5} completed {:>3}/{:<3} bit_identical {:<5} hung {:<5} \
                 p50 {:?} p99 {:?} deaths {} requeued {} spec {}/{}",
                row.preset,
                row.speculate,
                row.completed,
                row.jobs,
                row.bit_identical,
                row.hung,
                row.p50_makespan_ns,
                row.p99_makespan_ns,
                row.worker_deaths,
                row.requeued_shards,
                row.speculative_wins,
                row.speculative_launches,
            );
            rows.push(row);
        }
    }

    // statistical completion gate: pool gentle-preset completions and
    // test against the exact binomial tail at p0. On a marginal verdict,
    // add repetitions with derived seeds — one unlucky kill streak at
    // the root seed must not fail CI, a genuinely broken requeue path
    // keeps failing however much evidence is added.
    let stat = AdaptiveConfig::from_env();
    let mut gentle_completed: usize =
        rows.iter().filter(|r| r.preset == "gentle").map(|r| r.completed as usize).sum();
    let mut gentle_total: usize =
        rows.iter().filter(|r| r.preset == "gentle").map(|r| r.jobs).sum();
    let mut gate = completion_gate(
        "chaos_gentle_completion",
        gentle_completed,
        gentle_total,
        GENTLE_COMPLETION_P0,
        stat.alpha,
    );
    let mut rep = 1usize;
    while !gate.pass && rep < stat.max_reps.max(stat.min_reps).max(2) {
        let rep_seed = seed.wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(rep as u64));
        for speculate in [false, true] {
            let row = run_row(
                "gentle",
                ChaosConfig::gentle().with_seed(rep_seed),
                speculate,
                &mats,
                &golds,
                jobs,
            );
            anyhow::ensure!(!row.hung, "chaos gate rep {rep}: a parent hung");
            anyhow::ensure!(row.bit_identical, "chaos gate rep {rep}: result diverged");
            gentle_completed += row.completed as usize;
            gentle_total += row.jobs;
        }
        gate = completion_gate(
            "chaos_gentle_completion",
            gentle_completed,
            gentle_total,
            GENTLE_COMPLETION_P0,
            stat.alpha,
        );
        rep += 1;
    }
    println!(
        "  completion gate: {} (p={:.4}, alpha={}, gentle {}/{} over {} rep{})",
        if gate.pass { "pass" } else { "FAIL" },
        gate.p,
        gate.alpha,
        gentle_completed,
        gentle_total,
        rep,
        if rep == 1 { "" } else { "s" }
    );
    Ok(ChaosReport { jobs, seed, rows, gentle_completed, gentle_total, gates: vec![gate] })
}
