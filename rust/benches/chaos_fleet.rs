//! `cargo bench --bench chaos_fleet` — the shard fleet under
//! deterministic fault injection: {gentle, aggressive} × {speculate off,
//! on} rows (completion rate, bit-identity, p50/p99 makespan, deaths,
//! requeues, speculative launches/wins).
//!
//! Env:
//! * `OPSPARSE_BENCH_CHAOS_JOBS=<n>` — force-sharded jobs per row
//!   (default 24)
//! * `OPSPARSE_CHAOS_SEED=<n>` — root seed of the kill/delay schedule
//!   (default `chaos_bench::DEFAULT_CHAOS_SEED`)
//! * `OPSPARSE_BENCH_JSON_CHAOS=<path>` — record the report as JSON; CI
//!   writes `BENCH_chaos.json` this way and blocks on: the embedded
//!   exact-binomial completion gate (gentle-preset completions pooled
//!   across adaptively many seeded repetitions, tested against
//!   `GENTLE_COMPLETION_P0`), every row bit-identical, no hangs.
//! * `OPSPARSE_STAT_{MIN_REPS,MAX_REPS,REL_HW,ALPHA}` — statistical
//!   runner knobs (see `util::stats::AdaptiveConfig::from_env`).
//!
//! The bench itself enforces the hard contracts too, so a plain
//! `cargo bench --bench chaos_fleet` fails loudly without CI. Completion
//! is a hypothesis test, not a 100%-or-bust point check: one unlucky
//! kill streak at the root seed triggers extra derived-seed repetitions
//! instead of a flaky failure, while a genuinely broken requeue path
//! keeps failing with any amount of added evidence.

use opsparse::bench::{chaos_bench, write_chaos_json};

fn main() {
    let jobs = std::env::var("OPSPARSE_BENCH_CHAOS_JOBS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or(24);
    let seed = std::env::var("OPSPARSE_CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(chaos_bench::DEFAULT_CHAOS_SEED);
    let report = chaos_bench::chaos_fleet(jobs, seed).expect("chaos_fleet bench");
    for row in &report.rows {
        assert!(
            !row.hung,
            "{} (speculate {}): a parent job neither completed nor failed — barrier hang",
            row.preset, row.speculate
        );
        assert!(
            row.bit_identical,
            "{} (speculate {}): a completed job diverged from the undisturbed reference",
            row.preset, row.speculate
        );
        assert_eq!(
            row.completed + row.failed,
            row.jobs as u64,
            "{} (speculate {}): every parent must resolve exactly once",
            row.preset, row.speculate
        );
    }
    for g in &report.gates {
        assert!(
            g.pass,
            "{}: completion rate significantly below p0 \
             (p={:.4} < alpha={}, observed {:.4} vs p0 {:.4}, {}/{} pooled)",
            g.name,
            g.p,
            g.alpha,
            g.candidate_mean,
            g.reference_mean,
            report.gentle_completed,
            report.gentle_total
        );
    }
    if let Ok(path) = std::env::var("OPSPARSE_BENCH_JSON_CHAOS") {
        write_chaos_json(&path, &report).expect("write chaos json");
    }
}
