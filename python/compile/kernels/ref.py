"""Pure-jnp oracles for the L1 Pallas kernels — the correctness reference
pytest checks against (the CORE correctness signal of the build path)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)


def block_pair_matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """``C[p] = A[p] @ B[p]`` via einsum (no pallas)."""
    return jnp.einsum("pij,pjk->pik", a, b)


def row_window_accumulate_ref(a_vals: jax.Array, b_rows: jax.Array) -> jax.Array:
    """``c[r] = a_vals[r] @ b_rows[r]`` via einsum (no pallas)."""
    return jnp.einsum("rk,rkw->rw", a_vals, b_rows)
