//! Cross-module property tests (the in-house `util::prop` harness):
//! SpGEMM algebraic identities, CSR invariants through every pipeline,
//! binning partitions, and simulator sanity over random traces.

use opsparse::baselines::Library;
use opsparse::gen::banded::Banded;
use opsparse::gen::kron::Kron;
use opsparse::gen::powerlaw::PowerLaw;
use opsparse::gen::uniform::Uniform;
use opsparse::gpusim::{simulate, BlockWork, Kernel, Trace, V100};
use opsparse::sparse::ops::{add, scale, transpose};
use opsparse::sparse::stats::nprod_per_row;
use opsparse::sparse::Csr;
use opsparse::spgemm::pipeline::{multiply, OpSparseConfig};
use opsparse::spgemm::reference::spgemm_reference;
use opsparse::spgemm::sharded::ShardPlan;
use opsparse::util::prop::check;
use opsparse::util::rng::Rng;

fn random_csr(rng: &mut Rng, n: usize, per_row: usize) -> Csr {
    let mut rpt = vec![0usize];
    let mut col = Vec::new();
    let mut val = Vec::new();
    let mut scratch = Vec::new();
    for _ in 0..n {
        let k = rng.range(0, per_row + 1);
        rng.sample_distinct(n, k, &mut scratch);
        for &c in &scratch {
            col.push(c);
            val.push(rng.value());
        }
        rpt.push(col.len());
    }
    Csr::from_parts(n, n, rpt, col, val).unwrap()
}

#[test]
fn prop_every_library_output_is_valid_csr() {
    check(
        "library-valid-csr",
        12,
        40,
        |rng, size| random_csr(rng, size.max(4), 6),
        |a| {
            for lib in Library::all() {
                let out = lib.run(a, a).map_err(|e| format!("{}: {e}", lib.name()))?;
                out.c.validate().map_err(|e| format!("{}: {e}", lib.name()))?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_spgemm_transpose_identity() {
    // (A·B)^T == B^T · A^T
    check(
        "transpose-identity",
        10,
        30,
        |rng, size| {
            let a = random_csr(rng, size.max(4), 5);
            let b = random_csr(rng, size.max(4), 5);
            (a, b)
        },
        |(a, b)| {
            let ab_t = transpose(&spgemm_reference(a, b));
            let bt_at = spgemm_reference(&transpose(b), &transpose(a));
            if ab_t.approx_eq(&bt_at, 1e-9) {
                Ok(())
            } else {
                Err("(AB)^T != B^T A^T".into())
            }
        },
    );
}

#[test]
fn prop_spgemm_distributes_over_addition() {
    // A(B + C) == AB + AC
    check(
        "distributivity",
        10,
        24,
        |rng, size| {
            let n = size.max(4);
            (random_csr(rng, n, 4), random_csr(rng, n, 4), random_csr(rng, n, 4))
        },
        |(a, b, c)| {
            let lhs = spgemm_reference(a, &add(b, c).unwrap());
            let rhs = add(&spgemm_reference(a, b), &spgemm_reference(a, c)).unwrap();
            if lhs.approx_eq(&rhs, 1e-9) {
                Ok(())
            } else {
                Err("A(B+C) != AB + AC".into())
            }
        },
    );
}

#[test]
fn prop_scaling_commutes() {
    // (sA)·B == s(A·B)
    check(
        "scaling",
        10,
        24,
        |rng, size| {
            let n = size.max(4);
            (random_csr(rng, n, 5), random_csr(rng, n, 5), rng.value() * 3.0)
        },
        |(a, b, s)| {
            let lhs = spgemm_reference(&scale(a, *s), b);
            let rhs = scale(&spgemm_reference(a, b), *s);
            if lhs.approx_eq(&rhs, 1e-9) {
                Ok(())
            } else {
                Err("(sA)B != s(AB)".into())
            }
        },
    );
}

#[test]
fn prop_pipeline_equals_reference_on_random_matrices() {
    check(
        "pipeline-vs-reference",
        16,
        60,
        |rng, size| random_csr(rng, size.max(4), 8),
        |a| {
            let out = multiply(a, a, &OpSparseConfig::default()).map_err(|e| e.to_string())?;
            let gold = spgemm_reference(a, a);
            out.c
                .diff(&gold, 1e-9)
                .map_or(Ok(()), |d| Err(d))
        },
    );
}

#[test]
fn prop_simulator_time_monotone_in_work() {
    // doubling every block's bytes must not decrease simulated time
    check(
        "sim-monotone",
        12,
        64,
        |rng, size| {
            let blocks: Vec<BlockWork> = (0..size.max(1))
                .map(|_| BlockWork {
                    global_bytes: rng.below(1_000_000),
                    shared_accesses: rng.below(100_000),
                    ..Default::default()
                })
                .collect();
            blocks
        },
        |blocks| {
            let mk = |mult: u64| {
                let mut t = Trace::new();
                t.launch(Kernel {
                    name: "k".into(),
                    step: "numeric",
                    stream: 0,
                    tb_size: 256,
                    shared_bytes: 8192,
                    blocks: blocks
                        .iter()
                        .map(|b| BlockWork {
                            global_bytes: b.global_bytes * mult,
                            shared_accesses: b.shared_accesses * mult,
                            ..Default::default()
                        })
                        .collect(),
                });
                simulate(&t, &V100).total_ns
            };
            let t1 = mk(1);
            let t2 = mk(2);
            if t2 + 1e-6 >= t1 {
                Ok(())
            } else {
                Err(format!("time decreased: {t1} -> {t2}"))
            }
        },
    );
}

#[test]
fn prop_simulated_kernels_all_complete() {
    check(
        "sim-completion",
        12,
        32,
        |rng, size| {
            let mut t = Trace::new();
            let nk = rng.range(1, 5);
            for i in 0..nk {
                let nblocks = rng.range(1, size.max(2));
                t.launch(Kernel {
                    name: format!("k{i}"),
                    step: "symbolic",
                    stream: rng.range(0, 3),
                    tb_size: [64, 128, 256, 1024][rng.range(0, 4)],
                    shared_bytes: [0usize, 2048, 48 * 1024][rng.range(0, 3)],
                    blocks: vec![
                        BlockWork { global_bytes: rng.below(100_000), ..Default::default() };
                        nblocks
                    ],
                });
                if rng.f64() < 0.3 {
                    t.malloc(rng.below(1 << 20) as usize, "x", "setup");
                }
                if rng.f64() < 0.2 {
                    t.free("x", "cleanup");
                }
            }
            t
        },
        |t| {
            let tl = simulate(t, &V100);
            for k in &tl.kernels {
                if !k.start.is_finite() || !k.end.is_finite() || k.end < k.start {
                    return Err(format!("kernel {} has bad span {}..{}", k.name, k.start, k.end));
                }
            }
            if tl.total_ns <= 0.0 {
                return Err("zero total".into());
            }
            Ok(())
        },
    );
}

/// One matrix per generator family, sized by the harness's shrink knob.
/// (`Kron` sizes by scale, so the knob maps to 2^7..2^8 vertices.)
fn plan_family_matrix(rng: &mut Rng, fam: usize, n: usize) -> Csr {
    match fam {
        0 => Uniform { n, per_row: 6, jitter: 3 }.generate(rng),
        1 => PowerLaw {
            n,
            alpha: 2.0,
            max_row: (n / 4).max(8),
            mean_row: 4.0,
            hub_frac: 0.2,
            forced_giant_rows: 1,
        }
        .generate(rng),
        2 => Banded { n, per_row: 12, band: 10, contiguous_frac: 0.8 }.generate(rng),
        _ => Kron {
            scale: if n >= 200 { 8 } else { 7 },
            edge_factor: 6,
            a: 0.57,
            b: 0.19,
            c: 0.19,
        }
        .generate(rng),
    }
}

#[test]
fn prop_shard_plan_invariants_across_generator_families() {
    // `ShardPlan::balanced` invariants, checked per generator family so
    // every family is guaranteed covered (not left to the seed sequence):
    //  * bounds partition `0..n_rows` exactly, non-decreasing;
    //  * shards are non-empty unless rows ran out (empty shards only as
    //    a suffix once every row is consumed);
    //  * per-shard cost stays within the greedy balance tolerance
    //    (fair share + one max-row per boundary of slack);
    //  * the plan is deterministic for a fixed input.
    for (fam, name) in
        [(0usize, "uniform"), (1, "powerlaw"), (2, "banded"), (3, "kron")]
    {
        check(
            &format!("shard-plan-{name}"),
            10,
            240,
            |rng, size| {
                let a = plan_family_matrix(rng, fam, size.max(8));
                let shards = 1 + rng.below(12) as usize;
                (a, shards)
            },
            |(a, shards)| {
                let nprod = nprod_per_row(a, a);
                let plan = ShardPlan::balanced(&nprod, *shards);
                let m = plan.n_shards();
                if m != *shards {
                    return Err(format!("asked {shards} shards, planned {m}"));
                }
                let bounds = plan.bounds();
                if bounds.len() != m + 1 {
                    return Err(format!("{} bounds for {m} shards", bounds.len()));
                }
                if bounds[0] != 0 || plan.rows() != a.rows {
                    return Err(format!(
                        "bounds [{}..{}] must span 0..{}",
                        bounds[0],
                        plan.rows(),
                        a.rows
                    ));
                }
                for w in bounds.windows(2) {
                    if w[0] > w[1] {
                        return Err(format!("bounds decrease: {} -> {}", w[0], w[1]));
                    }
                }
                for s in 0..m {
                    let (lo, hi) = plan.range(s);
                    if lo == hi && lo != a.rows {
                        return Err(format!(
                            "interior empty shard {s} at row {lo} of {}",
                            a.rows
                        ));
                    }
                }
                let total: u64 = nprod.iter().map(|&p| p as u64 + 1).sum();
                if plan.costs().iter().sum::<u64>() != total {
                    return Err("costs must partition the total work".into());
                }
                let max_row = nprod.iter().map(|&p| p as u64 + 1).max().unwrap_or(1);
                let tolerance = total / m as u64 + m as u64 * max_row;
                for (s, &cost) in plan.costs().iter().enumerate() {
                    if cost > tolerance {
                        return Err(format!(
                            "shard {s} cost {cost} exceeds tolerance {tolerance} \
                             (total {total}, max row {max_row}, {m} shards)"
                        ));
                    }
                }
                let again = ShardPlan::balanced(&nprod, *shards);
                if again.bounds() != plan.bounds() || again.costs() != plan.costs() {
                    return Err("plan must be deterministic for a fixed input".into());
                }
                Ok(())
            },
        );
    }
}
