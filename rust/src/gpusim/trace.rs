//! Device-operation traces. A SpGEMM implementation records the exact
//! sequence of host/device operations it would issue on a CUDA device —
//! with per-thread-block work counters measured from the real input data —
//! and the scheduler replays it against the cost model.

/// Per-thread-block work counters, measured (not estimated) while the CPU
/// executes the same algorithm on the same data.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BlockWork {
    /// Global-memory bytes read + written by the block.
    pub global_bytes: u64,
    /// Shared-memory word accesses (table init + probes + condense).
    pub shared_accesses: u64,
    /// Global-memory atomic operations issued by the block.
    pub global_atomics: u64,
    /// Integer `%` operations in the probe loop (non-pow2 tables).
    pub mod_ops: u64,
    /// Floating-point operations (multiply + add per product).
    pub flops: u64,
}

impl BlockWork {
    pub fn add(&mut self, o: &BlockWork) {
        self.global_bytes += o.global_bytes;
        self.shared_accesses += o.shared_accesses;
        self.global_atomics += o.global_atomics;
        self.mod_ops += o.mod_ops;
        self.flops += o.flops;
    }
}

/// A kernel launch: configuration + per-block work.
#[derive(Clone, Debug)]
pub struct Kernel {
    pub name: String,
    /// Pipeline step this kernel belongs to (for per-step reporting:
    /// "setup", "sym_binning", "symbolic", "alloc_c", "num_binning",
    /// "numeric", "cleanup").
    pub step: &'static str,
    /// CUDA stream id; kernels in one stream serialize, different streams
    /// may run concurrently (§5.5).
    pub stream: usize,
    pub tb_size: usize,
    pub shared_bytes: usize,
    pub blocks: Vec<BlockWork>,
}

impl Kernel {
    pub fn total_work(&self) -> BlockWork {
        let mut t = BlockWork::default();
        for b in &self.blocks {
            t.add(b);
        }
        t
    }
}

/// One host-issued device operation.
#[derive(Clone, Debug)]
pub enum TraceOp {
    /// `cudaMalloc`: host-blocking, device keeps executing (§4.5).
    Malloc { bytes: usize, label: String, step: &'static str },
    /// `cudaFree`: implicit `cudaDeviceSynchronize` then host work (§4.6).
    Free { label: String, step: &'static str },
    /// Kernel launch (host overhead, then the kernel queues on its stream).
    Launch(Kernel),
    /// Explicit device synchronization.
    DeviceSync { step: &'static str },
    /// Small synchronous device-to-host copy (e.g. reading back total nnz).
    MemcpyD2H { bytes: usize, step: &'static str },
    /// Async host-to-device copy from pinned memory (e.g. uploading a
    /// cached `C.rpt`): host pays the transfer, the device keeps running.
    MemcpyH2D { bytes: usize, step: &'static str },
    /// Dependency on an inter-device broadcast chunk (a row panel of the
    /// replicated operand): the host blocks until chunk `chunk` has
    /// arrived over the interconnect, then resumes issuing work.
    /// Already-launched kernels keep executing — this is how chunked
    /// broadcasts overlap with the first symbolic kernels. Under a plain
    /// [`crate::gpusim::simulate`] (no arrival times) it is free, so
    /// annotated traces replay bit-identically on the serial path.
    AwaitChunk { chunk: usize, step: &'static str },
}

impl TraceOp {
    pub fn step(&self) -> &'static str {
        match self {
            TraceOp::Malloc { step, .. } => *step,
            TraceOp::Free { step, .. } => *step,
            TraceOp::Launch(k) => k.step,
            TraceOp::DeviceSync { step } => *step,
            TraceOp::MemcpyD2H { step, .. } => *step,
            TraceOp::MemcpyH2D { step, .. } => *step,
            TraceOp::AwaitChunk { step, .. } => *step,
        }
    }
}

/// A full device trace for one SpGEMM invocation.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub ops: Vec<TraceOp>,
}

impl Trace {
    pub fn new() -> Self {
        Trace { ops: Vec::new() }
    }

    pub fn malloc(&mut self, bytes: usize, label: impl Into<String>, step: &'static str) {
        self.ops.push(TraceOp::Malloc { bytes, label: label.into(), step });
    }

    pub fn free(&mut self, label: impl Into<String>, step: &'static str) {
        self.ops.push(TraceOp::Free { label: label.into(), step });
    }

    pub fn launch(&mut self, k: Kernel) {
        self.ops.push(TraceOp::Launch(k));
    }

    pub fn device_sync(&mut self, step: &'static str) {
        self.ops.push(TraceOp::DeviceSync { step });
    }

    pub fn memcpy_d2h(&mut self, bytes: usize, step: &'static str) {
        self.ops.push(TraceOp::MemcpyD2H { bytes, step });
    }

    pub fn memcpy_h2d(&mut self, bytes: usize, step: &'static str) {
        self.ops.push(TraceOp::MemcpyH2D { bytes, step });
    }

    pub fn await_chunk(&mut self, chunk: usize, step: &'static str) {
        self.ops.push(TraceOp::AwaitChunk { chunk, step });
    }

    /// Number of broadcast chunks this trace depends on: highest
    /// [`TraceOp::AwaitChunk`] index + 1, or 0 for an unannotated trace.
    pub fn chunk_deps(&self) -> usize {
        self.ops
            .iter()
            .filter_map(|op| match op {
                TraceOp::AwaitChunk { chunk, .. } => Some(chunk + 1),
                _ => None,
            })
            .max()
            .unwrap_or(0)
    }

    /// Total bytes requested through `cudaMalloc` (metadata accounting,
    /// §4.4 / §5.3).
    pub fn malloc_bytes(&self) -> usize {
        self.ops
            .iter()
            .map(|op| match op {
                TraceOp::Malloc { bytes, .. } => *bytes,
                _ => 0,
            })
            .sum()
    }

    /// Number of `cudaMalloc` calls.
    pub fn malloc_calls(&self) -> usize {
        self.ops.iter().filter(|op| matches!(op, TraceOp::Malloc { .. })).count()
    }

    /// Number of kernel launches.
    pub fn launches(&self) -> usize {
        self.ops.iter().filter(|op| matches!(op, TraceOp::Launch(_))).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_accounting() {
        let mut t = Trace::new();
        t.malloc(1024, "meta", "setup");
        t.launch(Kernel {
            name: "k".into(),
            step: "symbolic",
            stream: 0,
            tb_size: 64,
            shared_bytes: 2052,
            blocks: vec![BlockWork { global_bytes: 100, ..Default::default() }; 3],
        });
        t.malloc(2048, "c_col", "alloc_c");
        t.free("meta", "cleanup");
        assert_eq!(t.malloc_bytes(), 3072);
        assert_eq!(t.malloc_calls(), 2);
        assert_eq!(t.launches(), 1);
    }

    #[test]
    fn kernel_total_work_sums_blocks() {
        let k = Kernel {
            name: "k".into(),
            step: "numeric",
            stream: 1,
            tb_size: 128,
            shared_bytes: 0,
            blocks: vec![
                BlockWork { global_bytes: 10, shared_accesses: 5, ..Default::default() },
                BlockWork { global_bytes: 20, flops: 7, ..Default::default() },
            ],
        };
        let t = k.total_work();
        assert_eq!(t.global_bytes, 30);
        assert_eq!(t.shared_accesses, 5);
        assert_eq!(t.flops, 7);
    }
}
