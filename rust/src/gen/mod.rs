//! Synthetic sparse-matrix generators.
//!
//! The paper evaluates on 26 SuiteSparse matrices (Table 3). This build has
//! no network access, so each matrix is replaced by a synthetic stand-in of
//! the same *structural class*, scaled to run on one machine while keeping
//! the properties that drive SpGEMM behaviour: nnz/row distribution, max
//! nnz/row, and the compression ratio of A² (see DESIGN.md §2.2).
//!
//! Generator families:
//! * [`banded`] — banded matrices with per-row jitter (FEM-like: cant,
//!   consph, shipsec1, pdb1HYS, hood, pwtk…). High overlap between
//!   neighbouring rows ⇒ high compression ratio.
//! * [`stencil`] — regular k-point stencils on 1D/2D/3D grids (mc2depi,
//!   mario002, majorbasis, m133-b3…). CR ≈ small and uniform rows.
//! * [`powerlaw`] — power-law row sizes with skewed column sampling
//!   (webbase-1M, patents_main, wb-edu, scircuit…), including the
//!   single-huge-row behaviour that drives the paper's §6.3.4 case study.
//! * [`kron`] — Kronecker-product (RMAT-like) graphs (cage12/15-like
//!   diffusion patterns are approximated by stencil+jitter instead).
//! * [`uniform`] — uniform random rows (poisson3Da, 2cubes_sphere…).

pub mod banded;
pub mod kron;
pub mod powerlaw;
pub mod stencil;
pub mod suite;
pub mod uniform;

pub use suite::{suite_entry, suite_names, SuiteEntry, SuiteScale};

use crate::sparse::Csr;
use crate::util::rng::Rng;

/// Common generator entrypoint: every family produces a square CSR matrix
/// with strictly-sorted rows and values in roughly [-1, 1].
pub trait Generator {
    fn generate(&self, rng: &mut Rng) -> Csr;
}

/// Build a CSR matrix from a closure yielding per-row sorted column lists.
/// Shared scaffolding for all generator families.
pub(crate) fn build_rows<F>(n: usize, cols: usize, rng: &mut Rng, mut row_fn: F) -> Csr
where
    F: FnMut(usize, &mut Rng, &mut Vec<u32>),
{
    let mut rpt = Vec::with_capacity(n + 1);
    rpt.push(0usize);
    let mut col: Vec<u32> = Vec::new();
    let mut val: Vec<f64> = Vec::new();
    let mut scratch: Vec<u32> = Vec::new();
    for i in 0..n {
        scratch.clear();
        row_fn(i, rng, &mut scratch);
        scratch.sort_unstable();
        scratch.dedup();
        for &c in scratch.iter() {
            debug_assert!((c as usize) < cols);
            col.push(c);
            val.push(rng.value());
        }
        rpt.push(col.len());
    }
    let m = Csr { rows: n, cols, rpt, col, val };
    debug_assert!(m.validate().is_ok());
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_rows_sorts_and_dedups() {
        let mut rng = Rng::new(1);
        let m = build_rows(3, 10, &mut rng, |i, _, out| {
            out.extend_from_slice(&[5, 2, 5, (i as u32) % 10]);
        });
        m.validate().unwrap();
        for i in 0..3 {
            let cols = m.row_cols(i);
            assert!(cols.windows(2).all(|w| w[0] < w[1]));
        }
    }
}
