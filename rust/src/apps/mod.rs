//! Application workloads from the paper's introduction (§1): the reason
//! SpGEMM performance matters. Each app drives the OpSparse pipeline (or
//! a semiring variant) as its compute primitive:
//!
//! * [`amg`] — algebraic multigrid: the Galerkin triple product
//!   `A_coarse = R·A·P` is two SpGEMMs per level [1, 2].
//! * [`mcl`] — Markov clustering: the expansion step is `M²` [3].
//! * [`msbfs`] — multi-source BFS: frontier expansion is a boolean
//!   SpGEMM `F ⊗ A` [4].
//!
//! These apps are exactly the repeated-pattern workloads the device pool
//! and symbolic-reuse cache target: AMG re-setup on a fixed mesh reruns
//! the same Galerkin products every timestep, and MCL's expansion pattern
//! stabilizes as the clustering converges. [`SpgemmContext`] bundles a
//! [`DevicePool`] and a [`PatternCache`] so an app (or a caller looping
//! an app) reuses allocations and symbolic results across its multiplies.

pub mod amg;
pub mod mcl;
pub mod msbfs;

use crate::coordinator::cache::PatternCache;
use crate::gpusim::{DevicePool, PoolStats};
use crate::sparse::Csr;
use crate::spgemm::pipeline::{multiply_reuse, OpSparseConfig, SpgemmOutput, SymbolicReuse};
use anyhow::Result;
use std::sync::Arc;

/// Warm multiply state for an application: one device pool plus one
/// sparsity-pattern cache, threaded through every SpGEMM the app issues.
pub struct SpgemmContext {
    pool: DevicePool,
    cache: PatternCache,
    pub cfg: OpSparseConfig,
}

impl SpgemmContext {
    /// Default-capacity context (64 cached patterns).
    pub fn new() -> Self {
        SpgemmContext::with_capacity(64)
    }

    pub fn with_capacity(patterns: usize) -> Self {
        SpgemmContext {
            pool: DevicePool::new(),
            cache: PatternCache::new(patterns),
            cfg: OpSparseConfig::default(),
        }
    }

    /// `C = A·B` through the pooled pipeline, replaying the symbolic
    /// phase when this context has seen the pattern pair before.
    pub fn multiply(&mut self, a: &Csr, b: &Csr) -> Result<SpgemmOutput> {
        let key = (a.pattern_fingerprint(), b.pattern_fingerprint());
        let reuse = self.cache.lookup(key);
        let out = multiply_reuse(a, b, &self.cfg, Some(&mut self.pool), reuse.as_deref())?;
        if reuse.is_none() {
            self.cache.insert(key, Arc::new(SymbolicReuse::from_output(&out)));
        }
        Ok(out)
    }

    /// Symbolic phases skipped so far.
    pub fn sym_cache_hits(&self) -> u64 {
        self.cache.hits()
    }

    /// Symbolic phases computed (and cached) so far.
    pub fn sym_cache_misses(&self) -> u64 {
        self.cache.misses()
    }

    /// Cumulative device-pool counters.
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }
}

impl Default for SpgemmContext {
    fn default() -> Self {
        SpgemmContext::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::uniform::Uniform;
    use crate::spgemm::reference::spgemm_reference;
    use crate::util::rng::Rng;

    #[test]
    fn context_power_iteration_reuses_everything() {
        let mut rng = Rng::new(41);
        let a = Uniform { n: 150, per_row: 7, jitter: 3 }.generate(&mut rng);
        let mut ctx = SpgemmContext::new();
        let gold = spgemm_reference(&a, &a);
        for i in 0..3 {
            let out = ctx.multiply(&a, &a).unwrap();
            assert!(out.c.approx_eq(&gold, 1e-12), "iteration {i}");
            assert_eq!(out.symbolic_skipped, i > 0);
        }
        assert_eq!(ctx.sym_cache_misses(), 1);
        assert_eq!(ctx.sym_cache_hits(), 2);
        assert!(ctx.pool_stats().pool_hits > 0);
    }
}
