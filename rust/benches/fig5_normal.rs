//! `cargo bench --bench fig5_normal` — regenerates paper Figure 5:
//! SpGEMM GFLOPS of cuSPARSE/nsparse/spECK/OpSparse on the 19 normal
//! matrices (simulated V100; outputs verified against the reference).
//!
//! Set `OPSPARSE_BENCH_JSON=<path>` to also record the rows as JSON —
//! CI writes `BENCH_seed.json` this way so later PRs have a perf
//! trajectory to compare against.

use opsparse::baselines::Library;
use opsparse::bench::{figures, write_rows_json};
use opsparse::gen::suite::SuiteScale;

fn main() {
    let scale = scale_from_env();
    let rows = figures::fig5(scale, true).expect("fig5");
    if let Ok(path) = std::env::var("OPSPARSE_BENCH_JSON") {
        let libs = Library::all().map(|l| l.name());
        write_rows_json(&path, "fig5", scale, &libs, &rows).expect("write bench json");
    }
}

fn scale_from_env() -> SuiteScale {
    std::env::var("OPSPARSE_SCALE")
        .ok()
        .and_then(|s| SuiteScale::parse(&s))
        .unwrap_or(SuiteScale::Small)
}
