//! Row-window engine: the dense-accumulator path backed by the
//! `row_window_accumulate` Pallas kernel (see
//! `python/compile/kernels/block_matmul.py`).
//!
//! For a row `i` of `C = A·B` whose nonzero fanout fits the compiled `K`
//! and whose B-row column union fits a `W`-wide window, the numeric phase
//! is a dense `(1,K)×(K,W)` contraction — the VMEM accumulator tile
//! standing in for the GPU shared-memory hash table. The engine gathers
//! the window operands, batches `R` rows per PJRT call (zero-padded), and
//! compacts the dense outputs back to sparse rows.

use super::client::PjrtRuntime;
use crate::sparse::Csr;
use anyhow::{ensure, Result};
use std::path::PathBuf;

/// One computed row: `(row id, sorted (col, val) nonzeros)`.
pub type RowResult = (u32, Vec<(u32, f64)>);

/// Engine statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct RowEngineStats {
    pub rows: usize,
    pub batches: usize,
    pub skipped: usize,
}

/// PJRT-backed dense-window row engine for one compiled `(R, K, W)`.
pub struct RowWindowEngine {
    runtime: PjrtRuntime,
    artifact: PathBuf,
    pub r: usize,
    pub k: usize,
    pub w: usize,
    pub stats: RowEngineStats,
}

impl RowWindowEngine {
    /// Load the `row_window_r{R}_k{K}_w{W}_f64` artifact from `dir`.
    pub fn load(dir: &std::path::Path, r: usize, k: usize, w: usize) -> Result<Self> {
        let artifact = dir.join(format!("row_window_r{r}_k{k}_w{w}_f64.hlo.txt"));
        ensure!(
            artifact.exists(),
            "artifact {} not found — run `make artifacts`",
            artifact.display()
        );
        let mut runtime = PjrtRuntime::cpu()?;
        runtime.load(&artifact)?;
        Ok(RowWindowEngine { runtime, artifact, r, k, w, stats: RowEngineStats::default() })
    }

    /// True if row `i` of `A·B` fits this engine: `nnz(A_i) <= K` and the
    /// union of the referenced B rows' columns spans `< W`.
    pub fn row_fits(&self, a: &Csr, b: &Csr, i: usize) -> bool {
        let acols = a.row_cols(i);
        if acols.len() > self.k || acols.is_empty() {
            return false;
        }
        let mut lo = u32::MAX;
        let mut hi = 0u32;
        for &kk in acols {
            let bc = b.row_cols(kk as usize);
            if let (Some(&first), Some(&last)) = (bc.first(), bc.last()) {
                lo = lo.min(first);
                hi = hi.max(last);
            }
        }
        lo == u32::MAX || (hi - lo) < self.w as u32
    }

    /// Compute the given rows of `C = A·B`. Rows that don't fit the
    /// compiled shape are returned in the second list for the hash path.
    pub fn compute_rows(
        &mut self,
        a: &Csr,
        b: &Csr,
        rows: &[u32],
    ) -> Result<(Vec<RowResult>, Vec<u32>)> {
        ensure!(a.cols == b.rows, "dimension mismatch");
        let (r_cap, k_cap, w_cap) = (self.r, self.k, self.w);
        let mut fit: Vec<u32> = Vec::new();
        let mut overflow: Vec<u32> = Vec::new();
        for &i in rows {
            if self.row_fits(a, b, i as usize) {
                fit.push(i);
            } else {
                overflow.push(i);
            }
        }
        self.stats = RowEngineStats { rows: fit.len(), batches: 0, skipped: overflow.len() };

        let mut results: Vec<RowResult> = Vec::with_capacity(fit.len());
        let mut a_vals = vec![0f64; r_cap * k_cap];
        let mut b_rows = vec![0f64; r_cap * k_cap * w_cap];
        let mut bases = vec![0u32; r_cap];
        for chunk in fit.chunks(r_cap) {
            a_vals.fill(0.0);
            b_rows.fill(0.0);
            for (s, &row) in chunk.iter().enumerate() {
                let i = row as usize;
                let (acols, avals) = a.row(i);
                // window base = min column over the referenced B rows
                let mut base = u32::MAX;
                for &kk in acols {
                    if let Some(&first) = b.row_cols(kk as usize).first() {
                        base = base.min(first);
                    }
                }
                if base == u32::MAX {
                    base = 0;
                }
                bases[s] = base;
                for (slot, (&kk, &av)) in acols.iter().zip(avals).enumerate() {
                    a_vals[s * k_cap + slot] = av;
                    let (bc, bv) = b.row(kk as usize);
                    for (&c, &v) in bc.iter().zip(bv) {
                        let off = (c - base) as usize;
                        b_rows[(s * k_cap + slot) * w_cap + off] = v;
                    }
                }
            }
            let out = self.runtime.execute_f64(
                &self.artifact,
                &[(&a_vals, &[r_cap, k_cap]), (&b_rows, &[r_cap, k_cap, w_cap])],
            )?;
            ensure!(out.len() == r_cap * w_cap, "unexpected output size");
            for (s, &row) in chunk.iter().enumerate() {
                let base = bases[s];
                let dense = &out[s * w_cap..(s + 1) * w_cap];
                let mut sparse: Vec<(u32, f64)> = dense
                    .iter()
                    .enumerate()
                    .filter(|(_, &v)| v != 0.0)
                    .map(|(off, &v)| (base + off as u32, v))
                    .collect();
                sparse.sort_unstable_by_key(|&(c, _)| c);
                results.push((row, sparse));
            }
            self.stats.batches += 1;
        }
        Ok((results, overflow))
    }
}

// Integration tests live in rust/tests/integration_runtime.rs (require
// `make artifacts`).
