//! `cargo bench --bench fig7_8_binning` — regenerates paper Figures 7+8:
//! binning-step time (absolute and % of total) for nsparse/spECK/OpSparse
//! across the 26-matrix suite.

use opsparse::bench::figures;
use opsparse::gen::suite::SuiteScale;

fn main() {
    let scale = std::env::var("OPSPARSE_SCALE")
        .ok()
        .and_then(|s| SuiteScale::parse(&s))
        .unwrap_or(SuiteScale::Small);
    figures::fig7_8(scale).expect("fig7_8");
}
