//! Failure injection and robustness: malformed inputs must produce
//! errors, never panics or silent corruption — including through the
//! coordinator's cross-worker shard fan-out, where a failed or poisoned
//! shard must surface exactly one parent-job failure (never a hang or a
//! partial stitch) and shutdown must drain in-flight shard barriers.

use opsparse::baselines::Library;
use opsparse::coordinator::{Coordinator, Job, Route, Router};
use opsparse::gpusim::{simulate, BlockWork, Kernel, Trace, V100};
use opsparse::sparse::{mmio, Csr};
use opsparse::spgemm::pipeline::{multiply, OpSparseConfig};
use opsparse::util::prop::check;
use opsparse::util::rng::Rng;
use std::sync::Arc;

#[test]
fn fuzzed_matrix_market_never_panics() {
    // random byte soups and near-miss headers must all return Err
    let cases: Vec<String> = vec![
        String::new(),
        "%%MatrixMarket".into(),
        "%%MatrixMarket matrix coordinate real general".into(), // no size
        "%%MatrixMarket matrix coordinate real general\n-1 2 1\n1 1 1.0".into(),
        "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1".into(), // missing value
        "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1 notanumber".into(),
        "%%MatrixMarket matrix coordinate complex general\n1 1 1\n1 1 1 0".into(),
        "%%MatrixMarket matrix coordinate real general\n2 2 9999999999999\n".into(),
        "\u{0}\u{1}\u{2}garbage\nbinary\u{7f}".into(),
    ];
    for (i, text) in cases.iter().enumerate() {
        let r = mmio::read_matrix_market(text.as_bytes());
        assert!(r.is_err(), "case {i} should be rejected: {text:?}");
    }
}

#[test]
fn fuzzed_random_bytes_into_parser() {
    check(
        "mmio-fuzz",
        48,
        256,
        |rng: &mut Rng, size| {
            let n = rng.range(1, size.max(2));
            let mut bytes = Vec::with_capacity(n + 48);
            // half the cases start with a valid-ish header to reach the
            // deeper parsing paths
            if rng.f64() < 0.5 {
                bytes.extend_from_slice(b"%%MatrixMarket matrix coordinate real general\n");
            }
            for _ in 0..n {
                // printable-biased bytes
                let b = match rng.below(4) {
                    0 => b' ',
                    1 => b'\n',
                    2 => b'0' + (rng.below(10) as u8),
                    _ => rng.below(256) as u8,
                };
                bytes.push(b);
            }
            bytes
        },
        |bytes| {
            // must not panic; any Ok result must be a valid matrix
            match mmio::read_matrix_market(bytes.as_slice()) {
                Ok(m) => m.validate().map_err(|e| format!("parsed invalid CSR: {e}")),
                Err(_) => Ok(()),
            }
        },
    );
}

#[test]
fn mismatched_dims_error_everywhere() {
    let a = Csr::zero(4, 7);
    let b = Csr::zero(6, 4);
    for lib in Library::all() {
        assert!(lib.run(&a, &b).is_err(), "{} accepted bad dims", lib.name());
    }
}

#[test]
fn pathological_single_column_matrix() {
    // every row hits the same column: maximal duplicate-key pressure
    let n = 2000usize;
    let rpt: Vec<usize> = (0..=n).collect();
    let col = vec![0u32; n];
    let val = vec![1.0f64; n];
    let a = Csr::from_parts(n, n, rpt, col, val).unwrap();
    let out = multiply(&a, &a, &OpSparseConfig::default()).unwrap();
    // A*A: row i = A[i,0] * row0 of A = [1 at col 0] => all rows [0]->1
    assert_eq!(out.c.nnz(), n);
    assert!(out.c.val.iter().all(|&v| v == 1.0));
}

#[test]
fn pathological_dense_row_matrix() {
    // one fully dense row among empties
    let n = 3000usize;
    let mut rpt = vec![0usize; n + 1];
    let col: Vec<u32> = (0..n as u32).collect();
    let val = vec![0.5f64; n];
    for slot in rpt.iter_mut().skip(1) {
        *slot = n;
    }
    let a = Csr::from_parts(n, n, rpt.clone(), col, val).unwrap();
    // only row 0 dense: fix rpt so rows 1.. are empty
    let mut rpt2 = vec![0usize; n + 1];
    for slot in rpt2.iter_mut().skip(1) {
        *slot = n;
    }
    let _ = a; // (a above had every row dense via shared rpt — also fine)
    let a2 = Csr::from_parts(
        n,
        n,
        rpt2,
        (0..n as u32).collect(),
        vec![0.5; n],
    )
    .unwrap();
    let out = multiply(&a2, &a2, &OpSparseConfig::default()).unwrap();
    let gold = opsparse::spgemm::reference::spgemm_reference(&a2, &a2);
    assert!(out.c.approx_eq(&gold, 1e-12));
}

#[test]
fn simulator_handles_degenerate_traces() {
    // empty trace
    let tl = simulate(&Trace::new(), &V100);
    assert_eq!(tl.total_ns, 0.0);
    // kernel with zero blocks
    let mut t = Trace::new();
    t.launch(Kernel {
        name: "empty".into(),
        step: "symbolic",
        stream: 0,
        tb_size: 128,
        shared_bytes: 0,
        blocks: vec![],
    });
    let tl = simulate(&t, &V100);
    assert!(tl.total_ns > 0.0, "launch overhead still counts");
    // free with nothing launched
    let mut t = Trace::new();
    t.free("nothing", "cleanup");
    let tl = simulate(&t, &V100);
    assert!(tl.total_ns >= V100.free_base_ns);
    // malloc-only trace
    let mut t = Trace::new();
    t.malloc(1 << 20, "buf", "setup");
    let tl = simulate(&t, &V100);
    assert!(tl.total_ns >= V100.malloc_ns(1 << 20));
}

#[test]
fn zero_sized_and_single_element_matrices() {
    for (r, c) in [(0usize, 0usize), (1, 1), (0, 5), (5, 0)] {
        let a = Csr::zero(r, c);
        let b = Csr::zero(c, r);
        let out = multiply(&a, &b, &OpSparseConfig::default()).unwrap();
        assert_eq!(out.c.rows, r);
        assert_eq!(out.c.cols, r);
        assert_eq!(out.c.nnz(), 0);
    }
    let one = Csr::from_parts(1, 1, vec![0, 1], vec![0], vec![2.0]).unwrap();
    let out = multiply(&one, &one, &OpSparseConfig::default()).unwrap();
    assert_eq!(out.c.get(0, 0), 4.0);
}

/// A structurally poisoned `B`: rows `0..sound_rows` are a clean
/// diagonal, while the row pointers of rows `sound_rows..n` claim
/// entries beyond `col`/`val` — any shard whose `A` rows reference that
/// region panics inside its pipeline (caught by the worker's guard);
/// shards confined to the sound region succeed.
fn poisoned_b(n: usize, sound_rows: usize) -> Csr {
    let mut rpt: Vec<usize> = (0..=sound_rows).collect();
    for i in sound_rows + 1..=n {
        rpt.push(sound_rows + 2 * (i - sound_rows));
    }
    let col: Vec<u32> = (0..sound_rows as u32).collect();
    let val = vec![1.0f64; sound_rows];
    // deliberately bypasses `Csr::from_parts` validation
    Csr { rows: n, cols: n, rpt, col, val }
}

#[test]
fn poisoned_shard_fails_parent_once_and_workers_survive() {
    let n = 200;
    let a = Csr::identity(n); // row i of A references exactly row i of B
    let b = poisoned_b(n, 150);
    let coord = Coordinator::start(2, Router::default(), None);
    coord.submit(Job {
        id: 1,
        a: a.clone(),
        b,
        force_route: Some(Route::Sharded { n_devices: 4 }),
    });
    let r = coord.recv().expect("parent result must arrive, not hang");
    assert_eq!(r.id, 1);
    assert!(r.c.is_err(), "a poisoned shard must fail the whole parent job");
    assert_eq!(r.nprod, 0, "a failed parent reports no work, never a partial stitch");
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.jobs_failed, 1);
    assert_eq!(snap.shard_subjobs, 4, "every sub-job ran to a verdict");
    // the pool survives a poisoned shard: a healthy job still completes
    coord.submit(Job { id: 2, a: a.clone(), b: a.clone(), force_route: None });
    let r2 = coord.recv().unwrap();
    assert_eq!(r2.id, 2);
    assert!(r2.c.unwrap().approx_eq(&a, 1e-12), "I*I = I");
    assert_eq!(coord.metrics.snapshot().jobs_completed, 1);
    coord.shutdown();
}

#[test]
fn mismatched_dims_fail_sharded_jobs_cleanly_both_ways() {
    // shard planning asserts on the inner dimension (either direction);
    // the submit-side guard must convert that panic into one failed
    // JobResult per parent, never a panic on the caller's thread
    let coord = Coordinator::start(2, Router::default(), None);
    coord.submit(Job {
        id: 1,
        a: Csr::zero(4, 3),
        b: Csr::zero(6, 4),
        force_route: Some(Route::Sharded { n_devices: 3 }),
    });
    coord.submit(Job {
        id: 2,
        a: Csr::identity(7),
        b: Csr::zero(6, 4),
        force_route: Some(Route::Sharded { n_devices: 3 }),
    });
    for _ in 0..2 {
        let r = coord.recv().expect("failures must be reported, not hung");
        assert!(r.c.is_err(), "job {} must fail", r.id);
        assert!(matches!(r.route, Route::Sharded { .. }));
    }
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.jobs_failed, 2);
    assert_eq!(snap.shard_subjobs, 0, "nothing was fanned out for unplannable jobs");
    // the workers are untouched: a healthy job still completes
    let m = Csr::identity(8);
    coord.submit(Job { id: 3, a: m.clone(), b: m.clone(), force_route: None });
    assert!(coord.recv().unwrap().c.is_ok());
    coord.shutdown();
}

#[test]
fn shutdown_with_in_flight_shard_barriers_drains_cleanly() {
    let coord = Coordinator::start(3, Router::default(), None);
    let mut rng = Rng::new(77);
    let a = opsparse::gen::uniform::Uniform { n: 400, per_row: 8, jitter: 4 }.generate(&mut rng);
    let jobs = 4u64;
    for id in 0..jobs {
        coord.submit(Job {
            id,
            a: a.clone(),
            b: a.clone(),
            force_route: Some(Route::Sharded { n_devices: 8 }),
        });
    }
    // shut down immediately: stop markers queue behind the 32 in-flight
    // sub-jobs, so every barrier must drain before the workers exit —
    // no hang, no stranded parent
    let metrics = Arc::clone(&coord.metrics);
    coord.shutdown();
    let snap = metrics.snapshot();
    assert_eq!(snap.jobs_completed + snap.jobs_failed, jobs, "every parent got a verdict");
    assert_eq!(snap.jobs_completed, jobs, "healthy jobs drain to completion");
    assert_eq!(snap.shard_subjobs, jobs * 8, "every sub-job was executed");
}

#[test]
fn poisoned_batch_member_fails_alone_and_siblings_match_solo_runs() {
    // the whole per-job body of a batch member runs under the worker's
    // panic guard: a poisoned member fails its own JobResult and nothing
    // else — siblings in the same batch complete bitwise-identically to
    // solo submissions, and the worker survives for follow-up traffic
    let n = 200;
    let a = Csr::identity(n);
    let solo = {
        let coord = Coordinator::start(1, Router::default(), None);
        coord.submit(Job {
            id: 0,
            a: a.clone(),
            b: a.clone(),
            force_route: Some(Route::Hash),
        });
        let c = coord.recv().unwrap().c.expect("healthy solo run");
        coord.shutdown();
        c
    };
    let coord = Coordinator::start(1, Router::default(), None);
    coord.submit_batch(vec![
        Job { id: 10, a: a.clone(), b: a.clone(), force_route: None },
        Job { id: 11, a: a.clone(), b: poisoned_b(n, 150), force_route: None },
        Job { id: 12, a: a.clone(), b: a.clone(), force_route: None },
    ]);
    // one worker executes batch members sequentially, so results arrive
    // in member order
    let r10 = coord.recv().expect("member 0 reports");
    let r11 = coord.recv().expect("member 1 reports even when poisoned");
    let r12 = coord.recv().expect("member 2 survives its poisoned predecessor");
    assert_eq!((r10.id, r11.id, r12.id), (10, 11, 12));
    assert!(r11.c.is_err(), "the poisoned member must fail alone");
    assert_eq!(r10.c.unwrap(), solo, "sibling before the poison is bitwise-identical to solo");
    assert_eq!(r12.c.unwrap(), solo, "sibling after the poison is bitwise-identical to solo");
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.jobs_failed, 1);
    assert_eq!(snap.jobs_completed, 2);
    assert_eq!(snap.batches, 1);
    assert_eq!(snap.batched_jobs, 3);
    // the worker (pool + cache included) is untouched by the panic
    coord.submit(Job { id: 13, a: a.clone(), b: a.clone(), force_route: None });
    assert!(coord.recv().unwrap().c.is_ok(), "worker survives a poisoned batch member");
    coord.shutdown();
}

#[test]
fn extreme_value_magnitudes_survive() {
    let a = Csr::from_parts(
        2,
        2,
        vec![0, 2, 4],
        vec![0, 1, 0, 1],
        vec![1e150, 1e-150, -1e150, 1e-150],
    )
    .unwrap();
    let out = multiply(&a, &a, &OpSparseConfig::default()).unwrap();
    let gold = opsparse::spgemm::reference::spgemm_reference(&a, &a);
    assert!(out.c.approx_eq(&gold, 1e-9), "{:?}", out.c.diff(&gold, 1e-9));
    assert!(out.c.val.iter().all(|v| v.is_finite()));
}
