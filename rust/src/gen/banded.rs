//! Banded / FEM-like generator: each row has `k` nonzeros clustered inside
//! a band of width `band` around the diagonal, with strong overlap between
//! neighbouring rows. Squaring such a matrix yields many duplicate column
//! hits per output row ⇒ **high compression ratio**, like cant (CR 15.45),
//! consph (17.48), pdb1HYS (28.34) in Table 3.

use super::build_rows;
use crate::sparse::Csr;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Banded {
    pub n: usize,
    /// Target nonzeros per row.
    pub per_row: usize,
    /// Band half-width; columns are drawn from `[i-band, i+band]`.
    pub band: usize,
    /// Fraction of rows that get a contiguous run (FEM block rows) instead
    /// of scattered in-band columns.
    pub contiguous_frac: f64,
}

impl Banded {
    pub fn generate(&self, rng: &mut Rng) -> Csr {
        let n = self.n;
        let band = self.band.max(self.per_row);
        build_rows(n, n, rng, |i, rng, out| {
            let lo = i.saturating_sub(band);
            let hi = (i + band + 1).min(n);
            let width = hi - lo;
            let k = {
                // jitter row size +-25%
                let base = self.per_row.max(1);
                let j = rng.range(0, base / 2 + 1);
                (base - base / 4 + j).min(width)
            };
            if rng.f64() < self.contiguous_frac {
                // contiguous run of k columns (dense FEM block)
                let start = lo + rng.range(0, width.saturating_sub(k) + 1);
                for c in start..start + k {
                    out.push(c as u32);
                }
            } else {
                let mut tmp = Vec::new();
                rng.sample_distinct(width, k, &mut tmp);
                for c in tmp {
                    out.push((lo + c as usize) as u32);
                }
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::stats::{compression_ratio, total_nprod};
    use crate::spgemm_reference_for_tests as reference;

    #[test]
    fn shape_and_band() {
        let g = Banded { n: 500, per_row: 20, band: 40, contiguous_frac: 0.7 };
        let mut rng = Rng::new(7);
        let m = g.generate(&mut rng);
        m.validate().unwrap();
        assert_eq!(m.rows, 500);
        for i in 0..m.rows {
            for &c in m.row_cols(i) {
                let d = (c as i64 - i as i64).unsigned_abs() as usize;
                assert!(d <= 40 + 20, "column {c} too far from diagonal {i}");
            }
        }
    }

    #[test]
    fn high_compression_ratio() {
        let g = Banded { n: 800, per_row: 30, band: 25, contiguous_frac: 0.8 };
        let mut rng = Rng::new(3);
        let m = g.generate(&mut rng);
        let c = reference(&m, &m);
        let cr = compression_ratio(total_nprod(&m, &m), c.nnz());
        assert!(cr > 5.0, "banded FEM-like matrix should have high CR, got {cr:.2}");
    }

    #[test]
    fn deterministic_given_seed() {
        let g = Banded { n: 100, per_row: 8, band: 12, contiguous_frac: 0.5 };
        let a = g.generate(&mut Rng::new(42));
        let b = g.generate(&mut Rng::new(42));
        assert_eq!(a, b);
    }
}
