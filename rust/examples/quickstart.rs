//! Quickstart: generate a matrix, compute A² with OpSparse, verify it
//! against the sort-merge reference, and inspect the simulated V100
//! timeline.
//!
//! Run: `cargo run --release --example quickstart`

use opsparse::baselines::Library;
use opsparse::gen::suite::{suite_entry, SuiteScale};
use opsparse::gpusim::{simulate, V100};
use opsparse::spgemm::reference::spgemm_reference;
use opsparse::util::fmt;

fn main() -> anyhow::Result<()> {
    // 1. a matrix from the paper's suite (synthetic stand-in, Table 3 id 12)
    let entry = suite_entry("poisson3Da").expect("suite matrix");
    let a = entry.generate(SuiteScale::Small);
    println!(
        "A: {} ({}) — {}x{}, nnz {}",
        entry.name,
        entry.class,
        a.rows,
        a.cols,
        fmt::count(a.nnz())
    );

    // 2. C = A * A through the full OpSparse pipeline
    let out = Library::OpSparse.run(&a, &a)?;
    println!(
        "C: {}x{}, nnz {}, n_prod {} (CR {:.2})",
        out.c.rows,
        out.c.cols,
        fmt::count(out.c.nnz()),
        fmt::count(out.nprod),
        out.nprod as f64 / out.c.nnz() as f64
    );

    // 3. verify element-exact against the gold reference
    let gold = spgemm_reference(&a, &a);
    match out.c.diff(&gold, 1e-9) {
        None => println!("verify: OK"),
        Some(d) => anyhow::bail!("verify failed: {d}"),
    }

    // 4. simulate the device trace on the V100 model
    let tl = simulate(&out.trace, &V100);
    println!("simulated V100 time: {}", fmt::ns(tl.total_ns));
    println!("  => {:.2} GFLOPS (paper metric: 2*n_prod/time)", tl.gflops(out.flops()));
    for step in ["setup", "sym_binning", "symbolic", "alloc_c", "num_binning", "numeric"] {
        println!("  {:<12} {}", step, fmt::ns(tl.step_ns(step)));
    }
    println!(
        "hash stats: sym collisions/insert {:.3}, num {:.3}",
        out.sym_stats.collision_rate(),
        out.num_stats.collision_rate()
    );
    Ok(())
}
