//! Table regenerators: Tables 1, 2 (kernel configs + occupancy), 3 (suite
//! statistics, paper vs measured), 4, 5 (binning ranges).

use crate::gen::suite::{entries, SuiteScale};
use crate::sparse::stats::{compression_ratio, total_nprod, MatrixStats};
use crate::spgemm::kernel_tables::{
    numeric_kernels, symbolic_kernels, NumericRanges, SymbolicRanges, NUM_BINS,
};
use crate::spgemm::reference::spgemm_reference;
use anyhow::Result;

/// Table 1: symbolic-step kernel parameters + the adopted Sym_1.2x ranges.
pub fn table1() {
    println!("\n=== Table 1: symbolic kernels (V100) ===");
    println!("{:<8} {:>10} {:>8} {:>8} {:>10} {:>16}", "kernel", "table", "TB", "rows/TB", "occupancy", "range(1.2x)");
    let ranges = SymbolicRanges::Sym12x.ranges();
    for k in symbolic_kernels() {
        let range = if k.index == 8 {
            "(recompute)".to_string()
        } else if k.index == 7 {
            format!("{}-inf", ranges.upper[6] + 1)
        } else if k.index == 0 {
            format!("0-{}", ranges.upper[0])
        } else {
            format!("{}-{}", ranges.upper[k.index - 1] + 1, ranges.upper[k.index])
        };
        println!(
            "{:<8} {:>10} {:>8} {:>8} {:>9.0}% {:>16}",
            format!("kernel{}", k.index),
            k.table_size.map(|t| t.to_string()).unwrap_or_else(|| "global".into()),
            k.tb_size,
            k.rows_per_block,
            k.theoretical_occupancy() * 100.0,
            range,
        );
    }
}

/// Table 2: numeric-step kernel parameters + the adopted Num_2x ranges.
pub fn table2() {
    println!("\n=== Table 2: numeric kernels (V100) ===");
    println!("{:<8} {:>10} {:>8} {:>8} {:>10} {:>16}", "kernel", "table", "TB", "rows/TB", "occupancy", "range(2x)");
    let ranges = NumericRanges::Num2x.ranges();
    for k in numeric_kernels() {
        let range = if k.index == 7 {
            format!("{}-inf", ranges.upper[6] + 1)
        } else if k.index == 0 {
            format!("0-{}", ranges.upper[0])
        } else {
            format!("{}-{}", ranges.upper[k.index - 1] + 1, ranges.upper[k.index])
        };
        println!(
            "{:<8} {:>10} {:>8} {:>8} {:>9.0}% {:>16}",
            format!("kernel{}", k.index),
            k.table_size.map(|t| t.to_string()).unwrap_or_else(|| "global".into()),
            k.tb_size,
            k.rows_per_block,
            k.theoretical_occupancy() * 100.0,
            range,
        );
    }
}

/// Table 3: the 26-matrix suite — paper columns next to the measured
/// columns of our synthetic stand-ins (the audit of the substitution).
pub fn table3(scale: SuiteScale) -> Result<()> {
    println!("\n=== Table 3: suite statistics, paper vs synthetic stand-ins (scale {scale:?}) ===");
    println!(
        "{:<3} {:<17} {:>9} {:>10} {:>7} {:>7} {:>12} {:>12} {:>6} | {:>7} {:>7}",
        "id", "name", "rows", "nnz", "nnz/r", "max/r", "nprod(A2)", "nnz(A2)", "CR", "CR(pap)", "max(pap)"
    );
    for e in entries() {
        let a = e.generate(scale);
        let s = MatrixStats::of(&a);
        let c = spgemm_reference(&a, &a);
        let nprod = total_nprod(&a, &a);
        let cr = compression_ratio(nprod, c.nnz());
        println!(
            "{:<3} {:<17} {:>9} {:>10} {:>7.1} {:>7} {:>12} {:>12} {:>6.2} | {:>7.2} {:>7}",
            e.id,
            e.name,
            s.rows,
            s.nnz,
            s.avg_row_nnz,
            s.max_row_nnz,
            nprod,
            c.nnz(),
            cr,
            e.paper.cr,
            e.paper.max_row_nnz,
        );
    }
    Ok(())
}

/// Tables 4 + 5: the binning-range presets.
pub fn table4_5() {
    println!("\n=== Table 4: symbolic binning ranges ===");
    println!("{:<8} {:>10} {:>14} {:>14} {:>14}", "kernel", "table", "sym_1x", "sym_1.2x", "sym_1.5x");
    let all: Vec<_> = SymbolicRanges::all().iter().map(|r| r.ranges()).collect();
    let tables = symbolic_kernels();
    for j in 0..NUM_BINS {
        let bounds: Vec<String> = all
            .iter()
            .map(|r| {
                let lo = if j == 0 { 0 } else { r.upper[j - 1] + 1 };
                if r.upper[j] == usize::MAX {
                    format!("{lo}-inf")
                } else {
                    format!("{lo}-{}", r.upper[j])
                }
            })
            .collect();
        println!(
            "{:<8} {:>10} {:>14} {:>14} {:>14}",
            format!("kernel{j}"),
            tables[j].table_size.map(|t| t.to_string()).unwrap_or_else(|| "global".into()),
            bounds[0],
            bounds[1],
            bounds[2]
        );
    }
    println!("\n=== Table 5: numeric binning ranges ===");
    println!(
        "{:<8} {:>10} {:>14} {:>14} {:>14} {:>14}",
        "kernel", "table", "num_1x", "num_1.5x", "num_2x", "num_3x"
    );
    let all: Vec<_> = NumericRanges::all().iter().map(|r| r.ranges()).collect();
    let tables = numeric_kernels();
    for j in 0..NUM_BINS {
        let bounds: Vec<String> = all
            .iter()
            .map(|r| {
                let lo = if j == 0 { 0 } else { r.upper[j - 1] + 1 };
                if r.upper[j] == usize::MAX {
                    format!("{lo}-inf")
                } else {
                    format!("{lo}-{}", r.upper[j])
                }
            })
            .collect();
        println!(
            "{:<8} {:>10} {:>14} {:>14} {:>14} {:>14}",
            format!("kernel{j}"),
            tables[j].table_size.map(|t| t.to_string()).unwrap_or_else(|| "global".into()),
            bounds[0],
            bounds[1],
            bounds[2],
            bounds[3]
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_print_without_panicking() {
        table1();
        table2();
        table4_5();
        table3(SuiteScale::Tiny).unwrap();
    }
}
