//! Multi-source BFS via boolean SpGEMM — the paper's third motivating
//! application [4]: a frontier matrix `F` (sources × vertices) expands by
//! `F ⊗ A` over the `(∨, ∧)` semiring; visited masking keeps frontiers
//! sparse; per-source levels accumulate into a distance table.

use crate::sparse::Csr;
use crate::spgemm::semiring::{spgemm_semiring, BoolOrAnd};
use std::collections::HashSet;

/// BFS levels for each source: `levels[s][v]` = hop distance from
/// `sources[s]` to `v`, or `u32::MAX` if unreachable.
pub struct MsBfsResult {
    pub sources: Vec<u32>,
    pub levels: Vec<Vec<u32>>,
    pub iterations: usize,
}

/// Frontier matrix from the still-active rows.
fn frontier_matrix(nsrc: usize, n: usize, frontiers: &[HashSet<u32>]) -> Csr {
    let mut rpt = vec![0usize; nsrc + 1];
    let mut col: Vec<u32> = Vec::new();
    let val_of = |_c: u32| 1.0;
    for (s, f) in frontiers.iter().enumerate() {
        let mut cs: Vec<u32> = f.iter().copied().collect();
        cs.sort_unstable();
        for c in cs {
            col.push(c);
        }
        rpt[s + 1] = col.len();
    }
    let val: Vec<f64> = col.iter().map(|&c| val_of(c)).collect();
    Csr { rows: nsrc, cols: n, rpt, col, val }
}

/// Multi-source BFS over the adjacency matrix `a` (directed; treat rows
/// as out-edges).
pub fn msbfs(a: &Csr, sources: &[u32]) -> MsBfsResult {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let nsrc = sources.len();
    let mut levels = vec![vec![u32::MAX; n]; nsrc];
    let mut visited: Vec<HashSet<u32>> = vec![HashSet::new(); nsrc];
    let mut frontier: Vec<HashSet<u32>> = vec![HashSet::new(); nsrc];
    for (s, &src) in sources.iter().enumerate() {
        levels[s][src as usize] = 0;
        visited[s].insert(src);
        frontier[s].insert(src);
    }
    let mut depth = 0u32;
    let mut iterations = 0usize;
    while frontier.iter().any(|f| !f.is_empty()) {
        iterations += 1;
        depth += 1;
        let f = frontier_matrix(nsrc, n, &frontier);
        // one boolean SpGEMM expands every source's frontier at once
        let next = spgemm_semiring::<BoolOrAnd>(&f, a);
        for s in 0..nsrc {
            frontier[s].clear();
            for &v in next.row_cols(s) {
                if visited[s].insert(v) {
                    levels[s][v as usize] = depth;
                    frontier[s].insert(v);
                }
            }
        }
    }
    MsBfsResult { sources: sources.to_vec(), levels, iterations }
}

/// Scalar single-source BFS oracle.
pub fn bfs_scalar(a: &Csr, src: u32) -> Vec<u32> {
    let n = a.rows;
    let mut level = vec![u32::MAX; n];
    let mut queue = std::collections::VecDeque::new();
    level[src as usize] = 0;
    queue.push_back(src as usize);
    while let Some(u) = queue.pop_front() {
        for &c in a.row_cols(u) {
            let v = c as usize;
            if level[v] == u32::MAX {
                level[v] = level[u] + 1;
                queue.push_back(v);
            }
        }
    }
    level
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::kron::Kron;
    use crate::util::rng::Rng;

    #[test]
    fn matches_scalar_bfs_on_rmat() {
        let g = Kron { scale: 8, edge_factor: 6, ..Default::default() }
            .generate(&mut Rng::new(44));
        let sources = [0u32, 17, 200];
        let r = msbfs(&g, &sources);
        for (s, &src) in sources.iter().enumerate() {
            let gold = bfs_scalar(&g, src);
            assert_eq!(r.levels[s], gold, "source {src}");
        }
        assert!(r.iterations > 0);
    }

    #[test]
    fn path_graph_levels() {
        // 0 -> 1 -> 2 -> 3
        let a = Csr::from_parts(
            4,
            4,
            vec![0, 1, 2, 3, 3],
            vec![1, 2, 3],
            vec![1.0; 3],
        )
        .unwrap();
        let r = msbfs(&a, &[0]);
        assert_eq!(r.levels[0], vec![0, 1, 2, 3]);
    }

    #[test]
    fn unreachable_stays_max() {
        // two disconnected nodes
        let a = Csr::zero(2, 2);
        let r = msbfs(&a, &[0]);
        assert_eq!(r.levels[0], vec![0, u32::MAX]);
    }
}
