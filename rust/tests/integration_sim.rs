//! Simulator-level integration: the paper's headline *shapes* must hold
//! on the simulated timelines (who wins, what hides behind what).

use opsparse::baselines::Library;
use opsparse::bench::run_and_simulate;
use opsparse::gen::suite::{entries, suite_entry, SuiteScale};
use opsparse::gpusim::{simulate, V100};
use opsparse::spgemm::pipeline::{multiply, OpSparseConfig};

#[test]
fn opsparse_beats_both_binned_baselines_on_most_matrices() {
    let mut wins = 0usize;
    let mut total = 0usize;
    for e in entries().into_iter().filter(|e| !e.large) {
        let a = e.generate(SuiteScale::Tiny);
        let (_, tl_ops) = run_and_simulate(Library::OpSparse, &a, false).unwrap();
        let (_, tl_nsp) = run_and_simulate(Library::Nsparse, &a, false).unwrap();
        let (_, tl_spk) = run_and_simulate(Library::Speck, &a, false).unwrap();
        total += 1;
        if tl_ops.total_ns < tl_nsp.total_ns && tl_ops.total_ns < tl_spk.total_ns {
            wins += 1;
        }
    }
    assert!(
        wins * 10 >= total * 8,
        "OpSparse should win on >=80% of matrices, won {wins}/{total}"
    );
}

#[test]
fn cusparse_is_slowest_on_skewed_matrices() {
    // Small scale: at Tiny the fixed launch overheads dominate and the
    // binned pipelines can't amortize them (the paper's matrices are
    // full-size for the same reason)
    // power-law matrices: the single-kernel design pays its worst-case
    // table for every tiny row and recomputes the giant rows
    for name in ["webbase-1M", "scircuit"] {
        let a = suite_entry(name).unwrap().generate(SuiteScale::Small);
        let (_, tl_cus) = run_and_simulate(Library::Cusparse, &a, false).unwrap();
        let (_, tl_ops) = run_and_simulate(Library::OpSparse, &a, false).unwrap();
        assert!(
            tl_ops.total_ns < tl_cus.total_ns,
            "{name}: OpSparse {} vs cuSPARSE {}",
            tl_ops.total_ns,
            tl_cus.total_ns
        );
    }
}

#[test]
fn binning_share_is_an_order_of_magnitude_smaller_in_opsparse() {
    // paper: nsparse/spECK binning ~10% of total on average; OpSparse ~1.5%
    let mut ops_frac = Vec::new();
    let mut nsp_frac = Vec::new();
    for e in entries().into_iter().filter(|e| !e.large).take(6) {
        let a = e.generate(SuiteScale::Small);
        let (_, tl_o) = run_and_simulate(Library::OpSparse, &a, false).unwrap();
        let (_, tl_n) = run_and_simulate(Library::Nsparse, &a, false).unwrap();
        ops_frac.push((tl_o.step_ns("sym_binning") + tl_o.step_ns("num_binning")) / tl_o.total_ns);
        nsp_frac.push((tl_n.step_ns("sym_binning") + tl_n.step_ns("num_binning")) / tl_n.total_ns);
    }
    let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    assert!(
        avg(&nsp_frac) > 3.0 * avg(&ops_frac),
        "nsparse binning share {:.3} should dwarf OpSparse {:.3}",
        avg(&nsp_frac),
        avg(&ops_frac)
    );
}

#[test]
fn webbase_case_study_giant_row_hides_rest() {
    // §6.3.4: total numeric time ~ max(giant kernel, rest), not the sum
    let a = suite_entry("webbase-1M").unwrap().generate(SuiteScale::Small);
    let (_, tl) = run_and_simulate(Library::OpSparse, &a, false).unwrap();
    let giant = tl
        .kernels
        .iter()
        .filter(|k| k.name == "num_kernel7_global" && k.end.is_finite())
        .map(|k| k.end - k.start)
        .fold(0.0f64, f64::max);
    if giant == 0.0 {
        // scaled-down stand-in may not trigger the global kernel at Small;
        // the mechanism is separately covered in scheduler tests
        return;
    }
    // kernel-only span union vs sum of durations (host mallocs excluded —
    // at reduced scale no kernel is long enough to hide the 67us malloc)
    let mut spans: Vec<(f64, f64)> = tl
        .kernels
        .iter()
        .filter(|k| k.step == "numeric" && k.end.is_finite())
        .map(|k| (k.start, k.end))
        .collect();
    spans.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut union = 0.0;
    let mut cur: Option<(f64, f64)> = None;
    for (s, e) in spans {
        cur = match cur {
            None => Some((s, e)),
            Some((cs, ce)) if s <= ce => Some((cs, ce.max(e))),
            Some((cs, ce)) => {
                union += ce - cs;
                Some((s, e))
            }
        };
    }
    if let Some((cs, ce)) = cur {
        union += ce - cs;
    }
    let numeric_sum = tl.step_kernel_sum_ns("numeric");
    assert!(
        union < numeric_sum * 0.85,
        "concurrent kernels should overlap: union {union} vs sum {numeric_sum}"
    );
    // the giant kernel's span must intersect at least one other numeric
    // kernel's span (other rows execute while the giant row runs, §6.3.4)
    let g = tl
        .kernels
        .iter()
        .find(|k| k.name == "num_kernel7_global" && k.end.is_finite())
        .unwrap();
    let overlaps = tl.kernels.iter().any(|k| {
        k.step == "numeric"
            && k.name != g.name
            && k.end.is_finite()
            && k.start < g.end
            && g.start < k.end
    });
    assert!(overlaps, "no numeric kernel overlaps the giant-row kernel");
}

#[test]
fn malloc_overlap_saves_time_on_webbase() {
    // §6.3.5: the global-table malloc hides behind the first numeric kernel
    let a = suite_entry("webbase-1M").unwrap().generate(SuiteScale::Small);
    let mut on = OpSparseConfig::default();
    on.overlap_malloc = true;
    let mut off = OpSparseConfig::default();
    off.overlap_malloc = false;
    let t_on = simulate(&multiply(&a, &a, &on).unwrap().trace, &V100).total_ns;
    let t_off = simulate(&multiply(&a, &a, &off).unwrap().trace, &V100).total_ns;
    assert!(
        t_on <= t_off,
        "overlap must not hurt: on={t_on} off={t_off}"
    );
}

#[test]
fn eager_free_hurts_or_equals() {
    let a = suite_entry("webbase-1M").unwrap().generate(SuiteScale::Small);
    let mut eager = OpSparseConfig::default();
    eager.deferred_free = false;
    let t_deferred =
        simulate(&multiply(&a, &a, &OpSparseConfig::default()).unwrap().trace, &V100).total_ns;
    let t_eager = simulate(&multiply(&a, &a, &eager).unwrap().trace, &V100).total_ns;
    assert!(t_deferred <= t_eager * 1.001, "deferred {t_deferred} vs eager {t_eager}");
}
