//! Regular stencil generator on 1D/2D/3D grids: every row has the same
//! small set of neighbours (k-point stencil). Matches the uniform-row
//! matrices of Table 3 (m133-b3: 4/row, mc2depi: 4/row, mario002: ~5.4/row,
//! majorbasis: ~11/row) with low compression ratio (1.0–2.3).

use super::build_rows;
use crate::sparse::Csr;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Grid {
    /// 1D chain of length n.
    D1,
    /// 2D square grid (side = n.isqrt()).
    D2,
    /// 3D cube grid (side = n.cbrt()).
    D3,
}

#[derive(Clone, Debug)]
pub struct Stencil {
    pub n: usize,
    pub grid: Grid,
    /// Stencil reach: offsets within +-reach per axis are candidates.
    pub reach: usize,
    /// Keep-probability per candidate neighbour (1.0 = full stencil).
    pub keep: f64,
    /// Include the diagonal.
    pub diagonal: bool,
}

impl Stencil {
    pub fn generate(&self, rng: &mut Rng) -> Csr {
        let n = self.n;
        match self.grid {
            Grid::D1 => build_rows(n, n, rng, |i, rng, out| {
                for d in 1..=self.reach {
                    if i >= d && rng.f64() < self.keep {
                        out.push((i - d) as u32);
                    }
                    if i + d < n && rng.f64() < self.keep {
                        out.push((i + d) as u32);
                    }
                }
                if self.diagonal {
                    out.push(i as u32);
                }
            }),
            Grid::D2 => {
                let side = (n as f64).sqrt() as usize;
                let n = side * side;
                build_rows(n, n, rng, |i, rng, out| {
                    let (x, y) = (i % side, i / side);
                    for dy in -(self.reach as i64)..=(self.reach as i64) {
                        for dx in -(self.reach as i64)..=(self.reach as i64) {
                            if dx == 0 && dy == 0 {
                                continue;
                            }
                            // 5-point-style cross for reach=1, keep thins it
                            if dx != 0 && dy != 0 && self.reach == 1 {
                                continue;
                            }
                            let (nx, ny) = (x as i64 + dx, y as i64 + dy);
                            if nx >= 0
                                && ny >= 0
                                && (nx as usize) < side
                                && (ny as usize) < side
                                && rng.f64() < self.keep
                            {
                                out.push((ny as usize * side + nx as usize) as u32);
                            }
                        }
                    }
                    if self.diagonal {
                        out.push(i as u32);
                    }
                })
            }
            Grid::D3 => {
                let side = (n as f64).cbrt().round() as usize;
                let n = side * side * side;
                build_rows(n, n, rng, |i, rng, out| {
                    let (x, rem) = (i % side, i / side);
                    let (y, z) = (rem % side, rem / side);
                    for dz in -(self.reach as i64)..=(self.reach as i64) {
                        for dy in -(self.reach as i64)..=(self.reach as i64) {
                            for dx in -(self.reach as i64)..=(self.reach as i64) {
                                if dx == 0 && dy == 0 && dz == 0 {
                                    continue;
                                }
                                let (nx, ny, nz) =
                                    (x as i64 + dx, y as i64 + dy, z as i64 + dz);
                                if nx >= 0
                                    && ny >= 0
                                    && nz >= 0
                                    && (nx as usize) < side
                                    && (ny as usize) < side
                                    && (nz as usize) < side
                                    && rng.f64() < self.keep
                                {
                                    let ni = (nz as usize * side + ny as usize) * side
                                        + nx as usize;
                                    out.push(ni as u32);
                                }
                            }
                        }
                    }
                    if self.diagonal {
                        out.push(i as u32);
                    }
                })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::stats::{compression_ratio, total_nprod, MatrixStats};
    use crate::spgemm_reference_for_tests as reference;

    #[test]
    fn d1_chain_rows_bounded() {
        let g = Stencil { n: 100, grid: Grid::D1, reach: 2, keep: 1.0, diagonal: false };
        let m = g.generate(&mut Rng::new(1));
        m.validate().unwrap();
        assert!(m.max_row_nnz() <= 4);
        assert!(MatrixStats::of(&m).avg_row_nnz > 3.0);
    }

    #[test]
    fn d2_five_point_low_cr() {
        let g = Stencil { n: 900, grid: Grid::D2, reach: 1, keep: 1.0, diagonal: false };
        let m = g.generate(&mut Rng::new(2));
        m.validate().unwrap();
        assert_eq!(m.rows, 900);
        assert!(m.max_row_nnz() <= 4);
        let c = reference(&m, &m);
        let cr = compression_ratio(total_nprod(&m, &m), c.nnz());
        assert!(cr < 2.0, "5-point stencil squared has low CR, got {cr:.2}");
    }

    #[test]
    fn d3_rows() {
        let g = Stencil { n: 512, grid: Grid::D3, reach: 1, keep: 1.0, diagonal: true };
        let m = g.generate(&mut Rng::new(3));
        m.validate().unwrap();
        assert_eq!(m.rows, 512); // 8^3
        assert!(m.max_row_nnz() <= 27);
    }
}
