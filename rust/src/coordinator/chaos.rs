//! Deterministic fault injection for the worker fleet.
//!
//! Chaos is applied at **sub-job boundaries**: after a worker dequeues a
//! message and before it executes, the worker consults its
//! [`WorkerChaos`] stream and may be delayed (a straggler), have its
//! device pool torn down (memory pressure), or die outright (the
//! process-kill case — the worker still *owns* the dequeued message, so
//! the death path can requeue it onto the surviving fleet; see
//! `coordinator::service`). Injecting only at boundaries keeps results
//! bit-identical: a sub-job either runs the normal code path to
//! completion or never starts on that worker.
//!
//! Every decision comes from a per-`(seed, worker_id, generation)`
//! xoshiro stream with a fixed draw order, so the same
//! [`ChaosConfig::seed`] replays the same kill/delay/shrink schedule —
//! chaos CI failures reproduce locally (`tests/chaos.rs` pins this).

use crate::util::rng::{splitmix64, Rng};

/// Fault-injection knobs. `Default` (and [`ChaosConfig::off`]) injects
/// nothing — the fleet behaves exactly as without the chaos layer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChaosConfig {
    /// Probability a worker dies at a sub-job boundary (its in-flight
    /// message is requeued onto the surviving fleet, a replacement
    /// worker spawns).
    pub kill_prob: f64,
    /// Injected straggler delay per boundary, drawn uniformly from
    /// `[lo, hi)` ns. `(0, 0)` injects no delay.
    pub delay_ns_range: (u64, u64),
    /// Probability the worker's device pool + pattern cache are torn
    /// down at a boundary (simulated memory pressure; the next sub-job
    /// runs cold but correct).
    pub mem_pressure: f64,
    /// Root seed for the deterministic schedule.
    pub seed: u64,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        ChaosConfig::off()
    }
}

impl ChaosConfig {
    /// No injection at all (the production default).
    pub fn off() -> Self {
        ChaosConfig { kill_prob: 0.0, delay_ns_range: (0, 0), mem_pressure: 0.0, seed: 0 }
    }

    /// Mild background faults: rare deaths, sub-200µs stragglers, the
    /// occasional pool teardown. Under `gentle` every job must still
    /// complete (CI gates `BENCH_chaos.json` on a 100% completion rate).
    pub fn gentle() -> Self {
        ChaosConfig { kill_prob: 0.02, delay_ns_range: (0, 200_000), mem_pressure: 0.05, seed: 0 }
    }

    /// Hostile fleet: a quarter of boundaries kill the worker, delays up
    /// to 2ms, frequent pool teardowns. Jobs may exhaust their retry
    /// budget here — the contract is bit-identical result *or* clean
    /// typed error, never a hang or a torn stitch.
    pub fn aggressive() -> Self {
        ChaosConfig {
            kill_prob: 0.25,
            delay_ns_range: (0, 2_000_000),
            mem_pressure: 0.25,
            seed: 0,
        }
    }

    /// Parse a preset name (`off` / `gentle` / `aggressive`).
    pub fn preset(name: &str) -> Option<Self> {
        match name {
            "off" | "0" | "false" => Some(ChaosConfig::off()),
            "gentle" => Some(ChaosConfig::gentle()),
            "aggressive" => Some(ChaosConfig::aggressive()),
            _ => None,
        }
    }

    /// The preset with a specific root seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// True when no fault can ever fire — the worker loop skips the
    /// stream entirely, so `off` is byte-for-byte the pre-chaos path.
    pub fn is_off(&self) -> bool {
        self.kill_prob <= 0.0
            && self.mem_pressure <= 0.0
            && self.delay_ns_range.1 <= self.delay_ns_range.0
            && self.delay_ns_range.0 == 0
    }
}

/// What the stream decided for one sub-job boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BoundaryFault {
    /// The worker dies here (after requeueing its in-flight message).
    pub kill: bool,
    /// Injected straggler delay (0 = none).
    pub delay_ns: u64,
    /// Tear down the worker's device pool + pattern cache.
    pub shrink_pool: bool,
}

impl BoundaryFault {
    pub fn none() -> Self {
        BoundaryFault { kill: false, delay_ns: 0, shrink_pool: false }
    }
}

/// One worker's deterministic fault stream. Seeded from
/// `(cfg.seed, worker_id, generation)` — a replacement worker (same id,
/// generation + 1) gets a fresh stream, so a kill doesn't replay
/// immediately on the respawn.
pub struct WorkerChaos {
    cfg: ChaosConfig,
    rng: Rng,
}

impl WorkerChaos {
    pub fn new(cfg: &ChaosConfig, worker_id: usize, generation: u64) -> Self {
        // splitmix the three inputs into one stream seed; xor-folding
        // alone would collide (id, gen) pairs like (0,1)/(1,0)
        let mut s = cfg.seed;
        let mut mix = splitmix64(&mut s);
        s = mix ^ (worker_id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        mix = splitmix64(&mut s);
        s = mix ^ generation.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        let seed = splitmix64(&mut s);
        WorkerChaos { cfg: *cfg, rng: Rng::new(seed) }
    }

    /// Draw the fault decision for the next sub-job boundary. The draw
    /// order (kill, delay, shrink) is fixed so the schedule for a given
    /// config is a pure function of `(seed, worker_id, generation,
    /// boundary index)`.
    pub fn at_boundary(&mut self) -> BoundaryFault {
        if self.cfg.is_off() {
            return BoundaryFault::none();
        }
        let kill = self.rng.f64() < self.cfg.kill_prob;
        let (lo, hi) = self.cfg.delay_ns_range;
        let delay_ns = if hi > lo { lo + self.rng.below(hi - lo) } else { lo };
        let shrink_pool = self.rng.f64() < self.cfg.mem_pressure;
        BoundaryFault { kill, delay_ns, shrink_pool }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_injects_nothing_ever() {
        let mut c = WorkerChaos::new(&ChaosConfig::off(), 3, 0);
        for _ in 0..1000 {
            assert_eq!(c.at_boundary(), BoundaryFault::none());
        }
        assert!(ChaosConfig::off().is_off());
        assert!(ChaosConfig::default().is_off());
        assert!(!ChaosConfig::gentle().is_off());
        assert!(!ChaosConfig::aggressive().is_off());
    }

    #[test]
    fn same_seed_same_schedule() {
        let cfg = ChaosConfig::aggressive().with_seed(42);
        let mut a = WorkerChaos::new(&cfg, 1, 0);
        let mut b = WorkerChaos::new(&cfg, 1, 0);
        let sa: Vec<BoundaryFault> = (0..256).map(|_| a.at_boundary()).collect();
        let sb: Vec<BoundaryFault> = (0..256).map(|_| b.at_boundary()).collect();
        assert_eq!(sa, sb, "the schedule is a pure function of (seed, id, gen)");
    }

    #[test]
    fn workers_and_generations_get_distinct_streams() {
        let cfg = ChaosConfig::aggressive().with_seed(7);
        let draw = |id, gen| -> Vec<BoundaryFault> {
            let mut c = WorkerChaos::new(&cfg, id, gen);
            (0..64).map(|_| c.at_boundary()).collect()
        };
        assert_ne!(draw(0, 0), draw(1, 0), "per-worker streams differ");
        assert_ne!(draw(0, 0), draw(0, 1), "a respawn gets a fresh stream");
        assert_ne!(draw(0, 1), draw(1, 0), "(id, gen) pairs don't fold together");
    }

    #[test]
    fn aggressive_actually_fires_each_fault_kind() {
        let cfg = ChaosConfig::aggressive().with_seed(9);
        let mut c = WorkerChaos::new(&cfg, 0, 0);
        let faults: Vec<BoundaryFault> = (0..512).map(|_| c.at_boundary()).collect();
        assert!(faults.iter().any(|f| f.kill));
        assert!(faults.iter().any(|f| f.delay_ns > 0));
        assert!(faults.iter().any(|f| f.shrink_pool));
        let kills = faults.iter().filter(|f| f.kill).count();
        assert!((64..192).contains(&kills), "kill rate far off 25%: {kills}/512");
    }

    #[test]
    fn preset_parsing() {
        assert_eq!(ChaosConfig::preset("off"), Some(ChaosConfig::off()));
        assert_eq!(ChaosConfig::preset("gentle"), Some(ChaosConfig::gentle()));
        assert_eq!(ChaosConfig::preset("aggressive"), Some(ChaosConfig::aggressive()));
        assert_eq!(ChaosConfig::preset("cruel"), None);
        assert_eq!(ChaosConfig::preset("gentle").unwrap().with_seed(5).seed, 5);
    }
}
