//! `cargo bench --bench shard_scaling` — row-sharded multi-device SpGEMM
//! on a power-law matrix at 1/2/4/8 shards: per-device makespan under the
//! serial **and** the overlapped (pipelined broadcast/compute/gather)
//! schedule, modeled `B`-broadcast and `C`-gather interconnect costs,
//! planned and measured load imbalance, and both (honest,
//! communication-charged) scaling-efficiency columns.
//!
//! Env:
//! * `OPSPARSE_SCALE=tiny|small|medium` (default small)
//! * `OPSPARSE_INTERCONNECT=pcie|nvlink|none` (default pcie)
//! * `OPSPARSE_OVERLAP=off` — disable the pipelined schedule (ablation)
//! * `OPSPARSE_OVERLAP_CHUNK_KB=<n>` — broadcast chunk size (default 1024)
//! * `OPSPARSE_BENCH_JSON=<path>` — record the full rows as JSON; CI
//!   writes `BENCH_shards.json` this way, next to `BENCH_seed.json`.
//! * `OPSPARSE_BENCH_JSON_OVERLAP=<path>` — record the serial-vs-
//!   overlapped makespan ablation (`BENCH_overlap.json` in CI, where a
//!   blocking check asserts overlapped ≤ serial on every row).
//! * `OPSPARSE_REPLAN=on` — also run the adaptive re-planning ablation
//!   (cold proxy-cut vs warm measured re-cut per generator family and
//!   shard count), asserting warm ≤ cold on every row.
//! * `OPSPARSE_BENCH_JSON_ADAPTIVE=<path>` — record that ablation
//!   (`BENCH_adaptive.json` in CI, with a blocking warm-≤-cold check).
//!
//! The bench itself also enforces the overlap invariant: an overlapped
//! makespan above the serial one is a model regression and fails the run.

use opsparse::bench::{figures, write_adaptive_json, write_overlap_json, write_shard_scaling_json};
use opsparse::gen::suite::SuiteScale;
use opsparse::gpusim::{Interconnect, OverlapConfig};

fn main() {
    let scale = std::env::var("OPSPARSE_SCALE")
        .ok()
        .and_then(|s| SuiteScale::parse(&s))
        .unwrap_or(SuiteScale::Small);
    let ic = match std::env::var("OPSPARSE_INTERCONNECT").as_deref() {
        Ok(name) => Interconnect::parse_opt(name).expect("pcie|nvlink|none"),
        Err(_) => Some(Interconnect::pcie3()),
    };
    let overlap = OverlapConfig::from_env();
    let rows =
        figures::shard_scaling_with(scale, ic.as_ref(), overlap).expect("shard_scaling bench");
    for r in &rows {
        assert!(
            r.overlapped_makespan_ns <= r.makespan_ns + 1e-6,
            "{} shards: overlapped makespan {:.1}us exceeds serial {:.1}us — model regression",
            r.shards,
            r.overlapped_makespan_ns / 1e3,
            r.makespan_ns / 1e3
        );
    }
    if let Ok(path) = std::env::var("OPSPARSE_BENCH_JSON") {
        write_shard_scaling_json(&path, scale, &rows).expect("write bench json");
    }
    if let Ok(path) = std::env::var("OPSPARSE_BENCH_JSON_OVERLAP") {
        write_overlap_json(&path, scale, &rows).expect("write overlap json");
    }
    let replan_on = std::env::var("OPSPARSE_REPLAN")
        .ok()
        .and_then(|v| opsparse::coordinator::feedback::parse_on_off(&v))
        .unwrap_or(false);
    if replan_on {
        // warm <= cold is asserted inside adaptive_replan itself
        let arows = figures::adaptive_replan(scale).expect("adaptive_replan bench");
        if let Ok(path) = std::env::var("OPSPARSE_BENCH_JSON_ADAPTIVE") {
            write_adaptive_json(&path, scale, &arows).expect("write adaptive json");
        }
    }
}
